"""AOT pipeline tests: manifest entries lower to parseable HLO text and a
lowered kernel executes correctly through XLA (the same engine the rust
runtime drives via PJRT)."""

import json
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.aot import lower_entry
from compile.model import build
from compile.kernels import ref


def test_lowered_hlo_is_text_with_entry():
    text = lower_entry("x", dict(op="relu_f", n=256))
    assert "ENTRY" in text
    assert "f32[256]" in text


def test_gemm_lowering_contains_dot_or_loop():
    text = lower_entry("g", dict(op="gemm_nn", m=20, n=30, k=25, acc=False))
    assert "ENTRY" in text
    # pallas interpret lowering produces a while loop over the grid or a
    # fused dot; either implies real compute made it into the artifact
    assert ("while" in text) or ("dot(" in text)


def test_executable_roundtrip_matches_ref():
    # Compile a lowered fn via jax and compare with the oracle — numerical
    # proof the artifact math is right before rust ever loads it.
    import jax
    spec = dict(op="gemm_nn", m=12, n=18, k=7, acc=True)
    fn, args = build(spec)
    rng = np.random.default_rng(7)
    vals = [rng.standard_normal(a.shape).astype(np.float32) for a in args]
    out = np.asarray(jax.jit(fn)(*vals)[0])
    np.testing.assert_allclose(out, ref.gemm(vals[0], vals[1], c=vals[2]), rtol=2e-4, atol=2e-4)


def test_manifest_present_and_well_formed():
    path = Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
    if not path.exists():
        pytest.skip("artifacts/manifest.json not generated yet (run `make artifacts`)")
    manifest = json.loads(path.read_text())
    arts = manifest["artifacts"]
    assert len(arts) > 100
    # every spec must build
    for key, spec in list(arts.items())[::25]:
        fn, shapes = build(spec)
        assert callable(fn) and shapes, key
