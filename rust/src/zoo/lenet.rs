//! LeNet (Caffe `lenet_train_test.prototxt`) — the paper's Table 4
//! comparison workload against F-CNN: L1 conv(20×5) → L2 pool → L3
//! conv(50×5) → L4 pool → L5 fc(500) → L6 fc(10).

use super::NetBuilder;
use crate::proto::{NetParameter, PoolMethod};

pub fn lenet(batch: usize) -> NetParameter {
    let mut b = NetBuilder::new("LeNet");
    b.data(batch, 1, 28, 10, "digits");
    b.conv("conv1", "data", 20, 5, 1, 0);
    b.pool("pool1", "conv1", PoolMethod::Max, 2, 2, 0);
    b.conv("conv2", "pool1", 50, 5, 1, 0);
    b.pool("pool2", "conv2", PoolMethod::Max, 2, 2, 0);
    b.fc("ip1", "pool2", 500);
    b.relu_inplace("relu1", "ip1");
    b.fc("ip2", "ip1", 10);
    b.accuracy("accuracy", "ip2");
    b.softmax_loss("loss", "ip2", 1.0);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::net::Net;
    use crate::proto::Phase;

    #[test]
    fn builds_with_expected_shapes() {
        let mut dev = CpuDevice::new();
        let param = lenet(2);
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        let shapes: Vec<(String, Vec<usize>)> = ["conv1", "pool1", "conv2", "pool2", "ip1", "ip2"]
            .iter()
            .map(|n| {
                let b = net.blob(n).unwrap();
                let s = b.borrow().shape().to_vec();
                (n.to_string(), s)
            })
            .collect();
        assert_eq!(shapes[0].1, vec![2, 20, 24, 24]);
        assert_eq!(shapes[1].1, vec![2, 20, 12, 12]);
        assert_eq!(shapes[2].1, vec![2, 50, 8, 8]);
        assert_eq!(shapes[3].1, vec![2, 50, 4, 4]);
        assert_eq!(shapes[4].1, vec![2, 500]);
        assert_eq!(shapes[5].1, vec![2, 10]);
        // ~430k params like the classic LeNet
        let p = net.num_parameters();
        assert!((400_000..450_000).contains(&p), "params {p}");
        let loss = net.forward_backward(&mut dev).unwrap();
        assert!(loss.is_finite());
    }
}
