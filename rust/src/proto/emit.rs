//! Prototxt emitter: NetParameter/SolverParameter → text.
//!
//! The model zoo builds networks programmatically; this emitter turns them
//! back into standard prototxt so (a) users can inspect/edit them, and
//! (b) the parser is tested by the emit→parse→emit fixpoint property.

use super::schema::*;
use std::fmt::Write as _;

fn filler(out: &mut String, ind: &str, field: &str, f: &FillerParameter) {
    let _ = writeln!(out, "{ind}{field} {{");
    let _ = writeln!(out, "{ind}  type: \"{}\"", f.kind);
    match f.kind.as_str() {
        "constant" => {
            if f.value != 0.0 {
                let _ = writeln!(out, "{ind}  value: {}", f.value);
            }
        }
        "gaussian" => {
            let _ = writeln!(out, "{ind}  std: {}", f.std);
            if f.mean != 0.0 {
                let _ = writeln!(out, "{ind}  mean: {}", f.mean);
            }
        }
        "uniform" => {
            let _ = writeln!(out, "{ind}  min: {}", f.min);
            let _ = writeln!(out, "{ind}  max: {}", f.max);
        }
        _ => {}
    }
    let _ = writeln!(out, "{ind}}}");
}

pub fn emit_layer(l: &LayerParameter) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "layer {{");
    let _ = writeln!(out, "  name: \"{}\"", l.name);
    let _ = writeln!(out, "  type: \"{}\"", l.kind);
    for b in &l.bottoms {
        let _ = writeln!(out, "  bottom: \"{b}\"");
    }
    for t in &l.tops {
        let _ = writeln!(out, "  top: \"{t}\"");
    }
    if let Some(ph) = l.phase {
        let _ = writeln!(out, "  include {{ phase: {} }}", ph.ident());
    }
    for lw in &l.loss_weight {
        let _ = writeln!(out, "  loss_weight: {lw}");
    }
    for p in &l.params {
        let _ = writeln!(
            out,
            "  param {{ lr_mult: {} decay_mult: {} }}",
            p.lr_mult, p.decay_mult
        );
    }
    if let Some(c) = &l.conv {
        let _ = writeln!(out, "  convolution_param {{");
        let _ = writeln!(out, "    num_output: {}", c.num_output);
        if c.kernel_h == c.kernel_w {
            let _ = writeln!(out, "    kernel_size: {}", c.kernel_h);
        } else {
            let _ = writeln!(out, "    kernel_h: {}", c.kernel_h);
            let _ = writeln!(out, "    kernel_w: {}", c.kernel_w);
        }
        if (c.stride_h, c.stride_w) != (1, 1) {
            if c.stride_h == c.stride_w {
                let _ = writeln!(out, "    stride: {}", c.stride_h);
            } else {
                let _ = writeln!(out, "    stride_h: {}", c.stride_h);
                let _ = writeln!(out, "    stride_w: {}", c.stride_w);
            }
        }
        if (c.pad_h, c.pad_w) != (0, 0) {
            if c.pad_h == c.pad_w {
                let _ = writeln!(out, "    pad: {}", c.pad_h);
            } else {
                let _ = writeln!(out, "    pad_h: {}", c.pad_h);
                let _ = writeln!(out, "    pad_w: {}", c.pad_w);
            }
        }
        if c.group != 1 {
            let _ = writeln!(out, "    group: {}", c.group);
        }
        if !c.bias_term {
            let _ = writeln!(out, "    bias_term: false");
        }
        filler(&mut out, "    ", "weight_filler", &c.weight_filler);
        filler(&mut out, "    ", "bias_filler", &c.bias_filler);
        let _ = writeln!(out, "  }}");
    }
    if let Some(p) = &l.pool {
        let method = match p.method {
            PoolMethod::Max => "MAX",
            PoolMethod::Ave => "AVE",
        };
        let _ = writeln!(out, "  pooling_param {{");
        let _ = writeln!(out, "    pool: {method}");
        if p.global_pooling {
            let _ = writeln!(out, "    global_pooling: true");
        } else {
            let _ = writeln!(out, "    kernel_size: {}", p.kernel_h);
            let _ = writeln!(out, "    stride: {}", p.stride_h);
            if p.pad_h != 0 {
                let _ = writeln!(out, "    pad: {}", p.pad_h);
            }
        }
        let _ = writeln!(out, "  }}");
    }
    if let Some(ip) = &l.inner_product {
        let _ = writeln!(out, "  inner_product_param {{");
        let _ = writeln!(out, "    num_output: {}", ip.num_output);
        if !ip.bias_term {
            let _ = writeln!(out, "    bias_term: false");
        }
        filler(&mut out, "    ", "weight_filler", &ip.weight_filler);
        filler(&mut out, "    ", "bias_filler", &ip.bias_filler);
        let _ = writeln!(out, "  }}");
    }
    if let Some(p) = &l.lrn {
        let _ = writeln!(
            out,
            "  lrn_param {{ local_size: {} alpha: {} beta: {} k: {} }}",
            p.local_size, p.alpha, p.beta, p.k
        );
    }
    if let Some(d) = &l.dropout {
        let _ = writeln!(out, "  dropout_param {{ dropout_ratio: {} }}", d.dropout_ratio);
    }
    if let Some(c) = &l.concat {
        let _ = writeln!(out, "  concat_param {{ axis: {} }}", c.axis);
    }
    if let Some(d) = &l.data {
        let _ = writeln!(out, "  data_param {{");
        let _ = writeln!(out, "    batch_size: {}", d.batch_size);
        let _ = writeln!(out, "    channels: {}", d.channels);
        let _ = writeln!(out, "    height: {}", d.height);
        let _ = writeln!(out, "    width: {}", d.width);
        let _ = writeln!(out, "    num_classes: {}", d.num_classes);
        let _ = writeln!(out, "    source: \"{}\"", d.source);
        let _ = writeln!(out, "    seed: {}", d.seed);
        let _ = writeln!(out, "  }}");
    }
    if let Some(a) = &l.accuracy {
        if a.top_k != 1 {
            let _ = writeln!(out, "  accuracy_param {{ top_k: {} }}", a.top_k);
        }
    }
    let _ = writeln!(out, "}}");
    out
}

pub fn emit_net(net: &NetParameter) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "name: \"{}\"", net.name);
    for (name, shape) in &net.inputs {
        let _ = writeln!(out, "input: \"{name}\"");
        let _ = writeln!(
            out,
            "input_shape {{ dim: {} dim: {} dim: {} dim: {} }}",
            shape[0], shape[1], shape[2], shape[3]
        );
    }
    for l in &net.layers {
        out.push_str(&emit_layer(l));
    }
    out
}

pub fn emit_solver(s: &SolverParameter) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "net: \"{}\"", s.net);
    let _ = writeln!(out, "type: \"{}\"", s.kind.ident());
    let _ = writeln!(out, "base_lr: {}", s.base_lr);
    let _ = writeln!(out, "lr_policy: \"{}\"", s.lr_policy);
    let _ = writeln!(out, "gamma: {}", s.gamma);
    let _ = writeln!(out, "power: {}", s.power);
    let _ = writeln!(out, "stepsize: {}", s.stepsize);
    for v in &s.stepvalue {
        let _ = writeln!(out, "stepvalue: {v}");
    }
    let _ = writeln!(out, "momentum: {}", s.momentum);
    let _ = writeln!(out, "momentum2: {}", s.momentum2);
    let _ = writeln!(out, "rms_decay: {}", s.rms_decay);
    let _ = writeln!(out, "delta: {}", s.delta);
    let _ = writeln!(out, "weight_decay: {}", s.weight_decay);
    let _ = writeln!(out, "regularization_type: \"{}\"", s.regularization_type);
    let _ = writeln!(out, "max_iter: {}", s.max_iter);
    let _ = writeln!(out, "iter_size: {}", s.iter_size);
    let _ = writeln!(out, "display: {}", s.display);
    let _ = writeln!(out, "snapshot: {}", s.snapshot);
    let _ = writeln!(out, "snapshot_prefix: \"{}\"", s.snapshot_prefix);
    let _ = writeln!(out, "test_iter: {}", s.test_iter);
    let _ = writeln!(out, "test_interval: {}", s.test_interval);
    let _ = writeln!(out, "random_seed: {}", s.random_seed);
    let _ = writeln!(out, "clip_gradients: {}", s.clip_gradients);
    out
}

#[cfg(test)]
mod tests {
    use super::super::{parse_net, parse_solver};
    use super::*;

    #[test]
    fn solver_roundtrip() {
        let mut s = SolverParameter::default();
        s.net = "lenet".into();
        s.kind = SolverKind::RmsProp;
        s.base_lr = 0.003;
        s.lr_policy = "inv".into();
        s.rms_decay = 0.97;
        let text = emit_solver(&s);
        let back = parse_solver(&text).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn solver_roundtrip_multistep() {
        let mut s = SolverParameter::default();
        s.net = "alexnet".into();
        s.lr_policy = "multistep".into();
        s.gamma = 0.1;
        s.stepvalue = vec![1000, 2000, 6000];
        let text = emit_solver(&s);
        assert_eq!(text.matches("stepvalue:").count(), 3);
        let back = parse_solver(&text).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn layer_roundtrip_conv() {
        let mut l = LayerParameter::new("conv1", "Convolution");
        l.bottoms = vec!["data".into()];
        l.tops = vec!["conv1".into()];
        l.params = vec![
            ParamSpec { lr_mult: 1.0, decay_mult: 1.0 },
            ParamSpec { lr_mult: 2.0, decay_mult: 0.0 },
        ];
        let mut c = ConvolutionParameter::default();
        c.num_output = 96;
        c.kernel_h = 11;
        c.kernel_w = 11;
        c.stride_h = 4;
        c.stride_w = 4;
        c.weight_filler.kind = "gaussian".into();
        c.weight_filler.std = 0.01;
        l.conv = Some(c);
        let mut net = NetParameter::default();
        net.name = "t".into();
        net.layers.push(l);
        let text = emit_net(&net);
        let back = parse_net(&text).unwrap();
        assert_eq!(net, back);
    }

    #[test]
    fn net_emit_parse_emit_fixpoint() {
        let mut net = NetParameter::default();
        net.name = "fix".into();
        net.inputs.push(("data".into(), [1, 3, 32, 32]));
        let mut pool = LayerParameter::new("p", "Pooling");
        pool.bottoms = vec!["data".into()];
        pool.tops = vec!["p".into()];
        pool.pool = Some(PoolingParameter {
            method: PoolMethod::Ave,
            kernel_h: 2,
            kernel_w: 2,
            stride_h: 2,
            stride_w: 2,
            pad_h: 0,
            pad_w: 0,
            global_pooling: false,
        });
        net.layers.push(pool);
        let t1 = emit_net(&net);
        let t2 = emit_net(&parse_net(&t1).unwrap());
        assert_eq!(t1, t2);
    }
}
