//! Property tests (hand-rolled tcheck harness — DESIGN.md §10) over the
//! substrates' invariants: allocator, syncedmem coherence, prototxt
//! round-trips, split insertion, and the simulator's queue model.

use fecaffe::blob::{MemState, SyncedMem};
use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::ddr::DdrTracker;
use fecaffe::device::fpga::{FpgaSimDevice, QueueMode};
use fecaffe::device::{Device, Kernel, KernelCall};
use fecaffe::net::insert_splits;
use fecaffe::proto::{self, LayerParameter};
use fecaffe::util::tcheck;

#[test]
fn ddr_tracker_never_overbooks() {
    tcheck::check("ddr_overbook", 64, |rng| {
        let cap = rng.range_u(1_000, 100_000) as u64;
        let mut ddr = DdrTracker::new(cap);
        let mut live: Vec<(usize, u64)> = Vec::new();
        let mut next_id = 0usize;
        for _ in 0..200 {
            if rng.bernoulli(0.6) || live.is_empty() {
                let sz = rng.range_u(1, (cap / 4).max(2) as u32) as u64;
                if ddr.alloc(next_id, sz).is_ok() {
                    live.push((next_id, sz));
                }
                next_id += 1;
            } else {
                let i = rng.below(live.len() as u32) as usize;
                let (id, _) = live.swap_remove(i);
                ddr.free(id);
            }
            let used: u64 = live.iter().map(|(_, s)| s).sum();
            if ddr.used() != used {
                return Err(format!("accounting drift: {} vs {}", ddr.used(), used));
            }
            if ddr.used() > cap {
                return Err("over capacity".into());
            }
        }
        Ok(())
    });
}

#[test]
fn syncedmem_random_walk_never_loses_data() {
    tcheck::check("syncedmem_walk", 48, |rng| {
        let mut dev = CpuDevice::new();
        let n = rng.range_u(1, 64) as usize;
        let mut mem = SyncedMem::new(n);
        // shadow = ground truth
        let mut shadow = vec![0f32; n];
        for step in 0..40 {
            match rng.below(4) {
                0 => {
                    // host write
                    let v = rng.uniform(-5.0, 5.0);
                    let idx = rng.below(n as u32) as usize;
                    mem.host_data_mut(&mut dev)[idx] = v;
                    shadow[idx] = v;
                }
                1 => {
                    // device write through a kernel (scale by known factor)
                    let id = mem.dev_data(&mut dev);
                    let id2 = mem.dev_data_rw(&mut dev);
                    assert_eq!(id, id2);
                    dev.launch(&KernelCall::new(
                        Kernel::Scal { n, alpha: 2.0 },
                        &[id2],
                        &[id2],
                    ))
                    .unwrap();
                    for v in shadow.iter_mut() {
                        *v *= 2.0;
                    }
                }
                2 => {
                    // read host — must equal shadow
                    let host = mem.host_data(&mut dev);
                    if host != &shadow[..] {
                        return Err(format!("step {step}: host {host:?} != {shadow:?}"));
                    }
                }
                _ => {
                    let _ = mem.dev_data(&mut dev); // sync only
                }
            }
        }
        let host = mem.host_data(&mut dev).to_vec();
        if host != shadow {
            return Err("final state diverged".into());
        }
        if mem.state() == MemState::Uninit {
            return Err("state machine stuck at Uninit".into());
        }
        Ok(())
    });
}

#[test]
fn prototxt_emit_parse_emit_fixpoint_random_nets() {
    tcheck::check("prototxt_fixpoint", 32, |rng| {
        // Build a random sequential net with the builder.
        let mut b = fecaffe::zoo::NetBuilder::new("rand");
        b.data(rng.range_u(1, 8) as usize, 1, 16, 4, "digits");
        let mut prev = "data".to_string();
        let depth = rng.range_u(1, 5);
        for i in 0..depth {
            match rng.below(3) {
                0 => {
                    let name = format!("conv{i}");
                    b.conv_relu(&name, &prev, rng.range_u(1, 8) as usize, 3, 1, 1);
                    prev = name;
                }
                1 => {
                    let name = format!("pool{i}");
                    b.pool(&name, &prev, proto::PoolMethod::Max, 2, 2, 0);
                    prev = name;
                }
                _ => {
                    let name = format!("fc{i}");
                    b.fc(&name, &prev, rng.range_u(2, 16) as usize);
                    prev = name;
                }
            }
        }
        b.softmax_loss("loss", &prev, 1.0);
        let net = b.finish();
        let t1 = proto::emit::emit_net(&net);
        let parsed = proto::parse_net(&t1).map_err(|e| e.to_string())?;
        if parsed != net {
            return Err("parse(emit(net)) != net".into());
        }
        let t2 = proto::emit::emit_net(&parsed);
        if t1 != t2 {
            return Err("emit not a fixpoint".into());
        }
        Ok(())
    });
}

#[test]
fn insert_splits_preserves_consumer_counts() {
    tcheck::check("split_consumers", 32, |rng| {
        // Random DAG: each layer consumes a random earlier blob.
        let mut layers = Vec::new();
        let mut d = LayerParameter::new("data", "SyntheticData");
        d.tops = vec!["b0".into()];
        layers.push(d);
        let n = rng.range_u(2, 10) as usize;
        for i in 1..=n {
            let src = rng.below(i as u32) as usize;
            let mut l = LayerParameter::new(&format!("l{i}"), "ReLU");
            l.bottoms = vec![format!("b{src}")];
            l.tops = vec![format!("b{i}")];
            layers.push(l);
        }
        let out = insert_splits(&layers);
        // Invariant 1: every bottom reference resolves to a produced blob.
        let mut produced: std::collections::HashSet<String> = Default::default();
        for l in &out {
            for b in &l.bottoms {
                if !produced.contains(b) {
                    return Err(format!("{}: bottom {b} not yet produced", l.name));
                }
            }
            for t in &l.tops {
                produced.insert(t.clone());
            }
        }
        // Invariant 2: after splitting, no blob is consumed twice.
        let mut seen: std::collections::HashMap<String, usize> = Default::default();
        for l in &out {
            for b in &l.bottoms {
                *seen.entry(b.clone()).or_insert(0) += 1;
            }
        }
        for (b, c) in seen {
            if c > 1 {
                return Err(format!("blob {b} still has {c} consumers"));
            }
        }
        Ok(())
    });
}

#[test]
fn async_never_slower_than_sync() {
    tcheck::check("async_le_sync", 24, |rng| {
        let ops: Vec<(usize, bool)> = (0..rng.range_u(2, 20))
            .map(|_| (rng.range_u(100, 100_000) as usize, rng.bernoulli(0.4)))
            .collect();
        let run = |mode: QueueMode| -> u64 {
            let mut dev = FpgaSimDevice::new();
            dev.timing_only = true;
            dev.set_mode(mode);
            let x = dev.alloc(100_000).unwrap();
            let y = dev.alloc(100_000).unwrap();
            let data = vec![0f32; 100_000];
            for &(n, is_write) in &ops {
                if is_write {
                    dev.write(x, &data[..n]);
                } else {
                    dev.launch(&KernelCall::new(
                        Kernel::ReluF { n, slope: 0.0 },
                        &[x],
                        &[y],
                    ))
                    .unwrap();
                }
            }
            dev.synchronize();
            dev.sim_clock_ns().unwrap()
        };
        let sync = run(QueueMode::Sync);
        let async_ = run(QueueMode::Async);
        if async_ > sync {
            return Err(format!("async {async_} > sync {sync}"));
        }
        Ok(())
    });
}

#[test]
fn gemm_matches_naive_on_random_shapes() {
    tcheck::check("gemm_naive", 32, |rng| {
        let (m, n, k) = (
            rng.range_u(1, 48) as usize,
            rng.range_u(1, 48) as usize,
            rng.range_u(1, 48) as usize,
        );
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        rng.fill_uniform(&mut a, -1.0, 1.0);
        rng.fill_uniform(&mut b, -1.0, 1.0);
        let mut c = vec![0f32; m * n];
        fecaffe::math::gemm(
            fecaffe::math::Trans::No,
            fecaffe::math::Trans::No,
            m,
            n,
            k,
            1.0,
            &a,
            &b,
            0.0,
            &mut c,
        );
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                if (acc - c[i * n + j]).abs() > 1e-3 {
                    return Err(format!("({i},{j}): {acc} vs {}", c[i * n + j]));
                }
            }
        }
        Ok(())
    });
}

/// `parallel_for` must cover every index exactly once and produce results
/// identical to a serial loop, for adversarial (range, grain, budget)
/// combinations — empty ranges, grain larger than the range, grain 1 on
/// large ranges, and every intra-op cap from 1 to the machine width.
#[test]
fn parallel_for_equals_serial_for_adversarial_grains() {
    use fecaffe::util::pool;
    tcheck::check("parallel_for_serial_equiv", 48, |rng| {
        let n = match rng.below(4) {
            0 => 0usize,
            1 => rng.range_u(1, 7) as usize,
            2 => rng.range_u(8, 512) as usize,
            _ => rng.range_u(513, 20_000) as usize,
        };
        let grain = match rng.below(3) {
            0 => 1usize,
            1 => rng.range_u(1, 64) as usize,
            _ => rng.range_u(1, 40_000) as usize, // often > n
        };
        let start = rng.below(1000) as usize;
        let threads = 1 + rng.below(pool::default_threads().max(2) as u32) as usize;

        // Serial reference.
        let mut want = vec![0u64; n];
        for i in 0..n {
            want[i] = ((start + i) as u64).wrapping_mul(0x9e37_79b9);
        }
        // Parallel: each chunk writes its own disjoint window.
        let mut got = vec![0u64; n];
        pool::with_intra_op(threads, || {
            pool::parallel_chunks_mut(&mut got, grain, |off, chunk| {
                for (d, v) in chunk.iter_mut().enumerate() {
                    *v = ((start + off + d) as u64).wrapping_mul(0x9e37_79b9);
                }
            });
        });
        if got != want {
            return Err(format!(
                "mismatch at n={n} grain={grain} threads={threads}"
            ));
        }

        // Exactly-once coverage of an offset range.
        let hits: Vec<std::sync::atomic::AtomicU32> =
            (0..n).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
        pool::with_intra_op(threads, || {
            pool::parallel_for(start..start + n, grain, |r| {
                for i in r {
                    hits[i - start].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            });
        });
        for (i, h) in hits.iter().enumerate() {
            let c = h.load(std::sync::atomic::Ordering::Relaxed);
            if c != 1 {
                return Err(format!(
                    "index {i} covered {c} times (n={n} grain={grain} threads={threads})"
                ));
            }
        }
        Ok(())
    });
}

/// Packed GEMM must be bit-identical across thread budgets *through the
/// device launch path* (what serving and training actually execute).
#[test]
fn device_gemm_bit_identical_across_intra_op_budgets() {
    use fecaffe::util::pool;
    let (m, n, k) = (48usize, 200, 96);
    let mut rng = fecaffe::util::prng::Pcg32::new(40);
    let mut va = vec![0f32; m * k];
    let mut vb = vec![0f32; k * n];
    rng.fill_uniform(&mut va, -1.0, 1.0);
    rng.fill_uniform(&mut vb, -1.0, 1.0);
    let run = |threads: usize| -> Vec<f32> {
        let mut dev = CpuDevice::new().with_intra_op(threads);
        let a = dev.alloc(m * k).unwrap();
        let b = dev.alloc(k * n).unwrap();
        let c = dev.alloc(m * n).unwrap();
        dev.write(a, &va);
        dev.write(b, &vb);
        dev.launch(&KernelCall::new(
            Kernel::GemmNN { m, n, k, alpha: 1.0, beta: 0.0 },
            &[a, b],
            &[c],
        ))
        .unwrap();
        let mut out = vec![0f32; m * n];
        dev.read(c, &mut out);
        out
    };
    let c1 = run(1);
    for t in [2, pool::default_threads().max(2)] {
        assert_eq!(c1, run(t), "intra-op budget {t} changed gemm bits");
    }
}
