//! Deterministic pseudo-random number generation.
//!
//! A small PCG-XSH-RR 64/32 implementation plus the distributions Caffe's
//! fillers need (uniform, Gaussian via Box–Muller, Bernoulli). Determinism
//! matters twice here: weight init must be reproducible across the CPU and
//! FPGA-sim devices for the equivalence tests, and the property-test
//! harness logs seeds for replay.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output. Small, fast, and good
/// enough statistical quality for fillers and test-case generation.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits of a u32.
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        ((u64::from(self.next_u32()) * u64::from(n)) >> 32) as u32
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (one value per call; the pair's
    /// second half is dropped to keep state handling trivial).
    pub fn gaussian(&mut self, mean: f32, std: f32) -> f32 {
        let mut u1 = self.next_f32();
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let u2 = self.next_f32();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f32::consts::PI * u2;
        mean + std * r * theta.cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Fill a slice with uniform values.
    pub fn fill_uniform(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf {
            *v = self.uniform(lo, hi);
        }
    }

    /// Fill a slice with Gaussian values.
    pub fn fill_gaussian(&mut self, buf: &mut [f32], mean: f32, std: f32) {
        for v in buf {
            *v = self.gaussian(mean, std);
        }
    }

    /// Xavier/Glorot-style fill used by Caffe's `xavier` filler:
    /// uniform(-s, s) with s = sqrt(3 / fan_in).
    pub fn fill_xavier(&mut self, buf: &mut [f32], fan_in: usize) {
        let s = (3.0 / fan_in.max(1) as f32).sqrt();
        self.fill_uniform(buf, -s, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg32::new(7);
        let mut sum = 0.0f64;
        let n = 20_000;
        for _ in 0..n {
            let v = rng.uniform(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&v));
            sum += f64::from(v);
        }
        assert!((sum / f64::from(n)).abs() < 0.05, "mean {}", sum / f64::from(n));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::new(11);
        let n = 40_000;
        let (mut s1, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let v = f64::from(rng.gaussian(1.0, 2.0));
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / f64::from(n);
        let var = s2 / f64::from(n) - mean * mean;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.2, "var {var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut rng = Pcg32::new(3);
        for _ in 0..1000 {
            assert!(rng.below(10) < 10);
            let v = rng.range_u(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn xavier_bound_tracks_fan_in() {
        let mut rng = Pcg32::new(9);
        let mut buf = vec![0f32; 1000];
        rng.fill_xavier(&mut buf, 300);
        let s = (3.0f32 / 300.0).sqrt();
        assert!(buf.iter().all(|v| v.abs() <= s));
        assert!(buf.iter().any(|v| v.abs() > s * 0.5));
    }
}
