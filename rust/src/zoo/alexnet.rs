//! AlexNet (BVLC `bvlc_alexnet` train_val): 227×227 input, grouped conv2/4/5,
//! two LRN stages, fc6/7 with dropout — paper Table 1's first column.

use super::{gaussian, NetBuilder};
use crate::proto::{NetParameter, PoolMethod};

pub fn alexnet(batch: usize) -> NetParameter {
    let mut b = NetBuilder::new("AlexNet");
    b.data(batch, 3, 227, 1000, "imagenet");
    b.conv_full("conv1", "data", "conv1", 96, 11, 4, 0, 1, gaussian(0.01));
    b.relu_inplace("relu1", "conv1");
    b.lrn("norm1", "conv1");
    b.pool("pool1", "norm1", PoolMethod::Max, 3, 2, 0);
    b.conv_full("conv2", "pool1", "conv2", 256, 5, 1, 2, 2, gaussian(0.01));
    b.relu_inplace("relu2", "conv2");
    b.lrn("norm2", "conv2");
    b.pool("pool2", "norm2", PoolMethod::Max, 3, 2, 0);
    b.conv_full("conv3", "pool2", "conv3", 384, 3, 1, 1, 1, gaussian(0.01));
    b.relu_inplace("relu3", "conv3");
    b.conv_full("conv4", "conv3", "conv4", 384, 3, 1, 1, 2, gaussian(0.01));
    b.relu_inplace("relu4", "conv4");
    b.conv_full("conv5", "conv4", "conv5", 256, 3, 1, 1, 2, gaussian(0.01));
    b.relu_inplace("relu5", "conv5");
    b.pool("pool5", "conv5", PoolMethod::Max, 3, 2, 0);
    b.fc("fc6", "pool5", 4096);
    b.relu_inplace("relu6", "fc6");
    b.dropout_inplace("drop6", "fc6", 0.5);
    b.fc("fc7", "fc6", 4096);
    b.relu_inplace("relu7", "fc7");
    b.dropout_inplace("drop7", "fc7", 0.5);
    b.fc("fc8", "fc7", 1000);
    b.accuracy("accuracy", "fc8");
    b.softmax_loss("loss", "fc8", 1.0);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::net::Net;
    use crate::proto::Phase;

    #[test]
    fn geometry_matches_alexnet() {
        let mut dev = CpuDevice::new();
        let param = alexnet(1);
        let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        let shape = |n: &str| net.blob(n).unwrap().borrow().shape().to_vec();
        assert_eq!(shape("conv1"), vec![1, 96, 55, 55]);
        assert_eq!(shape("pool1"), vec![1, 96, 27, 27]);
        assert_eq!(shape("conv2"), vec![1, 256, 27, 27]);
        assert_eq!(shape("pool2"), vec![1, 256, 13, 13]);
        assert_eq!(shape("conv5"), vec![1, 256, 13, 13]);
        assert_eq!(shape("pool5"), vec![1, 256, 6, 6]);
        assert_eq!(shape("fc8"), vec![1, 1000]);
        // ~61M params
        let p = net.num_parameters();
        assert!((58_000_000..64_000_000).contains(&p), "params {p}");
    }
}
