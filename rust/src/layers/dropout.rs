//! Dropout layer (kernels `Dropout_F/B`). The Bernoulli mask is drawn
//! host-side (as Caffe does with its RNG) and uploaded — so on the FPGA
//! device every training-phase dropout also produces a `Write_Buffer`
//! event, matching the paper's transfer accounting.

use super::{Layer, SharedBlob};
use crate::blob::Blob;
use crate::device::{Device, Kernel, KernelCall};
use crate::proto::{LayerParameter, Phase};
use crate::util::prng::Pcg32;
use std::rc::Rc;

pub struct DropoutLayer {
    name: String,
    ratio: f32,
    phase: Phase,
    mask: Option<SharedBlob>,
    rng: Pcg32,
    count: usize,
}

impl DropoutLayer {
    pub fn new(param: &LayerParameter, phase: Phase) -> DropoutLayer {
        let seed = param
            .name
            .bytes()
            .fold(0x9e37_79b9_7f4a_7c15u64, |h, b| {
                (h ^ u64::from(b)).wrapping_mul(0x1000_0000_01b3)
            });
        DropoutLayer {
            name: param.name.clone(),
            ratio: param.dropout.as_ref().map(|d| d.dropout_ratio).unwrap_or(0.5),
            phase,
            mask: None,
            rng: Pcg32::new(seed),
            count: 0,
        }
    }

    fn scale(&self) -> f32 {
        1.0 / (1.0 - self.ratio)
    }
}

impl Layer for DropoutLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "Dropout"
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        self.mask = Some(super::shared(Blob::new("mask", &[1])));
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        self.count = bottoms[0].borrow().count();
        let shape = bottoms[0].borrow().shape().to_vec();
        if !Rc::ptr_eq(&bottoms[0], &tops[0]) {
            tops[0].borrow_mut().reshape_grow_only(dev, &shape);
        }
        self.mask
            .as_ref()
            .expect("mask blob created at setup")
            .borrow_mut()
            .reshape_grow_only(dev, &shape);
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let in_place = Rc::ptr_eq(&bottoms[0], &tops[0]);
        if self.phase == Phase::Test {
            // Inference: identity (Caffe scales at train time).
            if !in_place {
                let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
                let t_id = tops[0].borrow_mut().data.dev_data_mut(dev);
                dev.launch(&KernelCall::new(
                    Kernel::Axpby { n: self.count, alpha: 1.0, beta: 0.0 },
                    &[b_id],
                    &[t_id],
                ))?;
            }
            return Ok(0.0);
        }
        // Draw mask on host, upload (Write_Buffer on the FPGA device).
        // Only the logical `count` elements are drawn — a grow-only mask
        // keeps spare tail capacity the kernel never reads, and drawing
        // into it would silently shift the RNG stream across reshapes.
        let mask = self.mask.as_ref().unwrap();
        {
            let mut m = mask.borrow_mut();
            let host = m.data.host_data_mut(dev);
            for v in host.iter_mut().take(self.count) {
                *v = if self.rng.bernoulli(self.ratio) { 0.0 } else { 1.0 };
            }
        }
        let m_id = mask.borrow_mut().data.dev_data(dev);
        let scale = self.scale();
        if in_place {
            let mut b = bottoms[0].borrow_mut();
            let id = b.data.dev_data_rw(dev);
            dev.launch(&KernelCall::new(
                Kernel::DropoutF { n: self.count, scale },
                &[id, m_id],
                &[id],
            ))?;
        } else {
            let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
            let t_id = tops[0].borrow_mut().data.dev_data_mut(dev);
            dev.launch(&KernelCall::new(
                Kernel::DropoutF { n: self.count, scale },
                &[b_id, m_id],
                &[t_id],
            ))?;
        }
        Ok(0.0)
    }

    fn backward(
        &mut self,
        dev: &mut dyn Device,
        tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        if !prop_down.first().copied().unwrap_or(true) {
            return Ok(());
        }
        if self.phase == Phase::Test {
            anyhow::bail!("dropout backward in TEST phase");
        }
        let m_id = self.mask.as_ref().unwrap().borrow_mut().data.dev_data(dev);
        let scale = self.scale();
        let in_place = Rc::ptr_eq(&bottoms[0], &tops[0]);
        if in_place {
            let mut b = bottoms[0].borrow_mut();
            let d_id = b.diff.dev_data_rw(dev);
            dev.launch(&KernelCall::new(
                Kernel::DropoutB { n: self.count, scale },
                &[d_id, m_id],
                &[d_id],
            ))?;
        } else {
            let td_id = tops[0].borrow_mut().diff.dev_data(dev);
            let bd_id = bottoms[0].borrow_mut().diff.dev_data_mut(dev);
            dev.launch(&KernelCall::new(
                Kernel::DropoutB { n: self.count, scale },
                &[td_id, m_id],
                &[bd_id],
            ))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;

    fn mk(ratio: f32, phase: Phase) -> DropoutLayer {
        let mut lp = LayerParameter::new("drop", "Dropout");
        lp.dropout = Some(crate::proto::DropoutParameter { dropout_ratio: ratio });
        DropoutLayer::new(&lp, phase)
    }

    #[test]
    fn test_phase_is_identity() {
        let mut dev = CpuDevice::new();
        let mut layer = mk(0.5, Phase::Test);
        let bottom = super::super::shared(Blob::new("x", &[4]));
        let top = super::super::shared(Blob::new("y", &[4]));
        bottom.borrow_mut().set_data(&mut dev, &[1.0, 2.0, 3.0, 4.0]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        layer.forward(&mut dev, &[bottom], &[top.clone()]).unwrap();
        assert_eq!(top.borrow_mut().data_vec(&mut dev), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn train_phase_zeroes_and_scales() {
        let mut dev = CpuDevice::new();
        let mut layer = mk(0.5, Phase::Train);
        let n = 1000;
        let bottom = super::super::shared(Blob::new("x", &[n]));
        let top = super::super::shared(Blob::new("y", &[n]));
        bottom.borrow_mut().set_data(&mut dev, &vec![1.0; n]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        layer.forward(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        let out = top.borrow_mut().data_vec(&mut dev);
        let kept = out.iter().filter(|&&v| v != 0.0).count();
        assert!(out.iter().all(|&v| v == 0.0 || v == 2.0));
        assert!((300..700).contains(&kept), "kept {kept} of {n}");

        // Backward uses the same mask.
        top.borrow_mut().set_diff(&mut dev, &vec![1.0; n]);
        layer
            .backward(&mut dev, &[top], &[true], &[bottom.clone()])
            .unwrap();
        let bd = bottom.borrow_mut().diff_vec(&mut dev);
        for i in 0..n {
            assert_eq!(bd[i] != 0.0, out[i] != 0.0, "mask mismatch at {i}");
        }
    }
}
