//! Blob + SyncedMem: the paper's §3.3 memory synchronization mechanism.
//!
//! Caffe's `syncedmem` has four states (uninitialized / CPU / GPU /
//! synced); FeCaffe adds an **FPGA** head state so data can live in the
//! accelerator's DDR and only cross PCIe when a consumer on the other
//! side asks for it. This module reproduces that state machine over the
//! [`crate::device::Device`] abstraction: `AtDevice` means "head copy is
//! in FPGA DDR" when the device is the FPGA simulator (the PCIe billing
//! happens inside `Device::write/read`), and plain slab memory on the CPU
//! fallback device.
//!
//! A [`Blob`] is Caffe's NCHW tensor with separate `data` and `diff`
//! (gradient) SyncedMems.

use crate::device::{BufId, Device};

/// Head-of-data location. Mirrors paper Figure 3 (top).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemState {
    /// No data written yet anywhere.
    Uninit,
    /// Freshest copy on the host.
    AtHost,
    /// Freshest copy in device (FPGA DDR) memory.
    AtDevice,
    /// Host and device copies agree.
    Synced,
}

/// One logical buffer kept coherent between host memory and device memory.
#[derive(Debug)]
pub struct SyncedMem {
    len: usize,
    host: Vec<f32>,
    dev: Option<BufId>,
    state: MemState,
}

impl SyncedMem {
    pub fn new(len: usize) -> SyncedMem {
        SyncedMem { len, host: Vec::new(), dev: None, state: MemState::Uninit }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn state(&self) -> MemState {
        self.state
    }

    /// Resize, dropping contents (device buffer released lazily on next
    /// device access; `release` frees it eagerly).
    pub fn resize(&mut self, dev: &mut dyn Device, len: usize) {
        if len != self.len {
            self.len = len;
            self.host.clear();
            if let Some(id) = self.dev.take() {
                dev.free(id);
            }
            self.state = MemState::Uninit;
        }
    }

    fn ensure_host(&mut self) {
        if self.host.len() != self.len {
            self.host = vec![0.0; self.len];
        }
    }

    fn ensure_dev(&mut self, dev: &mut dyn Device) -> BufId {
        match self.dev {
            Some(id) => id,
            None => {
                let id = dev.alloc(self.len).expect("device allocation failed");
                self.dev = Some(id);
                id
            }
        }
    }

    /// `to_cpu` in the paper: make the host copy fresh.
    pub fn host_data(&mut self, dev: &mut dyn Device) -> &[f32] {
        self.sync_to_host(dev);
        &self.host
    }

    /// Mutable host access: head moves to host.
    pub fn host_data_mut(&mut self, dev: &mut dyn Device) -> &mut [f32] {
        self.sync_to_host(dev);
        self.state = MemState::AtHost;
        &mut self.host
    }

    /// `to_fpga` in the paper: make the device copy fresh, return its id.
    pub fn dev_data(&mut self, dev: &mut dyn Device) -> BufId {
        self.sync_to_dev(dev);
        self.dev.unwrap()
    }

    /// Device copy that will be overwritten by a kernel: head moves to
    /// device without paying an upload when host data isn't fresh anyway.
    pub fn dev_data_mut(&mut self, dev: &mut dyn Device) -> BufId {
        let id = self.ensure_dev(dev);
        self.state = MemState::AtDevice;
        id
    }

    /// Device copy that a kernel will read *and* write (accumulating
    /// gradients, in-place ops): sync to device first, then mark the head
    /// at the device.
    pub fn dev_data_rw(&mut self, dev: &mut dyn Device) -> BufId {
        self.sync_to_dev(dev);
        self.state = MemState::AtDevice;
        self.dev.unwrap()
    }

    fn sync_to_host(&mut self, dev: &mut dyn Device) {
        match self.state {
            MemState::Uninit => {
                self.ensure_host();
                self.state = MemState::AtHost;
            }
            MemState::AtDevice => {
                self.ensure_host();
                dev.read(self.dev.expect("AtDevice without device buffer"), &mut self.host);
                self.state = MemState::Synced;
            }
            MemState::AtHost | MemState::Synced => self.ensure_host(),
        }
    }

    fn sync_to_dev(&mut self, dev: &mut dyn Device) {
        match self.state {
            MemState::Uninit => {
                // Allocate and zero-fill on device (Caffe zero-initializes).
                self.ensure_host();
                let id = self.ensure_dev(dev);
                dev.write(id, &self.host);
                self.state = MemState::Synced;
            }
            MemState::AtHost => {
                let id = self.ensure_dev(dev);
                // Borrow dance: write needs &mut dev and &self.host.
                let host = std::mem::take(&mut self.host);
                dev.write(id, &host);
                self.host = host;
                self.state = MemState::Synced;
            }
            MemState::AtDevice | MemState::Synced => {
                self.ensure_dev(dev);
            }
        }
    }

    /// Release the device-side buffer (keeps host copy if fresh).
    pub fn release_dev(&mut self, dev: &mut dyn Device) {
        if let Some(id) = self.dev.take() {
            if self.state == MemState::AtDevice {
                self.ensure_host();
                dev.read(id, &mut self.host);
                self.state = MemState::AtHost;
            } else if self.state == MemState::Synced {
                self.state = MemState::AtHost;
            }
            dev.free(id);
        }
    }
}

/// Caffe's 4-D tensor: data + gradient, NCHW.
#[derive(Debug)]
pub struct Blob {
    pub name: String,
    shape: Vec<usize>,
    pub data: SyncedMem,
    pub diff: SyncedMem,
}

impl Blob {
    pub fn new(name: &str, shape: &[usize]) -> Blob {
        let count = shape.iter().product();
        Blob {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: SyncedMem::new(count),
            diff: SyncedMem::new(count),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn count(&self) -> usize {
        self.shape.iter().product()
    }

    /// NCHW accessors with Caffe's convention that missing trailing axes
    /// are size 1.
    pub fn num(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }
    pub fn channels(&self) -> usize {
        *self.shape.get(1).unwrap_or(&1)
    }
    pub fn height(&self) -> usize {
        *self.shape.get(2).unwrap_or(&1)
    }
    pub fn width(&self) -> usize {
        *self.shape.get(3).unwrap_or(&1)
    }

    pub fn reshape(&mut self, dev: &mut dyn Device, shape: &[usize]) {
        let count: usize = shape.iter().product();
        self.shape = shape.to_vec();
        self.data.resize(dev, count);
        self.diff.resize(dev, count);
    }

    /// Bytes of one copy (f32).
    pub fn bytes(&self) -> usize {
        self.count() * 4
    }

    /// Convenience for tests: set host data.
    pub fn set_data(&mut self, dev: &mut dyn Device, values: &[f32]) {
        assert_eq!(values.len(), self.count(), "set_data length mismatch");
        self.data.host_data_mut(dev).copy_from_slice(values);
    }

    pub fn set_diff(&mut self, dev: &mut dyn Device, values: &[f32]) {
        assert_eq!(values.len(), self.count(), "set_diff length mismatch");
        self.diff.host_data_mut(dev).copy_from_slice(values);
    }

    /// Convenience for tests/debug: snapshot host data.
    pub fn data_vec(&mut self, dev: &mut dyn Device) -> Vec<f32> {
        self.data.host_data(dev).to_vec()
    }

    pub fn diff_vec(&mut self, dev: &mut dyn Device) -> Vec<f32> {
        self.diff.host_data(dev).to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;

    #[test]
    fn state_machine_basics() {
        let mut dev = CpuDevice::new();
        let mut m = SyncedMem::new(4);
        assert_eq!(m.state(), MemState::Uninit);

        m.host_data_mut(&mut dev).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.state(), MemState::AtHost);

        let _id = m.dev_data(&mut dev);
        assert_eq!(m.state(), MemState::Synced);

        // Kernel writes device side → head at device.
        let id = m.dev_data_mut(&mut dev);
        assert_eq!(m.state(), MemState::AtDevice);
        dev.write(id, &[9.0, 9.0, 9.0, 9.0]);

        // Reading host syncs back.
        assert_eq!(m.host_data(&mut dev), &[9.0, 9.0, 9.0, 9.0]);
        assert_eq!(m.state(), MemState::Synced);
    }

    #[test]
    fn uninit_to_device_is_zeroed() {
        let mut dev = CpuDevice::new();
        let mut m = SyncedMem::new(3);
        let id = m.dev_data(&mut dev);
        let mut out = [7.0f32; 3];
        dev.read(id, &mut out);
        assert_eq!(out, [0.0, 0.0, 0.0]);
    }

    #[test]
    fn release_dev_preserves_data() {
        let mut dev = CpuDevice::new();
        let mut m = SyncedMem::new(2);
        let id = m.dev_data_mut(&mut dev);
        dev.write(id, &[5.0, 6.0]);
        m.release_dev(&mut dev);
        assert_eq!(m.state(), MemState::AtHost);
        assert_eq!(m.host_data(&mut dev), &[5.0, 6.0]);
    }

    #[test]
    fn resize_resets() {
        let mut dev = CpuDevice::new();
        let mut m = SyncedMem::new(2);
        m.host_data_mut(&mut dev)[0] = 1.0;
        m.resize(&mut dev, 5);
        assert_eq!(m.state(), MemState::Uninit);
        assert_eq!(m.len(), 5);
        assert_eq!(m.host_data(&mut dev), &[0.0; 5]);
    }

    #[test]
    fn blob_shape_helpers() {
        let b = Blob::new("x", &[2, 3, 4, 5]);
        assert_eq!(b.count(), 120);
        assert_eq!(
            (b.num(), b.channels(), b.height(), b.width()),
            (2, 3, 4, 5)
        );
        let fc = Blob::new("y", &[10, 20]);
        assert_eq!((fc.num(), fc.channels(), fc.height(), fc.width()), (10, 20, 1, 1));
    }

    #[test]
    fn blob_data_roundtrip() {
        let mut dev = CpuDevice::new();
        let mut b = Blob::new("x", &[2, 2]);
        b.set_data(&mut dev, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b.data_vec(&mut dev), vec![1.0, 2.0, 3.0, 4.0]);
        b.reshape(&mut dev, &[4, 1]);
        assert_eq!(b.count(), 4);
    }
}
