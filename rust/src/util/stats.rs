//! Bench statistics (criterion is unavailable offline — DESIGN.md §10).
//!
//! `Sampler` runs a closure repeatedly with warmup, collects wallclock
//! samples and reports median/p95/mean. All perf numbers in
//! EXPERIMENTS.md §Perf come through this.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl Summary {
    pub fn line(&self) -> String {
        format!(
            "{:<40} n={:<4} median={:>12} mean={:>12} p95={:>12} p99={:>12} min={:>12}",
            self.name,
            self.samples,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        )
    }
}

/// Nearest-rank quantile of an ascending-sorted sample (`q` in 0..=1).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` for `warmup` + `samples` iterations and summarize the timed part.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_nanos() as f64);
    }
    summarize(name, &mut times)
}

pub fn summarize(name: &str, times: &mut [f64]) -> Summary {
    assert!(!times.is_empty());
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = times.len();
    let mean = times.iter().sum::<f64>() / n as f64;
    let median = if n % 2 == 1 {
        times[n / 2]
    } else {
        0.5 * (times[n / 2 - 1] + times[n / 2])
    };
    Summary {
        name: name.to_string(),
        samples: n,
        mean_ns: mean,
        median_ns: median,
        p95_ns: percentile(times, 0.95),
        p99_ns: percentile(times, 0.99),
        min_ns: times[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_basic() {
        let mut t = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let s = summarize("x", &mut t);
        assert_eq!(s.median_ns, 3.0);
        assert_eq!(s.mean_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.p95_ns, 5.0);
    }

    #[test]
    fn even_median_interpolates() {
        let mut t = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(summarize("x", &mut t).median_ns, 2.5);
    }

    #[test]
    fn percentile_nearest_rank() {
        let sorted: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&sorted, 0.50), 50.0);
        assert_eq!(percentile(&sorted, 0.95), 95.0);
        assert_eq!(percentile(&sorted, 0.99), 99.0);
        assert_eq!(percentile(&sorted, 1.0), 100.0);
        assert_eq!(percentile(&sorted, 0.0), 1.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn bench_runs_closure() {
        let mut count = 0;
        let s = bench("noop", 2, 10, || count += 1);
        assert_eq!(count, 12);
        assert_eq!(s.samples, 10);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5.0e4).contains("us"));
        assert!(fmt_ns(5.0e7).contains("ms"));
        assert!(fmt_ns(5.0e9).contains("s"));
    }
}
