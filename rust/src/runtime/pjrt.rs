//! PjrtBackend — executes kernel launches through AOT-compiled HLO
//! artifacts on the PJRT CPU client (the `.aocx` load-and-launch
//! analogue; see /opt/xla-example/load_hlo for the reference wiring).
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see aot.py).

use super::plan::{kernel_plan, Arg};
use crate::device::fpga::NumericBackend;
use crate::device::native::Slab;
use crate::device::KernelCall;
use std::collections::HashMap;
use std::path::PathBuf;

enum Entry {
    Compiled(xla::PjRtLoadedExecutable),
    Missing,
}

#[derive(Debug, Default, Clone)]
pub struct BackendStats {
    pub artifact_hits: u64,
    pub artifact_misses: u64,
    pub compiles: u64,
}

pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, Entry>,
    pub stats: BackendStats,
}

impl PjrtBackend {
    /// Open the backend over an artifacts directory (must contain
    /// `manifest.json` + `<key>.hlo.txt` files from `make artifacts`).
    pub fn new(dir: impl Into<PathBuf>) -> anyhow::Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(PjrtBackend {
            client,
            dir: dir.into(),
            cache: HashMap::new(),
            stats: BackendStats::default(),
        })
    }

    /// Auto-locate artifacts; None if `make artifacts` hasn't run.
    pub fn auto() -> Option<PjrtBackend> {
        let dir = super::find_artifacts_dir()?;
        PjrtBackend::new(dir).ok()
    }

    fn executable(&mut self, key: &str) -> anyhow::Result<Option<&xla::PjRtLoadedExecutable>> {
        if !self.cache.contains_key(key) {
            let path = self.dir.join(format!("{key}.hlo.txt"));
            let entry = if path.is_file() {
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compile {key}: {e}"))?;
                self.stats.compiles += 1;
                Entry::Compiled(exe)
            } else {
                Entry::Missing
            };
            self.cache.insert(key.to_string(), entry);
        }
        match self.cache.get(key).unwrap() {
            Entry::Compiled(e) => Ok(Some(e)),
            Entry::Missing => Ok(None),
        }
    }
}

fn f32_literal(data: &[f32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    let want: usize = dims.iter().product();
    let bytes: &[u8] = if data.len() == want {
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, want * 4) }
    } else {
        // Bucketed kernel: pad with zeros (copy path).
        return padded_literal(data, dims, want);
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow::anyhow!("literal {dims:?}: {e}"))
}

fn padded_literal(data: &[f32], dims: &[usize], want: usize) -> anyhow::Result<xla::Literal> {
    let mut padded = vec![0f32; want];
    let n = data.len().min(want);
    padded[..n].copy_from_slice(&data[..n]);
    let bytes =
        unsafe { std::slice::from_raw_parts(padded.as_ptr() as *const u8, want * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow::anyhow!("padded literal {dims:?}: {e}"))
}

impl NumericBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(&mut self, slab: &mut Slab, call: &KernelCall) -> anyhow::Result<bool> {
        let Some(plan) = kernel_plan(&call.kernel) else {
            return Ok(false); // data-movement kernel: native path
        };
        // Borrow-check dance: look up the executable first.
        if self.executable(&plan.key)?.is_none() {
            self.stats.artifact_misses += 1;
            return Ok(false);
        }

        // Marshal arguments.
        let mut literals = Vec::with_capacity(plan.args.len());
        for arg in &plan.args {
            let lit = match arg {
                Arg::Scalar(v) => xla::Literal::scalar(*v),
                Arg::Buf { idx, dims } => {
                    let id = call.inputs[*idx];
                    let off = call.in_offsets[*idx];
                    let want: usize = dims.iter().product();
                    let buf = slab.get(id);
                    let end = (off + want).min(buf.len());
                    f32_literal(&buf[off..end], dims)?
                }
                Arg::OutBuf { idx, dims } => {
                    let id = call.outputs[*idx];
                    let off = call.out_offsets[*idx];
                    let want: usize = dims.iter().product();
                    let buf = slab.get(id);
                    let end = (off + want).min(buf.len());
                    f32_literal(&buf[off..end], dims)?
                }
            };
            literals.push(lit);
        }

        let exe = match self.cache.get(&plan.key) {
            Some(Entry::Compiled(e)) => e,
            _ => unreachable!("checked above"),
        };
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", plan.key))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {}: {e}", plan.key))?;
        // aot.py lowers with return_tuple=True → always a tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e}", plan.key))?;
        anyhow::ensure!(
            parts.len() == plan.outs.len(),
            "{}: artifact returned {} outputs, plan expects {}",
            plan.key,
            parts.len(),
            plan.outs.len()
        );
        for (part, om) in parts.iter().zip(plan.outs.iter()) {
            let vals: Vec<f32> = part
                .to_vec()
                .map_err(|e| anyhow::anyhow!("read output of {}: {e}", plan.key))?;
            let id = call.outputs[om.idx];
            let off = call.out_offsets[om.idx];
            let dst = slab.get_mut(id);
            let n = om.len.min(vals.len());
            dst[off..off + n].copy_from_slice(&vals[..n]);
        }
        self.stats.artifact_hits += 1;
        Ok(true)
    }
}

// Tests that need real artifacts live in rust/tests/integration_runtime.rs
// (they skip gracefully when `make artifacts` hasn't run).
