//! Integration: fault tolerance under deterministic chaos.
//!
//! * a seeded fault plan (transient device faults, a mid-batch panic, a
//!   worker kill, deadline-expired requests) across a multi-thousand-
//!   request load resolves EVERY request exactly once — no hangs — and
//!   the pool respawns back to full strength;
//! * forced consecutive batch failures open the per-model circuit
//!   breaker (fast `BreakerOpen` rejections), and the half-open probe
//!   re-closes it once the fault budget runs dry;
//! * with the restart budget at zero, killing every worker fail-drains
//!   the pipeline: all concurrent submitters resolve, none block;
//! * the HTTP surface speaks the same contract: `x-deadline-ms` /
//!   `deadline_ms` produce 504s, garbled deadlines produce 400s.

use fecaffe::proto::parse_net;
use fecaffe::serve::{
    DeviceKind, Engine, EngineConfig, HttpClient, HttpConfig, HttpServer, ModelRouter,
    ServeError,
};
use fecaffe::util::chaos::FaultPlan;
use fecaffe::util::prng::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A two-input, one-output InnerProduct net — forwards are microseconds,
/// so the chaos schedule (not compute) dominates the test's wall time.
const TINY_FC: &str = r#"
name: "tinyfc"
input: "data"
input_shape { dim: 1 dim: 1 dim: 1 dim: 2 }
layer { name: "fc" type: "InnerProduct" bottom: "data" top: "fc"
        inner_product_param { num_output: 1 weight_filler { type: "xavier" } } }
"#;

fn tiny_engine(cfg: EngineConfig) -> Engine {
    let param = parse_net(TINY_FC).unwrap();
    Engine::new(&param, cfg).unwrap()
}

fn sample(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    let mut v = vec![0f32; len];
    rng.fill_uniform(&mut v, 0.0, 1.0);
    v
}

/// The tentpole end-to-end: 3000 requests from 8 clients against a
/// 2-worker pool while the chaos plan injects transient forward faults
/// (retried transparently), one mid-batch panic (replica rebuilt), one
/// worker kill (supervisor respawn) and slow batches — and every 10th
/// request carries an already-expired deadline (shed as 504 semantics).
/// Exactly-once resolution: completions + sheds + failures == issued,
/// and the test finishing at all is the no-hang proof.
#[test]
fn chaos_load_resolves_every_request_and_pool_recovers() {
    let plan = FaultPlan::parse(
        "seed=11,fault=0.05,panic=1,panic-after=5,kill=1,kill-after=40,slow=0.02,slow-ms=1",
    )
    .unwrap();
    let engine = tiny_engine(EngineConfig {
        workers: 2,
        max_batch: 8,
        max_linger: Duration::from_micros(300),
        queue_capacity: 256,
        device: DeviceKind::Cpu,
        intra_op_threads: 1,
        // Breaker off: this test measures supervision and retry, not
        // fast-rejection (the breaker has its own test below).
        breaker_threshold: 0,
        restart_budget: 8,
        restart_backoff: Duration::from_millis(5),
        chaos: Some(plan),
        ..EngineConfig::default()
    });

    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 375; // 3000 total
    let ok = AtomicU64::new(0);
    let shed = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let zero_deadline_issued = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for cid in 0..CLIENTS {
            let engine = &engine;
            let (ok, shed, failed) = (&ok, &shed, &failed);
            let zero_deadline_issued = &zero_deadline_issued;
            scope.spawn(move || {
                let mut rng = Pcg32::with_stream(99, cid as u64 + 1);
                for i in 0..PER_CLIENT {
                    // Every 10th request has already missed its latency
                    // budget at submit time — it must be shed, never
                    // served and never hung.
                    let deadline = if i % 10 == 0 {
                        zero_deadline_issued.fetch_add(1, Ordering::Relaxed);
                        Some(Duration::ZERO)
                    } else {
                        None
                    };
                    let mut s = sample(&mut rng, engine.sample_len());
                    let handle = loop {
                        match engine.submit_with_deadline(s, deadline) {
                            Ok(h) => break Some(h),
                            Err(ServeError::Overloaded(rejected)) => {
                                s = rejected;
                                std::thread::sleep(Duration::from_micros(200));
                            }
                            Err(_) => break None,
                        }
                    };
                    let Some(handle) = handle else {
                        failed.fetch_add(1, Ordering::Relaxed);
                        continue;
                    };
                    match handle.wait() {
                        Ok(resp) => {
                            assert_eq!(resp.values.len(), 1);
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ServeError::DeadlineExceeded) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });

    let (ok, shed, failed) = (
        ok.load(Ordering::Relaxed),
        shed.load(Ordering::Relaxed),
        failed.load(Ordering::Relaxed),
    );
    let total = (CLIENTS * PER_CLIENT) as u64;
    // Exactly-once resolution across every fault mode.
    assert_eq!(ok + shed + failed, total, "every request resolves exactly once");
    // Every zero-deadline request was shed, and only those.
    assert_eq!(shed, zero_deadline_issued.load(Ordering::Relaxed));
    // Failures are bounded to the panicked/killed batches' requests —
    // the injected transients must have been retried, not surfaced.
    assert!(failed <= 2 * 8, "failures confined to the 2 disrupted batches, got {failed}");
    assert!(ok > total / 2, "most requests complete (got {ok}/{total})");

    // The pool healed: the killed worker was respawned (and the panic
    // cost a replica rebuild), so healthy strength returns to 2.
    let deadline = Instant::now() + Duration::from_secs(5);
    while engine.healthy_workers() < 2 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(engine.healthy_workers(), 2, "supervisor respawned the killed worker");

    // Shutdown joins the batcher/worker/supervisor threads, so every
    // counter increment has landed before we read the snapshot.
    engine.shutdown();
    let snap = engine.metrics().snapshot();
    assert!(snap.restarts >= 2, "one panic rebuild + one supervisor respawn: {}", snap.restarts);
    assert!(snap.retries >= 1, "injected transients were retried");
    // Post-shutdown the counters still reconcile: nothing double-booked.
    assert_eq!(snap.completed, ok);
    assert_eq!(snap.shed_expired, shed);
}

/// Forced failures open the breaker after exactly `threshold`
/// consecutive failed batches, submissions are fast-rejected while it
/// is open, and the half-open probe re-closes it once the injected
/// fault budget is spent. The arithmetic is deterministic: each fully
/// failed batch burns MAX_FORWARD_ATTEMPTS = 4 fault draws, so
/// `fault-n=14` fails batches 1–3 (12 draws) and leaves the probe 2
/// faults to retry through before its third attempt succeeds.
#[test]
fn breaker_opens_after_consecutive_failures_and_probe_recloses() {
    let plan = FaultPlan::parse("seed=3,fault=1.0,fault-n=14").unwrap();
    let engine = tiny_engine(EngineConfig {
        workers: 1,
        max_batch: 1,
        max_linger: Duration::from_micros(100),
        queue_capacity: 16,
        device: DeviceKind::Cpu,
        intra_op_threads: 1,
        breaker_threshold: 3,
        breaker_cooldown: Duration::from_millis(200),
        chaos: Some(plan),
        ..EngineConfig::default()
    });
    let mut rng = Pcg32::new(5);

    // Three sequential requests, each its own batch, each exhausting
    // the 4-attempt retry budget against p=1.0 faults.
    for i in 0..3 {
        let h = engine.submit(sample(&mut rng, 2)).unwrap();
        match h.wait() {
            Err(ServeError::Worker(msg)) => {
                assert!(msg.contains("transient"), "request {i}: {msg}");
            }
            other => panic!("request {i}: expected Worker error, got {other:?}"),
        }
    }
    // The breaker trips on the worker thread just after the waiters are
    // failed — poll briefly instead of racing it.
    let deadline = Instant::now() + Duration::from_secs(2);
    while engine.breaker_state() != "open" && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(engine.breaker_state(), "open");

    // Open circuit: fast rejection with a retry hint, without queueing.
    match engine.submit(sample(&mut rng, 2)) {
        Err(ServeError::BreakerOpen { retry_after_ms }) => assert!(retry_after_ms >= 1),
        other => panic!("expected BreakerOpen while open, got {other:?}"),
    }
    assert!(engine.metrics().breaker_rejected.load(Ordering::Relaxed) >= 1);

    // After the cooldown the next submission is the half-open probe;
    // its batch retries through the last 2 injected faults and
    // succeeds, re-closing the circuit.
    std::thread::sleep(Duration::from_millis(250));
    let h = engine.submit(sample(&mut rng, 2)).expect("half-open admits the probe");
    h.wait().expect("probe succeeds once the fault budget is dry");
    // The re-close happens on the worker thread just after the probe's
    // waiter is fulfilled — poll briefly, as with the trip above.
    let deadline = Instant::now() + Duration::from_secs(2);
    while engine.breaker_state() != "closed" && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(engine.breaker_state(), "closed");
    engine.shutdown();
    let snap = engine.metrics().snapshot();
    assert_eq!(snap.breaker_trips, 1, "exactly one trip across the episode");
    // Deterministic retry ledger: 3 failed batches x 3 retries each +
    // 2 probe retries (fault-n=14 = 3x4 draws + 2 left for the probe).
    assert_eq!(snap.retries, 11);
}

/// Kill every worker with the restart budget at zero: the last worker
/// out must close and fail-drain the pipeline so that every concurrent
/// submitter resolves — the submit returns `ShuttingDown`, or the
/// handle's wait returns an error — and nobody blocks forever.
#[test]
fn exhausted_pool_fails_all_waiters_without_hanging() {
    let plan = FaultPlan::parse("seed=2,kill=2,kill-after=0").unwrap();
    let engine = tiny_engine(EngineConfig {
        workers: 2,
        max_batch: 4,
        max_linger: Duration::from_micros(200),
        queue_capacity: 64,
        device: DeviceKind::Cpu,
        intra_op_threads: 1,
        breaker_threshold: 0,
        restart_budget: 0, // no supervisor: deaths are permanent
        chaos: Some(plan),
        ..EngineConfig::default()
    });

    const THREADS: usize = 8;
    const PER_THREAD: usize = 50;
    let resolved = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for tid in 0..THREADS {
            let engine = &engine;
            let resolved = &resolved;
            scope.spawn(move || {
                let mut rng = Pcg32::with_stream(7, tid as u64 + 1);
                for _ in 0..PER_THREAD {
                    match engine.submit(sample(&mut rng, 2)) {
                        Ok(h) => {
                            // Ok or Err both count — what matters is
                            // that wait() RETURNS for every handle.
                            let _ = h.wait();
                            resolved.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ServeError::Overloaded(_)) => {
                            resolved.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(Duration::from_micros(100));
                        }
                        Err(_) => {
                            // ShuttingDown once the drain closed the
                            // queue: resolved, not hung.
                            resolved.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    assert_eq!(
        resolved.load(Ordering::Relaxed),
        (THREADS * PER_THREAD) as u64,
        "every submission resolved"
    );
    assert_eq!(engine.healthy_workers(), 0, "both workers were killed for good");
    engine.shutdown();
}

/// The HTTP surface speaks the deadline contract: an already-expired
/// `x-deadline-ms` header sheds as 504, a body `deadline_ms` does the
/// same (and takes precedence over the header), garbled values are
/// 400s, and an undeadlined request still serves 200.
#[test]
fn http_deadlines_produce_504_and_garbage_produces_400() {
    let engine = tiny_engine(EngineConfig {
        workers: 1,
        max_batch: 4,
        max_linger: Duration::from_micros(200),
        queue_capacity: 64,
        device: DeviceKind::Cpu,
        intra_op_threads: 1,
        ..EngineConfig::default()
    });
    let engines = vec![("tinyfc".to_string(), engine)];
    let router = Arc::new(ModelRouter::from_engines(engines).unwrap());
    let server = HttpServer::bind("127.0.0.1:0", router, HttpConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let path = "/v1/models/tinyfc:predict";
    let body = br#"{"instances": [[0.25, 0.5]]}"#;

    let mut client = HttpClient::connect(&addr).unwrap();
    // No deadline: serves normally.
    let (status, _) = client.request("POST", path, body).unwrap();
    assert_eq!(status, 200);

    // Already-expired header deadline: shed as 504 before execution.
    let expired_hdr = [("x-deadline-ms", "0")];
    let (status, resp) = client.request_with("POST", path, &expired_hdr, body).unwrap();
    assert_eq!(status, 504, "{}", String::from_utf8_lossy(&resp));
    assert!(String::from_utf8_lossy(&resp).contains("deadline"));

    // Body deadline_ms: same shed, no header needed.
    let expired = br#"{"instances": [[0.25, 0.5]], "deadline_ms": 0}"#;
    let (status, _) = client.request("POST", path, expired).unwrap();
    assert_eq!(status, 504);

    // Precedence: a generous body budget overrides an expired header.
    let generous = br#"{"instances": [[0.25, 0.5]], "deadline_ms": 60000}"#;
    let (status, _) = client.request_with("POST", path, &expired_hdr, generous).unwrap();
    assert_eq!(status, 200);

    // Garbled body deadline: 400, not silently unbudgeted.
    let garbled = br#"{"instances": [[0.25, 0.5]], "deadline_ms": -3}"#;
    let (status, resp) = client.request("POST", path, garbled).unwrap();
    assert_eq!(status, 400);
    assert!(String::from_utf8_lossy(&resp).contains("deadline_ms"));

    // Garbled header deadline: malformed request, 400 (connection is
    // closed by the server afterwards, so use a throwaway client).
    let mut throwaway = HttpClient::connect(&addr).unwrap();
    let bad_hdr = [("x-deadline-ms", "soonish")];
    let (status, _) = throwaway.request_with("POST", path, &bad_hdr, body).unwrap();
    assert_eq!(status, 400);

    server.shutdown();
}
