//! Timeline export — the stand-in for the paper's VTune / OpenCL-profiler
//! views (Figures 4 and 5).
//!
//! Two renderers over [`crate::device::fpga::profiler::Span`]s:
//! * chrome-trace JSON (open in `chrome://tracing` / Perfetto) with one
//!   track per lane (host / pcie / fpga-kernel), mirroring Figure 4's
//!   CPU-green vs FPGA-pink lanes;
//! * an ASCII timeline for terminals and EXPERIMENTS.md.

use crate::device::fpga::profiler::Span;
use crate::util::json::Json;

/// Spans → chrome-trace JSON ("traceEvents" array of X events).
pub fn chrome_trace(spans: &[Span]) -> String {
    let mut events = Vec::new();
    for s in spans {
        let tid = match s.lane {
            "host" => 0,
            "pcie" => 1,
            _ => 2,
        };
        let mut e = Json::obj();
        e.set("name", Json::str(s.name.clone()))
            .set("ph", Json::str("X"))
            .set("pid", Json::num(1))
            .set("tid", Json::num(tid))
            .set("ts", Json::num(s.start_ns as f64 / 1e3))
            .set("dur", Json::num((s.dur_ns.max(1)) as f64 / 1e3))
            .set("cat", Json::str(s.lane));
        events.push(e);
    }
    // Thread name metadata.
    for (tid, name) in [(0, "host"), (1, "pcie"), (2, "fpga-kernel")] {
        let mut args = Json::obj();
        args.set("name", Json::str(name));
        let mut e = Json::obj();
        e.set("name", Json::str("thread_name"))
            .set("ph", Json::str("M"))
            .set("pid", Json::num(1))
            .set("tid", Json::num(tid))
            .set("args", args);
        events.push(e);
    }
    let mut root = Json::obj();
    root.set("traceEvents", Json::Arr(events));
    root.to_string()
}

/// Spans → fixed-width ASCII timeline (Figure 4 in a terminal).
/// `cols` character cells cover the full [0, end] range.
pub fn ascii_timeline(spans: &[Span], cols: usize) -> String {
    let end = spans
        .iter()
        .map(|s| s.start_ns + s.dur_ns)
        .max()
        .unwrap_or(1)
        .max(1);
    let mut out = String::new();
    let lanes = ["pcie", "fpga-kernel"];
    for lane in lanes {
        let mut row = vec![b'.'; cols];
        for s in spans.iter().filter(|s| s.lane == lane) {
            let a = (s.start_ns as u128 * cols as u128 / end as u128) as usize;
            let b = (((s.start_ns + s.dur_ns) as u128 * cols as u128 + end as u128 - 1)
                / end as u128) as usize;
            let glyph = s.name.bytes().next().unwrap_or(b'#');
            for c in row.iter_mut().take(b.min(cols)).skip(a) {
                *c = glyph;
            }
        }
        out.push_str(&format!(
            "{:<12} |{}|\n",
            lane,
            String::from_utf8_lossy(&row)
        ));
    }
    out.push_str(&format!(
        "{:<12}  0 {:>width$.3} ms\n",
        "",
        end as f64 / 1e6,
        width = cols.saturating_sub(2)
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spans() -> Vec<Span> {
        vec![
            Span { lane: "pcie", name: "Write_Buffer".into(), start_ns: 0, dur_ns: 100 },
            Span { lane: "fpga-kernel", name: "Gemm".into(), start_ns: 100, dur_ns: 300 },
            Span { lane: "fpga-kernel", name: "ReLU_F".into(), start_ns: 400, dur_ns: 50 },
        ]
    }

    #[test]
    fn chrome_trace_is_valid_json_with_all_events() {
        let text = chrome_trace(&spans());
        let v = Json::parse(&text).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 spans + 3 metadata
        assert_eq!(events.len(), 6);
        let first = &events[0];
        assert_eq!(first.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(first.get("ts").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn ascii_timeline_shows_lanes() {
        let text = ascii_timeline(&spans(), 40);
        assert!(text.contains("pcie"));
        assert!(text.contains("fpga-kernel"));
        // gemm glyph appears
        assert!(text.contains('G'));
        assert!(text.contains('W'));
    }

    #[test]
    fn empty_spans_dont_panic() {
        let text = ascii_timeline(&[], 10);
        assert!(text.contains("pcie"));
        let json = chrome_trace(&[]);
        assert!(Json::parse(&json).is_ok());
    }
}
