//! Worker pool: each worker thread owns one warm net replica bound to
//! its own device and drains the shared dispatch queue.
//!
//! `Net` is built on `Rc<RefCell<Blob>>` and cannot cross threads, so a
//! worker *builds* its replica inside the thread from the (Send)
//! `NetParameter` and adopts the engine's `WeightSnapshot` — the
//! `Arc`-shared host weights. Activations, scratch buffers and the
//! device are all private to the worker, which is what makes N workers
//! run forwards concurrently without any locking on the hot path.
//!
//! **Dynamic shapes**: the replica is built once at `max_batch` (warming
//! every grow-only activation to its high-water allocation), then
//! reshaped via `Net::reshape_batch` to each popped batch's *bucketed*
//! size (`runtime::plan::batch_bucket`: next power of two, capped at
//! `max_batch`). A partial batch therefore costs the FLOPs of its bucket
//! — at most 2× its filled rows — instead of a pad-to-`max_batch`
//! forward, and a lone request runs at batch 1 with no special-cased
//! second replica. Reshapes between consecutive batches of the same
//! bucket are free (no-op), and the bucket count bounds shape churn to
//! `log2(max_batch)+1` distinct execution shapes.
//!
//! **Weight hot-swap**: before executing each popped batch the worker
//! compares the engine's published weights version (one atomic load)
//! against the version its replica carries; on a mismatch it takes the
//! slot lock once, adopts the new snapshot, and only then serves.
//! Adoption is O(1) per blob (`Arc` attach), batches already popped
//! finish on the version they started with, and every response is
//! stamped with exactly the version that computed it.

use super::batcher::{gather, scatter, Batch};
use super::engine::{Breaker, DeviceKind, SharedWeights};
use super::lock_unpoisoned;
use super::metrics::Metrics;
use super::queue::SharedQueue;
use crate::device::{Device, DeviceError};
use crate::layers::{LayerTiming, SharedBlob};
use crate::net::{Net, WeightSnapshot};
use crate::obs::{BatchTraceBuilder, EngineObs, TraceScope, LANE_HOST, LANE_LAYER, LANE_QUEUE};
use crate::proto::Phase;
use crate::quant::{Precision, QuantSpec};
use crate::runtime::plan::batch_bucket;
use crate::util::chaos::ChaosState;
use crate::zoo::DeployNet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Forward attempts per batch: the first try plus up to three retries
/// on *transient* device errors (permanent errors fail immediately).
const MAX_FORWARD_ATTEMPTS: u32 = 4;

/// Base backoff between transient-fault retries; doubles per attempt.
const RETRY_BACKOFF: Duration = Duration::from_micros(100);

pub(crate) struct WorkerContext {
    pub id: usize,
    pub deploy: DeployNet,
    /// The engine's published-weights cell (version + snapshot slot).
    pub weights: Arc<SharedWeights>,
    pub device: DeviceKind,
    /// Numeric precision the replica serves at (fp32 native, or the
    /// emulated int8/fp16 matmul path via `QuantBackend`).
    pub precision: Precision,
    /// Static activation ranges for int8 (derived at engine boot);
    /// `None` for fp32/fp16.
    pub quant_spec: Option<Arc<QuantSpec>>,
    /// Intra-op threads this worker's kernels may fan out to (the
    /// engine's share of the process budget; see `util::pool`).
    pub intra_op: usize,
    /// Elements per output row (classes).
    pub output_len: usize,
    pub queue: Arc<SharedQueue<Batch>>,
    pub metrics: Arc<Metrics>,
    /// Sampled batch traces + per-layer aggregates (engine-wide).
    pub obs: Arc<EngineObs>,
    /// Workers still able to serve (shared across the pool).
    pub healthy: Arc<AtomicUsize>,
    /// The engine's circuit breaker, fed one outcome per executed batch.
    pub breaker: Arc<Breaker>,
    /// Fault-injection plan (None in production — zero-cost).
    pub chaos: Option<Arc<ChaosState>>,
}

impl WorkerContext {
    /// Snapshot currently published by the engine (cloned `Arc`).
    /// Poison-tolerant: the slot always holds a complete snapshot (the
    /// publisher builds it before the swap), so a panic elsewhere in the
    /// pool must not cascade here.
    fn current_weights(&self) -> Arc<WeightSnapshot> {
        lock_unpoisoned(&self.weights.slot).clone()
    }
}

/// Retires the worker from `healthy` however the thread exits — clean
/// return, failed build, or chaos kill. The last worker out closes
/// and fail-drains the dispatch queue, so the batcher can never block
/// pushing into a dead pool and no caller hangs on a queued request.
struct PoolGuard {
    queue: Arc<SharedQueue<Batch>>,
    healthy: Arc<AtomicUsize>,
    metrics: Arc<Metrics>,
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        let left = self.healthy.fetch_sub(1, Ordering::AcqRel) - 1;
        self.metrics.set_healthy_workers(left as u64);
        if left > 0 {
            return; // healthy workers remain; they keep draining
        }
        self.queue.close();
        while let Some(batch) = self.queue.pop() {
            for req in batch.requests {
                req.fail("serving worker pool exhausted");
            }
        }
    }
}

/// The worker's single net replica, reshaped on the fly to each batch's
/// bucketed row count.
struct Replica {
    net: Net,
    input: SharedBlob,
    output: SharedBlob,
    /// Batch rows the net is currently shaped for.
    rows: usize,
}

impl Replica {
    /// Build at the deploy net's full `max_batch` shape, so every
    /// grow-only activation starts at its high-water allocation and no
    /// later reshape ever allocates on the serving path.
    fn build(
        ctx: &WorkerContext,
        snap: &WeightSnapshot,
        dev: &mut dyn Device,
    ) -> anyhow::Result<Replica> {
        anyhow::ensure!(
            !ctx.deploy.param.inputs.is_empty(),
            "deploy param has no inputs"
        );
        let mut net = Net::from_param(&ctx.deploy.param, Phase::Test, dev)?;
        net.adopt_weights(dev, snap)?;
        let input = net
            .blob(&ctx.deploy.input)
            .ok_or_else(|| anyhow::anyhow!("input blob '{}' missing", ctx.deploy.input))?;
        let output = net
            .blob(&ctx.deploy.output)
            .ok_or_else(|| anyhow::anyhow!("output blob '{}' missing", ctx.deploy.output))?;
        Ok(Replica { net, input, output, rows: ctx.deploy.batch })
    }

    /// Reshape to the batch's bucket, execute, and scatter the results,
    /// stamping every response with the weights version that computed it.
    ///
    /// When this batch is sampled (`obs.traces.begin()`), every stage is
    /// bracketed in spans, the forward runs per-layer traced, and the
    /// device profiler's pcie/fpga-kernel lanes are merged in — rebased
    /// from the simulated clock so the batch's first device operation
    /// lands at the host-side upload offset. Un-sampled batches pass
    /// `None` builders everywhere and pay no clock reads.
    /// Returns the batch outcome for the circuit breaker: `Some(true)`
    /// executed and fulfilled, `Some(false)` failed (reshape or
    /// exhausted forward retries), `None` nothing executed — every
    /// request's deadline had already passed and the whole batch was
    /// shed without touching the device.
    fn serve(
        &mut self,
        dev: &mut dyn Device,
        batch: Batch,
        ctx: &WorkerContext,
        version: u64,
    ) -> Option<bool> {
        // Deadline re-check at the last moment before paying for the
        // batch: requests that expired waiting in the dispatch queue
        // are shed here (the batcher already shed what expired in
        // admission), so a stall never cascades into wasted forwards.
        let Batch { requests, formed } = batch;
        let now = Instant::now();
        let (live, dead): (Vec<_>, Vec<_>) =
            requests.into_iter().partition(|r| !r.expired(now));
        for req in dead {
            req.shed();
        }
        if live.is_empty() {
            return None;
        }
        let batch = Batch { requests: live, formed };
        let k = batch.requests.len();
        let rows = batch_bucket(k, ctx.deploy.batch);
        // Sampled trace, origin = the oldest request's submit instant:
        // origin→`formed` is queue + linger wait, `formed`→now is
        // dispatch-queue wait until this worker popped the batch.
        let mut trace = ctx.obs.traces.begin().map(|seq| {
            let t0 = batch.requests.iter().map(|r| r.submitted).min().unwrap_or(batch.formed);
            let mut b = BatchTraceBuilder::new(seq, t0, k, version);
            b.set_rows(rows);
            b.span_between(LANE_QUEUE, "queue-wait", t0, batch.formed);
            b.span_between(LANE_QUEUE, "dispatch-wait", batch.formed, Instant::now());
            b
        });
        if rows != self.rows {
            let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "reshape");
            if let Err(e) = self.net.reshape_batch(dev, rows) {
                // A failed reshape can leave the DAG half-propagated:
                // poison the cached shape so the next batch re-runs the
                // reshape instead of trusting a stale `rows` match.
                self.rows = 0;
                let msg = format!("worker {}: reshape to batch {rows} failed: {e:#}", ctx.id);
                for req in batch.requests {
                    req.fail(&msg);
                }
                return Some(false);
            }
            self.rows = rows;
        }
        let packed = {
            let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "gather");
            let samples: Vec<&[f32]> =
                batch.requests.iter().map(|r| r.sample.as_slice()).collect();
            gather(&samples, ctx.deploy.sample_len, rows)
        };
        // Device lanes: turn span recording on for the sampled batch and
        // note where its device work begins, on both clocks — `dev_base`
        // on the batch timeline, `sim0` on the simulated clock.
        let mut dev_base = 0u64;
        if let Some(b) = trace.as_mut() {
            dev.set_span_recording(true);
            dev_base = b.offset_of(Instant::now());
        }
        let sim0 = dev.sim_clock_ns().unwrap_or(0);
        {
            let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "upload");
            self.input.borrow_mut().set_data(dev, &packed);
        }
        // On the FPGA sim, meter the batch in *simulated* device time so
        // batching policy can be judged against the paper's cost model.
        let sim_before = dev.sim_clock_ns();
        let mut layer_rows: Vec<(String, u64, u64)> = Vec::new();
        let chaos = ctx.chaos.as_deref();
        // First attempt: traced when sampled. An injected chaos fault
        // replaces the forward for this attempt (it models the device
        // erroring out, not the net computing a wrong answer).
        let mut fwd = if let Some(msg) = chaos.and_then(|c| c.draw_fault()) {
            Err(anyhow::Error::new(DeviceError::Transient(msg)))
        } else {
            match trace.as_mut() {
                Some(b) => {
                    let fwd_base = b.offset_of(Instant::now());
                    let r = self.net.forward_traced(dev, &mut |t: LayerTiming<'_>| {
                        let start = fwd_base + t.wall_start_ns;
                        b.push(LANE_LAYER, t.name.to_string(), start, t.wall_ns.max(1));
                        layer_rows.push((t.name.to_string(), t.wall_ns, t.sim_ns.unwrap_or(0)));
                    });
                    let end = b.offset_of(Instant::now());
                    let dur = end.saturating_sub(fwd_base).max(1);
                    b.push(LANE_HOST, "forward".to_string(), fwd_base, dur);
                    r
                }
                None => self.net.forward(dev),
            }
        };
        // Bounded retry on *transient* device errors, with exponential
        // backoff — a glitching board link should cost a retry, not a
        // failed batch. Retries re-run the plain forward (the sampled
        // trace, if any, keeps the first attempt's spans) and each
        // retry re-draws chaos, so injected transients recover exactly
        // like real ones. Permanent errors break out immediately.
        let mut attempt = 1u32;
        while let Err(e) = &fwd {
            if attempt >= MAX_FORWARD_ATTEMPTS || !crate::device::is_transient(e) {
                break;
            }
            ctx.metrics.record_retry();
            std::thread::sleep(RETRY_BACKOFF * (1 << (attempt - 1).min(6)));
            fwd = if let Some(msg) = chaos.and_then(|c| c.draw_fault()) {
                Err(anyhow::Error::new(DeviceError::Transient(msg)))
            } else {
                self.net.forward(dev)
            };
            attempt += 1;
        }
        match fwd {
            Ok(_) => {
                // Row accounting only for batches that actually ran —
                // a failed forward must not inflate occupancy.
                ctx.metrics.record_rows(k, rows);
                if let (Some(t0), Some(t1)) = (sim_before, dev.sim_clock_ns()) {
                    ctx.metrics.record_sim_batch(t1.saturating_sub(t0));
                }
                if !layer_rows.is_empty() {
                    ctx.obs.layers.record(&layer_rows);
                }
                // Read back only the filled rows — the grow-only output
                // blob's allocation is sized for the largest batch ever
                // run, not this one.
                let mut out = vec![0.0f32; k * ctx.output_len];
                {
                    let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "readback");
                    self.output.borrow_mut().data.read_prefix(dev, &mut out);
                }
                // Merge the device lanes recorded across upload, forward
                // and readback, rebased onto the batch timeline.
                if let Some(b) = trace.as_mut() {
                    let spans = dev.take_spans();
                    dev.set_span_recording(false);
                    for s in spans {
                        let start = dev_base + s.start_ns.saturating_sub(sim0);
                        b.push(s.lane, s.name, start, s.dur_ns.max(1));
                    }
                }
                let result_rows = {
                    let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "scatter");
                    scatter(&out, ctx.output_len, k)
                };
                {
                    let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "respond");
                    for (req, row) in batch.requests.into_iter().zip(result_rows) {
                        let ns = req.submitted.elapsed().as_nanos() as u64;
                        req.fulfill(row, version);
                        ctx.metrics.record_done(ns);
                    }
                }
                if let Some(b) = trace.take() {
                    ctx.obs.traces.commit(b.finish());
                }
                Some(true)
            }
            Err(e) => {
                if trace.is_some() {
                    // Leave the device clean for the next batch; the
                    // partial trace is dropped, never committed.
                    dev.set_span_recording(false);
                    let _ = dev.take_spans();
                }
                let msg = format!("worker {}: forward failed: {e:#}", ctx.id);
                for req in batch.requests {
                    req.fail(&msg);
                }
                Some(false)
            }
        }
    }
}

// Thread entry point: the worker thread owns its context for its whole
// lifetime ('static), even though the body only borrows it.
#[allow(clippy::needless_pass_by_value)]
pub(crate) fn run(ctx: WorkerContext) {
    let _guard = PoolGuard {
        queue: ctx.queue.clone(),
        healthy: ctx.healthy.clone(),
        metrics: ctx.metrics.clone(),
    };

    // This worker's share of the machine: everything executed on this
    // thread (replica build and every kernel) fans out at most
    // `intra_op` wide, so N workers never oversubscribe the pool.
    crate::util::pool::set_intra_op(ctx.intra_op);

    let mut dev: Box<dyn Device> =
        ctx.device.create_with(ctx.precision, ctx.quant_spec.clone());

    // Build the replica before taking traffic, so no net construction
    // (layer setup + weight-filler init) ever lands on the serving path.
    let snap = ctx.current_weights();
    let mut version = snap.version();
    let mut replica = match Replica::build(&ctx, &snap, dev.as_mut()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("[serve] worker {}: replica build failed: {e:#}", ctx.id);
            return;
        }
    };
    drop(snap);

    while let Some(batch) = ctx.queue.pop() {
        let chaos = ctx.chaos.as_ref().map(|c| c.on_batch()).unwrap_or_default();
        if chaos.kill {
            // Simulated hard death (thread exit, not a panic): drop the
            // popped batch — `Request::drop` resolves its requests as
            // failures — and let the PoolGuard retire this worker. The
            // engine's supervisor respawns the slot.
            drop(batch);
            eprintln!("[serve] worker {}: chaos: injected worker death", ctx.id);
            return;
        }
        // Batch boundary: adopt a newly published snapshot before
        // executing. One relaxed-cost atomic load in the common case;
        // the slot lock is only taken when the version actually moved.
        // (The engine validated the snapshot against the shared schema,
        // so an adoption failure here indicates a bug, not bad input —
        // the worker keeps serving its current version.)
        if ctx.weights.version.load(Ordering::Acquire) != version {
            let snap = ctx.current_weights();
            match replica.net.adopt_weights(dev.as_mut(), &snap) {
                Ok(()) => version = snap.version(),
                Err(e) => {
                    eprintln!(
                        "[serve] worker {}: failed to adopt weights v{}: {e:#}; \
                         still serving v{version}",
                        ctx.id,
                        snap.version()
                    );
                }
            }
        }
        // Guarded execution: a panic mid-batch (a layer bug, an
        // injected one) fails only its own batch — requests resolve
        // via `Request::drop` during unwinding — and costs a replica
        // rebuild, never the worker thread. `AssertUnwindSafe` is
        // sound because both replica and device are unconditionally
        // rebuilt on the unwind path below, so no state observed after
        // a panic was touched by the panicking call.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if chaos.panic {
                panic!("chaos: injected worker panic mid-batch");
            }
            if let Some(delay) = chaos.slow {
                std::thread::sleep(delay);
            }
            replica.serve(dev.as_mut(), batch, &ctx, version)
        }));
        match outcome {
            Ok(Some(ok)) => ctx.breaker.on_batch(ok),
            // Nothing executed (every request's deadline had passed):
            // no outcome to feed the breaker.
            Ok(None) => {}
            Err(_) => {
                ctx.breaker.on_batch(false);
                ctx.metrics.record_restart();
                // The panic may have left the replica (or the device)
                // half-reshaped or mid-upload: rebuild both from the
                // currently published snapshot before serving again.
                dev = ctx.device.create_with(ctx.precision, ctx.quant_spec.clone());
                let snap = ctx.current_weights();
                version = snap.version();
                match Replica::build(&ctx, &snap, dev.as_mut()) {
                    Ok(r) => {
                        replica = r;
                        eprintln!(
                            "[serve] worker {}: batch panicked; replica rebuilt, resuming",
                            ctx.id
                        );
                    }
                    Err(e) => {
                        eprintln!(
                            "[serve] worker {}: rebuild after batch panic failed: {e:#}",
                            ctx.id
                        );
                        return;
                    }
                }
            }
        }
    }
}
