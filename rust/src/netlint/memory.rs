//! Pass 4: blob liveness and DDR-budget fit.
//!
//! Estimates the device-DDR footprint of one serving bucket from the
//! inferred shapes alone, mirroring what allocation actually commits:
//!
//! * **activations** — every blob in the net map, ×4 bytes, ×1
//!   (forward-only) or ×2 (training keeps data + diff), exactly like
//!   [`crate::net::Net::activation_bytes`];
//! * **params** — conv/IP weights and biases, same data/diff factor;
//! * **scratch** — the two shared per-device im2col slots, each sized to
//!   the largest `bucket(col_len)` over non-1×1 convolutions
//!   (see `ConvolutionLayer::reshape`);
//! * **aux** — per-layer internal blobs: MAX-pool argmax mask, dropout
//!   mask, softmax-loss probability buffer, LRN scale buffer.
//!
//! It also plays the forward schedule to find the *peak live* activation
//! set (a blob is live from its producer to its last consumer; inputs
//! from step 0, unconsumed outputs to the end). `reuse_headroom_bytes` —
//! allocated-minus-peak — is what an arena allocator reusing dead blob
//! storage would save. The fit check compares the (conservative,
//! no-reuse) total against
//! [`crate::device::fpga::costmodel::BoardParams::ddr_capacity_bytes`].

use crate::device::fpga::costmodel::BoardParams;
use crate::proto::LayerParameter;
use crate::runtime::plan::bucket;
use std::collections::BTreeMap;
use std::collections::HashMap;

/// Estimated DDR footprint of one net at one batch bucket.
#[derive(Debug, Clone)]
pub struct BucketMemoryReport {
    pub bucket: usize,
    pub activation_bytes: u64,
    pub param_bytes: u64,
    pub scratch_bytes: u64,
    pub aux_bytes: u64,
    pub total_bytes: u64,
    /// Largest simultaneously-live activation set over the forward
    /// schedule.
    pub peak_activation_bytes: u64,
    /// `activation_bytes - peak_activation_bytes`: what blob-storage
    /// reuse could reclaim.
    pub reuse_headroom_bytes: u64,
    pub ddr_capacity_bytes: u64,
}

impl BucketMemoryReport {
    pub fn fits(&self) -> bool {
        self.total_bytes <= self.ddr_capacity_bytes
    }
}

fn blob_bytes(shape: &[usize], elem_bytes: u64) -> u64 {
    shape.iter().product::<usize>() as u64 * elem_bytes
}

/// Estimate the device-DDR footprint at `elem_bytes` per element (4 for
/// fp32, 2 for fp16 storage, 1 for int8 — reduced-precision serving
/// stores *every* device buffer at the narrow width, exactly like
/// `FpgaSimDevice`'s width-scaled allocation accounting).
pub fn analyze(
    with_splits: &[LayerParameter],
    shapes: &BTreeMap<String, Vec<usize>>,
    batch: usize,
    forward_only: bool,
    board: &BoardParams,
    elem_bytes: u64,
) -> BucketMemoryReport {
    // Training keeps a diff buffer next to every data buffer.
    let factor: u64 = if forward_only { 1 } else { 2 };
    let blob_bytes = |shape: &[usize]| blob_bytes(shape, elem_bytes);

    let activation_bytes: u64 = shapes.values().map(|s| blob_bytes(s) * factor).sum();

    let param_bytes: u64 = super::shapes::param_schema(with_splits, shapes)
        .iter()
        .map(|(_, len)| *len as u64 * elem_bytes * factor)
        .sum();

    // Shared im2col scratch: two slots, each sized to the max rounded
    // col buffer any non-1x1 conv requests.
    let mut max_col = 0usize;
    let mut aux_bytes = 0u64;
    for lp in with_splits {
        let bot = lp.bottoms.first().and_then(|b| shapes.get(b));
        let top = lp.tops.first().and_then(|t| shapes.get(t));
        match lp.kind.as_str() {
            "Convolution" => {
                let (p, b, t) = match (&lp.conv, bot, top) {
                    (Some(p), Some(b), Some(t)) => (p, b, t),
                    _ => continue,
                };
                let is_1x1 = p.kernel_h == 1
                    && p.kernel_w == 1
                    && p.stride_h == 1
                    && p.stride_w == 1
                    && p.pad_h == 0
                    && p.pad_w == 0;
                if !is_1x1 {
                    let c = b.get(1).copied().unwrap_or(1);
                    let (oh, ow) = (
                        t.get(2).copied().unwrap_or(1),
                        t.get(3).copied().unwrap_or(1),
                    );
                    let col_len = c * p.kernel_h * p.kernel_w * oh * ow;
                    max_col = max_col.max(bucket(col_len));
                }
            }
            "Pooling" => {
                // MAX pooling keeps an argmax mask shaped like the top.
                let is_max = lp
                    .pool
                    .as_ref()
                    .is_some_and(|p| matches!(p.method, crate::proto::PoolMethod::Max));
                if is_max {
                    if let Some(t) = top {
                        aux_bytes += blob_bytes(t);
                    }
                }
            }
            "Dropout" => {
                if let Some(b) = bot {
                    aux_bytes += blob_bytes(b);
                }
            }
            "SoftmaxWithLoss" => {
                if let Some(b) = bot {
                    aux_bytes += blob_bytes(b);
                }
            }
            "LRN" => {
                if let Some(b) = bot {
                    aux_bytes += blob_bytes(b);
                }
            }
            _ => {}
        }
    }
    let scratch_bytes = 2 * max_col as u64 * elem_bytes;

    // Liveness over the forward schedule. birth < 0 ⇒ net input.
    let steps = with_splits.len() as i64;
    let mut birth: HashMap<&str, i64> = HashMap::new();
    let mut last_use: HashMap<&str, i64> = HashMap::new();
    for name in shapes.keys() {
        birth.insert(name.as_str(), -1);
        last_use.insert(name.as_str(), steps - 1);
    }
    for (i, lp) in with_splits.iter().enumerate() {
        for t in &lp.tops {
            // First producer wins (in-place layers reuse the blob).
            if let Some(b) = birth.get_mut(t.as_str()) {
                if *b == -1 && !lp.bottoms.contains(t) {
                    *b = i as i64;
                }
            }
        }
    }
    // Unconsumed tops stay live to the end (they are the outputs); any
    // consumed blob dies after its last consumer.
    let mut consumed: HashMap<&str, i64> = HashMap::new();
    for (i, lp) in with_splits.iter().enumerate() {
        for b in &lp.bottoms {
            consumed.insert(b.as_str(), i as i64);
        }
    }
    for (name, step) in consumed {
        if let Some(l) = last_use.get_mut(name) {
            *l = step;
        }
    }
    let mut peak = 0u64;
    for i in 0..steps.max(1) {
        let live: u64 = shapes
            .iter()
            .filter(|(n, _)| birth[n.as_str()] <= i && i <= last_use[n.as_str()])
            .map(|(_, s)| blob_bytes(s) * factor)
            .sum();
        peak = peak.max(live);
    }

    let total_bytes = activation_bytes + param_bytes + scratch_bytes + aux_bytes;
    BucketMemoryReport {
        bucket: batch,
        activation_bytes,
        param_bytes,
        scratch_bytes,
        aux_bytes,
        total_bytes,
        peak_activation_bytes: peak,
        reuse_headroom_bytes: activation_bytes.saturating_sub(peak),
        ddr_capacity_bytes: board.ddr_capacity_bytes,
    }
}
