//! E5 — Figures 4 and 5: the GoogLeNet training-process timeline
//! (batch 16, Adam, a few iterations) as a chrome-trace JSON
//! (`traces/googlenet_training.json`, open in chrome://tracing) plus an
//! ASCII rendering, and the per-kernel execution totals of Figure 5.

use fecaffe::bench_tables::timing_device;
use fecaffe::device::Device;
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::solver::Solver;
use fecaffe::trace;
use fecaffe::util::table::{ms, Table};
use fecaffe::zoo;

fn main() -> anyhow::Result<()> {
    let iterations = 3; // paper uses 10; the trace shape repeats per iter
    let mut dev = timing_device();
    let param = zoo::by_name("googlenet", 16)?;
    let net = Net::from_param(&param, Phase::Train, &mut dev)?;
    let sp = zoo::default_solver("googlenet")?;
    let mut solver = Solver::new(sp, net, &mut dev)?;
    solver.step(&mut dev)?; // warm allocations
    dev.reset_timing();
    dev.profiler.record_spans = true;
    for _ in 0..iterations {
        solver.step(&mut dev)?;
    }
    dev.synchronize();

    // Figure 4: CPU/FPGA lanes.
    std::fs::create_dir_all("traces")?;
    let json = trace::chrome_trace(dev.profiler.spans());
    std::fs::write("traces/googlenet_training.json", &json)?;
    println!(
        "Figure 4 — wrote {} spans to traces/googlenet_training.json ({} iterations, batch 16, Adam)",
        dev.profiler.spans().len(),
        iterations
    );
    println!("\nASCII timeline (first 20 ms window; glyph = kernel initial):");
    let window: Vec<_> = dev
        .profiler
        .spans()
        .iter()
        .filter(|s| s.start_ns < 20_000_000)
        .cloned()
        .collect();
    println!("{}", trace::ascii_timeline(&window, 100));

    // Figure 5: per-kernel totals across the whole training run.
    let mut t = Table::new(
        &format!("Figure 5 — kernel totals over {iterations} training iterations"),
        &["Kernel", "Instances", "Total (ms)"],
    );
    for (class, s) in dev.profiler.stats() {
        t.row(&[
            class.label().to_string(),
            s.instances.to_string(),
            ms(s.total_ns as f64 / 1e6),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Simulated training wall: {:.1} ms for {} iterations",
        dev.sim_clock_ns().unwrap() as f64 / 1e6,
        iterations
    );
    Ok(())
}
