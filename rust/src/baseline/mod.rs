//! Comparator systems from paper Table 4.
//!
//! * [`fcnn`] — F-CNN (Zhao et al., ASAP'16): 2× Stratix V GSD8 boards,
//!   MaxCompiler systolic conv/pool pipelines at 150 MHz, FP32. The paper
//!   compares LeNet per-layer times against it (6.4×/8.4×).
//! * [`fpdeep`] — FPDeep (Geng et al.): 15-FPGA deeply-pipelined cluster,
//!   fixed-point 16, all weights/activations in BRAM (AlexNet epoch
//!   0.17 h).

pub mod fcnn;
pub mod fpdeep;
