//! `QuantizedSnapshot`: per-blob int8 payloads + symmetric scales
//! derived from a [`WeightSnapshot`], serialized as an `FEQSNAP1`
//! container over `util::binio`.
//!
//! Weights are quantized symmetrically (`scale = maxabs/127`, zero
//! point 0) per blob — the standard post-training choice, since weight
//! distributions are zero-centered. Dequantizing yields the *fake
//! quant* snapshot the serving engine actually adopts: every weight
//! sits exactly on its int8 grid, so the emulated int8 GEMM's dynamic
//! re-quantization recovers the codes losslessly.

use super::gemm::{dequantize, quantize, QuantParams};
use crate::net::WeightSnapshot;
use std::sync::Arc;

/// Magic header of the quantized-weights container.
const QSNAP_MAGIC: &[u8; 8] = b"FEQSNAP1";

/// One quantized parameter blob.
#[derive(Debug, Clone)]
pub struct QuantBlob {
    /// Symmetric scale: `real = scale · q`.
    pub scale: f32,
    pub data: Vec<i8>,
}

/// Int8 form of a [`WeightSnapshot`]: same identity keys and version,
/// quarter the payload.
#[derive(Debug, Clone, Default)]
pub struct QuantizedSnapshot {
    version: u64,
    tag: Option<String>,
    keys: Vec<(String, usize)>,
    blobs: Vec<QuantBlob>,
}

impl QuantizedSnapshot {
    /// Quantize every blob of `snap` symmetrically.
    pub fn from_snapshot(snap: &WeightSnapshot) -> QuantizedSnapshot {
        let mut blobs = Vec::with_capacity(snap.len());
        for i in 0..snap.len() {
            let data = snap.blob_data(i).expect("blob index in range");
            let p = QuantParams::symmetric(super::gemm::maxabs(data));
            blobs.push(QuantBlob {
                scale: p.scale,
                data: data.iter().map(|&x| quantize(x, p)).collect(),
            });
        }
        QuantizedSnapshot {
            version: snap.version(),
            tag: snap.tag().map(str::to_owned),
            keys: snap.keys().to_vec(),
            blobs,
        }
    }

    pub fn len(&self) -> usize {
        self.blobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blobs.is_empty()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn tag(&self) -> Option<&str> {
        self.tag.as_deref()
    }

    pub fn keys(&self) -> &[(String, usize)] {
        &self.keys
    }

    pub fn blob(&self, i: usize) -> Option<&QuantBlob> {
        self.blobs.get(i)
    }

    /// Total int8 payload bytes (the DDR footprint of the weights).
    pub fn payload_bytes(&self) -> usize {
        self.blobs.iter().map(|b| b.data.len()).sum()
    }

    /// Expand back to an f32 [`WeightSnapshot`] whose values sit exactly
    /// on the int8 grid (the engine-facing fake-quant snapshot).
    pub fn dequantize(&self) -> WeightSnapshot {
        let blobs = self
            .blobs
            .iter()
            .map(|b| {
                let p = QuantParams { scale: b.scale, zero_point: 0 };
                Arc::new(b.data.iter().map(|&q| dequantize(q, p)).collect::<Vec<f32>>())
            })
            .collect();
        WeightSnapshot::from_parts(self.version, self.tag.clone(), self.keys.clone(), blobs)
    }

    /// Serialize as an `FEQSNAP1` container (little-endian, one record
    /// per blob: identity key, scale, int8 payload).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        use crate::util::binio::{put_f32s, put_str, put_u32, put_u64};
        use std::io::Write;
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
        w.write_all(QSNAP_MAGIC)?;
        put_u64(&mut w, self.version)?;
        put_str(&mut w, self.tag.as_deref().unwrap_or(""))?;
        put_u32(&mut w, self.blobs.len() as u32)?;
        for ((owner, slot), blob) in self.keys.iter().zip(self.blobs.iter()) {
            put_str(&mut w, owner)?;
            put_u32(&mut w, *slot as u32)?;
            put_f32s(&mut w, &[blob.scale])?;
            put_u32(&mut w, blob.data.len() as u32)?;
            // i8 codes are written as raw two's-complement bytes.
            let bytes: Vec<u8> = blob.data.iter().map(|&v| v as u8).collect();
            w.write_all(&bytes)?;
        }
        Ok(())
    }

    /// Load an `FEQSNAP1` container; every length is bounded by the file
    /// size before allocation (same hardening as `FEWSNAP1`).
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<QuantizedSnapshot> {
        use crate::util::binio::{get_f32s, get_str, get_u32, get_u64};
        use std::io::Read;
        let file = std::fs::File::open(&path)?;
        let file_len = file.metadata()?.len() as usize;
        let mut r = std::io::BufReader::new(file);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == QSNAP_MAGIC, "not a FEQSNAP1 quantized snapshot (bad magic)");
        let version = get_u64(&mut r)?;
        let tag = get_str(&mut r, file_len)?;
        let count = get_u32(&mut r)? as usize;
        anyhow::ensure!(
            count <= file_len / 16,
            "implausible blob count {count} for a {file_len}-byte container"
        );
        let mut keys = Vec::with_capacity(count);
        let mut blobs = Vec::with_capacity(count);
        for _ in 0..count {
            let owner = get_str(&mut r, file_len)?;
            let slot = get_u32(&mut r)? as usize;
            let scale = get_f32s(&mut r, 1)?[0];
            anyhow::ensure!(
                scale.is_finite() && scale > 0.0,
                "corrupt scale {scale} for layer '{owner}'"
            );
            let n = get_u32(&mut r)? as usize;
            anyhow::ensure!(
                n <= file_len,
                "implausible blob length {n} for a {file_len}-byte container"
            );
            let mut bytes = vec![0u8; n];
            r.read_exact(&mut bytes)?;
            let data = bytes.into_iter().map(|b| b as i8).collect();
            keys.push((owner, slot));
            blobs.push(QuantBlob { scale, data });
        }
        Ok(QuantizedSnapshot {
            version,
            tag: if tag.is_empty() { None } else { Some(tag) },
            keys,
            blobs,
        })
    }
}
