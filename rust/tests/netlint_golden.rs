//! Golden diagnostics for the netlint static analyzer.
//!
//! Each fixture is a deliberately broken prototxt asserting the *exact*
//! `NLxxxx` code(s) the linter must emit — the codes are a stable,
//! grep-able contract (README "Static analysis" table). The suite also
//! pins the two properties the analyzer is trusted for at admission:
//!
//! * every zoo net lints clean (train graph + solver + projection, and
//!   the deploy graph at every serving bucket the manifest records);
//! * the allocation-free shape inference is bit-identical to a built
//!   `Net` after `reshape_batch`, for every zoo net × serving bucket.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::costmodel::BoardParams;
use fecaffe::net::Net;
use fecaffe::netlint::{infer_shapes, lint_net, LintOptions, LintReport, Severity};
use fecaffe::proto::{parse_net, Phase, SolverParameter};
use fecaffe::runtime::plan::{serve_bucket_cap, serve_buckets};
use fecaffe::zoo;

fn lint(text: &str, opts: &LintOptions) -> LintReport {
    lint_net(&parse_net(text).expect("fixture parses"), opts)
}

/// Distinct codes of all findings (errors and warnings), first-seen order.
fn all_codes(r: &LintReport) -> Vec<&'static str> {
    let mut codes = Vec::new();
    for d in &r.diagnostics {
        if !codes.contains(&d.code) {
            codes.push(d.code);
        }
    }
    codes
}

// ------------------------------------------------------------- pass 1: graph

#[test]
fn dangling_bottom_is_nl0001() {
    let r = lint(
        r#"name: "broken"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { source: "digits" batch_size: 4 channels: 1 height: 8 width: 8 } }
layer { name: "fc" type: "InnerProduct" bottom: "missing" top: "fc"
        inner_product_param { num_output: 3 } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc" bottom: "label" top: "loss" }
"#,
        &LintOptions { phase: Phase::Train, ..Default::default() },
    );
    assert_eq!(r.error_codes(), vec!["NL0001"], "{}", r.render_text());
}

#[test]
fn forward_reference_is_nl0002() {
    // A two-layer cycle: in declaration order, `a` consumes the blob `b`
    // produces later.
    let r = lint(
        r#"name: "cycle"
layer { name: "a" type: "ReLU" bottom: "y" top: "x" }
layer { name: "b" type: "ReLU" bottom: "x" top: "y" }
"#,
        &LintOptions::default(),
    );
    assert_eq!(r.error_codes(), vec!["NL0002"], "{}", r.render_text());
}

#[test]
fn duplicate_top_is_nl0003() {
    let r = lint(
        r#"name: "dup"
input: "data"
input_shape { dim: 1 dim: 2 dim: 4 dim: 4 }
layer { name: "r1" type: "ReLU" bottom: "data" top: "x" }
layer { name: "r2" type: "ReLU" bottom: "data" top: "x" }
"#,
        &LintOptions::default(),
    );
    assert_eq!(r.error_codes(), vec!["NL0003"], "{}", r.render_text());
}

#[test]
fn dead_layer_is_nl0004_warning() {
    // `fc2` has no path to the loss: a warning, not an error — the net
    // still runs, it just wastes DDR and schedule slots.
    let r = lint(
        r#"name: "dead"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { source: "digits" batch_size: 4 channels: 1 height: 8 width: 8 } }
layer { name: "fc" type: "InnerProduct" bottom: "data" top: "fc"
        inner_product_param { num_output: 3 } }
layer { name: "fc2" type: "InnerProduct" bottom: "data" top: "fc2"
        inner_product_param { num_output: 3 } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc" bottom: "label" top: "loss" }
"#,
        &LintOptions { phase: Phase::Train, ..Default::default() },
    );
    assert!(!r.has_errors(), "{}", r.render_text());
    assert_eq!(all_codes(&r), vec!["NL0004"], "{}", r.render_text());
    let d = &r.diagnostics[0];
    assert_eq!(d.severity, Severity::Warning);
    assert_eq!(d.layer.as_deref(), Some("fc2"));
}

#[test]
fn test_only_producer_is_nl0005_and_breaks_projection_nl0411() {
    // `fc1` exists only in the TEST phase, but the loss (phase-neutral)
    // consumes its top: in the TRAIN graph that bottom is produced only
    // by the other phase (NL0005), and the derived deploy net then needs
    // fc1's weights, which the train net never learns (NL0411 — the
    // exact failure `WeightSnapshot::project` would hit at serve time).
    let r = lint(
        r#"name: "phase_broken"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { source: "digits" batch_size: 4 channels: 1 height: 8 width: 8 } }
layer { name: "fc1" type: "InnerProduct" bottom: "data" top: "fc1"
        include { phase: TEST }
        inner_product_param { num_output: 10 } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc1" bottom: "label" top: "loss" }
"#,
        &LintOptions {
            phase: Phase::Train,
            check_deploy_projection: true,
            ..Default::default()
        },
    );
    let codes = r.error_codes();
    assert!(codes.contains(&"NL0005"), "{}", r.render_text());
    assert!(codes.contains(&"NL0411"), "{}", r.render_text());
}

// ------------------------------------------------------------ pass 2: shapes

#[test]
fn conv_kernel_exceeding_input_is_nl0101() {
    let r = lint(
        r#"name: "geom"
input: "data"
input_shape { dim: 1 dim: 1 dim: 8 dim: 8 }
layer { name: "conv" type: "Convolution" bottom: "data" top: "conv"
        convolution_param { num_output: 4 kernel_size: 11 } }
"#,
        &LintOptions::default(),
    );
    assert_eq!(r.error_codes(), vec!["NL0101"], "{}", r.render_text());
}

#[test]
fn conv_group_channel_mismatch_is_nl0102() {
    let r = lint(
        r#"name: "group"
input: "data"
input_shape { dim: 1 dim: 4 dim: 8 dim: 8 }
layer { name: "conv" type: "Convolution" bottom: "data" top: "conv"
        convolution_param { num_output: 6 kernel_size: 3 group: 3 } }
"#,
        &LintOptions::default(),
    );
    assert_eq!(r.error_codes(), vec!["NL0102"], "{}", r.render_text());
}

#[test]
fn concat_spatial_mismatch_is_nl0103() {
    // `pool` halves the spatial dims, then concat sees 8x8 vs 4x4.
    let r = lint(
        r#"name: "concat_mismatch"
input: "data"
input_shape { dim: 1 dim: 2 dim: 8 dim: 8 }
layer { name: "pool" type: "Pooling" bottom: "data" top: "pool"
        pooling_param { pool: MAX kernel_size: 2 stride: 2 } }
layer { name: "cat" type: "Concat" bottom: "data" bottom: "pool" top: "cat" }
"#,
        &LintOptions::default(),
    );
    assert_eq!(r.error_codes(), vec!["NL0103"], "{}", r.render_text());
}

#[test]
fn concat_on_unsupported_axis_is_nl0104() {
    let r = lint(
        r#"name: "axis"
input: "data"
input_shape { dim: 1 dim: 2 dim: 4 dim: 4 }
layer { name: "cat" type: "Concat" bottom: "data" bottom: "data" top: "cat"
        concat_param { axis: 0 } }
"#,
        &LintOptions::default(),
    );
    assert_eq!(r.error_codes(), vec!["NL0104"], "{}", r.render_text());
}

#[test]
fn unknown_layer_kind_is_nl0105() {
    let r = lint(
        r#"name: "unknown"
input: "data"
input_shape { dim: 1 dim: 2 dim: 4 dim: 4 }
layer { name: "w" type: "Warp" bottom: "data" top: "w" }
"#,
        &LintOptions::default(),
    );
    assert_eq!(r.error_codes(), vec!["NL0105"], "{}", r.render_text());
}

// ------------------------------------------------------------- pass 3: alias

#[test]
fn in_place_convolution_is_nl0201() {
    let r = lint(
        r#"name: "inplace"
input: "data"
input_shape { dim: 1 dim: 2 dim: 8 dim: 8 }
layer { name: "conv" type: "Convolution" bottom: "data" top: "data"
        convolution_param { num_output: 2 kernel_size: 3 pad: 1 } }
"#,
        &LintOptions::default(),
    );
    assert_eq!(r.error_codes(), vec!["NL0201"], "{}", r.render_text());
}

#[test]
fn straddled_in_place_overwrite_is_nl0202_warning() {
    // Same wiring as the `insert_splits` regression test in `net.rs`:
    // `c` reads `t` before the in-place ReLU overwrites it, `d` after.
    // Split insertion keeps it correct (at the cost of a copy), so this
    // is a warning, not an error.
    let r = lint(
        r#"name: "straddle"
input: "data"
input_shape { dim: 1 dim: 1 dim: 1 dim: 2 }
layer { name: "a" type: "Pooling" bottom: "data" top: "t"
        pooling_param { pool: AVE kernel_size: 1 stride: 1 } }
layer { name: "c" type: "Pooling" bottom: "t" top: "c"
        pooling_param { pool: AVE global_pooling: true } }
layer { name: "b" type: "ReLU" bottom: "t" top: "t" }
layer { name: "d" type: "Pooling" bottom: "t" top: "d"
        pooling_param { pool: AVE global_pooling: true } }
"#,
        &LintOptions::default(),
    );
    assert!(!r.has_errors(), "{}", r.render_text());
    assert_eq!(all_codes(&r), vec!["NL0202"], "{}", r.render_text());
    assert_eq!(r.diagnostics[0].layer.as_deref(), Some("b"));
}

// ------------------------------------------------------------ pass 4: memory

#[test]
fn ddr_over_budget_is_nl0301() {
    // LeNet deploy easily fits 2 GiB; on a 1 MiB board it cannot.
    let dep = zoo::deploy_by_name("lenet", 1).unwrap();
    let tiny = BoardParams {
        ddr_capacity_bytes: 1 << 20,
        ..Default::default()
    };
    let r = lint_net(
        &dep.param,
        &LintOptions {
            buckets: vec![1],
            board: tiny,
            forward_only: true,
            ..Default::default()
        },
    );
    assert_eq!(r.error_codes(), vec!["NL0301"], "{}", r.render_text());

    // Same net, default 2 GiB board: clean, with a memory report.
    let r = lint_net(
        &dep.param,
        &LintOptions {
            buckets: vec![1],
            forward_only: true,
            ..Default::default()
        },
    );
    assert!(r.is_clean(), "{}", r.render_text());
    assert!(r.memory.iter().all(|m| m.fits()));
}

#[test]
fn vgg16_training_at_batch_32_exceeds_2gb_nl0301() {
    // Paper §4.4: VGG-16 *training* does not fit the board's 2 GB DDR at
    // realistic batch sizes (data + diff for every blob and parameter),
    // while the forward-only deploy net at serving buckets does.
    let train = zoo::by_name("vgg16", 32).unwrap();
    let r = lint_net(
        &train,
        &LintOptions { phase: Phase::Train, ..Default::default() },
    );
    assert_eq!(r.error_codes(), vec!["NL0301"], "{}", r.render_text());
    assert!(r.memory.iter().any(|m| !m.fits()));
}

#[test]
fn memory_pass_accounts_at_the_serving_precision() {
    // LeNet deploy on a 1 MiB board: the fp32 footprint (~2 MB) fails
    // NL0301, but the int8 footprint (1 B/elem, ~0.5 MB) fits — the
    // diagnostic must say which precision it costed and point at the
    // `name@int8` escape hatch.
    let dep = zoo::deploy_by_name("lenet", 1).unwrap();
    let one_mib = BoardParams { ddr_capacity_bytes: 1 << 20, ..Default::default() };
    let r = lint_net(
        &dep.param,
        &LintOptions {
            buckets: vec![1],
            board: one_mib.clone(),
            forward_only: true,
            ..Default::default()
        },
    );
    assert_eq!(r.error_codes(), vec!["NL0301"], "{}", r.render_text());
    let text = r.render_text();
    assert!(text.contains("(fp32)"), "NL0301 must name the costed precision:\n{text}");
    assert!(text.contains("name@int8"), "help must suggest the int8 variant:\n{text}");
    assert!(!all_codes(&r).contains(&"NL0303"), "int8 fits, no NL0303:\n{text}");

    // Same board, linted *at* int8: clean — every device buffer is
    // costed at 1 byte per element.
    let r = lint_net(
        &dep.param,
        &LintOptions {
            buckets: vec![1],
            board: one_mib,
            forward_only: true,
            precision: fecaffe::quant::Precision::Int8,
            ..Default::default()
        },
    );
    assert!(r.is_clean(), "{}", r.render_text());
    assert!(r.memory.iter().all(|m| m.fits()), "{}", r.render_text());
}

#[test]
fn quantization_cannot_rescue_the_fit_is_nl0303() {
    // 256 KiB board: even the int8 footprint of LeNet's ~430k
    // parameters exceeds capacity, so alongside the NL0301 error the
    // linter warns (NL0303) that reduced precision is not an escape
    // hatch here — and the help text loses the int8 suggestion.
    let dep = zoo::deploy_by_name("lenet", 1).unwrap();
    let tiny = BoardParams { ddr_capacity_bytes: 1 << 18, ..Default::default() };
    let r = lint_net(
        &dep.param,
        &LintOptions {
            buckets: vec![1],
            board: tiny,
            forward_only: true,
            ..Default::default()
        },
    );
    assert_eq!(r.error_codes(), vec!["NL0301"], "{}", r.render_text());
    assert_eq!(all_codes(&r), vec!["NL0301", "NL0303"], "{}", r.render_text());
    let nl0303 = r.diagnostics.iter().find(|d| d.code == "NL0303").unwrap();
    assert_eq!(nl0303.severity, Severity::Warning);
    assert!(nl0303.message.contains("even int8-quantized"), "{}", nl0303.message);
    assert!(!r.render_text().contains("name@int8"), "{}", r.render_text());
}

// ------------------------------------------------------------ pass 5: solver

#[test]
fn unknown_lr_policy_is_nl0401() {
    // The prototxt parser rejects bad policies up front, so build the
    // solver config programmatically — lint guards the API path too.
    let net = zoo::by_name("lenet", 4).unwrap();
    let solver = SolverParameter {
        lr_policy: "bogus".to_string(),
        ..Default::default()
    };
    let r = lint_net(
        &net,
        &LintOptions {
            phase: Phase::Train,
            solver: Some(solver),
            ..Default::default()
        },
    );
    assert_eq!(r.error_codes(), vec!["NL0401"], "{}", r.render_text());
}

#[test]
fn degenerate_step_schedule_is_nl0402_warning() {
    let net = zoo::by_name("lenet", 4).unwrap();
    let solver = SolverParameter {
        lr_policy: "step".to_string(),
        stepsize: 0,
        ..Default::default()
    };
    let r = lint_net(
        &net,
        &LintOptions {
            phase: Phase::Train,
            solver: Some(solver),
            ..Default::default()
        },
    );
    assert!(!r.has_errors(), "{}", r.render_text());
    assert_eq!(all_codes(&r), vec!["NL0402"], "{}", r.render_text());
}

#[test]
fn non_ascending_multistep_is_nl0403() {
    let net = zoo::by_name("lenet", 4).unwrap();
    let solver = SolverParameter {
        lr_policy: "multistep".to_string(),
        stepvalue: vec![100, 50],
        ..Default::default()
    };
    let r = lint_net(
        &net,
        &LintOptions {
            phase: Phase::Train,
            solver: Some(solver),
            ..Default::default()
        },
    );
    assert_eq!(r.error_codes(), vec!["NL0403"], "{}", r.render_text());
}

// --------------------------------------------------------------- properties

/// Every zoo net must lint clean — the CI `lint-nets` leg runs
/// `fecaffe lint --deny-warnings` over the same set, and engine admission
/// refuses anything with errors, so a regression here bricks serving.
#[test]
fn zoo_nets_lint_clean_at_all_serving_buckets() {
    for name in zoo::NETWORKS {
        // Batch 1, like the CI leg's `fecaffe lint` default: VGG-16's
        // training footprint is DDR-marginal at larger batches (that is
        // the paper-§4.4 NL0301 test above, not a zoo regression).
        let train = zoo::by_name(name, 1).unwrap();
        let r = lint_net(
            &train,
            &LintOptions {
                phase: Phase::Train,
                solver: Some(zoo::default_solver(name).unwrap()),
                check_deploy_projection: true,
                ..Default::default()
            },
        );
        assert!(r.is_clean(), "{name} train: {}", r.render_text());

        let cap = serve_bucket_cap(name);
        let dep = zoo::deploy_by_name(name, 1).unwrap();
        let r = lint_net(
            &dep.param,
            &LintOptions {
                buckets: serve_buckets(cap),
                forward_only: true,
                ..Default::default()
            },
        );
        assert!(r.is_clean(), "{name} deploy: {}", r.render_text());
        assert_eq!(r.memory.len(), serve_buckets(cap).len());
        assert!(r.memory.iter().all(|m| m.fits()), "{name}: {}", r.render_text());
    }
}

/// The linter's allocation-free shape inference must agree bit-for-bit
/// with what `Net::reshape_batch` actually produces, for every zoo net at
/// every serving bucket — otherwise admission would approve shapes the
/// engine never executes. One sequential test (vgg16's parameters are
/// ~550 MB; don't build the heavy nets concurrently).
#[test]
fn lint_shape_inference_matches_reshape_batch() {
    for name in zoo::NETWORKS {
        let dep = zoo::deploy_by_name(name, 1).unwrap();
        let mut dev = CpuDevice::new();
        let mut net = Net::from_param(&dep.param, Phase::Test, &mut dev).unwrap();
        for b in serve_buckets(serve_bucket_cap(name)) {
            net.reshape_batch(&mut dev, b).unwrap();
            let inferred = infer_shapes(&dep.param, Phase::Test, Some(b)).unwrap();
            let blob_names = net.blob_names();
            assert_eq!(
                inferred.keys().cloned().collect::<Vec<_>>(),
                blob_names,
                "{name}@{b}: blob name sets diverge"
            );
            for n in &blob_names {
                let actual = net.blob(n).unwrap();
                let actual = actual.borrow();
                assert_eq!(
                    inferred[n].as_slice(),
                    actual.shape(),
                    "{name}@{b}: shape of '{n}' diverges"
                );
            }
        }
    }
}
