#!/usr/bin/env bash
# HTTP serving smoke test: start `serve --http` on an ephemeral port,
# hit healthz/predict/metrics through the binary's own load-generator
# path, then assert a clean drain on the SIGTERM-equivalent shutdown
# (POST /admin/shutdown). CI runs this after a release build.
set -euo pipefail

SERVE="${SERVE:-target/release/serve}"
LOG="$(mktemp)"
trap 'kill "$SERVER_PID" 2>/dev/null || true; rm -f "$LOG"' EXIT

[ -x "$SERVE" ] || { echo "serve binary not found at $SERVE (set SERVE=...)"; exit 1; }

"$SERVE" --http 127.0.0.1:0 --models lenet --workers 2 --max-batch 8 >"$LOG" 2>&1 &
SERVER_PID=$!

# Wait for the listener line and extract the bound address.
ADDR=""
for _ in $(seq 1 100); do
    ADDR="$(sed -n 's|.*listening on http://||p' "$LOG" | head -n1)"
    [ -n "$ADDR" ] && break
    kill -0 "$SERVER_PID" 2>/dev/null || { echo "server died during startup:"; cat "$LOG"; exit 1; }
    sleep 0.2
done
[ -n "$ADDR" ] || { echo "server never reported its address:"; cat "$LOG"; exit 1; }
echo "server up at $ADDR"

fail() { echo "FAIL: $1"; cat "$LOG"; exit 1; }

# healthz
curl -sf "http://$ADDR/healthz" | grep -q ok || fail "healthz"

# predict + metrics through the external load-generator path.
"$SERVE" --target "$ADDR" --net lenet --requests 64 --clients 4 || fail "http load generator"
curl -sf "http://$ADDR/metrics" | grep -q '"completed"' || fail "metrics"

# Unknown model must 404, not crash the server.
CODE="$(curl -s -o /dev/null -w '%{http_code}' -X POST \
    -d '{"instances": [[0]]}' "http://$ADDR/v1/models/resnet:predict")"
[ "$CODE" = "404" ] || fail "expected 404 for unknown model, got $CODE"

# SIGTERM-equivalent shutdown: the server must drain and exit 0.
curl -sf -X POST "http://$ADDR/admin/shutdown" >/dev/null || fail "admin shutdown"
for _ in $(seq 1 100); do
    kill -0 "$SERVER_PID" 2>/dev/null || break
    sleep 0.2
done
if kill -0 "$SERVER_PID" 2>/dev/null; then
    fail "server did not exit after /admin/shutdown"
fi
wait "$SERVER_PID" || fail "server exited non-zero"
grep -q "drained clean" "$LOG" || fail "server did not report a clean drain"
echo "http smoke: OK"
