//! GoogLeNet kernel breakdown (paper §4.2): run one F→B at batch 1 on
//! the simulated board and print the Table-2-style per-kernel statistics
//! plus the per-group layer times — the "deepest network" analysis the
//! paper uses to motivate its §5 optimization directions.
//!
//!     cargo run --release --example googlenet_breakdown

use fecaffe::bench_tables::{grouped_layer_times, timing_device};

fn main() -> anyhow::Result<()> {
    // Per-layer groups (Table 1 GoogLeNet column).
    let mut dev = timing_device();
    let rows = grouped_layer_times("googlenet", 1, &mut dev)?;
    println!("GoogLeNet per-group times (ms, batch 1):");
    let (mut tf, mut tb) = (0.0, 0.0);
    for (g, f, b) in &rows {
        println!("  {g:<12} fwd {f:>9.3}   bwd {b:>9.3}");
        tf += f;
        tb += b;
    }
    println!("  {:<12} fwd {tf:>9.3}   bwd {tb:>9.3}   F->B {:.3}\n", "TOTAL", tf + tb);

    // Kernel statistics (Table 2).
    let (text, stats) = fecaffe::bench_tables::table2()?;
    println!("{text}");

    // The §5.2 observation: im2col + col2im share of kernel time.
    use fecaffe::device::KClass;
    let kernel_ms: f64 = stats
        .iter()
        .filter(|(c, _)| !matches!(c, KClass::WriteBuffer | KClass::ReadBuffer))
        .map(|(_, v)| v.1)
        .sum();
    let im2col_ms = stats.get(&KClass::Im2col).map(|v| v.1).unwrap_or(0.0)
        + stats.get(&KClass::Col2im).map(|v| v.1).unwrap_or(0.0);
    println!(
        "im2col+col2im: {im2col_ms:.1} ms = {:.0}% of kernel time (paper: 37%) — \
         the §5.2 argument for CPU fallback of data-reshaping kernels",
        im2col_ms / kernel_ms * 100.0
    );
    Ok(())
}
