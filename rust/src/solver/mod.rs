//! Solvers — the paper's §4.3 training-on-FPGA machinery.
//!
//! Caffe's weight update has three compute phases, and FeCaffe maps each
//! to device kernels exactly as the paper describes: **normalization**
//! (`Scal` by 1/iter_size) and **regularization** (`Axpy` of λ·w into the
//! gradient) are "combinations of BLAS-based kernels", while the
//! **compute update** is a dedicated solver kernel per policy
//! (`SgdUpdate`, `NesterovUpdate`, `AdaGradUpdate`, `RmsPropUpdate`,
//! `AdaDeltaUpdate`, `AdamUpdate` — Table 4's "Solver Supported" row).
//!
//! Learning-rate policies, gradient clipping, snapshot/restore and the
//! train loop match `caffe::Solver`/`caffe::SGDSolver` semantics.

pub mod snapshot;

use crate::device::{BufId, Device, Kernel, KernelCall};
use crate::net::{Net, WeightSnapshot};
use crate::obs::TrainMetrics;
use crate::proto::{SolverKind, SolverParameter};
use std::sync::Arc;
use std::time::Instant;

pub struct Solver {
    pub param: SolverParameter,
    pub net: Net,
    pub iter: usize,
    /// Per-parameter history buffers on the device (1 slot for SGD-family,
    /// 2 for AdaDelta/Adam).
    history: Vec<Vec<BufId>>,
    /// Loss trace (one entry per iteration) for convergence reporting.
    pub loss_history: Vec<f32>,
    /// Wait-free training counters (iterations, last loss, phase-timing
    /// histograms). Behind an `Arc` so a serving front-end can hold the
    /// same handle and expose it on `/metrics` while training runs
    /// (`fecaffe train --serve` attaches it to the router).
    pub metrics: Arc<TrainMetrics>,
}

/// Learning rate for `p` at iteration `iter` — caffe
/// `SGDSolver::GetLearningRate`, all seven stock policies. Fails on an
/// unknown `lr_policy` instead of panicking: solver parameters built in
/// code (rather than parsed, where the policy is already validated)
/// reach here with arbitrary strings.
pub fn learning_rate_at(p: &SolverParameter, iter: usize) -> anyhow::Result<f32> {
    let t = iter as f32;
    let rate = match p.lr_policy.as_str() {
        "fixed" => p.base_lr,
        "step" => {
            let current_step = (iter / p.stepsize.max(1)) as i32;
            p.base_lr * p.gamma.powi(current_step)
        }
        "exp" => p.base_lr * p.gamma.powf(t),
        "inv" => p.base_lr * (1.0 + p.gamma * t).powf(-p.power),
        "poly" => {
            let max = p.max_iter.max(1) as f32;
            p.base_lr * (1.0 - t / max).max(0.0).powf(p.power)
        }
        "sigmoid" => p.base_lr / (1.0 + (-p.gamma * (t - p.stepsize as f32)).exp()),
        // Caffe advances `current_step_` once per stepvalue boundary
        // passed; with ascending stepvalues (and the rate queried every
        // iteration, as `apply_update` does) that equals the count of
        // boundaries at or below the current iteration.
        "multistep" => {
            let current_step = p.stepvalue.iter().filter(|&&s| iter >= s).count() as i32;
            p.base_lr * p.gamma.powi(current_step)
        }
        other => anyhow::bail!(
            "unknown lr_policy '{other}' (have: {})",
            crate::proto::LR_POLICIES.join(", ")
        ),
    };
    Ok(rate)
}

impl Solver {
    pub fn new(param: SolverParameter, net: Net, dev: &mut dyn Device) -> anyhow::Result<Solver> {
        // Reject unknown lr policies up front, so a bad configuration
        // fails at construction instead of iterations into a run.
        learning_rate_at(&param, 0)?;
        let slots = match param.kind {
            SolverKind::AdaDelta | SolverKind::Adam => 2,
            _ => 1,
        };
        let mut history = Vec::new();
        for p in net.params() {
            let n = p.blob.borrow().count();
            let mut bufs = Vec::new();
            for _ in 0..slots {
                let id = dev.alloc(n)?;
                // zero-initialize
                dev.launch(&KernelCall::new(
                    Kernel::SetConst { n, value: 0.0 },
                    &[],
                    &[id],
                ))?;
                bufs.push(id);
            }
            history.push(bufs);
        }
        Ok(Solver {
            param,
            net,
            iter: 0,
            history,
            loss_history: Vec::new(),
            metrics: Arc::new(TrainMetrics::new()),
        })
    }

    /// Current learning rate under the configured policy (caffe
    /// `GetLearningRate`). Unknown policies surface as `Err` —
    /// user-supplied solver prototxts reach here.
    pub fn learning_rate(&self) -> anyhow::Result<f32> {
        learning_rate_at(&self.param, self.iter)
    }

    /// One training iteration: forward/backward + update. Returns loss.
    /// Forward, backward and update wall time land in [`Solver::metrics`]
    /// (summed across `iter_size` accumulation passes, so one sample =
    /// one iteration regardless of accumulation).
    pub fn step(&mut self, dev: &mut dyn Device) -> anyhow::Result<f32> {
        let mut loss = 0.0;
        let (mut forward_ns, mut backward_ns) = (0u64, 0u64);
        // iter_size forward/backwards accumulate gradients (Caffe's
        // gradient accumulation for large effective batches).
        for _ in 0..self.param.iter_size {
            let t0 = Instant::now();
            loss += self.net.forward(dev)?;
            let t1 = Instant::now();
            self.net.backward(dev)?;
            forward_ns += (t1 - t0).as_nanos() as u64;
            backward_ns += t1.elapsed().as_nanos() as u64;
        }
        loss /= self.param.iter_size as f32;
        let t2 = Instant::now();
        self.apply_update(dev)?;
        let update_ns = t2.elapsed().as_nanos() as u64;
        self.iter += 1;
        self.loss_history.push(loss);
        self.metrics.record_iteration(forward_ns, backward_ns, update_ns, loss);
        Ok(loss)
    }

    /// Run `iters` iterations with Caffe-style display logging.
    pub fn solve(&mut self, dev: &mut dyn Device, iters: usize) -> anyhow::Result<()> {
        self.solve_with_publish(dev, iters, 0, &mut |_| Ok(()))
    }

    /// [`Solver::solve`] with a weight-publish hook: every
    /// `publish_every` iterations (0 = never) the current weights are
    /// exported as a [`WeightSnapshot`] and handed to `publish` — the
    /// train-and-serve loop, where the callback feeds a running
    /// `serve::Engine` (`fecaffe train --serve`). Export is O(1) per
    /// blob (host vectors move behind `Arc`s; the next update step
    /// detaches copy-on-write), so publishing barely perturbs training.
    pub fn solve_with_publish(
        &mut self,
        dev: &mut dyn Device,
        iters: usize,
        publish_every: usize,
        publish: &mut dyn FnMut(WeightSnapshot) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        for _ in 0..iters {
            let loss = self.step(dev)?;
            if self.param.display > 0 && self.iter % self.param.display == 0 {
                let lr = self.learning_rate()?;
                println!("Iteration {}, lr = {lr:.6}, loss = {loss:.6}", self.iter);
            }
            if self.param.snapshot > 0 && self.iter % self.param.snapshot == 0 {
                let path = format!("{}_iter_{}.fecaffemodel", self.param.snapshot_prefix, self.iter);
                snapshot::save(&path, self, dev)?;
            }
            if publish_every > 0 && self.iter % publish_every == 0 {
                let t0 = Instant::now();
                publish(self.export_weights(dev))?;
                self.metrics.record_publish(t0.elapsed().as_nanos() as u64);
            }
        }
        Ok(())
    }

    /// Export the training net's current weights as a publishable
    /// snapshot, tagged with the iteration. The version is left at 0
    /// ("unversioned") so a receiving engine assigns the next monotonic
    /// version — publish cadence and engine versioning stay decoupled.
    pub fn export_weights(&mut self, dev: &mut dyn Device) -> WeightSnapshot {
        self.net
            .share_weights(dev)
            .with_tag(format!("iter-{}", self.iter))
    }

    /// Normalize → regularize → clip → compute-update, all on-device.
    pub fn apply_update(&mut self, dev: &mut dyn Device) -> anyhow::Result<()> {
        let rate = self.learning_rate()?;
        let p = self.param.clone();

        // Gradient clipping by global L2 norm (host-side norm of the
        // per-param asums, like caffe's ClipGradients).
        let clip_scale = if p.clip_gradients > 0.0 {
            let mut sumsq = 0.0f64;
            for np in self.net.params() {
                let mut blob = np.blob.borrow_mut();
                let d = blob.diff.host_data(dev);
                sumsq += d.iter().map(|&v| f64::from(v) * f64::from(v)).sum::<f64>();
            }
            let l2 = sumsq.sqrt() as f32;
            if l2 > p.clip_gradients {
                p.clip_gradients / l2
            } else {
                1.0
            }
        } else {
            1.0
        };

        for (i, np) in self.net.params().iter().enumerate() {
            let mut blob = np.blob.borrow_mut();
            let n = blob.count();
            let diff_id = blob.diff.dev_data_rw(dev);
            let data_id = blob.data.dev_data_rw(dev);

            // 1. normalization: diff /= iter_size (skip when 1, like caffe)
            let mut scale = clip_scale;
            if p.iter_size > 1 {
                scale /= p.iter_size as f32;
            }
            if scale != 1.0 {
                dev.launch(&KernelCall::new(
                    Kernel::Scal { n, alpha: scale },
                    &[diff_id],
                    &[diff_id],
                ))?;
            }

            // 2. regularization: diff += λ·decay_mult · data  (L2)
            let local_decay = p.weight_decay * np.spec.decay_mult;
            if local_decay != 0.0 {
                match p.regularization_type.as_str() {
                    "L2" => {
                        dev.launch(&KernelCall::new(
                            Kernel::Axpy { n, alpha: local_decay },
                            &[data_id],
                            &[diff_id],
                        ))?;
                    }
                    "L1" => {
                        // sign(data) computed host-side into a temp, then axpy.
                        let sgn: Vec<f32> = blob
                            .data
                            .host_data(dev)
                            .iter()
                            .map(|&v| {
                                if v > 0.0 {
                                    1.0
                                } else if v < 0.0 {
                                    -1.0
                                } else {
                                    0.0
                                }
                            })
                            .collect();
                        let tmp = dev.alloc(n)?;
                        dev.write(tmp, &sgn);
                        dev.launch(&KernelCall::new(
                            Kernel::Axpy { n, alpha: local_decay },
                            &[tmp],
                            &[diff_id],
                        ))?;
                        dev.free(tmp);
                    }
                    other => anyhow::bail!("unknown regularization_type '{other}'"),
                }
            }

            // 3. compute update (dedicated kernel per solver type)
            let local_rate = rate * np.spec.lr_mult;
            let hist = &self.history[i];
            let kernel = match p.kind {
                SolverKind::Sgd => Kernel::SgdUpdate { n, lr: local_rate, momentum: p.momentum },
                SolverKind::Nesterov => {
                    Kernel::NesterovUpdate { n, lr: local_rate, momentum: p.momentum }
                }
                SolverKind::AdaGrad => {
                    Kernel::AdaGradUpdate { n, lr: local_rate, delta: p.delta }
                }
                SolverKind::RmsProp => Kernel::RmsPropUpdate {
                    n,
                    lr: local_rate,
                    decay: p.rms_decay,
                    delta: p.delta,
                },
                SolverKind::AdaDelta => Kernel::AdaDeltaUpdate {
                    n,
                    momentum: p.momentum,
                    delta: p.delta,
                    lr: local_rate,
                },
                SolverKind::Adam => Kernel::AdamUpdate {
                    n,
                    lr: local_rate,
                    beta1: p.momentum,
                    beta2: p.momentum2,
                    delta: p.delta,
                    t: self.iter + 1,
                },
            };
            let outputs: Vec<BufId> = hist.iter().copied().chain([data_id]).collect();
            dev.launch(&KernelCall::new(kernel, &[diff_id], &outputs))?;

            // Zero the diff for the next iteration (caffe:
            // net_->ClearParamDiffs()).
            dev.launch(&KernelCall::new(
                Kernel::SetConst { n, value: 0.0 },
                &[],
                &[diff_id],
            ))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::net::Net;
    use crate::proto::{parse_net, Phase};

    const NET: &str = r#"
name: "t"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 8 channels: 1 height: 8 width: 8 num_classes: 3 source: "digits" seed: 5 } }
layer { name: "fc" type: "InnerProduct" bottom: "data" top: "fc"
        inner_product_param { num_output: 3 weight_filler { type: "xavier" } } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc" bottom: "label" top: "loss" }
"#;

    fn mk_solver(kind: &str, dev: &mut CpuDevice) -> Solver {
        let netp = parse_net(NET).unwrap();
        let net = Net::from_param(&netp, Phase::Train, dev).unwrap();
        let mut sp = SolverParameter::default();
        sp.kind = SolverKind::from_ident(kind).unwrap();
        sp.base_lr = 0.05;
        sp.display = 0;
        Solver::new(sp, net, dev).unwrap()
    }

    #[test]
    fn every_solver_reduces_loss() {
        for kind in ["SGD", "Nesterov", "AdaGrad", "RMSProp", "AdaDelta", "Adam"] {
            let mut dev = CpuDevice::new();
            let mut s = mk_solver(kind, &mut dev);
            let mut iters = 60;
            if s.param.kind == SolverKind::AdaDelta {
                // caffe convention: adadelta lr ≈ 1; its effective step
                // warms up slowly (update history starts at zero)
                s.param.base_lr = 1.0;
                s.param.delta = 1e-2;
                iters = 300;
            }
            let first: f32 = (0..5).map(|_| s.step(&mut dev).unwrap()).sum::<f32>() / 5.0;
            for _ in 0..iters {
                s.step(&mut dev).unwrap();
            }
            let last: f32 =
                s.loss_history.iter().rev().take(5).sum::<f32>() / 5.0;
            assert!(
                last < first * 0.9,
                "{kind}: loss did not decrease ({first} → {last})"
            );
        }
    }

    #[test]
    fn lr_policies() {
        let mut dev = CpuDevice::new();
        let mut s = mk_solver("SGD", &mut dev);
        s.param.base_lr = 0.1;
        s.param.lr_policy = "step".into();
        s.param.gamma = 0.5;
        s.param.stepsize = 10;
        s.iter = 0;
        assert_eq!(s.learning_rate().unwrap(), 0.1);
        s.iter = 10;
        assert_eq!(s.learning_rate().unwrap(), 0.05);
        s.iter = 25;
        assert_eq!(s.learning_rate().unwrap(), 0.025);

        s.param.lr_policy = "inv".into();
        s.param.gamma = 1e-4;
        s.param.power = 0.75;
        s.iter = 0;
        assert_eq!(s.learning_rate().unwrap(), 0.1);
        s.iter = 10000;
        assert!(s.learning_rate().unwrap() < 0.1);

        s.param.lr_policy = "poly".into();
        s.param.max_iter = 100;
        s.iter = 100;
        assert_eq!(s.learning_rate().unwrap(), 0.0);

        s.param.lr_policy = "multistep".into();
        s.param.gamma = 0.5;
        s.param.stepvalue = vec![10, 20];
        s.iter = 9;
        assert_eq!(s.learning_rate().unwrap(), 0.1);
        s.iter = 10;
        assert_eq!(s.learning_rate().unwrap(), 0.05);
        s.iter = 25;
        assert_eq!(s.learning_rate().unwrap(), 0.025);
    }

    #[test]
    fn unknown_lr_policy_is_an_error_not_a_panic() {
        let mut dev = CpuDevice::new();
        let mut s = mk_solver("SGD", &mut dev);
        s.param.lr_policy = "bogus".into();
        let err = s.learning_rate().unwrap_err().to_string();
        assert!(err.contains("unknown lr_policy 'bogus'"), "{err}");
        // Mid-training the error propagates out of step() instead of
        // aborting the process.
        assert!(s.step(&mut dev).is_err());
        // And Solver::new rejects the configuration up front.
        let netp = parse_net(NET).unwrap();
        let net = Net::from_param(&netp, Phase::Train, &mut dev).unwrap();
        let mut sp = SolverParameter::default();
        sp.lr_policy = "nope".into();
        assert!(Solver::new(sp, net, &mut dev).is_err());
    }

    #[test]
    fn publish_hook_fires_on_cadence() {
        let mut dev = CpuDevice::new();
        let mut s = mk_solver("SGD", &mut dev);
        let mut published: Vec<(usize, String)> = Vec::new();
        s.solve_with_publish(&mut dev, 10, 3, &mut |snap| {
            published.push((snap.len(), snap.tag().unwrap_or("").to_string()));
            Ok(())
        })
        .unwrap();
        // Iterations 3, 6 and 9 publish; each snapshot covers both fc
        // param blobs (weight + bias).
        let tags: Vec<&str> = published.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(tags, vec!["iter-3", "iter-6", "iter-9"]);
        assert!(published.iter().all(|(n, _)| *n == 2), "{published:?}");
        assert_eq!(s.iter, 10);
        // Training metrics tracked the run: one sample per iteration,
        // one publish timing per callback invocation.
        let m = s.metrics.to_json();
        assert_eq!(m.get("iterations").unwrap().as_usize().unwrap(), 10);
        assert_eq!(m.get("publishes").unwrap().as_usize().unwrap(), 3);
        assert_eq!(
            m.get("last_loss").unwrap().as_f64().unwrap() as f32,
            *s.loss_history.last().unwrap()
        );
    }

    #[test]
    fn exported_weights_are_immutable_under_further_training() {
        let mut dev = CpuDevice::new();
        let mut s = mk_solver("SGD", &mut dev);
        s.step(&mut dev).unwrap();
        let snap = s.export_weights(&mut dev);
        assert_eq!(snap.tag(), Some("iter-1"));
        assert_eq!(snap.version(), 0, "solver snapshots are engine-versioned");
        let frozen: Vec<f32> = snap.blob_data(0).unwrap().to_vec();
        // Training on must not write through the exported Arc (the
        // solver's update detaches copy-on-write)...
        for _ in 0..5 {
            s.step(&mut dev).unwrap();
        }
        assert_eq!(snap.blob_data(0).unwrap(), frozen.as_slice());
        // ...while the solver's live weights have moved past it.
        let live = s.export_weights(&mut dev);
        assert_ne!(live.blob_data(0).unwrap(), frozen.as_slice());
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let mut dev = CpuDevice::new();
        let mut s = mk_solver("SGD", &mut dev);
        s.param.weight_decay = 0.5;
        s.param.base_lr = 0.1;
        s.param.momentum = 0.0;
        // Zero gradients path: update = -lr*decay*w ⇒ weights shrink.
        let w0: f32 = {
            let p = &s.net.params()[0];
            let mut b = p.blob.borrow_mut();
            b.data.host_data(&mut dev).iter().map(|v| v.abs()).sum()
        };
        s.apply_update(&mut dev).unwrap();
        let w1: f32 = {
            let p = &s.net.params()[0];
            let mut b = p.blob.borrow_mut();
            b.data.host_data(&mut dev).iter().map(|v| v.abs()).sum()
        };
        assert!(w1 < w0, "decay should shrink weights: {w0} → {w1}");
    }

    #[test]
    fn gradient_clipping_bounds_update() {
        let mut dev = CpuDevice::new();
        let mut s = mk_solver("SGD", &mut dev);
        s.param.clip_gradients = 1e-3;
        s.param.momentum = 0.0;
        s.net.forward_backward(&mut dev).unwrap();
        // L2 of all diffs after clipping must be ≤ clip (checked via data
        // change magnitude ≈ lr * clipped grad)
        let before: Vec<f32> = {
            let p = &s.net.params()[0];
            let mut b = p.blob.borrow_mut();
            b.data.host_data(&mut dev).to_vec()
        };
        s.apply_update(&mut dev).unwrap();
        let after: Vec<f32> = {
            let p = &s.net.params()[0];
            let mut b = p.blob.borrow_mut();
            b.data.host_data(&mut dev).to_vec()
        };
        let delta_l2: f32 = before
            .iter()
            .zip(after.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        assert!(delta_l2 <= s.param.base_lr * 1.2e-3, "delta {delta_l2}");
    }

    #[test]
    fn diffs_cleared_after_update() {
        let mut dev = CpuDevice::new();
        let mut s = mk_solver("SGD", &mut dev);
        s.step(&mut dev).unwrap();
        for p in s.net.params() {
            let mut b = p.blob.borrow_mut();
            assert!(b.diff.host_data(&mut dev).iter().all(|&v| v == 0.0));
        }
    }
}
