//! The simulated Stratix 10 OpenCL board (DESIGN.md §2's central
//! substitution).
//!
//! * numerics: kernels execute for real — through a PJRT-compiled AOT
//!   artifact when the runtime provides one (the `.aocx` analogue), else
//!   through the native math library;
//! * timing: a deterministic event model with three lanes (host, PCIe
//!   channel, kernel engine) driven by [`costmodel::CostModel`]. The
//!   paper's synchronous OpenCL interface (§5.2) is the default
//!   [`QueueMode::Sync`]; the §5.2 "asynchronous mechanism" optimization
//!   is [`QueueMode::Async`], benchmarked by `benches/ablation_async.rs`;
//! * capacity: a [`ddr::DdrTracker`] enforcing the board's 2 GB.

pub mod costmodel;
pub mod ddr;
pub mod profiler;
pub mod resources;

use super::native::{execute, Slab};
use super::{BufId, Device, KClass, KernelCall, ScratchAction, ScratchPool};
use costmodel::CostModel;
use ddr::DdrTracker;
use profiler::Profiler;

/// Pluggable numerical engine (implemented by `runtime::PjrtBackend`).
/// Returns Ok(true) if it executed the call, Ok(false) if no artifact
/// covers it (caller falls back to native math). `Send` so a device
/// holding a backend can move into a serving worker thread.
pub trait NumericBackend: Send {
    fn execute(&mut self, slab: &mut Slab, call: &KernelCall) -> anyhow::Result<bool>;
    /// Identifier for logs.
    fn name(&self) -> &'static str;
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueMode {
    /// Paper default: host blocks on every kernel and transfer.
    Sync,
    /// §5.2 optimization: host enqueues; PCIe overlaps kernel execution.
    Async,
}

pub struct FpgaSimDevice {
    slab: Slab,
    ddr: DdrTracker,
    pub cost: CostModel,
    pub profiler: Profiler,
    mode: QueueMode,
    backend: Option<Box<dyn NumericBackend>>,
    /// Simulated clocks, ns.
    host_ns: u64,
    kernel_free_ns: u64,
    pcie_free_ns: u64,
    /// Async submission overhead (queue push instead of blocking wait).
    async_submit_ns: u64,
    scratch: ScratchPool,
    /// Timing-only mode: bill simulated time but skip numerical kernel
    /// execution (for whole-net timing studies — Table 1/4 benches).
    pub timing_only: bool,
    /// §5.2 workload partitioning: kernel classes routed to the *host*
    /// instead of the FPGA ("it is wiser to deploy such memory-bounded
    /// and small functions on CPU"). Host execution bills host-memory
    /// streaming time on the host lane plus the PCIe transfers the
    /// partition implies, and frees the FPGA kernel engine.
    pub host_classes: std::collections::BTreeSet<KClass>,
    /// Effective host memory bandwidth for partitioned kernels (a single
    /// Core i7-7700K channel pair sustains ~20 GB/s).
    pub host_bw_bytes_per_s: f64,
    /// Intra-op thread cap for *native* kernel execution (0 = inherit).
    /// Only the host-side numerics engine parallelizes; the simulated
    /// board's timing is unaffected.
    intra_op: usize,
}

impl FpgaSimDevice {
    pub fn new() -> FpgaSimDevice {
        let cost = CostModel::new();
        let capacity = cost.board.ddr_capacity_bytes;
        FpgaSimDevice {
            slab: Slab::new(),
            ddr: DdrTracker::new(capacity),
            cost,
            profiler: Profiler::new(),
            mode: QueueMode::Sync,
            backend: None,
            host_ns: 0,
            kernel_free_ns: 0,
            pcie_free_ns: 0,
            async_submit_ns: 20_000,
            scratch: ScratchPool::new(),
            timing_only: false,
            host_classes: Default::default(),
            host_bw_bytes_per_s: 20.0e9,
            intra_op: 0,
        }
    }

    /// Cap native-numerics kernels at `threads` intra-op threads
    /// (0 clears the cap); see [`crate::util::pool`].
    pub fn with_intra_op(mut self, threads: usize) -> FpgaSimDevice {
        self.intra_op = threads;
        self
    }

    /// Enable §5.2 partitioning for a kernel class (e.g. Im2col/Col2im).
    pub fn partition_to_host(&mut self, class: KClass) {
        self.host_classes.insert(class);
    }

    /// Override the simulated board's DDR capacity (documented deviations
    /// only — see EXPERIMENTS.md notes on VGG-16 Table 1).
    pub fn with_capacity(mut self, bytes: u64) -> FpgaSimDevice {
        self.cost.board.ddr_capacity_bytes = bytes;
        self.ddr = DdrTracker::new(bytes);
        self
    }

    pub fn with_backend(mut self, backend: Box<dyn NumericBackend>) -> FpgaSimDevice {
        self.backend = Some(backend);
        self
    }

    /// Model a bitstream compiled at `precision`: the cost model re-rates
    /// matmul compute and DDR traffic, and device-memory accounting plus
    /// PCIe transfer billing use the narrow element width (host buffers
    /// stay f32 — the narrowing is what the real board's DMA would do).
    pub fn with_precision(mut self, precision: crate::quant::Precision) -> FpgaSimDevice {
        self.cost.precision = precision;
        self
    }

    /// Modeled bytes per stored element at this device's precision.
    fn elem_bytes(&self) -> u64 {
        self.cost.precision.elem_bytes()
    }

    pub fn set_mode(&mut self, mode: QueueMode) {
        self.mode = mode;
    }

    pub fn mode(&self) -> QueueMode {
        self.mode
    }

    pub fn ddr(&self) -> &DdrTracker {
        &self.ddr
    }

    /// Reset simulated clocks + profiler (keep memory contents).
    pub fn reset_timing(&mut self) {
        self.host_ns = 0;
        self.kernel_free_ns = 0;
        self.pcie_free_ns = 0;
        self.profiler.reset();
    }

    fn completion(&self) -> u64 {
        self.host_ns.max(self.kernel_free_ns).max(self.pcie_free_ns)
    }

    /// Schedule a span of `dur` on a lane (`engine_free`), honoring the
    /// queue mode. Returns (start, end).
    fn schedule(&mut self, engine_free: &mut u64, dur: u64, overhead: u64) -> (u64, u64) {
        match self.mode {
            QueueMode::Sync => {
                // Host pays overhead, then blocks until the engine finishes.
                self.host_ns += overhead;
                let start = self.host_ns.max(*engine_free);
                let end = start + dur;
                self.host_ns = end;
                *engine_free = end;
                (start, end)
            }
            QueueMode::Async => {
                self.host_ns += self.async_submit_ns.min(overhead);
                let start = self.host_ns.max(*engine_free);
                let end = start + dur;
                *engine_free = end;
                (start, end)
            }
        }
    }

    fn bill_kernel(&mut self, call: &KernelCall) -> (u64, u64) {
        let dur = self.cost.kernel_time_ns(&call.kernel);
        let overhead = self.cost.launch_overhead_ns();
        let mut engine = self.kernel_free_ns;
        let span = self.schedule(&mut engine, dur, overhead);
        self.kernel_free_ns = engine;
        span
    }

    fn bill_pcie(&mut self, bytes: u64, class: KClass, blocking: bool) {
        let dur = self.cost.pcie_time_ns(bytes);
        let overhead = self.cost.launch_overhead_ns() / 4;
        if blocking {
            // Reads always drain outstanding work first (OpenCL finish()).
            self.host_ns = self.completion();
        }
        let mut engine = self.pcie_free_ns;
        let (start, end) = self.schedule(&mut engine, dur, overhead);
        self.pcie_free_ns = engine;
        if blocking {
            self.host_ns = end;
        }
        let label = class.label();
        self.profiler.record(class, label, "pcie", start, end - start);
    }
}

impl Default for FpgaSimDevice {
    fn default() -> Self {
        Self::new()
    }
}

impl Device for FpgaSimDevice {
    fn kind(&self) -> &'static str {
        "fpga-sim"
    }

    fn alloc(&mut self, len: usize) -> anyhow::Result<BufId> {
        // Account DDR capacity first; then back the buffer in the slab.
        let id = self.slab.alloc(len);
        if let Err(e) = self.ddr.alloc(id.0, len as u64 * self.elem_bytes()) {
            self.slab.free(id);
            return Err(anyhow::anyhow!(e));
        }
        Ok(id)
    }

    fn free(&mut self, id: BufId) {
        self.ddr.free(id.0);
        self.slab.free(id);
    }

    fn write(&mut self, id: BufId, data: &[f32]) {
        self.bill_pcie(data.len() as u64 * self.elem_bytes(), KClass::WriteBuffer, false);
        let buf = self.slab.get_mut(id);
        buf[..data.len()].copy_from_slice(data);
    }

    fn read(&mut self, id: BufId, out: &mut [f32]) {
        self.bill_pcie(out.len() as u64 * self.elem_bytes(), KClass::ReadBuffer, true);
        let buf = self.slab.get(id);
        out.copy_from_slice(&buf[..out.len()]);
    }

    fn launch(&mut self, call: &KernelCall) -> anyhow::Result<()> {
        // Numerics: artifact path if available, else native fallback.
        // (Skipped entirely in timing-only mode.)
        if !self.timing_only {
            let via_artifact = match self.backend.as_mut() {
                Some(b) => b.execute(&mut self.slab, call)?,
                None => false,
            };
            if via_artifact {
                self.profiler.artifact_launches += 1;
            } else {
                let slab = &mut self.slab;
                crate::util::pool::with_intra_op(self.intra_op, || execute(slab, call))?;
                self.profiler.native_launches += 1;
            }
        }
        // Timing: cost model regardless of the numerical engine.
        let class = call.kernel.class();
        if self.host_classes.contains(&class) {
            // §5.2 partition: run on the host. The operands cross PCIe
            // (billed on the PCIe lane) and the compute streams host
            // memory; the FPGA kernel engine stays free.
            let bytes = call.kernel.bytes() * self.elem_bytes() / 4;
            self.bill_pcie(bytes / 2, KClass::ReadBuffer, true);
            let dur = (bytes as f64 / self.host_bw_bytes_per_s * 1e9) as u64;
            let start = self.host_ns;
            self.host_ns += dur;
            self.bill_pcie(bytes / 2, KClass::WriteBuffer, false);
            self.profiler
                .record(class, class.label(), "host", start, dur);
        } else {
            let (start, end) = self.bill_kernel(call);
            self.profiler
                .record(class, class.label(), "fpga-kernel", start, end - start);
        }
        Ok(())
    }

    fn synchronize(&mut self) {
        self.host_ns = self.completion();
    }

    fn scratch(&mut self, slot: usize, len: usize) -> anyhow::Result<BufId> {
        match self.scratch.plan(slot, len) {
            ScratchAction::Use(id) => Ok(id),
            ScratchAction::Grow(old) => {
                if let Some(id) = old {
                    self.ddr.free(id.0);
                    self.slab.free(id);
                }
                let id = self.slab.alloc(len);
                if let Err(e) = self.ddr.alloc(id.0, len as u64 * self.elem_bytes()) {
                    self.slab.free(id);
                    return Err(anyhow::anyhow!(e));
                }
                self.scratch.commit(slot, id, len);
                Ok(id)
            }
        }
    }

    fn sim_clock_ns(&self) -> Option<u64> {
        Some(self.completion())
    }

    fn set_span_recording(&mut self, on: bool) {
        self.profiler.record_spans = on;
    }

    fn take_spans(&mut self) -> Vec<profiler::Span> {
        self.profiler.take_spans()
    }

    fn kernel_stats(&self) -> Vec<(&'static str, u64, u64)> {
        self.profiler
            .stats()
            .iter()
            .map(|(class, s)| (class.label(), s.instances, s.total_ns))
            .collect()
    }

    fn reset_timing(&mut self) {
        // Resolves to the inherent method (clocks + profiler), exposed
        // here so `Box<dyn Device>` callers (the profile CLI) can reset
        // without downcasting.
        FpgaSimDevice::reset_timing(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Kernel;

    fn relu_call(dev: &mut FpgaSimDevice, n: usize) -> KernelCall {
        let x = dev.alloc(n).unwrap();
        let y = dev.alloc(n).unwrap();
        dev.write(x, &vec![1.0; n]);
        KernelCall::new(Kernel::ReluF { n, slope: 0.0 }, &[x], &[y])
    }

    #[test]
    fn sync_mode_serializes_everything() {
        let mut dev = FpgaSimDevice::new();
        let call = relu_call(&mut dev, 1000);
        let t0 = dev.sim_clock_ns().unwrap();
        dev.launch(&call).unwrap();
        dev.launch(&call).unwrap();
        let t1 = dev.sim_clock_ns().unwrap();
        let per = (t1 - t0) / 2;
        // Each launch ≥ launch overhead (0.27 ms)
        assert!(per >= dev.cost.launch_overhead_ns());
    }

    #[test]
    fn async_mode_is_faster_than_sync() {
        let mk = |mode| {
            let mut dev = FpgaSimDevice::new();
            dev.set_mode(mode);
            let n = 200_000;
            let x = dev.alloc(n).unwrap();
            let y = dev.alloc(n).unwrap();
            let data = vec![1.0f32; n];
            for _ in 0..10 {
                dev.write(x, &data);
                dev.launch(&KernelCall::new(
                    Kernel::ReluF { n, slope: 0.0 },
                    &[x],
                    &[y],
                ))
                .unwrap();
            }
            dev.synchronize();
            dev.sim_clock_ns().unwrap()
        };
        let sync = mk(QueueMode::Sync);
        let async_ = mk(QueueMode::Async);
        assert!(
            async_ < sync,
            "async ({async_}) should beat sync ({sync}) by overlapping PCIe"
        );
    }

    #[test]
    fn ddr_capacity_enforced() {
        let mut dev = FpgaSimDevice::new();
        dev.cost.board.ddr_capacity_bytes = 1024;
        dev.ddr = DdrTracker::new(1024);
        let a = dev.alloc(200).unwrap(); // 800 B
        assert!(dev.alloc(100).is_err()); // 400 B > remaining
        dev.free(a);
        assert!(dev.alloc(100).is_ok());
    }

    #[test]
    fn profiler_counts_match_activity() {
        let mut dev = FpgaSimDevice::new();
        let call = relu_call(&mut dev, 100);
        dev.launch(&call).unwrap();
        dev.launch(&call).unwrap();
        let stats = dev.profiler.stats();
        assert_eq!(stats[&KClass::ReluF].instances, 2);
        assert_eq!(stats[&KClass::WriteBuffer].instances, 1);
        assert_eq!(dev.profiler.native_launches, 2);
    }

    #[test]
    fn numerics_match_cpu_device() {
        use crate::device::cpu::CpuDevice;
        let mut fpga = FpgaSimDevice::new();
        let mut cpu = CpuDevice::new();
        let data: Vec<f32> = (-50..50).map(|v| v as f32 * 0.1).collect();
        for dev in [&mut fpga as &mut dyn Device, &mut cpu as &mut dyn Device] {
            let x = dev.alloc(100).unwrap();
            let y = dev.alloc(100).unwrap();
            dev.write(x, &data);
            dev.launch(&KernelCall::new(
                Kernel::ReluF { n: 100, slope: 0.1 },
                &[x],
                &[y],
            ))
            .unwrap();
        }
        // Both executed natively → identical results by construction; check
        // via read.
        let mut out_f = vec![0.0; 100];
        let mut out_c = vec![0.0; 100];
        // re-derive ids: second alloc in each device is BufId(1)
        fpga.read(BufId(1), &mut out_f);
        cpu.read(BufId(1), &mut out_c);
        assert_eq!(out_f, out_c);
    }

    #[test]
    fn host_partition_moves_kernel_off_fpga_lane() {
        let mut dev = FpgaSimDevice::new();
        dev.timing_only = true;
        dev.partition_to_host(KClass::Im2col);
        let geom = crate::math::ConvGeom {
            channels: 3, height: 32, width: 32,
            kernel_h: 3, kernel_w: 3, pad_h: 1, pad_w: 1, stride_h: 1, stride_w: 1,
        };
        let im = dev.alloc(geom.im_len()).unwrap();
        let col = dev.alloc(geom.col_len()).unwrap();
        dev.launch(&KernelCall::new(Kernel::Im2col { geom }, &[im], &[col]))
            .unwrap();
        let stats = dev.profiler.stats();
        assert_eq!(stats[&KClass::Im2col].instances, 1);
        // partition paid PCIe both ways
        assert!(stats.contains_key(&KClass::ReadBuffer));
        assert!(stats.contains_key(&KClass::WriteBuffer));
    }

    #[test]
    fn int8_device_quarters_ddr_and_pcie_accounting() {
        use crate::quant::Precision;
        // Same element count costs 1/4 the DDR budget at int8…
        let mut fp32 = FpgaSimDevice::new().with_capacity(4096);
        let mut int8 = FpgaSimDevice::new().with_capacity(4096).with_precision(Precision::Int8);
        assert!(fp32.alloc(2048).is_err(), "8 KiB of f32 must not fit in 4 KiB");
        assert!(int8.alloc(2048).is_ok(), "2 KiB of int8 fits in 4 KiB");
        // …and PCIe uploads bill a quarter of the bytes.
        let mut fp32 = FpgaSimDevice::new();
        let mut int8 = FpgaSimDevice::new().with_precision(Precision::Int8);
        let data = vec![1.0f32; 1_000_000];
        let a = fp32.alloc(data.len()).unwrap();
        let b = int8.alloc(data.len()).unwrap();
        fp32.write(a, &data);
        int8.write(b, &data);
        let t32 = fp32.sim_clock_ns().unwrap();
        let t8 = int8.sim_clock_ns().unwrap();
        assert!(
            t8 < t32 / 2,
            "int8 upload ({t8} ns) should be well under half the fp32 upload ({t32} ns)"
        );
    }

    #[test]
    fn reset_timing_zeroes_clock() {
        let mut dev = FpgaSimDevice::new();
        let call = relu_call(&mut dev, 10);
        dev.launch(&call).unwrap();
        assert!(dev.sim_clock_ns().unwrap() > 0);
        dev.reset_timing();
        assert_eq!(dev.sim_clock_ns().unwrap(), 0);
        assert_eq!(dev.profiler.total_instances(), 0);
    }
}
