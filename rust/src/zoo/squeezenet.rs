//! SqueezeNet v1.0 — fire modules (squeeze 1×1 → expand 1×1 ∥ 3×3 →
//! concat). The paper highlights FeCaffe as the *first* to train
//! SqueezeNet on FPGA; Table 1's "fire" rows aggregate each module.

use super::NetBuilder;
use crate::proto::{NetParameter, PoolMethod};

/// Append fire module `name` on `bottom`: squeeze s1x1, expand e1x1+e3x3.
pub fn fire(b: &mut NetBuilder, name: &str, bottom: &str, s: usize, e1: usize, e3: usize) {
    let sq = format!("{name}/squeeze1x1");
    let ex1 = format!("{name}/expand1x1");
    let ex3 = format!("{name}/expand3x3");
    b.conv_relu(&sq, bottom, s, 1, 1, 0);
    b.conv_relu(&ex1, &sq, e1, 1, 1, 0);
    b.conv_relu(&ex3, &sq, e3, 3, 1, 1);
    b.concat(&format!("{name}/concat"), &[&ex1, &ex3]);
}

pub fn squeezenet(batch: usize) -> NetParameter {
    let mut b = NetBuilder::new("SqueezeNet_v1.0");
    b.data(batch, 3, 227, 1000, "imagenet");
    b.conv_relu("conv1", "data", 96, 7, 2, 0);
    b.pool("pool1", "conv1", PoolMethod::Max, 3, 2, 0);
    fire(&mut b, "fire2", "pool1", 16, 64, 64);
    fire(&mut b, "fire3", "fire2/concat", 16, 64, 64);
    fire(&mut b, "fire4", "fire3/concat", 32, 128, 128);
    b.pool("pool4", "fire4/concat", PoolMethod::Max, 3, 2, 0);
    fire(&mut b, "fire5", "pool4", 32, 128, 128);
    fire(&mut b, "fire6", "fire5/concat", 48, 192, 192);
    fire(&mut b, "fire7", "fire6/concat", 48, 192, 192);
    fire(&mut b, "fire8", "fire7/concat", 64, 256, 256);
    b.pool("pool8", "fire8/concat", PoolMethod::Max, 3, 2, 0);
    fire(&mut b, "fire9", "pool8", 64, 256, 256);
    b.dropout_inplace("drop9", "fire9/concat", 0.5);
    b.conv_relu("conv10", "fire9/concat", 1000, 1, 1, 0);
    b.global_ave_pool("pool10", "conv10");
    b.accuracy("accuracy", "pool10");
    b.softmax_loss("loss", "pool10", 1.0);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::net::Net;
    use crate::proto::Phase;

    #[test]
    fn structure() {
        let net = squeezenet(1);
        let convs = net.layers.iter().filter(|l| l.kind == "Convolution").count();
        // conv1 + 8 fires × 3 + conv10 = 26
        assert_eq!(convs, 26);
        let concats = net.layers.iter().filter(|l| l.kind == "Concat").count();
        assert_eq!(concats, 8);
    }

    #[test]
    fn builds_and_fans_out_with_splits() {
        let mut dev = CpuDevice::new();
        let param = squeezenet(1);
        let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        // fire squeeze output feeds both expands → Split layers inserted
        assert!(net.layer_kinds().iter().filter(|&&k| k == "Split").count() >= 8);
        let shape = |n: &str| net.blob(n).unwrap().borrow().shape().to_vec();
        assert_eq!(shape("conv1"), vec![1, 96, 111, 111]);
        assert_eq!(shape("pool1"), vec![1, 96, 55, 55]);
        assert_eq!(shape("fire2/concat"), vec![1, 128, 55, 55]);
        assert_eq!(shape("pool10"), vec![1, 1000, 1, 1]);
        // ~1.25M params
        let p = net.num_parameters();
        assert!((1_150_000..1_350_000).contains(&p), "params {p}");
    }
}
