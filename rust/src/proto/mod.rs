//! Caffe prototxt support: a protobuf *text format* subset parser,
//! a generic message tree, typed schema extraction, and an emitter.
//!
//! FeCaffe's "ease of use" claim (paper Table 4) is that users keep the
//! conventional Caffe workflow — prototxt + solver files + snapshots —
//! unchanged while kernels run on the FPGA. This module makes that real:
//! the model zoo, the CLI (`fecaffe train --solver ...`) and the tests all
//! speak standard prototxt.

pub mod lexer;
pub mod ast;
pub mod schema;
pub mod emit;

pub use ast::{PMessage, PValue};
pub use schema::*;

/// Parse prototxt text into a generic message tree.
pub fn parse_text(text: &str) -> Result<PMessage, String> {
    let tokens = lexer::lex(text)?;
    ast::parse(&tokens)
}

/// Parse a full NetParameter from prototxt text.
pub fn parse_net(text: &str) -> Result<NetParameter, String> {
    NetParameter::from_message(&parse_text(text)?)
}

/// Parse a SolverParameter from prototxt text.
pub fn parse_solver(text: &str) -> Result<SolverParameter, String> {
    SolverParameter::from_message(&parse_text(text)?)
}
