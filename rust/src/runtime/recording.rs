//! RecordingDevice — walks networks collecting the exact (kernel, shape)
//! set they launch, to drive manifest generation (`gen-manifest`).
//!
//! By default launches are *not* executed numerically (shapes are fixed
//! by host-side setup, so recording a VGG-16 F→B takes milliseconds, not
//! minutes); pass `compute = true` when recorded runs must also produce
//! real numbers.

use crate::device::native::{execute, Slab};
use crate::device::{BufId, Device, KernelCall, ScratchAction, ScratchPool};
use crate::runtime::plan::kernel_plan;
use crate::util::json::Json;
use std::collections::BTreeMap;

pub struct RecordingDevice {
    slab: Slab,
    scratch: ScratchPool,
    pub compute: bool,
    /// key → lowering spec
    pub specs: BTreeMap<String, Json>,
    pub native_only: u64,
    pub launches: u64,
}

impl RecordingDevice {
    pub fn new(compute: bool) -> RecordingDevice {
        RecordingDevice {
            slab: Slab::new(),
            scratch: ScratchPool::new(),
            compute,
            specs: BTreeMap::new(),
            native_only: 0,
            launches: 0,
        }
    }

    /// The manifest document: {"artifacts": {key: spec}}.
    pub fn manifest(&self) -> Json {
        let mut arts = Json::obj();
        for (k, v) in &self.specs {
            arts.set(k, v.clone());
        }
        let mut root = Json::obj();
        root.set("artifacts", arts);
        root.set("version", Json::num(1));
        root
    }

    /// Merge another recording into this one.
    pub fn merge_from(&mut self, other: &RecordingDevice) {
        for (k, v) in &other.specs {
            self.specs.insert(k.clone(), v.clone());
        }
    }

    /// The recorded plans as sorted `(key, compact spec JSON)` pairs —
    /// the form the AOT `FEPLAN1` container serializes. `specs` is a
    /// `BTreeMap` and `Json::to_string` emits object keys in sorted
    /// order, so two recordings of the same net produce identical
    /// entries byte for byte.
    pub fn spec_entries(&self) -> Vec<(String, String)> {
        self.specs.iter().map(|(k, v)| (k.clone(), v.to_string())).collect()
    }
}

impl Device for RecordingDevice {
    fn kind(&self) -> &'static str {
        "recording"
    }

    fn alloc(&mut self, len: usize) -> anyhow::Result<BufId> {
        Ok(self.slab.alloc(len))
    }

    fn free(&mut self, id: BufId) {
        self.slab.free(id);
    }

    fn write(&mut self, id: BufId, data: &[f32]) {
        self.slab.get_mut(id)[..data.len()].copy_from_slice(data);
    }

    fn read(&mut self, id: BufId, out: &mut [f32]) {
        let buf = self.slab.get(id);
        out.copy_from_slice(&buf[..out.len()]);
    }

    fn launch(&mut self, call: &KernelCall) -> anyhow::Result<()> {
        self.launches += 1;
        match kernel_plan(&call.kernel) {
            Some(plan) => {
                self.specs.entry(plan.key).or_insert(plan.spec);
            }
            None => self.native_only += 1,
        }
        if self.compute {
            execute(&mut self.slab, call)?;
        }
        Ok(())
    }

    fn scratch(&mut self, slot: usize, len: usize) -> anyhow::Result<BufId> {
        match self.scratch.plan(slot, len) {
            ScratchAction::Use(id) => Ok(id),
            ScratchAction::Grow(old) => {
                if let Some(id) = old {
                    self.slab.free(id);
                }
                let id = self.slab.alloc(len);
                self.scratch.commit(slot, id, len);
                Ok(id)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Net;
    use crate::proto::Phase;
    use crate::zoo;

    #[test]
    fn lenet_recording_collects_expected_keys() {
        let mut dev = RecordingDevice::new(false);
        let param = zoo::by_name("lenet", 2).unwrap();
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        net.forward_backward(&mut dev).unwrap();
        let keys: Vec<&String> = dev.specs.keys().collect();
        // conv1 fwd gemm: M=20, K=25, N=576
        assert!(dev.specs.contains_key("gemm_nn_20x25x576"), "{keys:?}");
        // im2col for conv1 geometry
        assert!(dev.specs.contains_key("im2col_1x28x28_k5x5_s1x1_p0x0"));
        // pool + relu + softmax heads
        assert!(keys.iter().any(|k| k.starts_with("maxpool_f_2x20x24x24")));
        assert!(keys.iter().any(|k| k.starts_with("relu_f_")));
        assert!(dev.specs.contains_key("softmax_2x10"));
        // backward keys
        assert!(keys.iter().any(|k| k.starts_with("gemm_nt_")));
        assert!(keys.iter().any(|k| k.starts_with("col2im_")));
    }

    #[test]
    fn recording_without_compute_is_fast_and_stable() {
        let mut dev = RecordingDevice::new(false);
        let param = zoo::by_name("lenet", 1).unwrap();
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        net.forward_backward(&mut dev).unwrap();
        let first = dev.specs.len();
        net.forward_backward(&mut dev).unwrap();
        assert_eq!(dev.specs.len(), first, "second pass adds no new keys");
        let manifest = dev.manifest();
        assert!(manifest.get("artifacts").is_some());
    }

    #[test]
    fn two_independent_recordings_serialize_identically() {
        // The determinism the AOT cache and the CI `repro` leg rest on:
        // record the same net twice in fresh devices, and both the
        // manifest document and the plan entries must match byte for
        // byte — no map-iteration-order or float-formatting drift.
        let record = || {
            let mut dev = RecordingDevice::new(false);
            let param = zoo::by_name("lenet", 2).unwrap();
            let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
            net.forward_backward(&mut dev).unwrap();
            dev
        };
        let a = record();
        let b = record();
        assert_eq!(a.manifest().to_pretty(), b.manifest().to_pretty());
        assert_eq!(a.spec_entries(), b.spec_entries());
        // Entries are sorted by kernel key.
        let entries = a.spec_entries();
        let keys: Vec<&str> = entries.iter().map(|(k, _)| k.as_str()).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
    }
}
