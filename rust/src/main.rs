//! fecaffe CLI — the conventional Caffe workflow (`caffe train`,
//! `caffe time`) over the FPGA-simulated backend, paper Table 4's
//! "Ease of Use" row.
//!
//! ```text
//! fecaffe train --solver path/to/solver.prototxt [--device fpga|cpu] [--iters N]
//! fecaffe train --net lenet --iters 200            # zoo net + default solver
//! fecaffe time  --net googlenet --batch 1 --iterations 10
//! fecaffe zoo                                      # list networks
//! fecaffe export --net lenet                       # print prototxt
//! ```

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::Device;
use fecaffe::net::Net;
use fecaffe::proto::{self, Phase};
use fecaffe::runtime::PjrtBackend;
use fecaffe::solver::Solver;
use fecaffe::util::cli::{usage, Args, Spec};
use fecaffe::zoo;

const SPECS: &[Spec] = &[
    Spec::opt("solver", None, "solver prototxt path"),
    Spec::opt("net", None, "zoo network name or net prototxt path"),
    Spec::opt("device", Some("fpga"), "fpga | cpu"),
    Spec::opt("batch", Some("1"), "train batch size (zoo nets)"),
    Spec::opt("iters", None, "override solver max_iter"),
    Spec::opt("iterations", Some("10"), "timing iterations (time command)"),
    Spec::opt("snapshot", None, "restore from snapshot before training"),
    Spec::flag("timing-only", "skip numerics, simulate timing only"),
    Spec::flag("no-artifacts", "force native math (skip PJRT artifacts)"),
];

fn make_device(args: &Args) -> anyhow::Result<Box<dyn Device>> {
    match args.get("device").unwrap_or("fpga") {
        "cpu" => Ok(Box::new(CpuDevice::new())),
        "fpga" => {
            let mut dev = FpgaSimDevice::new();
            if args.has_flag("timing-only") {
                dev.timing_only = true;
            } else if !args.has_flag("no-artifacts") {
                match PjrtBackend::auto() {
                    Some(b) => {
                        eprintln!(
                            "[fecaffe] PJRT artifacts loaded from {:?}",
                            fecaffe::runtime::find_artifacts_dir().unwrap()
                        );
                        dev = dev.with_backend(Box::new(b));
                    }
                    None => eprintln!(
                        "[fecaffe] no artifacts found (run `make artifacts`); using native math"
                    ),
                }
            }
            Ok(Box::new(dev))
        }
        other => anyhow::bail!("unknown device '{other}'"),
    }
}

fn load_net_param(args: &Args) -> anyhow::Result<proto::NetParameter> {
    let name = args
        .get("net")
        .ok_or_else(|| anyhow::anyhow!("--net required"))?;
    let batch = args.get_usize("batch").map_err(anyhow::Error::msg)?;
    if std::path::Path::new(name).is_file() {
        let text = std::fs::read_to_string(name)?;
        proto::parse_net(&text).map_err(anyhow::Error::msg)
    } else {
        zoo::by_name(name, batch)
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut dev = make_device(args)?;
    let (netp, mut solverp) = if let Some(path) = args.get("solver") {
        let text = std::fs::read_to_string(path)?;
        let sp = proto::parse_solver(&text).map_err(anyhow::Error::msg)?;
        let netp = if std::path::Path::new(&sp.net).is_file() {
            proto::parse_net(&std::fs::read_to_string(&sp.net)?).map_err(anyhow::Error::msg)?
        } else {
            let batch = args.get_usize("batch").map_err(anyhow::Error::msg)?;
            zoo::by_name(&sp.net, batch)?
        };
        (netp, sp)
    } else {
        let netp = load_net_param(args)?;
        let name = args.get("net").unwrap();
        let sp = zoo::default_solver(name).unwrap_or_default();
        (netp, sp)
    };
    if let Ok(iters) = args.get_usize("iters") {
        solverp.max_iter = iters;
    }
    println!(
        "Training {} on {} with {} (lr {} / {}), {} iterations",
        netp.name,
        dev.kind(),
        solverp.kind.ident(),
        solverp.base_lr,
        solverp.lr_policy,
        solverp.max_iter
    );
    let net = Net::from_param(&netp, Phase::Train, dev.as_mut())?;
    println!(
        "Net: {} layers, {} parameters",
        net.layer_names().len(),
        net.num_parameters()
    );
    let max_iter = solverp.max_iter;
    let mut solver = Solver::new(solverp, net, dev.as_mut())?;
    if let Some(snap) = args.get("snapshot") {
        fecaffe::solver::snapshot::restore(snap, &mut solver, dev.as_mut())?;
        println!("Restored snapshot {} (iter {})", snap, solver.iter);
    }
    let t0 = std::time::Instant::now();
    solver.solve(dev.as_mut(), max_iter)?;
    let wall = t0.elapsed();
    let tail = solver.loss_history.len().min(10);
    let final_loss: f32 =
        solver.loss_history.iter().rev().take(tail).sum::<f32>() / tail.max(1) as f32;
    println!(
        "Done: {} iterations in {:.1}s wall, final loss ({}-iter mean) {:.4}",
        solver.iter,
        wall.as_secs_f64(),
        tail,
        final_loss
    );
    if let Some(ns) = dev.sim_clock_ns() {
        println!("Simulated device time: {:.3} s", ns as f64 / 1e9);
    }
    Ok(())
}

fn cmd_time(args: &Args) -> anyhow::Result<()> {
    let mut dev = make_device(args)?;
    let netp = load_net_param(args)?;
    let iters = args.get_usize("iterations").map_err(anyhow::Error::msg)?;
    let mut net = Net::from_param(&netp, Phase::Train, dev.as_mut())?;
    println!("*** Benchmark begins ***  ({} iterations, {})", iters, dev.kind());
    let names = net.layer_names();
    let mut fwd = vec![0u64; names.len()];
    let mut bwd = vec![0u64; names.len()];
    for _ in 0..iters {
        let (_, f) = net.forward_timed(dev.as_mut())?;
        let b = net.backward_timed(dev.as_mut())?;
        for i in 0..names.len() {
            fwd[i] += f[i];
            bwd[i] += b[i];
        }
    }
    let mut table = fecaffe::util::table::Table::new(
        &format!("{} per-layer time (ms, avg of {iters})", netp.name),
        &["Layer", "Forward", "Backward"],
    );
    for i in 0..names.len() {
        table.row(&[
            names[i].clone(),
            format!("{:.3}", fwd[i] as f64 / iters as f64 / 1e6),
            format!("{:.3}", bwd[i] as f64 / iters as f64 / 1e6),
        ]);
    }
    let tf: u64 = fwd.iter().sum();
    let tb: u64 = bwd.iter().sum();
    table.row(&[
        "TOTAL".into(),
        format!("{:.3}", tf as f64 / iters as f64 / 1e6),
        format!("{:.3}", tb as f64 / iters as f64 / 1e6),
    ]);
    println!("{}", table.render());
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, SPECS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage("fecaffe", "FeCaffe coordinator", SPECS));
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "time" => cmd_time(&args),
        "zoo" => {
            for n in zoo::NETWORKS {
                println!("{n}");
            }
            Ok(())
        }
        "export" => load_net_param(&args).map(|p| {
            print!("{}", proto::emit::emit_net(&p));
        }),
        _ => {
            println!(
                "{}",
                usage(
                    "fecaffe <train|time|zoo|export>",
                    "FeCaffe: FPGA-enabled Caffe (simulated Stratix 10 + PJRT AOT kernels)",
                    SPECS
                )
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
