//! Convolution layer: im2col + grouped GEMM + Bias, exactly Caffe's
//! lowering (and therefore the paper's kernel-instance accounting: one
//! `Im2col` per image, one `Gemm` per (image, group), one `Bias` per
//! image in forward; `Gemv` bias-grad, `Gemm` weight/data-grad and
//! `Col2im` per image in backward). 1×1/stride-1/pad-0 convolutions skip
//! im2col and address the input directly (Caffe's `is_1x1_` fast path).
//!
//! The per-(image, group) loop stays serial at the launch level — kernel
//! ordering is the paper's accounting unit and the device interface is
//! synchronous — but every launched kernel (im2col, the packed GEMMs,
//! col2im, the bias gemv) shards internally across the intra-op pool
//! (`util::pool`), so the training hot path uses the whole machine while
//! per-image results stay bit-identical to the serial schedule. All
//! loop-invariant buffer lookups are hoisted out of the image loop so
//! the launch path does no redundant blob resolution.

use super::{fill_blob, Layer, SharedBlob};
use crate::blob::Blob;
use crate::device::{BufId, Device, Kernel, KernelCall};
use crate::math::ConvGeom;
use crate::proto::{ConvolutionParameter, LayerParameter, ParamSpec};
use crate::util::prng::Pcg32;

pub struct ConvolutionLayer {
    name: String,
    p: ConvolutionParameter,
    specs: Vec<ParamSpec>,
    weight: SharedBlob,
    bias: Option<SharedBlob>,
    /// ones(out_h*out_w) for the bias-gradient gemv (grow-only).
    ones: Option<BufId>,
    ones_len: usize,
    geom: Option<ConvGeom>,
    num: usize,
    is_1x1: bool,
}

impl ConvolutionLayer {
    pub fn new(param: &LayerParameter) -> anyhow::Result<ConvolutionLayer> {
        let p = param
            .conv
            .clone()
            .ok_or_else(|| anyhow::anyhow!("layer {}: missing convolution_param", param.name))?;
        Ok(ConvolutionLayer {
            name: param.name.clone(),
            specs: param.params.clone(),
            p,
            weight: super::shared(Blob::new("w", &[0])),
            bias: None,
            ones: None,
            ones_len: 0,
            geom: None,
            num: 0,
            is_1x1: false,
        })
    }

    fn seed(&self) -> u64 {
        // Deterministic per-layer-name seed so CPU and FPGA-sim nets share
        // identical initialization.
        self.name
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
                (h ^ u64::from(b)).wrapping_mul(0x1000_0000_01b3)
            })
    }
}

impl Layer for ConvolutionLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "Convolution"
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(bottoms.len() == 1 && tops.len() == 1, "conv: 1 bottom, 1 top");
        let channels = bottoms[0].borrow().channels();
        anyhow::ensure!(
            channels % self.p.group == 0 && self.p.num_output % self.p.group == 0,
            "conv {}: channels/num_output not divisible by group",
            self.name
        );

        // Learnable blobs.
        let k_per_group = channels / self.p.group * self.p.kernel_h * self.p.kernel_w;
        let mut rng = Pcg32::new(self.seed());
        {
            let mut w = self.weight.borrow_mut();
            w.reshape(
                dev,
                &[
                    self.p.num_output,
                    channels / self.p.group,
                    self.p.kernel_h,
                    self.p.kernel_w,
                ],
            );
            fill_blob(&mut w, dev, &self.p.weight_filler, k_per_group, &mut rng);
        }
        if self.p.bias_term {
            let bias = super::shared(Blob::new("b", &[self.p.num_output]));
            fill_blob(
                &mut bias.borrow_mut(),
                dev,
                &self.p.bias_filler,
                k_per_group,
                &mut rng,
            );
            self.bias = Some(bias);
        }

        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let b = bottoms[0].borrow();
        let (num, channels, height, width) =
            (b.num(), b.channels(), b.height(), b.width());
        drop(b);
        // Batch and spatial dims may change between reshapes; the channel
        // count is pinned by the filters allocated at setup.
        let w_channels = self.weight.borrow().channels();
        anyhow::ensure!(
            channels == w_channels * self.p.group,
            "conv {}: bottom has {channels} channels, filters expect {}",
            self.name,
            w_channels * self.p.group
        );
        let geom = ConvGeom {
            channels,
            height,
            width,
            kernel_h: self.p.kernel_h,
            kernel_w: self.p.kernel_w,
            pad_h: self.p.pad_h,
            pad_w: self.p.pad_w,
            stride_h: self.p.stride_h,
            stride_w: self.p.stride_w,
        };
        let (oh, ow) = (geom.out_h(), geom.out_w());
        self.is_1x1 = self.p.kernel_h == 1
            && self.p.kernel_w == 1
            && self.p.stride_h == 1
            && self.p.stride_w == 1
            && self.p.pad_h == 0
            && self.p.pad_w == 0;
        self.num = num;
        self.geom = Some(geom);

        // Scratch: the col/col_diff matrices live in device scratch slots
        // 0/1 shared across all conv layers (one global DDR region, like
        // the OpenCL implementation). Reserve at the bucketed size so
        // repeated reshapes re-use one grown region instead of churning
        // per geometry change (the pool itself only grows).
        if !self.is_1x1 {
            let want = crate::runtime::plan::bucket(geom.col_len());
            dev.scratch(0, want)?;
            dev.scratch(1, want)?;
        }
        // ones vector for the bias gradient (grow-only: a larger buffer
        // of ones serves any smaller gemv).
        let ohw = oh * ow;
        if self.ones.is_none() || self.ones_len < ohw {
            if let Some(id) = self.ones.take() {
                dev.free(id);
            }
            let ones = dev.alloc(ohw)?;
            dev.launch(&KernelCall::new(
                Kernel::SetConst { n: ohw, value: 1.0 },
                &[],
                &[ones],
            ))?;
            self.ones = Some(ones);
            self.ones_len = ohw;
        }

        tops[0]
            .borrow_mut()
            .reshape_grow_only(dev, &[num, self.p.num_output, oh, ow]);
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let geom = self.geom.unwrap();
        let g = self.p.group;
        let (oh, ow) = (geom.out_h(), geom.out_w());
        let ohw = oh * ow;
        let m_g = self.p.num_output / g; // output channels per group
        let k_g = geom.col_rows() / g; // col rows per group
        let in_len = geom.im_len();
        let top_len = self.p.num_output * ohw;

        let mut bottom = bottoms[0].borrow_mut();
        let mut top = tops[0].borrow_mut();
        let b_id = bottom.data.dev_data(dev);
        let t_id = top.data.dev_data_mut(dev);
        let w_id = self.weight.borrow_mut().data.dev_data(dev);
        // Hoisted: resolving the bias blob per image would re-walk the
        // SyncedMem state machine num times for the same BufId.
        let bias_id = match &self.bias {
            Some(bias) => Some(bias.borrow_mut().data.dev_data(dev)),
            None => None,
        };
        let scratch_col = if self.is_1x1 { None } else { Some(dev.scratch(0, geom.col_len())?) };

        for i in 0..self.num {
            // im2col (skipped for 1x1: the input *is* the col matrix).
            let (col_id, col_base) = match scratch_col {
                None => (b_id, i * in_len),
                Some(cid) => {
                    dev.launch(
                        &KernelCall::new(Kernel::Im2col { geom }, &[b_id], &[cid])
                            .at(&[i * in_len], &[0]),
                    )?;
                    (cid, 0)
                }
            };
            for gi in 0..g {
                dev.launch(
                    &KernelCall::new(
                        Kernel::GemmNN { m: m_g, n: ohw, k: k_g, alpha: 1.0, beta: 0.0 },
                        &[w_id, col_id],
                        &[t_id],
                    )
                    .at(
                        &[gi * m_g * k_g, col_base + gi * k_g * ohw],
                        &[i * top_len + gi * m_g * ohw],
                    ),
                )?;
            }
            if let Some(bias_id) = bias_id {
                dev.launch(
                    &KernelCall::new(
                        Kernel::BiasF { outer: 1, channels: self.p.num_output, dim: ohw },
                        &[bias_id],
                        &[t_id],
                    )
                    .at(&[0], &[i * top_len]),
                )?;
            }
        }
        Ok(0.0)
    }

    fn backward(
        &mut self,
        dev: &mut dyn Device,
        tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let geom = self.geom.unwrap();
        let g = self.p.group;
        let ohw = geom.out_h() * geom.out_w();
        let m_g = self.p.num_output / g;
        let k_g = geom.col_rows() / g;
        let in_len = geom.im_len();
        let top_len = self.p.num_output * ohw;

        let mut bottom = bottoms[0].borrow_mut();
        let mut top = tops[0].borrow_mut();
        let td_id = top.data.dev_data(dev); // not needed, but keeps data resident
        let _ = td_id;
        let tdiff_id = top.diff.dev_data(dev);
        let b_id = bottom.data.dev_data(dev);
        let w_id = self.weight.borrow_mut().data.dev_data(dev);
        let wd_id = self.weight.borrow_mut().diff.dev_data_rw(dev);

        // Bias gradient: gemv(top_diff_i · ones), accumulated over images.
        if let Some(bias) = &self.bias {
            let bd_id = bias.borrow_mut().diff.dev_data_rw(dev);
            let ones = self.ones.unwrap();
            for i in 0..self.num {
                dev.launch(
                    &KernelCall::new(
                        Kernel::Gemv {
                            trans: false,
                            m: self.p.num_output,
                            n: ohw,
                            alpha: 1.0,
                            beta: 1.0,
                        },
                        &[tdiff_id, ones],
                        &[bd_id],
                    )
                    .at(&[i * top_len, 0], &[0]),
                )?;
            }
        }

        let prop = prop_down.first().copied().unwrap_or(true);
        if prop {
            // bottom_diff zeroed once; col2im accumulates into it.
            let bdiff_id = bottom.diff.dev_data_mut(dev);
            dev.launch(&KernelCall::new(
                Kernel::SetConst { n: self.num * in_len, value: 0.0 },
                &[],
                &[bdiff_id],
            ))?;
        }

        let scratch_col = if self.is_1x1 { None } else { Some(dev.scratch(0, geom.col_len())?) };
        let scratch_cd = if self.is_1x1 || !prop {
            None
        } else {
            Some(dev.scratch(1, geom.col_len())?)
        };
        for i in 0..self.num {
            // Recompute col (Caffe does the same in backward).
            let (col_id, col_base) = match scratch_col {
                None => (b_id, i * in_len),
                Some(cid) => {
                    dev.launch(
                        &KernelCall::new(Kernel::Im2col { geom }, &[b_id], &[cid])
                            .at(&[i * in_len], &[0]),
                    )?;
                    (cid, 0)
                }
            };
            // Weight gradient: wd_g += top_diff_g · col_g^T.
            for gi in 0..g {
                dev.launch(
                    &KernelCall::new(
                        Kernel::GemmNT { m: m_g, n: k_g, k: ohw, alpha: 1.0, beta: 1.0 },
                        &[tdiff_id, col_id],
                        &[wd_id],
                    )
                    .at(
                        &[i * top_len + gi * m_g * ohw, col_base + gi * k_g * ohw],
                        &[gi * m_g * k_g],
                    ),
                )?;
            }
            if prop {
                let bdiff_id = bottom.diff.dev_data_mut(dev);
                if self.is_1x1 {
                    // col_diff IS bottom_diff slice; beta=1 accumulates over
                    // (nothing else writes it, but keep the zero+acc scheme).
                    for gi in 0..g {
                        dev.launch(
                            &KernelCall::new(
                                Kernel::GemmTN {
                                    m: k_g,
                                    n: ohw,
                                    k: m_g,
                                    alpha: 1.0,
                                    beta: 1.0,
                                },
                                &[w_id, tdiff_id],
                                &[bdiff_id],
                            )
                            .at(
                                &[gi * m_g * k_g, i * top_len + gi * m_g * ohw],
                                &[i * in_len + gi * k_g * ohw],
                            ),
                        )?;
                    }
                } else {
                    let cd_id = scratch_cd.expect("col-diff scratch reserved above");
                    for gi in 0..g {
                        dev.launch(
                            &KernelCall::new(
                                Kernel::GemmTN {
                                    m: k_g,
                                    n: ohw,
                                    k: m_g,
                                    alpha: 1.0,
                                    beta: 0.0,
                                },
                                &[w_id, tdiff_id],
                                &[cd_id],
                            )
                            .at(
                                &[gi * m_g * k_g, i * top_len + gi * m_g * ohw],
                                &[gi * k_g * ohw],
                            ),
                        )?;
                    }
                    dev.launch(
                        &KernelCall::new(Kernel::Col2im { geom }, &[cd_id], &[bdiff_id])
                            .at(&[0], &[i * in_len]),
                    )?;
                }
            }
        }
        Ok(())
    }

    fn param_blobs(&self) -> Vec<SharedBlob> {
        let mut v = vec![self.weight.clone()];
        if let Some(b) = &self.bias {
            v.push(b.clone());
        }
        v
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        self.specs.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::proto::parse_text;

    fn conv_param(text: &str) -> LayerParameter {
        let m = parse_text(text).unwrap();
        let lp = LayerParameter::from_message(m.msgs("layer").next().unwrap()).unwrap();
        lp
    }

    fn simple_conv(num_output: usize, k: usize) -> ConvolutionLayer {
        let text = format!(
            r#"layer {{ name: "c" type: "Convolution" bottom: "x" top: "y"
                 convolution_param {{ num_output: {num_output} kernel_size: {k}
                   weight_filler {{ type: "constant" value: 1 }} }} }}"#
        );
        ConvolutionLayer::new(&conv_param(&text)).unwrap()
    }

    #[test]
    fn forward_sum_filter() {
        // all-ones 2x2 filter over a known image = windowed sums (+0 bias)
        let mut dev = CpuDevice::new();
        let mut layer = simple_conv(1, 2);
        let bottom = super::super::shared(Blob::new("x", &[1, 1, 3, 3]));
        let top = super::super::shared(Blob::new("y", &[1]));
        bottom
            .borrow_mut()
            .set_data(&mut dev, &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        assert_eq!(top.borrow().shape(), &[1, 1, 2, 2]);
        layer.forward(&mut dev, &[bottom], &[top.clone()]).unwrap();
        let out = top.borrow_mut().data_vec(&mut dev);
        assert_eq!(out, vec![8.0, 12.0, 20.0, 24.0]);
    }

    #[test]
    fn output_geometry_alexnet_conv1() {
        // AlexNet conv1: 227x227, k11 s4 → 55x55
        let text = r#"layer { name: "c" type: "Convolution" bottom: "x" top: "y"
            convolution_param { num_output: 96 kernel_size: 11 stride: 4 } }"#;
        let mut layer = ConvolutionLayer::new(&conv_param(text)).unwrap();
        let mut dev = CpuDevice::new();
        let bottom = super::super::shared(Blob::new("x", &[1, 3, 227, 227]));
        let top = super::super::shared(Blob::new("y", &[1]));
        layer.setup(&mut dev, &[bottom], &[top.clone()]).unwrap();
        assert_eq!(top.borrow().shape(), &[1, 96, 55, 55]);
    }

    #[test]
    fn group_conv_blocks_cross_group_flow() {
        // 2 groups, 2-in 2-out channels, 1x1 kernel: out_c0 only sees in_c0.
        let text = r#"layer { name: "c" type: "Convolution" bottom: "x" top: "y"
            convolution_param { num_output: 2 kernel_size: 1 group: 2 bias_term: false
              weight_filler { type: "constant" value: 1 } } }"#;
        let mut layer = ConvolutionLayer::new(&conv_param(text)).unwrap();
        let mut dev = CpuDevice::new();
        let bottom = super::super::shared(Blob::new("x", &[1, 2, 2, 2]));
        let top = super::super::shared(Blob::new("y", &[1]));
        bottom
            .borrow_mut()
            .set_data(&mut dev, &[1.0, 1.0, 1.0, 1.0, 5.0, 5.0, 5.0, 5.0]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        layer.forward(&mut dev, &[bottom], &[top.clone()]).unwrap();
        let out = top.borrow_mut().data_vec(&mut dev);
        assert_eq!(out, vec![1.0, 1.0, 1.0, 1.0, 5.0, 5.0, 5.0, 5.0]);
    }

    #[test]
    fn one_by_one_skips_im2col() {
        let text = r#"layer { name: "c" type: "Convolution" bottom: "x" top: "y"
            convolution_param { num_output: 4 kernel_size: 1 } }"#;
        let mut layer = ConvolutionLayer::new(&conv_param(text)).unwrap();
        let mut dev = CpuDevice::new();
        let bottom = super::super::shared(Blob::new("x", &[2, 3, 5, 5]));
        let top = super::super::shared(Blob::new("y", &[1]));
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        assert!(layer.is_1x1);
        let before = dev.launches();
        layer.forward(&mut dev, &[bottom], &[top]).unwrap();
        // 2 images × (1 gemm + 1 bias) = 4 launches, no im2col
        assert_eq!(dev.launches() - before, 4);
    }

    #[test]
    fn param_blobs_and_specs() {
        let mut dev = CpuDevice::new();
        let mut layer = simple_conv(3, 2);
        let bottom = super::super::shared(Blob::new("x", &[1, 1, 4, 4]));
        let top = super::super::shared(Blob::new("y", &[1]));
        layer.setup(&mut dev, &[bottom], &[top]).unwrap();
        assert_eq!(layer.param_blobs().len(), 2); // weight + bias
    }
}
