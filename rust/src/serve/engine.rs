//! The serving engine: public submit/response API, admission control,
//! and lifecycle (spawn → serve → graceful shutdown).
//!
//! `Engine::new` builds one *master* net replica to initialize weights,
//! publishes them as a [`WeightSnapshot`] (host vectors behind `Arc`s),
//! and spawns the batcher plus a pool of workers that each own a single
//! shape-polymorphic net replica adopting the snapshot — weights
//! shared, activations per-worker, the replica reshaped per batch to
//! its bucketed row count (output rows are accounted per batch, with
//! `output_len` fixed by the model: the deploy output count divided by
//! the build batch). `submit` is non-blocking: when the bounded admission
//! queue is full the caller gets [`ServeError::Overloaded`] and must
//! back off (HTTP-429 semantics), which keeps tail latency bounded
//! instead of letting the queue grow without limit.
//!
//! Weights are *hot-swappable*: [`Engine::publish_weights`] validates a
//! new versioned snapshot against the model's parameter schema and
//! swaps it into a shared cell; each worker adopts it at its next batch
//! boundary, so in-flight batches finish on the old version and no
//! request is ever dropped or served from mixed weights.

use super::batcher::{self, Batch, BatcherConfig};
use super::lock_unpoisoned;
use super::metrics::Metrics;
use super::queue::{PushError, SharedQueue};
use super::worker;
use crate::net::{Net, WeightSnapshot};
use crate::obs::EngineObs;
use crate::proto::{NetParameter, Phase};
use crate::quant::{self, backend::QuantBackend, Precision, QuantSpec};
use crate::util::chaos::{ChaosState, FaultPlan};
use crate::zoo::{deploy, DeployNet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Device each worker replica binds (one device instance per worker).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    /// Native CPU math.
    Cpu,
    /// Simulated Stratix 10 board (native math numerics + cost-model
    /// timing; each worker owns a private board).
    FpgaSim,
}

impl DeviceKind {
    pub(crate) fn create(&self) -> Box<dyn crate::device::Device> {
        self.create_with(Precision::Fp32, None)
    }

    /// Create a device serving at `precision`: reduced modes attach the
    /// emulated quant backend for numerics, and the FPGA sim's cost
    /// model is re-rated for the narrow bitstream.
    pub(crate) fn create_with(
        &self,
        precision: Precision,
        spec: Option<Arc<QuantSpec>>,
    ) -> Box<dyn crate::device::Device> {
        match self {
            DeviceKind::Cpu => {
                let dev = crate::device::cpu::CpuDevice::new();
                if precision == Precision::Fp32 {
                    Box::new(dev)
                } else {
                    Box::new(dev.with_backend(Box::new(QuantBackend::new(precision, spec))))
                }
            }
            DeviceKind::FpgaSim => {
                let dev = crate::device::fpga::FpgaSimDevice::new().with_precision(precision);
                if precision == Precision::Fp32 {
                    Box::new(dev)
                } else {
                    Box::new(dev.with_backend(Box::new(QuantBackend::new(precision, spec))))
                }
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker replicas (one thread + one net + one device each).
    pub workers: usize,
    /// Micro-batch upper bound — the capacity each worker's single
    /// replica is built at. Workers reshape the replica down to each
    /// popped batch's bucketed size before `forward`, so a partial
    /// batch executes its bucket's rows, never a pad to this cap.
    pub max_batch: usize,
    /// Micro-batch linger deadline.
    pub max_linger: Duration,
    /// Admission queue capacity — the backpressure bound.
    pub queue_capacity: usize,
    pub device: DeviceKind,
    /// Intra-op threads each worker's kernels may fan out to — a *cap*,
    /// not a reservation. 0 = split the process thread budget evenly:
    /// `default_threads() / workers`, at least 1, so inter-op workers ×
    /// intra-op threads never oversubscribe the machine. The shared pool
    /// runs one fan-out at a time; workers that lose the race execute
    /// that kernel serially (see `util::pool` — intra-op parallelism
    /// pays off most at low worker counts).
    pub intra_op_threads: usize,
    /// Batch-trace sampling: record a full span timeline for one batch
    /// in every `trace_sample` executed (0 = off). When off the hot
    /// path takes no clock reads and no locks for tracing; when on,
    /// only the sampled batch pays the span-recording cost.
    pub trace_sample: u64,
    /// How many dead workers the supervisor may respawn over the
    /// engine's lifetime (0 disables supervision — a dead worker stays
    /// dead, as in the pre-supervision engine).
    pub restart_budget: usize,
    /// Base delay before a respawn; doubles per consecutive restart of
    /// the same worker slot (capped), so a crash-looping replica can't
    /// burn the whole budget in milliseconds.
    pub restart_backoff: Duration,
    /// Consecutive failed batches that trip the per-model circuit
    /// breaker (0 disables the breaker).
    pub breaker_threshold: usize,
    /// How long an open circuit rejects before admitting a half-open
    /// probe; doubles per consecutive reopening.
    pub breaker_cooldown: Duration,
    /// Fault-injection plan for this engine. `None` falls back to the
    /// `FECAFFE_CHAOS` environment variable; a no-op plan (or neither
    /// source set) leaves the serve path entirely fault-free.
    pub chaos: Option<FaultPlan>,
    /// AOT plan-cache directory (`fecaffe aot build` output). `None`
    /// falls back to the `FECAFFE_AOT_CACHE` environment variable; with
    /// neither set the engine always plans live. When a cache is
    /// configured and every serving bucket's artifact validates, boot
    /// skips the live admission re-planning entirely; any miss demotes
    /// to the live path with a typed error and a `cache_miss` metric.
    pub aot_cache: Option<std::path::PathBuf>,
    /// Serving numeric precision. `Int8` fake-quantizes every published
    /// snapshot onto its per-blob int8 grid, runs a boot-time
    /// calibration pass for static activation ranges, and executes
    /// matmuls through the emulated int8 path; `Fp16` rounds weights
    /// and matmul operands through the binary16 grid. Both re-rate the
    /// FPGA sim's cost model.
    pub precision: Precision,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            max_batch: 8,
            max_linger: Duration::from_millis(2),
            queue_capacity: 256,
            device: DeviceKind::Cpu,
            intra_op_threads: 0,
            trace_sample: 0,
            restart_budget: 8,
            restart_backoff: Duration::from_millis(20),
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_millis(250),
            chaos: None,
            aot_cache: None,
            precision: Precision::Fp32,
        }
    }
}

/// Sampled batch traces kept for `/admin/trace` — bounded so a
/// long-running engine holds only the most recent timelines.
const TRACE_RING_CAP: usize = 32;

impl EngineConfig {
    /// Effective per-worker intra-op thread budget.
    pub fn intra_op_budget(&self) -> usize {
        if self.intra_op_threads > 0 {
            self.intra_op_threads
        } else {
            (crate::util::pool::default_threads() / self.workers.max(1)).max(1)
        }
    }
}

/// Why a submission (or a wait) failed.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Admission queue full — back off and retry. Hands the rejected
    /// sample back so retries don't have to clone it per attempt.
    Overloaded(Vec<f32>),
    /// Engine is shutting down (or already shut down).
    ShuttingDown,
    /// Internal resolution for a request refused at admission whose
    /// handle was never exposed (`submit` returned `Overloaded` and
    /// handed the sample back). Kept distinct from `ShuttingDown` so
    /// debug traces and metrics can't misreport overload as shutdown;
    /// callers never observe it from `submit` or `wait`.
    Rejected,
    /// Sample didn't match the model's input schema.
    BadRequest(String),
    /// Worker-side failure while executing the request.
    Worker(String),
    /// The request's deadline passed before a worker executed it; it
    /// was shed (batcher or worker) without spending a batch slot.
    /// HTTP 504 semantics — accounted in `shed_expired`, not `failed`.
    DeadlineExceeded,
    /// The model's circuit breaker is open after consecutive batch
    /// failures: fast-rejected at submit without queueing. HTTP 503
    /// semantics with a `Retry-After` derived from the remaining
    /// cooldown.
    BreakerOpen {
        /// Milliseconds until the breaker admits a half-open probe.
        retry_after_ms: u64,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded(_) => write!(f, "engine overloaded (admission queue full)"),
            ServeError::ShuttingDown => write!(f, "engine is shutting down"),
            ServeError::Rejected => {
                write!(f, "request rejected at admission (queue full)")
            }
            ServeError::BadRequest(m) => write!(f, "bad request: {m}"),
            ServeError::Worker(m) => write!(f, "worker error: {m}"),
            ServeError::DeadlineExceeded => {
                write!(f, "deadline exceeded before execution (request shed)")
            }
            ServeError::BreakerOpen { retry_after_ms } => write!(
                f,
                "circuit breaker open (model failing consecutively; retry in {retry_after_ms} ms)"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Why a [`Engine::publish_weights`] call was refused. Kept separate
/// from [`ServeError`]: publishing is a control-plane operation with its
/// own HTTP status mapping (400 for schema mismatch, 409 for a stale
/// version), never a data-plane serving failure.
#[derive(Debug, Clone, PartialEq)]
pub enum PublishError {
    /// Snapshot doesn't cover the model's parameter schema (missing
    /// owner key or element-count mismatch).
    Mismatch(String),
    /// Offered version is not greater than the currently published one
    /// — versions are strictly monotonic per engine.
    Stale { current: u64, offered: u64 },
}

impl std::fmt::Display for PublishError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PublishError::Mismatch(m) => write!(f, "snapshot does not match model: {m}"),
            PublishError::Stale { current, offered } => write!(
                f,
                "stale weights version {offered} (currently serving {current}; \
                 versions are strictly monotonic)"
            ),
        }
    }
}

impl std::error::Error for PublishError {}

const BREAKER_CLOSED: u8 = 0;
const BREAKER_OPEN: u8 = 1;
const BREAKER_HALF_OPEN: u8 = 2;

/// Per-model circuit breaker: `threshold` consecutive failed batches
/// open the circuit, submissions are fast-rejected for `cooldown`, then
/// one batch is admitted as a half-open probe — success re-closes, a
/// failed probe reopens with a doubled cooldown. The closed-state hot
/// path is a single relaxed atomic load; the mutex is touched only at
/// batch boundaries and while the circuit is not closed.
pub(crate) struct Breaker {
    threshold: u32,
    cooldown: Duration,
    metrics: Arc<Metrics>,
    /// Mirror of the state machine's tag for the lock-free fast path —
    /// transitions happen only under `state`'s lock.
    tag: AtomicU8,
    state: Mutex<BreakerInner>,
}

struct BreakerInner {
    /// Failed batches since the last success (closed state only).
    consecutive: u32,
    /// When the open circuit starts admitting a half-open probe.
    open_until: Option<Instant>,
    /// Consecutive reopenings (failed probes) — scales the cooldown.
    reopenings: u32,
}

impl Breaker {
    pub(crate) fn new(threshold: u32, cooldown: Duration, metrics: Arc<Metrics>) -> Breaker {
        Breaker {
            threshold,
            cooldown,
            metrics,
            tag: AtomicU8::new(BREAKER_CLOSED),
            state: Mutex::new(BreakerInner { consecutive: 0, open_until: None, reopenings: 0 }),
        }
    }

    /// Admission check. `None` admits the request (closed, half-open,
    /// or an open circuit whose cooldown just elapsed — that request
    /// becomes the probe); `Some(ms)` fast-rejects with the remaining
    /// cooldown for a `Retry-After` header.
    pub(crate) fn check_reject(&self) -> Option<u64> {
        if self.threshold == 0 || self.tag.load(Ordering::Relaxed) != BREAKER_OPEN {
            return None;
        }
        let mut inner = lock_unpoisoned(&self.state);
        // Re-check under the lock: a racing transition may have already
        // moved the circuit on.
        if self.tag.load(Ordering::Relaxed) != BREAKER_OPEN {
            return None;
        }
        let now = Instant::now();
        let until = inner.open_until.unwrap_or(now);
        if now >= until {
            // Cooldown over: this submission rides through as the probe.
            inner.open_until = None;
            self.tag.store(BREAKER_HALF_OPEN, Ordering::Relaxed);
            self.metrics.set_breaker_state(2);
            None
        } else {
            Some((until.duration_since(now).as_millis() as u64).max(1))
        }
    }

    /// Feed one batch outcome into the state machine (workers call this
    /// once per executed batch).
    pub(crate) fn on_batch(&self, ok: bool) {
        if self.threshold == 0 {
            return;
        }
        let mut inner = lock_unpoisoned(&self.state);
        if ok {
            inner.consecutive = 0;
            if self.tag.swap(BREAKER_CLOSED, Ordering::Relaxed) != BREAKER_CLOSED {
                inner.reopenings = 0;
                inner.open_until = None;
                self.metrics.set_breaker_state(0);
            }
            return;
        }
        match self.tag.load(Ordering::Relaxed) {
            // A straggler batch finishing after the trip changes nothing.
            BREAKER_OPEN => {}
            // Failed probe: reopen, doubling the cooldown.
            BREAKER_HALF_OPEN => {
                inner.reopenings = inner.reopenings.saturating_add(1);
                self.open_locked(&mut inner);
            }
            _ => {
                inner.consecutive = inner.consecutive.saturating_add(1);
                if inner.consecutive >= self.threshold {
                    self.open_locked(&mut inner);
                }
            }
        }
    }

    fn open_locked(&self, inner: &mut BreakerInner) {
        let cooldown = self.cooldown.saturating_mul(1u32 << inner.reopenings.min(10));
        inner.open_until = Some(Instant::now() + cooldown);
        inner.consecutive = 0;
        self.tag.store(BREAKER_OPEN, Ordering::Relaxed);
        self.metrics.record_breaker_trip();
        self.metrics.set_breaker_state(1);
    }

    /// Human-readable state for `/healthz` and load reports.
    pub(crate) fn state_name(&self) -> &'static str {
        super::metrics::breaker_state_name(u64::from(self.tag.load(Ordering::Relaxed)))
    }
}

/// The engine's published-weights cell: workers poll `version` (one
/// relaxed-cost atomic load per batch) and only take the `slot` lock
/// when it moved — the hot path never contends with a publish.
pub(crate) struct SharedWeights {
    pub(crate) version: AtomicU64,
    pub(crate) slot: Mutex<Arc<WeightSnapshot>>,
}

/// A successfully computed output row plus the weights version that
/// produced it.
#[derive(Debug)]
struct Fulfilled {
    values: Vec<f32>,
    weights_version: u64,
}

/// One-shot response slot shared between a request and its handle.
struct Slot {
    result: Mutex<Option<Result<Fulfilled, ServeError>>>,
    ready: Condvar,
}

/// Handle to one in-flight request.
pub struct ResponseHandle {
    slot: Arc<Slot>,
    submitted: Instant,
}

impl ResponseHandle {
    /// Block until the response (or failure) arrives.
    pub fn wait(self) -> Result<Response, ServeError> {
        // Poison-tolerant: the slot only ever holds a valid
        // `Option<Result<..>>`, so a panicking writer can't leave it
        // half-updated — recover the guard instead of cascading.
        let mut guard = lock_unpoisoned(&self.slot.result);
        while guard.is_none() {
            guard = self.slot.ready.wait(guard).unwrap_or_else(|p| p.into_inner());
        }
        let done = guard.take().expect("checked is_some")?;
        Ok(Response {
            values: done.values,
            weights_version: done.weights_version,
            latency: self.submitted.elapsed(),
        })
    }
}

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    /// The model's output row for this sample (post-softmax scores).
    pub values: Vec<f32>,
    /// Version of the weight snapshot this row was computed from —
    /// exactly one version per response, never mixed (workers adopt a
    /// published snapshot only at batch boundaries).
    pub weights_version: u64,
    /// Submit-to-response wall time as seen by this handle.
    pub latency: Duration,
}

impl Response {
    /// Index of the highest-scoring class.
    pub fn argmax(&self) -> usize {
        let mut best = 0;
        for (i, v) in self.values.iter().enumerate() {
            if *v > self.values[best] {
                best = i;
            }
        }
        best
    }
}

/// Internal request record flowing submit → batcher → worker.
pub(crate) struct Request {
    pub sample: Vec<f32>,
    pub submitted: Instant,
    /// Absolute expiry; a request past it is shed (batcher or worker)
    /// instead of spending a batch slot. `None` = no deadline.
    pub deadline: Option<Instant>,
    slot: Arc<Slot>,
    metrics: Arc<Metrics>,
}

impl Request {
    /// Resolve the slot; returns true if this call set the result.
    fn complete(&self, r: Result<Fulfilled, ServeError>) -> bool {
        let mut g = lock_unpoisoned(&self.slot.result);
        if g.is_some() {
            return false;
        }
        *g = Some(r);
        drop(g);
        self.slot.ready.notify_all();
        true
    }

    pub(crate) fn fulfill(self, values: Vec<f32>, weights_version: u64) {
        self.complete(Ok(Fulfilled { values, weights_version }));
    }

    /// Fail the request; accounted in `Metrics::failed` exactly once.
    pub(crate) fn fail(self, why: &str) {
        if self.complete(Err(ServeError::Worker(why.to_string()))) {
            self.metrics.record_failed();
        }
    }

    /// True once the request's deadline has passed.
    pub(crate) fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Shed an expired request: resolve as `DeadlineExceeded` and
    /// account in `shed_expired` (not `failed` — nothing broke, the
    /// caller's latency budget simply ran out).
    pub(crate) fn shed(self) {
        if self.complete(Err(ServeError::DeadlineExceeded)) {
            self.metrics.record_shed_expired();
        }
    }
}

impl Drop for Request {
    /// A request dropped anywhere on the pipeline (queue teardown,
    /// worker panic unwinding a batch) still resolves its handle — so
    /// callers never hang on a lost request — and still counts as a
    /// failure in the metrics.
    fn drop(&mut self) {
        if self.complete(Err(ServeError::Worker(
            "request dropped before completion".to_string(),
        ))) {
            self.metrics.record_failed();
        }
    }
}

struct Threads {
    batcher: JoinHandle<()>,
    supervisor: Option<JoinHandle<()>>,
}

/// Post-training calibration forwards run at engine boot for int8
/// models: enough synthetic batches to observe every matmul shape, with
/// a fixed seed so every boot of the same net derives the same
/// [`QuantSpec`] (and thus bit-identical serving behaviour).
const CALIBRATION_BATCHES: usize = 2;
const CALIBRATION_SEED: u64 = 0x5eed_cafe;

/// Everything needed to (re)spawn a worker thread — kept by the
/// supervisor so a respawned worker is indistinguishable from one
/// spawned at startup.
struct WorkerSpawner {
    deploy: DeployNet,
    weights: Arc<SharedWeights>,
    device: DeviceKind,
    precision: Precision,
    quant_spec: Option<Arc<QuantSpec>>,
    intra_op: usize,
    output_len: usize,
    queue: Arc<SharedQueue<Batch>>,
    metrics: Arc<Metrics>,
    obs: Arc<EngineObs>,
    healthy: Arc<AtomicUsize>,
    breaker: Arc<Breaker>,
    chaos: Option<Arc<ChaosState>>,
}

impl WorkerSpawner {
    fn spawn(&self, wid: usize) -> std::io::Result<JoinHandle<()>> {
        let ctx = worker::WorkerContext {
            id: wid,
            deploy: self.deploy.clone(),
            weights: self.weights.clone(),
            device: self.device,
            precision: self.precision,
            quant_spec: self.quant_spec.clone(),
            intra_op: self.intra_op,
            output_len: self.output_len,
            queue: self.queue.clone(),
            metrics: self.metrics.clone(),
            obs: self.obs.clone(),
            healthy: self.healthy.clone(),
            breaker: self.breaker.clone(),
            chaos: self.chaos.clone(),
        };
        std::thread::Builder::new()
            .name(format!("serve-worker-{wid}"))
            .spawn(move || worker::run(ctx))
    }
}

/// Supervisor liveness-sweep interval (also the backoff sleep slice, so
/// shutdown is never held up by more than one slice).
const SUPERVISE_POLL: Duration = Duration::from_millis(10);

/// Engine-side worker supervision: sweep the pool, join workers whose
/// threads finished (replica-build failure, injected kill), and respawn
/// them — with per-slot exponential backoff — while the restart budget
/// lasts and the pool hasn't fully drained (a closed dispatch queue
/// means shutdown or last-worker-out; respawning into it would serve
/// nothing).
// Thread entry point: the supervisor thread owns its handles for its
// whole lifetime ('static), even though the body only borrows them.
#[allow(clippy::needless_pass_by_value)]
fn supervise(
    spawner: WorkerSpawner,
    slots: Arc<Mutex<Vec<Option<JoinHandle<()>>>>>,
    shutting_down: Arc<AtomicBool>,
    mut budget: usize,
    backoff: Duration,
) {
    let n = lock_unpoisoned(&slots).len();
    let mut attempts = vec![0u32; n];
    'sweep: while !shutting_down.load(Ordering::Acquire) {
        std::thread::sleep(SUPERVISE_POLL);
        for wid in 0..n {
            if shutting_down.load(Ordering::Acquire) {
                return;
            }
            let dead = {
                let guard = lock_unpoisoned(&slots);
                matches!(&guard[wid], Some(h) if h.is_finished())
            };
            if !dead {
                continue;
            }
            if let Some(h) = lock_unpoisoned(&slots)[wid].take() {
                let _ = h.join();
            }
            if budget == 0 || spawner.queue.is_closed() {
                continue;
            }
            let delay = backoff.saturating_mul(1u32 << attempts[wid].min(6));
            let respawn_at = Instant::now() + delay;
            while Instant::now() < respawn_at {
                if shutting_down.load(Ordering::Acquire) || spawner.queue.is_closed() {
                    continue 'sweep;
                }
                std::thread::sleep(SUPERVISE_POLL.min(delay));
            }
            budget -= 1;
            attempts[wid] = attempts[wid].saturating_add(1);
            // Count the replacement as healthy *before* it runs so a
            // burst of deaths can't observe an over-drained gauge; undo
            // if the OS refuses the thread.
            let now_healthy = spawner.healthy.fetch_add(1, Ordering::AcqRel) + 1;
            spawner.metrics.set_healthy_workers(now_healthy as u64);
            spawner.metrics.record_restart();
            match spawner.spawn(wid) {
                Ok(h) => lock_unpoisoned(&slots)[wid] = Some(h),
                Err(e) => {
                    let left = spawner.healthy.fetch_sub(1, Ordering::AcqRel) - 1;
                    spawner.metrics.set_healthy_workers(left as u64);
                    eprintln!("[serve] supervisor: respawn of worker {wid} failed: {e}");
                }
            }
        }
    }
}

/// Batched, multi-worker inference serving engine.
pub struct Engine {
    cfg: EngineConfig,
    deploy: DeployNet,
    shared: Arc<SharedWeights>,
    /// The deploy net's parameter schema — identity keys and element
    /// counts — against which every published snapshot is validated
    /// (and projected) *before* it can reach a worker.
    param_keys: Vec<(String, usize)>,
    param_lens: Vec<usize>,
    quant_spec: Option<Arc<QuantSpec>>,
    output_len: usize,
    submit_q: Arc<SharedQueue<Request>>,
    dispatch_q: Arc<SharedQueue<Batch>>,
    metrics: Arc<Metrics>,
    obs: Arc<EngineObs>,
    healthy: Arc<AtomicUsize>,
    breaker: Arc<Breaker>,
    shutting_down: Arc<AtomicBool>,
    worker_slots: Arc<Mutex<Vec<Option<JoinHandle<()>>>>>,
    threads: Mutex<Option<Threads>>,
}

impl Engine {
    /// Build and start an engine for a train_val (or deploy-style)
    /// `NetParameter`.
    pub fn new(param: &NetParameter, cfg: EngineConfig) -> anyhow::Result<Engine> {
        anyhow::ensure!(cfg.workers >= 1, "engine needs at least one worker");
        anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        let dep = deploy(param, cfg.max_batch)?;
        let buckets = crate::runtime::plan::serve_buckets(cfg.max_batch);

        // Static admission gate: lint the deploy net at every batch
        // bucket a worker can reshape to, *before* any blob is allocated
        // or thread spawned. Error-severity findings refuse the model
        // with a typed `netlint::LintError`; warnings are surfaced but
        // don't block serving.
        let precision = cfg.precision;
        let run_live_lint = |dep: &DeployNet| -> anyhow::Result<()> {
            let lint = crate::netlint::lint_net(
                &dep.param,
                &crate::netlint::LintOptions {
                    phase: Phase::Test,
                    buckets: buckets.clone(),
                    forward_only: true,
                    precision,
                    ..Default::default()
                },
            );
            if lint.has_errors() {
                eprint!("{}", lint.render_text());
                return Err(anyhow::Error::new(crate::netlint::LintError::new(lint))
                    .context("model refused at admission"));
            }
            for d in &lint.diagnostics {
                eprintln!(
                    "[serve] netlint {}[{}]: {}",
                    d.severity.label(),
                    d.code,
                    d.message
                );
            }
            Ok(())
        };

        // AOT cold boot: when a cache directory is configured (config
        // field, else FECAFFE_AOT_CACHE) and *every* serving bucket's
        // artifact loads and validates, the cached envelopes already
        // carry what the live admission pass would recompute — so the
        // boot skips re-planning. All-or-nothing: a single miss demotes
        // the whole boot to the live path, because a partially trusted
        // cache could mask a bucket whose plans no longer fit.
        let cache_dir = cfg.aot_cache.clone().or_else(crate::aot::env_cache_dir);
        let board = crate::device::fpga::costmodel::BoardParams::default();
        let mut boot = match &cache_dir {
            Some(dir) => crate::aot::cold_boot(dir, &dep, &buckets, &board, cfg.precision),
            None => crate::aot::ColdBoot::disabled(),
        };
        if let Some(dir) = &cache_dir {
            if boot.complete() {
                eprintln!(
                    "[serve] aot: cold boot from {} ({} bucket(s), key {}…)",
                    dir.display(),
                    boot.hits.len(),
                    &boot.hits[0].1.key[..12],
                );
            } else {
                for e in &boot.errors {
                    eprintln!("[serve] {e}");
                }
                eprintln!(
                    "[serve] aot: cache at {} unusable, planning live",
                    dir.display()
                );
            }
        }
        if !boot.complete() {
            run_live_lint(&dep)?;
        }

        // Master replica: initialize weights once, publish the snapshot,
        // and learn the output row length from the shaped net. Built on
        // the *configured* device kind so device-specific build failures
        // surface here as an Err instead of as silent worker deaths
        // later.
        let mut dev = cfg.device.create();
        let mut master = Net::from_param(&dep.param, Phase::Test, dev.as_mut())?;
        let weights = master.share_weights(dev.as_mut());
        let out_blob = master.blob(&dep.output).ok_or_else(|| {
            anyhow::anyhow!("deploy output blob '{}' not found in net", dep.output)
        })?;
        let out_count = out_blob.borrow().count();
        anyhow::ensure!(
            out_count % cfg.max_batch == 0,
            "output blob '{}' count {} is not a multiple of batch {}",
            dep.output,
            out_count,
            cfg.max_batch
        );
        let output_len = out_count / cfg.max_batch;
        drop(out_blob);
        drop(master);

        let param_keys = weights.keys().to_vec();
        let param_lens = weights.blob_lens();

        // Reduced precision: project the boot weights onto the serving
        // grid (int8 fake-quant / fp16 rounding) before anything is
        // published, and — int8 only — run the post-training calibration
        // forwards on the weights that will actually serve, deriving the
        // static per-kernel-shape activation ranges workers quantize by.
        let weights = quant::prepare_weights(&weights, cfg.precision);
        let quant_spec = if cfg.precision == Precision::Int8 {
            let spec = quant::calibrate::calibrate(
                &dep.param.name,
                &dep,
                Some(&weights),
                CALIBRATION_BATCHES,
                CALIBRATION_SEED,
            )?;
            eprintln!(
                "[serve] quant: calibrated {} matmul shape(s) for '{}' @ int8",
                spec.len(),
                dep.param.name
            );
            Some(Arc::new(spec))
        } else {
            None
        };

        // The weights schema only materializes with the master replica,
        // so a cold boot is confirmed here: cached envelopes must name
        // exactly the live parameter blobs. A mismatch demotes the boot
        // (the skipped admission lint runs now) rather than letting
        // workers adopt snapshots a stale cache never described.
        if boot.complete() {
            let (b0, art) = &boot.hits[0];
            let rel = crate::aot::plan_rel_path(&dep.param.name, *b0, cfg.precision);
            if let Err(e) = crate::aot::validate_weights(art, &param_keys, &param_lens, &rel) {
                eprintln!("[serve] {e}");
                eprintln!("[serve] aot: demoting cold boot, planning live");
                boot.demote(e);
                run_live_lint(&dep)?;
            }
        }

        let shared = Arc::new(SharedWeights {
            version: AtomicU64::new(weights.version()),
            slot: Mutex::new(Arc::new(weights)),
        });

        let submit_q = Arc::new(SharedQueue::new(cfg.queue_capacity));
        // Small dispatch buffer: enough to keep workers busy, small
        // enough that queueing (and thus latency) stays visible at the
        // admission queue where backpressure applies.
        let dispatch_q = Arc::new(SharedQueue::new(cfg.workers * 2));
        let metrics = Arc::new(Metrics::new());
        metrics.set_healthy_workers(cfg.workers as u64);
        metrics.set_aot_cache(boot.hit_count(), boot.miss_count());

        // Fault-injection plan: explicit config wins, else the
        // `FECAFFE_CHAOS` env var (so smoke scripts can inject faults
        // into an unmodified server invocation). A present-but-invalid
        // spec is a hard error — never a silently fault-free run.
        let plan = match cfg.chaos.clone() {
            Some(p) => Some(p),
            None => FaultPlan::from_env().map_err(|e| {
                anyhow::anyhow!("invalid {} spec: {e}", crate::util::chaos::CHAOS_ENV)
            })?,
        };
        let chaos = plan.filter(|p| !p.is_noop()).map(|p| Arc::new(ChaosState::new(p)));
        let breaker = Arc::new(Breaker::new(
            cfg.breaker_threshold as u32,
            cfg.breaker_cooldown,
            metrics.clone(),
        ));

        // On a thread-spawn failure partway through, close the queues and
        // join what already started — otherwise the spawned workers (each
        // holding a warm net replica) would park on the queue forever.
        let unwind = |slots: Vec<Option<JoinHandle<()>>>| {
            submit_q.close();
            dispatch_q.close();
            for w in slots.into_iter().flatten() {
                let _ = w.join();
            }
        };

        let healthy = Arc::new(AtomicUsize::new(cfg.workers));
        let obs = Arc::new(EngineObs::new(cfg.trace_sample, TRACE_RING_CAP));
        let spawner = WorkerSpawner {
            deploy: dep.clone(),
            weights: shared.clone(),
            device: cfg.device,
            precision: cfg.precision,
            quant_spec: quant_spec.clone(),
            intra_op: cfg.intra_op_budget(),
            output_len,
            queue: dispatch_q.clone(),
            metrics: metrics.clone(),
            obs: obs.clone(),
            healthy: healthy.clone(),
            breaker: breaker.clone(),
            chaos,
        };
        let mut slots: Vec<Option<JoinHandle<()>>> = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            match spawner.spawn(wid) {
                Ok(handle) => slots.push(Some(handle)),
                Err(e) => {
                    unwind(slots);
                    return Err(anyhow::anyhow!("spawn worker {wid}: {e}"));
                }
            }
        }

        let bcfg = BatcherConfig { max_batch: cfg.max_batch, max_linger: cfg.max_linger };
        let (sq, dq, bm) = (submit_q.clone(), dispatch_q.clone(), metrics.clone());
        let batcher = match std::thread::Builder::new()
            .name("serve-batcher".to_string())
            .spawn(move || batcher::run(sq, dq, bcfg, bm))
        {
            Ok(handle) => handle,
            Err(e) => {
                unwind(slots);
                return Err(anyhow::anyhow!("spawn batcher: {e}"));
            }
        };

        let worker_slots = Arc::new(Mutex::new(slots));
        let shutting_down = Arc::new(AtomicBool::new(false));
        // The supervisor is best-effort: if the OS refuses the thread
        // the engine still serves, workers just aren't respawned.
        let supervisor = if cfg.restart_budget > 0 {
            let (sl, sd) = (worker_slots.clone(), shutting_down.clone());
            let (budget, backoff) = (cfg.restart_budget, cfg.restart_backoff);
            std::thread::Builder::new()
                .name("serve-supervisor".to_string())
                .spawn(move || supervise(spawner, sl, sd, budget, backoff))
                .ok()
        } else {
            None
        };

        Ok(Engine {
            cfg,
            deploy: dep,
            shared,
            param_keys,
            param_lens,
            quant_spec,
            output_len,
            submit_q,
            dispatch_q,
            metrics,
            obs,
            healthy,
            breaker,
            shutting_down,
            worker_slots,
            threads: Mutex::new(Some(Threads { batcher, supervisor })),
        })
    }

    /// Elements per input sample (C*H*W).
    pub fn sample_len(&self) -> usize {
        self.deploy.sample_len
    }

    /// Elements per output row (e.g. number of classes).
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn deploy_net(&self) -> &DeployNet {
        &self.deploy
    }

    /// The currently published weight snapshot (what workers serve from
    /// after their next batch boundary).
    pub fn weights(&self) -> WeightSnapshot {
        lock_unpoisoned(&self.shared.slot).as_ref().clone()
    }

    /// Version of the currently published weight snapshot (0 until the
    /// first publish — the engine's own initialization weights).
    pub fn weights_version(&self) -> u64 {
        self.shared.version.load(Ordering::Acquire)
    }

    /// Atomically publish a new weight snapshot. The snapshot is
    /// validated (and, for training-net snapshots with extra params,
    /// projected) against the deploy net's parameter schema *before*
    /// the swap, so a bad snapshot can never reach a worker. Each
    /// worker adopts the new weights at its next batch boundary:
    /// in-flight batches complete on the old version, no request is
    /// dropped, and no response ever mixes two versions.
    ///
    /// Versions are strictly monotonic. A snapshot with `version() ==
    /// 0` (unversioned) is assigned `current + 1`; an explicit version
    /// must be greater than the current one or the publish is refused
    /// with [`PublishError::Stale`]. `u64::MAX` is reserved (accepting
    /// it would leave `current + 1` nowhere to go, wedging every later
    /// auto-versioned publish) and refused as a mismatch. Returns the
    /// published version.
    // By-value is the publication contract — callers hand the snapshot
    // off to the engine. The body itself only borrows it (projection
    // Arc-clones the blobs), which needless_pass_by_value flags.
    #[allow(clippy::needless_pass_by_value)]
    pub fn publish_weights(&self, snap: WeightSnapshot) -> Result<u64, PublishError> {
        let projected = snap
            .project(&self.param_keys, &self.param_lens)
            .map_err(|e| PublishError::Mismatch(format!("{e:#}")))?;
        // Hot-swapped snapshots serve at the engine's precision too:
        // project onto the quantization grid before taking the lock, so
        // workers never mix a full-precision publish into an int8/fp16
        // serving path.
        let projected = quant::prepare_weights(&projected, self.cfg.precision);
        let mut slot = lock_unpoisoned(&self.shared.slot);
        let current = self.shared.version.load(Ordering::Acquire);
        let offered = projected.version();
        // u64::MAX is reserved: explicit publishes of it are refused,
        // and an auto-assignment that would reach it (the version space
        // is exhausted) fails cleanly here instead of overflowing under
        // the lock (debug panic would poison it; release wrap-to-0
        // would wedge every later publish as Stale).
        let version = if offered == 0 { current.saturating_add(1) } else { offered };
        if version == u64::MAX {
            return Err(PublishError::Mismatch(format!(
                "weights version {} is reserved (max {})",
                u64::MAX,
                u64::MAX - 1
            )));
        }
        if version <= current {
            return Err(PublishError::Stale { current, offered: version });
        }
        *slot = Arc::new(projected.with_version(version));
        // Workers poll `version` without the lock; publish it only once
        // the slot holds the matching snapshot (still under the lock, so
        // concurrent publishers serialize). The metrics gauge is also
        // recorded under the lock — otherwise two racing publishers
        // could land their `record_publish` calls out of order and
        // leave `/metrics` reporting an older version than is served.
        self.shared.version.store(version, Ordering::Release);
        self.metrics.record_publish(version);
        drop(slot);
        Ok(version)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Numeric precision this engine serves at.
    pub fn precision(&self) -> Precision {
        self.cfg.precision
    }

    /// Static activation-range spec derived at boot (int8 engines only).
    pub fn quant_spec(&self) -> Option<&Arc<QuantSpec>> {
        self.quant_spec.as_ref()
    }

    /// The engine's observability hub: sampled batch traces and
    /// per-layer timing aggregates.
    pub fn obs(&self) -> &EngineObs {
        &self.obs
    }

    /// Workers still alive (each decrements on replica-build failure or
    /// batch poisoning) — the `/healthz` per-model health signal.
    pub fn healthy_workers(&self) -> usize {
        self.healthy.load(Ordering::Relaxed)
    }

    /// Current circuit-breaker state: `"closed"`, `"open"`, or
    /// `"half-open"`.
    pub fn breaker_state(&self) -> &'static str {
        self.breaker.state_name()
    }

    /// Current admission-queue depth (requests admitted, not yet pulled
    /// into a batch).
    pub fn queue_depth(&self) -> usize {
        self.submit_q.len()
    }

    /// Submit one sample. Non-blocking admission: `Overloaded` means the
    /// bounded queue is full and the caller should back off.
    pub fn submit(&self, sample: Vec<f32>) -> Result<ResponseHandle, ServeError> {
        self.submit_with_deadline(sample, None)
    }

    /// Submit one sample with an optional latency budget. A request
    /// whose deadline passes before a worker executes it is shed
    /// (resolved as [`ServeError::DeadlineExceeded`]) instead of
    /// wasting a batch slot on an answer nobody is waiting for.
    pub fn submit_with_deadline(
        &self,
        sample: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<ResponseHandle, ServeError> {
        if sample.len() != self.deploy.sample_len {
            return Err(ServeError::BadRequest(format!(
                "sample has {} elements, model expects {}",
                sample.len(),
                self.deploy.sample_len
            )));
        }
        // Breaker first: while the circuit is open the model is known
        // to be failing whole batches, so rejecting here is cheaper for
        // everyone than queueing work that will fail.
        if let Some(retry_after_ms) = self.breaker.check_reject() {
            self.metrics.record_breaker_rejected();
            return Err(ServeError::BreakerOpen { retry_after_ms });
        }
        // Cheap pre-check so the common rejection path pays no Slot
        // allocation (racy; try_push below still enforces the bound).
        if self.submit_q.is_full() {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::Overloaded(sample));
        }
        let slot = Arc::new(Slot { result: Mutex::new(None), ready: Condvar::new() });
        let submitted = Instant::now();
        let req = Request {
            sample,
            submitted,
            deadline: deadline.map(|d| submitted + d),
            slot: slot.clone(),
            metrics: self.metrics.clone(),
        };
        match self.submit_q.try_push(req) {
            Ok(depth) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                // Depth including this request, returned by the push so
                // the gauge costs no extra lock on the hot path.
                self.metrics.record_queue_depth(depth as u64);
                Ok(ResponseHandle { slot, submitted })
            }
            Err(PushError::Full(mut req)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                // Hand the sample back for a clone-free retry. Resolve
                // the never-exposed slot with the dedicated `Rejected`
                // marker — not `ShuttingDown`, which would misreport
                // overload as shutdown in traces — so the drop below
                // doesn't book a `failed` on top of the `rejected`.
                let sample = std::mem::take(&mut req.sample);
                req.complete(Err(ServeError::Rejected));
                Err(ServeError::Overloaded(sample))
            }
            Err(PushError::Closed(req)) => {
                // Never admitted: resolve the unexposed slot with the
                // true reason so Drop doesn't book a worker `failed`
                // for a request that was refused at the door.
                req.complete(Err(ServeError::ShuttingDown));
                Err(ServeError::ShuttingDown)
            }
        }
    }

    /// Graceful shutdown: stop admissions, drain every already-admitted
    /// request through the workers, then join all threads. Idempotent;
    /// also invoked by `Drop`.
    pub fn shutdown(&self) {
        let threads = lock_unpoisoned(&self.threads).take();
        let Some(Threads { batcher, supervisor }) = threads else {
            return;
        };
        // 1. Stop the supervisor's respawn decisions first — a worker
        //    exiting because the pool is draining must stay exited.
        self.shutting_down.store(true, Ordering::Release);
        // 2. No new admissions; the batcher drains what's queued.
        self.submit_q.close();
        let _ = batcher.join();
        // 3. Batcher flushed everything into dispatch; workers drain it.
        self.dispatch_q.close();
        if let Some(s) = supervisor {
            let _ = s.join();
        }
        // 4. Supervisor joined: the slot table is stable now.
        let handles: Vec<JoinHandle<()>> = {
            let mut slots = lock_unpoisoned(&self.worker_slots);
            slots.iter_mut().filter_map(|s| s.take()).collect()
        };
        for w in handles {
            let _ = w.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_request(metrics: &Arc<Metrics>) -> (Request, Arc<Slot>) {
        let slot = Arc::new(Slot { result: Mutex::new(None), ready: Condvar::new() });
        let req = Request {
            sample: vec![1.0, 2.0],
            submitted: Instant::now(),
            deadline: None,
            slot: slot.clone(),
            metrics: metrics.clone(),
        };
        (req, slot)
    }

    /// The admission-overflow resolution must be `Rejected`, not
    /// `ShuttingDown`, and must not count as a worker failure — the
    /// `rejected` counter (bumped by `submit`) is the only record.
    #[test]
    fn rejected_resolution_is_not_shutdown_and_not_a_failure() {
        let metrics = Arc::new(Metrics::new());
        let (req, slot) = mk_request(&metrics);
        assert!(req.complete(Err(ServeError::Rejected)));
        drop(req); // Drop sees the slot resolved: no double accounting.
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 0);
        match slot.result.lock().unwrap().as_ref() {
            Some(Err(ServeError::Rejected)) => {}
            other => panic!("expected Rejected resolution, got {other:?}"),
        }
    }

    /// A request dropped unresolved still books exactly one failure.
    #[test]
    fn dropped_request_books_one_failure() {
        let metrics = Arc::new(Metrics::new());
        let (req, slot) = mk_request(&metrics);
        drop(req);
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 1);
        match slot.result.lock().unwrap().as_ref() {
            Some(Err(ServeError::Worker(_))) => {}
            other => panic!("expected Worker resolution, got {other:?}"),
        }
    }

    /// First resolution wins; later ones (including Drop) are no-ops.
    #[test]
    fn resolution_is_first_writer_wins() {
        let metrics = Arc::new(Metrics::new());
        let (req, slot) = mk_request(&metrics);
        assert!(req.complete(Ok(Fulfilled { values: vec![0.5], weights_version: 3 })));
        assert!(!req.complete(Err(ServeError::Rejected)));
        drop(req);
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 0);
        match slot.result.lock().unwrap().as_ref() {
            Some(Ok(f)) => {
                assert_eq!(f.values, vec![0.5]);
                assert_eq!(f.weights_version, 3);
            }
            other => panic!("expected fulfilled slot, got {other:?}"),
        }
    }

    /// An expired request sheds as `DeadlineExceeded` — accounted in
    /// `shed_expired`, never `failed` (nothing broke), exactly once.
    #[test]
    fn shed_request_is_deadline_exceeded_not_failed() {
        let metrics = Arc::new(Metrics::new());
        let (mut req, slot) = mk_request(&metrics);
        req.deadline = Some(Instant::now() - Duration::from_millis(1));
        assert!(req.expired(Instant::now()));
        req.shed();
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.shed_expired.load(Ordering::Relaxed), 1);
        match slot.result.lock().unwrap().as_ref() {
            Some(Err(ServeError::DeadlineExceeded)) => {}
            other => panic!("expected DeadlineExceeded resolution, got {other:?}"),
        }
        // No deadline, or a future one, never reads as expired.
        let (req, _slot) = mk_request(&metrics);
        assert!(!req.expired(Instant::now()));
        drop(req);
    }

    /// Breaker lifecycle: threshold consecutive failures open it, the
    /// open circuit fast-rejects with a remaining-cooldown hint, the
    /// post-cooldown submission rides through as a half-open probe, and
    /// a successful probe re-closes (resetting the reopening scale).
    #[test]
    fn breaker_opens_after_threshold_and_probe_recloses() {
        let metrics = Arc::new(Metrics::new());
        let b = Breaker::new(3, Duration::from_millis(20), metrics.clone());
        assert_eq!(b.state_name(), "closed");
        b.on_batch(false);
        b.on_batch(false);
        assert!(b.check_reject().is_none(), "two failures stay under threshold 3");
        b.on_batch(true); // success resets the consecutive count
        b.on_batch(false);
        b.on_batch(false);
        b.on_batch(false);
        assert_eq!(b.state_name(), "open");
        let ms = b.check_reject().expect("open circuit fast-rejects");
        assert!(ms >= 1 && ms <= 20, "retry hint {ms} ms within cooldown");
        std::thread::sleep(Duration::from_millis(25));
        assert!(b.check_reject().is_none(), "post-cooldown submission is the probe");
        assert_eq!(b.state_name(), "half-open");
        b.on_batch(true);
        assert_eq!(b.state_name(), "closed");
        assert_eq!(metrics.breaker_trips.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.breaker_state.load(Ordering::Relaxed), 0);
    }

    /// A failed half-open probe reopens the circuit (a second trip)
    /// with a doubled cooldown, and threshold 0 disables the breaker.
    #[test]
    fn failed_probe_reopens_and_zero_threshold_disables() {
        let metrics = Arc::new(Metrics::new());
        let b = Breaker::new(1, Duration::from_millis(10), metrics.clone());
        b.on_batch(false);
        assert_eq!(b.state_name(), "open");
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.check_reject().is_none());
        b.on_batch(false); // probe fails → reopen with 2× cooldown
        assert_eq!(b.state_name(), "open");
        assert_eq!(metrics.breaker_trips.load(Ordering::Relaxed), 2);
        let ms = b.check_reject().expect("reopened circuit rejects");
        assert!(ms <= 20, "doubled cooldown bounds the retry hint, got {ms}");

        let off = Breaker::new(0, Duration::from_millis(10), Arc::new(Metrics::new()));
        for _ in 0..10 {
            off.on_batch(false);
        }
        assert!(off.check_reject().is_none(), "threshold 0 never trips");
        assert_eq!(off.state_name(), "closed");
    }

    /// A panic while holding the response-slot lock must not cascade:
    /// the slot only ever holds valid state, so waiters and completers
    /// recover the poisoned guard instead of panicking (the satellite
    /// mutex-poisoning audit, pinned).
    #[test]
    fn response_slot_survives_mutex_poisoning() {
        let metrics = Arc::new(Metrics::new());
        let (req, slot) = mk_request(&metrics);
        let poisoner = slot.clone();
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.result.lock().unwrap();
            panic!("poison the slot mutex");
        })
        .join();
        assert!(slot.result.lock().is_err(), "precondition: mutex is poisoned");
        req.fulfill(vec![0.25], 7);
        let handle = ResponseHandle { slot, submitted: Instant::now() };
        let resp = handle.wait().expect("wait recovers through the poison");
        assert_eq!(resp.values, vec![0.25]);
        assert_eq!(resp.weights_version, 7);
    }

    /// Stale-version publishes are refused with a message naming both
    /// versions, and the error display reads well in HTTP bodies.
    #[test]
    fn publish_error_display() {
        let e = PublishError::Stale { current: 5, offered: 5 };
        let s = e.to_string();
        assert!(s.contains('5') && s.contains("monotonic"), "{s}");
        let e = PublishError::Mismatch("layer 'fc' missing".to_string());
        assert!(e.to_string().contains("fc"));
    }

    /// u64::MAX is a reserved version: accepting it would leave the
    /// auto-assigned `current + 1` nowhere to go (overflow in debug,
    /// permanent Stale in release), wedging the publish path forever.
    #[test]
    fn publish_refuses_the_reserved_max_version() {
        let param = crate::proto::parse_net(
            r#"
name: "one"
input: "data"
input_shape { dim: 1 dim: 1 dim: 1 dim: 2 }
layer { name: "fc" type: "InnerProduct" bottom: "data" top: "fc"
        inner_product_param { num_output: 1 weight_filler { type: "xavier" } } }
"#,
        )
        .unwrap();
        let engine = Engine::new(
            &param,
            EngineConfig { workers: 1, max_batch: 1, ..EngineConfig::default() },
        )
        .unwrap();
        let snap = engine.weights().with_version(u64::MAX);
        match engine.publish_weights(snap) {
            Err(PublishError::Mismatch(m)) => assert!(m.contains("reserved"), "{m}"),
            other => panic!("expected Mismatch for reserved version, got {other:?}"),
        }
        // The engine is not wedged: an auto-versioned publish still lands.
        assert_eq!(engine.publish_weights(engine.weights()).unwrap(), 1);
        // Version-space exhaustion also fails cleanly: u64::MAX - 1 is
        // the legal ceiling, and the auto-assignment that would step
        // past it reports the reserved version instead of overflowing
        // (which would poison the slot lock in debug builds).
        let ceiling = engine.weights().with_version(u64::MAX - 1);
        assert_eq!(engine.publish_weights(ceiling).unwrap(), u64::MAX - 1);
        match engine.publish_weights(engine.weights().with_version(0)) {
            Err(PublishError::Mismatch(m)) => assert!(m.contains("reserved"), "{m}"),
            other => panic!("expected clean exhaustion error, got {other:?}"),
        }
        engine.shutdown();
    }
}
