//! Post-training reduced-precision serving (ROADMAP "Reduced-precision
//! serving (fp16/int8 emulation)").
//!
//! The FPGA CNN literature's dominant lever: int8 multiplies pack ~4×
//! more MACs per DSP than fp32 and move a quarter of the DDR bytes.
//! This module provides the whole pipeline:
//!
//! * [`calibrate`] — run a few fp32 batches, record per-GEMM operand
//!   ranges, derive a versioned [`QuantSpec`] (`FEQSPEC1` container);
//! * [`snapshot::QuantizedSnapshot`] — per-blob int8 payloads + scales
//!   (`FEQSNAP1` container), dequantizing to the *fake-quant* snapshot
//!   the engine serves;
//! * [`backend::QuantBackend`] — a [`NumericBackend`] that executes
//!   GEMM/GEMV in emulated int8 (i32 accumulation, requantize) or fp16
//!   (operands rounded through the f16 grid, f32 accumulation) —
//!   bit-identical at any thread count, like the fp32 packed kernel;
//! * a precision-aware cost model (`device/fpga/costmodel.rs` charges
//!   int8 at its SIMD-lane advantage and reduced DDR traffic).
//!
//! Model names carry precision as a suffix: `lenet@int8` serves the
//! quantized variant next to plain fp32 `lenet` in one process.
//!
//! [`NumericBackend`]: crate::device::fpga::NumericBackend

pub mod backend;
pub mod calibrate;
pub mod f16;
pub mod gemm;
pub mod snapshot;

pub use calibrate::{quant_key, QuantSpec};
pub use snapshot::QuantizedSnapshot;

use crate::device::KClass;
use crate::net::WeightSnapshot;

/// Serving numeric precision. `Fp32` is the native path; the reduced
/// modes change weight storage, the GEMM/GEMV execution path, and the
/// FPGA cost model's lane/byte accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    Fp32,
    Fp16,
    Int8,
}

impl Precision {
    /// Parse a precision suffix/flag value (`fp32`, `fp16`, `int8`).
    pub fn parse(s: &str) -> anyhow::Result<Precision> {
        match s {
            "fp32" | "f32" | "float" => Ok(Precision::Fp32),
            "fp16" | "f16" | "half" => Ok(Precision::Fp16),
            "int8" | "i8" => Ok(Precision::Int8),
            other => anyhow::bail!(
                "unknown precision '{other}' (expected fp32, fp16 or int8)"
            ),
        }
    }

    /// Canonical label for metrics, file names and logs.
    pub fn label(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
        }
    }

    /// Storage bytes per element on the device.
    pub fn elem_bytes(self) -> u64 {
        match self {
            Precision::Fp32 => 4,
            Precision::Fp16 => 2,
            Precision::Int8 => 1,
        }
    }

    /// SIMD-lane multiplier for a kernel class relative to fp32: how
    /// many more MACs per DSP the precision packs. Only the matmul
    /// engines are DSP-bound; the streaming kernels are memory-bound and
    /// take their win from the byte reduction instead.
    pub fn lane_multiplier(self, class: KClass) -> f64 {
        match (self, class) {
            (Precision::Fp32, _) => 1.0,
            (Precision::Fp16, KClass::Gemm | KClass::Gemv) => 2.0,
            (Precision::Int8, KClass::Gemm | KClass::Gemv) => 4.0,
            _ => 1.0,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Split a serving model name into (base zoo name, precision).
/// `"lenet"` → `("lenet", Fp32)`; `"lenet@int8"` → `("lenet", Int8)`.
pub fn split_model_name(name: &str) -> anyhow::Result<(&str, Precision)> {
    match name.split_once('@') {
        None => Ok((name, Precision::Fp32)),
        Some((base, suffix)) => {
            anyhow::ensure!(!base.is_empty(), "empty model name before '@' in '{name}'");
            let p = Precision::parse(suffix)
                .map_err(|e| e.context(format!("model '{name}'")))?;
            Ok((base, p))
        }
    }
}

/// Transform a published weight snapshot onto the serving precision's
/// grid, preserving version/tag identity:
///
/// * `Fp32` — unchanged;
/// * `Fp16` — every weight rounded through the f16 grid (RNE);
/// * `Int8` — fake-quant: quantize symmetrically per blob and
///   dequantize, so replicas hold weights that sit exactly on their
///   int8 grid and the emulated GEMM's re-quantization is lossless.
pub fn prepare_weights(snap: &WeightSnapshot, precision: Precision) -> WeightSnapshot {
    match precision {
        Precision::Fp32 => snap.clone(),
        Precision::Int8 => QuantizedSnapshot::from_snapshot(snap)
            .dequantize()
            .with_version(snap.version()),
        Precision::Fp16 => {
            let blobs = (0..snap.len())
                .map(|i| {
                    let mut v = snap.blob_data(i).expect("blob index in range").to_vec();
                    f16::f16_round_slice(&mut v);
                    std::sync::Arc::new(v)
                })
                .collect();
            let mut out = WeightSnapshot::from_parts(
                snap.version(),
                snap.tag().map(str::to_owned),
                snap.keys().to_vec(),
                blobs,
            );
            // from_parts keeps version; ensure tag/version identity.
            out = out.with_version(snap.version());
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_labels_round_trip() {
        for p in [Precision::Fp32, Precision::Fp16, Precision::Int8] {
            assert_eq!(Precision::parse(p.label()).unwrap(), p);
        }
        assert!(Precision::parse("int4").is_err());
    }

    #[test]
    fn split_model_name_handles_suffixes() {
        assert_eq!(split_model_name("lenet").unwrap(), ("lenet", Precision::Fp32));
        assert_eq!(split_model_name("lenet@int8").unwrap(), ("lenet", Precision::Int8));
        assert_eq!(split_model_name("vgg16@fp16").unwrap(), ("vgg16", Precision::Fp16));
        assert!(split_model_name("lenet@int4").is_err());
        assert!(split_model_name("@int8").is_err());
    }

    #[test]
    fn lane_multiplier_only_boosts_matmul() {
        assert_eq!(Precision::Int8.lane_multiplier(KClass::Gemm), 4.0);
        assert_eq!(Precision::Int8.lane_multiplier(KClass::ReluF), 1.0);
        assert_eq!(Precision::Fp16.lane_multiplier(KClass::Gemv), 2.0);
        assert_eq!(Precision::Fp32.lane_multiplier(KClass::Gemm), 1.0);
    }
}
