#!/usr/bin/env bash
# Chaos smoke test: boot `serve --http` with a seeded fault plan from
# the FECAFFE_CHAOS env var (the unmodified-binary injection path),
# drive real load through the binary's own HTTP load generator while
# transient device faults and a mid-batch worker panic fire, and assert
# the fault-tolerance ledger:
#   * zero hung requests — submitted == completed + failed + shed,
#   * the panicked replica was rebuilt (restarts >= 1),
#   * injected transients were retried, not surfaced (retries >= 1),
#   * an expired x-deadline-ms request sheds as 504,
#   * /healthz recovers to "ok" and the server still drains clean.
# Artifacts (uploaded by the CI chaos-smoke leg): chaos_load.json (the
# load generator's report) and chaos_metrics.json (final /metrics).
set -euo pipefail

SERVE="${SERVE:-target/release/serve}"
LOG="$(mktemp)"
trap 'kill "$SERVER_PID" 2>/dev/null || true; rm -f "$LOG"' EXIT

[ -x "$SERVE" ] || { echo "serve binary not found at $SERVE (set SERVE=...)"; exit 1; }

# Seeded plan: ~2% transient forward faults (bounded at 64 so the tail
# of the run is quiet), one worker panic after the fifth batch. No
# kills: this leg checks in-place replica rebuild; supervision has its
# own integration tests.
export FECAFFE_CHAOS="seed=7,fault=0.02,fault-n=64,panic=1,panic-after=5"

"$SERVE" --http 127.0.0.1:0 --models lenet --workers 2 --max-batch 8 \
    >"$LOG" 2>&1 &
SERVER_PID=$!

ADDR=""
for _ in $(seq 1 100); do
    ADDR="$(sed -n 's|.*listening on http://||p' "$LOG" | head -n1)"
    [ -n "$ADDR" ] && break
    kill -0 "$SERVER_PID" 2>/dev/null || { echo "server died during startup:"; cat "$LOG"; exit 1; }
    sleep 0.2
done
[ -n "$ADDR" ] || { echo "server never reported its address:"; cat "$LOG"; exit 1; }
echo "server up at $ADDR (chaos: $FECAFFE_CHAOS)"

fail() { echo "FAIL: $1"; cat "$LOG"; exit 1; }

# The server must announce it picked the plan up from the environment.
grep -q "FECAFFE_CHAOS set" "$LOG" || fail "server did not report the env chaos plan"

curl -sf "http://$ADDR/healthz" | grep -q '"status": "ok"' || fail "healthz before load"

# An already-expired deadline is shed as 504 — before any fault fires,
# so this also pins that deadlines work independently of chaos.
BODY="{\"instances\": [[$(python3 -c 'print(",".join(["0.5"]*784))')]]}"
CODE="$(curl -s -o /dev/null -w '%{http_code}' -X POST -H 'x-deadline-ms: 0' \
    -d "$BODY" "http://$ADDR/v1/models/lenet:predict")"
[ "$CODE" = "504" ] || fail "expected 504 for x-deadline-ms: 0, got $CODE"

# Load through the chaos window: enough requests that the panic
# (after batch 5) and the 64 transient faults all land mid-run. The
# generator tolerates the panicked batch's 500s; what it must not do
# is hang or lose a request.
"$SERVE" --target "$ADDR" --net lenet --requests 512 --clients 4 \
    --json chaos_load.json || fail "load generator under chaos"

curl -sf "http://$ADDR/metrics" > chaos_metrics.json || fail "metrics fetch"
python3 - <<'EOF' || fail "chaos ledger assertions"
import json
m = json.load(open("chaos_metrics.json"))["lenet"]
submitted = m["submitted"]
resolved = m["completed"] + m["failed"] + m["shed_expired"]
assert submitted == resolved, \
    f"hung requests: submitted {submitted} != resolved {resolved} ({m['failure_breakdown']})"
assert m["restarts"] >= 1, f"panicked replica was not rebuilt: {m['restarts']}"
assert m["retries"] >= 1, f"no transient retries recorded: {m['retries']}"
assert m["shed_expired"] >= 1, "the 504 probe was not accounted as shed"
assert m["breaker_state"] == 0, f"breaker not closed after recovery: {m['breaker_state']}"
fb = m["failure_breakdown"]
print(f"ledger OK: {submitted} submitted = {m['completed']} completed "
      f"+ {m['failed']} worker-failed + {m['shed_expired']} shed "
      f"(retries {m['retries']}, restarts {m['restarts']}, breakdown {fb})")
EOF

# The pool healed in place: full strength, breaker closed, status ok.
HEALTH_OK=""
for _ in $(seq 1 50); do
    if curl -sf "http://$ADDR/healthz" | grep -q '"status": "ok"'; then
        HEALTH_OK=1
        break
    fi
    sleep 0.2
done
[ -n "$HEALTH_OK" ] || { curl -s "http://$ADDR/healthz"; fail "healthz never recovered to ok"; }
echo "recovery: OK (healthz ok, breaker closed)"

# Chaos must not break the graceful-drain contract.
curl -sf -X POST "http://$ADDR/admin/shutdown" >/dev/null || fail "admin shutdown"
for _ in $(seq 1 100); do
    kill -0 "$SERVER_PID" 2>/dev/null || break
    sleep 0.2
done
if kill -0 "$SERVER_PID" 2>/dev/null; then
    fail "server did not exit after /admin/shutdown"
fi
wait "$SERVER_PID" || fail "server exited non-zero"
grep -q "drained clean" "$LOG" || fail "server did not report a clean drain"
echo "chaos smoke: OK"
