//! FPGA resource model — regenerates paper Table 3.
//!
//! The paper reports post-placement utilization of the one bitstream that
//! contains the whole kernel inventory. We estimate each kernel's
//! ALM/register/M20K/DSP cost from its microarchitecture (tile sizes,
//! SIMD lanes, pipeline depth) using per-primitive cost constants from
//! Intel's S10 OpenCL reports. Absolute numbers are estimates; the
//! structure (gemm and gemv dominate, total ≈ half the chip) is the
//! claim being reproduced.

/// Stratix 10 GX 2800 (dev-kit device) totals.
pub const S10_ALMS: u64 = 933_120;
pub const S10_M20K: u64 = 11_721;
pub const S10_DSPS: u64 = 5_760;

#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Usage {
    pub alms: u64,
    pub regs: u64,
    pub m20k: u64,
    pub dsps: u64,
}

impl Usage {
    fn add(&mut self, o: &Usage) {
        self.alms += o.alms;
        self.regs += o.regs;
        self.m20k += o.m20k;
        self.dsps += o.dsps;
    }
}

/// gemm kernel: 2-D local-memory tiled NDRange (paper §3.2).
/// TM×TN work-group with per-work-item MAC → DSPs ≈ TM*TN (+ address
/// generators); local A/B tiles double-buffered in M20K; ALMs dominated
/// by the load/store network around each DSP lane.
pub fn gemm_kernel(tm: u64, tn: u64, tk: u64) -> Usage {
    let lanes = tm * tn;
    let dsps = lanes + 13; // MAC lanes + index arithmetic
    // double-buffered A(tm×tk) + B(tk×tn) f32 tiles, 20 kbit per M20K
    let tile_bits = 2 * (tm * tk + tk * tn) * 32 * 2;
    let m20k_tiles = tile_bits / 20_480 + 1;
    // C accumulators live in registers; interconnect + barrels in ALMs
    Usage {
        alms: 95 * lanes + 9_000,
        regs: 290 * lanes + 30_000,
        m20k: m20k_tiles + 2 * lanes,
        dsps,
    }
}

/// gemv kernel: 1-D local buffer + SIMD reduction (paper §3.2).
pub fn gemv_kernel(tile: u64, simd: u64) -> Usage {
    let lanes = tile * simd / 8;
    Usage {
        alms: 330 * lanes + 6_000,
        regs: 780 * lanes + 14_000,
        m20k: (tile * simd * 32 * 2) / 20_480 + 5 * lanes,
        dsps: lanes + 2,
    }
}

/// A streaming (elementwise / windowed) NDRange kernel with `lanes`
/// parallel f32 lanes and `regs_per_stage` pipeline registers.
pub fn streaming_kernel(lanes: u64, depth: u64) -> Usage {
    Usage {
        alms: 420 * lanes + 110 * depth,
        regs: 1_200 * lanes + 300 * depth,
        m20k: 6 * lanes + depth / 2,
        dsps: 2 * lanes,
    }
}

/// Board-support (DDR controllers, PCIe, host interface) static region.
pub fn bsp_static() -> Usage {
    Usage { alms: 92_000, regs: 210_000, m20k: 480, dsps: 0 }
}

/// The full FeCaffe bitstream inventory (paper Table 2's 25 kernels).
pub fn full_bitstream() -> (Usage, Usage, Usage) {
    // Tile choices matching the paper's achieved utilization: gemm 32×32
    // tiles (1037 DSPs ⇒ 32*32=1024 lanes + control), gemv 128-wide tile
    // with 8-lane SIMD.
    let gemm = gemm_kernel(32, 32, 64);
    let gemv = gemv_kernel(128, 8);
    let mut total = bsp_static();
    total.add(&gemm);
    total.add(&gemv);
    // 23 further streaming kernels (pool ×4, relu ×2, lrn ×3, dropout ×2,
    // softmax ×3, im2col, col2im, concat, split, bias, add, axpy, scal,
    // asum, solver-update) — lane counts by bandwidth demand.
    let heavy = ["im2col", "col2im", "max_pool_f", "max_pool_b", "lrn_diff"];
    let medium = ["ave_pool_f", "ave_pool_b", "lrn_scale", "lrn_output", "solver"];
    for _ in heavy {
        total.add(&streaming_kernel(16, 160));
    }
    for _ in medium {
        total.add(&streaming_kernel(8, 120));
    }
    for _ in 0..13 {
        // light elementwise kernels
        total.add(&streaming_kernel(4, 80));
    }
    (gemm, gemv, total)
}

/// Percent helper for the table.
pub fn pct(part: u64, whole: u64) -> f64 {
    part as f64 / whole as f64 * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_matches_paper_scale() {
        // Paper Table 3: gemm = 107K ALMs (12%), 2338 M20K (20%), 1037 DSPs (18%)
        let g = gemm_kernel(32, 32, 64);
        assert_eq!(g.dsps, 1037);
        assert!((g.alms as f64 - 107_000.0).abs() / 107_000.0 < 0.15, "{}", g.alms);
        assert!((g.m20k as f64 - 2_338.0).abs() / 2_338.0 < 0.15, "{}", g.m20k);
    }

    #[test]
    fn gemv_matches_paper_scale() {
        // Paper Table 3: gemv = 49K ALMs, 756 M20K, 130 DSPs
        let g = gemv_kernel(128, 8);
        assert_eq!(g.dsps, 130);
        assert!((g.alms as f64 - 49_000.0).abs() / 49_000.0 < 0.2, "{}", g.alms);
        assert!((g.m20k as f64 - 756.0).abs() / 756.0 < 0.2, "{}", g.m20k);
    }

    #[test]
    fn total_matches_paper_scale() {
        // Paper Table 3: total 616K ALMs (66%), 5419 M20K (47%), 1796 DSPs (31%)
        let (_, _, t) = full_bitstream();
        assert!((pct(t.alms, S10_ALMS) - 66.0).abs() < 8.0, "alms {}%", pct(t.alms, S10_ALMS));
        assert!((pct(t.m20k, S10_M20K) - 47.0).abs() < 8.0, "m20k {}%", pct(t.m20k, S10_M20K));
        assert!((pct(t.dsps, S10_DSPS) - 31.0).abs() < 5.0, "dsps {}%", pct(t.dsps, S10_DSPS));
    }

    #[test]
    fn more_lanes_cost_more() {
        let small = streaming_kernel(4, 80);
        let big = streaming_kernel(16, 80);
        assert!(big.alms > small.alms && big.dsps > small.dsps);
    }
}
