//! End-to-end validation driver (DESIGN.md E6): train LeNet on the
//! procedural digit dataset for several hundred iterations on the
//! simulated FPGA with real kernel execution, log the loss curve and
//! test accuracy, snapshot, and report simulated device time.
//!
//!     cargo run --release --example train_lenet [iters] [--cpu]
//!
//! The recorded run lives in EXPERIMENTS.md §E6.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::Device;
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::runtime::PjrtBackend;
use fecaffe::solver::{snapshot, Solver};
use fecaffe::zoo;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let iters: usize = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(300);
    let use_cpu = args.iter().any(|a| a == "--cpu");

    let mut dev: Box<dyn Device> = if use_cpu {
        println!("device: cpu (fallback path)");
        Box::new(CpuDevice::new())
    } else {
        let mut d = FpgaSimDevice::new();
        match PjrtBackend::auto() {
            Some(b) => {
                println!("device: fpga-sim + PJRT artifacts");
                d = d.with_backend(Box::new(b));
            }
            None => println!("device: fpga-sim (native math; run `make artifacts` for PJRT)"),
        }
        Box::new(d)
    };

    let batch = 64;
    let param = zoo::by_name("lenet", batch)?;
    let net = Net::from_param(&param, Phase::Train, dev.as_mut())?;
    println!(
        "LeNet: {} parameters, batch {batch}, {iters} iterations, SGD(inv)",
        net.num_parameters()
    );
    let mut sp = zoo::default_solver("lenet")?;
    sp.display = 0; // we log ourselves
    sp.max_iter = iters;
    let mut solver = Solver::new(sp, net, dev.as_mut())?;

    let wall = std::time::Instant::now();
    for i in 0..iters {
        let loss = solver.step(dev.as_mut())?;
        if i % 20 == 0 || i + 1 == iters {
            println!("iter {i:>4}  loss {loss:.4}  lr {:.5}", solver.learning_rate()?);
        }
    }
    let wall = wall.elapsed();

    // Loss-curve verdict: first-20 mean vs last-20 mean.
    let h = &solver.loss_history;
    let head: f32 = h.iter().take(20).sum::<f32>() / 20.0_f32.min(h.len() as f32);
    let tail: f32 = h.iter().rev().take(20).sum::<f32>() / 20.0_f32.min(h.len() as f32);
    println!("\nloss curve: {head:.3} (first 20) -> {tail:.3} (last 20)");
    anyhow::ensure!(
        tail < head * 0.5,
        "training did not converge (loss {head:.3} -> {tail:.3})"
    );

    // Evaluate on a fresh TEST-phase net sharing nothing but the weights
    // (weights are copied through a snapshot round-trip).
    let snap = std::env::temp_dir().join("lenet_e2e.fecaffemodel");
    snapshot::save(&snap, &solver, dev.as_mut())?;
    println!("snapshot written: {}", snap.display());

    // Accuracy on held-out synthetic digits using the TEST-phase net.
    let test_param = zoo::by_name("lenet", 100)?;
    let mut test_net = Net::from_param(&test_param, Phase::Test, dev.as_mut())?;
    // Copy trained weights in (same layer order ⇒ same param order).
    for (src, dst) in solver.net.params().iter().zip(test_net.params().iter()) {
        let w = src.blob.borrow_mut().data_vec(dev.as_mut());
        dst.blob.borrow_mut().set_data(dev.as_mut(), &w);
    }
    test_net.forward(dev.as_mut())?;
    let acc = test_net
        .blob("accuracy")
        .expect("test net has accuracy layer")
        .borrow_mut()
        .data_vec(dev.as_mut())[0];
    println!("test accuracy (100 fresh digits): {:.1}%", acc * 100.0);
    anyhow::ensure!(acc > 0.6, "accuracy too low: {acc}");

    println!(
        "\nwall time: {:.1}s ({:.2} iters/s)",
        wall.as_secs_f64(),
        iters as f64 / wall.as_secs_f64()
    );
    if let Some(ns) = dev.sim_clock_ns() {
        println!(
            "simulated S10 device time: {:.2} s ({:.1} ms/iter)",
            ns as f64 / 1e9,
            ns as f64 / 1e6 / iters as f64
        );
    }
    println!("E2E OK");
    Ok(())
}
