//! Layer classes — the paper's L3 "class layer".
//!
//! Every layer implements [`Layer`]: `setup` initializes learnable
//! blobs, `reshape` propagates shapes (and may run again whenever the
//! batch changes — dynamic-shape serving), `forward`/`backward` enqueue kernels on the
//! [`Device`] through the same fine-grained calls the paper's wrapper
//! layer makes (one `im2col` per image, one `gemm` per group, one `Bias`
//! per conv, ...), so kernel instance counts in the profiler match the
//! paper's Table 2 accounting scheme.

pub mod conv;
pub mod pooling;
pub mod relu;
pub mod lrn;
pub mod inner_product;
pub mod softmax;
pub mod softmax_loss;
pub mod accuracy;
pub mod dropout;
pub mod concat;
pub mod split;
pub mod data;

use crate::blob::Blob;
use crate::device::Device;
use crate::proto::{LayerParameter, ParamSpec, Phase};
use std::cell::RefCell;
use std::rc::Rc;

/// Shared tensor handle (blobs are shared between layers and the net).
pub type SharedBlob = Rc<RefCell<Blob>>;

pub fn shared(blob: Blob) -> SharedBlob {
    Rc::new(RefCell::new(blob))
}

/// The layer interface (mirrors caffe::Layer).
///
/// Shape propagation is a first-class phase, split from execution like
/// Caffe's `Reshape`: `setup` runs once (validates wiring, creates and
/// initializes learnable blobs, then calls `reshape`), while `reshape`
/// may run again whenever a bottom's shape changed — it recomputes
/// cached geometry and re-shapes top blobs and internal activations
/// (grow-only, so repeated reshapes settle at the high-water allocation)
/// without ever touching learnable parameters. `Net::reshape_batch`
/// drives it through the whole DAG.
pub trait Layer {
    fn name(&self) -> &str;
    fn kind(&self) -> &'static str;

    /// One-time setup: validate, allocate + initialize learnable blobs,
    /// then propagate shapes (implementations end by calling
    /// [`Layer::reshape`]).
    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()>;

    /// Re-propagate shapes from the (possibly re-batched) bottoms to the
    /// tops and internal buffers. Must not reallocate or reinitialize
    /// learnable parameters; top/activation storage grows only.
    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()>;

    /// Compute tops from bottoms; returns this layer's weighted loss
    /// contribution (0 for non-loss layers).
    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32>;

    /// Compute bottom diffs (and param diffs) from top diffs.
    /// `prop_down[i]` gates gradient propagation to `bottoms[i]`.
    fn backward(
        &mut self,
        dev: &mut dyn Device,
        tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()>;

    /// Learnable parameter blobs (weights, biases).
    fn param_blobs(&self) -> Vec<SharedBlob> {
        Vec::new()
    }

    /// lr/decay multipliers aligned with `param_blobs` (padded with
    /// defaults by the net when absent).
    fn param_specs(&self) -> Vec<ParamSpec> {
        Vec::new()
    }

    /// True if this layer produces a loss (drives backward from here).
    fn is_loss(&self) -> bool {
        false
    }

    /// Whether backward needs to run at all (data layers: no).
    fn needs_backward(&self) -> bool {
        true
    }
}

/// One layer's timing sample, delivered to a [`LayerTimer`] during a
/// traced forward pass (`Net::forward_traced`). Offsets are relative
/// to the start of that pass; wall time is always present, simulated
/// device time only on devices with a sim clock (FPGA sim).
#[derive(Debug, Clone, Copy)]
pub struct LayerTiming<'a> {
    /// Position in the net's execution order.
    pub index: usize,
    pub name: &'a str,
    pub kind: &'static str,
    /// Wall-clock start offset, ns, from the start of the pass.
    pub wall_start_ns: u64,
    pub wall_ns: u64,
    /// Simulated-clock start offset from the start of the pass.
    pub sim_start_ns: Option<u64>,
    /// Simulated-clock advance across this layer. Per-layer durations
    /// telescope: each span runs from the previous layer's synchronize
    /// to this one's, so their sum equals the sim-clock advance of the
    /// whole pass (the invariant `fecaffe profile` checks).
    pub sim_ns: Option<u64>,
}

/// Per-layer timing hook for `Net::forward_traced` — how both the CPU
/// and FPGA-sim paths report per-layer wall/sim time to the
/// observability layer without the net knowing who is listening.
pub trait LayerTimer {
    fn record(&mut self, t: LayerTiming<'_>);
}

impl<F: for<'a> FnMut(LayerTiming<'a>)> LayerTimer for F {
    fn record(&mut self, t: LayerTiming<'_>) {
        self(t);
    }
}

/// Construct a layer from its prototxt definition (the layer registry).
pub fn create_layer(param: &LayerParameter, phase: Phase) -> anyhow::Result<Box<dyn Layer>> {
    let l: Box<dyn Layer> = match param.kind.as_str() {
        "Convolution" => Box::new(conv::ConvolutionLayer::new(param)?),
        "Pooling" => Box::new(pooling::PoolingLayer::new(param)?),
        "ReLU" => Box::new(relu::ReluLayer::new(param)),
        "LRN" => Box::new(lrn::LrnLayer::new(param)),
        "InnerProduct" => Box::new(inner_product::InnerProductLayer::new(param)?),
        "Softmax" => Box::new(softmax::SoftmaxLayer::new(param)),
        "SoftmaxWithLoss" => Box::new(softmax_loss::SoftmaxWithLossLayer::new(param)),
        "Accuracy" => Box::new(accuracy::AccuracyLayer::new(param)),
        "Dropout" => Box::new(dropout::DropoutLayer::new(param, phase)),
        "Concat" => Box::new(concat::ConcatLayer::new(param)),
        "Split" => Box::new(split::SplitLayer::new(param)),
        "SyntheticData" | "Data" => Box::new(data::SyntheticDataLayer::new(param, phase)?),
        other => anyhow::bail!("unknown layer type '{other}' (layer {})", param.name),
    };
    Ok(l)
}

/// Weight-filler dispatch shared by conv/ip layers.
pub(crate) fn fill_blob(
    blob: &mut Blob,
    dev: &mut dyn Device,
    filler: &crate::proto::FillerParameter,
    fan_in: usize,
    rng: &mut crate::util::prng::Pcg32,
) {
    let data = blob.data.host_data_mut(dev);
    match filler.kind.as_str() {
        "xavier" => rng.fill_xavier(data, fan_in),
        "gaussian" => rng.fill_gaussian(data, filler.mean, filler.std),
        "uniform" => rng.fill_uniform(data, filler.min, filler.max),
        _ => crate::math::set(data, filler.value),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::LayerParameter;

    #[test]
    fn registry_knows_all_simple_layers() {
        for kind in ["ReLU", "Softmax", "Concat", "Split", "Accuracy"] {
            let p = LayerParameter::new("x", kind);
            assert!(create_layer(&p, Phase::Train).is_ok(), "{kind}");
        }
    }

    #[test]
    fn registry_rejects_unknown() {
        let p = LayerParameter::new("x", "FancyAttention");
        assert!(create_layer(&p, Phase::Train).is_err());
    }
}
