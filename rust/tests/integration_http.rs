//! Integration: the HTTP front-end's status-code contract and the
//! multi-model router, over real sockets.
//!
//! * predict → 200 with one softmax row per instance, for two models
//!   served concurrently from one process;
//! * admission-queue overflow → 429 (pinned with a deliberately slow
//!   model so the pipeline stays saturated while requests arrive);
//! * wrong sample length / bad JSON → 400, unknown model → 404,
//!   wrong method → 405;
//! * engines shut down → 503; `POST /admin/shutdown` drains cleanly.

use fecaffe::proto::parse_net;
use fecaffe::serve::http::predict_body;
use fecaffe::serve::{
    http_request, DeviceKind, Engine, EngineConfig, HttpClient, HttpConfig, HttpServer,
    ModelRouter,
};
use fecaffe::util::json::Json;
use fecaffe::zoo;
use std::sync::Arc;
use std::time::Duration;

fn lenet_engine() -> Engine {
    let param = zoo::by_name("lenet", 1).unwrap();
    Engine::new(
        &param,
        EngineConfig {
            workers: 1,
            max_batch: 4,
            max_linger: Duration::from_micros(500),
            queue_capacity: 64,
            device: DeviceKind::Cpu,
            intra_op_threads: 1,
            trace_sample: 0,
            ..EngineConfig::default()
        },
    )
    .unwrap()
}

fn parse_json(body: &[u8]) -> Json {
    Json::parse(std::str::from_utf8(body).unwrap()).unwrap()
}

#[test]
fn two_models_predict_healthz_metrics_inventory() {
    // Two engines served concurrently from one process (the router's
    // whole point); both happen to be LeNet so the test stays fast.
    let router = Arc::new(
        ModelRouter::from_engines(vec![
            ("lenet-a".to_string(), lenet_engine()),
            ("lenet-b".to_string(), lenet_engine()),
        ])
        .unwrap(),
    );
    let server = HttpServer::bind("127.0.0.1:0", router, HttpConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    // healthz: JSON with overall status, uptime and per-model health.
    let (status, body) = http_request(&addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(status, 200);
    let health = parse_json(&body);
    assert_eq!(health.get("status").unwrap().as_str().unwrap(), "ok");
    assert!(health.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    let entries = health.get("models").unwrap().as_arr().unwrap();
    assert_eq!(entries.len(), 2);
    for m in entries {
        assert_eq!(m.get("weights_version").unwrap().as_usize().unwrap(), 0);
        assert!(m.get("healthy_workers").unwrap().as_usize().unwrap() >= 1);
        assert_eq!(
            m.get("workers").unwrap().as_usize(),
            m.get("healthy_workers").unwrap().as_usize()
        );
    }

    // Inventory lists both models with LeNet's schema.
    let (status, body) = http_request(&addr, "GET", "/v1/models", b"").unwrap();
    assert_eq!(status, 200);
    let inv = parse_json(&body);
    let models = inv.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 2);
    for m in models {
        assert_eq!(m.get("sample_len").unwrap().as_usize().unwrap(), 28 * 28);
        assert_eq!(m.get("output_len").unwrap().as_usize().unwrap(), 10);
    }

    // Concurrent predicts against both models on persistent
    // connections: every response is one softmax row per instance.
    let handles: Vec<_> = ["lenet-a", "lenet-b"]
        .into_iter()
        .map(|model| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = HttpClient::connect(&addr).unwrap();
                let path = format!("/v1/models/{model}:predict");
                for k in 0..3 {
                    let body =
                        predict_body(&[vec![0.25; 28 * 28], vec![0.5; 28 * 28]]);
                    let (status, resp) =
                        client.request("POST", &path, body.as_bytes()).unwrap();
                    assert_eq!(status, 200, "{model} request {k}");
                    let json = parse_json(&resp);
                    assert_eq!(json.get("model").unwrap().as_str().unwrap(), model);
                    let preds = json.get("predictions").unwrap().as_arr().unwrap();
                    assert_eq!(preds.len(), 2);
                    for row in preds {
                        let row = row.as_arr().unwrap();
                        assert_eq!(row.len(), 10);
                        let sum: f64 = row.iter().map(|v| v.as_f64().unwrap()).sum();
                        assert!((sum - 1.0).abs() < 1e-3, "softmax row sum {sum}");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // Metrics report both models, with completions recorded.
    let (status, body) = http_request(&addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(status, 200);
    let metrics = parse_json(&body);
    for model in ["lenet-a", "lenet-b"] {
        let m = metrics.get(model).unwrap();
        assert_eq!(m.get("completed").unwrap().as_usize().unwrap(), 6);
        assert_eq!(m.get("failed").unwrap().as_usize().unwrap(), 0);
    }

    server.shutdown();
}

#[test]
fn bad_requests_map_to_4xx() {
    let router = Arc::new(
        ModelRouter::from_engines(vec![("lenet".to_string(), lenet_engine())]).unwrap(),
    );
    let server = HttpServer::bind("127.0.0.1:0", router, HttpConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let predict = "/v1/models/lenet:predict";

    // Wrong sample length → the engine's BadRequest → 400.
    let (status, body) =
        http_request(&addr, "POST", predict, predict_body(&[vec![0.1; 3]]).as_bytes())
            .unwrap();
    assert_eq!(status, 400);
    let err = parse_json(&body);
    assert!(
        err.get("error").unwrap().as_str().unwrap().contains("bad request"),
        "{err:?}"
    );

    // Malformed JSON → 400.
    let (status, _) = http_request(&addr, "POST", predict, b"{not json").unwrap();
    assert_eq!(status, 400);
    // Valid JSON, wrong shape → 400.
    let (status, _) = http_request(&addr, "POST", predict, b"{\"instances\": 5}").unwrap();
    assert_eq!(status, 400);
    let (status, _) = http_request(&addr, "POST", predict, b"{\"instances\": []}").unwrap();
    assert_eq!(status, 400);

    // Unknown model → 404; unknown action/path → 404; GET predict → 405.
    let (status, _) = http_request(
        &addr,
        "POST",
        "/v1/models/resnet:predict",
        predict_body(&[vec![0.0; 784]]).as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 404);
    let (status, _) = http_request(&addr, "POST", "/v1/models/lenet:explain", b"{}").unwrap();
    assert_eq!(status, 404);
    let (status, _) = http_request(&addr, "GET", "/nope", b"").unwrap();
    assert_eq!(status, 404);
    let (status, _) = http_request(&addr, "GET", predict, b"").unwrap();
    assert_eq!(status, 405);

    server.shutdown();
}

/// Saturating the admission pipeline returns 429, not an error or a
/// hang. The model is deliberately slow (three wide fully-connected
/// layers) and the queue tiny, so the pipeline — queue(1) + batcher(1)
/// + dispatch(2) + worker(1) — is still full when the last of ten
/// parallel requests arrives.
#[test]
fn full_admission_queue_returns_429() {
    const SLOW_NET: &str = r#"
name: "slowmlp"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 1 channels: 1 height: 64 width: 64 num_classes: 10 source: "digits" seed: 1 } }
layer { name: "fc1" type: "InnerProduct" bottom: "data" top: "fc1"
        inner_product_param { num_output: 2048 weight_filler { type: "xavier" } } }
layer { name: "relu1" type: "ReLU" bottom: "fc1" top: "fc1" }
layer { name: "fc2" type: "InnerProduct" bottom: "fc1" top: "fc2"
        inner_product_param { num_output: 2048 weight_filler { type: "xavier" } } }
layer { name: "relu2" type: "ReLU" bottom: "fc2" top: "fc2" }
layer { name: "fc3" type: "InnerProduct" bottom: "fc2" top: "fc3"
        inner_product_param { num_output: 10 weight_filler { type: "xavier" } } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc3" bottom: "label" top: "loss" }
"#;
    let netp = parse_net(SLOW_NET).unwrap();
    let engine = Engine::new(
        &netp,
        EngineConfig {
            workers: 1,
            max_batch: 1,
            max_linger: Duration::from_micros(100),
            queue_capacity: 1,
            device: DeviceKind::Cpu,
            intra_op_threads: 1,
            trace_sample: 0,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let router =
        Arc::new(ModelRouter::from_engines(vec![("slowmlp".to_string(), engine)]).unwrap());
    let server = HttpServer::bind("127.0.0.1:0", router, HttpConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let body = predict_body(&[vec![0.1; 64 * 64]]);

    let statuses: Vec<u16> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..10)
            .map(|_| {
                let addr = &addr;
                let body = &body;
                scope.spawn(move || {
                    http_request(
                        addr,
                        "POST",
                        "/v1/models/slowmlp:predict",
                        body.as_bytes(),
                    )
                    .unwrap()
                    .0
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(
        statuses.iter().any(|&s| s == 429),
        "expected at least one 429 from 10 parallel requests, got {statuses:?}"
    );
    assert!(
        statuses.iter().any(|&s| s == 200),
        "admitted requests must still complete, got {statuses:?}"
    );
    assert!(
        statuses.iter().all(|&s| s == 200 || s == 429),
        "only 200/429 expected under pure overload, got {statuses:?}"
    );
    server.shutdown();
}

/// `POST /admin/models/<name>:publish` — the weight hot-swap endpoint:
/// loads a FEWSNAP1 file, publishes it, and the predict / metrics /
/// inventory surfaces all report the new `weights_version`. The error
/// contract (400 bad file, 404 unknown model/action, 405 wrong method,
/// 409 stale version) is pinned here and in the README.
#[test]
fn publish_endpoint_hot_swaps_weights() {
    use fecaffe::device::cpu::CpuDevice;
    use fecaffe::net::Net;
    use fecaffe::proto::Phase;

    let router = Arc::new(
        ModelRouter::from_engines(vec![("lenet".to_string(), lenet_engine())]).unwrap(),
    );
    let server = HttpServer::bind("127.0.0.1:0", router, HttpConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    // Baseline predict: engine-initialized weights are version 0.
    let body = predict_body(&[vec![0.25; 784]]);
    let (status, resp) =
        http_request(&addr, "POST", "/v1/models/lenet:predict", body.as_bytes()).unwrap();
    assert_eq!(status, 200);
    let v0 = parse_json(&resp);
    assert_eq!(v0.get("weights_version").unwrap().as_usize().unwrap(), 0);

    // Write a versioned snapshot file and publish it into the engine.
    let snap_path = std::env::temp_dir().join("fecaffe_http_publish_test.fewts");
    let param = zoo::by_name("lenet", 1).unwrap();
    let mut dev = CpuDevice::new();
    let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    net.share_weights(&mut dev)
        .with_version(3)
        .with_tag("golden")
        .save(&snap_path)
        .unwrap();
    let mut pb = Json::obj();
    pb.set("path", Json::str(snap_path.to_str().unwrap()));
    let (status, resp) = http_request(
        &addr,
        "POST",
        "/admin/models/lenet:publish",
        pb.to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    let j = parse_json(&resp);
    assert_eq!(j.get("model").unwrap().as_str().unwrap(), "lenet");
    assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 3);
    assert_eq!(j.get("tag").unwrap().as_str().unwrap(), "golden");

    // Predict now reports the new version (publish returned before the
    // submit, so the worker adopted at the batch boundary in between).
    let (status, resp) =
        http_request(&addr, "POST", "/v1/models/lenet:predict", body.as_bytes()).unwrap();
    assert_eq!(status, 200);
    assert_eq!(
        parse_json(&resp).get("weights_version").unwrap().as_usize().unwrap(),
        3
    );

    // Metrics and the model inventory surface the version too.
    let (_, m) = http_request(&addr, "GET", "/metrics", b"").unwrap();
    let m = parse_json(&m);
    let lenet = m.get("lenet").unwrap();
    assert_eq!(lenet.get("weights_version").unwrap().as_usize().unwrap(), 3);
    assert_eq!(lenet.get("publishes").unwrap().as_usize().unwrap(), 1);
    let (_, inv) = http_request(&addr, "GET", "/v1/models", b"").unwrap();
    let inv = parse_json(&inv);
    let model = &inv.get("models").unwrap().as_arr().unwrap()[0];
    assert_eq!(model.get("weights_version").unwrap().as_usize().unwrap(), 3);

    // Error contract.
    let (status, _) = http_request(
        &addr,
        "POST",
        "/admin/models/lenet:publish",
        pb.to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 409, "republishing version 3 must be stale");
    let (status, _) = http_request(
        &addr,
        "POST",
        "/admin/models/resnet:publish",
        pb.to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 404);
    let mut bad = Json::obj();
    bad.set("path", Json::str("/nonexistent/weights.fewts"));
    let (status, _) = http_request(
        &addr,
        "POST",
        "/admin/models/lenet:publish",
        bad.to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 400);
    let (status, _) =
        http_request(&addr, "POST", "/admin/models/lenet:publish", b"{}").unwrap();
    assert_eq!(status, 400, "missing path field");
    let mut neg = Json::obj();
    neg.set("path", Json::str(snap_path.to_str().unwrap()));
    neg.set("version", Json::num(-3.0));
    let (status, _) = http_request(
        &addr,
        "POST",
        "/admin/models/lenet:publish",
        neg.to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 400, "negative version must be rejected, not saturated to 0");
    let (status, _) =
        http_request(&addr, "GET", "/admin/models/lenet:publish", b"").unwrap();
    assert_eq!(status, 405);
    let (status, _) =
        http_request(&addr, "POST", "/admin/models/lenet:republish", b"{}").unwrap();
    assert_eq!(status, 404);

    server.shutdown();
    let _ = std::fs::remove_file(snap_path);
}

#[test]
fn engines_down_returns_503_and_admin_shutdown_drains() {
    let router = Arc::new(
        ModelRouter::from_engines(vec![("lenet".to_string(), lenet_engine())]).unwrap(),
    );
    let server =
        HttpServer::bind("127.0.0.1:0", router.clone(), HttpConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    // A healthy predict first, proving the 503 below is the shutdown.
    let ok_body = predict_body(&[vec![0.5; 784]]);
    let (status, _) =
        http_request(&addr, "POST", "/v1/models/lenet:predict", ok_body.as_bytes()).unwrap();
    assert_eq!(status, 200);

    // Stop the engines but keep the HTTP layer up: predict → 503,
    // health endpoints still answer.
    router.shutdown();
    let (status, body) =
        http_request(&addr, "POST", "/v1/models/lenet:predict", ok_body.as_bytes()).unwrap();
    assert_eq!(status, 503);
    assert!(
        parse_json(&body)
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("shutting down"),
        "503 body should name the shutdown"
    );
    let (status, _) = http_request(&addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(status, 200);

    // The SIGTERM equivalent: POST /admin/shutdown flips the flag the
    // server process parks on, then shutdown() drains.
    assert!(!server.shutdown_requested());
    let (status, _) = http_request(&addr, "POST", "/admin/shutdown", b"").unwrap();
    assert_eq!(status, 200);
    server.wait_shutdown(); // returns because the flag is set
    assert!(server.shutdown_requested());
    server.shutdown();

    // Listener is gone: a fresh connection must fail.
    assert!(http_request(&addr, "GET", "/healthz", b"").is_err());
}
