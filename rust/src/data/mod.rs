//! Synthetic datasets (DESIGN.md substitution: no MNIST/ImageNet files
//! offline).
//!
//! * [`digits`] — a procedural MNIST stand-in: 28×28 renderings of a 5×7
//!   bitmap font with random shift, scale jitter and noise. LeNet
//!   genuinely *learns* on it (the E2E example drives loss from ~2.3 to
//!   <0.3), which is what the training-correctness claim needs.
//! * [`imagenet`] — label-conditioned Gaussian-blob images at ImageNet
//!   shapes for throughput/epoch-time workloads where only shapes and
//!   label-consistency matter.

pub mod digits;
pub mod imagenet;

use crate::util::prng::Pcg32;

/// A batch: NCHW images + integer labels (as f32, Caffe-style).
pub struct Batch {
    pub data: Vec<f32>,
    pub labels: Vec<f32>,
}

/// Common interface for synthetic sources.
pub trait DataSource {
    /// (channels, height, width)
    fn shape(&self) -> (usize, usize, usize);
    fn num_classes(&self) -> usize;
    fn sample(&self, rng: &mut Pcg32) -> (Vec<f32>, usize);

    fn batch(&self, rng: &mut Pcg32, batch_size: usize) -> Batch {
        let (c, h, w) = self.shape();
        let mut data = Vec::with_capacity(batch_size * c * h * w);
        let mut labels = Vec::with_capacity(batch_size);
        for _ in 0..batch_size {
            let (img, label) = self.sample(rng);
            debug_assert_eq!(img.len(), c * h * w);
            data.extend_from_slice(&img);
            labels.push(label as f32);
        }
        Batch { data, labels }
    }
}

/// Factory by source name (prototxt `data_param { source: ... }`).
pub fn create_source(
    source: &str,
    channels: usize,
    height: usize,
    width: usize,
    num_classes: usize,
) -> anyhow::Result<Box<dyn DataSource>> {
    match source {
        "digits" => Ok(Box::new(digits::Digits::with_classes(height, width, num_classes))),
        "imagenet" => Ok(Box::new(imagenet::ImagenetSynth::new(
            channels,
            height,
            width,
            num_classes,
        ))),
        other => anyhow::bail!("unknown synthetic data source '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_and_batch_shapes() {
        let mut rng = Pcg32::new(1);
        let src = create_source("digits", 1, 28, 28, 10).unwrap();
        let b = src.batch(&mut rng, 4);
        assert_eq!(b.data.len(), 4 * 28 * 28);
        assert_eq!(b.labels.len(), 4);
        assert!(b.labels.iter().all(|&l| (0.0..10.0).contains(&l)));
        assert!(create_source("nope", 1, 1, 1, 1).is_err());
    }
}
