//! Blob + SyncedMem: the paper's §3.3 memory synchronization mechanism.
//!
//! Caffe's `syncedmem` has four states (uninitialized / CPU / GPU /
//! synced); FeCaffe adds an **FPGA** head state so data can live in the
//! accelerator's DDR and only cross PCIe when a consumer on the other
//! side asks for it. This module reproduces that state machine over the
//! [`crate::device::Device`] abstraction: `AtDevice` means "head copy is
//! in FPGA DDR" when the device is the FPGA simulator (the PCIe billing
//! happens inside `Device::write/read`), and plain slab memory on the CPU
//! fallback device.
//!
//! A [`Blob`] is Caffe's NCHW tensor with separate `data` and `diff`
//! (gradient) SyncedMems.

use crate::device::{BufId, Device};
use std::sync::Arc;

/// Host-side storage of a [`SyncedMem`]: owned by this blob, or an
/// `Arc` shared read-only across net replicas (weight sharing for the
/// serving engine — see `net::WeightSnapshot`). Shared buffers detach
/// copy-on-write the moment someone asks for mutable host access, so
/// training a replica never writes through another replica's weights.
#[derive(Debug, Clone)]
enum HostBuf {
    Owned(Vec<f32>),
    Shared(Arc<Vec<f32>>),
}

impl HostBuf {
    fn len(&self) -> usize {
        match self {
            HostBuf::Owned(v) => v.len(),
            HostBuf::Shared(a) => a.len(),
        }
    }

    fn as_slice(&self) -> &[f32] {
        match self {
            HostBuf::Owned(v) => v,
            HostBuf::Shared(a) => a,
        }
    }
}

/// Head-of-data location. Mirrors paper Figure 3 (top).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemState {
    /// No data written yet anywhere.
    Uninit,
    /// Freshest copy on the host.
    AtHost,
    /// Freshest copy in device (FPGA DDR) memory.
    AtDevice,
    /// Host and device copies agree.
    Synced,
}

/// One logical buffer kept coherent between host memory and device memory.
#[derive(Debug)]
pub struct SyncedMem {
    len: usize,
    host: HostBuf,
    dev: Option<BufId>,
    state: MemState,
}

impl SyncedMem {
    pub fn new(len: usize) -> SyncedMem {
        SyncedMem {
            len,
            host: HostBuf::Owned(Vec::new()),
            dev: None,
            state: MemState::Uninit,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn state(&self) -> MemState {
        self.state
    }

    /// Resize, dropping contents (device buffer released lazily on next
    /// device access; `release` frees it eagerly).
    pub fn resize(&mut self, dev: &mut dyn Device, len: usize) {
        if len != self.len {
            self.len = len;
            self.host = HostBuf::Owned(Vec::new());
            if let Some(id) = self.dev.take() {
                dev.free(id);
            }
            self.state = MemState::Uninit;
        }
    }

    fn ensure_host(&mut self) {
        if self.host.len() != self.len {
            self.host = HostBuf::Owned(vec![0.0; self.len]);
        }
    }

    /// Detach from a shared host buffer (copy-on-write).
    fn make_owned(&mut self) {
        if let HostBuf::Shared(a) = &self.host {
            self.host = HostBuf::Owned(a.as_ref().clone());
        }
    }

    /// Owned host buffer of the right length whose contents are about to
    /// be fully overwritten (device readback): skips the copy-on-write
    /// clone a `make_owned` would pay on a shared buffer.
    fn ensure_owned_for_overwrite(&mut self) {
        if self.host.len() != self.len || matches!(self.host, HostBuf::Shared(_)) {
            self.host = HostBuf::Owned(vec![0.0; self.len]);
        }
    }

    fn ensure_dev(&mut self, dev: &mut dyn Device) -> BufId {
        match self.dev {
            Some(id) => id,
            None => {
                let id = dev.alloc(self.len).expect("device allocation failed");
                self.dev = Some(id);
                id
            }
        }
    }

    /// `to_cpu` in the paper: make the host copy fresh.
    pub fn host_data(&mut self, dev: &mut dyn Device) -> &[f32] {
        self.sync_to_host(dev);
        self.host.as_slice()
    }

    /// Mutable host access: head moves to host (detaching from a shared
    /// buffer first, so replicas never write through each other).
    pub fn host_data_mut(&mut self, dev: &mut dyn Device) -> &mut [f32] {
        self.sync_to_host(dev);
        self.make_owned();
        self.state = MemState::AtHost;
        match &mut self.host {
            HostBuf::Owned(v) => v,
            HostBuf::Shared(_) => unreachable!("make_owned detached"),
        }
    }

    /// Snapshot the host copy as a shared (`Arc`) buffer. Subsequent
    /// replicas can [`SyncedMem::adopt_shared`] it without copying; this
    /// mem keeps using the same storage (read-only until the next
    /// mutable access detaches it).
    pub fn share_host(&mut self, dev: &mut dyn Device) -> Arc<Vec<f32>> {
        self.sync_to_host(dev);
        if let HostBuf::Owned(v) = &mut self.host {
            let arc = Arc::new(std::mem::take(v));
            self.host = HostBuf::Shared(arc);
        }
        match &self.host {
            HostBuf::Shared(a) => a.clone(),
            HostBuf::Owned(_) => unreachable!("just converted to shared"),
        }
    }

    /// Attach a shared host buffer (replica weight adoption). The head
    /// moves to the host; any stale device copy is released and will be
    /// re-uploaded on the next device access.
    pub fn adopt_shared(
        &mut self,
        dev: &mut dyn Device,
        data: Arc<Vec<f32>>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            data.len() == self.len,
            "adopt_shared: buffer has {} elements, mem expects {}",
            data.len(),
            self.len
        );
        if let Some(id) = self.dev.take() {
            dev.free(id);
        }
        self.host = HostBuf::Shared(data);
        self.state = MemState::AtHost;
        Ok(())
    }

    /// True while the host copy is an `Arc` shared with other mems.
    pub fn is_shared(&self) -> bool {
        matches!(self.host, HostBuf::Shared(_))
    }

    /// `to_fpga` in the paper: make the device copy fresh, return its id.
    pub fn dev_data(&mut self, dev: &mut dyn Device) -> BufId {
        self.sync_to_dev(dev);
        self.dev.unwrap()
    }

    /// Device copy that will be overwritten by a kernel: head moves to
    /// device without paying an upload when host data isn't fresh anyway.
    pub fn dev_data_mut(&mut self, dev: &mut dyn Device) -> BufId {
        let id = self.ensure_dev(dev);
        self.state = MemState::AtDevice;
        id
    }

    /// Device copy that a kernel will read *and* write (accumulating
    /// gradients, in-place ops): sync to device first, then mark the head
    /// at the device.
    pub fn dev_data_rw(&mut self, dev: &mut dyn Device) -> BufId {
        self.sync_to_dev(dev);
        self.state = MemState::AtDevice;
        self.dev.unwrap()
    }

    fn sync_to_host(&mut self, dev: &mut dyn Device) {
        match self.state {
            MemState::Uninit => {
                self.ensure_host();
                self.state = MemState::AtHost;
            }
            MemState::AtDevice => {
                self.ensure_owned_for_overwrite();
                let id = self.dev.expect("AtDevice without device buffer");
                match &mut self.host {
                    HostBuf::Owned(v) => dev.read(id, v),
                    HostBuf::Shared(_) => unreachable!("ensure_owned_for_overwrite"),
                }
                self.state = MemState::Synced;
            }
            MemState::AtHost | MemState::Synced => self.ensure_host(),
        }
    }

    fn sync_to_dev(&mut self, dev: &mut dyn Device) {
        match self.state {
            MemState::Uninit => {
                // Allocate and zero-fill on device (Caffe zero-initializes).
                self.ensure_host();
                let id = self.ensure_dev(dev);
                dev.write(id, self.host.as_slice());
                self.state = MemState::Synced;
            }
            MemState::AtHost => {
                let id = self.ensure_dev(dev);
                dev.write(id, self.host.as_slice());
                self.state = MemState::Synced;
            }
            MemState::AtDevice | MemState::Synced => {
                self.ensure_dev(dev);
            }
        }
    }

    /// Read the first `out.len()` elements back to the host without
    /// syncing (or billing PCIe for) the rest of the buffer. Used by the
    /// serving worker to read exactly the filled rows of a grow-only
    /// output blob whose allocation is sized for the largest batch it
    /// has ever run. Does not move the head-of-data state.
    pub fn read_prefix(&mut self, dev: &mut dyn Device, out: &mut [f32]) {
        assert!(
            out.len() <= self.len,
            "read_prefix: asked for {} of {} elements",
            out.len(),
            self.len
        );
        if self.state == MemState::AtDevice {
            dev.read(self.dev.expect("AtDevice without device buffer"), out);
        } else {
            self.sync_to_host(dev); // host already fresh (or zero-filled)
            out.copy_from_slice(&self.host.as_slice()[..out.len()]);
        }
    }

    /// Release the device-side buffer (keeps host copy if fresh).
    pub fn release_dev(&mut self, dev: &mut dyn Device) {
        if let Some(id) = self.dev.take() {
            if self.state == MemState::AtDevice {
                self.ensure_owned_for_overwrite();
                match &mut self.host {
                    HostBuf::Owned(v) => dev.read(id, v),
                    HostBuf::Shared(_) => unreachable!("ensure_owned_for_overwrite"),
                }
                self.state = MemState::AtHost;
            } else if self.state == MemState::Synced {
                self.state = MemState::AtHost;
            }
            dev.free(id);
        }
    }
}

/// Caffe's 4-D tensor: data + gradient, NCHW.
#[derive(Debug)]
pub struct Blob {
    pub name: String,
    shape: Vec<usize>,
    pub data: SyncedMem,
    pub diff: SyncedMem,
}

impl Blob {
    pub fn new(name: &str, shape: &[usize]) -> Blob {
        let count = shape.iter().product();
        Blob {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: SyncedMem::new(count),
            diff: SyncedMem::new(count),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn count(&self) -> usize {
        self.shape.iter().product()
    }

    /// NCHW accessors with Caffe's convention that missing trailing axes
    /// are size 1.
    pub fn num(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }
    pub fn channels(&self) -> usize {
        *self.shape.get(1).unwrap_or(&1)
    }
    pub fn height(&self) -> usize {
        *self.shape.get(2).unwrap_or(&1)
    }
    pub fn width(&self) -> usize {
        *self.shape.get(3).unwrap_or(&1)
    }

    /// Exact reshape (training semantics): storage is resized to the new
    /// element count, contents are dropped, and — audited for the FPGA
    /// DDR budget — a shrink releases the old oversized device buffer
    /// immediately (`SyncedMem::resize` frees the `BufId` eagerly), so
    /// nothing stale stays billed against device memory.
    pub fn reshape(&mut self, dev: &mut dyn Device, shape: &[usize]) {
        let count: usize = shape.iter().product();
        self.shape = shape.to_vec();
        self.data.resize(dev, count);
        self.diff.resize(dev, count);
    }

    /// Grow-only reshape (serving semantics): the logical shape changes,
    /// but storage is only reallocated when the new count exceeds the
    /// current allocation. A replica that cycles through batch sizes
    /// therefore settles at its high-water allocation and pays zero
    /// alloc/free churn per reshape; kernels are launched with shapes
    /// derived from `shape()`, so the tail beyond `count()` is never
    /// read. Contents are not preserved (activations are rewritten every
    /// forward).
    pub fn reshape_grow_only(&mut self, dev: &mut dyn Device, shape: &[usize]) {
        let count: usize = shape.iter().product();
        self.shape = shape.to_vec();
        if count > self.data.len() {
            self.data.resize(dev, count);
        }
        if count > self.diff.len() {
            self.diff.resize(dev, count);
        }
    }

    /// Bytes of one copy (f32).
    pub fn bytes(&self) -> usize {
        self.count() * 4
    }

    /// Set host data for the blob's current shape. On a grow-only blob
    /// the allocation may be larger than `count()`; only the logical
    /// prefix is written (the tail is never read by kernels).
    pub fn set_data(&mut self, dev: &mut dyn Device, values: &[f32]) {
        assert_eq!(values.len(), self.count(), "set_data length mismatch");
        self.data.host_data_mut(dev)[..values.len()].copy_from_slice(values);
    }

    pub fn set_diff(&mut self, dev: &mut dyn Device, values: &[f32]) {
        assert_eq!(values.len(), self.count(), "set_diff length mismatch");
        self.diff.host_data_mut(dev)[..values.len()].copy_from_slice(values);
    }

    /// Convenience for tests/debug: snapshot host data for the current
    /// shape (`count()` elements; a grow-only blob's spare tail is not
    /// included).
    pub fn data_vec(&mut self, dev: &mut dyn Device) -> Vec<f32> {
        let n = self.count();
        self.data.host_data(dev)[..n].to_vec()
    }

    pub fn diff_vec(&mut self, dev: &mut dyn Device) -> Vec<f32> {
        let n = self.count();
        self.diff.host_data(dev)[..n].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;

    #[test]
    fn state_machine_basics() {
        let mut dev = CpuDevice::new();
        let mut m = SyncedMem::new(4);
        assert_eq!(m.state(), MemState::Uninit);

        m.host_data_mut(&mut dev).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.state(), MemState::AtHost);

        let _id = m.dev_data(&mut dev);
        assert_eq!(m.state(), MemState::Synced);

        // Kernel writes device side → head at device.
        let id = m.dev_data_mut(&mut dev);
        assert_eq!(m.state(), MemState::AtDevice);
        dev.write(id, &[9.0, 9.0, 9.0, 9.0]);

        // Reading host syncs back.
        assert_eq!(m.host_data(&mut dev), &[9.0, 9.0, 9.0, 9.0]);
        assert_eq!(m.state(), MemState::Synced);
    }

    #[test]
    fn uninit_to_device_is_zeroed() {
        let mut dev = CpuDevice::new();
        let mut m = SyncedMem::new(3);
        let id = m.dev_data(&mut dev);
        let mut out = [7.0f32; 3];
        dev.read(id, &mut out);
        assert_eq!(out, [0.0, 0.0, 0.0]);
    }

    #[test]
    fn release_dev_preserves_data() {
        let mut dev = CpuDevice::new();
        let mut m = SyncedMem::new(2);
        let id = m.dev_data_mut(&mut dev);
        dev.write(id, &[5.0, 6.0]);
        m.release_dev(&mut dev);
        assert_eq!(m.state(), MemState::AtHost);
        assert_eq!(m.host_data(&mut dev), &[5.0, 6.0]);
    }

    #[test]
    fn resize_resets() {
        let mut dev = CpuDevice::new();
        let mut m = SyncedMem::new(2);
        m.host_data_mut(&mut dev)[0] = 1.0;
        m.resize(&mut dev, 5);
        assert_eq!(m.state(), MemState::Uninit);
        assert_eq!(m.len(), 5);
        assert_eq!(m.host_data(&mut dev), &[0.0; 5]);
    }

    #[test]
    fn blob_shape_helpers() {
        let b = Blob::new("x", &[2, 3, 4, 5]);
        assert_eq!(b.count(), 120);
        assert_eq!(
            (b.num(), b.channels(), b.height(), b.width()),
            (2, 3, 4, 5)
        );
        let fc = Blob::new("y", &[10, 20]);
        assert_eq!((fc.num(), fc.channels(), fc.height(), fc.width()), (10, 20, 1, 1));
    }

    #[test]
    fn share_and_adopt_host_buffers() {
        let mut dev = CpuDevice::new();
        let mut a = SyncedMem::new(3);
        a.host_data_mut(&mut dev).copy_from_slice(&[1.0, 2.0, 3.0]);
        let arc = a.share_host(&mut dev);
        assert!(a.is_shared());
        assert_eq!(a.host_data(&mut dev), &[1.0, 2.0, 3.0]);

        // A second mem adopts the same storage without copying.
        let mut b = SyncedMem::new(3);
        b.adopt_shared(&mut dev, arc.clone()).unwrap();
        assert!(b.is_shared());
        assert_eq!(b.state(), MemState::AtHost);
        assert_eq!(b.host_data(&mut dev), &[1.0, 2.0, 3.0]);

        // Length mismatch is rejected.
        let mut c = SyncedMem::new(2);
        assert!(c.adopt_shared(&mut dev, arc).is_err());
    }

    #[test]
    fn shared_host_detaches_copy_on_write() {
        let mut dev = CpuDevice::new();
        let mut a = SyncedMem::new(2);
        a.host_data_mut(&mut dev).copy_from_slice(&[5.0, 6.0]);
        let arc = a.share_host(&mut dev);
        let mut b = SyncedMem::new(2);
        b.adopt_shared(&mut dev, arc).unwrap();

        // Writing through b must not be visible to a (or the Arc).
        b.host_data_mut(&mut dev)[0] = 99.0;
        assert!(!b.is_shared(), "mutable access must detach");
        assert_eq!(b.host_data(&mut dev), &[99.0, 6.0]);
        assert_eq!(a.host_data(&mut dev), &[5.0, 6.0]);
    }

    #[test]
    fn adopted_buffer_uploads_to_device() {
        let mut dev = CpuDevice::new();
        let mut a = SyncedMem::new(2);
        a.host_data_mut(&mut dev).copy_from_slice(&[7.0, 8.0]);
        let arc = a.share_host(&mut dev);
        let mut b = SyncedMem::new(2);
        // Give b a device copy first; adoption must invalidate it.
        let id0 = b.dev_data_mut(&mut dev);
        dev.write(id0, &[0.0, 0.0]);
        b.adopt_shared(&mut dev, arc).unwrap();
        let id = b.dev_data(&mut dev);
        let mut out = [0.0f32; 2];
        dev.read(id, &mut out);
        assert_eq!(out, [7.0, 8.0]);
    }

    /// Satellite audit pin (ISSUE 5): an exact reshape to a smaller
    /// shape must release the oversized device buffer immediately — no
    /// stale DDR billing, no leaked `BufId` — and a later device access
    /// allocates a right-sized buffer.
    #[test]
    fn reshape_shrink_releases_device_buffer() {
        use crate::device::fpga::FpgaSimDevice;
        let mut dev = FpgaSimDevice::new();
        let mut b = Blob::new("x", &[4, 4]);
        b.set_data(&mut dev, &[1.0; 16]);
        let _ = b.data.dev_data(&mut dev);
        let used_big = dev.ddr().used();
        assert!(used_big >= 16 * 4, "device copy billed: {used_big}");

        b.reshape(&mut dev, &[2, 2]);
        assert_eq!(
            dev.ddr().used(),
            0,
            "shrink must free the old device buffer eagerly"
        );

        // Fresh device access allocates exactly the new size and is
        // zero-initialized (contents dropped by the exact reshape).
        let id = b.data.dev_data(&mut dev);
        assert_eq!(dev.ddr().used(), 4 * 4);
        let mut out = [9.0f32; 4];
        dev.read(id, &mut out);
        assert_eq!(out, [0.0; 4]);
    }

    /// Grow-only reshape keeps the high-water allocation across a
    /// shrink (zero realloc churn for serving replicas) while the
    /// logical shape and `count()` track the requested shape.
    #[test]
    fn grow_only_reshape_keeps_capacity() {
        use crate::device::fpga::FpgaSimDevice;
        let mut dev = FpgaSimDevice::new();
        let mut b = Blob::new("x", &[8, 2]);
        b.set_data(&mut dev, &[1.0; 16]);
        let _ = b.data.dev_data(&mut dev);
        let used_big = dev.ddr().used();

        b.reshape_grow_only(&mut dev, &[2, 2]);
        assert_eq!(b.count(), 4);
        assert_eq!(b.shape(), &[2, 2]);
        // Capacity (and the device buffer) stays at the high-water mark.
        assert_eq!(b.data.len(), 16);
        assert_eq!(dev.ddr().used(), used_big);

        // set_data/data_vec operate on the logical prefix only.
        b.set_data(&mut dev, &[7.0, 8.0, 9.0, 10.0]);
        assert_eq!(b.data_vec(&mut dev), vec![7.0, 8.0, 9.0, 10.0]);

        // Growing back within capacity is free; growing past it resizes.
        b.reshape_grow_only(&mut dev, &[8, 2]);
        assert_eq!(b.data.len(), 16);
        b.reshape_grow_only(&mut dev, &[9, 2]);
        assert_eq!(b.data.len(), 18);
    }

    #[test]
    fn read_prefix_returns_leading_elements() {
        let mut dev = CpuDevice::new();
        let mut m = SyncedMem::new(4);
        m.host_data_mut(&mut dev).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        // Host-fresh path.
        let mut out = [0.0f32; 2];
        m.read_prefix(&mut dev, &mut out);
        assert_eq!(out, [1.0, 2.0]);
        // Device-fresh path (head at device).
        let id = m.dev_data_mut(&mut dev);
        dev.write(id, &[5.0, 6.0, 7.0, 8.0]);
        let mut out = [0.0f32; 3];
        m.read_prefix(&mut dev, &mut out);
        assert_eq!(out, [5.0, 6.0, 7.0]);
        // read_prefix must not move the head: a full host sync still
        // sees the device data.
        assert_eq!(m.state(), MemState::AtDevice);
        assert_eq!(m.host_data(&mut dev), &[5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn blob_data_roundtrip() {
        let mut dev = CpuDevice::new();
        let mut b = Blob::new("x", &[2, 2]);
        b.set_data(&mut dev, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b.data_vec(&mut dev), vec![1.0, 2.0, 3.0, 4.0]);
        b.reshape(&mut dev, &[4, 1]);
        assert_eq!(b.count(), 4);
    }
}
