//! Deadline-aware dynamic micro-batcher.
//!
//! A single batcher thread sits between the admission queue and the
//! worker pool: it blocks for the first request, then lingers up to
//! `max_linger` collecting more, and flushes as soon as the batch is
//! full *or* the deadline passes — the classic latency/throughput knob
//! pair (big `max_batch` + long linger amortizes per-launch overhead;
//! linger 0 degenerates to one-request batches). The gather/scatter
//! helpers below are the blob-packing half: k single samples become one
//! `[rows, C, H, W]` input blob shaped for the batch the worker actually
//! executes (the *bucketed* batch size — see `runtime::plan::
//! batch_bucket` — never a pad to `max_batch`), and the batched output
//! rows scatter back to the per-request response slots.

use super::engine::Request;
use super::metrics::Metrics;
use super::queue::{Pop, SharedQueue};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush when this many requests have coalesced.
    pub max_batch: usize,
    /// Flush when the oldest request in the forming batch has waited
    /// this long.
    pub max_linger: Duration,
}

/// One coalesced unit of work for a worker.
pub(crate) struct Batch {
    pub requests: Vec<Request>,
    /// When the batcher sealed this batch — the boundary between
    /// queue/linger wait and dispatch wait on a sampled trace.
    pub formed: Instant,
}

/// Pack up to `rows` samples (each `sample_len` elements) into one
/// batched input blob of exactly `rows` rows, zero-filling unused tail
/// rows. `rows` is the batch shape the replica will execute (the
/// bucketed batch size), not `max_batch`.
pub fn gather(samples: &[&[f32]], sample_len: usize, rows: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * sample_len];
    for (i, s) in samples.iter().take(rows).enumerate() {
        assert_eq!(s.len(), sample_len, "gather: sample {i} length mismatch");
        out[i * sample_len..(i + 1) * sample_len].copy_from_slice(s);
    }
    out
}

/// Split the first `k` rows of a batched output blob back into
/// per-request vectors.
pub fn scatter(batched: &[f32], row_len: usize, k: usize) -> Vec<Vec<f32>> {
    assert!(batched.len() >= k * row_len, "scatter: output too small");
    (0..k)
        .map(|i| batched[i * row_len..(i + 1) * row_len].to_vec())
        .collect()
}

/// Batcher thread body: drains `submit` into coalesced batches on
/// `dispatch` until `submit` is closed *and* empty (graceful shutdown
/// therefore flushes every admitted request).
// Thread entry point: the batcher thread must own its queue handles
// and config for its whole lifetime ('static), even though the body
// only ever borrows them.
#[allow(clippy::needless_pass_by_value)]
pub(crate) fn run(
    submit: Arc<SharedQueue<Request>>,
    dispatch: Arc<SharedQueue<Batch>>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
) {
    while let Some(first) = submit.pop() {
        // Load shedding before batch formation: a request whose
        // per-request deadline already passed while queued is resolved
        // as `DeadlineExceeded` right here — it never occupies a batch
        // slot, so under backlog the batcher spends capacity only on
        // work someone is still waiting for.
        if first.expired(Instant::now()) {
            first.shed();
            continue;
        }
        // Anchor the linger at the oldest request's submit time, so queue
        // wait counts against the deadline instead of stacking on top of
        // it. Under backlog the deadline is already past, but pop_until
        // still drains queued items without waiting — batches stay full.
        let deadline = (first.submitted + cfg.max_linger).max(Instant::now());
        let mut requests = vec![first];
        while requests.len() < cfg.max_batch {
            match submit.pop_until(deadline) {
                Pop::Item(r) => {
                    if r.expired(Instant::now()) {
                        r.shed();
                    } else {
                        requests.push(r);
                    }
                }
                Pop::TimedOut | Pop::Closed => break,
            }
        }
        metrics.record_batch(requests.len(), cfg.max_batch);
        // The drain edge of the queue-depth gauge (submit is the rise).
        metrics.record_queue_depth(submit.len() as u64);
        if let Err(batch) = dispatch.push(Batch { requests, formed: Instant::now() }) {
            // Dispatch closed under us: the worker pool is gone (build
            // failures or panics exhausted it). Stop admissions and fail
            // everything in flight so no caller blocks forever on a
            // request nothing will ever pop.
            submit.close();
            for req in batch.requests {
                req.fail("serving worker pool exhausted");
            }
            while let Some(req) = submit.pop() {
                req.fail("serving worker pool exhausted");
            }
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_packs_and_pads() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let packed = gather(&[&a, &b], 2, 4);
        assert_eq!(packed, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn scatter_returns_first_k_rows() {
        let out = [0.1f32, 0.9, 0.8, 0.2, 7.0, 7.0];
        let rows = scatter(&out, 2, 2);
        assert_eq!(rows, vec![vec![0.1, 0.9], vec![0.8, 0.2]]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let s0 = [5.0f32, 6.0, 7.0];
        let s1 = [8.0f32, 9.0, 10.0];
        let packed = gather(&[&s0, &s1], 3, 2);
        let rows = scatter(&packed, 3, 2);
        assert_eq!(rows[0], s0);
        assert_eq!(rows[1], s1);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn gather_rejects_wrong_sample_len() {
        let s = [1.0f32];
        gather(&[&s], 2, 1);
    }
}
