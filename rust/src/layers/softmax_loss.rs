//! SoftmaxWithLoss layer (kernels `Softmax` + `SoftmaxLoss_F/B`).
//!
//! The loss scalar is read back over PCIe — the paper's three
//! `Read_Buffer` instances per GoogLeNet F→B are exactly its three loss
//! heads doing this.

use super::{Layer, SharedBlob};
use crate::blob::Blob;
use crate::device::{BufId, Device, Kernel, KernelCall};
use crate::proto::LayerParameter;

pub struct SoftmaxWithLossLayer {
    name: String,
    loss_weight: f32,
    prob: Option<SharedBlob>,
    loss_buf: Option<BufId>,
    n: usize,
    c: usize,
}

impl SoftmaxWithLossLayer {
    pub fn new(param: &LayerParameter) -> SoftmaxWithLossLayer {
        SoftmaxWithLossLayer {
            name: param.name.clone(),
            loss_weight: param.loss_weight.first().copied().unwrap_or(1.0),
            prob: None,
            loss_buf: None,
            n: 0,
            c: 0,
        }
    }

    pub fn probabilities(&self) -> Option<SharedBlob> {
        self.prob.clone()
    }
}

impl Layer for SoftmaxWithLossLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "SoftmaxWithLoss"
    }
    fn is_loss(&self) -> bool {
        true
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(bottoms.len() == 2, "SoftmaxWithLoss: needs [scores, labels]");
        self.prob = Some(super::shared(Blob::new("prob", &[1])));
        self.loss_buf = Some(dev.alloc(1)?);
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let b = bottoms[0].borrow();
        self.n = b.num();
        self.c = b.count() / self.n.max(1);
        let shape = b.shape().to_vec();
        drop(b);
        self.prob
            .as_ref()
            .expect("prob blob created at setup")
            .borrow_mut()
            .reshape_grow_only(dev, &shape);
        tops[0].borrow_mut().reshape_grow_only(dev, &[1]);
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let scores = bottoms[0].borrow_mut().data.dev_data(dev);
        let labels = bottoms[1].borrow_mut().data.dev_data(dev);
        let p_id = self.prob.as_ref().unwrap().borrow_mut().data.dev_data_mut(dev);
        dev.launch(&KernelCall::new(
            Kernel::SoftmaxF { n: self.n, c: self.c },
            &[scores],
            &[p_id],
        ))?;
        let l_id = self.loss_buf.unwrap();
        dev.launch(&KernelCall::new(
            Kernel::SoftmaxLossF { n: self.n, c: self.c },
            &[p_id, labels],
            &[l_id],
        ))?;
        // Read the loss scalar back to the host (a Read_Buffer event).
        let mut loss = [0.0f32];
        dev.read(l_id, &mut loss);
        tops[0].borrow_mut().set_data(dev, &[loss[0]]);
        Ok(loss[0] * self.loss_weight)
    }

    fn backward(
        &mut self,
        dev: &mut dyn Device,
        _tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        if !prop_down.first().copied().unwrap_or(true) {
            return Ok(());
        }
        let labels = bottoms[1].borrow_mut().data.dev_data(dev);
        let p_id = self.prob.as_ref().unwrap().borrow_mut().data.dev_data(dev);
        let bd_id = bottoms[0].borrow_mut().diff.dev_data_mut(dev);
        dev.launch(&KernelCall::new(
            Kernel::SoftmaxLossB { n: self.n, c: self.c, weight: self.loss_weight },
            &[p_id, labels],
            &[bd_id],
        ))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;

    #[test]
    fn loss_and_gradient() {
        let mut dev = CpuDevice::new();
        let mut layer = SoftmaxWithLossLayer::new(&LayerParameter::new("l", "SoftmaxWithLoss"));
        let scores = super::super::shared(Blob::new("s", &[2, 3]));
        let labels = super::super::shared(Blob::new("y", &[2]));
        let top = super::super::shared(Blob::new("loss", &[1]));
        scores
            .borrow_mut()
            .set_data(&mut dev, &[10.0, 0.0, 0.0, 0.0, 10.0, 0.0]);
        labels.borrow_mut().set_data(&mut dev, &[0.0, 1.0]);
        layer
            .setup(&mut dev, &[scores.clone(), labels.clone()], &[top.clone()])
            .unwrap();
        let loss = layer
            .forward(&mut dev, &[scores.clone(), labels.clone()], &[top.clone()])
            .unwrap();
        assert!(loss < 0.01, "confident correct predictions ⇒ tiny loss, got {loss}");
        layer
            .backward(&mut dev, &[top], &[true, false], &[scores.clone(), labels])
            .unwrap();
        let grad = scores.borrow_mut().diff_vec(&mut dev);
        // gradient ≈ (prob - onehot)/n: tiny at the right class, positive elsewhere
        assert!(grad[0] < 0.0 && grad[1] > 0.0);
        assert!(grad.iter().all(|v| v.abs() < 1.0));
    }

    #[test]
    fn loss_weight_scales_loss() {
        let mut dev = CpuDevice::new();
        let mut lp = LayerParameter::new("aux", "SoftmaxWithLoss");
        lp.loss_weight = vec![0.3];
        let mut layer = SoftmaxWithLossLayer::new(&lp);
        let scores = super::super::shared(Blob::new("s", &[1, 2]));
        let labels = super::super::shared(Blob::new("y", &[1]));
        let top = super::super::shared(Blob::new("loss", &[1]));
        scores.borrow_mut().set_data(&mut dev, &[0.0, 0.0]);
        labels.borrow_mut().set_data(&mut dev, &[0.0]);
        layer
            .setup(&mut dev, &[scores.clone(), labels.clone()], &[top.clone()])
            .unwrap();
        let loss = layer
            .forward(&mut dev, &[scores, labels], &[top])
            .unwrap();
        assert!((loss - 0.3 * (2.0f32).ln()).abs() < 1e-5);
    }
}
