//! Content-addressed AOT plan cache: build, verify and cold-boot the
//! zoo × serving-bucket execution-plan matrix.
//!
//! The paper's deployment story compiles kernels and network plans
//! ahead of time and reuses them across runs; this module is that story
//! for the simulated stack. `fecaffe aot build` records every zoo net's
//! deploy forward at every serving bucket ([`crate::runtime::recording`])
//! and serializes the recorded plans plus a *plan envelope* — blob
//! shapes, the netlint memory pass's DDR peak, the weights schema — into
//! deterministic [`container`] files keyed by a content hash of
//! (canonical net schema, bucket, device config, code version, serving
//! precision) over [`crate::util::sha256`]. Reduced-precision variants
//! (`lenet@int8`) are separate cache entries: their DDR envelope is
//! checked at the narrow byte width, their artifacts live beside the
//! fp32 ones under precision-suffixed filenames, and a cache built at
//! one precision can never validate for another (the key differs). `Engine::new` cold-boots from the cache:
//! when every bucket's artifact loads and its envelope validates against
//! the live net and board, the engine skips live admission planning
//! entirely; any mismatch is a typed [`AotError`] (mirroring
//! [`crate::netlint::LintError`]) that demotes the boot to the live path
//! and shows up as a `cache_miss` in `/metrics` — never a panic, never a
//! silently wrong plan.
//!
//! Cache layout under a cache directory:
//!
//! ```text
//! <dir>/lenet_deploy/bucket_001.feplan      one FEPLAN1 container per
//! <dir>/lenet_deploy/bucket_002.feplan      (net, bucket)
//! <dir>/...
//! <dir>/MANIFEST.sha256                     "<sha256>  <relpath>" lines
//! ```
//!
//! Two builds of the same commit produce byte-identical trees (the CI
//! `repro` leg diffs the manifests); `fecaffe aot verify` re-derives
//! every content key from the live zoo and checks the manifest hashes.

pub mod container;

use crate::device::fpga::costmodel::BoardParams;
use crate::net::Net;
use crate::netlint::{infer_shapes, lint_net, LintError, LintOptions};
use crate::proto::{NetParameter, Phase};
use crate::quant::Precision;
use crate::runtime::plan::{serve_bucket_cap, serve_buckets};
use crate::runtime::recording::RecordingDevice;
use crate::util::sha256;
use crate::zoo::{self, DeployNet};
use std::path::{Path, PathBuf};

/// Version of the plan-producing code paths (recording, bucket policy,
/// kernel keys). Bump on any change that alters recorded plans for an
/// unchanged net, so stale caches key-miss instead of validating.
pub const CODE_VERSION: u32 = 1;

/// Environment variable naming the cache directory when
/// `EngineConfig::aot_cache` is unset. There is deliberately no
/// cwd-relative probing: a cache must be asked for explicitly, so tests
/// and benches never pick one up by accident.
pub const AOT_CACHE_ENV: &str = "FECAFFE_AOT_CACHE";

/// Checksum manifest filename at the cache root.
pub const MANIFEST_NAME: &str = "MANIFEST.sha256";

// ---------------------------------------------------------------- errors

/// Typed cache-validation failure, mirroring [`LintError`]: stable
/// `AOTxxxx` codes, a one-line `Display` that reads well in an `anyhow`
/// chain, and enough structure for callers to test each failure class.
/// Every variant demotes a cold boot to live planning — none is fatal.
#[derive(Debug, Clone, PartialEq)]
pub enum AotError {
    /// No artifact at the expected logical path.
    Missing { path: String },
    /// Container bytes unreadable: bad magic, truncation, implausible
    /// counts, trailing garbage, checksum mismatch.
    Corrupt { path: String, detail: String },
    /// Content key mismatch — the net schema, bucket policy, device
    /// config or code version changed under the same logical path.
    StaleKey { path: String, expected: String, found: String },
    /// Container parsed and the key matched, but an envelope field
    /// contradicts the live net/device (wrong bucket, DDR budget,
    /// sample length, weights schema).
    EnvelopeMismatch { path: String, detail: String },
}

impl AotError {
    /// Stable grep-able code, in the `NLxxxx` style.
    pub fn code(&self) -> &'static str {
        match self {
            AotError::Missing { .. } => "AOT0001",
            AotError::Corrupt { .. } => "AOT0002",
            AotError::StaleKey { .. } => "AOT0003",
            AotError::EnvelopeMismatch { .. } => "AOT0004",
        }
    }
}

impl std::fmt::Display for AotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AotError::Missing { path } => {
                write!(f, "aot[AOT0001]: no cached plan at '{path}'")
            }
            AotError::Corrupt { path, detail } => {
                write!(f, "aot[AOT0002]: corrupt plan container '{path}': {detail}")
            }
            AotError::StaleKey { path, expected, found } => write!(
                f,
                "aot[AOT0003]: stale plan '{path}': content key {} does not match live {} \
                 (net schema, bucket policy or code version changed — rebuild the cache)",
                &found[..found.len().min(12)],
                &expected[..expected.len().min(12)],
            ),
            AotError::EnvelopeMismatch { path, detail } => {
                write!(f, "aot[AOT0004]: plan envelope mismatch in '{path}': {detail}")
            }
        }
    }
}

impl std::error::Error for AotError {}

// ------------------------------------------------------------- artifacts

/// Everything the engine must re-validate before trusting cached plans:
/// the live-net facts the plans were derived from, in fully-ordered
/// fields (sorted `Vec`s, no map iteration order anywhere).
#[derive(Debug, Clone, PartialEq)]
pub struct PlanEnvelope {
    /// Deploy net name (e.g. `LeNet_deploy`).
    pub net: String,
    /// Device-config string the DDR checks ran against
    /// ([`device_config`]).
    pub device: String,
    pub code_version: u32,
    /// Serving bucket these plans execute at.
    pub bucket: usize,
    /// Elements per input sample (C·H·W) — must match the live deploy.
    pub sample_len: usize,
    /// The netlint memory pass's estimated DDR footprint at this bucket.
    pub ddr_peak_bytes: u64,
    /// Board capacity the fit check used.
    pub ddr_capacity_bytes: u64,
    /// Inferred blob shapes at this bucket, sorted by blob name.
    pub blob_shapes: Vec<(String, Vec<usize>)>,
    /// Weights schema: (owner layer, slot) identity keys in snapshot
    /// order, with per-blob element counts alongside.
    pub weight_keys: Vec<(String, usize)>,
    pub weight_lens: Vec<usize>,
}

/// One cached plan: the content key it was built under, the envelope,
/// and the recorded (kernel key → lowering spec JSON) plans sorted by
/// kernel key.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanArtifact {
    pub key: String,
    pub envelope: PlanEnvelope,
    pub plans: Vec<(String, String)>,
}

// ----------------------------------------------------------- key scheme

/// Canonical net schema for content addressing: the prototxt emission of
/// the deploy param with the input batch dimension normalized to 1, so a
/// replica built at `max_batch` and a cache built per bucket derive the
/// *same* schema text (the bucket enters the key as its own field).
pub fn canonical_schema(param: &NetParameter) -> String {
    let mut p = param.clone();
    if let Some(input) = p.inputs.first_mut() {
        input.1[0] = 1;
    }
    crate::proto::emit::emit_net(&p)
}

/// Device-config component of the content key. Plans are device-kind
/// independent (the same kernel keys serve CPU and FPGA-sim workers);
/// what they *do* depend on is the board the DDR-fit envelope was
/// checked against.
pub fn device_config(board: &BoardParams) -> String {
    format!("board:ddr={}", board.ddr_capacity_bytes)
}

/// SHA-256 content key over (canonical schema, bucket, device config,
/// code version, serving precision). Fields are length-framed so no
/// concatenation of different inputs can collide; the precision label
/// is a fifth framed field under the same `feplan-key-v1` tag, so an
/// fp32 cache presented to an int8 boot key-misses (AOT0003/AOT0001)
/// instead of serving plans whose DDR envelope was checked at the
/// wrong byte width.
pub fn content_key(
    schema: &str,
    bucket: usize,
    device_cfg: &str,
    code_version: u32,
    precision: Precision,
) -> String {
    let mut h = sha256::Sha256::new();
    for field in [
        "feplan-key-v1",
        schema,
        &bucket.to_string(),
        device_cfg,
        &code_version.to_string(),
        precision.label(),
    ] {
        h.update(&(field.len() as u64).to_le_bytes());
        h.update(field.as_bytes());
    }
    sha256::to_hex(&h.finalize())
}

/// Logical path of a (net, bucket, precision) artifact relative to the
/// cache root. Fp32 keeps the original `bucket_NNN.feplan` filename so
/// pre-quantization manifests remain byte-stable; reduced precisions
/// get a `bucket_NNN.<label>.feplan` sibling in the same directory.
pub fn plan_rel_path(net_name: &str, bucket: usize, precision: Precision) -> String {
    let dir: String = net_name
        .chars()
        .map(|c| {
            let c = c.to_ascii_lowercase();
            if c.is_ascii_alphanumeric() { c } else { '_' }
        })
        .collect();
    match precision {
        Precision::Fp32 => format!("{dir}/bucket_{bucket:03}.feplan"),
        p => format!("{dir}/bucket_{bucket:03}.{}.feplan", p.label()),
    }
}

/// Cache directory from the environment (`FECAFFE_AOT_CACHE`), if set.
pub fn env_cache_dir() -> Option<PathBuf> {
    std::env::var(AOT_CACHE_ENV).ok().filter(|s| !s.is_empty()).map(PathBuf::from)
}

// ---------------------------------------------------------------- build

/// Record one deploy net's forward at `bucket` and assemble the artifact.
/// Lints first with the same options engine admission uses (including
/// the serving precision's byte width for the DDR pass) — a net that
/// would be refused live is refused here too, so a cache can never admit
/// what live planning would not.
pub fn build_plan(
    dep: &DeployNet,
    bucket: usize,
    board: &BoardParams,
    precision: Precision,
) -> anyhow::Result<PlanArtifact> {
    let lint = lint_net(
        &dep.param,
        &LintOptions {
            phase: Phase::Test,
            buckets: vec![bucket],
            forward_only: true,
            board: board.clone(),
            precision,
            ..Default::default()
        },
    );
    if lint.has_errors() {
        return Err(anyhow::Error::new(LintError::new(lint))
            .context(format!("refusing to cache plans for bucket {bucket}")));
    }
    let mem = lint
        .memory
        .first()
        .ok_or_else(|| anyhow::anyhow!("netlint produced no memory report for bucket {bucket}"))?;

    let mut dev = RecordingDevice::new(false);
    let mut net = Net::from_param(&dep.param, Phase::Test, &mut dev)?;
    let weights = net.share_weights(&mut dev);
    net.forward(&mut dev)?;

    let shapes = infer_shapes(&dep.param, Phase::Test, Some(bucket))?;
    Ok(PlanArtifact {
        key: content_key(
            &canonical_schema(&dep.param),
            bucket,
            &device_config(board),
            CODE_VERSION,
            precision,
        ),
        envelope: PlanEnvelope {
            net: dep.param.name.clone(),
            device: device_config(board),
            code_version: CODE_VERSION,
            bucket,
            sample_len: dep.sample_len,
            ddr_peak_bytes: mem.total_bytes,
            ddr_capacity_bytes: mem.ddr_capacity_bytes,
            blob_shapes: shapes.into_iter().collect(),
            weight_keys: weights.keys().to_vec(),
            weight_lens: weights.blob_lens(),
        },
        plans: dev.spec_entries(),
    })
}

/// What `build_matrix` materialized.
pub struct BuildReport {
    /// `(relpath, sha256)` per written container, sorted by relpath —
    /// exactly the `MANIFEST.sha256` content.
    pub files: Vec<(String, String)>,
    /// Total recorded (kernel, spec) plans across all containers.
    pub plan_count: usize,
}

/// Build the full `nets` × serving-bucket matrix into `dir` and write
/// the checksum manifest. Deterministic: same commit, same bytes. Names
/// take the router's `name[@precision]` form — `lenet@int8` caches the
/// int8 serving variant beside the fp32 one.
pub fn build_matrix(dir: &Path, nets: &[&str]) -> anyhow::Result<BuildReport> {
    let mut files = Vec::new();
    let mut plan_count = 0usize;
    for name in nets {
        let (base, precision) = crate::quant::split_model_name(name)?;
        for bucket in serve_buckets(serve_bucket_cap(base)) {
            let dep = zoo::deploy_by_name(base, bucket)?;
            let art = build_plan(&dep, bucket, &BoardParams::default(), precision)
                .map_err(|e| e.context(format!("building {name} at bucket {bucket}")))?;
            plan_count += art.plans.len();
            let rel = plan_rel_path(&art.envelope.net, bucket, precision);
            let bytes = container::artifact_bytes(&art);
            let path = dir.join(&rel);
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(&path, &bytes)?;
            files.push((rel, sha256::hex_digest(&bytes)));
        }
    }
    files.sort();
    let mut manifest = String::new();
    for (rel, hash) in &files {
        manifest.push_str(&format!("{hash}  {rel}\n"));
    }
    std::fs::write(dir.join(MANIFEST_NAME), manifest)?;
    Ok(BuildReport { files, plan_count })
}

// --------------------------------------------------------------- verify

/// What `verify_matrix` checked.
pub struct VerifyReport {
    pub files: usize,
    pub plan_count: usize,
    pub total_bytes: u64,
}

/// Parse a `MANIFEST.sha256` body into sorted `(relpath, sha256)` pairs.
pub fn parse_manifest(text: &str) -> anyhow::Result<Vec<(String, String)>> {
    let mut entries = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (hash, rel) = line
            .split_once("  ")
            .ok_or_else(|| anyhow::anyhow!("manifest line {}: not '<sha256>  <path>'", i + 1))?;
        anyhow::ensure!(
            hash.len() == 64 && hash.chars().all(|c| c.is_ascii_hexdigit()),
            "manifest line {}: '{hash}' is not a sha256 digest",
            i + 1
        );
        entries.push((rel.to_string(), hash.to_string()));
    }
    entries.sort();
    Ok(entries)
}

/// Verify the `nets` × bucket matrix in `dir`: the manifest covers
/// exactly the expected files, every file's bytes match its manifest
/// digest, every container parses, and every content key and envelope
/// re-validates against the *live* zoo at that bucket. Errors carry the
/// typed [`AotError`] in their chain.
pub fn verify_matrix(dir: &Path, nets: &[&str]) -> anyhow::Result<VerifyReport> {
    let manifest_path = dir.join(MANIFEST_NAME);
    let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
        anyhow::anyhow!("{}: {e} (run `fecaffe aot build` first)", manifest_path.display())
    })?;
    let entries = parse_manifest(&text)?;
    let board = BoardParams::default();

    let mut expected = Vec::new();
    for name in nets {
        let (base, precision) = crate::quant::split_model_name(name)?;
        let dep1 = zoo::deploy_by_name(base, 1)?;
        for bucket in serve_buckets(serve_bucket_cap(base)) {
            expected.push((
                plan_rel_path(&dep1.param.name, bucket, precision),
                dep1.param.clone(),
                bucket,
                precision,
            ));
        }
    }

    let by_rel: std::collections::BTreeMap<&str, &str> =
        entries.iter().map(|(r, h)| (r.as_str(), h.as_str())).collect();
    for (rel, _, _, _) in &expected {
        if !by_rel.contains_key(rel.as_str()) {
            return Err(anyhow::Error::new(AotError::Missing { path: rel.clone() })
                .context("manifest does not cover the expected matrix"));
        }
    }
    let expected_rels: std::collections::BTreeSet<&str> =
        expected.iter().map(|(r, _, _, _)| r.as_str()).collect();
    for (rel, _) in &entries {
        anyhow::ensure!(
            expected_rels.contains(rel.as_str()),
            "manifest names '{rel}', which is not in the {} × bucket matrix",
            nets.join(",")
        );
    }

    let mut plan_count = 0usize;
    let mut total_bytes = 0u64;
    for (rel, param, bucket, precision) in &expected {
        let path = dir.join(rel);
        let bytes = std::fs::read(&path)
            .map_err(|_| anyhow::Error::new(AotError::Missing { path: rel.clone() }))?;
        let digest = sha256::hex_digest(&bytes);
        if digest != by_rel[rel.as_str()] {
            return Err(anyhow::Error::new(AotError::Corrupt {
                path: rel.clone(),
                detail: format!(
                    "sha256 {} does not match manifest {}",
                    &digest[..12],
                    &by_rel[rel.as_str()][..12]
                ),
            }));
        }
        let art = container::read_artifact(&bytes, rel).map_err(anyhow::Error::new)?;
        let expected_key = content_key(
            &canonical_schema(param),
            *bucket,
            &device_config(&board),
            CODE_VERSION,
            *precision,
        );
        validate_artifact(&art, &expected_key, *bucket, &board, rel).map_err(anyhow::Error::new)?;
        plan_count += art.plans.len();
        total_bytes += bytes.len() as u64;
    }
    Ok(VerifyReport { files: expected.len(), plan_count, total_bytes })
}

/// Delete a cache directory. Refuses directories without a
/// `MANIFEST.sha256` (they are probably not a plan cache).
pub fn clean(dir: &Path) -> anyhow::Result<bool> {
    if !dir.exists() {
        return Ok(false);
    }
    anyhow::ensure!(
        dir.join(MANIFEST_NAME).is_file(),
        "refusing to delete '{}': no {MANIFEST_NAME} — not an aot cache?",
        dir.display()
    );
    std::fs::remove_dir_all(dir)?;
    Ok(true)
}

// ------------------------------------------------------------ validation

/// Validate a parsed artifact against the live expectations: content
/// key, bucket, code version, and the DDR envelope. Weights-schema
/// validation happens separately ([`validate_weights`]) because the live
/// schema only exists once a master replica is built.
pub fn validate_artifact(
    art: &PlanArtifact,
    expected_key: &str,
    bucket: usize,
    board: &BoardParams,
    path: &str,
) -> Result<(), AotError> {
    if art.key != expected_key {
        return Err(AotError::StaleKey {
            path: path.to_string(),
            expected: expected_key.to_string(),
            found: art.key.clone(),
        });
    }
    let env = &art.envelope;
    let mismatch = |detail: String| AotError::EnvelopeMismatch { path: path.to_string(), detail };
    if env.code_version != CODE_VERSION {
        return Err(mismatch(format!(
            "plan code version {} (this build is {CODE_VERSION})",
            env.code_version
        )));
    }
    if env.bucket != bucket {
        return Err(mismatch(format!("envelope is for bucket {}, wanted {bucket}", env.bucket)));
    }
    if env.ddr_capacity_bytes != board.ddr_capacity_bytes {
        return Err(mismatch(format!(
            "DDR budget checked against {} bytes, live board has {}",
            env.ddr_capacity_bytes, board.ddr_capacity_bytes
        )));
    }
    if env.ddr_peak_bytes > env.ddr_capacity_bytes {
        return Err(mismatch(format!(
            "recorded DDR peak {} exceeds capacity {}",
            env.ddr_peak_bytes, env.ddr_capacity_bytes
        )));
    }
    Ok(())
}

/// Validate a cached envelope's weights schema against the live master
/// replica's snapshot (identity keys and element counts).
pub fn validate_weights(
    art: &PlanArtifact,
    keys: &[(String, usize)],
    lens: &[usize],
    path: &str,
) -> Result<(), AotError> {
    let env = &art.envelope;
    if env.weight_keys != keys || env.weight_lens != lens {
        let divergence = env
            .weight_keys
            .iter()
            .zip(keys)
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| env.weight_keys.len().min(keys.len()));
        return Err(AotError::EnvelopeMismatch {
            path: path.to_string(),
            detail: format!(
                "weights schema: cached {} blob(s), live net has {} (first divergence at {})",
                env.weight_keys.len(),
                keys.len(),
                divergence
            ),
        });
    }
    Ok(())
}

// ------------------------------------------------------------- cold boot

/// Result of an engine cold-boot attempt over a cache directory.
pub struct ColdBoot {
    /// Per-bucket artifacts that loaded *and* validated.
    pub hits: Vec<(usize, PlanArtifact)>,
    /// One typed error per bucket that did not.
    pub errors: Vec<AotError>,
    /// Set by [`ColdBoot::demote`]: a post-load check (weights schema)
    /// failed, so the boot fell back to live planning after the fact.
    demoted: bool,
}

impl ColdBoot {
    /// The no-cache-configured outcome: nothing attempted, no misses.
    pub fn disabled() -> ColdBoot {
        ColdBoot { hits: Vec::new(), errors: Vec::new(), demoted: false }
    }

    /// Every requested bucket validated — live planning can be skipped.
    pub fn complete(&self) -> bool {
        !self.hits.is_empty() && self.errors.is_empty() && !self.demoted
    }

    /// Record a post-load validation failure and fall back.
    pub fn demote(&mut self, err: AotError) {
        self.demoted = true;
        self.errors.push(err);
    }

    pub fn hit_count(&self) -> u64 {
        if self.complete() {
            self.hits.len() as u64
        } else {
            0
        }
    }

    pub fn miss_count(&self) -> u64 {
        self.errors.len() as u64
    }
}

/// Attempt to cold-boot `dep` from `dir` at every serving bucket, for
/// one serving precision. Each bucket either contributes a validated
/// artifact or a typed error; the caller decides (all-or-nothing)
/// whether live planning can be skipped.
pub fn cold_boot(
    dir: &Path,
    dep: &DeployNet,
    buckets: &[usize],
    board: &BoardParams,
    precision: Precision,
) -> ColdBoot {
    let schema = canonical_schema(&dep.param);
    let devcfg = device_config(board);
    let mut boot = ColdBoot::disabled();
    for &bucket in buckets {
        let rel = plan_rel_path(&dep.param.name, bucket, precision);
        let path = dir.join(&rel);
        let label = path.display().to_string();
        let result = (|| -> Result<PlanArtifact, AotError> {
            let bytes = std::fs::read(&path)
                .map_err(|_| AotError::Missing { path: label.clone() })?;
            let art = container::read_artifact(&bytes, &label)?;
            let expected = content_key(&schema, bucket, &devcfg, CODE_VERSION, precision);
            validate_artifact(&art, &expected, bucket, board, &label)?;
            if art.envelope.sample_len != dep.sample_len {
                return Err(AotError::EnvelopeMismatch {
                    path: label.clone(),
                    detail: format!(
                        "sample_len {} cached, live deploy needs {}",
                        art.envelope.sample_len, dep.sample_len
                    ),
                });
            }
            Ok(art)
        })();
        match result {
            Ok(art) => boot.hits.push((bucket, art)),
            Err(e) => boot.errors.push(e),
        }
    }
    boot
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn content_key_is_stable_and_input_sensitive() {
        let dep = zoo::deploy_by_name("lenet", 4).unwrap();
        let schema = canonical_schema(&dep.param);
        let dev = device_config(&BoardParams::default());
        let fp32 = Precision::Fp32;
        let k1 = content_key(&schema, 4, &dev, CODE_VERSION, fp32);
        let k2 = content_key(&schema, 4, &dev, CODE_VERSION, fp32);
        assert_eq!(k1, k2);
        assert_eq!(k1.len(), 64);
        // Each key component changes the digest.
        assert_ne!(k1, content_key(&schema, 8, &dev, CODE_VERSION, fp32));
        assert_ne!(k1, content_key(&schema, 4, "board:ddr=1", CODE_VERSION, fp32));
        assert_ne!(k1, content_key(&schema, 4, &dev, CODE_VERSION + 1, fp32));
        assert_ne!(k1, content_key(&format!("{schema} "), 4, &dev, CODE_VERSION, fp32));
        // Precision is its own key field: an fp32 cache can never
        // validate for an int8 boot (and int8 ≠ fp16).
        let k_int8 = content_key(&schema, 4, &dev, CODE_VERSION, Precision::Int8);
        let k_fp16 = content_key(&schema, 4, &dev, CODE_VERSION, Precision::Fp16);
        assert_ne!(k1, k_int8);
        assert_ne!(k1, k_fp16);
        assert_ne!(k_int8, k_fp16);
    }

    #[test]
    fn canonical_schema_is_batch_invariant() {
        // A replica deployed at max_batch and a cache built per bucket
        // must agree on the schema text — the whole point of
        // normalizing the input batch dimension.
        let at2 = canonical_schema(&zoo::deploy_by_name("lenet", 2).unwrap().param);
        let at32 = canonical_schema(&zoo::deploy_by_name("lenet", 32).unwrap().param);
        assert_eq!(at2, at32);
        // But different nets differ.
        let squeeze = canonical_schema(&zoo::deploy_by_name("squeezenet", 2).unwrap().param);
        assert_ne!(at2, squeeze);
    }

    #[test]
    fn rel_paths_are_sanitized_and_bucket_ordered() {
        let fp32 = Precision::Fp32;
        assert_eq!(plan_rel_path("LeNet_deploy", 1, fp32), "lenet_deploy/bucket_001.feplan");
        assert_eq!(plan_rel_path("LeNet_deploy", 32, fp32), "lenet_deploy/bucket_032.feplan");
        assert_eq!(plan_rel_path("weird name!", 2, fp32), "weird_name_/bucket_002.feplan");
        // Reduced precisions are siblings with a label infix; fp32
        // keeps the legacy filename so old manifests stay valid.
        assert_eq!(
            plan_rel_path("LeNet_deploy", 1, Precision::Int8),
            "lenet_deploy/bucket_001.int8.feplan"
        );
        assert_eq!(
            plan_rel_path("LeNet_deploy", 1, Precision::Fp16),
            "lenet_deploy/bucket_001.fp16.feplan"
        );
        // Zero-padding keeps lexicographic order == numeric order for
        // every bucket the zoo can serve.
        let mut rels: Vec<String> =
            serve_buckets(32).iter().map(|&b| plan_rel_path("x", b, fp32)).collect();
        let sorted = rels.clone();
        rels.sort();
        assert_eq!(rels, sorted);
    }

    #[test]
    fn build_plan_records_envelope_and_plans() {
        let dep = zoo::deploy_by_name("lenet", 2).unwrap();
        let art = build_plan(&dep, 2, &BoardParams::default(), Precision::Fp32).unwrap();
        assert_eq!(art.envelope.net, "LeNet_deploy");
        assert_eq!(art.envelope.bucket, 2);
        assert_eq!(art.envelope.sample_len, 784);
        assert!(art.envelope.ddr_peak_bytes > 0);
        assert!(art.envelope.ddr_peak_bytes <= art.envelope.ddr_capacity_bytes);
        assert!(!art.envelope.weight_keys.is_empty());
        assert_eq!(art.envelope.weight_keys.len(), art.envelope.weight_lens.len());
        // Plans are sorted by kernel key and include the conv1 gemm.
        let keys: Vec<&str> = art.plans.iter().map(|(k, _)| k.as_str()).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
        assert!(keys.contains(&"gemm_nn_20x25x576"), "{keys:?}");
        // Shapes are sorted by blob name and batch-scaled.
        let names: Vec<&str> = art.envelope.blob_shapes.iter().map(|(n, _)| n.as_str()).collect();
        let mut snames = names.clone();
        snames.sort_unstable();
        assert_eq!(names, snames);
        let data = art.envelope.blob_shapes.iter().find(|(n, _)| n == "data").unwrap();
        assert_eq!(data.1, vec![2, 1, 28, 28]);
    }

    #[test]
    fn build_plan_is_deterministic() {
        let dep = zoo::deploy_by_name("lenet", 2).unwrap();
        let fp32 = Precision::Fp32;
        let a =
            container::artifact_bytes(&build_plan(&dep, 2, &BoardParams::default(), fp32).unwrap());
        let b =
            container::artifact_bytes(&build_plan(&dep, 2, &BoardParams::default(), fp32).unwrap());
        assert_eq!(a, b, "two independent builds must be byte-identical");
    }

    #[test]
    fn validate_artifact_flags_each_mismatch_as_typed_error() {
        let board = BoardParams::default();
        let dep = zoo::deploy_by_name("lenet", 2).unwrap();
        let art = build_plan(&dep, 2, &board, Precision::Fp32).unwrap();
        let key = art.key.clone();
        assert!(validate_artifact(&art, &key, 2, &board, "p").is_ok());

        // Stale key.
        let err = validate_artifact(&art, "0".repeat(64).as_str(), 2, &board, "p").unwrap_err();
        assert_eq!(err.code(), "AOT0003");
        assert!(err.to_string().contains("stale plan"), "{err}");

        // Wrong bucket (tamper the envelope; key check must be bypassed
        // with the artifact's own key to reach the envelope check).
        let mut tampered = art.clone();
        tampered.envelope.bucket = 4;
        let err = validate_artifact(&tampered, &key, 2, &board, "p").unwrap_err();
        assert_eq!(err.code(), "AOT0004");
        assert!(err.to_string().contains("bucket 4"), "{err}");

        // Wrong DDR budget.
        let mut tampered = art.clone();
        tampered.envelope.ddr_capacity_bytes = 1;
        let err = validate_artifact(&tampered, &key, 2, &board, "p").unwrap_err();
        assert_eq!(err.code(), "AOT0004");
        assert!(err.to_string().contains("DDR budget"), "{err}");

        // Peak exceeding capacity.
        let mut tampered = art.clone();
        tampered.envelope.ddr_peak_bytes = tampered.envelope.ddr_capacity_bytes + 1;
        let err = validate_artifact(&tampered, &key, 2, &board, "p").unwrap_err();
        assert_eq!(err.code(), "AOT0004");

        // Wrong code version.
        let mut tampered = art.clone();
        tampered.envelope.code_version = CODE_VERSION + 1;
        let err = validate_artifact(&tampered, &key, 2, &board, "p").unwrap_err();
        assert_eq!(err.code(), "AOT0004");

        // Wrong weights schema.
        let err = validate_weights(&art, &[("nope".to_string(), 0)], &[1], "p").unwrap_err();
        assert_eq!(err.code(), "AOT0004");
        assert!(err.to_string().contains("weights schema"), "{err}");
        let lens = art.envelope.weight_lens.clone();
        assert!(validate_weights(&art, &art.envelope.weight_keys, &lens, "p").is_ok());
    }

    #[test]
    fn manifest_parses_and_rejects_junk() {
        let good = "0123456789abcdef0123456789abcdef0123456789abcdef0123456789abcdef  a/b.feplan\n";
        let entries = parse_manifest(good).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, "a/b.feplan");
        assert!(parse_manifest("not-a-digest  x\n").is_err());
        assert!(parse_manifest("0123  x\n").is_err());
        assert!(parse_manifest("deadbeef\n").is_err());
        assert!(parse_manifest("\n\n").unwrap().is_empty());
    }
}
