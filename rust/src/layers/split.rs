//! Split layer — auto-inserted by the net when one blob feeds several
//! consumers (GoogLeNet's inception fan-outs). Forward shares data
//! (zero-copy, like Caffe); backward *accumulates* the top diffs with the
//! `Split` kernel — the paper's 41 Split instances per GoogLeNet F→B.

use super::{Layer, SharedBlob};
use crate::device::{Device, Kernel, KernelCall};
use crate::proto::LayerParameter;

pub struct SplitLayer {
    name: String,
    count: usize,
}

impl SplitLayer {
    pub fn new(param: &LayerParameter) -> SplitLayer {
        SplitLayer { name: param.name.clone(), count: 0 }
    }
}

impl Layer for SplitLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "Split"
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        self.count = bottoms[0].borrow().count();
        let shape = bottoms[0].borrow().shape().to_vec();
        for t in tops {
            t.borrow_mut().reshape_grow_only(dev, &shape);
        }
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        // Data sharing: copy bottom data into each top (device-side copy;
        // Caffe shares pointers, we pay one eltwise copy per top to keep
        // blob ownership simple — same DDR traffic the Concat kernel has).
        let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
        for t in tops {
            let t_id = t.borrow_mut().data.dev_data_mut(dev);
            dev.launch(&KernelCall::new(
                Kernel::Axpby { n: self.count, alpha: 1.0, beta: 0.0 },
                &[b_id],
                &[t_id],
            ))?;
        }
        Ok(0.0)
    }

    fn backward(
        &mut self,
        dev: &mut dyn Device,
        tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        if !prop_down.first().copied().unwrap_or(true) {
            return Ok(());
        }
        // bottom_diff = Σ top_diffs: first top overwrites, rest accumulate
        // via the Split kernel.
        let bd_id = bottoms[0].borrow_mut().diff.dev_data_mut(dev);
        // subsequent Split kernels read+write bd; head already AtDevice
        for (i, t) in tops.iter().enumerate() {
            let td_id = t.borrow_mut().diff.dev_data(dev);
            if i == 0 {
                dev.launch(&KernelCall::new(
                    Kernel::Axpby { n: self.count, alpha: 1.0, beta: 0.0 },
                    &[td_id],
                    &[bd_id],
                ))?;
            } else {
                dev.launch(&KernelCall::new(
                    Kernel::Split { n: self.count },
                    &[td_id],
                    &[bd_id],
                ))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::Blob;
    use crate::device::cpu::CpuDevice;

    #[test]
    fn forward_copies_backward_sums() {
        let mut dev = CpuDevice::new();
        let mut layer = SplitLayer::new(&LayerParameter::new("sp", "Split"));
        let bottom = super::super::shared(Blob::new("x", &[3]));
        let t1 = super::super::shared(Blob::new("x_split_0", &[1]));
        let t2 = super::super::shared(Blob::new("x_split_1", &[1]));
        bottom.borrow_mut().set_data(&mut dev, &[1.0, 2.0, 3.0]);
        layer
            .setup(&mut dev, &[bottom.clone()], &[t1.clone(), t2.clone()])
            .unwrap();
        layer
            .forward(&mut dev, &[bottom.clone()], &[t1.clone(), t2.clone()])
            .unwrap();
        assert_eq!(t1.borrow_mut().data_vec(&mut dev), vec![1.0, 2.0, 3.0]);
        assert_eq!(t2.borrow_mut().data_vec(&mut dev), vec![1.0, 2.0, 3.0]);

        t1.borrow_mut().set_diff(&mut dev, &[1.0, 1.0, 1.0]);
        t2.borrow_mut().set_diff(&mut dev, &[10.0, 20.0, 30.0]);
        layer
            .backward(&mut dev, &[t1, t2], &[true], &[bottom.clone()])
            .unwrap();
        assert_eq!(
            bottom.borrow_mut().diff_vec(&mut dev),
            vec![11.0, 21.0, 31.0]
        );
    }
}
