"""L2: the jax compute graphs for every FeCaffe kernel.

`build(spec)` maps one manifest entry (emitted by rust's gen-manifest; see
rust/src/runtime/plan.rs for the spec schema) to a jax function plus its
example input ShapeDtypeStructs. GEMM/GEMV route through the L1 Pallas
kernels in kernels/gemm.py; everything else is jnp, written to match the
rust native math bit-for-bit in layout and tie-breaking (the runtime's
equivalence tests depend on it).

Conventions shared with rust/src/runtime/plan.rs:
  * scalars (lr, slopes, alpha, ...) are rank-0 f32 runtime inputs;
  * accumulating kernels take the current output as their last input;
  * every function returns a tuple (lowered with return_tuple=True).
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import gemm as gk

F32 = jnp.float32


def _s(*dims):
    return jax.ShapeDtypeStruct(tuple(int(d) for d in dims), F32)


SCALAR = _s()


def _pool_geom(spec):
    return (
        int(spec["num"]), int(spec["channels"]), int(spec["height"]), int(spec["width"]),
        int(spec["kernel_h"]), int(spec["kernel_w"]),
        int(spec["stride_h"]), int(spec["stride_w"]),
        int(spec["pad_h"]), int(spec["pad_w"]),
    )


def pooled_dim(inp, k, p, s):
    out = int(np.ceil((inp + 2 * p - k) / s)) + 1
    if p > 0 and (out - 1) * s >= inp + p:
        out -= 1
    return out


def _window_gather(x, kh, kw, sh, sw, ph, pw, oh, ow, pad_value):
    """x: (N,C,H,W) -> values (N,C,oh,ow,kh*kw) and plane indices
    (oh,ow,kh*kw), window scan order (kh, kw) — identical to the rust
    max-pool loop, so argmax tie-breaking matches.

    IMPORTANT: index/valid grids are built from *iota* ops, never from
    embedded numpy constants — XLA's HLO text printer elides large dense
    literals, which would corrupt the AOT artifact (aot.py guards this)."""
    n, c, h, w = x.shape
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (ph, ph + kh), (pw, pw + kw)),
        constant_values=pad_value,
    )
    vals = []
    for ki in range(kh):
        for kj in range(kw):
            vals.append(xp[:, :, ki:ki + sh * oh:sh, kj:kj + sw * ow:sw])
    vals = jnp.stack(vals, axis=-1)  # (N,C,oh,ow,kh*kw)
    # plane index of each tap, from iotas: iy*w + ix (or invalid).
    iy = jnp.arange(oh, dtype=jnp.int32)[:, None] * sh - ph  # (oh,1)
    ix = jnp.arange(ow, dtype=jnp.int32)[None, :] * sw - pw  # (1,ow)
    idx_taps = []
    valid_taps = []
    for ki in range(kh):
        for kj in range(kw):
            yy = iy + ki  # (oh,1)
            xx = ix + kj  # (1,ow)
            ok = (yy >= 0) & (yy < h) & (xx >= 0) & (xx < w)
            plane = jnp.clip(yy, 0, h - 1) * w + jnp.clip(xx, 0, w - 1)
            idx_taps.append(jnp.broadcast_to(plane, (oh, ow)))
            valid_taps.append(jnp.broadcast_to(ok, (oh, ow)))
    idx = jnp.stack(idx_taps, axis=-1)
    valid = jnp.stack(valid_taps, axis=-1)
    return vals, idx, valid


def build(spec):
    """spec (dict) -> (fn, [example args])."""
    op = spec["op"]

    if op in ("gemm_nn", "gemm_nt", "gemm_tn"):
        m, n, k = int(spec["m"]), int(spec["n"]), int(spec["k"])
        acc = bool(spec.get("acc", False))
        ta = op == "gemm_tn"
        tb = op == "gemm_nt"
        a_shape = _s(k, m) if ta else _s(m, k)
        b_shape = _s(n, k) if tb else _s(k, n)
        if acc:
            def fn(a, b, c):
                return (gk.gemm(a, b, ta=ta, tb=tb, c=c),)
            return fn, [a_shape, b_shape, _s(m, n)]
        def fn(a, b):
            return (gk.gemm(a, b, ta=ta, tb=tb),)
        return fn, [a_shape, b_shape]

    if op == "gemv":
        m, n = int(spec["m"]), int(spec["n"])
        trans = bool(spec.get("trans", False))
        acc = bool(spec.get("acc", False))
        xl, yl = (m, n) if trans else (n, m)
        if acc:
            def fn(a, x, y):
                return (gk.gemv(a, x, trans=trans, y=y),)
            return fn, [_s(m, n), _s(xl), _s(yl)]
        def fn(a, x):
            return (gk.gemv(a, x, trans=trans),)
        return fn, [_s(m, n), _s(xl)]

    if op == "axpy":
        n = int(spec["n"])
        return (lambda alpha, x, y: (alpha * x + y,)), [SCALAR, _s(n), _s(n)]

    if op == "axpby":
        n = int(spec["n"])
        return (
            lambda alpha, beta, x, y: (alpha * x + beta * y,),
            [SCALAR, SCALAR, _s(n), _s(n)],
        )

    if op == "scal":
        n = int(spec["n"])
        return (lambda alpha, x: (alpha * x,)), [SCALAR, _s(n)]

    if op == "asum":
        n = int(spec["n"])
        return (lambda x: (jnp.abs(x).sum()[None],)), [_s(n)]

    if op == "add":
        n = int(spec["n"])
        return (lambda x, y: (x + y,)), [_s(n), _s(n)]

    if op == "mul":
        n = int(spec["n"])
        return (lambda x, y: (x * y,)), [_s(n), _s(n)]

    if op == "powx":
        n = int(spec["n"])
        return (lambda p, x: (jnp.power(x, p),)), [SCALAR, _s(n)]

    if op == "relu_f":
        n = int(spec["n"])
        return (
            lambda slope, x: (jnp.where(x > 0, x, slope * x),),
            [SCALAR, _s(n)],
        )

    if op == "relu_b":
        n = int(spec["n"])
        return (
            lambda slope, data, td: (td * jnp.where(data > 0, 1.0, slope),),
            [SCALAR, _s(n), _s(n)],
        )

    if op == "dropout":
        n = int(spec["n"])
        return (
            lambda scale, x, mask: (x * mask * scale,),
            [SCALAR, _s(n), _s(n)],
        )

    if op == "bias":
        outer, c, dim = int(spec["outer"]), int(spec["channels"]), int(spec["dim"])
        return (
            lambda b, top: (top + b[None, :, None],),
            [_s(c), _s(outer, c, dim)],
        )

    if op == "im2col":
        c, h, w = int(spec["channels"]), int(spec["height"]), int(spec["width"])
        kh, kw = int(spec["kernel_h"]), int(spec["kernel_w"])
        sh, sw = int(spec["stride_h"]), int(spec["stride_w"])
        ph, pw = int(spec["pad_h"]), int(spec["pad_w"])
        oh = (h + 2 * ph - kh) // sh + 1
        ow = (w + 2 * pw - kw) // sw + 1

        def fn(im):
            xp = jnp.pad(im, ((0, 0), (ph, ph), (pw, pw)))
            rows = []
            for ki in range(kh):
                for kj in range(kw):
                    rows.append(
                        xp[:, ki:ki + sh * oh:sh, kj:kj + sw * ow:sw].reshape(c, oh * ow)
                    )
            # order (c, kh, kw): stack taps then interleave channels
            col = jnp.stack(rows, axis=1)  # (c, kh*kw, oh*ow)
            return (col.reshape(c * kh * kw, oh * ow),)

        return fn, [_s(c, h, w)]

    if op == "col2im":
        c, h, w = int(spec["channels"]), int(spec["height"]), int(spec["width"])
        kh, kw = int(spec["kernel_h"]), int(spec["kernel_w"])
        sh, sw = int(spec["stride_h"]), int(spec["stride_w"])
        ph, pw = int(spec["pad_h"]), int(spec["pad_w"])
        oh = (h + 2 * ph - kh) // sh + 1
        ow = (w + 2 * pw - kw) // sw + 1

        def fn(col, im):
            colr = col.reshape(c, kh * kw, oh, ow)
            padded = jnp.zeros((c, h + 2 * ph, w + 2 * pw), F32)
            t = 0
            for ki in range(kh):
                for kj in range(kw):
                    padded = padded.at[:, ki:ki + sh * oh:sh, kj:kj + sw * ow:sw].add(
                        colr[:, t]
                    )
                    t += 1
            return (im + padded[:, ph:ph + h, pw:pw + w],)

        return fn, [_s(c * kh * kw, oh * ow), _s(c, h, w)]

    if op in ("maxpool_f", "maxpool_b", "avepool_f", "avepool_b"):
        n, c, h, w, kh, kw, sh, sw, ph, pw = _pool_geom(spec)
        oh, ow = pooled_dim(h, kh, ph, sh), pooled_dim(w, kw, pw, sw)

        if op == "maxpool_f":
            def fn(x):
                vals, idx, valid = _window_gather(
                    x, kh, kw, sh, sw, ph, pw, oh, ow, -jnp.inf
                )
                vals = jnp.where(valid[None, None], vals, -jnp.inf)
                arg = jnp.argmax(vals, axis=-1)
                top = jnp.max(vals, axis=-1)
                mask = jnp.take_along_axis(
                    jnp.broadcast_to(idx[None, None], vals.shape).astype(F32),
                    arg[..., None].astype(jnp.int32),
                    axis=-1,
                )[..., 0]
                return top, mask
            return fn, [_s(n, c, h, w)]

        if op == "maxpool_b":
            def fn(td, mask):
                flat_td = td.reshape(n * c, oh * ow)
                flat_mask = mask.reshape(n * c, oh * ow).astype(jnp.int32)
                bd = jnp.zeros((n * c, h * w), F32)
                rows = jnp.arange(n * c)[:, None]
                bd = bd.at[rows, flat_mask].add(flat_td)
                return (bd.reshape(n, c, h, w),)
            return fn, [_s(n, c, oh, ow), _s(n, c, oh, ow)]

        # Caffe's padded-window divisor — from iotas (see _window_gather
        # note on why no numpy constants may be embedded).
        hs0 = jnp.arange(oh, dtype=jnp.float32)[:, None] * sh - ph
        ws0 = jnp.arange(ow, dtype=jnp.float32)[None, :] * sw - pw
        he0 = jnp.minimum(hs0 + kh, h + ph)
        we0 = jnp.minimum(ws0 + kw, w + pw)
        jdiv = jnp.broadcast_to((he0 - hs0) * (we0 - ws0), (oh, ow))

        if op == "avepool_f":
            def fn(x):
                vals, _, valid = _window_gather(x, kh, kw, sh, sw, ph, pw, oh, ow, 0.0)
                vals = jnp.where(valid[None, None], vals, 0.0)
                return (vals.sum(axis=-1) / jdiv[None, None],)
            return fn, [_s(n, c, h, w)]

        def fn(td):  # avepool_b: scatter shares back
            share = td / jdiv[None, None]
            padded = jnp.zeros((n, c, h + 2 * ph + kh, w + 2 * pw + kw), F32)
            for ki in range(kh):
                for kj in range(kw):
                    padded = padded.at[
                        :, :, ki:ki + sh * oh:sh, kj:kj + sw * ow:sw
                    ].add(share)
            return (padded[:, :, ph:ph + h, pw:pw + w],)
        return fn, [_s(n, c, oh, ow)]

    if op == "lrn_scale":
        num, c, dim = int(spec["num"]), int(spec["channels"]), int(spec["dim"])
        ls = int(spec["local_size"])
        half = (ls - 1) // 2

        def fn(alpha, k, x):
            sq = x * x
            acc = jnp.zeros_like(x)
            for off in range(-half, half + 1):
                if off < 0:
                    acc = acc.at[:, -off:, :].add(sq[:, :off, :])
                elif off > 0:
                    acc = acc.at[:, :-off, :].add(sq[:, off:, :])
                else:
                    acc = acc + sq
            return (k + alpha / ls * acc,)

        return fn, [SCALAR, SCALAR, _s(num, c, dim)]

    if op == "lrn_output":
        n = int(spec["n"])
        return (
            lambda beta, x, scale: (x * jnp.power(scale, -beta),),
            [SCALAR, _s(n), _s(n)],
        )

    if op == "lrn_diff":
        num, c, dim = int(spec["num"]), int(spec["channels"]), int(spec["dim"])
        ls = int(spec["local_size"])
        half = (ls - 1) // 2

        def fn(alpha, beta, x, top, scale, td):
            ratio = td * top / scale
            acc = jnp.zeros_like(x)
            for off in range(-half, half + 1):
                if off < 0:
                    acc = acc.at[:, -off:, :].add(ratio[:, :off, :])
                elif off > 0:
                    acc = acc.at[:, :-off, :].add(ratio[:, off:, :])
                else:
                    acc = acc + ratio
            cache = 2.0 * alpha * beta / ls
            return (td * jnp.power(scale, -beta) - cache * x * acc,)

        dims = _s(num, c, dim)
        return fn, [SCALAR, SCALAR, dims, dims, dims, dims]

    if op == "softmax":
        n, c = int(spec["n"]), int(spec["c"])

        def fn(x):
            m = jnp.max(x, axis=1, keepdims=True)
            e = jnp.exp(x - m)
            return (e / jnp.sum(e, axis=1, keepdims=True),)

        return fn, [_s(n, c)]

    if op == "softmaxloss_f":
        n, c = int(spec["n"]), int(spec["c"])

        def fn(prob, labels):
            p = jnp.take_along_axis(
                prob, labels.astype(jnp.int32)[:, None], axis=1
            )[:, 0]
            p = jnp.maximum(p, jnp.finfo(F32).tiny)
            return (-jnp.log(p).mean()[None],)

        return fn, [_s(n, c), _s(n)]

    if op == "softmaxloss_b":
        n, c = int(spec["n"]), int(spec["c"])

        def fn(weight, prob, labels):
            onehot = jax.nn.one_hot(labels.astype(jnp.int32), c, dtype=F32)
            return ((prob - onehot) * (weight / n),)

        return fn, [SCALAR, _s(n, c), _s(n)]

    # ---- solver updates (paper §4.3 compute-update kernels) ----
    if op == "sgd":
        n = int(spec["n"])

        def fn(lr, momentum, diff, hist, data):
            h2 = momentum * hist + lr * diff
            return h2, data - h2

        return fn, [SCALAR, SCALAR, _s(n), _s(n), _s(n)]

    if op == "nesterov":
        n = int(spec["n"])

        def fn(lr, momentum, diff, hist, data):
            h2 = momentum * hist + lr * diff
            return h2, data - ((1 + momentum) * h2 - momentum * hist)

        return fn, [SCALAR, SCALAR, _s(n), _s(n), _s(n)]

    if op == "adagrad":
        n = int(spec["n"])

        def fn(lr, delta, diff, hist, data):
            h2 = hist + diff * diff
            return h2, data - lr * diff / (jnp.sqrt(h2) + delta)

        return fn, [SCALAR, SCALAR, _s(n), _s(n), _s(n)]

    if op == "rmsprop":
        n = int(spec["n"])

        def fn(lr, decay, delta, diff, hist, data):
            h2 = decay * hist + (1 - decay) * diff * diff
            return h2, data - lr * diff / (jnp.sqrt(h2) + delta)

        return fn, [SCALAR, SCALAR, SCALAR, _s(n), _s(n), _s(n)]

    if op == "adadelta":
        n = int(spec["n"])

        def fn(momentum, delta, lr, diff, hg, hu, data):
            hg2 = momentum * hg + (1 - momentum) * diff * diff
            update = diff * jnp.sqrt((hu + delta) / (hg2 + delta))
            hu2 = momentum * hu + (1 - momentum) * update * update
            return hg2, hu2, data - lr * update

        return fn, [SCALAR, SCALAR, SCALAR, _s(n), _s(n), _s(n), _s(n)]

    if op == "adam":
        n = int(spec["n"])

        def fn(lr, b1, b2, delta, t, diff, m, v, data):
            m2 = b1 * m + (1 - b1) * diff
            v2 = b2 * v + (1 - b2) * diff * diff
            corr = jnp.sqrt(1 - jnp.power(b2, t)) / (1 - jnp.power(b1, t))
            return m2, v2, data - lr * corr * m2 / (jnp.sqrt(v2) + delta)

        return fn, [SCALAR, SCALAR, SCALAR, SCALAR, SCALAR, _s(n), _s(n), _s(n), _s(n)]

    raise ValueError(f"unknown op '{op}'")
