//! Cross-channel Local Response Normalization, split into the paper's
//! three kernels (Table 2: `LRN_Scale`, `LRN_Output`, `LRN_Diff`):
//!
//!   scale[i]  = k + (alpha/n) * sum_{j in window(i)} x[j]^2
//!   top[i]    = x[i] * scale[i]^(-beta)
//!   bdiff[i]  = tdiff[i]*scale[i]^(-beta)
//!               - (2*alpha*beta/n) * x[i] * sum_{j} tdiff[j]*top[j]/scale[j]
//!
//! matching Caffe's `LRNLayer` (ACROSS_CHANNELS).
//!
//! The per-image kernels carry the numerics; `lrn_scale_batch` /
//! `lrn_diff_batch` shard the batch across the intra-op pool (disjoint
//! per-image planes), and `lrn_output` — a flat powf map — shards
//! elementwise.

use crate::util::pool as thr;

/// scale = k + (alpha/local_size) * window-sum of squares, per channel.
/// Shapes: (channels, dim) where dim = H*W for one image.
pub fn lrn_scale(
    bottom: &[f32],
    scale: &mut [f32],
    channels: usize,
    dim: usize,
    local_size: usize,
    alpha: f32,
    k: f32,
) {
    assert!(bottom.len() >= channels * dim && scale.len() >= channels * dim);
    let half = (local_size - 1) / 2;
    let a = alpha / local_size as f32;
    for d in 0..dim {
        for c in 0..channels {
            let lo = c.saturating_sub(half);
            let hi = (c + half + 1).min(channels);
            let mut acc = 0.0f32;
            for j in lo..hi {
                let v = bottom[j * dim + d];
                acc += v * v;
            }
            scale[c * dim + d] = k + a * acc;
        }
    }
}

/// top = bottom * scale^(-beta)
pub fn lrn_output(bottom: &[f32], scale: &[f32], top: &mut [f32], beta: f32) {
    assert!(bottom.len() == scale.len() && scale.len() == top.len());
    thr::parallel_chunks_mut(top, super::blas1::GRAIN_POWF, |off, tc| {
        let bc = &bottom[off..off + tc.len()];
        let sc = &scale[off..off + tc.len()];
        for ((t, &bv), &sv) in tc.iter_mut().zip(bc.iter()).zip(sc.iter()) {
            *t = bv * sv.powf(-beta);
        }
    });
}

/// Batched `lrn_scale`: `num` images of (channels, dim), images sharded
/// across the intra-op pool.
#[allow(clippy::too_many_arguments)]
pub fn lrn_scale_batch(
    num: usize,
    bottom: &[f32],
    scale: &mut [f32],
    channels: usize,
    dim: usize,
    local_size: usize,
    alpha: f32,
    k: f32,
) {
    let plane = channels * dim;
    assert!(bottom.len() >= num * plane && scale.len() >= num * plane);
    let sp = thr::SendPtr::new(scale.as_mut_ptr());
    thr::parallel_for(0..num, 1, |r| {
        for i in r {
            // Safety: image planes are disjoint across tasks.
            let s = unsafe { sp.slice(i * plane, plane) };
            lrn_scale(
                &bottom[i * plane..(i + 1) * plane],
                s,
                channels,
                dim,
                local_size,
                alpha,
                k,
            );
        }
    });
}

/// Batched `lrn_diff`, images sharded across the intra-op pool.
#[allow(clippy::too_many_arguments)]
pub fn lrn_diff_batch(
    num: usize,
    bottom: &[f32],
    top: &[f32],
    scale: &[f32],
    top_diff: &[f32],
    bottom_diff: &mut [f32],
    channels: usize,
    dim: usize,
    local_size: usize,
    alpha: f32,
    beta: f32,
) {
    let plane = channels * dim;
    assert!(bottom_diff.len() >= num * plane);
    let bp = thr::SendPtr::new(bottom_diff.as_mut_ptr());
    thr::parallel_for(0..num, 1, |r| {
        for i in r {
            let pr = i * plane..(i + 1) * plane;
            // Safety: image planes are disjoint across tasks.
            let bd = unsafe { bp.slice(i * plane, plane) };
            lrn_diff(
                &bottom[pr.clone()],
                &top[pr.clone()],
                &scale[pr.clone()],
                &top_diff[pr],
                bd,
                channels,
                dim,
                local_size,
                alpha,
                beta,
            );
        }
    });
}

/// LRN backward (one image).
#[allow(clippy::too_many_arguments)]
pub fn lrn_diff(
    bottom: &[f32],
    top: &[f32],
    scale: &[f32],
    top_diff: &[f32],
    bottom_diff: &mut [f32],
    channels: usize,
    dim: usize,
    local_size: usize,
    alpha: f32,
    beta: f32,
) {
    let half = (local_size - 1) / 2;
    let cache_ratio = 2.0 * alpha * beta / local_size as f32;
    for d in 0..dim {
        for c in 0..channels {
            let i = c * dim + d;
            let mut acc = 0.0f32;
            let lo = c.saturating_sub(half);
            let hi = (c + half + 1).min(channels);
            for j in lo..hi {
                let jj = j * dim + d;
                acc += top_diff[jj] * top[jj] / scale[jj];
            }
            bottom_diff[i] = top_diff[i] * scale[i].powf(-beta) - cache_ratio * bottom[i] * acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tcheck;

    #[test]
    fn scale_with_k_only() {
        // zero input → scale = k everywhere
        let bottom = vec![0.0; 6];
        let mut scale = vec![0.0; 6];
        lrn_scale(&bottom, &mut scale, 3, 2, 3, 2.0, 1.5);
        assert!(scale.iter().all(|&v| v == 1.5));
    }

    #[test]
    fn scale_window_clips_at_edges() {
        // channels=3, dim=1, local_size=3, alpha=3 (so alpha/n = 1), k=0
        let bottom = [1.0, 2.0, 3.0];
        let mut scale = [0.0; 3];
        lrn_scale(&bottom, &mut scale, 3, 1, 3, 3.0, 0.0);
        // c0 window {0,1}: 1+4=5; c1 {0,1,2}: 14; c2 {1,2}: 13
        assert_eq!(scale, [5.0, 14.0, 13.0]);
    }

    #[test]
    fn output_formula() {
        let bottom = [2.0];
        let scale = [4.0];
        let mut top = [0.0];
        lrn_output(&bottom, &scale, &mut top, 0.5);
        assert!((top[0] - 1.0).abs() < 1e-6); // 2 * 4^-0.5 = 1
    }

    #[test]
    fn gradient_matches_fd() {
        tcheck::check("lrn_fd", 12, |rng| {
            let channels = rng.range_u(3, 6) as usize;
            let dim = rng.range_u(1, 4) as usize;
            let local_size = 3;
            let (alpha, beta, k) = (1e-1, 0.75, 1.0);
            let n = channels * dim;
            let mut bottom = vec![0.0; n];
            rng.fill_uniform(&mut bottom, -1.0, 1.0);
            let mut td = vec![0.0; n];
            rng.fill_uniform(&mut td, -1.0, 1.0);

            let fwd = |b: &[f32]| -> Vec<f32> {
                let mut s = vec![0.0; n];
                let mut t = vec![0.0; n];
                lrn_scale(b, &mut s, channels, dim, local_size, alpha, k);
                lrn_output(b, &s, &mut t, beta);
                t
            };

            let mut scale = vec![0.0; n];
            lrn_scale(&bottom, &mut scale, channels, dim, local_size, alpha, k);
            let mut top = vec![0.0; n];
            lrn_output(&bottom, &scale, &mut top, beta);
            let mut bd = vec![0.0; n];
            lrn_diff(
                &bottom, &top, &scale, &td, &mut bd, channels, dim, local_size, alpha, beta,
            );

            let eps = 1e-3;
            for i in 0..n {
                let mut bp = bottom.clone();
                bp[i] += eps;
                let mut bm = bottom.clone();
                bm[i] -= eps;
                let (fp, fm) = (fwd(&bp), fwd(&bm));
                let fd: f32 = fp
                    .iter()
                    .zip(fm.iter())
                    .zip(td.iter())
                    .map(|((p, m), t)| (p - m) / (2.0 * eps) * t)
                    .sum();
                if (fd - bd[i]).abs() > 2e-2 {
                    return Err(format!("lrn fd mismatch at {i}: {fd} vs {}", bd[i]));
                }
            }
            Ok(())
        });
    }
}
