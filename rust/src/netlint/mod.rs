//! netlint: static analysis of nets and solver configs.
//!
//! A parsed [`NetParameter`] is analyzed **without allocating blobs or
//! touching a device** — the FPGA-deployment precondition: misconfigured
//! nets must fail at admission (or in `fecaffe lint`) with a structured
//! diagnostic, not deep inside `setup`/`reshape`/`forward` after DDR and
//! batch slots were committed. Five passes:
//!
//! 1. **graph** ([`graph`]) — dangling bottoms, forward references /
//!    cycles, duplicate tops, dead layers, phase-inconsistent wiring;
//! 2. **shapes** ([`shapes`]) — allocation-free shape inference over the
//!    whole DAG (the split-inserted graph, so blob names match
//!    [`crate::net::Net`] exactly), per serving bucket, reusing the same
//!    geometry math as `Layer::reshape`;
//! 3. **alias** ([`alias`]) — in-place aliasing safety;
//! 4. **memory** ([`memory`]) — blob liveness, peak-activation / reuse
//!    report and DDR-budget fit per bucket against
//!    [`crate::device::fpga::costmodel::BoardParams`];
//! 5. **solver** ([`solver`]) — lr-schedule sanity and train→deploy
//!    parameter-projection compatibility with
//!    [`crate::net::WeightSnapshot::project`].
//!
//! Diagnostics carry stable `NLxxxx` codes (grep-able, asserted by the
//! golden test suite) and render as text or JSON. The serving engine
//! runs the linter at model admission and refuses error-severity nets
//! with a typed [`LintError`].

pub mod alias;
pub mod graph;
pub mod memory;
pub mod shapes;
pub mod solver;

use crate::device::fpga::costmodel::BoardParams;
use crate::proto::{NetParameter, Phase, SolverParameter};
use crate::util::json::Json;

pub use memory::BucketMemoryReport;
pub use shapes::infer_shapes;

/// Diagnostic severity. `Error` findings make a net unservable
/// (admission refuses it); `Warning` findings are reported and fail
/// `fecaffe lint --deny-warnings`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding. `code` is a stable `NLxxxx` identifier (see the README
/// code table); `layer` names the offending layer when there is one.
#[derive(Debug, Clone)]
pub struct LintDiagnostic {
    pub code: &'static str,
    pub severity: Severity,
    pub layer: Option<String>,
    pub message: String,
    pub help: Option<String>,
}

impl LintDiagnostic {
    pub fn error(code: &'static str, layer: Option<&str>, message: String) -> LintDiagnostic {
        LintDiagnostic {
            code,
            severity: Severity::Error,
            layer: layer.map(str::to_string),
            message,
            help: None,
        }
    }

    pub fn warning(code: &'static str, layer: Option<&str>, message: String) -> LintDiagnostic {
        LintDiagnostic {
            code,
            severity: Severity::Warning,
            layer: layer.map(str::to_string),
            message,
            help: None,
        }
    }

    pub fn with_help(mut self, help: impl Into<String>) -> LintDiagnostic {
        self.help = Some(help.into());
        self
    }
}

/// What to lint and against which budget.
#[derive(Debug, Clone)]
pub struct LintOptions {
    /// Phase whose layer graph is analyzed.
    pub phase: Phase,
    /// Serving batch buckets for deploy-style nets (explicit `input`
    /// blobs): shape inference and the memory pass run per bucket, with
    /// the first input's batch dimension rewritten exactly like
    /// [`crate::net::Net::reshape_batch`]. Empty → one pass at the
    /// declared shapes (data-layer-fed training nets always take the
    /// single pass at their configured batch).
    pub buckets: Vec<usize>,
    /// Board the DDR-fit check runs against (paper Table 4: 2 GB).
    pub board: BoardParams,
    /// Forward-only (serving) memory accounting: activations and params
    /// count data only; training counts data + diff.
    pub forward_only: bool,
    /// Solver config to check (lr schedule sanity).
    pub solver: Option<SolverParameter>,
    /// For train_val nets: verify the train net's parameter schema can
    /// satisfy [`crate::net::WeightSnapshot::project`] onto the derived
    /// deploy net.
    pub check_deploy_projection: bool,
    /// Serving precision the memory pass accounts at: every device
    /// buffer is costed at this precision's element width (fp32 4 B,
    /// fp16 2 B, int8 1 B). When an fp32 footprint exceeds the board,
    /// the linter also reports whether int8 quantization would rescue
    /// the fit (`NL0303` when even that is not enough).
    pub precision: crate::quant::Precision,
}

impl Default for LintOptions {
    fn default() -> Self {
        LintOptions {
            phase: Phase::Test,
            buckets: Vec::new(),
            board: BoardParams::default(),
            forward_only: false,
            solver: None,
            check_deploy_projection: false,
            precision: crate::quant::Precision::Fp32,
        }
    }
}

/// Result of linting one net: diagnostics plus the per-bucket memory
/// reports (present when the net was structurally sound enough to infer
/// shapes).
#[derive(Debug, Clone)]
pub struct LintReport {
    pub net: String,
    pub diagnostics: Vec<LintDiagnostic>,
    pub memory: Vec<BucketMemoryReport>,
}

impl LintReport {
    pub fn error_count(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Warning)
            .count()
    }

    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Distinct codes of error-severity findings, in first-seen order.
    pub fn error_codes(&self) -> Vec<&'static str> {
        let mut codes = Vec::new();
        for d in &self.diagnostics {
            if d.severity == Severity::Error && !codes.contains(&d.code) {
                codes.push(d.code);
            }
        }
        codes
    }

    pub fn render_text(&self) -> String {
        let mut out = format!(
            "netlint: {}: {} error(s), {} warning(s)\n",
            self.net,
            self.error_count(),
            self.warning_count()
        );
        for d in &self.diagnostics {
            let at = d
                .layer
                .as_deref()
                .map(|l| format!(" layer '{l}':"))
                .unwrap_or_default();
            out.push_str(&format!(
                "  {}[{}]{at} {}\n",
                d.severity.label(),
                d.code,
                d.message
            ));
            if let Some(h) = &d.help {
                out.push_str(&format!("    help: {h}\n"));
            }
        }
        if !self.memory.is_empty() {
            out.push_str("  memory (per batch bucket, estimated device-DDR footprint):\n");
            for m in &self.memory {
                out.push_str(&format!(
                    "    batch {:>4}: total {:>8} = act {} + params {} + scratch {} + aux {} \
                     (peak-live act {}, reuse headroom {}) — {} of {} capacity\n",
                    m.bucket,
                    fmt_bytes(m.total_bytes),
                    fmt_bytes(m.activation_bytes),
                    fmt_bytes(m.param_bytes),
                    fmt_bytes(m.scratch_bytes),
                    fmt_bytes(m.aux_bytes),
                    fmt_bytes(m.peak_activation_bytes),
                    fmt_bytes(m.reuse_headroom_bytes),
                    if m.fits() { "fits" } else { "EXCEEDS" },
                    fmt_bytes(m.ddr_capacity_bytes),
                ));
            }
        }
        out
    }

    pub fn render_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("net", Json::str(self.net.clone()));
        o.set("errors", Json::num(self.error_count() as f64));
        o.set("warnings", Json::num(self.warning_count() as f64));
        o.set(
            "diagnostics",
            Json::arr(self.diagnostics.iter().map(|d| {
                let mut j = Json::obj();
                j.set("code", Json::str(d.code));
                j.set("severity", Json::str(d.severity.label()));
                if let Some(l) = &d.layer {
                    j.set("layer", Json::str(l.clone()));
                }
                j.set("message", Json::str(d.message.clone()));
                if let Some(h) = &d.help {
                    j.set("help", Json::str(h.clone()));
                }
                j
            })),
        );
        o.set(
            "memory",
            Json::arr(self.memory.iter().map(|m| {
                let mut j = Json::obj();
                j.set("bucket", Json::num(m.bucket as f64));
                j.set("activation_bytes", Json::num(m.activation_bytes as f64));
                j.set("param_bytes", Json::num(m.param_bytes as f64));
                j.set("scratch_bytes", Json::num(m.scratch_bytes as f64));
                j.set("aux_bytes", Json::num(m.aux_bytes as f64));
                j.set("total_bytes", Json::num(m.total_bytes as f64));
                j.set(
                    "peak_activation_bytes",
                    Json::num(m.peak_activation_bytes as f64),
                );
                j.set(
                    "reuse_headroom_bytes",
                    Json::num(m.reuse_headroom_bytes as f64),
                );
                j.set("ddr_capacity_bytes", Json::num(m.ddr_capacity_bytes as f64));
                j.set("fits", Json::Bool(m.fits()));
                j
            })),
        );
        o
    }
}

fn fmt_bytes(b: u64) -> String {
    const MIB: f64 = 1024.0 * 1024.0;
    let b = b as f64;
    if b >= 1024.0 * MIB {
        format!("{:.2}GiB", b / (1024.0 * MIB))
    } else if b >= MIB {
        format!("{:.1}MiB", b / MIB)
    } else {
        format!("{:.1}KiB", b / 1024.0)
    }
}

/// Typed admission-refusal error: a net with error-severity findings.
/// Carries the full report; `Display` stays one-line (with the NL codes)
/// so it reads well inside an `anyhow` chain — callers print
/// `report.render_text()` for the details.
#[derive(Debug)]
pub struct LintError {
    pub report: LintReport,
}

impl LintError {
    pub fn new(report: LintReport) -> LintError {
        LintError { report }
    }
}

impl std::fmt::Display for LintError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "net '{}' rejected by netlint: {} error(s) [{}]",
            self.report.net,
            self.report.error_count(),
            self.report.error_codes().join(", ")
        )
    }
}

impl std::error::Error for LintError {}

/// Run all static passes over `param` and collect a report.
pub fn lint_net(param: &NetParameter, opts: &LintOptions) -> LintReport {
    let mut diags = Vec::new();

    // Pass 1: graph hygiene (+ phase cross-check).
    graph::check(param, opts.phase, &mut diags);
    // Pass 3 needs only the phase graph, not shapes.
    let layers: Vec<crate::proto::LayerParameter> = param
        .layers_for_phase(opts.phase)
        .into_iter()
        .cloned()
        .collect();
    alias::check(&layers, &mut diags);

    // Pass 2: shape inference over the split-inserted graph, so blob
    // names (including `_split_` aliases) match `Net::from_param`.
    let with_splits = crate::net::insert_splits(&layers);
    let buckets: Vec<Option<usize>> = if param.inputs.is_empty() || opts.buckets.is_empty() {
        vec![None]
    } else {
        opts.buckets.iter().map(|&b| Some(b)).collect()
    };
    let mut shape_sets = Vec::new();
    for (i, b) in buckets.iter().enumerate() {
        // Geometry diagnostics are batch-independent — collect them once
        // (first bucket) instead of once per bucket.
        let mut sink = Vec::new();
        let shapes = shapes::infer_with_splits(&with_splits, &param.inputs, *b, &mut sink);
        if i == 0 {
            diags.extend(sink);
        }
        shape_sets.push((*b, shapes));
    }

    // Pass 4: memory/liveness + DDR fit, only on structurally sound nets
    // (footprints derived from partial shapes would mislead).
    let mut memory = Vec::new();
    if !diags.iter().any(|d| d.severity == Severity::Error) {
        for (b, shapes) in &shape_sets {
            let bucket = b.unwrap_or_else(|| default_batch(param, opts.phase));
            let rep = memory::analyze(
                &with_splits,
                shapes,
                bucket,
                opts.forward_only,
                &opts.board,
                opts.precision.elem_bytes(),
            );
            if !rep.fits() {
                // Would the int8 grid rescue the fit? Re-run the pass at
                // 1 B/element: if even the quantized footprint exceeds
                // the board, say so (NL0303) — the standard "just
                // quantize it" escape hatch is closed for this net.
                let int8 = memory::analyze(
                    &with_splits,
                    shapes,
                    bucket,
                    opts.forward_only,
                    &opts.board,
                    crate::quant::Precision::Int8.elem_bytes(),
                );
                diags.push(
                    LintDiagnostic::error(
                        "NL0301",
                        None,
                        format!(
                            "batch {}: estimated DDR footprint {} ({}) exceeds board capacity {}",
                            rep.bucket,
                            fmt_bytes(rep.total_bytes),
                            opts.precision.label(),
                            fmt_bytes(rep.ddr_capacity_bytes)
                        ),
                    )
                    .with_help(if int8.fits() && opts.precision != crate::quant::Precision::Int8 {
                        format!(
                            "reduce the batch size, serve with a smaller max_batch, or serve the \
                             int8 variant (`name@int8`): quantized footprint {} fits \
                             (paper §4.4: VGG-16 training at batch 32 does not fit 2 GB DDR)",
                            fmt_bytes(int8.total_bytes)
                        )
                    } else {
                        "reduce the batch size, or serve with a smaller max_batch \
                         (paper §4.4: VGG-16 training at batch 32 does not fit 2 GB DDR)"
                            .to_string()
                    }),
                );
                if !int8.fits() {
                    diags.push(LintDiagnostic::warning(
                        "NL0303",
                        None,
                        format!(
                            "batch {}: even int8-quantized, the estimated DDR footprint {} \
                             exceeds board capacity {} — reduced precision cannot make this \
                             configuration servable",
                            rep.bucket,
                            fmt_bytes(int8.total_bytes),
                            fmt_bytes(int8.ddr_capacity_bytes)
                        ),
                    ));
                }
            } else if rep.total_bytes.saturating_mul(10) > rep.ddr_capacity_bytes.saturating_mul(9)
            {
                diags.push(LintDiagnostic::warning(
                    "NL0302",
                    None,
                    format!(
                        "batch {}: estimated DDR footprint {} is above 90% of board capacity {}",
                        rep.bucket,
                        fmt_bytes(rep.total_bytes),
                        fmt_bytes(rep.ddr_capacity_bytes)
                    ),
                ));
            }
            memory.push(rep);
        }
    }

    // Pass 5: solver schedule + train→deploy projection schema.
    solver::check(param, opts, &mut diags);

    LintReport {
        net: param.name.clone(),
        diagnostics: diags,
        memory,
    }
}

/// Batch size a data-layer-fed net runs at (for memory-report labeling
/// when there is no explicit input to re-bucket).
fn default_batch(param: &NetParameter, phase: Phase) -> usize {
    param
        .layers_for_phase(phase)
        .iter()
        .find_map(|l| l.data.as_ref().map(|d| d.batch_size))
        .or_else(|| param.inputs.first().map(|(_, s)| s[0]))
        .unwrap_or(1)
}
