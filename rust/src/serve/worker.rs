//! Worker pool: each worker thread owns warm net replicas bound to its
//! own device and drains the shared dispatch queue.
//!
//! `Net` is built on `Rc<RefCell<Blob>>` and cannot cross threads, so a
//! worker *builds* its replicas inside the thread from the (Send)
//! `NetParameter` and adopts the engine's `WeightSnapshot` — the
//! `Arc`-shared host weights. Activations, scratch buffers and the
//! device are all private to the worker, which is what makes N workers
//! run forwards concurrently without any locking on the hot path.
//!
//! A worker pre-builds two replica shapes at startup — full `max_batch`
//! for coalesced traffic and batch-1 for lone requests — so the common
//! low-occupancy case doesn't pay a full-batch forward per request, and
//! no net construction ever happens on the serving path.
//!
//! **Weight hot-swap**: before executing each popped batch the worker
//! compares the engine's published weights version (one atomic load)
//! against the version its replicas carry; on a mismatch it takes the
//! slot lock once, adopts the new snapshot into *both* replicas, and
//! only then serves. Adoption is O(1) per blob (`Arc` attach), batches
//! already popped finish on the version they started with, and every
//! response is stamped with exactly the version that computed it.

use super::batcher::{gather, scatter, Batch};
use super::engine::{DeviceKind, SharedWeights};
use super::metrics::Metrics;
use super::queue::SharedQueue;
use crate::device::Device;
use crate::layers::SharedBlob;
use crate::net::{Net, WeightSnapshot};
use crate::proto::Phase;
use crate::zoo::DeployNet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

pub(crate) struct WorkerContext {
    pub id: usize,
    pub deploy: DeployNet,
    /// The engine's published-weights cell (version + snapshot slot).
    pub weights: Arc<SharedWeights>,
    pub device: DeviceKind,
    /// Intra-op threads this worker's kernels may fan out to (the
    /// engine's share of the process budget; see `util::pool`).
    pub intra_op: usize,
    /// Elements per output row (classes).
    pub output_len: usize,
    pub queue: Arc<SharedQueue<Batch>>,
    pub metrics: Arc<Metrics>,
    /// Workers still able to serve (shared across the pool).
    pub healthy: Arc<AtomicUsize>,
}

impl WorkerContext {
    /// Snapshot currently published by the engine (cloned `Arc`).
    fn current_weights(&self) -> Arc<WeightSnapshot> {
        self.weights.slot.lock().unwrap().clone()
    }
}

/// Retires the worker from `healthy` however the thread exits — clean
/// return, failed build, or panic mid-batch. The last worker out closes
/// and fail-drains the dispatch queue, so the batcher can never block
/// pushing into a dead pool and no caller hangs on a queued request.
struct PoolGuard {
    queue: Arc<SharedQueue<Batch>>,
    healthy: Arc<AtomicUsize>,
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        if self.healthy.fetch_sub(1, Ordering::AcqRel) > 1 {
            return; // healthy workers remain; they keep draining
        }
        self.queue.close();
        while let Some(batch) = self.queue.pop() {
            for req in batch.requests {
                req.fail("serving worker pool exhausted");
            }
        }
    }
}

/// One net replica at a fixed batch shape.
struct Replica {
    net: Net,
    input: SharedBlob,
    output: SharedBlob,
    batch: usize,
}

impl Replica {
    fn build(
        ctx: &WorkerContext,
        batch: usize,
        snap: &WeightSnapshot,
        dev: &mut dyn Device,
    ) -> anyhow::Result<Replica> {
        let mut param = ctx.deploy.param.clone();
        anyhow::ensure!(!param.inputs.is_empty(), "deploy param has no inputs");
        param.inputs[0].1[0] = batch;
        let mut net = Net::from_param(&param, Phase::Test, dev)?;
        net.adopt_weights(dev, snap)?;
        let input = net
            .blob(&ctx.deploy.input)
            .ok_or_else(|| anyhow::anyhow!("input blob '{}' missing", ctx.deploy.input))?;
        let output = net
            .blob(&ctx.deploy.output)
            .ok_or_else(|| anyhow::anyhow!("output blob '{}' missing", ctx.deploy.output))?;
        Ok(Replica { net, input, output, batch })
    }

    /// Execute one coalesced batch and scatter the results, stamping
    /// every response with the weights version that computed it.
    fn serve(&mut self, dev: &mut dyn Device, batch: Batch, ctx: &WorkerContext, version: u64) {
        let k = batch.requests.len();
        let samples: Vec<&[f32]> =
            batch.requests.iter().map(|r| r.sample.as_slice()).collect();
        let packed = gather(&samples, ctx.deploy.sample_len, self.batch);
        drop(samples);
        self.input.borrow_mut().set_data(dev, &packed);
        // On the FPGA sim, meter the batch in *simulated* device time so
        // batching policy can be judged against the paper's cost model.
        let sim_before = dev.sim_clock_ns();
        match self.net.forward(dev) {
            Ok(_) => {
                if let (Some(t0), Some(t1)) = (sim_before, dev.sim_clock_ns()) {
                    ctx.metrics.record_sim_batch(t1.saturating_sub(t0));
                }
                let out = self.output.borrow_mut().data_vec(dev);
                let rows = scatter(&out, ctx.output_len, k);
                for (req, row) in batch.requests.into_iter().zip(rows) {
                    let ns = req.submitted.elapsed().as_nanos() as u64;
                    req.fulfill(row, version);
                    ctx.metrics.record_done(ns);
                }
            }
            Err(e) => {
                let msg = format!("worker {}: forward failed: {e:#}", ctx.id);
                for req in batch.requests {
                    req.fail(&msg);
                }
            }
        }
    }
}

pub(crate) fn run(ctx: WorkerContext) {
    let _guard = PoolGuard {
        queue: ctx.queue.clone(),
        healthy: ctx.healthy.clone(),
    };

    // This worker's share of the machine: everything executed on this
    // thread (replica build and every kernel) fans out at most
    // `intra_op` wide, so N workers never oversubscribe the pool.
    crate::util::pool::set_intra_op(ctx.intra_op);

    let mut dev: Box<dyn Device> = ctx.device.create();

    // Pre-build both replica shapes before taking traffic, so no net
    // construction (layer setup + weight-filler init) ever lands on the
    // serving path. The full-batch replica is mandatory (the guard
    // retires this worker if it fails); the batch-1 replica is a
    // fast-path optimization and its absence only costs padding.
    let snap = ctx.current_weights();
    let mut version = snap.version();
    let max_batch = ctx.deploy.batch;
    let mut full = match Replica::build(&ctx, max_batch, &snap, dev.as_mut()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("[serve] worker {}: replica build failed: {e:#}", ctx.id);
            return;
        }
    };
    let mut single = if max_batch > 1 {
        match Replica::build(&ctx, 1, &snap, dev.as_mut()) {
            Ok(r) => Some(r),
            Err(e) => {
                eprintln!(
                    "[serve] worker {}: batch-1 replica build failed ({e:#}); \
                     lone requests will pad to the full batch",
                    ctx.id
                );
                None
            }
        }
    } else {
        None
    };
    drop(snap);

    while let Some(batch) = ctx.queue.pop() {
        // Batch boundary: adopt a newly published snapshot before
        // executing. One relaxed-cost atomic load in the common case;
        // the slot lock is only taken when the version actually moved.
        if ctx.weights.version.load(Ordering::Acquire) != version {
            let snap = ctx.current_weights();
            // Adopt the batch-1 fast path first: if it can't follow the
            // swap, drop it rather than risk serving two versions from
            // one worker. (The engine validated the snapshot against
            // the shared schema, so failures here indicate a bug, not
            // bad input.)
            let mut drop_single = false;
            if let Some(s) = single.as_mut() {
                if let Err(e) = s.net.adopt_weights(dev.as_mut(), &snap) {
                    eprintln!(
                        "[serve] worker {}: batch-1 replica failed to adopt weights v{}: \
                         {e:#}; dropping the fast path",
                        ctx.id,
                        snap.version()
                    );
                    drop_single = true;
                }
            }
            if drop_single {
                single = None;
            }
            match full.net.adopt_weights(dev.as_mut(), &snap) {
                Ok(()) => version = snap.version(),
                Err(e) => {
                    eprintln!(
                        "[serve] worker {}: failed to adopt weights v{}: {e:#}; \
                         still serving v{version}",
                        ctx.id,
                        snap.version()
                    );
                    // The batch-1 replica may already carry the new
                    // weights — drop it so this worker can't serve two
                    // versions at once (padding to full batch is the
                    // only cost).
                    single = None;
                }
            }
        }
        let replica = match (&mut single, batch.requests.len()) {
            (Some(s), 1) => s,
            _ => &mut full,
        };
        replica.serve(dev.as_mut(), batch, &ctx, version);
    }
}
