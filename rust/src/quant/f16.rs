//! IEEE-754 binary16 emulation: f32↔f16 bit conversion with
//! round-to-nearest-even, used by the fp16 storage-emulation path.
//!
//! The fp16 execution mode stores operands on the f16 grid but
//! accumulates in f32 (the usual FPGA half-precision GEMM contract), so
//! only the conversions need to be exact — and they are pinned here
//! against golden IEEE-754 vectors independently of the GEMM path.

/// Convert an f32 to IEEE-754 binary16 bits with round-to-nearest-even.
///
/// Handles subnormals, overflow-to-infinity, and NaN (payload truncated
/// to the high mantissa bits, quiet bit forced so no NaN becomes inf).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf or NaN.
        return if man == 0 {
            sign | 0x7c00
        } else {
            // Keep the top 10 payload bits; force quiet bit so a NaN with
            // only low payload bits does not collapse to infinity.
            sign | 0x7c00 | 0x0200 | ((man >> 13) as u16 & 0x03ff)
        };
    }

    // Unbiased exponent; f16 bias is 15, f32 bias is 127.
    let e = exp - 127 + 15;
    if e >= 0x1f {
        // Overflow → ±inf.
        return sign | 0x7c00;
    }
    if e <= 0 {
        // Subnormal (or underflow to zero). The implicit leading 1 (for
        // normal f32 inputs) joins the mantissa, then we shift right by
        // the subnormal deficit and round to nearest even.
        if e < -10 {
            return sign; // underflows to ±0 even after rounding
        }
        let man = if exp == 0 { man } else { man | 0x0080_0000 };
        let shift = (14 - e) as u32; // bits dropped below the f16 ulp
        let halfway = 1u32 << (shift - 1);
        let q = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let q = if rem > halfway || (rem == halfway && (q & 1) == 1) {
            q + 1 // may carry into the exponent: 0x0400 == smallest normal
        } else {
            q
        };
        return sign | q as u16;
    }

    // Normal: drop 13 mantissa bits with round-to-nearest-even.
    let q = man >> 13;
    let rem = man & 0x1fff;
    let mut out = (sign as u32) | ((e as u32) << 10) | q;
    if rem > 0x1000 || (rem == 0x1000 && (q & 1) == 1) {
        out += 1; // mantissa carry rolls into the exponent correctly
    }
    out as u16
}

/// Convert IEEE-754 binary16 bits to the exactly-representable f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = u32::from(h & 0x8000) << 16;
    let exp = i32::from((h >> 10) & 0x1f);
    let man = u32::from(h & 0x03ff);

    let bits = match (exp, man) {
        (0, 0) => sign,                       // ±0
        (0, _) => {
            // Subnormal man·2^-24: normalize so the leading bit becomes
            // the implicit one. shift = 10 - position_of_leading_bit.
            let shift = man.leading_zeros() - 21;
            let man = (man << shift) & 0x03ff;
            let e = (127 - 14 - shift as i32) as u32;
            sign | (e << 23) | (man << 13)
        }
        (0x1f, 0) => sign | 0x7f80_0000,      // ±inf
        (0x1f, _) => sign | 0x7f80_0000 | (man << 13), // NaN, payload widened
        _ => sign | (((exp - 15 + 127) as u32) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

/// Round an f32 through the f16 grid (the storage-emulation primitive).
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Round a slice through the f16 grid in place.
pub fn f16_round_slice(xs: &mut [f32]) {
    for x in xs {
        *x = f16_round(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden IEEE-754 binary16 vectors: (f32 bits, expected f16 bits).
    /// Sources: the binary16 tables in IEEE 754-2019 §3.6 and the widely
    /// cross-checked conversion corpora (half/numpy agree on all rows).
    const GOLDEN_TO_F16: &[(u32, u16)] = &[
        (0x0000_0000, 0x0000), // +0
        (0x8000_0000, 0x8000), // -0
        (0x3f80_0000, 0x3c00), // 1.0
        (0xbf80_0000, 0xbc00), // -1.0
        (0x4000_0000, 0x4000), // 2.0
        (0x3f00_0000, 0x3800), // 0.5
        (0x4049_0000, 0x4248), // 3.140625 (exact in both formats)
        (0xc5fc_4000, 0xefe2), // -8072.0
    ];

    #[test]
    fn golden_simple_values() {
        for &(fbits, hbits) in GOLDEN_TO_F16 {
            assert_eq!(
                f32_to_f16_bits(f32::from_bits(fbits)),
                hbits,
                "f32 bits {fbits:#010x}"
            );
        }
        // 65504 is the largest finite f16 (0x7bff).
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0);
        // Smallest positive normal f16: 2^-14.
        assert_eq!(f32_to_f16_bits(6.103_515_625e-5), 0x0400);
        // Smallest positive subnormal f16: 2^-24 ≈ 5.960464e-8.
        assert_eq!(f32_to_f16_bits(5.960_464_477_539_063e-8), 0x0001);
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_477_539_063e-8);
        // Largest subnormal: (1023/1024)·2^-14.
        assert_eq!(f16_bits_to_f32(0x03ff), 6.097_555_160_522_461e-5);
        assert_eq!(f32_to_f16_bits(6.097_555_160_522_461e-5), 0x03ff);
    }

    #[test]
    fn round_to_nearest_even_ties() {
        // 1 + 2^-11 is exactly halfway between 1.0 (0x3c00) and the next
        // f16 (0x3c01); the tie must go to the even mantissa (0x3c00).
        let tie_down = 1.0 + 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie_down), 0x3c00);
        // 1 + 3·2^-11 is halfway between 0x3c01 and 0x3c02; the tie goes
        // up to the even 0x3c02.
        let tie_up = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie_up), 0x3c02);
        // Just above the halfway point rounds up.
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11) + 2f32.powi(-20)), 0x3c01);
        // Just below rounds down.
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11) - 2f32.powi(-20)), 0x3c00);
    }

    #[test]
    fn subnormal_ties_round_to_even() {
        // 2^-25 is halfway between 0 and the smallest subnormal (2^-24):
        // ties-to-even keeps the even quotient 0.
        assert_eq!(f32_to_f16_bits(2f32.powi(-25)), 0x0000);
        // 1.5·2^-24 is halfway between 1 and 2 ulps: rounds to even (2).
        assert_eq!(f32_to_f16_bits(1.5 * 2f32.powi(-24)), 0x0002);
        // 2.5·2^-24 is halfway between 2 and 3 ulps: stays at even (2).
        assert_eq!(f32_to_f16_bits(2.5 * 2f32.powi(-24)), 0x0002);
        // Largest subnormal + half ulp carries into the normal range.
        let carry = (1023.5) * 2f32.powi(-24);
        assert_eq!(f32_to_f16_bits(carry), 0x0400);
        // Negative subnormals keep the sign.
        assert_eq!(f32_to_f16_bits(-5.960_464_477_539_063e-8), 0x8001);
    }

    #[test]
    fn infinity_and_overflow() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(0xfc00), f32::NEG_INFINITY);
        // 65520 = 65504 + 16 is exactly halfway to the (unrepresentable)
        // next step; RNE rounds to even → overflow to +inf (IEEE 754
        // round-half-even at the top of the range).
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00);
        // 65519.996… stays at the max finite value.
        assert_eq!(f32_to_f16_bits(65519.0), 0x7bff);
        // Anything ≥ 65536 overflows regardless of rounding.
        assert_eq!(f32_to_f16_bits(1.0e9), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1.0e9), 0xfc00);
    }

    #[test]
    fn nan_payload_preserved_and_quieted() {
        let h = f32_to_f16_bits(f32::NAN);
        assert_eq!(h & 0x7c00, 0x7c00, "NaN exponent all-ones");
        assert_ne!(h & 0x03ff, 0, "NaN mantissa nonzero (did not become inf)");
        // A signaling-style NaN with only low payload bits must not
        // collapse to infinity: the quiet bit is forced.
        let snan = f32::from_bits(0x7f80_0001);
        let h = f32_to_f16_bits(snan);
        assert_eq!(h & 0x7c00, 0x7c00);
        assert_ne!(h & 0x03ff, 0);
        // Round-trip keeps NaN-ness and sign.
        let back = f16_bits_to_f32(f32_to_f16_bits(-f32::NAN));
        assert!(back.is_nan());
        assert!(back.is_sign_negative());
    }

    #[test]
    fn roundtrip_is_identity_on_the_f16_grid() {
        // Every one of the 65536 f16 bit patterns must survive
        // f16→f32→f16 exactly (NaNs compared by bit class).
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            let back = f32_to_f16_bits(x);
            if x.is_nan() {
                assert_eq!(back & 0x7c00, 0x7c00);
                assert_ne!(back & 0x03ff, 0);
                assert_eq!(back & 0x8000, h & 0x8000);
            } else {
                assert_eq!(back, h, "f16 bits {h:#06x} → {x} → {back:#06x}");
            }
        }
    }

    #[test]
    fn widening_is_exact_against_f32_arithmetic() {
        // Every finite f16 equals sign·man·2^(e-25) computed in exact
        // integer arithmetic — an independent check of the widening path.
        for h in 0..=u16::MAX {
            let exp = i32::from((h >> 10) & 0x1f);
            let man = i64::from(h & 0x03ff);
            if exp == 0x1f {
                continue;
            }
            let (sig, e) = if exp == 0 { (man, -24) } else { (man + 1024, exp - 25) };
            let expect = sig as f64 * 2f64.powi(e) * if h & 0x8000 != 0 { -1.0 } else { 1.0 };
            let got = f64::from(f16_bits_to_f32(h));
            assert_eq!(got, expect, "f16 bits {h:#06x}");
        }
    }
}
