//! Pooling layer (MAX/AVE, incl. global pooling) — kernels
//! `Max_pool_F/B`, `Ave_pool_F/B`; one invocation covers the whole batch,
//! matching the paper's instance counts (13 max-pool layers → 13
//! `Max_pool_F` instances for GoogLeNet F→B).

use super::{Layer, SharedBlob};
use crate::blob::Blob;
use crate::device::{Device, Kernel, KernelCall};
use crate::math::PoolGeom;
use crate::proto::{LayerParameter, PoolMethod, PoolingParameter};

pub struct PoolingLayer {
    name: String,
    p: PoolingParameter,
    geom: Option<PoolGeom>,
    num: usize,
    /// argmax mask (device) for MAX backward.
    mask: Option<SharedBlob>,
}

impl PoolingLayer {
    pub fn new(param: &LayerParameter) -> anyhow::Result<PoolingLayer> {
        let p = param
            .pool
            .clone()
            .ok_or_else(|| anyhow::anyhow!("layer {}: missing pooling_param", param.name))?;
        Ok(PoolingLayer { name: param.name.clone(), p, geom: None, num: 0, mask: None })
    }
}

impl Layer for PoolingLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "Pooling"
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        if self.p.method == PoolMethod::Max {
            self.mask = Some(super::shared(Blob::new("mask", &[1])));
        }
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let b = bottoms[0].borrow();
        let (num, c, h, w) = (b.num(), b.channels(), b.height(), b.width());
        drop(b);
        let (kh, kw) = if self.p.global_pooling {
            (h, w)
        } else {
            (self.p.kernel_h, self.p.kernel_w)
        };
        let geom = PoolGeom {
            channels: c,
            height: h,
            width: w,
            kernel_h: kh,
            kernel_w: kw,
            pad_h: self.p.pad_h,
            pad_w: self.p.pad_w,
            stride_h: self.p.stride_h,
            stride_w: self.p.stride_w,
        };
        let (oh, ow) = (geom.out_h(), geom.out_w());
        self.num = num;
        self.geom = Some(geom);
        tops[0].borrow_mut().reshape_grow_only(dev, &[num, c, oh, ow]);
        if let Some(mask) = &self.mask {
            mask.borrow_mut().reshape_grow_only(dev, &[num, c, oh, ow]);
        }
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let geom = self.geom.unwrap();
        let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
        let t_id = tops[0].borrow_mut().data.dev_data_mut(dev);
        match self.p.method {
            PoolMethod::Max => {
                let m_id = self
                    .mask
                    .as_ref()
                    .unwrap()
                    .borrow_mut()
                    .data
                    .dev_data_mut(dev);
                dev.launch(&KernelCall::new(
                    Kernel::MaxPoolF { geom, num: self.num },
                    &[b_id],
                    &[t_id, m_id],
                ))?;
            }
            PoolMethod::Ave => {
                dev.launch(&KernelCall::new(
                    Kernel::AvePoolF { geom, num: self.num },
                    &[b_id],
                    &[t_id],
                ))?;
            }
        }
        Ok(0.0)
    }

    fn backward(
        &mut self,
        dev: &mut dyn Device,
        tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        if !prop_down.first().copied().unwrap_or(true) {
            return Ok(());
        }
        let geom = self.geom.unwrap();
        let td_id = tops[0].borrow_mut().diff.dev_data(dev);
        let bd_id = bottoms[0].borrow_mut().diff.dev_data_mut(dev);
        match self.p.method {
            PoolMethod::Max => {
                let m_id = self.mask.as_ref().unwrap().borrow_mut().data.dev_data(dev);
                dev.launch(&KernelCall::new(
                    Kernel::MaxPoolB { geom, num: self.num },
                    &[td_id, m_id],
                    &[bd_id],
                ))?;
            }
            PoolMethod::Ave => {
                dev.launch(&KernelCall::new(
                    Kernel::AvePoolB { geom, num: self.num },
                    &[td_id],
                    &[bd_id],
                ))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::proto::parse_text;

    fn mk(kind: &str, extra: &str) -> PoolingLayer {
        let text = format!(
            r#"layer {{ name: "p" type: "Pooling" bottom: "x" top: "y"
                 pooling_param {{ pool: {kind} {extra} }} }}"#
        );
        let m = parse_text(&text).unwrap();
        let lp = LayerParameter::from_message(m.msgs("layer").next().unwrap()).unwrap();
        PoolingLayer::new(&lp).unwrap()
    }

    #[test]
    fn max_forward_backward_batch2() {
        let mut dev = CpuDevice::new();
        let mut layer = mk("MAX", "kernel_size: 2 stride: 2");
        let bottom = super::super::shared(Blob::new("x", &[2, 1, 2, 2]));
        let top = super::super::shared(Blob::new("y", &[1]));
        bottom
            .borrow_mut()
            .set_data(&mut dev, &[1.0, 2.0, 3.0, 4.0, 8.0, 7.0, 6.0, 5.0]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        assert_eq!(top.borrow().shape(), &[2, 1, 1, 1]);
        layer.forward(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        assert_eq!(top.borrow_mut().data_vec(&mut dev), vec![4.0, 8.0]);

        top.borrow_mut().set_diff(&mut dev, &[1.0, 2.0]);
        layer
            .backward(&mut dev, &[top], &[true], &[bottom.clone()])
            .unwrap();
        assert_eq!(
            bottom.borrow_mut().diff_vec(&mut dev),
            vec![0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn global_pooling_covers_input() {
        let mut dev = CpuDevice::new();
        let mut layer = mk("AVE", "global_pooling: true");
        let bottom = super::super::shared(Blob::new("x", &[1, 2, 3, 3]));
        let top = super::super::shared(Blob::new("y", &[1]));
        let mut data = vec![1.0; 9];
        data.extend(vec![5.0; 9]);
        bottom.borrow_mut().set_data(&mut dev, &data);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        assert_eq!(top.borrow().shape(), &[1, 2, 1, 1]);
        layer.forward(&mut dev, &[bottom], &[top.clone()]).unwrap();
        assert_eq!(top.borrow_mut().data_vec(&mut dev), vec![1.0, 5.0]);
    }

    #[test]
    fn prop_down_false_skips_kernel() {
        let mut dev = CpuDevice::new();
        let mut layer = mk("MAX", "kernel_size: 2 stride: 2");
        let bottom = super::super::shared(Blob::new("x", &[1, 1, 2, 2]));
        let top = super::super::shared(Blob::new("y", &[1]));
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        let before = dev.launches();
        layer
            .backward(&mut dev, &[top], &[false], &[bottom])
            .unwrap();
        assert_eq!(dev.launches(), before);
    }
}
