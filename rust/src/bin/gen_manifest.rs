//! gen-manifest: walk the model zoo with a RecordingDevice and emit the
//! artifact manifest (`artifacts/manifest.json`) that `python -m
//! compile.aot` lowers to HLO. This is step 1 of `make artifacts` — the
//! kernel-inventory enumeration that the OpenCL flow does by listing .cl
//! files.

use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::runtime::plan::serve_matrix;
use fecaffe::runtime::recording::RecordingDevice;
use fecaffe::solver::Solver;
use fecaffe::util::sha256;
use fecaffe::zoo;

fn record_net(
    rec: &mut RecordingDevice,
    name: &str,
    batch: usize,
    with_solver: bool,
) -> anyhow::Result<()> {
    let mut dev = RecordingDevice::new(false);
    let param = zoo::by_name(name, batch)?;
    let net = Net::from_param(&param, Phase::Train, &mut dev)?;
    if with_solver {
        let sp = zoo::default_solver(name)?;
        let mut solver = Solver::new(sp, net, &mut dev)?;
        solver.step(&mut dev)?;
        // Second step: Adam's bias-correction step t is a runtime scalar,
        // but record anyway in case of key drift.
        solver.step(&mut dev)?;
    } else {
        let mut net = net;
        net.forward_backward(&mut dev)?;
    }
    eprintln!(
        "  {name} (batch {batch}{}) -> {} distinct kernels, {} launches",
        if with_solver { ", +solver" } else { "" },
        dev.specs.len(),
        dev.launches
    );
    rec.merge_from(&dev);
    Ok(())
}

/// Record one deploy-net forward (the shapes the serving engine
/// executes) at the given batch size.
fn record_deploy(rec: &mut RecordingDevice, name: &str, batch: usize) -> anyhow::Result<()> {
    let mut dev = RecordingDevice::new(false);
    let dep = zoo::deploy_by_name(name, batch)?;
    let mut net = Net::from_param(&dep.param, Phase::Test, &mut dev)?;
    net.forward(&mut dev)?;
    eprintln!(
        "  {name} deploy (batch {batch}) -> {} distinct kernels, {} launches",
        dev.specs.len(),
        dev.launches
    );
    rec.merge_from(&dev);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let out = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts/manifest.json".to_string());
    let mut rec = RecordingDevice::new(false);

    // The paper's evaluation settings (DESIGN.md §5 experiment index):
    // Table 1: batch 1 F→B for the four big nets; Table 4: LeNet at 384
    // and the epoch-projection batches; training example: LeNet at 64;
    // Figures 4/5: GoogLeNet at 16 with Adam.
    for (name, batch, solver) in [
        ("lenet", 1, true),
        ("lenet", 64, true),
        ("lenet", 384, true),
        ("alexnet", 1, false),
        ("alexnet", 32, true),
        ("vgg16", 1, false),
        ("squeezenet", 1, false),
        ("squeezenet", 16, true),
        ("googlenet", 1, false),
        ("googlenet", 16, true),
    ] {
        record_net(&mut rec, name, batch, solver)?;
    }

    // Serving shapes (ROADMAP "Batched AOT artifacts"): the serving
    // engine reshapes each worker's replica to *bucketed* batch sizes
    // (`runtime::plan::batch_bucket`), so an `xla`-featured build needs
    // artifacts for every bucket a worker can execute, not just the
    // batch-1 zoo shapes above. The zoo × bucket walk is
    // `runtime::plan::serve_matrix()` — the same matrix `fecaffe lint`,
    // engine admission and the `fecaffe aot` artifact cache enumerate,
    // so every consumer checks the same shapes.
    for (name, buckets) in serve_matrix() {
        for b in buckets {
            record_deploy(&mut rec, name, b)?;
        }
    }

    let manifest = rec.manifest();
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    // `specs` is a BTreeMap and `to_pretty` emits sorted keys, so the
    // manifest bytes — and the digest alongside them — are reproducible
    // across independent runs of the same commit (the CI `repro` leg
    // relies on this).
    let body = manifest.to_pretty();
    std::fs::write(&out, &body)?;
    let digest = sha256::hex_digest(body.as_bytes());
    let digest_path = format!("{out}.sha256");
    let base = std::path::Path::new(&out)
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| out.clone());
    std::fs::write(&digest_path, format!("{digest}  {base}\n"))?;
    let count = match manifest.get("artifacts") {
        Some(fecaffe::util::json::Json::Obj(m)) => m.len(),
        _ => 0,
    };
    println!("wrote {count} artifact specs to {out} (sha256 {})", &digest[..12]);
    Ok(())
}
