//! Small self-contained substrates (the offline vendor set has only the
//! `xla` crate closure, so PRNG, JSON, CLI parsing, table formatting,
//! bench statistics and the property-test harness are all built here —
//! see DESIGN.md §10).

pub mod binio;
pub mod chaos;
pub mod sha256;
pub mod prng;
pub mod json;
pub mod cli;
pub mod pool;
pub mod table;
pub mod stats;
pub mod tcheck;
