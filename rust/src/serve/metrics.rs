//! Serving metrics: lock-free throughput counters and a power-of-two
//! latency histogram (p50/p95/p99).
//!
//! Counters are plain relaxed atomics so the request hot path never
//! takes a lock; exact-quantile reporting for offline load tests goes
//! through [`crate::util::stats`] instead (the CLI and bench collect
//! per-request samples client-side and `summarize` them).

use crate::util::stats::fmt_ns;
use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 64;

/// Log2-bucketed histogram over nanoseconds: bucket `i` counts samples
/// in `[2^i, 2^(i+1))`. Quantiles return the geometric midpoint of the
/// bucket holding the requested rank — coarse (±~40%) but constant-space
/// and wait-free, which is what a serving hot path wants.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, ns: u64) {
        let idx = (63 - ns.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Non-empty buckets as `(upper_bound_exclusive_ns, count)` pairs,
    /// in ascending order. Bucket bounds are exact powers of two (the
    /// last bucket's bound saturates to `u64::MAX`), so cumulative
    /// sums over these pairs are *exact* — this is what the Prometheus
    /// exposition renders, and what callers should prefer over
    /// [`Histogram::quantile_ns`] when precision matters.
    pub fn bucket_counts(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                if n == 0 {
                    return None;
                }
                let bound = if i + 1 >= BUCKETS { u64::MAX } else { 1u64 << (i + 1) };
                Some((bound, n))
            })
            .collect()
    }

    /// Nearest-rank quantile estimate (`q` in 0..=1).
    ///
    /// Returns the *geometric midpoint* of the log2 bucket holding the
    /// requested rank, so the estimate can be off by up to a factor of
    /// √2 (±~40%) from the true quantile. Good enough for dashboards
    /// and trend lines; for exact cumulative counts use
    /// [`Histogram::bucket_counts`] (bucket boundaries are exact).
    pub fn quantile_ns(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                // Geometric midpoint of [2^i, 2^(i+1)).
                return (1u64 << i) as f64 * std::f64::consts::SQRT_2;
            }
        }
        self.max_ns() as f64
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Engine-wide counters, shared by the submit path, the batcher and
/// every worker.
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// Requests shed because their deadline expired before execution
    /// (resolved as `DeadlineExceeded` / HTTP 504 — not a `failed`).
    pub shed_expired: AtomicU64,
    /// Submissions refused fast because the model's circuit breaker was
    /// open (HTTP 503 + Retry-After — not a `rejected`).
    pub breaker_rejected: AtomicU64,
    /// Times the circuit breaker tripped open.
    pub breaker_trips: AtomicU64,
    /// Breaker state gauge: 0 = closed, 1 = open, 2 = half-open.
    pub breaker_state: AtomicU64,
    /// Transient device-fault retries performed by workers (each is one
    /// re-attempted forward, not one request).
    pub retries: AtomicU64,
    /// Worker recoveries: replica rebuilds after a caught batch panic
    /// plus supervisor respawns of dead worker threads.
    pub restarts: AtomicU64,
    /// Workers currently able to serve (gauge, mirrors
    /// `Engine::healthy_workers`).
    pub healthy_workers: AtomicU64,
    pub batches: AtomicU64,
    pub batched_samples: AtomicU64,
    pub full_batches: AtomicU64,
    /// Rows carrying a real request across all executed batches — the
    /// numerator of `batch_occupancy`.
    pub filled_rows: AtomicU64,
    /// Rows the (dynamically reshaped) replicas actually executed —
    /// each batch contributes its *bucketed* row count, never
    /// `max_batch` padding. The denominator of `batch_occupancy`.
    pub executed_rows: AtomicU64,
    /// Executed-rows-per-batch histogram (values are row counts, not
    /// nanoseconds; buckets are exact for the power-of-two batch
    /// buckets the workers execute).
    pub executed_hist: Histogram,
    /// Admission-queue depth observed at the latest submit/drain event,
    /// and the deepest it has ever been — the queue-pressure gauges.
    pub queue_depth: AtomicU64,
    pub queue_depth_hwm: AtomicU64,
    /// Weight publishes accepted by the engine (hot-swaps).
    pub publishes: AtomicU64,
    /// Version of the most recently published weight snapshot (0 until
    /// the first publish — the engine's initialization weights).
    pub weights_version: AtomicU64,
    /// AOT plan-cache outcome at engine boot: buckets whose cached
    /// artifact loaded and validated vs buckets that fell back to live
    /// planning. Set once by `Engine::new`; `cache_miss == 0` with
    /// `cache_hit > 0` is the cold-boot success signal the CI
    /// `aot-verify` smoke asserts.
    pub aot_cache_hit: AtomicU64,
    pub aot_cache_miss: AtomicU64,
    pub latency: Histogram,
    /// Per-batch *simulated* device time (FPGA-sim workers only): the
    /// `sim_clock_ns` delta across each batched forward, so batching
    /// policy can be evaluated against the paper's cost model instead of
    /// host wallclock. Empty when serving on the CPU device.
    pub sim_batch: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            shed_expired: AtomicU64::new(0),
            breaker_rejected: AtomicU64::new(0),
            breaker_trips: AtomicU64::new(0),
            breaker_state: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            healthy_workers: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_samples: AtomicU64::new(0),
            full_batches: AtomicU64::new(0),
            filled_rows: AtomicU64::new(0),
            executed_rows: AtomicU64::new(0),
            executed_hist: Histogram::new(),
            queue_depth: AtomicU64::new(0),
            queue_depth_hwm: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
            weights_version: AtomicU64::new(0),
            aot_cache_hit: AtomicU64::new(0),
            aot_cache_miss: AtomicU64::new(0),
            latency: Histogram::new(),
            sim_batch: Histogram::new(),
        }
    }

    /// Record the engine's AOT cold-boot outcome (once, at boot).
    pub(crate) fn set_aot_cache(&self, hits: u64, misses: u64) {
        self.aot_cache_hit.store(hits, Ordering::Relaxed);
        self.aot_cache_miss.store(misses, Ordering::Relaxed);
    }

    pub(crate) fn record_batch(&self, size: usize, max_batch: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_samples.fetch_add(size as u64, Ordering::Relaxed);
        if size >= max_batch {
            self.full_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one executed batch's row accounting: `filled` rows carried
    /// requests, the reshaped replica ran `executed` rows (its bucket).
    pub(crate) fn record_rows(&self, filled: usize, executed: usize) {
        self.filled_rows.fetch_add(filled as u64, Ordering::Relaxed);
        self.executed_rows.fetch_add(executed as u64, Ordering::Relaxed);
        self.executed_hist.record(executed as u64);
    }

    pub(crate) fn record_done(&self, latency_ns: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.record(latency_ns);
    }

    pub(crate) fn record_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_shed_expired(&self) {
        self.shed_expired.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_breaker_rejected(&self) {
        self.breaker_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_breaker_trip(&self) {
        self.breaker_trips.fetch_add(1, Ordering::Relaxed);
    }

    /// Breaker state gauge: 0 = closed, 1 = open, 2 = half-open.
    pub(crate) fn set_breaker_state(&self, state: u64) {
        self.breaker_state.store(state, Ordering::Relaxed);
    }

    pub(crate) fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn set_healthy_workers(&self, n: u64) {
        self.healthy_workers.store(n, Ordering::Relaxed);
    }

    pub(crate) fn record_sim_batch(&self, sim_ns: u64) {
        self.sim_batch.record(sim_ns);
    }

    /// Update the queue-depth gauge (and its high-water mark). Called
    /// on both edges — submit (depth including the new request) and
    /// batch formation (depth after the drain) — so the gauge decays.
    pub(crate) fn record_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_depth_hwm.fetch_max(depth, Ordering::Relaxed);
    }

    pub(crate) fn record_publish(&self, version: u64) {
        self.publishes.fetch_add(1, Ordering::Relaxed);
        self.weights_version.store(version, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsReport {
        let batches = self.batches.load(Ordering::Relaxed);
        let samples = self.batched_samples.load(Ordering::Relaxed);
        let filled_rows = self.filled_rows.load(Ordering::Relaxed);
        let executed_rows = self.executed_rows.load(Ordering::Relaxed);
        MetricsReport {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            shed_expired: self.shed_expired.load(Ordering::Relaxed),
            breaker_rejected: self.breaker_rejected.load(Ordering::Relaxed),
            breaker_trips: self.breaker_trips.load(Ordering::Relaxed),
            breaker_state: self.breaker_state.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            restarts: self.restarts.load(Ordering::Relaxed),
            healthy_workers: self.healthy_workers.load(Ordering::Relaxed),
            batches,
            batched_samples: samples,
            full_batches: self.full_batches.load(Ordering::Relaxed),
            filled_rows,
            executed_rows,
            batch_occupancy: if executed_rows == 0 {
                0.0
            } else {
                filled_rows as f64 / executed_rows as f64
            },
            mean_executed_rows: self.executed_hist.mean_ns(),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            queue_depth_hwm: self.queue_depth_hwm.load(Ordering::Relaxed),
            publishes: self.publishes.load(Ordering::Relaxed),
            weights_version: self.weights_version.load(Ordering::Relaxed),
            cache_hit: self.aot_cache_hit.load(Ordering::Relaxed),
            cache_miss: self.aot_cache_miss.load(Ordering::Relaxed),
            mean_batch: if batches == 0 { 0.0 } else { samples as f64 / batches as f64 },
            p50_ns: self.latency.quantile_ns(0.50),
            p95_ns: self.latency.quantile_ns(0.95),
            p99_ns: self.latency.quantile_ns(0.99),
            mean_ns: self.latency.mean_ns(),
            max_ns: self.latency.max_ns(),
            latency_buckets: self.latency.bucket_counts(),
            latency_sum_ns: self.latency.sum_ns(),
            latency_count: self.latency.count(),
            sim_batches: self.sim_batch.count(),
            sim_total_ns: self.sim_batch.sum_ns(),
            sim_mean_ns: self.sim_batch.mean_ns(),
            sim_p50_ns: self.sim_batch.quantile_ns(0.50),
            sim_p99_ns: self.sim_batch.quantile_ns(0.99),
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

/// Point-in-time view of [`Metrics`].
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsReport {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    /// Requests shed at a deadline expiry (504s), fast breaker refusals
    /// (503s), breaker trips, and the breaker state gauge (0 closed /
    /// 1 open / 2 half-open).
    pub shed_expired: u64,
    pub breaker_rejected: u64,
    pub breaker_trips: u64,
    pub breaker_state: u64,
    /// Transient-fault forward retries and worker recoveries (replica
    /// rebuilds + supervisor respawns), plus the healthy-workers gauge.
    pub retries: u64,
    pub restarts: u64,
    pub healthy_workers: u64,
    pub batches: u64,
    pub batched_samples: u64,
    pub full_batches: u64,
    /// Rows carrying real requests vs rows the reshaped replicas
    /// actually executed (bucketed batch sizes).
    pub filled_rows: u64,
    pub executed_rows: u64,
    /// filled/executed over all batches: 1.0 = every executed row
    /// carried a request; the old pad-to-`max_batch` worker pinned this
    /// at mean_batch/max_batch instead.
    pub batch_occupancy: f64,
    /// Mean executed rows per batch (from the executed-rows histogram).
    pub mean_executed_rows: f64,
    /// Admission-queue depth gauge at snapshot time, plus its
    /// high-water mark since the engine started.
    pub queue_depth: u64,
    pub queue_depth_hwm: u64,
    /// Accepted weight hot-swaps and the currently published version.
    pub publishes: u64,
    pub weights_version: u64,
    /// AOT plan-cache outcome at boot: serving buckets restored from
    /// validated cached artifacts vs buckets that required live
    /// planning (no cache configured ⇒ both stay 0).
    pub cache_hit: u64,
    pub cache_miss: u64,
    pub mean_batch: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub mean_ns: f64,
    pub max_ns: u64,
    /// Exact latency-histogram buckets as `(upper_bound_exclusive_ns,
    /// count)` pairs (non-empty buckets only), with the matching sum and
    /// count. Unlike the `p*_ns` midpoint estimates above, cumulative
    /// sums over these are exact — Prometheus `le` buckets render from
    /// this.
    pub latency_buckets: Vec<(u64, u64)>,
    pub latency_sum_ns: u64,
    pub latency_count: u64,
    /// Batches metered in simulated device time (FPGA-sim workers only).
    pub sim_batches: u64,
    pub sim_total_ns: u64,
    pub sim_mean_ns: f64,
    pub sim_p50_ns: f64,
    pub sim_p99_ns: f64,
}

impl MetricsReport {
    /// JSON object mirror of the report — the HTTP `/metrics` endpoint
    /// and bench logs share this shape. Sim-time fields appear only
    /// when FPGA-sim batches were metered, matching `render`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.set("submitted", Json::num(self.submitted as f64));
        o.set("rejected", Json::num(self.rejected as f64));
        o.set("completed", Json::num(self.completed as f64));
        o.set("failed", Json::num(self.failed as f64));
        o.set("shed_expired", Json::num(self.shed_expired as f64));
        o.set("breaker_rejected", Json::num(self.breaker_rejected as f64));
        o.set("breaker_trips", Json::num(self.breaker_trips as f64));
        o.set("breaker_state", Json::num(self.breaker_state as f64));
        o.set("retries", Json::num(self.retries as f64));
        o.set("restarts", Json::num(self.restarts as f64));
        o.set("healthy_workers", Json::num(self.healthy_workers as f64));
        // One greppable place for every way a request can not complete —
        // bench runs and the CI chaos leg read this instead of diffing
        // the individual counters.
        let mut fb = Json::obj();
        fb.set("worker_failed", Json::num(self.failed as f64));
        fb.set("shed_expired", Json::num(self.shed_expired as f64));
        fb.set("rejected", Json::num(self.rejected as f64));
        fb.set("breaker_rejected", Json::num(self.breaker_rejected as f64));
        o.set("failure_breakdown", fb);
        o.set("batches", Json::num(self.batches as f64));
        o.set("batched_samples", Json::num(self.batched_samples as f64));
        o.set("full_batches", Json::num(self.full_batches as f64));
        o.set("filled_rows", Json::num(self.filled_rows as f64));
        o.set("executed_rows", Json::num(self.executed_rows as f64));
        o.set("occupancy", Json::num(self.batch_occupancy));
        o.set("mean_executed_rows", Json::num(self.mean_executed_rows));
        o.set("queue_depth", Json::num(self.queue_depth as f64));
        o.set("queue_depth_hwm", Json::num(self.queue_depth_hwm as f64));
        o.set("publishes", Json::num(self.publishes as f64));
        o.set("weights_version", Json::num(self.weights_version as f64));
        o.set("cache_hit", Json::num(self.cache_hit as f64));
        o.set("cache_miss", Json::num(self.cache_miss as f64));
        o.set("mean_batch", Json::num(self.mean_batch));
        o.set("p50_ms", Json::num(self.p50_ns / 1e6));
        o.set("p95_ms", Json::num(self.p95_ns / 1e6));
        o.set("p99_ms", Json::num(self.p99_ns / 1e6));
        o.set("mean_ms", Json::num(self.mean_ns / 1e6));
        o.set("max_ms", Json::num(self.max_ns as f64 / 1e6));
        // Exact histogram buckets: `le_ns` is the exclusive power-of-two
        // upper bound, `count` the per-bucket tally, `cum` the exact
        // cumulative count up to that bound (Prometheus-style).
        let mut cum = 0u64;
        o.set(
            "latency_buckets",
            Json::arr(self.latency_buckets.iter().map(|&(le_ns, count)| {
                cum += count;
                let mut b = Json::obj();
                b.set("le_ns", Json::num(le_ns as f64));
                b.set("count", Json::num(count as f64));
                b.set("cum", Json::num(cum as f64));
                b
            })),
        );
        o.set("latency_count", Json::num(self.latency_count as f64));
        o.set("latency_sum_ms", Json::num(self.latency_sum_ns as f64 / 1e6));
        if self.sim_batches > 0 {
            o.set("sim_batches", Json::num(self.sim_batches as f64));
            o.set("sim_total_ms", Json::num(self.sim_total_ns as f64 / 1e6));
            o.set("sim_mean_ms", Json::num(self.sim_mean_ns / 1e6));
            o.set("sim_p50_ms", Json::num(self.sim_p50_ns / 1e6));
            o.set("sim_p99_ms", Json::num(self.sim_p99_ns / 1e6));
        }
        o
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "requests: {} submitted, {} completed, {} failed, {} rejected, \
             {} shed (deadline), {} breaker-rejected\n\
             batches:  {} ({} full), mean size {:.2}\n\
             rows:     occupancy {:.2} ({} filled / {} executed, mean {:.2} rows/batch)\n\
             faults:   {} transient retries, {} restarts, {} healthy worker(s), breaker {}\n\
             weights:  version {} ({} publish(es))\n\
             latency:  p50 {} / p95 {} / p99 {} (mean {}, max {})",
            self.submitted,
            self.completed,
            self.failed,
            self.rejected,
            self.shed_expired,
            self.breaker_rejected,
            self.batches,
            self.full_batches,
            self.mean_batch,
            self.batch_occupancy,
            self.filled_rows,
            self.executed_rows,
            self.mean_executed_rows,
            self.retries,
            self.restarts,
            self.healthy_workers,
            breaker_state_name(self.breaker_state),
            self.weights_version,
            self.publishes,
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.max_ns as f64),
        );
        if self.sim_batches > 0 {
            s.push_str(&format!(
                "\nsim time: {} batches, p50 {} / p99 {} per batch (mean {}, total {})",
                self.sim_batches,
                fmt_ns(self.sim_p50_ns),
                fmt_ns(self.sim_p99_ns),
                fmt_ns(self.sim_mean_ns),
                fmt_ns(self.sim_total_ns as f64),
            ));
        }
        s
    }
}

/// Human name for the breaker-state gauge values (0/1/2).
pub fn breaker_state_name(state: u64) -> &'static str {
    match state {
        1 => "open",
        2 => "half-open",
        _ => "closed",
    }
}

/// Render per-model metric reports in the Prometheus text exposition
/// format (`text/plain; version=0.0.4`). Each family's `# HELP`/`# TYPE`
/// header appears once, followed by one sample per `(model, precision)`
/// label pair — `lenet` and `lenet@int8` are separate series sharing
/// `model="lenet"`, distinguished by the `precision` label. The
/// request-latency family is a true Prometheus histogram: cumulative
/// `le` buckets converted from the log2 histogram's exact power-of-two
/// nanosecond bounds into seconds, so bucket counts carry none of the
/// midpoint error the JSON quantile estimates have.
pub fn prometheus_text(reports: &[(String, String, MetricsReport)]) -> String {
    let mut out = String::new();
    // One `model="…",precision="…"` label set per report, reused by
    // every family below.
    let reports: Vec<(String, &MetricsReport)> = reports
        .iter()
        .map(|(model, precision, r)| {
            (format!("model=\"{model}\",precision=\"{precision}\""), r)
        })
        .collect();
    let counters: &[(&str, &str, fn(&MetricsReport) -> u64)] = &[
        ("fecaffe_requests_submitted_total", "Requests admitted into the engine.", |r| r.submitted),
        ("fecaffe_requests_rejected_total", "Requests rejected at admission (queue full).", |r| {
            r.rejected
        }),
        ("fecaffe_requests_completed_total", "Requests answered successfully.", |r| r.completed),
        ("fecaffe_requests_failed_total", "Requests that failed during execution.", |r| r.failed),
        ("fecaffe_batches_total", "Micro-batches executed.", |r| r.batches),
        ("fecaffe_batched_samples_total", "Requests carried across all batches.", |r| {
            r.batched_samples
        }),
        ("fecaffe_full_batches_total", "Batches that filled max_batch rows.", |r| r.full_batches),
        ("fecaffe_filled_rows_total", "Executed rows that carried a request.", |r| r.filled_rows),
        ("fecaffe_executed_rows_total", "Rows executed by reshaped replicas.", |r| {
            r.executed_rows
        }),
        ("fecaffe_weight_publishes_total", "Weight hot-swaps accepted.", |r| r.publishes),
        ("fecaffe_requests_shed_expired_total", "Requests shed at deadline expiry.", |r| {
            r.shed_expired
        }),
        ("fecaffe_breaker_rejected_total", "Submissions refused by an open breaker.", |r| {
            r.breaker_rejected
        }),
        ("fecaffe_breaker_trips_total", "Circuit-breaker open transitions.", |r| r.breaker_trips),
        ("fecaffe_transient_retries_total", "Transient device-fault forward retries.", |r| {
            r.retries
        }),
        ("fecaffe_worker_restarts_total", "Replica rebuilds plus worker respawns.", |r| {
            r.restarts
        }),
        ("fecaffe_aot_cache_hit_total", "Serving buckets cold-booted from the plan cache.", |r| {
            r.cache_hit
        }),
        ("fecaffe_aot_cache_miss_total", "Serving buckets that fell back to live planning.", |r| {
            r.cache_miss
        }),
    ];
    for &(name, help, get) in counters {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n"));
        for (labels, r) in &reports {
            out.push_str(&format!("{name}{{{labels}}} {}\n", get(r)));
        }
    }
    let gauges: &[(&str, &str, fn(&MetricsReport) -> f64)] = &[
        ("fecaffe_weights_version", "Currently published weight snapshot version.", |r| {
            r.weights_version as f64
        }),
        ("fecaffe_queue_depth", "Admission-queue depth at the latest submit/drain.", |r| {
            r.queue_depth as f64
        }),
        ("fecaffe_queue_depth_high_water", "Deepest the admission queue has been.", |r| {
            r.queue_depth_hwm as f64
        }),
        ("fecaffe_batch_occupancy", "Filled rows / executed rows over all batches.", |r| {
            r.batch_occupancy
        }),
        ("fecaffe_mean_batch_size", "Mean requests per micro-batch.", |r| r.mean_batch),
        ("fecaffe_healthy_workers", "Workers currently able to serve.", |r| {
            r.healthy_workers as f64
        }),
        ("fecaffe_breaker_state", "Circuit breaker: 0 closed, 1 open, 2 half-open.", |r| {
            r.breaker_state as f64
        }),
    ];
    for &(name, help, get) in gauges {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
        for (labels, r) in &reports {
            out.push_str(&format!("{name}{{{labels}}} {}\n", get(r)));
        }
    }
    let lat = "fecaffe_request_latency_seconds";
    out.push_str(&format!(
        "# HELP {lat} End-to-end request latency (submit to response).\n# TYPE {lat} histogram\n"
    ));
    for (labels, r) in &reports {
        let mut cum = 0u64;
        for &(le_ns, count) in &r.latency_buckets {
            if le_ns == u64::MAX {
                break; // folded into the +Inf bucket below
            }
            cum += count;
            out.push_str(&format!(
                "{lat}_bucket{{{labels},le=\"{}\"}} {cum}\n",
                le_ns as f64 / 1e9
            ));
        }
        out.push_str(&format!(
            "{lat}_bucket{{{labels},le=\"+Inf\"}} {}\n",
            r.latency_count
        ));
        out.push_str(&format!(
            "{lat}_sum{{{labels}}} {}\n",
            r.latency_sum_ns as f64 / 1e9
        ));
        out.push_str(&format!("{lat}_count{{{labels}}} {}\n", r.latency_count));
    }
    let sim = "fecaffe_sim_batch_seconds";
    out.push_str(&format!(
        "# HELP {sim} Simulated device time per batch (FPGA-sim workers).\n# TYPE {sim} summary\n"
    ));
    for (labels, r) in &reports {
        out.push_str(&format!(
            "{sim}_sum{{{labels}}} {}\n",
            r.sim_total_ns as f64 / 1e9
        ));
        out.push_str(&format!("{sim}_count{{{labels}}} {}\n", r.sim_batches));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(1_000); // bucket [512, 1024) ≈ 724 ns midpoint
        }
        for _ in 0..10 {
            h.record(1_000_000);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_ns(0.50);
        assert!((500.0..2_000.0).contains(&p50), "p50 {p50}");
        let p99 = h.quantile_ns(0.99);
        assert!((500_000.0..2_000_000.0).contains(&p99), "p99 {p99}");
        assert_eq!(h.max_ns(), 1_000_000);
        assert!(h.mean_ns() > 1_000.0 && h.mean_ns() < 1_000_000.0);
    }

    #[test]
    fn histogram_handles_extremes() {
        let h = Histogram::new();
        h.record(0); // clamped into the first bucket
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.quantile_ns(0.01) >= 1.0);
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile_ns(0.99), 0.0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn metrics_snapshot_aggregates() {
        let m = Metrics::new();
        m.record_batch(4, 4);
        m.record_batch(2, 4);
        for _ in 0..6 {
            m.record_done(2_000);
        }
        m.record_failed();
        let r = m.snapshot();
        assert_eq!(r.batches, 2);
        assert_eq!(r.batched_samples, 6);
        assert_eq!(r.full_batches, 1);
        assert_eq!(r.completed, 6);
        assert_eq!(r.failed, 1);
        assert!((r.mean_batch - 3.0).abs() < 1e-9);
        assert!(r.render().contains("mean size 3.00"));
        // No FPGA-sim batches recorded: report stays silent about them.
        assert_eq!(r.sim_batches, 0);
        assert!(!r.render().contains("sim time"));
    }

    #[test]
    fn report_to_json_round_trips() {
        use crate::util::json::Json;
        let m = Metrics::new();
        m.record_batch(4, 4);
        for _ in 0..4 {
            m.record_done(2_000_000);
        }
        let r = m.snapshot();
        let j = r.to_json();
        let back = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(back.get("completed").unwrap().as_usize().unwrap(), 4);
        assert_eq!(back.get("batches").unwrap().as_usize().unwrap(), 1);
        assert!(back.get("p50_ms").unwrap().as_f64().unwrap() > 0.0);
        // No sim batches recorded → the sim block is absent.
        assert!(back.get("sim_batches").is_none());
        m.record_sim_batch(1_000);
        assert!(m.snapshot().to_json().get("sim_batches").is_some());
    }

    #[test]
    fn occupancy_tracks_filled_vs_executed_rows() {
        let m = Metrics::new();
        // Nothing executed yet: occupancy reports 0 without dividing by 0.
        assert_eq!(m.snapshot().batch_occupancy, 0.0);
        // A batch of 3 bucketed to 4 rows, then a lone request at batch 1.
        m.record_rows(3, 4);
        m.record_rows(1, 1);
        let r = m.snapshot();
        assert_eq!(r.filled_rows, 4);
        assert_eq!(r.executed_rows, 5);
        assert!((r.batch_occupancy - 0.8).abs() < 1e-9);
        assert!((r.mean_executed_rows - 2.5).abs() < 1e-9);
        assert!(r.render().contains("occupancy 0.80"), "{}", r.render());
        let j = r.to_json();
        assert!((j.get("occupancy").unwrap().as_f64().unwrap() - 0.8).abs() < 1e-9);
        assert_eq!(j.get("executed_rows").unwrap().as_usize().unwrap(), 5);
        assert_eq!(j.get("filled_rows").unwrap().as_usize().unwrap(), 4);
    }

    #[test]
    fn publish_tracking_surfaces_in_report() {
        let m = Metrics::new();
        let r = m.snapshot();
        assert_eq!((r.publishes, r.weights_version), (0, 0));
        m.record_publish(3);
        m.record_publish(4);
        let r = m.snapshot();
        assert_eq!(r.publishes, 2);
        assert_eq!(r.weights_version, 4);
        assert!(r.render().contains("version 4 (2 publish(es))"), "{}", r.render());
        let j = r.to_json();
        assert_eq!(j.get("weights_version").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.get("publishes").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn bucket_counts_are_exact_and_ordered() {
        let h = Histogram::new();
        h.record(1_000); // [512, 1024) → bound 1024
        h.record(1_000);
        h.record(3_000); // [2048, 4096) → bound 4096
        h.record(u64::MAX); // top bucket → bound saturates
        let buckets = h.bucket_counts();
        assert_eq!(buckets, vec![(1024, 2), (4096, 1), (u64::MAX, 1)]);
        // Cumulative sums over the pairs are exact — the total matches
        // count() with no midpoint estimation involved.
        let total: u64 = buckets.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, h.count());
        // And they surface in the JSON report with running cumulatives.
        let m = Metrics::new();
        m.latency.record(1_000);
        m.latency.record(3_000);
        let j = m.snapshot().to_json();
        let arr = j.get("latency_buckets").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("le_ns").unwrap().as_usize().unwrap(), 1024);
        assert_eq!(arr[0].get("cum").unwrap().as_usize().unwrap(), 1);
        assert_eq!(arr[1].get("cum").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("latency_count").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn queue_depth_gauge_decays_but_hwm_sticks() {
        let m = Metrics::new();
        m.record_queue_depth(3);
        m.record_queue_depth(7);
        m.record_queue_depth(1);
        let r = m.snapshot();
        assert_eq!(r.queue_depth, 1);
        assert_eq!(r.queue_depth_hwm, 7);
        let j = r.to_json();
        assert_eq!(j.get("queue_depth").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("queue_depth_hwm").unwrap().as_usize().unwrap(), 7);
    }

    #[test]
    fn prometheus_text_renders_families_once_with_model_labels() {
        let m = Metrics::new();
        m.submitted.fetch_add(5, Ordering::Relaxed);
        m.record_done(1_000);
        m.record_done(3_000);
        m.record_queue_depth(2);
        m.record_publish(4);
        let reports = vec![
            ("lenet".to_string(), "fp32".to_string(), m.snapshot()),
            ("lenet".to_string(), "int8".to_string(), Metrics::new().snapshot()),
        ];
        let text = prometheus_text(&reports);
        // One TYPE header per family, one sample per (model, precision):
        // the int8 variant shares the model label, distinguished by the
        // precision label.
        assert_eq!(text.matches("# TYPE fecaffe_requests_completed_total counter").count(), 1);
        assert!(text
            .contains("fecaffe_requests_completed_total{model=\"lenet\",precision=\"fp32\"} 2"));
        assert!(text
            .contains("fecaffe_requests_completed_total{model=\"lenet\",precision=\"int8\"} 0"));
        assert!(text.contains("fecaffe_queue_depth{model=\"lenet\",precision=\"fp32\"} 2"));
        assert!(
            text.contains("fecaffe_queue_depth_high_water{model=\"lenet\",precision=\"fp32\"} 2")
        );
        assert!(text.contains("fecaffe_weights_version{model=\"lenet\",precision=\"fp32\"} 4"));
        // Histogram: exact cumulative le buckets in seconds, +Inf = count.
        let lat = "fecaffe_request_latency_seconds";
        let l32 = "model=\"lenet\",precision=\"fp32\"";
        let l8 = "model=\"lenet\",precision=\"int8\"";
        assert!(text.contains(&format!("{lat}_bucket{{{l32},le=\"0.000001024\"}} 1")));
        assert!(text.contains(&format!("{lat}_bucket{{{l32},le=\"+Inf\"}} 2")));
        assert!(text.contains(&format!("{lat}_count{{{l32}}} 2")));
        assert!(text.contains(&format!("{lat}_count{{{l8}}} 0")));
        // Every line is a comment or `name{labels} value`.
        for line in text.lines() {
            assert!(line.starts_with('#') || line.contains("} "), "bad line: {line}");
        }
    }

    #[test]
    fn fault_counters_surface_in_report_json_and_prometheus() {
        let m = Metrics::new();
        m.record_shed_expired();
        m.record_shed_expired();
        m.record_breaker_rejected();
        m.record_breaker_trip();
        m.set_breaker_state(2);
        m.record_retry();
        m.record_restart();
        m.set_healthy_workers(3);
        m.rejected.fetch_add(4, Ordering::Relaxed);
        m.record_failed();
        let r = m.snapshot();
        assert_eq!(r.shed_expired, 2);
        assert_eq!(r.breaker_rejected, 1);
        assert_eq!(r.breaker_trips, 1);
        assert_eq!(r.breaker_state, 2);
        assert_eq!((r.retries, r.restarts, r.healthy_workers), (1, 1, 3));
        let rendered = r.render();
        assert!(rendered.contains("2 shed (deadline)"), "{rendered}");
        assert!(rendered.contains("breaker half-open"), "{rendered}");
        // The JSON failure breakdown is the one greppable place for the
        // four ways a request can not complete.
        let j = r.to_json();
        let fb = j.get("failure_breakdown").unwrap();
        assert_eq!(fb.get("worker_failed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(fb.get("shed_expired").unwrap().as_usize().unwrap(), 2);
        assert_eq!(fb.get("rejected").unwrap().as_usize().unwrap(), 4);
        assert_eq!(fb.get("breaker_rejected").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("restarts").unwrap().as_usize().unwrap(), 1);
        // Prometheus families for the fault-tolerance layer.
        let text = prometheus_text(&[("lenet".to_string(), "fp32".to_string(), r)]);
        let l = "{model=\"lenet\",precision=\"fp32\"}";
        assert!(text.contains(&format!("fecaffe_requests_shed_expired_total{l} 2")));
        assert!(text.contains(&format!("fecaffe_worker_restarts_total{l} 1")));
        assert!(text.contains(&format!("fecaffe_transient_retries_total{l} 1")));
        assert!(text.contains(&format!("fecaffe_breaker_rejected_total{l} 1")));
        assert!(text.contains(&format!("fecaffe_breaker_trips_total{l} 1")));
        assert!(text.contains(&format!("fecaffe_healthy_workers{l} 3")));
        assert!(text.contains(&format!("fecaffe_breaker_state{l} 2")));
        assert_eq!(breaker_state_name(0), "closed");
        assert_eq!(breaker_state_name(1), "open");
        assert_eq!(breaker_state_name(2), "half-open");
    }

    #[test]
    fn aot_cache_counters_surface_everywhere() {
        let m = Metrics::new();
        // Default (no cache configured): both zero, keys still present
        // so `grep '"cache_miss": 0'` in the smoke scripts never 404s.
        let j = m.snapshot().to_json();
        assert_eq!(j.get("cache_hit").unwrap().as_usize().unwrap(), 0);
        assert_eq!(j.get("cache_miss").unwrap().as_usize().unwrap(), 0);
        m.set_aot_cache(4, 0);
        let r = m.snapshot();
        assert_eq!((r.cache_hit, r.cache_miss), (4, 0));
        let j = r.to_json();
        assert_eq!(j.get("cache_hit").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.get("cache_miss").unwrap().as_usize().unwrap(), 0);
        let text = prometheus_text(&[("lenet".to_string(), "int8".to_string(), r)]);
        assert!(text
            .contains("fecaffe_aot_cache_hit_total{model=\"lenet\",precision=\"int8\"} 4"));
        assert!(text
            .contains("fecaffe_aot_cache_miss_total{model=\"lenet\",precision=\"int8\"} 0"));
        // A demoted boot records the misses.
        m.set_aot_cache(0, 4);
        assert_eq!(m.snapshot().cache_miss, 4);
    }

    #[test]
    fn sim_time_surfaces_in_snapshot_and_render() {
        let m = Metrics::new();
        m.record_sim_batch(2_000_000);
        m.record_sim_batch(4_000_000);
        let r = m.snapshot();
        assert_eq!(r.sim_batches, 2);
        assert_eq!(r.sim_total_ns, 6_000_000);
        assert!((r.sim_mean_ns - 3_000_000.0).abs() < 1.0);
        assert!(r.sim_p99_ns >= r.sim_p50_ns);
        assert!(r.render().contains("sim time"));
    }
}
