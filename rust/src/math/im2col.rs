//! im2col / col2im — the paper's single biggest kernel-time consumer
//! (Table 2: 187.4 ms over 98 instances) and the §5.2 candidate for CPU
//! fallback. Lowers convolution to GEMM exactly like Caffe.
//!
//! Both directions shard across the intra-op pool (`util::pool`):
//! `im2col` over col-matrix rows (each row is written by exactly one
//! task) and `col2im` over image *channels* (channel plane `c` only
//! accumulates from col rows with the same `c`, so planes are disjoint).

use crate::util::pool;

/// Convolution geometry for one image (batch handled by callers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub kernel_h: usize,
    pub kernel_w: usize,
    pub pad_h: usize,
    pub pad_w: usize,
    pub stride_h: usize,
    pub stride_w: usize,
}

impl ConvGeom {
    pub fn out_h(&self) -> usize {
        (self.height + 2 * self.pad_h - self.kernel_h) / self.stride_h + 1
    }
    pub fn out_w(&self) -> usize {
        (self.width + 2 * self.pad_w - self.kernel_w) / self.stride_w + 1
    }
    /// Rows of the col matrix: C*kh*kw.
    pub fn col_rows(&self) -> usize {
        self.channels * self.kernel_h * self.kernel_w
    }
    /// Cols of the col matrix: out_h*out_w.
    pub fn col_cols(&self) -> usize {
        self.out_h() * self.out_w()
    }
    pub fn col_len(&self) -> usize {
        self.col_rows() * self.col_cols()
    }
    pub fn im_len(&self) -> usize {
        self.channels * self.height * self.width
    }
}

/// Fill col-matrix rows `rows` (each row is one (c, kh, kw) tap across
/// the whole output map). `data_col` starts at row `rows.start`.
fn im2col_rows(
    g: &ConvGeom,
    data_im: &[f32],
    data_col: &mut [f32],
    rows: std::ops::Range<usize>,
) {
    let (oh, ow) = (g.out_h(), g.out_w());
    let taps = g.kernel_h * g.kernel_w;
    let mut col_idx = 0;
    for rid in rows {
        let c = rid / taps;
        let kh = (rid / g.kernel_w) % g.kernel_h;
        let kw = rid % g.kernel_w;
        for y in 0..oh {
            let iy = (y * g.stride_h + kh) as isize - g.pad_h as isize;
            if iy < 0 || iy >= g.height as isize {
                for _ in 0..ow {
                    data_col[col_idx] = 0.0;
                    col_idx += 1;
                }
                continue;
            }
            let row_base = (c * g.height + iy as usize) * g.width;
            for x in 0..ow {
                let ix = (x * g.stride_w + kw) as isize - g.pad_w as isize;
                data_col[col_idx] = if ix < 0 || ix >= g.width as isize {
                    0.0
                } else {
                    data_im[row_base + ix as usize]
                };
                col_idx += 1;
            }
        }
    }
}

/// data_im (C,H,W) → data_col (C*kh*kw, out_h*out_w), zero padding.
pub fn im2col(g: &ConvGeom, data_im: &[f32], data_col: &mut [f32]) {
    assert!(data_im.len() >= g.im_len(), "im2col: image too small");
    assert!(data_col.len() >= g.col_len(), "im2col: col too small");
    let ohw = g.col_cols();
    let rows = g.col_rows();
    // Enough rows per task that a chunk moves at least ~one elementwise
    // grain of data.
    let grain = (pool::GRAIN_ELEMWISE / ohw.max(1)).max(1);
    let col = pool::SendPtr::new(data_col.as_mut_ptr());
    pool::parallel_for(0..rows, grain, |r| {
        // Safety: row ranges are disjoint across tasks; each covers
        // exactly r.len()*ohw contiguous elements of data_col.
        let chunk = unsafe { col.slice(r.start * ohw, r.len() * ohw) };
        im2col_rows(g, data_im, chunk, r);
    });
}

/// Accumulate the col rows belonging to image channels `chans` back into
/// those channels' planes (the gradient path).
fn col2im_channels(
    g: &ConvGeom,
    data_col: &[f32],
    data_im: &mut [f32],
    chans: std::ops::Range<usize>,
) {
    let (oh, ow) = (g.out_h(), g.out_w());
    let ohw = oh * ow;
    let taps = g.kernel_h * g.kernel_w;
    // data_im starts at channel chans.start's plane.
    let plane0 = chans.start * g.height * g.width;
    for c in chans {
        for kh in 0..g.kernel_h {
            for kw in 0..g.kernel_w {
                let mut col_idx = ((c * taps) + kh * g.kernel_w + kw) * ohw;
                for y in 0..oh {
                    let iy = (y * g.stride_h + kh) as isize - g.pad_h as isize;
                    if iy < 0 || iy >= g.height as isize {
                        col_idx += ow;
                        continue;
                    }
                    let row_base = (c * g.height + iy as usize) * g.width - plane0;
                    for x in 0..ow {
                        let ix = (x * g.stride_w + kw) as isize - g.pad_w as isize;
                        if ix >= 0 && ix < g.width as isize {
                            data_im[row_base + ix as usize] += data_col[col_idx];
                        }
                        col_idx += 1;
                    }
                }
            }
        }
    }
}

/// data_col → data_im, *accumulating* overlapping windows (gradient path).
/// The output image must be zeroed by the caller if it starts fresh.
pub fn col2im(g: &ConvGeom, data_col: &[f32], data_im: &mut [f32]) {
    assert!(data_col.len() >= g.col_len(), "col2im: col too small");
    assert!(data_im.len() >= g.im_len(), "col2im: image too small");
    let plane = g.height * g.width;
    let per_chan = g.kernel_h * g.kernel_w * g.col_cols();
    let grain = (pool::GRAIN_ELEMWISE / per_chan.max(1)).max(1);
    let im = pool::SendPtr::new(data_im.as_mut_ptr());
    pool::parallel_for(0..g.channels, grain, |r| {
        // Safety: channel ranges are disjoint across tasks; plane `c`
        // only receives contributions from col rows with the same `c`.
        let chunk = unsafe { im.slice(r.start * plane, r.len() * plane) };
        col2im_channels(g, data_col, chunk, r);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::tcheck;

    #[test]
    fn identity_1x1() {
        let g = ConvGeom {
            channels: 2,
            height: 2,
            width: 2,
            kernel_h: 1,
            kernel_w: 1,
            pad_h: 0,
            pad_w: 0,
            stride_h: 1,
            stride_w: 1,
        };
        let im: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let mut col = vec![0.0; g.col_len()];
        im2col(&g, &im, &mut col);
        assert_eq!(col, im);
    }

    #[test]
    fn known_3x3_kernel_2x2_no_pad() {
        let g = ConvGeom {
            channels: 1,
            height: 3,
            width: 3,
            kernel_h: 2,
            kernel_w: 2,
            pad_h: 0,
            pad_w: 0,
            stride_h: 1,
            stride_w: 1,
        };
        // image 0..9 row-major
        let im: Vec<f32> = (0..9).map(|v| v as f32).collect();
        let mut col = vec![0.0; g.col_len()]; // 4 rows x 4 cols
        im2col(&g, &im, &mut col);
        // row 0 = top-left of each window: [0,1,3,4]
        assert_eq!(&col[0..4], &[0.0, 1.0, 3.0, 4.0]);
        // row 3 = bottom-right of each window: [4,5,7,8]
        assert_eq!(&col[12..16], &[4.0, 5.0, 7.0, 8.0]);
    }

    #[test]
    fn padding_produces_zero_border() {
        let g = ConvGeom {
            channels: 1,
            height: 2,
            width: 2,
            kernel_h: 3,
            kernel_w: 3,
            pad_h: 1,
            pad_w: 1,
            stride_h: 1,
            stride_w: 1,
        };
        let im = [1.0, 2.0, 3.0, 4.0];
        let mut col = vec![9.0; g.col_len()];
        im2col(&g, &im, &mut col);
        // kernel position (0,0) hits padding for the first output pixel
        assert_eq!(col[0], 0.0);
        // center tap (kh=1, kw=1) copies the image directly
        let center_row = (1 * 3 + 1) * g.col_cols();
        assert_eq!(&col[center_row..center_row + 4], &im);
    }

    /// col2im is the adjoint of im2col: <im2col(x), y> == <x, col2im(y)>.
    #[test]
    fn adjoint_property() {
        tcheck::check("im2col_adjoint", 32, |rng| {
            let g = ConvGeom {
                channels: rng.range_u(1, 3) as usize,
                height: rng.range_u(3, 8) as usize,
                width: rng.range_u(3, 8) as usize,
                kernel_h: rng.range_u(1, 3) as usize,
                kernel_w: rng.range_u(1, 3) as usize,
                pad_h: rng.range_u(0, 1) as usize,
                pad_w: rng.range_u(0, 1) as usize,
                stride_h: rng.range_u(1, 2) as usize,
                stride_w: rng.range_u(1, 2) as usize,
            };
            if g.height + 2 * g.pad_h < g.kernel_h || g.width + 2 * g.pad_w < g.kernel_w {
                return Ok(());
            }
            let mut x = vec![0.0; g.im_len()];
            let mut y = vec![0.0; g.col_len()];
            rng.fill_uniform(&mut x, -1.0, 1.0);
            rng.fill_uniform(&mut y, -1.0, 1.0);
            let mut colx = vec![0.0; g.col_len()];
            im2col(&g, &x, &mut colx);
            let mut imy = vec![0.0; g.im_len()];
            col2im(&g, &y, &mut imy);
            let lhs: f32 = colx.iter().zip(y.iter()).map(|(a, b)| a * b).sum();
            let rhs: f32 = x.iter().zip(imy.iter()).map(|(a, b)| a * b).sum();
            if (lhs - rhs).abs() > 1e-3 * (1.0 + lhs.abs()) {
                return Err(format!("adjoint mismatch: {lhs} vs {rhs} for {g:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn col2im_accumulates_overlaps() {
        let g = ConvGeom {
            channels: 1,
            height: 3,
            width: 1,
            kernel_h: 2,
            kernel_w: 1,
            pad_h: 0,
            pad_w: 0,
            stride_h: 1,
            stride_w: 1,
        };
        // col is 2 rows x 2 cols of ones; middle image pixel is covered twice.
        let col = vec![1.0; 4];
        let mut im = vec![0.0; 3];
        col2im(&g, &col, &mut im);
        assert_eq!(im, vec![1.0, 2.0, 1.0]);
    }

    #[test]
    fn stride_geometry() {
        let g = ConvGeom {
            channels: 1,
            height: 5,
            width: 5,
            kernel_h: 3,
            kernel_w: 3,
            pad_h: 0,
            pad_w: 0,
            stride_h: 2,
            stride_w: 2,
        };
        assert_eq!((g.out_h(), g.out_w()), (2, 2));
        let mut rng = Pcg32::new(3);
        let mut im = vec![0.0; g.im_len()];
        rng.fill_uniform(&mut im, -1.0, 1.0);
        let mut col = vec![0.0; g.col_len()];
        im2col(&g, &im, &mut col);
        // window at (1,1) output covers image rows 2..5, cols 2..5; its
        // (0,0) tap is image[2*5+2].
        assert_eq!(col[3], im[12]);
    }
}
