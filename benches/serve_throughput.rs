//! Serving-engine throughput bench: LeNet under a closed-loop load test
//! at micro-batch caps 1 / 8 / 32 in-process, the same engine config
//! behind the HTTP front-end (real sockets, persistent connections),
//! and a weight hot-swap leg (continuous publishes under load), emitting
//! `BENCH_serve.json` (requests/s and p99 latency per configuration).
//! `cargo bench --bench serve_throughput`; set `FECAFFE_BENCH_QUICK=1`
//! for the CI smoke variant (same shape, fewer requests).

use fecaffe::serve::{
    http_load_test, load_test, DeviceKind, Engine, EngineConfig, HttpConfig, HttpServer,
    ModelRouter, RouterConfig,
};
use fecaffe::util::json::Json;
use fecaffe::util::stats::summarize;
use fecaffe::zoo;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

const WORKERS: usize = 4;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("FECAFFE_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let (clients, requests) = if quick { (8, 96) } else { (16, 384) };
    let param = zoo::by_name("lenet", 1)?;
    let mut results = Vec::new();
    for &max_batch in &[1usize, 8, 32] {
        let cfg = EngineConfig {
            workers: WORKERS,
            max_batch,
            max_linger: Duration::from_micros(1000),
            queue_capacity: 1024,
            device: DeviceKind::Cpu,
            intra_op_threads: 0, // auto: split the machine across workers
            trace_sample: 0,     // sampling off — measures the wait-free path
            ..EngineConfig::default()
        };
        let engine = Engine::new(&param, cfg)?;
        // Warm the replicas (first forward pays blob upload + scratch
        // growth), then snapshot so warm-up traffic doesn't contaminate
        // the measured batch statistics.
        let _ = load_test(&engine, clients, clients * 2, 1);
        let warm = engine.metrics().snapshot();
        let report = load_test(&engine, clients, requests, 7);
        engine.shutdown();
        let snap = engine.metrics().snapshot();
        let batches = snap.batches - warm.batches;
        let samples = snap.batched_samples - warm.batched_samples;
        let mean_batch = if batches == 0 { 0.0 } else { samples as f64 / batches as f64 };

        anyhow::ensure!(report.requests > 0, "no completed requests at max-batch {max_batch}");
        let mut lats = report.latencies_ns.clone();
        let s = summarize(&format!("lenet serve, max-batch {max_batch:>2}"), &mut lats);
        println!(
            "{}   ({:.1} req/s, mean batch {mean_batch:.2})",
            s.line(),
            report.rps,
        );

        let mut o = Json::obj();
        o.set("transport", Json::str("inproc"));
        o.set("max_batch", Json::num(max_batch as f64));
        o.set("requests", Json::num(report.requests as f64));
        o.set("failed", Json::num(report.failed as f64));
        o.set("rps", Json::num(report.rps));
        o.set("p50_ms", Json::num(s.median_ns / 1e6));
        o.set("p99_ms", Json::num(s.p99_ns / 1e6));
        o.set("mean_batch", Json::num(mean_batch));
        results.push(o);
    }

    // HTTP path: the same serving stack behind the TcpListener
    // front-end — measures end-to-end over real sockets (parse +
    // JSON + engine), the number an external load generator sees.
    {
        let cfg = RouterConfig {
            total_workers: WORKERS,
            max_batch: 8,
            max_linger: Duration::from_micros(1000),
            queue_capacity: 1024,
            device: DeviceKind::Cpu,
            intra_op_threads: 0,
            trace_sample: 0,
            ..RouterConfig::default()
        };
        let router = Arc::new(ModelRouter::from_zoo(&["lenet"], &cfg)?);
        let sample_len = router.engine("lenet").expect("registered").sample_len();
        let server = HttpServer::bind("127.0.0.1:0", router, HttpConfig::default())?;
        let addr = server.local_addr().to_string();
        let _ = http_load_test(&addr, "lenet", sample_len, clients, clients * 2, 1)?; // warm
        let report = http_load_test(&addr, "lenet", sample_len, clients, requests, 7)?;
        server.shutdown();
        anyhow::ensure!(report.requests > 0, "no completed requests over HTTP");
        let mut lats = report.latencies_ns.clone();
        let s = summarize("lenet serve, http max-batch  8", &mut lats);
        println!("{}   ({:.1} req/s over HTTP)", s.line(), report.rps);

        let mut o = Json::obj();
        o.set("transport", Json::str("http"));
        o.set("max_batch", Json::num(8.0));
        o.set("requests", Json::num(report.requests as f64));
        o.set("failed", Json::num(report.failed as f64));
        o.set("rps", Json::num(report.rps));
        o.set("p50_ms", Json::num(s.median_ns / 1e6));
        o.set("p99_ms", Json::num(s.p99_ns / 1e6));
        results.push(o);
    }

    // Hot-swap path: the same in-process engine under closed-loop load
    // while a publisher thread continuously republishes the weights —
    // what continuous train-and-serve costs the serving path. Zero
    // failed requests is part of the contract, not just a perf number.
    {
        let cfg = EngineConfig {
            workers: WORKERS,
            max_batch: 8,
            max_linger: Duration::from_micros(1000),
            queue_capacity: 1024,
            device: DeviceKind::Cpu,
            intra_op_threads: 0,
            trace_sample: 0,
            ..EngineConfig::default()
        };
        let engine = Engine::new(&param, cfg)?;
        let _ = load_test(&engine, clients, clients * 2, 1); // warm
        let stop = AtomicBool::new(false);
        let publishes = AtomicU64::new(0);
        let report = std::thread::scope(|scope| {
            let publisher = scope.spawn(|| {
                let snap = engine.weights();
                while !stop.load(Ordering::Acquire) {
                    engine
                        .publish_weights(snap.clone().with_version(0))
                        .expect("publish under load");
                    publishes.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(Duration::from_millis(5));
                }
            });
            let report = load_test(&engine, clients, requests, 7);
            stop.store(true, Ordering::Release);
            publisher.join().expect("publisher panicked");
            report
        });
        anyhow::ensure!(
            report.failed == 0,
            "hot-swap load test had {} failed requests",
            report.failed
        );
        anyhow::ensure!(report.requests > 0, "no completed requests under hot-swap");
        let n_pub = publishes.load(Ordering::Relaxed);
        let version = engine.weights_version();
        engine.shutdown();
        let mut lats = report.latencies_ns.clone();
        let s = summarize("lenet serve, hot-swap     8", &mut lats);
        println!(
            "{}   ({:.1} req/s under {} publishes)",
            s.line(),
            report.rps,
            n_pub
        );

        let mut o = Json::obj();
        o.set("transport", Json::str("inproc+publish"));
        o.set("max_batch", Json::num(8.0));
        o.set("requests", Json::num(report.requests as f64));
        o.set("failed", Json::num(report.failed as f64));
        o.set("publishes", Json::num(n_pub as f64));
        o.set("weights_version", Json::num(version as f64));
        o.set("rps", Json::num(report.rps));
        o.set("p50_ms", Json::num(s.median_ns / 1e6));
        o.set("p99_ms", Json::num(s.p99_ns / 1e6));
        results.push(o);
    }

    // Low-occupancy path: offered load ≈ 25% of max_batch. The dynamic-
    // shape worker reshapes its replica to each batch's bucketed size,
    // so executed rows track offered rows instead of padding every
    // partial batch to max_batch — this leg records both (padded_rows is
    // what the pre-reshape pad-to-max worker would have executed) and
    // asserts the occupancy accounting is present.
    {
        let max_batch = 32usize;
        let low_clients = max_batch / 4; // 8 in-flight ≈ 25% offered load
        let cfg = EngineConfig {
            workers: 1,
            max_batch,
            max_linger: Duration::from_micros(1000),
            queue_capacity: 1024,
            device: DeviceKind::Cpu,
            intra_op_threads: 0,
            trace_sample: 0,
            ..EngineConfig::default()
        };
        let engine = Engine::new(&param, cfg)?;
        let _ = load_test(&engine, low_clients, low_clients * 2, 1); // warm
        let warm = engine.metrics().snapshot();
        let report = load_test(&engine, low_clients, requests, 7);
        engine.shutdown();
        let snap = engine.metrics().snapshot();
        let batches = snap.batches - warm.batches;
        let filled = snap.filled_rows - warm.filled_rows;
        let executed = snap.executed_rows - warm.executed_rows;
        let padded = batches * max_batch as u64;
        let occupancy = if executed == 0 { 0.0 } else { filled as f64 / executed as f64 };

        anyhow::ensure!(report.requests > 0, "no completed requests at low occupancy");
        anyhow::ensure!(
            occupancy > 0.0,
            "low-occupancy leg must report a batch occupancy"
        );
        anyhow::ensure!(
            executed < padded,
            "dynamic shapes must execute fewer rows than pad-to-max \
             ({executed} executed vs {padded} padded)"
        );
        let mut lats = report.latencies_ns.clone();
        let s = summarize("lenet serve, low-occupancy 32", &mut lats);
        println!(
            "{}   ({:.1} req/s, occupancy {occupancy:.2}: {filled} filled / {executed} executed \
             rows; pad-to-max would have executed {padded})",
            s.line(),
            report.rps,
        );

        let mut o = Json::obj();
        o.set("transport", Json::str("inproc-low-occupancy"));
        o.set("max_batch", Json::num(max_batch as f64));
        o.set("clients", Json::num(low_clients as f64));
        o.set("requests", Json::num(report.requests as f64));
        o.set("failed", Json::num(report.failed as f64));
        o.set("rps", Json::num(report.rps));
        o.set("p50_ms", Json::num(s.median_ns / 1e6));
        o.set("p99_ms", Json::num(s.p99_ns / 1e6));
        o.set("filled_rows", Json::num(filled as f64));
        o.set("executed_rows", Json::num(executed as f64));
        o.set("padded_rows", Json::num(padded as f64));
        o.set("occupancy", Json::num(occupancy));
        results.push(o);
    }

    let mut root = Json::obj();
    root.set("bench", Json::str("serve_throughput"));
    root.set("net", Json::str("lenet"));
    root.set("workers", Json::num(WORKERS as f64));
    root.set("clients", Json::num(clients as f64));
    root.set("results", Json::Arr(results));
    std::fs::write("BENCH_serve.json", root.to_pretty())?;
    println!("wrote BENCH_serve.json");
    Ok(())
}
