//! Accuracy layer — evaluation-only metric computed host-side (Caffe does
//! the same: AccuracyLayer has no GPU implementation), which exercises the
//! FPGA→host read path of the syncedmem state machine.

use super::{Layer, SharedBlob};
use crate::device::Device;
use crate::math::accuracy;
use crate::proto::LayerParameter;

pub struct AccuracyLayer {
    name: String,
    top_k: usize,
    n: usize,
    c: usize,
}

impl AccuracyLayer {
    pub fn new(param: &LayerParameter) -> AccuracyLayer {
        AccuracyLayer {
            name: param.name.clone(),
            top_k: param.accuracy.as_ref().map(|a| a.top_k).unwrap_or(1),
            n: 0,
            c: 0,
        }
    }
}

impl Layer for AccuracyLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "Accuracy"
    }
    fn needs_backward(&self) -> bool {
        false
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(bottoms.len() == 2, "Accuracy: needs [scores, labels]");
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let b = bottoms[0].borrow();
        self.n = b.num();
        self.c = b.count() / self.n.max(1);
        drop(b);
        tops[0].borrow_mut().reshape_grow_only(dev, &[1]);
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        // Host-side: sync scores + labels back (Read_Buffer events on the
        // FPGA device).
        let mut s = bottoms[0].borrow_mut();
        let scores = s.data.host_data(dev).to_vec();
        drop(s);
        let mut l = bottoms[1].borrow_mut();
        let labels = l.data.host_data(dev).to_vec();
        drop(l);
        let acc = accuracy(&scores, &labels, self.n, self.c, self.top_k);
        tops[0].borrow_mut().set_data(dev, &[acc]);
        Ok(0.0)
    }

    fn backward(
        &mut self,
        _dev: &mut dyn Device,
        _tops: &[SharedBlob],
        _prop_down: &[bool],
        _bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::Blob;
    use crate::device::cpu::CpuDevice;

    #[test]
    fn computes_topk() {
        let mut dev = CpuDevice::new();
        let mut lp = LayerParameter::new("acc", "Accuracy");
        lp.accuracy = Some(crate::proto::AccuracyParameter { top_k: 1 });
        let mut layer = AccuracyLayer::new(&lp);
        let scores = super::super::shared(Blob::new("s", &[2, 3]));
        let labels = super::super::shared(Blob::new("y", &[2]));
        let top = super::super::shared(Blob::new("a", &[1]));
        scores
            .borrow_mut()
            .set_data(&mut dev, &[0.9, 0.05, 0.05, 0.1, 0.1, 0.8]);
        labels.borrow_mut().set_data(&mut dev, &[0.0, 0.0]);
        layer
            .setup(&mut dev, &[scores.clone(), labels.clone()], &[top.clone()])
            .unwrap();
        layer
            .forward(&mut dev, &[scores, labels], &[top.clone()])
            .unwrap();
        assert_eq!(top.borrow_mut().data_vec(&mut dev), vec![0.5]);
    }
}
