//! E8 — §5.2 ablation: synchronous (paper default) vs asynchronous queue.

fn main() -> anyhow::Result<()> {
    println!("{}", fecaffe::bench_tables::ablation_async()?);
    println!("{}", fecaffe::bench_tables::ablation_partition()?);
    Ok(())
}
