//! F-CNN execution model (the paper's Table 4 comparator).
//!
//! F-CNN maps each layer onto a fixed systolic pipeline reconfigured
//! between layers, streaming feature maps from board DDR at 150 MHz.
//! The model: convolution is compute-bound on the pipeline with a
//! per-layer *fill efficiency* (shallow input channels fill the systolic
//! chain better — F-CNN's own published numbers imply ~1.25 MAC/cycle on
//! conv1 (C_in = 1) vs ~0.88 on conv2 (C_in = 20)); pooling streams at an
//! effective ~40 MB/s (their pool layers are reconfiguration/stream
//! bound); FC layers are compute-bound plus a fixed ~170 ms
//! reconfiguration. Backward multiplies by the measured fwd→bwd factor
//! (two extra passes at lower pipeline efficiency).
//!
//! Every constant is calibrated against the *published* LeNet batch-384
//! per-layer times from [8] and validated by the unit tests below; the
//! Table 4 ratios then emerge from running this model and the FeCaffe
//! simulator on the same workload.

/// F-CNN machine constants (from [8] and its board spec).
pub struct FcnnMachine {
    pub fmax_hz: f64,
    /// Pipeline fill efficiency by input depth: MACs/cycle.
    pub conv_eff_shallow: f64, // C_in < 8
    pub conv_eff_deep: f64,    // C_in ≥ 8
    /// Effective pooling stream rate (reconfig + DDR bound).
    pub pool_bytes_per_s: f64,
    /// FC pipeline efficiency (MACs/cycle) and per-layer reconfig.
    pub fc_eff: f64,
    pub fc_reconfig_s: f64,
    /// Backward multipliers (measured from [8]: conv ≈ 2.1×, pool ≈ 1.07×,
    /// fc ≈ 2×).
    pub conv_bwd_factor: f64,
    pub pool_bwd_factor: f64,
    pub fc_bwd_factor: f64,
}

impl Default for FcnnMachine {
    fn default() -> Self {
        FcnnMachine {
            fmax_hz: 150.0e6,
            conv_eff_shallow: 1.25,
            conv_eff_deep: 0.88,
            pool_bytes_per_s: 40.0e6,
            fc_eff: 1.11,
            fc_reconfig_s: 0.17,
            conv_bwd_factor: 2.1,
            pool_bwd_factor: 1.07,
            fc_bwd_factor: 2.0,
        }
    }
}

/// LeNet layer workload description (per image).
#[derive(Debug, Clone, Copy)]
pub enum LayerWork {
    /// (MACs per image, input channels)
    Conv { macs: u64, c_in: usize },
    /// bytes streamed per image (in + out feature maps)
    Pool { bytes: u64 },
    /// MACs per image
    Fc { macs: u64 },
}

impl FcnnMachine {
    fn conv_eff(&self, c_in: usize) -> f64 {
        if c_in < 8 {
            self.conv_eff_shallow
        } else {
            self.conv_eff_deep
        }
    }

    /// Forward time for a layer over `batch` images, seconds.
    pub fn forward_s(&self, work: LayerWork, batch: usize) -> f64 {
        let b = batch as f64;
        match work {
            LayerWork::Conv { macs, c_in } => {
                b * macs as f64 / (self.conv_eff(c_in) * self.fmax_hz)
            }
            LayerWork::Pool { bytes } => b * bytes as f64 / self.pool_bytes_per_s,
            LayerWork::Fc { macs } => {
                b * macs as f64 / (self.fc_eff * self.fmax_hz) + self.fc_reconfig_s
            }
        }
    }

    /// Backward time for a layer over `batch` images, seconds.
    pub fn backward_s(&self, work: LayerWork, batch: usize) -> f64 {
        match work {
            LayerWork::Conv { .. } => self.forward_s(work, batch) * self.conv_bwd_factor,
            LayerWork::Pool { .. } => self.forward_s(work, batch) * self.pool_bwd_factor,
            LayerWork::Fc { .. } => {
                (self.forward_s(work, batch) - self.fc_reconfig_s) * self.fc_bwd_factor
                    + self.fc_reconfig_s
            }
        }
    }
}

/// LeNet L1–L6 workloads (per image), matching the paper's row labels.
pub fn lenet_layers() -> Vec<(&'static str, LayerWork)> {
    vec![
        ("L1 (Conv)", LayerWork::Conv { macs: 20 * 24 * 24 * 25, c_in: 1 }),
        ("L2 (Pool)", LayerWork::Pool { bytes: 4 * (20 * 24 * 24 + 20 * 12 * 12) }),
        ("L3 (Conv)", LayerWork::Conv { macs: 50 * 8 * 8 * 25 * 20, c_in: 20 }),
        ("L4 (Pool)", LayerWork::Pool { bytes: 4 * (50 * 8 * 8 + 50 * 4 * 4) }),
        ("L5 (FC)", LayerWork::Fc { macs: 800 * 500 }),
        ("L6 (FC)", LayerWork::Fc { macs: 500 * 10 }),
    ]
}

/// The published LeNet batch-384 numbers from [8] (ms) for validation.
pub const PUBLISHED_FWD_MS: [f64; 6] = [590.0, 530.0, 4670.0, 180.0, 920.0, 180.0];
pub const PUBLISHED_BWD_MS: [f64; 6] = [1210.0, 570.0, 10320.0, 180.0, 1820.0, 200.0];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_reproduces_published_totals_within_15pct() {
        let m = FcnnMachine::default();
        let layers = lenet_layers();
        let fwd: f64 = layers
            .iter()
            .map(|(_, w)| m.forward_s(*w, 384) * 1e3)
            .sum();
        let bwd: f64 = layers
            .iter()
            .map(|(_, w)| m.backward_s(*w, 384) * 1e3)
            .sum();
        let pub_fwd: f64 = PUBLISHED_FWD_MS.iter().sum();
        let pub_bwd: f64 = PUBLISHED_BWD_MS.iter().sum();
        assert!(
            (fwd - pub_fwd).abs() / pub_fwd < 0.15,
            "fwd {fwd:.0} vs published {pub_fwd:.0}"
        );
        assert!(
            (bwd - pub_bwd).abs() / pub_bwd < 0.15,
            "bwd {bwd:.0} vs published {pub_bwd:.0}"
        );
    }

    #[test]
    fn per_layer_within_2x_of_published() {
        let m = FcnnMachine::default();
        for (i, (name, w)) in lenet_layers().iter().enumerate() {
            let fwd = m.forward_s(*w, 384) * 1e3;
            let bwd = m.backward_s(*w, 384) * 1e3;
            let rf = fwd / PUBLISHED_FWD_MS[i];
            let rb = bwd / PUBLISHED_BWD_MS[i];
            assert!((0.5..2.0).contains(&rf), "{name} fwd ratio {rf}");
            assert!((0.5..2.0).contains(&rb), "{name} bwd ratio {rb}");
        }
    }

    #[test]
    fn conv2_dominates_like_published() {
        let m = FcnnMachine::default();
        let layers = lenet_layers();
        let times: Vec<f64> = layers.iter().map(|(_, w)| m.forward_s(*w, 384)).collect();
        let max = times.iter().cloned().fold(0.0, f64::max);
        assert_eq!(times[2], max, "conv2 must be the slowest layer");
    }
}
