//! AOT kernel runtime — the `.aocx` loading half of the architecture.
//!
//! `make artifacts` runs `gen-manifest` (walks the zoo with a
//! [`recording::RecordingDevice`] and emits `artifacts/manifest.json`),
//! then python lowers every entry to `artifacts/<key>.hlo.txt` (L1
//! Pallas gemm/gemv + L2 jnp kernels, `interpret=True`). At run time
//! [`pjrt::PjrtBackend`] lazily compiles each HLO on the PJRT CPU client
//! and serves kernel launches from the executable cache; python is never
//! on the request path.

pub mod plan;
pub mod recording;

// The PJRT executor needs the off-by-default `xla` feature *and* the
// offline-vendored `xla` crate closure (build.rs emits `xla_vendored`
// when `../vendor/xla` is present). Any other combination — including
// the CI `xla-check` leg, which turns the feature on without the
// closure — compiles the stub, which keeps the same public surface and
// routes every kernel to the native math path.
#[cfg(all(feature = "xla", xla_vendored))]
pub mod pjrt;
#[cfg(not(all(feature = "xla", xla_vendored)))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use plan::{kernel_plan, Arg, ExecPlan};
pub use pjrt::PjrtBackend;

/// Default artifacts directory (relative to the repo root / cwd).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts dir: $FECAFFE_ARTIFACTS, ./artifacts, or
/// ../artifacts (for tests running in target dirs).
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("FECAFFE_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.is_dir() {
            return Some(p);
        }
    }
    for cand in [ARTIFACTS_DIR, "../artifacts", "../../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.join("manifest.json").is_file() {
            return Some(p);
        }
    }
    None
}
