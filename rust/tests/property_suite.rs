//! Property tests (hand-rolled tcheck harness — DESIGN.md §10) over the
//! substrates' invariants: allocator, syncedmem coherence, prototxt
//! round-trips, split insertion, and the simulator's queue model.

use fecaffe::blob::{MemState, SyncedMem};
use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::ddr::DdrTracker;
use fecaffe::device::fpga::{FpgaSimDevice, QueueMode};
use fecaffe::device::{Device, Kernel, KernelCall};
use fecaffe::net::insert_splits;
use fecaffe::proto::{self, LayerParameter};
use fecaffe::util::tcheck;

#[test]
fn ddr_tracker_never_overbooks() {
    tcheck::check("ddr_overbook", 64, |rng| {
        let cap = rng.range_u(1_000, 100_000) as u64;
        let mut ddr = DdrTracker::new(cap);
        let mut live: Vec<(usize, u64)> = Vec::new();
        let mut next_id = 0usize;
        for _ in 0..200 {
            if rng.bernoulli(0.6) || live.is_empty() {
                let sz = rng.range_u(1, (cap / 4).max(2) as u32) as u64;
                if ddr.alloc(next_id, sz).is_ok() {
                    live.push((next_id, sz));
                }
                next_id += 1;
            } else {
                let i = rng.below(live.len() as u32) as usize;
                let (id, _) = live.swap_remove(i);
                ddr.free(id);
            }
            let used: u64 = live.iter().map(|(_, s)| s).sum();
            if ddr.used() != used {
                return Err(format!("accounting drift: {} vs {}", ddr.used(), used));
            }
            if ddr.used() > cap {
                return Err("over capacity".into());
            }
        }
        Ok(())
    });
}

#[test]
fn syncedmem_random_walk_never_loses_data() {
    tcheck::check("syncedmem_walk", 48, |rng| {
        let mut dev = CpuDevice::new();
        let n = rng.range_u(1, 64) as usize;
        let mut mem = SyncedMem::new(n);
        // shadow = ground truth
        let mut shadow = vec![0f32; n];
        for step in 0..40 {
            match rng.below(4) {
                0 => {
                    // host write
                    let v = rng.uniform(-5.0, 5.0);
                    let idx = rng.below(n as u32) as usize;
                    mem.host_data_mut(&mut dev)[idx] = v;
                    shadow[idx] = v;
                }
                1 => {
                    // device write through a kernel (scale by known factor)
                    let id = mem.dev_data(&mut dev);
                    let id2 = mem.dev_data_rw(&mut dev);
                    assert_eq!(id, id2);
                    dev.launch(&KernelCall::new(
                        Kernel::Scal { n, alpha: 2.0 },
                        &[id2],
                        &[id2],
                    ))
                    .unwrap();
                    for v in shadow.iter_mut() {
                        *v *= 2.0;
                    }
                }
                2 => {
                    // read host — must equal shadow
                    let host = mem.host_data(&mut dev);
                    if host != &shadow[..] {
                        return Err(format!("step {step}: host {host:?} != {shadow:?}"));
                    }
                }
                _ => {
                    let _ = mem.dev_data(&mut dev); // sync only
                }
            }
        }
        let host = mem.host_data(&mut dev).to_vec();
        if host != shadow {
            return Err("final state diverged".into());
        }
        if mem.state() == MemState::Uninit {
            return Err("state machine stuck at Uninit".into());
        }
        Ok(())
    });
}

#[test]
fn prototxt_emit_parse_emit_fixpoint_random_nets() {
    tcheck::check("prototxt_fixpoint", 32, |rng| {
        // Build a random sequential net with the builder.
        let mut b = fecaffe::zoo::NetBuilder::new("rand");
        b.data(rng.range_u(1, 8) as usize, 1, 16, 4, "digits");
        let mut prev = "data".to_string();
        let depth = rng.range_u(1, 5);
        for i in 0..depth {
            match rng.below(3) {
                0 => {
                    let name = format!("conv{i}");
                    b.conv_relu(&name, &prev, rng.range_u(1, 8) as usize, 3, 1, 1);
                    prev = name;
                }
                1 => {
                    let name = format!("pool{i}");
                    b.pool(&name, &prev, proto::PoolMethod::Max, 2, 2, 0);
                    prev = name;
                }
                _ => {
                    let name = format!("fc{i}");
                    b.fc(&name, &prev, rng.range_u(2, 16) as usize);
                    prev = name;
                }
            }
        }
        b.softmax_loss("loss", &prev, 1.0);
        let net = b.finish();
        let t1 = proto::emit::emit_net(&net);
        let parsed = proto::parse_net(&t1).map_err(|e| e.to_string())?;
        if parsed != net {
            return Err("parse(emit(net)) != net".into());
        }
        let t2 = proto::emit::emit_net(&parsed);
        if t1 != t2 {
            return Err("emit not a fixpoint".into());
        }
        Ok(())
    });
}

#[test]
fn insert_splits_preserves_consumer_counts() {
    tcheck::check("split_consumers", 32, |rng| {
        // Random DAG: each layer consumes a random earlier blob.
        let mut layers = Vec::new();
        let mut d = LayerParameter::new("data", "SyntheticData");
        d.tops = vec!["b0".into()];
        layers.push(d);
        let n = rng.range_u(2, 10) as usize;
        for i in 1..=n {
            let src = rng.below(i as u32) as usize;
            let mut l = LayerParameter::new(&format!("l{i}"), "ReLU");
            l.bottoms = vec![format!("b{src}")];
            l.tops = vec![format!("b{i}")];
            layers.push(l);
        }
        let out = insert_splits(&layers);
        // Invariant 1: every bottom reference resolves to a produced blob.
        let mut produced: std::collections::HashSet<String> = Default::default();
        for l in &out {
            for b in &l.bottoms {
                if !produced.contains(b) {
                    return Err(format!("{}: bottom {b} not yet produced", l.name));
                }
            }
            for t in &l.tops {
                produced.insert(t.clone());
            }
        }
        // Invariant 2: after splitting, no blob is consumed twice.
        let mut seen: std::collections::HashMap<String, usize> = Default::default();
        for l in &out {
            for b in &l.bottoms {
                *seen.entry(b.clone()).or_insert(0) += 1;
            }
        }
        for (b, c) in seen {
            if c > 1 {
                return Err(format!("blob {b} still has {c} consumers"));
            }
        }
        Ok(())
    });
}

#[test]
fn async_never_slower_than_sync() {
    tcheck::check("async_le_sync", 24, |rng| {
        let ops: Vec<(usize, bool)> = (0..rng.range_u(2, 20))
            .map(|_| (rng.range_u(100, 100_000) as usize, rng.bernoulli(0.4)))
            .collect();
        let run = |mode: QueueMode| -> u64 {
            let mut dev = FpgaSimDevice::new();
            dev.timing_only = true;
            dev.set_mode(mode);
            let x = dev.alloc(100_000).unwrap();
            let y = dev.alloc(100_000).unwrap();
            let data = vec![0f32; 100_000];
            for &(n, is_write) in &ops {
                if is_write {
                    dev.write(x, &data[..n]);
                } else {
                    dev.launch(&KernelCall::new(
                        Kernel::ReluF { n, slope: 0.0 },
                        &[x],
                        &[y],
                    ))
                    .unwrap();
                }
            }
            dev.synchronize();
            dev.sim_clock_ns().unwrap()
        };
        let sync = run(QueueMode::Sync);
        let async_ = run(QueueMode::Async);
        if async_ > sync {
            return Err(format!("async {async_} > sync {sync}"));
        }
        Ok(())
    });
}

#[test]
fn gemm_matches_naive_on_random_shapes() {
    tcheck::check("gemm_naive", 32, |rng| {
        let (m, n, k) = (
            rng.range_u(1, 48) as usize,
            rng.range_u(1, 48) as usize,
            rng.range_u(1, 48) as usize,
        );
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        rng.fill_uniform(&mut a, -1.0, 1.0);
        rng.fill_uniform(&mut b, -1.0, 1.0);
        let mut c = vec![0f32; m * n];
        fecaffe::math::gemm(
            fecaffe::math::Trans::No,
            fecaffe::math::Trans::No,
            m,
            n,
            k,
            1.0,
            &a,
            &b,
            0.0,
            &mut c,
        );
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                if (acc - c[i * n + j]).abs() > 1e-3 {
                    return Err(format!("({i},{j}): {acc} vs {}", c[i * n + j]));
                }
            }
        }
        Ok(())
    });
}
