//! Perf bench — the L3 hot path (DESIGN.md §7 targets):
//!   * kernel-launch overhead on the simulator (bookkeeping only),
//!   * native gemm throughput (CPU fallback engine),
//!   * PJRT dispatch overhead per artifact launch (marshal + execute),
//!   * end-to-end LeNet train-iteration rate.
//! Results feed EXPERIMENTS.md §Perf.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::{Device, Kernel, KernelCall};
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::runtime::PjrtBackend;
use fecaffe::solver::Solver;
use fecaffe::util::stats::bench;
use fecaffe::zoo;

fn main() -> anyhow::Result<()> {
    // 1. Simulator launch bookkeeping (timing-only: pure L3 cost).
    {
        let mut dev = FpgaSimDevice::new();
        dev.timing_only = true;
        let x = dev.alloc(1024)?;
        let y = dev.alloc(1024)?;
        let call = KernelCall::new(Kernel::ReluF { n: 1024, slope: 0.0 }, &[x], &[y]);
        let s = bench("sim launch bookkeeping", 1000, 20_000, || {
            dev.launch(&call).unwrap();
        });
        println!("{}", s.line());
    }

    // 2. Native gemm throughput (googlenet inception 3x3 shape).
    {
        let mut dev = CpuDevice::new();
        let (m, k, n) = (128usize, 1152, 784);
        let a = dev.alloc(m * k)?;
        let b = dev.alloc(k * n)?;
        let c = dev.alloc(m * n)?;
        // Random data: zero buffers would trip the gemm zero-skip fast
        // path and overstate throughput.
        let mut rng = fecaffe::util::prng::Pcg32::new(1);
        let mut va = vec![0f32; m * k];
        let mut vb = vec![0f32; k * n];
        rng.fill_uniform(&mut va, -1.0, 1.0);
        rng.fill_uniform(&mut vb, -1.0, 1.0);
        dev.write(a, &va);
        dev.write(b, &vb);
        let call = KernelCall::new(
            Kernel::GemmNN { m, n, k, alpha: 1.0, beta: 0.0 },
            &[a, b],
            &[c],
        );
        let s = bench("native gemm 128x1152x784", 2, 20, || {
            dev.launch(&call).unwrap();
        });
        let gflops = 2.0 * (m * n * k) as f64 / s.median_ns;
        println!("{}   ({gflops:.2} GFLOP/s)", s.line());
    }

    // 3. PJRT dispatch for the same gemm (if artifacts exist).
    if let Some(backend) = PjrtBackend::auto() {
        let mut dev = FpgaSimDevice::new().with_backend(Box::new(backend));
        let (m, k, n) = (128usize, 1152, 784);
        let a = dev.alloc(m * k)?;
        let b = dev.alloc(k * n)?;
        let c = dev.alloc(m * n)?;
        let mut rng = fecaffe::util::prng::Pcg32::new(1);
        let mut va = vec![0f32; m * k];
        let mut vb = vec![0f32; k * n];
        rng.fill_uniform(&mut va, -1.0, 1.0);
        rng.fill_uniform(&mut vb, -1.0, 1.0);
        dev.write(a, &va);
        dev.write(b, &vb);
        let call = KernelCall::new(
            Kernel::GemmNN { m, n, k, alpha: 1.0, beta: 0.0 },
            &[a, b],
            &[c],
        );
        let s = bench("pjrt gemm 128x1152x784", 2, 20, || {
            dev.launch(&call).unwrap();
        });
        let gflops = 2.0 * (m * n * k) as f64 / s.median_ns;
        println!("{}   ({gflops:.2} GFLOP/s incl. marshal)", s.line());
    } else {
        println!("pjrt gemm: skipped (no artifacts; run `make artifacts`)");
    }

    // 4. End-to-end LeNet train iteration (numerics on, batch 16).
    {
        let mut dev = FpgaSimDevice::new();
        let param = zoo::by_name("lenet", 16)?;
        let net = Net::from_param(&param, Phase::Train, &mut dev)?;
        let mut solver = Solver::new(zoo::default_solver("lenet")?, net, &mut dev)?;
        solver.step(&mut dev)?; // warm
        let s = bench("lenet train iter (native, bs16)", 1, 10, || {
            solver.step(&mut dev).unwrap();
        });
        println!("{}", s.line());
    }
    Ok(())
}
