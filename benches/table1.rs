//! E1 — regenerate paper Table 1: per-layer forward/backward times for
//! AlexNet, VGG-16, SqueezeNet v1.0 and GoogLeNet v1 at batch 1 on the
//! simulated Stratix 10 board. `cargo bench --bench table1`.

fn main() -> anyhow::Result<()> {
    println!("{}", fecaffe::bench_tables::table1()?);
    println!("Paper reference totals (Table 1, ms):");
    println!("  AlexNet      fwd  93.2   bwd 177.5   F->B  270.8");
    println!("  VGG_16       fwd 1270.4  bwd 2684.9  F->B 3955.4");
    println!("  SqueezeNet   fwd 199.5   bwd 263.0   F->B  462.6");
    println!("  GoogLeNet    fwd 341.3   bwd 516.5   F->B  857.8");
    Ok(())
}
