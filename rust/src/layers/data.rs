//! SyntheticData layer — the input layer (stands in for Caffe's LMDB
//! `Data` layer; see DESIGN.md substitution table). Each forward draws a
//! host-side batch from the configured [`crate::data::DataSource`] and
//! uploads it, so on the FPGA device every iteration starts with the
//! same `Write_Buffer` traffic real FeCaffe pays for input data.

use super::{Layer, SharedBlob};
use crate::data::{create_source, DataSource};
use crate::device::Device;
use crate::proto::{LayerParameter, Phase, SyntheticDataParameter};
use crate::util::prng::Pcg32;

pub struct SyntheticDataLayer {
    name: String,
    p: SyntheticDataParameter,
    source: Box<dyn DataSource>,
    rng: Pcg32,
}

impl SyntheticDataLayer {
    pub fn new(param: &LayerParameter, phase: Phase) -> anyhow::Result<SyntheticDataLayer> {
        let p = param
            .data
            .clone()
            .ok_or_else(|| anyhow::anyhow!("layer {}: missing data_param", param.name))?;
        let source = create_source(&p.source, p.channels, p.height, p.width, p.num_classes)?;
        // Distinct stream per phase so TRAIN and TEST see different data.
        let stream = match phase {
            Phase::Train => 1,
            Phase::Test => 2,
        };
        Ok(SyntheticDataLayer {
            name: param.name.clone(),
            rng: Pcg32::with_stream(p.seed, stream),
            p,
            source,
        })
    }
}

impl Layer for SyntheticDataLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "SyntheticData"
    }
    fn needs_backward(&self) -> bool {
        false
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(bottoms.is_empty(), "data layer takes no bottoms");
        anyhow::ensure!(tops.len() == 2, "data layer: tops = [data, label]");
        self.reshape(dev, bottoms, tops)
    }

    /// The data layer owns its batch: a net-wide reshape re-asserts the
    /// configured `batch_size` rather than following an upstream shape
    /// (there is none — this is the source).
    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        _bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let (c, h, w) = self.source.shape();
        tops[0]
            .borrow_mut()
            .reshape_grow_only(dev, &[self.p.batch_size, c, h, w]);
        tops[1]
            .borrow_mut()
            .reshape_grow_only(dev, &[self.p.batch_size]);
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        _bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let batch = self.source.batch(&mut self.rng, self.p.batch_size);
        tops[0].borrow_mut().set_data(dev, &batch.data);
        tops[1].borrow_mut().set_data(dev, &batch.labels);
        // Push to device now so the Write_Buffer cost lands in this
        // layer's timing (as the paper's data loading does).
        tops[0].borrow_mut().data.dev_data(dev);
        tops[1].borrow_mut().data.dev_data(dev);
        Ok(0.0)
    }

    fn backward(
        &mut self,
        _dev: &mut dyn Device,
        _tops: &[SharedBlob],
        _prop_down: &[bool],
        _bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::Blob;
    use crate::device::cpu::CpuDevice;
    use crate::proto::parse_text;

    fn mk(batch: usize) -> SyntheticDataLayer {
        let text = format!(
            r#"layer {{ name: "d" type: "SyntheticData" top: "data" top: "label"
                 data_param {{ batch_size: {batch} channels: 1 height: 28 width: 28
                               num_classes: 10 source: "digits" seed: 3 }} }}"#
        );
        let m = parse_text(&text).unwrap();
        let lp = LayerParameter::from_message(m.msgs("layer").next().unwrap()).unwrap();
        SyntheticDataLayer::new(&lp, Phase::Train).unwrap()
    }

    #[test]
    fn shapes_and_fresh_batches() {
        let mut dev = CpuDevice::new();
        let mut layer = mk(4);
        let data = super::super::shared(Blob::new("data", &[1]));
        let label = super::super::shared(Blob::new("label", &[1]));
        layer
            .setup(&mut dev, &[], &[data.clone(), label.clone()])
            .unwrap();
        assert_eq!(data.borrow().shape(), &[4, 1, 28, 28]);
        layer
            .forward(&mut dev, &[], &[data.clone(), label.clone()])
            .unwrap();
        let b1 = data.borrow_mut().data_vec(&mut dev);
        layer
            .forward(&mut dev, &[], &[data.clone(), label.clone()])
            .unwrap();
        let b2 = data.borrow_mut().data_vec(&mut dev);
        assert_ne!(b1, b2, "successive batches must differ");
        let labels = label.borrow_mut().data_vec(&mut dev);
        assert!(labels.iter().all(|&l| (0.0..10.0).contains(&l)));
    }
}
