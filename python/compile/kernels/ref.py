"""Pure-numpy correctness oracles for every kernel (the build-time
equivalent of the rust native math library; pytest checks the Pallas/jnp
kernels against these before artifacts ship)."""

import numpy as np


def gemm(a, b, ta=False, tb=False, c=None):
    a = a.T if ta else a
    b = b.T if tb else b
    out = a.astype(np.float64) @ b.astype(np.float64)
    if c is not None:
        out = out + c
    return out.astype(np.float32)


def gemv(a, x, trans=False, y=None):
    out = (a.T if trans else a).astype(np.float64) @ x.astype(np.float64)
    if y is not None:
        out = out + y
    return out.astype(np.float32)


def im2col(im, kh, kw, sh, sw, ph, pw):
    """im: (C,H,W) -> (C*kh*kw, oh*ow), matching the rust loop order."""
    c, h, w = im.shape
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (w + 2 * pw - kw) // sw + 1
    padded = np.zeros((c, h + 2 * ph, w + 2 * pw), dtype=im.dtype)
    padded[:, ph:ph + h, pw:pw + w] = im
    rows = []
    for ci in range(c):
        for ki in range(kh):
            for kj in range(kw):
                rows.append(
                    padded[ci, ki:ki + sh * oh:sh, kj:kj + sw * ow:sw].reshape(-1)
                )
    return np.stack(rows)


def col2im(col, c, h, w, kh, kw, sh, sw, ph, pw, im=None):
    """Adjoint of im2col, accumulating into `im` (zeros if None)."""
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (w + 2 * pw - kw) // sw + 1
    padded = np.zeros((c, h + 2 * ph, w + 2 * pw), dtype=np.float32)
    idx = 0
    for ci in range(c):
        for ki in range(kh):
            for kj in range(kw):
                padded[ci, ki:ki + sh * oh:sh, kj:kj + sw * ow:sw] += col[idx].reshape(oh, ow)
                idx += 1
    out = padded[:, ph:ph + h, pw:pw + w]
    if im is not None:
        out = out + im
    return out


def pooled_dim(inp, k, p, s):
    out = int(np.ceil((inp + 2 * p - k) / s)) + 1
    if p > 0 and (out - 1) * s >= inp + p:
        out -= 1
    return out


def max_pool_forward(x, kh, kw, sh, sw, ph, pw):
    """x: (N,C,H,W) -> (top, mask) with mask = plane argmax index."""
    n, c, h, w = x.shape
    oh, ow = pooled_dim(h, kh, ph, sh), pooled_dim(w, kw, pw, sw)
    top = np.full((n, c, oh, ow), -np.inf, dtype=np.float32)
    mask = np.zeros((n, c, oh, ow), dtype=np.float32)
    for ni in range(n):
        for ci in range(c):
            for y in range(oh):
                for xx in range(ow):
                    hs = max(y * sh - ph, 0)
                    ws = max(xx * sw - pw, 0)
                    he = min(y * sh - ph + kh, h)
                    we = min(xx * sw - pw + kw, w)
                    win = x[ni, ci, hs:he, ws:we]
                    ij = np.unravel_index(np.argmax(win), win.shape)
                    top[ni, ci, y, xx] = win[ij]
                    mask[ni, ci, y, xx] = (hs + ij[0]) * w + (ws + ij[1])
    return top, mask


def max_pool_backward(td, mask, h, w):
    n, c, oh, ow = td.shape
    bd = np.zeros((n, c, h * w), dtype=np.float32)
    for ni in range(n):
        for ci in range(c):
            for y in range(oh):
                for xx in range(ow):
                    bd[ni, ci, int(mask[ni, ci, y, xx])] += td[ni, ci, y, xx]
    return bd.reshape(n, c, h, w)


def ave_pool_forward(x, kh, kw, sh, sw, ph, pw):
    n, c, h, w = x.shape
    oh, ow = pooled_dim(h, kh, ph, sh), pooled_dim(w, kw, pw, sw)
    top = np.zeros((n, c, oh, ow), dtype=np.float32)
    for y in range(oh):
        for xx in range(ow):
            hs0, ws0 = y * sh - ph, xx * sw - pw
            he0 = min(hs0 + kh, h + ph)
            we0 = min(ws0 + kw, w + pw)
            size = (he0 - hs0) * (we0 - ws0)
            hs, ws = max(hs0, 0), max(ws0, 0)
            he, we = min(he0, h), min(we0, w)
            top[:, :, y, xx] = x[:, :, hs:he, ws:we].sum(axis=(2, 3)) / size
    return top


def ave_pool_backward(td, h, w, kh, kw, sh, sw, ph, pw):
    n, c, oh, ow = td.shape
    bd = np.zeros((n, c, h, w), dtype=np.float32)
    for y in range(oh):
        for xx in range(ow):
            hs0, ws0 = y * sh - ph, xx * sw - pw
            he0 = min(hs0 + kh, h + ph)
            we0 = min(ws0 + kw, w + pw)
            size = (he0 - hs0) * (we0 - ws0)
            hs, ws = max(hs0, 0), max(ws0, 0)
            he, we = min(he0, h), min(we0, w)
            bd[:, :, hs:he, ws:we] += td[:, :, y:y + 1, xx:xx + 1] / size
    return bd


def lrn_scale(x, local_size, alpha, k):
    """x: (N,C,D) -> scale."""
    n, c, d = x.shape
    half = (local_size - 1) // 2
    sq = x * x
    out = np.zeros_like(x)
    for ci in range(c):
        lo, hi = max(ci - half, 0), min(ci + half + 1, c)
        out[:, ci, :] = k + alpha / local_size * sq[:, lo:hi, :].sum(axis=1)
    return out.astype(np.float32)


def lrn_output(x, scale, beta):
    return (x * np.power(scale, -beta)).astype(np.float32)


def lrn_diff(x, top, scale, td, local_size, alpha, beta):
    n, c, d = x.shape
    half = (local_size - 1) // 2
    ratio = td * top / scale
    acc = np.zeros_like(x)
    for ci in range(c):
        lo, hi = max(ci - half, 0), min(ci + half + 1, c)
        acc[:, ci, :] = ratio[:, lo:hi, :].sum(axis=1)
    cache = 2.0 * alpha * beta / local_size
    return (td * np.power(scale, -beta) - cache * x * acc).astype(np.float32)


def softmax(x):
    m = x.max(axis=1, keepdims=True)
    e = np.exp(x - m)
    return (e / e.sum(axis=1, keepdims=True)).astype(np.float32)


def softmax_loss(prob, labels):
    n = prob.shape[0]
    p = prob[np.arange(n), labels.astype(int)]
    return np.float32(-np.log(np.maximum(p, np.finfo(np.float32).tiny)).mean())


def softmax_loss_backward(prob, labels, weight):
    n, c = prob.shape
    onehot = np.zeros_like(prob)
    onehot[np.arange(n), labels.astype(int)] = 1.0
    return ((prob - onehot) * (weight / n)).astype(np.float32)


def adam(diff, m, v, data, lr, b1, b2, delta, t):
    m2 = b1 * m + (1 - b1) * diff
    v2 = b2 * v + (1 - b2) * diff * diff
    corr = np.sqrt(1 - b2 ** t) / (1 - b1 ** t)
    data2 = data - lr * corr * m2 / (np.sqrt(v2) + delta)
    return m2.astype(np.float32), v2.astype(np.float32), data2.astype(np.float32)
