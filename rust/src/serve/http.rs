//! HTTP/1.1 front-end for the serving engine — the network boundary
//! that lets the load generator (and real clients) live outside the
//! process.
//!
//! Std-only by design, like the rest of the crate: a `TcpListener`
//! accept loop, one thread per connection with keep-alive, a small
//! hand-rolled HTTP/1.1 parser (no hyper offline), and request/response
//! bodies through [`crate::util::json`]. The REST surface maps onto a
//! [`ModelRouter`]:
//!
//! ```text
//! POST /v1/models/<name>:predict   {"instances": [[f32; sample_len], ...]}
//!   Optional latency budget: "deadline_ms": N in the body, or an
//!   `x-deadline-ms: N` request header (the body field wins when both
//!   are present). Requests still queued when it expires are shed.
//!   200 {"model": "...", "predictions": [[f32; output_len], ...]}
//!   400 bad JSON / wrong sample length     (ServeError::BadRequest)
//!   404 unknown model, action or path
//!   413 body over HttpConfig::max_body
//!   429 admission queue full — back off    (ServeError::Overloaded)
//!   500 worker-side failure                (ServeError::Worker)
//!   503 engine shutting down               (ServeError::ShuttingDown)
//!   503 + retry-after: <s>  circuit breaker open — the model failed
//!       too many consecutive batches       (ServeError::BreakerOpen)
//!   504 deadline expired before execution  (ServeError::DeadlineExceeded)
//! GET  /v1/models       model inventory (sample_len/output_len each)
//! GET  /metrics         per-model serve::Metrics as JSON;
//!                       `?format=prometheus` switches to Prometheus
//!                       text exposition (text/plain; version=0.0.4)
//! GET  /healthz         200 JSON: status "ok" (full strength, breakers
//!                       closed) / "degraded" (a model below its
//!                       configured worker count or with a non-closed
//!                       breaker) / "unhealthy" (a model has zero
//!                       healthy workers); uptime_s, per-model
//!                       weights_version / worker counts / breaker
//!                       state / restarts / queue depth
//! GET  /admin/trace     chrome-trace JSON of the sampled-batch ring
//!                       (`--trace-sample`); `?clear=1` also empties
//!                       the ring after the dump
//! POST /admin/models/<name>:publish   {"path": "w.fewts", ...}
//!   200 {"model","version","tag"?}  weight hot-swap: load a FEWSNAP1
//!       snapshot file and atomically publish it into the model's
//!       engine; workers adopt at their next batch boundary
//!   400 unreadable/mismatched snapshot  404 unknown model
//!   409 stale version (versions are strictly monotonic)
//! POST /admin/shutdown  200, then graceful drain — the SIGTERM
//!                       equivalent (std has no signal handling)
//! ```
//!
//! The module also carries the client half ([`HttpClient`],
//! [`http_load_test`]): a blocking keep-alive HTTP client that the
//! `serve --target` load generator, the throughput bench and the CI
//! smoke test reuse, so the whole stack is exercised over real sockets.

use super::engine::{PublishError, ServeError};
use super::router::{ModelRouter, RouteError};
use super::{lock_unpoisoned, LoadReport};
use crate::net::WeightSnapshot;
use crate::util::json::Json;
use crate::util::prng::Pcg32;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Largest accepted request body, bytes (413 beyond it).
    pub max_body: usize,
    /// Per-connection read timeout; idle keep-alive connections are
    /// dropped after it.
    pub read_timeout: Duration,
    /// Concurrent-connection cap; excess connections get an immediate
    /// 503 and are closed (admission control at the socket layer,
    /// mirroring the engine's bounded queue one layer down).
    pub max_connections: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            max_body: 32 << 20,
            read_timeout: Duration::from_secs(30),
            max_connections: 256,
        }
    }
}

/// State shared between the accept loop and every connection thread.
struct ServerState {
    router: Arc<ModelRouter>,
    cfg: HttpConfig,
    /// Bind time — `/healthz` reports uptime relative to it.
    started: Instant,
    /// Set once teardown starts: accept and keep-alive loops exit.
    stop: AtomicBool,
    /// Open connections (capacity admission at the socket layer).
    active: AtomicUsize,
    /// Requests currently being routed/executed — what the graceful
    /// drain actually waits for. Idle keep-alive connections (threads
    /// parked in `read`) don't count, so they can't stall shutdown.
    busy: AtomicUsize,
    shutdown_requested: Mutex<bool>,
    shutdown_cv: Condvar,
}

impl ServerState {
    fn request_shutdown(&self) {
        let mut g = lock_unpoisoned(&self.shutdown_requested);
        *g = true;
        self.shutdown_cv.notify_all();
    }
}

/// The serving engine's TCP front door. Bind, then either block on
/// [`wait_shutdown`](HttpServer::wait_shutdown) (server processes) or
/// keep driving the router in-process (tests, benches); `shutdown`
/// drains connections before stopping the engines.
pub struct HttpServer {
    state: Arc<ServerState>,
    addr: SocketAddr,
    accept: Mutex<Option<JoinHandle<()>>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` — port 0 picks a free one;
    /// read it back from [`local_addr`](HttpServer::local_addr)) and
    /// start serving `router`.
    pub fn bind(
        addr: &str,
        router: Arc<ModelRouter>,
        cfg: HttpConfig,
    ) -> anyhow::Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| anyhow::anyhow!("http: bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let state = Arc::new(ServerState {
            router,
            cfg,
            started: Instant::now(),
            stop: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            busy: AtomicUsize::new(0),
            shutdown_requested: Mutex::new(false),
            shutdown_cv: Condvar::new(),
        });
        let st = state.clone();
        let accept = std::thread::Builder::new()
            .name("serve-http-accept".to_string())
            .spawn(move || accept_loop(listener, st))
            .map_err(|e| anyhow::anyhow!("http: spawn accept loop: {e}"))?;
        Ok(HttpServer { state, addr: local, accept: Mutex::new(Some(accept)) })
    }

    /// The actually-bound address (resolves a `:0` port request).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a client POSTed `/admin/shutdown` (or `shutdown` ran).
    pub fn shutdown_requested(&self) -> bool {
        *lock_unpoisoned(&self.state.shutdown_requested)
    }

    /// Block until shutdown is requested — the server process's main
    /// loop (`serve --http` parks here).
    pub fn wait_shutdown(&self) {
        let mut g = lock_unpoisoned(&self.state.shutdown_requested);
        while !*g {
            g = self
                .state
                .shutdown_cv
                .wait(g)
                .unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Graceful drain: stop accepting, let in-flight connections finish
    /// their current request (bounded wait), then shut the router's
    /// engines down. Idempotent; also runs on `Drop`.
    pub fn shutdown(&self) {
        let accept = lock_unpoisoned(&self.accept).take();
        let Some(accept) = accept else { return };
        self.state.request_shutdown();
        self.state.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection; the
        // loop re-checks `stop` per accepted stream. A wildcard bind
        // (0.0.0.0 / ::) isn't connectable as-is, so aim at loopback.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            let lo = match wake.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            };
            wake.set_ip(lo);
        }
        let woke = TcpStream::connect_timeout(&wake, Duration::from_millis(500)).is_ok();
        if woke {
            let _ = accept.join();
        }
        // If the wake connect failed (backlog full under the very
        // overload that prompted the shutdown), don't block forever on
        // the join — the accept thread exits on the next incoming
        // connection; teardown proceeds without it.

        // Wait (bounded) for requests that are mid-route — NOT for idle
        // keep-alive connections, whose threads are parked in read()
        // and exit on their own — then stop the engines so in-flight
        // predicts have completed by the time the listener is gone.
        let t0 = Instant::now();
        while self.state.busy.load(Ordering::SeqCst) > 0
            && t0.elapsed() < Duration::from_secs(10)
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.state.router.shutdown();
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Decrements the active-connection count however a handler exits.
struct ConnGuard(Arc<ServerState>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::SeqCst);
    }
}

// Thread entry point: the accept thread owns the listener and server
// state for its whole lifetime ('static); the body only borrows them.
#[allow(clippy::needless_pass_by_value)]
fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    for stream in listener.incoming() {
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        if state.active.load(Ordering::SeqCst) >= state.cfg.max_connections {
            refuse_at_capacity(stream);
            continue;
        }
        state.active.fetch_add(1, Ordering::SeqCst);
        let st = state.clone();
        let spawned = std::thread::Builder::new()
            .name("serve-http-conn".to_string())
            .spawn(move || {
                let guard = ConnGuard(st);
                handle_connection(stream, &guard.0);
            });
        if spawned.is_err() {
            state.active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Best-effort bounded drain of unread request bytes before dropping a
/// socket: closing with data still queued in the kernel receive buffer
/// sends a TCP RST, which discards the error response we just wrote.
/// Hard-capped in bytes and wall time so a trickling client can't pin
/// the caller.
fn drain_briefly(stream: &mut TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut sink = [0u8; 4096];
    let t0 = Instant::now();
    let mut total = 0usize;
    while total < 256 * 1024 && t0.elapsed() < Duration::from_millis(300) {
        match stream.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(n) => total += n,
        }
    }
}

/// Write an error response, half-close, and drain briefly so the
/// response survives the close (see `drain_briefly`).
fn reply_and_close(stream: &mut TcpStream, status: u16, reason: &'static str, body: &[u8]) {
    let _ = write_response(stream, status, reason, "text/plain", body, &[], false);
    let _ = stream.shutdown(std::net::Shutdown::Write);
    drain_briefly(stream);
}

/// Turn away a connection over the cap with a real 503 — on a
/// throwaway thread, so a slow client can never stall the accept loop
/// during the very overload this path exists for.
fn refuse_at_capacity(stream: TcpStream) {
    let spawned = std::thread::Builder::new()
        .name("serve-http-refuse".to_string())
        .spawn(move || {
            let mut stream = stream;
            reply_and_close(
                &mut stream,
                503,
                "Service Unavailable",
                b"server at connection capacity\n",
            );
        });
    // Out of threads: just drop the stream (RST beats blocking accepts).
    let _ = spawned;
}

fn handle_connection(stream: TcpStream, state: &Arc<ServerState>) {
    let _ = stream.set_read_timeout(Some(state.cfg.read_timeout));
    let _ = stream.set_nodelay(true);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        if state.stop.load(Ordering::SeqCst) {
            return;
        }
        let req = match read_request(&mut reader, state.cfg.max_body) {
            Ok(Some(r)) => r,
            // Clean EOF between requests: client hung up.
            Ok(None) => return,
            Err(HttpReadError::TooLarge) => {
                // The body was never read, so the connection can't be
                // reused — reply, half-close, drain, close (the drain
                // keeps the 413 from being destroyed by a RST).
                reply_and_close(&mut writer, 413, "Payload Too Large", b"request body too large\n");
                return;
            }
            Err(HttpReadError::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Idle keep-alive connection timed out: drop silently.
                return;
            }
            Err(HttpReadError::Io(_)) => return,
            Err(HttpReadError::Malformed(m)) => {
                reply_and_close(
                    &mut writer,
                    400,
                    "Bad Request",
                    format!("malformed HTTP request: {m}\n").as_bytes(),
                );
                return;
            }
        };
        let keep_alive = req.keep_alive && !state.stop.load(Ordering::SeqCst);
        // Mark the request in-flight while it routes and replies, so
        // the graceful drain waits for it (and only it).
        state.busy.fetch_add(1, Ordering::SeqCst);
        let reply = route(state, &req);
        let wrote = write_response(
            &mut writer,
            reply.status,
            reply.reason,
            reply.ctype,
            &reply.body,
            &reply.extra,
            keep_alive,
        );
        state.busy.fetch_sub(1, Ordering::SeqCst);
        if wrote.is_err() || !keep_alive {
            return;
        }
    }
}

// ----------------------------------------------------------- parsing

struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
    /// Per-request latency budget from an `x-deadline-ms` header
    /// (overridden by a `deadline_ms` body field on predict).
    deadline_ms: Option<u64>,
}

#[derive(Debug)]
enum HttpReadError {
    TooLarge,
    Io(std::io::Error),
    Malformed(String),
}

/// Read one CRLF- (or LF-) terminated line; `Ok(None)` on EOF before
/// any byte. `budget`, if set, bounds the wall time from the line's
/// *first byte* to its newline — the socket read timeout alone can't
/// stop a slow-loris client that trickles one byte per timeout window,
/// while waiting for the first byte (idle keep-alive) stays governed by
/// the socket timeout only.
fn read_line(
    r: &mut impl BufRead,
    limit: usize,
    budget: Option<Duration>,
) -> Result<Option<String>, HttpReadError> {
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    let mut started: Option<Instant> = None;
    loop {
        match r.read(&mut byte) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(HttpReadError::Malformed("truncated line".to_string()));
            }
            Ok(_) => {
                let t0 = *started.get_or_insert_with(Instant::now);
                if let Some(b) = budget {
                    if t0.elapsed() > b {
                        return Err(HttpReadError::Malformed(
                            "header line read timed out".to_string(),
                        ));
                    }
                }
                if byte[0] == b'\n' {
                    break;
                }
                buf.push(byte[0]);
                if buf.len() > limit {
                    return Err(HttpReadError::Malformed("header line too long".to_string()));
                }
            }
            Err(e) => return Err(HttpReadError::Io(e)),
        }
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf)
        .map(Some)
        .map_err(|_| HttpReadError::Malformed("non-utf8 header bytes".to_string()))
}

/// Parse one request (request line, headers, `Content-Length` body).
/// `Ok(None)` = clean EOF before a request started (keep-alive close).
/// Per-line trickle budget and header-count cap: together with the
/// 8 KB line limit they bound a request's header phase in bytes *and*
/// wall time, so a slow-loris client can't hold a connection slot
/// indefinitely.
const LINE_BUDGET: Duration = Duration::from_secs(10);
const MAX_HEADER_LINES: usize = 100;

fn read_request(
    r: &mut BufReader<TcpStream>,
    max_body: usize,
) -> Result<Option<HttpRequest>, HttpReadError> {
    let line = match read_line(r, 8192, Some(LINE_BUDGET))? {
        Some(l) => l,
        None => return Ok(None),
    };
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(HttpReadError::Malformed(format!("bad request line '{line}'")));
    }
    let mut content_length = 0usize;
    // HTTP/1.1 defaults to keep-alive, HTTP/1.0 to close.
    let mut keep_alive = version == "HTTP/1.1";
    let mut deadline_ms = None;
    let mut header_lines = 0usize;
    loop {
        header_lines += 1;
        if header_lines > MAX_HEADER_LINES {
            return Err(HttpReadError::Malformed("too many header lines".to_string()));
        }
        let line = read_line(r, 8192, Some(LINE_BUDGET))?
            .ok_or_else(|| HttpReadError::Malformed("eof inside headers".to_string()))?;
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpReadError::Malformed(format!("bad header '{line}'")));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                content_length = value
                    .parse()
                    .map_err(|_| HttpReadError::Malformed("bad content-length".to_string()))?;
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "x-deadline-ms" => {
                // Strict parse: a garbled deadline must surface as 400,
                // not silently serve with no latency budget.
                deadline_ms = Some(value.parse::<u64>().map_err(|_| {
                    HttpReadError::Malformed(
                        "bad x-deadline-ms (want whole milliseconds)".to_string(),
                    )
                })?);
            }
            "transfer-encoding" => {
                // Chunked bodies are out of scope for this minimal
                // parser; every client we ship sends Content-Length.
                return Err(HttpReadError::Malformed(
                    "transfer-encoding not supported (send content-length)".to_string(),
                ));
            }
            _ => {}
        }
    }
    if content_length > max_body {
        return Err(HttpReadError::TooLarge);
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).map_err(HttpReadError::Io)?;
    Ok(Some(HttpRequest { method, path, body, keep_alive, deadline_ms }))
}

fn write_response(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
    extra_headers: &[(&'static str, String)],
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: {}\r\n",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in extra_headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

// ----------------------------------------------------------- routing

/// One routed response: status line, body, and any extra response
/// headers (today only `retry-after` on a breaker-rejected 503).
struct Reply {
    status: u16,
    reason: &'static str,
    ctype: &'static str,
    body: Vec<u8>,
    extra: Vec<(&'static str, String)>,
}

fn reply(status: u16, reason: &'static str, ctype: &'static str, body: Vec<u8>) -> Reply {
    Reply { status, reason, ctype, body, extra: Vec::new() }
}

fn ok_text(s: &str) -> Reply {
    reply(200, "OK", "text/plain", s.as_bytes().to_vec())
}

fn ok_json(j: &Json) -> Reply {
    reply(200, "OK", "application/json", j.to_pretty().into_bytes())
}

fn error_reply(status: u16, reason: &'static str, msg: &str) -> Reply {
    let mut o = Json::obj();
    o.set("error", Json::str(msg));
    reply(status, reason, "application/json", o.to_pretty().into_bytes())
}

/// The HTTP status contract for serving errors (documented in the
/// README's "Serving over HTTP" section; the integration tests pin it).
pub fn status_for(e: &RouteError) -> (u16, &'static str) {
    match e {
        RouteError::UnknownModel(_) => (404, "Not Found"),
        RouteError::Serve(ServeError::BadRequest(_)) => (400, "Bad Request"),
        RouteError::Serve(ServeError::Overloaded(_)) | RouteError::Serve(ServeError::Rejected) => {
            (429, "Too Many Requests")
        }
        RouteError::Serve(ServeError::ShuttingDown) => (503, "Service Unavailable"),
        RouteError::Serve(ServeError::BreakerOpen { .. }) => (503, "Service Unavailable"),
        RouteError::Serve(ServeError::DeadlineExceeded) => (504, "Gateway Timeout"),
        RouteError::Serve(ServeError::Worker(_)) => (500, "Internal Server Error"),
        RouteError::Publish(PublishError::Mismatch(_)) => (400, "Bad Request"),
        RouteError::Publish(PublishError::Stale { .. }) => (409, "Conflict"),
    }
}

fn route_error_reply(e: &RouteError) -> Reply {
    let (status, reason) = status_for(e);
    let mut r = error_reply(status, reason, &e.to_string());
    if let RouteError::Serve(ServeError::BreakerOpen { retry_after_ms }) = e {
        // Retry-After is whole seconds; round up and floor at 1 so a
        // 250 ms cooldown never becomes "retry immediately".
        let secs = ((retry_after_ms + 999) / 1000).max(1);
        r.extra.push(("retry-after", secs.to_string()));
    }
    r
}

/// Value of `key` in a raw query string (`a=1&b=2`); `Some("")` for a
/// bare flag (`?clear`). No percent-decoding — every query parameter
/// the surface accepts is a plain token.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        (k == key).then_some(v)
    })
}

fn route(state: &Arc<ServerState>, req: &HttpRequest) -> Reply {
    let (path, query) = req.path.split_once('?').unwrap_or((req.path.as_str(), ""));
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            ok_json(&state.router.health_json(state.started.elapsed().as_secs_f64()))
        }
        ("GET", "/metrics") => {
            if query_param(query, "format") == Some("prometheus") {
                let text = state.router.metrics_prometheus();
                reply(200, "OK", "text/plain; version=0.0.4", text.into_bytes())
            } else {
                ok_json(&state.router.metrics_json())
            }
        }
        ("GET", "/admin/trace") => {
            let clear = matches!(query_param(query, "clear"), Some("1") | Some("true"));
            let text = state.router.traces_chrome_json(clear);
            reply(200, "OK", "application/json", text.into_bytes())
        }
        ("GET", "/v1/models") => ok_json(&state.router.models_json()),
        ("POST", "/admin/shutdown") => {
            state.request_shutdown();
            ok_text("shutting down\n")
        }
        (method, path) => {
            if let Some(rest) = path.strip_prefix("/v1/models/") {
                if let Some((model, action)) = rest.split_once(':') {
                    if action != "predict" {
                        return error_reply(
                            404,
                            "Not Found",
                            &format!("unknown action '{action}' (have: predict)"),
                        );
                    }
                    if method != "POST" {
                        return error_reply(405, "Method Not Allowed", "predict requires POST");
                    }
                    return predict(state, model, req);
                }
            }
            if let Some(rest) = path.strip_prefix("/admin/models/") {
                if let Some((model, action)) = rest.split_once(':') {
                    if action != "publish" {
                        return error_reply(
                            404,
                            "Not Found",
                            &format!("unknown admin action '{action}' (have: publish)"),
                        );
                    }
                    if method != "POST" {
                        return error_reply(405, "Method Not Allowed", "publish requires POST");
                    }
                    return publish(state, model, &req.body);
                }
            }
            error_reply(404, "Not Found", &format!("no route for {method} {path}"))
        }
    }
}

/// `{"instances": [[...], ...]}` → one sample vector per instance.
fn parse_instances(json: &Json) -> Result<Vec<Vec<f32>>, String> {
    let arr = json
        .get("instances")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| "expected {\"instances\": [[...], ...]}".to_string())?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, inst) in arr.iter().enumerate() {
        let row = inst
            .as_arr()
            .ok_or_else(|| format!("instance {i} is not an array of numbers"))?;
        let mut sample = Vec::with_capacity(row.len());
        for v in row {
            match v.as_f64() {
                Some(n) => sample.push(n as f32),
                None => return Err(format!("instance {i} contains a non-number")),
            }
        }
        out.push(sample);
    }
    Ok(out)
}

fn predict(state: &Arc<ServerState>, model: &str, req: &HttpRequest) -> Reply {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return error_reply(400, "Bad Request", "body is not UTF-8"),
    };
    let json = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => return error_reply(400, "Bad Request", &format!("bad JSON: {e}")),
    };
    // Latency budget: body field wins over the x-deadline-ms header.
    // Same validation shape as publish's "version": reject negatives
    // and fractions before the cast instead of saturating them away.
    let deadline_ms = match json.get("deadline_ms") {
        None => req.deadline_ms,
        Some(v) => match v.as_f64() {
            Some(n) if n >= 0.0 && n.fract() == 0.0 && n <= 9.0e15 => Some(n as u64),
            _ => {
                return error_reply(
                    400,
                    "Bad Request",
                    "\"deadline_ms\" must be a non-negative integer",
                )
            }
        },
    };
    let deadline = deadline_ms.map(Duration::from_millis);
    let instances = match parse_instances(&json) {
        Ok(v) => v,
        Err(e) => return error_reply(400, "Bad Request", &e),
    };
    if instances.is_empty() {
        return error_reply(400, "Bad Request", "no instances in request");
    }
    // Submit every instance (the engine's micro-batcher coalesces
    // them), then wait for all. The first error decides the status;
    // any already-submitted instances still execute — wasted work on a
    // mixed outcome, but no handle is ever left blocking.
    let mut handles = Vec::with_capacity(instances.len());
    for sample in instances {
        match state.router.submit_with_deadline(model, sample, deadline) {
            Ok(h) => handles.push(h),
            Err(e) => return route_error_reply(&e),
        }
    }
    let mut predictions = Vec::with_capacity(handles.len());
    let mut versions: Vec<u64> = Vec::with_capacity(handles.len());
    for h in handles {
        match h.wait() {
            Ok(resp) => {
                versions.push(resp.weights_version);
                predictions.push(Json::nums(&resp.values));
            }
            Err(e) => return route_error_reply(&RouteError::Serve(e)),
        }
    }
    let mut o = Json::obj();
    o.set("model", Json::str(model));
    o.set("predictions", Json::Arr(predictions));
    // Each row is computed from exactly one snapshot version.
    // `weights_version` (the newest across the rows) is always present
    // — it's part of the documented 200 contract — and when a publish
    // landed between this request's micro-batches, a per-row
    // `weights_versions` array is added alongside it.
    let newest = *versions.iter().max().expect("instances is non-empty");
    o.set("weights_version", Json::num(newest as f64));
    if versions.iter().any(|&v| v != newest) {
        o.set(
            "weights_versions",
            Json::arr(versions.iter().map(|&v| Json::num(v as f64))),
        );
    }
    ok_json(&o)
}

/// `POST /admin/models/<name>:publish` — weight hot-swap. Body:
/// `{"path": "<FEWSNAP1 file>", "version": N?, "tag": "..."?}`; the
/// optional fields override what the file carries (version 0 in the
/// file or body means "assign the next version"). The snapshot is
/// validated against the model's parameter schema before the swap, so a
/// bad file can never reach a worker.
fn publish(state: &Arc<ServerState>, model: &str, body: &[u8]) -> Reply {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return error_reply(400, "Bad Request", "body is not UTF-8"),
    };
    let json = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => return error_reply(400, "Bad Request", &format!("bad JSON: {e}")),
    };
    let Some(path) = json.get("path").and_then(|p| p.as_str()) else {
        return error_reply(
            400,
            "Bad Request",
            "expected {\"path\": \"<weight snapshot file>\"}",
        );
    };
    let mut snap = match WeightSnapshot::load(path) {
        Ok(s) => s,
        Err(e) => {
            return error_reply(
                400,
                "Bad Request",
                &format!("load snapshot '{path}': {e:#}"),
            )
        }
    };
    if let Some(v) = json.get("version") {
        // Validate before the `as u64` cast: a negative value would
        // silently saturate to 0 ("auto-assign"), masking a client bug
        // the 400 contract should surface. 9e15 keeps the value inside
        // f64's exact-integer range.
        let version = match v.as_f64() {
            Some(n) if n >= 0.0 && n.fract() == 0.0 && n <= 9.0e15 => n as u64,
            _ => {
                return error_reply(
                    400,
                    "Bad Request",
                    "\"version\" must be a non-negative integer",
                )
            }
        };
        snap = snap.with_version(version);
    }
    if let Some(t) = json.get("tag").and_then(|t| t.as_str()) {
        snap = snap.with_tag(t);
    }
    let tag = snap.tag().map(|t| t.to_string());
    match state.router.publish(model, snap) {
        Ok(version) => {
            let mut o = Json::obj();
            o.set("model", Json::str(model));
            o.set("version", Json::num(version as f64));
            if let Some(t) = tag {
                o.set("tag", Json::str(t));
            }
            ok_json(&o)
        }
        Err(e) => route_error_reply(&e),
    }
}

// ------------------------------------------------------------ client

/// Minimal blocking HTTP/1.1 client over one keep-alive connection —
/// just enough for the load generator, the throughput bench and the
/// integration tests (no reqwest offline).
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl HttpClient {
    pub fn connect(addr: &str) -> anyhow::Result<HttpClient> {
        let stream =
            TcpStream::connect(addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone()?;
        Ok(HttpClient { reader: BufReader::new(stream), writer })
    }

    /// One request/response round-trip on the persistent connection;
    /// returns (status, body).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> anyhow::Result<(u16, Vec<u8>)> {
        self.request_with(method, path, &[], body)
    }

    /// [`request`](HttpClient::request) with extra request headers
    /// (e.g. `("x-deadline-ms", "50")` for a per-request deadline).
    pub fn request_with(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> anyhow::Result<(u16, Vec<u8>)> {
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nhost: fecaffe\r\ncontent-length: {}\r\n",
            body.len()
        );
        for (name, value) in headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        self.writer.write_all(head.as_bytes())?;
        self.writer.write_all(body)?;
        self.writer.flush()?;
        let line = read_line(&mut self.reader, 8192, None)
            .map_err(|e| anyhow::anyhow!("read status line: {e:?}"))?
            .ok_or_else(|| anyhow::anyhow!("server closed the connection"))?;
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad status line '{line}'"))?;
        let mut content_length = 0usize;
        loop {
            let line = read_line(&mut self.reader, 8192, None)
                .map_err(|e| anyhow::anyhow!("read header: {e:?}"))?
                .ok_or_else(|| anyhow::anyhow!("eof inside response headers"))?;
            if line.is_empty() {
                break;
            }
            if let Some((name, value)) = line.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse().unwrap_or(0);
                }
            }
        }
        let mut body = vec![0u8; content_length];
        self.reader.read_exact(&mut body)?;
        Ok((status, body))
    }
}

/// One-shot convenience request on a fresh connection.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> anyhow::Result<(u16, Vec<u8>)> {
    HttpClient::connect(addr)?.request(method, path, body)
}

/// Serialize one predict body for `samples`.
pub fn predict_body(samples: &[Vec<f32>]) -> String {
    let mut o = Json::obj();
    o.set(
        "instances",
        Json::Arr(samples.iter().map(|s| Json::nums(s)).collect()),
    );
    o.to_string()
}

/// Closed-loop HTTP load test against a running server: `clients`
/// persistent connections each posting single-instance predict
/// requests and waiting for the response, retrying with a short
/// backoff on 429 (queue full) and on a breaker-open 503 (the body
/// names the circuit breaker — a plain shutting-down 503 is terminal).
/// 504s count as shed, not failed. The TCP twin of [`super::load_test`].
pub fn http_load_test(
    addr: &str,
    model: &str,
    sample_len: usize,
    clients: usize,
    total: usize,
    seed: u64,
) -> anyhow::Result<LoadReport> {
    let clients = clients.max(1);
    let path = format!("/v1/models/{model}:predict");
    let issued = AtomicUsize::new(0);
    let retries = AtomicU64::new(0);
    let breaker_retries = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let shed = AtomicU64::new(0);
    let t0 = Instant::now();
    let latencies_ns: Vec<f64> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(clients);
        for cid in 0..clients {
            let issued = &issued;
            let retries = &retries;
            let breaker_retries = &breaker_retries;
            let failed = &failed;
            let shed = &shed;
            let path = &path;
            handles.push(scope.spawn(move || {
                let mut rng = Pcg32::with_stream(seed, cid as u64 + 1);
                let mut lats = Vec::new();
                let mut client = match HttpClient::connect(addr) {
                    Ok(c) => c,
                    Err(_) => return lats,
                };
                'requests: loop {
                    if issued.fetch_add(1, Ordering::Relaxed) >= total {
                        break;
                    }
                    let mut sample = vec![0f32; sample_len];
                    rng.fill_uniform(&mut sample, 0.0, 1.0);
                    let body = predict_body(&[sample]);
                    loop {
                        let t = Instant::now();
                        match client.request("POST", path, body.as_bytes()) {
                            Ok((200, _)) => {
                                lats.push(t.elapsed().as_nanos() as f64);
                                break;
                            }
                            Ok((429, _)) => {
                                retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(Duration::from_micros(200));
                            }
                            Ok((504, _)) => {
                                // Deadline shed: the latency budget did
                                // its job — not a failure.
                                shed.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                            Ok((503, rbody))
                                if String::from_utf8_lossy(&rbody).contains("circuit") =>
                            {
                                // Breaker open: wait a beat and retry —
                                // a breaker that re-closes must not show
                                // up as client-visible failures.
                                breaker_retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(Duration::from_millis(5));
                            }
                            Ok((_, _)) => {
                                // 4xx/5xx other than backpressure:
                                // count and move to the next request.
                                failed.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                            Err(_) => {
                                // Connection died: one failure, then
                                // reconnect or give up on this client.
                                failed.fetch_add(1, Ordering::Relaxed);
                                match HttpClient::connect(addr) {
                                    Ok(c) => {
                                        client = c;
                                        break;
                                    }
                                    Err(_) => break 'requests,
                                }
                            }
                        }
                    }
                }
                lats
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("http_load_test client panicked"))
            .collect()
    });
    let wall = t0.elapsed();
    let requests = latencies_ns.len() as u64;
    Ok(LoadReport {
        requests,
        failed: failed.load(Ordering::Relaxed),
        shed_expired: shed.load(Ordering::Relaxed),
        backpressure_retries: retries.load(Ordering::Relaxed),
        breaker_retries: breaker_retries.load(Ordering::Relaxed),
        wall,
        rps: requests as f64 / wall.as_secs_f64().max(1e-9),
        latencies_ns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_contract_matches_the_readme_table() {
        assert_eq!(status_for(&RouteError::UnknownModel("x".into())).0, 404);
        assert_eq!(
            status_for(&RouteError::Serve(ServeError::BadRequest("len".into()))).0,
            400
        );
        assert_eq!(
            status_for(&RouteError::Serve(ServeError::Overloaded(vec![]))).0,
            429
        );
        assert_eq!(status_for(&RouteError::Serve(ServeError::Rejected)).0, 429);
        assert_eq!(
            status_for(&RouteError::Serve(ServeError::ShuttingDown)).0,
            503
        );
        assert_eq!(
            status_for(&RouteError::Serve(ServeError::BreakerOpen { retry_after_ms: 250 })).0,
            503
        );
        assert_eq!(
            status_for(&RouteError::Serve(ServeError::DeadlineExceeded)),
            (504, "Gateway Timeout")
        );
        assert_eq!(
            status_for(&RouteError::Serve(ServeError::Worker("boom".into()))).0,
            500
        );
        assert_eq!(
            status_for(&RouteError::Publish(PublishError::Mismatch("len".into()))).0,
            400
        );
        assert_eq!(
            status_for(&RouteError::Publish(PublishError::Stale {
                current: 4,
                offered: 3
            }))
            .0,
            409
        );
    }

    /// A breaker-rejected 503 must carry a whole-second `retry-after`
    /// hint, rounded up and floored at 1 — and name the circuit breaker
    /// in the body so clients can tell it from a shutdown 503.
    #[test]
    fn breaker_rejections_carry_a_retry_after_header() {
        let r = route_error_reply(&RouteError::Serve(ServeError::BreakerOpen {
            retry_after_ms: 250,
        }));
        assert_eq!(r.status, 503);
        assert_eq!(r.extra, vec![("retry-after", "1".to_string())]);
        assert!(String::from_utf8_lossy(&r.body).contains("circuit"));
        let r = route_error_reply(&RouteError::Serve(ServeError::BreakerOpen {
            retry_after_ms: 3500,
        }));
        assert_eq!(r.extra, vec![("retry-after", "4".to_string())]);
        // Non-breaker errors carry no extra headers.
        let r = route_error_reply(&RouteError::Serve(ServeError::ShuttingDown));
        assert!(r.extra.is_empty());
    }

    #[test]
    fn parse_instances_accepts_rows_and_rejects_garbage() {
        let j = Json::parse(r#"{"instances": [[1, 2.5], [3, 4]]}"#).unwrap();
        let v = parse_instances(&j).unwrap();
        assert_eq!(v, vec![vec![1.0, 2.5], vec![3.0, 4.0]]);
        assert!(parse_instances(&Json::parse(r#"{"inputs": []}"#).unwrap()).is_err());
        assert!(parse_instances(&Json::parse(r#"{"instances": [1, 2]}"#).unwrap()).is_err());
        assert!(
            parse_instances(&Json::parse(r#"{"instances": [["a"]]}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn query_param_parses_pairs_and_bare_flags() {
        let q = "format=prometheus&clear=1";
        assert_eq!(query_param(q, "format"), Some("prometheus"));
        assert_eq!(query_param(q, "clear"), Some("1"));
        assert_eq!(query_param("clear", "clear"), Some(""));
        assert_eq!(query_param("", "clear"), None);
        assert_eq!(query_param("clearx=1", "clear"), None);
    }

    #[test]
    fn predict_body_round_trips_through_parse_instances() {
        let body = predict_body(&[vec![0.25, 0.5], vec![1.0, -2.0]]);
        let j = Json::parse(&body).unwrap();
        let v = parse_instances(&j).unwrap();
        assert_eq!(v, vec![vec![0.25, 0.5], vec![1.0, -2.0]]);
    }
}
