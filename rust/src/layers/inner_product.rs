//! InnerProduct (fully-connected) layer. Forward is one `Gemm` over the
//! whole batch + `Bias`; backward is two `Gemm`s + a `Gemv` — the exact
//! BLAS lowering of `caffe::InnerProductLayer`, which is why FC-heavy
//! nets (AlexNet fc6-8, VGG) spend their time in the gemm/gemv kernels.

use super::{fill_blob, Layer, SharedBlob};
use crate::blob::Blob;
use crate::device::{Device, Kernel, KernelCall};
use crate::proto::{InnerProductParameter, LayerParameter, ParamSpec};
use crate::util::prng::Pcg32;

pub struct InnerProductLayer {
    name: String,
    p: InnerProductParameter,
    specs: Vec<ParamSpec>,
    weight: SharedBlob, // [num_output, K]
    bias: Option<SharedBlob>,
    m: usize, // batch
    k: usize, // flattened input dim
}

impl InnerProductLayer {
    pub fn new(param: &LayerParameter) -> anyhow::Result<InnerProductLayer> {
        let p = param
            .inner_product
            .clone()
            .ok_or_else(|| anyhow::anyhow!("layer {}: missing inner_product_param", param.name))?;
        Ok(InnerProductLayer {
            name: param.name.clone(),
            specs: param.params.clone(),
            p,
            weight: super::shared(Blob::new("w", &[0])),
            bias: None,
            m: 0,
            k: 0,
        })
    }

    fn seed(&self) -> u64 {
        self.name
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
                (h ^ u64::from(b)).wrapping_mul(0x1000_0000_01b3)
            })
    }
}

impl Layer for InnerProductLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "InnerProduct"
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let b = bottoms[0].borrow();
        let m = b.num();
        self.k = b.count() / m.max(1);
        drop(b);
        let n = self.p.num_output;
        let mut rng = Pcg32::new(self.seed());
        {
            let mut w = self.weight.borrow_mut();
            w.reshape(dev, &[n, self.k]);
            fill_blob(&mut w, dev, &self.p.weight_filler, self.k, &mut rng);
        }
        if self.p.bias_term {
            let bias = super::shared(Blob::new("b", &[n]));
            fill_blob(&mut bias.borrow_mut(), dev, &self.p.bias_filler, self.k, &mut rng);
            self.bias = Some(bias);
        }
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let b = bottoms[0].borrow();
        let m = b.num();
        let k = b.count() / m.max(1);
        drop(b);
        // The flattened per-sample dim is pinned by the weight matrix
        // allocated at setup; only the batch dim may move.
        anyhow::ensure!(
            k == self.k,
            "inner_product {}: flattened input dim {k} != weight K {}",
            self.name,
            self.k
        );
        self.m = m;
        tops[0]
            .borrow_mut()
            .reshape_grow_only(dev, &[m, self.p.num_output]);
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let n = self.p.num_output;
        let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
        let w_id = self.weight.borrow_mut().data.dev_data(dev);
        let t_id = tops[0].borrow_mut().data.dev_data_mut(dev);
        // top(M,N) = bottom(M,K) · weight(N,K)^T
        dev.launch(&KernelCall::new(
            Kernel::GemmNT { m: self.m, n, k: self.k, alpha: 1.0, beta: 0.0 },
            &[b_id, w_id],
            &[t_id],
        ))?;
        if let Some(bias) = &self.bias {
            let bias_id = bias.borrow_mut().data.dev_data(dev);
            dev.launch(&KernelCall::new(
                Kernel::BiasF { outer: self.m, channels: n, dim: 1 },
                &[bias_id],
                &[t_id],
            ))?;
        }
        Ok(0.0)
    }

    fn backward(
        &mut self,
        dev: &mut dyn Device,
        tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let n = self.p.num_output;
        let td_id = tops[0].borrow_mut().diff.dev_data(dev);
        let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
        // weight_diff(N,K) += top_diff(M,N)^T · bottom(M,K)
        let wd_id = self.weight.borrow_mut().diff.dev_data_rw(dev);
        dev.launch(&KernelCall::new(
            Kernel::GemmTN { m: n, n: self.k, k: self.m, alpha: 1.0, beta: 1.0 },
            &[td_id, b_id],
            &[wd_id],
        ))?;
        if let Some(bias) = &self.bias {
            // bias_diff(N) += top_diff(M,N)^T · ones(M)
            let bd_id = bias.borrow_mut().diff.dev_data_rw(dev);
            let ones = dev.alloc(self.m)?;
            dev.launch(&KernelCall::new(
                Kernel::SetConst { n: self.m, value: 1.0 },
                &[],
                &[ones],
            ))?;
            dev.launch(&KernelCall::new(
                Kernel::Gemv { trans: true, m: self.m, n, alpha: 1.0, beta: 1.0 },
                &[td_id, ones],
                &[bd_id],
            ))?;
            dev.free(ones);
        }
        if prop_down.first().copied().unwrap_or(true) {
            // bottom_diff(M,K) = top_diff(M,N) · weight(N,K)
            let w_id = self.weight.borrow_mut().data.dev_data(dev);
            let bd_id = bottoms[0].borrow_mut().diff.dev_data_mut(dev);
            dev.launch(&KernelCall::new(
                Kernel::GemmNN { m: self.m, n: self.k, k: n, alpha: 1.0, beta: 0.0 },
                &[td_id, w_id],
                &[bd_id],
            ))?;
        }
        Ok(())
    }

    fn param_blobs(&self) -> Vec<SharedBlob> {
        let mut v = vec![self.weight.clone()];
        if let Some(b) = &self.bias {
            v.push(b.clone());
        }
        v
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        self.specs.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::proto::parse_text;

    fn ip_layer(n: usize, filler: &str) -> InnerProductLayer {
        let text = format!(
            r#"layer {{ name: "fc" type: "InnerProduct" bottom: "x" top: "y"
                 inner_product_param {{ num_output: {n}
                   weight_filler {{ type: "{filler}" value: 1 }} }} }}"#
        );
        let m = parse_text(&text).unwrap();
        let lp = LayerParameter::from_message(m.msgs("layer").next().unwrap()).unwrap();
        InnerProductLayer::new(&lp).unwrap()
    }

    #[test]
    fn forward_is_row_sums_with_ones_weight() {
        let mut dev = CpuDevice::new();
        let mut layer = ip_layer(2, "constant");
        let bottom = super::super::shared(Blob::new("x", &[2, 3]));
        let top = super::super::shared(Blob::new("y", &[1]));
        bottom
            .borrow_mut()
            .set_data(&mut dev, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        layer.forward(&mut dev, &[bottom], &[top.clone()]).unwrap();
        assert_eq!(
            top.borrow_mut().data_vec(&mut dev),
            vec![6.0, 6.0, 15.0, 15.0]
        );
    }

    #[test]
    fn backward_gradients_match_hand_computation() {
        let mut dev = CpuDevice::new();
        let mut layer = ip_layer(1, "constant");
        let bottom = super::super::shared(Blob::new("x", &[1, 2]));
        let top = super::super::shared(Blob::new("y", &[1]));
        bottom.borrow_mut().set_data(&mut dev, &[3.0, 4.0]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        layer
            .forward(&mut dev, &[bottom.clone()], &[top.clone()])
            .unwrap();
        top.borrow_mut().set_diff(&mut dev, &[2.0]);
        layer
            .backward(&mut dev, &[top], &[true], &[bottom.clone()])
            .unwrap();
        // dW = td^T · x = [6, 8]; db = 2; dx = td · W = [2, 2] (W = ones)
        assert_eq!(
            layer.weight.borrow_mut().diff_vec(&mut dev),
            vec![6.0, 8.0]
        );
        assert_eq!(
            layer.bias.as_ref().unwrap().borrow_mut().diff_vec(&mut dev),
            vec![2.0]
        );
        assert_eq!(bottom.borrow_mut().diff_vec(&mut dev), vec![2.0, 2.0]);
    }

    #[test]
    fn flattens_spatial_input() {
        let mut dev = CpuDevice::new();
        let mut layer = ip_layer(5, "xavier");
        let bottom = super::super::shared(Blob::new("x", &[2, 3, 4, 4]));
        let top = super::super::shared(Blob::new("y", &[1]));
        layer.setup(&mut dev, &[bottom], &[top.clone()]).unwrap();
        assert_eq!(layer.k, 48);
        assert_eq!(top.borrow().shape(), &[2, 5]);
    }
}
