"""L2 jnp op kernels vs the numpy oracle, over the manifest spec schema
(the same `build()` the AOT driver lowers)."""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.kernels import ref
from compile.model import build

RNG = np.random.default_rng(0xCAFE)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


def run(spec, *args):
    fn, shapes = build(spec)
    assert len(shapes) == len(args), (len(shapes), len(args))
    for s, a in zip(shapes, args):
        assert tuple(s.shape) == tuple(np.shape(a)), (spec["op"], s.shape, np.shape(a))
    return [np.asarray(o) for o in fn(*args)]


def test_im2col_matches_ref():
    for c, h, w, kh, kw, sh, sw, ph, pw in [
        (1, 28, 28, 5, 5, 1, 1, 0, 0),
        (3, 11, 13, 3, 3, 2, 2, 1, 1),
        (2, 7, 7, 3, 3, 1, 1, 2, 2),
    ]:
        im = rand(c, h, w)
        (out,) = run(
            dict(op="im2col", channels=c, height=h, width=w, kernel_h=kh,
                 kernel_w=kw, stride_h=sh, stride_w=sw, pad_h=ph, pad_w=pw),
            im,
        )
        np.testing.assert_allclose(out, ref.im2col(im, kh, kw, sh, sw, ph, pw))


def test_col2im_accumulates():
    c, h, w, kh, kw, sh, sw, ph, pw = 2, 6, 6, 3, 3, 1, 1, 1, 1
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (w + 2 * pw - kw) // sw + 1
    col = rand(c * kh * kw, oh * ow)
    im0 = rand(c, h, w)
    (out,) = run(
        dict(op="col2im", channels=c, height=h, width=w, kernel_h=kh,
             kernel_w=kw, stride_h=sh, stride_w=sw, pad_h=ph, pad_w=pw),
        col, im0,
    )
    expect = ref.col2im(col, c, h, w, kh, kw, sh, sw, ph, pw, im=im0)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "geom",
    [
        (2, 3, 8, 8, 2, 2, 2, 2, 0, 0),
        (1, 2, 7, 7, 3, 3, 2, 2, 0, 0),
        (1, 2, 6, 6, 3, 3, 1, 1, 1, 1),  # padded inception pool
    ],
)
def test_maxpool_fwd_bwd(geom):
    n, c, h, w, kh, kw, sh, sw, ph, pw = geom
    x = rand(n, c, h, w)
    spec = dict(op="maxpool_f", num=n, channels=c, height=h, width=w,
                kernel_h=kh, kernel_w=kw, stride_h=sh, stride_w=sw,
                pad_h=ph, pad_w=pw)
    top, mask = run(spec, x)
    rt, rm = ref.max_pool_forward(x, kh, kw, sh, sw, ph, pw)
    np.testing.assert_allclose(top, rt)
    np.testing.assert_array_equal(mask, rm)

    td = rand(*top.shape)
    spec["op"] = "maxpool_b"
    (bd,) = run(spec, td, mask)
    np.testing.assert_allclose(bd, ref.max_pool_backward(td, mask, h, w), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize(
    "geom",
    [
        (2, 2, 8, 8, 2, 2, 2, 2, 0, 0),
        (1, 3, 14, 14, 5, 5, 3, 3, 0, 0),  # googlenet aux head pool
        (1, 2, 7, 7, 7, 7, 1, 1, 0, 0),    # global
    ],
)
def test_avepool_fwd_bwd(geom):
    n, c, h, w, kh, kw, sh, sw, ph, pw = geom
    x = rand(n, c, h, w)
    spec = dict(op="avepool_f", num=n, channels=c, height=h, width=w,
                kernel_h=kh, kernel_w=kw, stride_h=sh, stride_w=sw,
                pad_h=ph, pad_w=pw)
    (top,) = run(spec, x)
    np.testing.assert_allclose(
        top, ref.ave_pool_forward(x, kh, kw, sh, sw, ph, pw), rtol=1e-5, atol=1e-6
    )
    td = rand(*top.shape)
    spec["op"] = "avepool_b"
    (bd,) = run(spec, td)
    np.testing.assert_allclose(
        bd, ref.ave_pool_backward(td, h, w, kh, kw, sh, sw, ph, pw), rtol=1e-5, atol=1e-6
    )


def test_lrn_chain():
    num, c, dim, ls = 2, 6, 5, 5
    alpha, beta, k = np.float32(1e-2), np.float32(0.75), np.float32(1.0)
    x = rand(num, c, dim)
    (scale,) = run(dict(op="lrn_scale", num=num, channels=c, dim=dim, local_size=ls),
                   alpha, k, x)
    np.testing.assert_allclose(scale, ref.lrn_scale(x, ls, alpha, k), rtol=1e-5)
    nflat = num * c * dim
    (top,) = run(dict(op="lrn_output", n=nflat), beta,
                 x.reshape(-1), scale.reshape(-1))
    np.testing.assert_allclose(
        top, ref.lrn_output(x, scale.reshape(x.shape), beta).reshape(-1), rtol=1e-5
    )
    td = rand(num, c, dim)
    (bd,) = run(dict(op="lrn_diff", num=num, channels=c, dim=dim, local_size=ls),
                alpha, beta, x, top.reshape(x.shape), scale.reshape(x.shape), td)
    np.testing.assert_allclose(
        bd, ref.lrn_diff(x, top.reshape(x.shape), scale.reshape(x.shape), td, ls, alpha, beta),
        rtol=1e-4, atol=1e-5,
    )


def test_softmax_family():
    n, c = 4, 7
    x = rand(n, c)
    (prob,) = run(dict(op="softmax", n=n, c=c), x)
    np.testing.assert_allclose(prob, ref.softmax(x), rtol=1e-5, atol=1e-6)
    labels = RNG.integers(0, c, n).astype(np.float32)
    (loss,) = run(dict(op="softmaxloss_f", n=n, c=c), prob, labels)
    np.testing.assert_allclose(loss[0], ref.softmax_loss(prob, labels), rtol=1e-5)
    (grad,) = run(dict(op="softmaxloss_b", n=n, c=c), np.float32(0.3), prob, labels)
    np.testing.assert_allclose(
        grad, ref.softmax_loss_backward(prob, labels, 0.3), rtol=1e-5, atol=1e-7
    )


def test_eltwise_ops():
    n = 64
    x, y = rand(n), rand(n)
    (out,) = run(dict(op="axpy", n=n), np.float32(2.5), x, y)
    np.testing.assert_allclose(out, 2.5 * x + y, rtol=1e-6)
    (out,) = run(dict(op="axpby", n=n), np.float32(2.0), np.float32(-0.5), x, y)
    np.testing.assert_allclose(out, 2.0 * x - 0.5 * y, rtol=1e-6)
    (out,) = run(dict(op="relu_f", n=n), np.float32(0.1), x)
    np.testing.assert_allclose(out, np.where(x > 0, x, 0.1 * x), rtol=1e-6)
    (out,) = run(dict(op="relu_b", n=n), np.float32(0.0), x, y)
    np.testing.assert_allclose(out, y * (x > 0), rtol=1e-6)
    (out,) = run(dict(op="asum", n=n), x)
    np.testing.assert_allclose(out[0], np.abs(x).sum(), rtol=1e-5)
    mask = (RNG.random(n) > 0.5).astype(np.float32)
    (out,) = run(dict(op="dropout", n=n), np.float32(2.0), x, mask)
    np.testing.assert_allclose(out, x * mask * 2.0, rtol=1e-6)


def test_bias_broadcast():
    outer, c, dim = 2, 3, 4
    b, top = rand(c), rand(outer, c, dim)
    (out,) = run(dict(op="bias", outer=outer, channels=c, dim=dim), b, top)
    np.testing.assert_allclose(out, top + b[None, :, None], rtol=1e-6)


def test_solver_updates_match_ref():
    n = 128
    diff, m, v, data = rand(n), rand(n) * 0.1, np.abs(rand(n)) * 0.1, rand(n)
    m2, v2, d2 = run(dict(op="adam", n=n), np.float32(0.01), np.float32(0.9),
                     np.float32(0.999), np.float32(1e-8), np.float32(3.0),
                     diff, m, v, data)
    rm, rv, rd = ref.adam(diff, m, v, data, 0.01, 0.9, 0.999, 1e-8, 3)
    np.testing.assert_allclose(m2, rm, rtol=1e-5)
    np.testing.assert_allclose(v2, rv, rtol=1e-5)
    np.testing.assert_allclose(d2, rd, rtol=1e-4, atol=1e-6)

    hist = np.abs(rand(n))
    h2, d2 = run(dict(op="sgd", n=n), np.float32(0.1), np.float32(0.9), diff, hist, data)
    np.testing.assert_allclose(h2, 0.9 * hist + 0.1 * diff, rtol=1e-6)
    np.testing.assert_allclose(d2, data - h2, rtol=1e-5, atol=1e-6)
