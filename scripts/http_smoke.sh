#!/usr/bin/env bash
# HTTP serving smoke test: start `serve --http` on an ephemeral port,
# hit healthz/predict/metrics through the binary's own load-generator
# path, hot-swap a weight snapshot mid-load (zero failed requests,
# weights_version must advance), then assert a clean drain on the
# SIGTERM-equivalent shutdown (POST /admin/shutdown). Finally, assert
# the netlint admission gate: a broken net must be *refused* at serve
# startup with an NL-coded diagnostic and a non-zero exit. CI runs this
# after a release build.
set -euo pipefail

SERVE="${SERVE:-target/release/serve}"
FECAFFE="${FECAFFE:-target/release/fecaffe}"
LOG="$(mktemp)"
SNAP="$(mktemp -u).fewts"
LOADJSON="$(mktemp)"
BROKEN="$(mktemp)"
AOTDIR="$(mktemp -d)"
AOTLOG="$(mktemp)"
QLOG="$(mktemp)"
trap 'kill $SERVER_PID $AOT_PID $QUANT_PID 2>/dev/null || true; rm -f "$LOG" "$SNAP" "$LOADJSON" "$BROKEN" "$AOTLOG" "$QLOG"; rm -rf "$AOTDIR"' EXIT
SERVER_PID=""
AOT_PID=""
QUANT_PID=""

[ -x "$SERVE" ] || { echo "serve binary not found at $SERVE (set SERVE=...)"; exit 1; }
[ -x "$FECAFFE" ] || { echo "fecaffe binary not found at $FECAFFE (set FECAFFE=...)"; exit 1; }

"$SERVE" --http 127.0.0.1:0 --models lenet --workers 2 --max-batch 8 \
    --trace-sample 1 >"$LOG" 2>&1 &
SERVER_PID=$!

# Wait for the listener line and extract the bound address.
ADDR=""
for _ in $(seq 1 100); do
    ADDR="$(sed -n 's|.*listening on http://||p' "$LOG" | head -n1)"
    [ -n "$ADDR" ] && break
    kill -0 "$SERVER_PID" 2>/dev/null || { echo "server died during startup:"; cat "$LOG"; exit 1; }
    sleep 0.2
done
[ -n "$ADDR" ] || { echo "server never reported its address:"; cat "$LOG"; exit 1; }
echo "server up at $ADDR"

fail() { echo "FAIL: $1"; cat "$LOG"; exit 1; }

# healthz
curl -sf "http://$ADDR/healthz" | grep -q ok || fail "healthz"

# predict + metrics through the external load-generator path.
"$SERVE" --target "$ADDR" --net lenet --requests 64 --clients 4 || fail "http load generator"
curl -sf "http://$ADDR/metrics" | grep -q '"completed"' || fail "metrics"

# --- Observability surface ------------------------------------------
# Prometheus text exposition: the core metric families must render.
PROM="$(curl -sf "http://$ADDR/metrics?format=prometheus")" || fail "prometheus metrics fetch"
for family in \
    'TYPE fecaffe_requests_completed_total counter' \
    'TYPE fecaffe_request_latency_seconds histogram' \
    'TYPE fecaffe_queue_depth gauge' \
    'fecaffe_requests_completed_total{model="lenet",precision="fp32"}' \
    'fecaffe_request_latency_seconds_bucket{model="lenet",precision="fp32",le="+Inf"}'; do
    echo "$PROM" | grep -qF "$family" || fail "prometheus family missing: $family"
done

# /admin/trace: valid chrome-trace JSON with at least one span (the
# server runs with --trace-sample 1, so the load above was sampled).
TRACE="$(curl -sf "http://$ADDR/admin/trace")" || fail "trace fetch"
echo "$TRACE" | python3 -c '
import json, sys
doc = json.load(sys.stdin)
events = doc["traceEvents"]
spans = [e for e in events if e.get("ph") == "X"]
assert spans, "no spans in /admin/trace"
assert any(e.get("name") == "queue-wait" for e in spans), "queue-wait span missing"
assert any(e.get("cat") == "layer" for e in spans), "layer lane missing"
' || fail "trace JSON invalid or missing expected spans"
echo "observability: OK (prometheus families + sampled trace)"

# Unknown model must 404, not crash the server.
CODE="$(curl -s -o /dev/null -w '%{http_code}' -X POST \
    -d '{"instances": [[0]]}' "http://$ADDR/v1/models/resnet:predict")"
[ "$CODE" = "404" ] || fail "expected 404 for unknown model, got $CODE"

# --- Weight hot-swap under load -------------------------------------
# Export a versioned snapshot file, publish it while the load generator
# is mid-run, and require (a) zero failed requests across the swap and
# (b) weights_version advancing to the published version in /metrics.
"$FECAFFE" weights --net lenet --version 7 --tag smoke --out "$SNAP" \
    || fail "fecaffe weights export"
curl -sf "http://$ADDR/metrics" | grep -q '"weights_version": 0' \
    || fail "expected weights_version 0 before publish"

# A long enough run that the publish provably lands mid-load (checked
# below: the generator must still be running after the publish returns).
"$SERVE" --target "$ADDR" --net lenet --requests 2048 --clients 4 \
    --json "$LOADJSON" >/dev/null 2>&1 &
LOAD_PID=$!
sleep 0.2
PUB="$(curl -s -X POST -d "{\"path\": \"$SNAP\"}" \
    "http://$ADDR/admin/models/lenet:publish")"
echo "$PUB" | grep -q '"version": 7' || fail "publish did not return version 7: $PUB"
kill -0 "$LOAD_PID" 2>/dev/null \
    || fail "load generator finished before the publish — swap window not exercised"

wait "$LOAD_PID" || fail "load generator failed across the hot-swap"
grep -q '"failed": 0' "$LOADJSON" \
    || { echo "load report:"; cat "$LOADJSON"; fail "requests failed during hot-swap"; }
curl -sf "http://$ADDR/metrics" | grep -q '"weights_version": 7' \
    || fail "weights_version did not advance to 7 in /metrics"
# A stale republish is refused with 409 (strict monotonicity).
CODE="$(curl -s -o /dev/null -w '%{http_code}' -X POST \
    -d "{\"path\": \"$SNAP\"}" "http://$ADDR/admin/models/lenet:publish")"
[ "$CODE" = "409" ] || fail "expected 409 for stale republish, got $CODE"
echo "hot-swap: OK (version 7 live, zero failed requests)"

# SIGTERM-equivalent shutdown: the server must drain and exit 0.
curl -sf -X POST "http://$ADDR/admin/shutdown" >/dev/null || fail "admin shutdown"
for _ in $(seq 1 100); do
    kill -0 "$SERVER_PID" 2>/dev/null || break
    sleep 0.2
done
if kill -0 "$SERVER_PID" 2>/dev/null; then
    fail "server did not exit after /admin/shutdown"
fi
wait "$SERVER_PID" || fail "server exited non-zero"
grep -q "drained clean" "$LOG" || fail "server did not report a clean drain"

# --- Admission lint gate ---------------------------------------------
# A structurally broken net (dangling bottom on the score path) must be
# refused at engine admission with an NL-coded netlint diagnostic and a
# non-zero exit — before any worker, replica, or DDR commitment.
cat >"$BROKEN" <<'EOF'
name: "broken"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { source: "digits" batch_size: 4 channels: 1 height: 8 width: 8 } }
layer { name: "fc" type: "InnerProduct" bottom: "missing" top: "fc"
        inner_product_param { num_output: 3 } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc" bottom: "label" top: "loss" }
EOF
set +e
REFUSE_OUT="$("$SERVE" --net "$BROKEN" --workers 1 --requests 1 --clients 1 2>&1)"
REFUSE_CODE=$?
set -e
if [ "$REFUSE_CODE" -eq 0 ]; then
    echo "$REFUSE_OUT"
    fail "broken net was admitted (serve exited 0)"
fi
echo "$REFUSE_OUT" | grep -q "NL0001" \
    || { echo "$REFUSE_OUT"; fail "refusal output lacks the NL0001 diagnostic"; }
echo "$REFUSE_OUT" | grep -q "rejected by netlint" \
    || { echo "$REFUSE_OUT"; fail "refusal output lacks the netlint rejection message"; }
echo "admission lint gate: OK (broken net refused with NL0001)"

# --- AOT cold-boot serving -------------------------------------------
# Materialize the lenet plan cache, verify it against the live zoo,
# then boot a fresh server *from the cache* (FECAFFE_AOT_CACHE). The
# server must report the cold boot, serve real load, and /metrics must
# show every serving bucket restored from cache: at --max-batch 8 the
# buckets are [1,2,4,8], so cache_hit == 4 and cache_miss == 0.
"$FECAFFE" aot build --cache-dir "$AOTDIR" --net lenet || fail "fecaffe aot build"
"$FECAFFE" aot verify --cache-dir "$AOTDIR" --net lenet || fail "fecaffe aot verify"

FECAFFE_AOT_CACHE="$AOTDIR" "$SERVE" --http 127.0.0.1:0 --models lenet \
    --workers 2 --max-batch 8 >"$AOTLOG" 2>&1 &
AOT_PID=$!

fail_aot() { echo "FAIL: $1"; cat "$AOTLOG"; exit 1; }

AOT_ADDR=""
for _ in $(seq 1 100); do
    AOT_ADDR="$(sed -n 's|.*listening on http://||p' "$AOTLOG" | head -n1)"
    [ -n "$AOT_ADDR" ] && break
    kill -0 "$AOT_PID" 2>/dev/null || fail_aot "aot server died during startup"
    sleep 0.2
done
[ -n "$AOT_ADDR" ] || fail_aot "aot server never reported its address"

grep -q "aot: cold boot" "$AOTLOG" \
    || fail_aot "server did not report an aot cold boot"
"$SERVE" --target "$AOT_ADDR" --net lenet --requests 64 --clients 4 \
    || fail_aot "http load against the cold-booted server"
AOT_METRICS="$(curl -sf "http://$AOT_ADDR/metrics")" || fail_aot "metrics fetch"
echo "$AOT_METRICS" | grep -q '"cache_hit": 4' \
    || { echo "$AOT_METRICS"; fail_aot "expected cache_hit 4 (buckets 1,2,4,8)"; }
echo "$AOT_METRICS" | grep -q '"cache_miss": 0' \
    || { echo "$AOT_METRICS"; fail_aot "expected cache_miss 0 on a warm cache"; }

curl -sf -X POST "http://$AOT_ADDR/admin/shutdown" >/dev/null || fail_aot "aot shutdown"
wait "$AOT_PID" || fail_aot "aot server exited non-zero"
echo "aot cold boot: OK (4 buckets from cache, cache_miss 0, load served)"

# --- Reduced-precision serving ---------------------------------------
# One process serving the fp32 and int8 variants side by side: boot
# --models lenet,lenet@int8 (the int8 engine fake-quantizes its weights
# and calibrates activation ranges at startup), predict against both
# names, and require the precision label to split the metric series.
"$SERVE" --http 127.0.0.1:0 --models lenet,lenet@int8 --workers 2 \
    --max-batch 8 >"$QLOG" 2>&1 &
QUANT_PID=$!

fail_quant() { echo "FAIL: $1"; cat "$QLOG"; exit 1; }

QADDR=""
for _ in $(seq 1 150); do
    QADDR="$(sed -n 's|.*listening on http://||p' "$QLOG" | head -n1)"
    [ -n "$QADDR" ] && break
    kill -0 "$QUANT_PID" 2>/dev/null || fail_quant "quant server died during startup"
    sleep 0.2
done
[ -n "$QADDR" ] || fail_quant "quant server never reported its address"
grep -q "quant: calibrated" "$QLOG" \
    || fail_quant "int8 engine did not report boot-time calibration"

SAMPLE="$(python3 -c 'print("[[" + ",".join(["0.5"]*784) + "]]")')"
for model in lenet lenet@int8; do
    CODE="$(curl -s -o /dev/null -w '%{http_code}' -X POST \
        -d "{\"instances\": $SAMPLE}" "http://$QADDR/v1/models/$model:predict")"
    [ "$CODE" = "200" ] || fail_quant "predict against $model returned $CODE"
done

QPROM="$(curl -sf "http://$QADDR/metrics?format=prometheus")" \
    || fail_quant "prometheus fetch"
for series in \
    'fecaffe_requests_completed_total{model="lenet",precision="fp32"} 1' \
    'fecaffe_requests_completed_total{model="lenet",precision="int8"} 1'; do
    echo "$QPROM" | grep -qF "$series" \
        || { echo "$QPROM" | grep fecaffe_requests_completed_total; \
             fail_quant "prometheus series missing: $series"; }
done

curl -sf -X POST "http://$QADDR/admin/shutdown" >/dev/null || fail_quant "shutdown"
wait "$QUANT_PID" || fail_quant "quant server exited non-zero"
echo "reduced precision: OK (lenet + lenet@int8 served, precision-labelled metrics)"

echo "http smoke: OK"
