//! Typed Caffe parameter messages extracted from the generic tree.
//!
//! Covers the subset of `caffe.proto` that LeNet, AlexNet, VGG-16,
//! SqueezeNet v1.0 and GoogLeNet v1 (train_val + deploy) plus the paper's
//! solver configurations actually use.

use super::ast::PMessage;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Train,
    Test,
}

impl Phase {
    pub fn from_ident(s: &str) -> Result<Phase, String> {
        match s {
            "TRAIN" => Ok(Phase::Train),
            "TEST" => Ok(Phase::Test),
            other => Err(format!("unknown phase '{other}'")),
        }
    }
    pub fn ident(&self) -> &'static str {
        match self {
            Phase::Train => "TRAIN",
            Phase::Test => "TEST",
        }
    }
}

/// Weight/bias filler (`weight_filler { type: "xavier" }`).
#[derive(Debug, Clone, PartialEq)]
pub struct FillerParameter {
    pub kind: String, // "constant" | "xavier" | "gaussian" | "uniform"
    pub value: f32,   // for constant
    pub std: f32,     // for gaussian
    pub mean: f32,
    pub min: f32,
    pub max: f32,
}

impl Default for FillerParameter {
    fn default() -> Self {
        FillerParameter {
            kind: "constant".into(),
            value: 0.0,
            std: 0.01,
            mean: 0.0,
            min: 0.0,
            max: 1.0,
        }
    }
}

impl FillerParameter {
    pub fn from_message(m: &PMessage) -> FillerParameter {
        let mut f = FillerParameter::default();
        if let Some(t) = m.get_str("type") {
            f.kind = t.to_string();
        }
        if let Some(v) = m.get_num("value") {
            f.value = v as f32;
        }
        if let Some(v) = m.get_num("std") {
            f.std = v as f32;
        }
        if let Some(v) = m.get_num("mean") {
            f.mean = v as f32;
        }
        if let Some(v) = m.get_num("min") {
            f.min = v as f32;
        }
        if let Some(v) = m.get_num("max") {
            f.max = v as f32;
        }
        f
    }
}

/// Per-learnable-param multipliers (`param { lr_mult: 1 decay_mult: 1 }`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParamSpec {
    pub lr_mult: f32,
    pub decay_mult: f32,
}

impl Default for ParamSpec {
    fn default() -> Self {
        ParamSpec { lr_mult: 1.0, decay_mult: 1.0 }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ConvolutionParameter {
    pub num_output: usize,
    pub kernel_h: usize,
    pub kernel_w: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    pub pad_h: usize,
    pub pad_w: usize,
    pub group: usize,
    pub bias_term: bool,
    pub weight_filler: FillerParameter,
    pub bias_filler: FillerParameter,
}

impl Default for ConvolutionParameter {
    fn default() -> Self {
        ConvolutionParameter {
            num_output: 0,
            kernel_h: 1,
            kernel_w: 1,
            stride_h: 1,
            stride_w: 1,
            pad_h: 0,
            pad_w: 0,
            group: 1,
            bias_term: true,
            weight_filler: FillerParameter::default(),
            bias_filler: FillerParameter::default(),
        }
    }
}

impl ConvolutionParameter {
    pub fn from_message(m: &PMessage) -> Result<ConvolutionParameter, String> {
        let mut p = ConvolutionParameter::default();
        p.num_output = m
            .get_u("num_output")
            .ok_or("convolution_param: missing num_output")?;
        if let Some(k) = m.get_u("kernel_size") {
            p.kernel_h = k;
            p.kernel_w = k;
        }
        if let Some(k) = m.get_u("kernel_h") {
            p.kernel_h = k;
        }
        if let Some(k) = m.get_u("kernel_w") {
            p.kernel_w = k;
        }
        if let Some(s) = m.get_u("stride") {
            p.stride_h = s;
            p.stride_w = s;
        }
        if let Some(s) = m.get_u("stride_h") {
            p.stride_h = s;
        }
        if let Some(s) = m.get_u("stride_w") {
            p.stride_w = s;
        }
        if let Some(v) = m.get_u("pad") {
            p.pad_h = v;
            p.pad_w = v;
        }
        if let Some(v) = m.get_u("pad_h") {
            p.pad_h = v;
        }
        if let Some(v) = m.get_u("pad_w") {
            p.pad_w = v;
        }
        if let Some(g) = m.get_u("group") {
            p.group = g;
        }
        if let Some(b) = m.get_bool("bias_term") {
            p.bias_term = b;
        }
        if let Some(f) = m.get_msg("weight_filler") {
            p.weight_filler = FillerParameter::from_message(f);
        }
        if let Some(f) = m.get_msg("bias_filler") {
            p.bias_filler = FillerParameter::from_message(f);
        }
        if p.kernel_h == 0 || p.kernel_w == 0 {
            return Err("convolution_param: kernel size is zero".into());
        }
        Ok(p)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolMethod {
    Max,
    Ave,
}

#[derive(Debug, Clone, PartialEq)]
pub struct PoolingParameter {
    pub method: PoolMethod,
    pub kernel_h: usize,
    pub kernel_w: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    pub pad_h: usize,
    pub pad_w: usize,
    pub global_pooling: bool,
}

impl Default for PoolingParameter {
    fn default() -> Self {
        PoolingParameter {
            method: PoolMethod::Max,
            kernel_h: 1,
            kernel_w: 1,
            stride_h: 1,
            stride_w: 1,
            pad_h: 0,
            pad_w: 0,
            global_pooling: false,
        }
    }
}

impl PoolingParameter {
    pub fn from_message(m: &PMessage) -> Result<PoolingParameter, String> {
        let mut p = PoolingParameter::default();
        match m.get_str("pool") {
            Some("MAX") | None => p.method = PoolMethod::Max,
            Some("AVE") => p.method = PoolMethod::Ave,
            Some(other) => return Err(format!("pooling_param: unsupported pool {other}")),
        }
        if let Some(k) = m.get_u("kernel_size") {
            p.kernel_h = k;
            p.kernel_w = k;
        }
        if let Some(s) = m.get_u("stride") {
            p.stride_h = s;
            p.stride_w = s;
        }
        if let Some(v) = m.get_u("pad") {
            p.pad_h = v;
            p.pad_w = v;
        }
        if let Some(b) = m.get_bool("global_pooling") {
            p.global_pooling = b;
        }
        Ok(p)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct InnerProductParameter {
    pub num_output: usize,
    pub bias_term: bool,
    pub weight_filler: FillerParameter,
    pub bias_filler: FillerParameter,
}

impl InnerProductParameter {
    pub fn from_message(m: &PMessage) -> Result<InnerProductParameter, String> {
        Ok(InnerProductParameter {
            num_output: m
                .get_u("num_output")
                .ok_or("inner_product_param: missing num_output")?,
            bias_term: m.get_bool("bias_term").unwrap_or(true),
            weight_filler: m
                .get_msg("weight_filler")
                .map(FillerParameter::from_message)
                .unwrap_or_default(),
            bias_filler: m
                .get_msg("bias_filler")
                .map(FillerParameter::from_message)
                .unwrap_or_default(),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct LrnParameter {
    pub local_size: usize,
    pub alpha: f32,
    pub beta: f32,
    pub k: f32,
}

impl Default for LrnParameter {
    fn default() -> Self {
        LrnParameter { local_size: 5, alpha: 1.0, beta: 0.75, k: 1.0 }
    }
}

impl LrnParameter {
    pub fn from_message(m: &PMessage) -> LrnParameter {
        let mut p = LrnParameter::default();
        if let Some(v) = m.get_u("local_size") {
            p.local_size = v;
        }
        if let Some(v) = m.get_num("alpha") {
            p.alpha = v as f32;
        }
        if let Some(v) = m.get_num("beta") {
            p.beta = v as f32;
        }
        if let Some(v) = m.get_num("k") {
            p.k = v as f32;
        }
        p
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct DropoutParameter {
    pub dropout_ratio: f32,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ConcatParameter {
    pub axis: usize,
}

/// Synthetic data layer parameters (stands in for Caffe's DataParameter;
/// see DESIGN.md substitution table — no LMDB/ImageNet offline).
#[derive(Debug, Clone, PartialEq)]
pub struct SyntheticDataParameter {
    pub batch_size: usize,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub num_classes: usize,
    /// "digits" (procedural MNIST-like) or "imagenet" (label-conditioned
    /// Gaussian blobs at ImageNet shapes).
    pub source: String,
    pub seed: u64,
}

impl SyntheticDataParameter {
    pub fn from_message(m: &PMessage) -> Result<SyntheticDataParameter, String> {
        Ok(SyntheticDataParameter {
            batch_size: m.get_u("batch_size").ok_or("data_param: missing batch_size")?,
            channels: m.get_u("channels").unwrap_or(3),
            height: m.get_u("height").unwrap_or(224),
            width: m.get_u("width").unwrap_or(224),
            num_classes: m.get_u("num_classes").unwrap_or(1000),
            source: m.get_str("source").unwrap_or("imagenet").to_string(),
            seed: m.get_num("seed").unwrap_or(1.0) as u64,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct AccuracyParameter {
    pub top_k: usize,
}

/// One layer definition.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerParameter {
    pub name: String,
    pub kind: String, // Caffe `type`: "Convolution", "ReLU", ...
    pub bottoms: Vec<String>,
    pub tops: Vec<String>,
    pub phase: Option<Phase>, // from include { phase: ... }
    pub params: Vec<ParamSpec>,
    pub loss_weight: Vec<f32>,
    pub conv: Option<ConvolutionParameter>,
    pub pool: Option<PoolingParameter>,
    pub inner_product: Option<InnerProductParameter>,
    pub lrn: Option<LrnParameter>,
    pub dropout: Option<DropoutParameter>,
    pub concat: Option<ConcatParameter>,
    pub data: Option<SyntheticDataParameter>,
    pub accuracy: Option<AccuracyParameter>,
}

impl LayerParameter {
    pub fn new(name: &str, kind: &str) -> LayerParameter {
        LayerParameter {
            name: name.to_string(),
            kind: kind.to_string(),
            bottoms: Vec::new(),
            tops: Vec::new(),
            phase: None,
            params: Vec::new(),
            loss_weight: Vec::new(),
            conv: None,
            pool: None,
            inner_product: None,
            lrn: None,
            dropout: None,
            concat: None,
            data: None,
            accuracy: None,
        }
    }

    pub fn from_message(m: &PMessage) -> Result<LayerParameter, String> {
        let name = m
            .get_str("name")
            .ok_or("layer: missing name")?
            .to_string();
        let kind = m
            .get_str("type")
            .ok_or_else(|| format!("layer {name}: missing type"))?
            .to_string();
        let mut l = LayerParameter::new(&name, &kind);
        l.bottoms = m.strs("bottom");
        l.tops = m.strs("top");
        for inc in m.msgs("include") {
            if let Some(ph) = inc.get_str("phase") {
                l.phase = Some(Phase::from_ident(ph)?);
            }
        }
        for pm in m.msgs("param") {
            l.params.push(ParamSpec {
                lr_mult: pm.get_num("lr_mult").unwrap_or(1.0) as f32,
                decay_mult: pm.get_num("decay_mult").unwrap_or(1.0) as f32,
            });
        }
        l.loss_weight = m.nums("loss_weight").iter().map(|&v| v as f32).collect();
        if let Some(cm) = m.get_msg("convolution_param") {
            l.conv = Some(ConvolutionParameter::from_message(cm)?);
        }
        if let Some(pm) = m.get_msg("pooling_param") {
            l.pool = Some(PoolingParameter::from_message(pm)?);
        }
        if let Some(im) = m.get_msg("inner_product_param") {
            l.inner_product = Some(InnerProductParameter::from_message(im)?);
        }
        if let Some(lm) = m.get_msg("lrn_param") {
            l.lrn = Some(LrnParameter::from_message(lm));
        }
        if let Some(dm) = m.get_msg("dropout_param") {
            l.dropout = Some(DropoutParameter {
                dropout_ratio: dm.get_num("dropout_ratio").unwrap_or(0.5) as f32,
            });
        }
        if let Some(cm) = m.get_msg("concat_param") {
            l.concat = Some(ConcatParameter { axis: cm.get_u("axis").unwrap_or(1) });
        }
        if let Some(dm) = m.get_msg("data_param") {
            l.data = Some(SyntheticDataParameter::from_message(dm)?);
        }
        if let Some(am) = m.get_msg("accuracy_param") {
            l.accuracy = Some(AccuracyParameter { top_k: am.get_u("top_k").unwrap_or(1) });
        }
        Ok(l)
    }
}

/// Whole-network definition.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NetParameter {
    pub name: String,
    pub layers: Vec<LayerParameter>,
    /// Deploy-style explicit inputs: (blob name, NCHW shape).
    pub inputs: Vec<(String, [usize; 4])>,
}

impl NetParameter {
    pub fn from_message(m: &PMessage) -> Result<NetParameter, String> {
        let mut net = NetParameter {
            name: m.get_str("name").unwrap_or("net").to_string(),
            ..Default::default()
        };
        // deploy format: input: "data" input_shape { dim: 1 dim: 3 ... }
        let input_names = m.strs("input");
        let shapes: Vec<[usize; 4]> = m
            .msgs("input_shape")
            .map(|sm| {
                let dims = sm.nums("dim");
                let mut s = [1usize; 4];
                for (i, d) in dims.iter().take(4).enumerate() {
                    s[i] = *d as usize;
                }
                s
            })
            .collect();
        for (i, n) in input_names.iter().enumerate() {
            let shape = shapes.get(i).copied().unwrap_or([1, 1, 1, 1]);
            net.inputs.push((n.clone(), shape));
        }
        for lm in m.msgs("layer") {
            net.layers.push(LayerParameter::from_message(lm)?);
        }
        Ok(net)
    }

    /// Layers visible in `phase` (layers without an include clause are in
    /// every phase).
    pub fn layers_for_phase(&self, phase: Phase) -> Vec<&LayerParameter> {
        self.layers
            .iter()
            .filter(|l| l.phase.map_or(true, |p| p == phase))
            .collect()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Sgd,
    Nesterov,
    AdaGrad,
    RmsProp,
    AdaDelta,
    Adam,
}

impl SolverKind {
    pub fn from_ident(s: &str) -> Result<SolverKind, String> {
        match s.to_ascii_uppercase().as_str() {
            "SGD" => Ok(SolverKind::Sgd),
            "NESTEROV" => Ok(SolverKind::Nesterov),
            "ADAGRAD" => Ok(SolverKind::AdaGrad),
            "RMSPROP" => Ok(SolverKind::RmsProp),
            "ADADELTA" => Ok(SolverKind::AdaDelta),
            "ADAM" => Ok(SolverKind::Adam),
            other => Err(format!("unknown solver type '{other}'")),
        }
    }
    pub fn ident(&self) -> &'static str {
        match self {
            SolverKind::Sgd => "SGD",
            SolverKind::Nesterov => "Nesterov",
            SolverKind::AdaGrad => "AdaGrad",
            SolverKind::RmsProp => "RMSProp",
            SolverKind::AdaDelta => "AdaDelta",
            SolverKind::Adam => "Adam",
        }
    }
}

/// Learning-rate policies the solver implements (caffe
/// `SGDSolver::GetLearningRate`). `SolverParameter::from_message`
/// rejects anything else at parse time, so an unknown policy in a
/// user-supplied prototxt is an `Err`, never a mid-training panic.
pub const LR_POLICIES: &[&str] =
    &["fixed", "step", "exp", "inv", "poly", "sigmoid", "multistep"];

/// Solver configuration (`lenet_solver.prototxt` style).
#[derive(Debug, Clone, PartialEq)]
pub struct SolverParameter {
    pub net: String, // path or zoo name
    pub kind: SolverKind,
    pub base_lr: f32,
    pub lr_policy: String, // one of [`LR_POLICIES`]
    pub gamma: f32,
    pub power: f32,
    pub stepsize: usize,
    /// `multistep` boundaries (caffe repeated `stepvalue`), ascending.
    pub stepvalue: Vec<usize>,
    pub momentum: f32,
    pub momentum2: f32, // adam beta2
    pub rms_decay: f32,
    pub delta: f32, // numerical stability for adagrad/adadelta/adam/rmsprop
    pub weight_decay: f32,
    pub regularization_type: String, // L2 | L1
    pub max_iter: usize,
    pub iter_size: usize,
    pub display: usize,
    pub snapshot: usize,
    pub snapshot_prefix: String,
    pub test_iter: usize,
    pub test_interval: usize,
    pub random_seed: u64,
    pub clip_gradients: f32, // <=0 disables
}

impl Default for SolverParameter {
    fn default() -> Self {
        SolverParameter {
            net: String::new(),
            kind: SolverKind::Sgd,
            base_lr: 0.01,
            lr_policy: "fixed".into(),
            gamma: 0.1,
            power: 0.75,
            stepsize: 100_000,
            stepvalue: Vec::new(),
            momentum: 0.9,
            momentum2: 0.999,
            rms_decay: 0.99,
            delta: 1e-8,
            weight_decay: 0.0,
            regularization_type: "L2".into(),
            max_iter: 100,
            iter_size: 1,
            display: 20,
            snapshot: 0,
            snapshot_prefix: "snapshots/net".into(),
            test_iter: 0,
            test_interval: 0,
            random_seed: 1,
            clip_gradients: -1.0,
        }
    }
}

impl SolverParameter {
    pub fn from_message(m: &PMessage) -> Result<SolverParameter, String> {
        let mut s = SolverParameter::default();
        if let Some(v) = m.get_str("net") {
            s.net = v.to_string();
        }
        if let Some(v) = m.get_str("type") {
            s.kind = SolverKind::from_ident(v)?;
        }
        if let Some(v) = m.get_str("solver_type") {
            s.kind = SolverKind::from_ident(v)?;
        }
        macro_rules! num {
            ($field:ident, $name:literal, $t:ty) => {
                if let Some(v) = m.get_num($name) {
                    s.$field = v as $t;
                }
            };
        }
        num!(base_lr, "base_lr", f32);
        num!(gamma, "gamma", f32);
        num!(power, "power", f32);
        num!(stepsize, "stepsize", usize);
        num!(momentum, "momentum", f32);
        num!(momentum2, "momentum2", f32);
        num!(rms_decay, "rms_decay", f32);
        num!(delta, "delta", f32);
        num!(weight_decay, "weight_decay", f32);
        num!(max_iter, "max_iter", usize);
        num!(iter_size, "iter_size", usize);
        num!(display, "display", usize);
        num!(snapshot, "snapshot", usize);
        num!(test_iter, "test_iter", usize);
        num!(test_interval, "test_interval", usize);
        num!(random_seed, "random_seed", u64);
        num!(clip_gradients, "clip_gradients", f32);
        if let Some(v) = m.get_str("lr_policy") {
            s.lr_policy = v.to_string();
        }
        if !LR_POLICIES.contains(&s.lr_policy.as_str()) {
            return Err(format!(
                "unknown lr_policy '{}' (have: {})",
                s.lr_policy,
                LR_POLICIES.join(", ")
            ));
        }
        s.stepvalue = m.nums("stepvalue").iter().map(|&v| v as usize).collect();
        if let Some(v) = m.get_str("regularization_type") {
            s.regularization_type = v.to_string();
        }
        if let Some(v) = m.get_str("snapshot_prefix") {
            s.snapshot_prefix = v.to_string();
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::super::parse_text;
    use super::*;

    const LENET_CONV: &str = r#"
layer {
  name: "conv1"
  type: "Convolution"
  bottom: "data"
  top: "conv1"
  param { lr_mult: 1 }
  param { lr_mult: 2 decay_mult: 0 }
  convolution_param {
    num_output: 20
    kernel_size: 5
    stride: 1
    weight_filler { type: "xavier" }
    bias_filler { type: "constant" }
  }
}
"#;

    #[test]
    fn parses_conv_layer() {
        let m = parse_text(LENET_CONV).unwrap();
        let l = LayerParameter::from_message(m.msgs("layer").next().unwrap()).unwrap();
        assert_eq!(l.name, "conv1");
        assert_eq!(l.kind, "Convolution");
        assert_eq!(l.bottoms, vec!["data"]);
        let c = l.conv.unwrap();
        assert_eq!(c.num_output, 20);
        assert_eq!((c.kernel_h, c.kernel_w), (5, 5));
        assert_eq!(c.weight_filler.kind, "xavier");
        assert_eq!(l.params.len(), 2);
        assert_eq!(l.params[1].lr_mult, 2.0);
        assert_eq!(l.params[1].decay_mult, 0.0);
    }

    #[test]
    fn parses_phase_include() {
        let text = r#"
layer { name: "d" type: "SyntheticData" top: "data" top: "label"
        include { phase: TRAIN }
        data_param { batch_size: 64 channels: 1 height: 28 width: 28 num_classes: 10 source: "digits" } }
"#;
        let m = parse_text(text).unwrap();
        let l = LayerParameter::from_message(m.msgs("layer").next().unwrap()).unwrap();
        assert_eq!(l.phase, Some(Phase::Train));
        let d = l.data.unwrap();
        assert_eq!(d.batch_size, 64);
        assert_eq!(d.source, "digits");
        assert_eq!(l.tops, vec!["data", "label"]);
    }

    #[test]
    fn parses_pooling_variants() {
        let text = r#"
layer { name: "p1" type: "Pooling" bottom: "c" top: "p"
        pooling_param { pool: MAX kernel_size: 3 stride: 2 } }
layer { name: "p2" type: "Pooling" bottom: "p" top: "q"
        pooling_param { pool: AVE kernel_size: 7 stride: 1 } }
layer { name: "p3" type: "Pooling" bottom: "q" top: "r"
        pooling_param { pool: AVE global_pooling: true } }
"#;
        let net = NetParameter::from_message(&parse_text(text).unwrap()).unwrap();
        assert_eq!(net.layers[0].pool.as_ref().unwrap().method, PoolMethod::Max);
        assert_eq!(net.layers[1].pool.as_ref().unwrap().method, PoolMethod::Ave);
        assert!(net.layers[2].pool.as_ref().unwrap().global_pooling);
    }

    #[test]
    fn parses_deploy_inputs() {
        let text = r#"
name: "Deploy"
input: "data"
input_shape { dim: 1 dim: 3 dim: 224 dim: 224 }
layer { name: "r" type: "ReLU" bottom: "data" top: "data" }
"#;
        let net = NetParameter::from_message(&parse_text(text).unwrap()).unwrap();
        assert_eq!(net.inputs, vec![("data".to_string(), [1, 3, 224, 224])]);
    }

    #[test]
    fn parses_solver() {
        let text = r#"
net: "lenet"
type: "Adam"
base_lr: 0.001
lr_policy: "step"
gamma: 0.5
stepsize: 200
momentum: 0.9
momentum2: 0.995
weight_decay: 0.0005
max_iter: 500
display: 50
snapshot: 250
snapshot_prefix: "snapshots/lenet"
random_seed: 7
"#;
        let s = parse_solver(text).unwrap();
        assert_eq!(s.kind, SolverKind::Adam);
        assert_eq!(s.base_lr, 0.001);
        assert_eq!(s.lr_policy, "step");
        assert_eq!(s.stepsize, 200);
        assert_eq!(s.momentum2, 0.995);
        assert_eq!(s.random_seed, 7);
    }

    #[test]
    fn parses_multistep_stepvalues() {
        let text = r#"
net: "alexnet"
base_lr: 0.01
lr_policy: "multistep"
gamma: 0.1
stepvalue: 1000
stepvalue: 2000
stepvalue: 6000
"#;
        let s = parse_solver(text).unwrap();
        assert_eq!(s.lr_policy, "multistep");
        assert_eq!(s.stepvalue, vec![1000, 2000, 6000]);
        // Other policies simply carry an empty list.
        let s = parse_solver("net: \"lenet\"\nlr_policy: \"fixed\"").unwrap();
        assert!(s.stepvalue.is_empty());
    }

    #[test]
    fn rejects_unknown_lr_policy_at_parse() {
        let err = parse_solver("net: \"lenet\"\nlr_policy: \"bogus\"").unwrap_err();
        assert!(err.contains("unknown lr_policy 'bogus'"), "{err}");
        assert!(err.contains("multistep"), "error should list valid policies: {err}");
    }

    use super::super::parse_solver;

    #[test]
    fn phase_filter() {
        let text = r#"
layer { name: "a" type: "ReLU" include { phase: TRAIN } }
layer { name: "b" type: "ReLU" include { phase: TEST } }
layer { name: "c" type: "ReLU" }
"#;
        let net = NetParameter::from_message(&parse_text(text).unwrap()).unwrap();
        let train: Vec<&str> = net
            .layers_for_phase(Phase::Train)
            .iter()
            .map(|l| l.name.as_str())
            .collect();
        assert_eq!(train, vec!["a", "c"]);
    }

    #[test]
    fn solver_kind_roundtrip() {
        for k in [
            SolverKind::Sgd,
            SolverKind::Nesterov,
            SolverKind::AdaGrad,
            SolverKind::RmsProp,
            SolverKind::AdaDelta,
            SolverKind::Adam,
        ] {
            assert_eq!(SolverKind::from_ident(k.ident()).unwrap(), k);
        }
    }
}
