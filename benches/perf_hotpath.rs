//! Perf bench — the L3 hot path (DESIGN.md §7 targets):
//!   * kernel-launch overhead on the simulator (bookkeeping only),
//!   * native gemm throughput (CPU fallback engine) at 1 and N intra-op
//!     threads → `BENCH_gemm.json` (machine-readable perf trajectory,
//!     like `BENCH_serve.json`),
//!   * PJRT dispatch overhead per artifact launch (marshal + execute),
//!   * end-to-end LeNet train-iteration rate.
//! Results feed EXPERIMENTS.md §Perf and the README "Performance"
//! section.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::{Device, Kernel, KernelCall};
use fecaffe::math::{self, Trans};
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::runtime::PjrtBackend;
use fecaffe::solver::Solver;
use fecaffe::util::json::Json;
use fecaffe::util::pool;
use fecaffe::util::stats::bench;
use fecaffe::zoo;

fn main() -> anyhow::Result<()> {
    // 1. Simulator launch bookkeeping (timing-only: pure L3 cost).
    {
        let mut dev = FpgaSimDevice::new();
        dev.timing_only = true;
        let x = dev.alloc(1024)?;
        let y = dev.alloc(1024)?;
        let call = KernelCall::new(Kernel::ReluF { n: 1024, slope: 0.0 }, &[x], &[y]);
        let s = bench("sim launch bookkeeping", 1000, 20_000, || {
            dev.launch(&call).unwrap();
        });
        println!("{}", s.line());
    }

    // 2. Native packed GEMM throughput at 1 thread and the full intra-op
    //    budget → BENCH_gemm.json. Shapes: the googlenet inception-3x3
    //    forward NN gemm (m=128, k=1152, n=784) and a LeNet conv2
    //    backward data-grad TN gemm (m=500, n=64, k=50).
    {
        struct Shape {
            label: &'static str,
            ta: Trans,
            tb: Trans,
            m: usize,
            n: usize,
            k: usize,
        }
        let shapes = [
            Shape {
                label: "googlenet_3x3_NN",
                ta: Trans::No,
                tb: Trans::No,
                m: 128,
                n: 784,
                k: 1152,
            },
            Shape {
                label: "lenet_conv2_bwd_TN",
                ta: Trans::Yes,
                tb: Trans::No,
                m: 500,
                n: 64,
                k: 50,
            },
        ];
        let max_threads = pool::default_threads();
        let mut results = Vec::new();
        for sh in &shapes {
            // Random data: zero buffers would trip the unpacked remainder
            // path's zero-skip and overstate throughput.
            let mut rng = fecaffe::util::prng::Pcg32::new(1);
            let mut va = vec![0f32; sh.m * sh.k];
            let mut vb = vec![0f32; sh.k * sh.n];
            rng.fill_uniform(&mut va, -1.0, 1.0);
            rng.fill_uniform(&mut vb, -1.0, 1.0);
            let mut vc = vec![0f32; sh.m * sh.n];
            let flops = 2.0 * (sh.m * sh.n * sh.k) as f64;
            let mut threads: Vec<usize> = vec![1];
            if max_threads > 1 {
                threads.push(max_threads);
            }
            for &t in &threads {
                let name = format!("gemm {} {}x{}x{} t={t}", sh.label, sh.m, sh.n, sh.k);
                let iters = if sh.m * sh.n * sh.k > 10_000_000 { 20 } else { 60 };
                let s = pool::with_intra_op(t, || {
                    bench(&name, 2, iters, || {
                        math::gemm(
                            sh.ta, sh.tb, sh.m, sh.n, sh.k, 1.0, &va, &vb, 0.0, &mut vc,
                        );
                    })
                });
                let gflops = flops / s.median_ns;
                println!("{}   ({gflops:.2} GFLOP/s)", s.line());
                let mut o = Json::obj();
                o.set("shape", Json::str(sh.label));
                o.set("m", Json::num(sh.m as f64));
                o.set("n", Json::num(sh.n as f64));
                o.set("k", Json::num(sh.k as f64));
                o.set("threads", Json::num(t as f64));
                o.set("median_ns", Json::num(s.median_ns));
                o.set("gflops", Json::num(gflops));
                results.push(o);
            }
        }
        let mut root = Json::obj();
        root.set("bench", Json::str("gemm"));
        root.set("max_threads", Json::num(max_threads as f64));
        root.set("results", Json::Arr(results));
        std::fs::write("BENCH_gemm.json", root.to_pretty())?;
        println!("wrote BENCH_gemm.json");
    }

    // 2b. Same gemm through the CPU device launch path (adds dispatch +
    //     slab bookkeeping to the kernel time above).
    {
        let mut dev = CpuDevice::new();
        let (m, k, n) = (128usize, 1152, 784);
        let a = dev.alloc(m * k)?;
        let b = dev.alloc(k * n)?;
        let c = dev.alloc(m * n)?;
        let mut rng = fecaffe::util::prng::Pcg32::new(1);
        let mut va = vec![0f32; m * k];
        let mut vb = vec![0f32; k * n];
        rng.fill_uniform(&mut va, -1.0, 1.0);
        rng.fill_uniform(&mut vb, -1.0, 1.0);
        dev.write(a, &va);
        dev.write(b, &vb);
        let call = KernelCall::new(
            Kernel::GemmNN { m, n, k, alpha: 1.0, beta: 0.0 },
            &[a, b],
            &[c],
        );
        let s = bench("native gemm 128x1152x784 (device)", 2, 20, || {
            dev.launch(&call).unwrap();
        });
        let gflops = 2.0 * (m * n * k) as f64 / s.median_ns;
        println!("{}   ({gflops:.2} GFLOP/s)", s.line());
    }

    // 3. PJRT dispatch for the same gemm (if artifacts exist).
    if let Some(backend) = PjrtBackend::auto() {
        let mut dev = FpgaSimDevice::new().with_backend(Box::new(backend));
        let (m, k, n) = (128usize, 1152, 784);
        let a = dev.alloc(m * k)?;
        let b = dev.alloc(k * n)?;
        let c = dev.alloc(m * n)?;
        let mut rng = fecaffe::util::prng::Pcg32::new(1);
        let mut va = vec![0f32; m * k];
        let mut vb = vec![0f32; k * n];
        rng.fill_uniform(&mut va, -1.0, 1.0);
        rng.fill_uniform(&mut vb, -1.0, 1.0);
        dev.write(a, &va);
        dev.write(b, &vb);
        let call = KernelCall::new(
            Kernel::GemmNN { m, n, k, alpha: 1.0, beta: 0.0 },
            &[a, b],
            &[c],
        );
        let s = bench("pjrt gemm 128x1152x784", 2, 20, || {
            dev.launch(&call).unwrap();
        });
        let gflops = 2.0 * (m * n * k) as f64 / s.median_ns;
        println!("{}   ({gflops:.2} GFLOP/s incl. marshal)", s.line());
    } else {
        println!("pjrt gemm: skipped (no artifacts; run `make artifacts`)");
    }

    // 4. End-to-end LeNet train iteration (numerics on, batch 16).
    {
        let mut dev = FpgaSimDevice::new();
        let param = zoo::by_name("lenet", 16)?;
        let net = Net::from_param(&param, Phase::Train, &mut dev)?;
        let mut solver = Solver::new(zoo::default_solver("lenet")?, net, &mut dev)?;
        solver.step(&mut dev)?; // warm
        let s = bench("lenet train iter (native, bs16)", 1, 10, || {
            solver.step(&mut dev).unwrap();
        });
        println!("{}", s.line());
    }
    Ok(())
}
