//! Label-conditioned synthetic ImageNet workload.
//!
//! Each class has a deterministic "prototype" (a handful of colored
//! Gaussian blobs placed by a class-seeded PRNG); samples are the
//! prototype plus noise. Shapes and label statistics match ImageNet 2012
//! (3×224×224 by default, 1000 classes, 1.28 M train / 50 k val images
//! for the epoch-time projections in Table 4).

use super::DataSource;
use crate::util::prng::Pcg32;

pub const IMAGENET_TRAIN_IMAGES: usize = 1_281_167;
pub const IMAGENET_VAL_IMAGES: usize = 50_000;

pub struct ImagenetSynth {
    channels: usize,
    height: usize,
    width: usize,
    num_classes: usize,
    blobs_per_class: usize,
}

struct ClassBlob {
    cx: f32,
    cy: f32,
    sigma: f32,
    amp: [f32; 3],
}

impl ImagenetSynth {
    pub fn new(channels: usize, height: usize, width: usize, num_classes: usize) -> Self {
        ImagenetSynth { channels, height, width, num_classes, blobs_per_class: 4 }
    }

    fn class_blobs(&self, label: usize) -> Vec<ClassBlob> {
        let mut rng = Pcg32::with_stream(0xc1a5_5000 + label as u64, 7);
        (0..self.blobs_per_class)
            .map(|_| ClassBlob {
                cx: rng.uniform(0.2, 0.8) * self.width as f32,
                cy: rng.uniform(0.2, 0.8) * self.height as f32,
                sigma: rng.uniform(0.08, 0.25) * self.width as f32,
                amp: [
                    rng.uniform(-1.0, 1.0),
                    rng.uniform(-1.0, 1.0),
                    rng.uniform(-1.0, 1.0),
                ],
            })
            .collect()
    }
}

impl DataSource for ImagenetSynth {
    fn shape(&self) -> (usize, usize, usize) {
        (self.channels, self.height, self.width)
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn sample(&self, rng: &mut Pcg32) -> (Vec<f32>, usize) {
        let label = rng.below(self.num_classes as u32) as usize;
        let (c, h, w) = self.shape();
        let mut img = vec![0.0f32; c * h * w];
        let blobs = self.class_blobs(label);
        for b in &blobs {
            let inv2s2 = 1.0 / (2.0 * b.sigma * b.sigma);
            // Bounding box cutoff at 3 sigma for speed.
            let x_lo = ((b.cx - 3.0 * b.sigma).max(0.0)) as usize;
            let x_hi = ((b.cx + 3.0 * b.sigma).min(w as f32 - 1.0)) as usize;
            let y_lo = ((b.cy - 3.0 * b.sigma).max(0.0)) as usize;
            let y_hi = ((b.cy + 3.0 * b.sigma).min(h as f32 - 1.0)) as usize;
            for y in y_lo..=y_hi {
                for x in x_lo..=x_hi {
                    let d2 = (x as f32 - b.cx).powi(2) + (y as f32 - b.cy).powi(2);
                    let g = (-d2 * inv2s2).exp();
                    for ch in 0..c {
                        img[(ch * h + y) * w + x] += b.amp[ch % 3] * g;
                    }
                }
            }
        }
        // Per-sample noise.
        for v in img.iter_mut() {
            *v += rng.gaussian(0.0, 0.1);
        }
        (img, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_label_shares_structure() {
        let src = ImagenetSynth::new(3, 32, 32, 10);
        let mut rng = Pcg32::new(4);
        // draw many samples, find two with the same label
        let mut by_label: std::collections::HashMap<usize, Vec<Vec<f32>>> = Default::default();
        for _ in 0..40 {
            let (img, l) = src.sample(&mut rng);
            by_label.entry(l).or_default().push(img);
        }
        let (_, imgs) = by_label.iter().find(|(_, v)| v.len() >= 2).unwrap();
        let corr = correlation(&imgs[0], &imgs[1]);
        assert!(corr > 0.3, "same-class correlation {corr}");
    }

    #[test]
    fn different_labels_differ_more() {
        let src = ImagenetSynth::new(3, 32, 32, 1000);
        let mut rng = Pcg32::new(4);
        let (a, la) = src.sample(&mut rng);
        let mut b;
        loop {
            let (img, lb) = src.sample(&mut rng);
            if lb != la {
                b = img;
                break;
            }
        }
        b[0] += 0.0; // silence unused-mut lint pattern
        let corr = correlation(&a, &b);
        assert!(corr < 0.5, "cross-class correlation {corr}");
    }

    fn correlation(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len() as f32;
        let ma = a.iter().sum::<f32>() / n;
        let mb = b.iter().sum::<f32>() / n;
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for (&x, &y) in a.iter().zip(b.iter()) {
            cov += (x - ma) * (y - mb);
            va += (x - ma) * (x - ma);
            vb += (y - mb) * (y - mb);
        }
        cov / (va.sqrt() * vb.sqrt() + 1e-9)
    }

    #[test]
    fn epoch_constants() {
        assert_eq!(IMAGENET_TRAIN_IMAGES + IMAGENET_VAL_IMAGES, 1_331_167);
    }
}
