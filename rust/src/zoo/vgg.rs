//! VGG-16 (configuration D): 13 3×3 convolutions in five blocks + 3 FCs.
//! Paper Table 1 reports per-block times; Table 4 notes its *training*
//! does not fit the S10 board's 2 GB DDR — the fpga-sim reproduces that
//! (see benches/table4.rs).

use super::NetBuilder;
use crate::proto::{NetParameter, PoolMethod};

pub fn vgg16(batch: usize) -> NetParameter {
    let mut b = NetBuilder::new("VGG_16");
    b.data(batch, 3, 224, 1000, "imagenet");
    b.conv_relu("conv1_1", "data", 64, 3, 1, 1);
    b.conv_relu("conv1_2", "conv1_1", 64, 3, 1, 1);
    b.pool("pool1", "conv1_2", PoolMethod::Max, 2, 2, 0);
    b.conv_relu("conv2_1", "pool1", 128, 3, 1, 1);
    b.conv_relu("conv2_2", "conv2_1", 128, 3, 1, 1);
    b.pool("pool2", "conv2_2", PoolMethod::Max, 2, 2, 0);
    b.conv_relu("conv3_1", "pool2", 256, 3, 1, 1);
    b.conv_relu("conv3_2", "conv3_1", 256, 3, 1, 1);
    b.conv_relu("conv3_3", "conv3_2", 256, 3, 1, 1);
    b.pool("pool3", "conv3_3", PoolMethod::Max, 2, 2, 0);
    b.conv_relu("conv4_1", "pool3", 512, 3, 1, 1);
    b.conv_relu("conv4_2", "conv4_1", 512, 3, 1, 1);
    b.conv_relu("conv4_3", "conv4_2", 512, 3, 1, 1);
    b.pool("pool4", "conv4_3", PoolMethod::Max, 2, 2, 0);
    b.conv_relu("conv5_1", "pool4", 512, 3, 1, 1);
    b.conv_relu("conv5_2", "conv5_1", 512, 3, 1, 1);
    b.conv_relu("conv5_3", "conv5_2", 512, 3, 1, 1);
    b.pool("pool5", "conv5_3", PoolMethod::Max, 2, 2, 0);
    b.fc("fc6", "pool5", 4096);
    b.relu_inplace("relu6", "fc6");
    b.dropout_inplace("drop6", "fc6", 0.5);
    b.fc("fc7", "fc6", 4096);
    b.relu_inplace("relu7", "fc7");
    b.dropout_inplace("drop7", "fc7", 0.5);
    b.fc("fc8", "fc7", 1000);
    b.accuracy("accuracy", "fc8");
    b.softmax_loss("loss", "fc8", 1.0);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_13_convs_and_3_fcs() {
        let net = vgg16(1);
        let convs = net.layers.iter().filter(|l| l.kind == "Convolution").count();
        let fcs = net.layers.iter().filter(|l| l.kind == "InnerProduct").count();
        assert_eq!(convs, 13);
        assert_eq!(fcs, 3);
    }

    // Geometry/params checked in the integration suite (building VGG at
    // 224² allocates ~0.5 GB of activations — too heavy for a unit test).
}
