//! Net: the layer graph (caffe::Net).
//!
//! Built from a [`NetParameter`] for one phase. Reproduces Caffe's
//! initialization semantics the paper relies on:
//!
//! * **auto-Split insertion** — when one blob feeds several consumers
//!   (GoogLeNet's inception fan-out), a `Split` layer is inserted whose
//!   backward *accumulates* the branch gradients (paper Table 2's 41
//!   `Split` instances);
//! * **in-place layers** — ReLU/Dropout with `bottom == top` share the
//!   blob (versioned, so split counting stays correct);
//! * **backward-need propagation** — gradients only flow where a learnable
//!   parameter or a grad-needing bottom lies upstream (`prop_down`).

use crate::blob::Blob;
use crate::device::Device;
use crate::layers::{create_layer, shared, Layer, LayerTimer, LayerTiming, SharedBlob};
use crate::proto::{LayerParameter, NetParameter, ParamSpec, Phase};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

/// Immutable host-side snapshot of every learnable parameter, shared
/// between net replicas via `Arc` — the serving engine's "weights
/// shared, activations per-replica" contract. The snapshot is `Send +
/// Sync`, so it can cross threads even though `Net` itself (built on
/// `Rc<RefCell<Blob>>`) cannot: each worker thread builds its own
/// replica from the same `NetParameter` and adopts the snapshot.
///
/// Snapshots are *versioned*: a monotonic `version` (0 = "unversioned";
/// the serving engine assigns `current + 1` on publish) plus an optional
/// free-form `tag` (e.g. `iter-500`). Each blob also carries a stable
/// identity key — `(owner layer name, slot index within that layer)` —
/// so a snapshot exported from a *training* net can be projected onto a
/// *deploy* net that pruned param-carrying layers (GoogLeNet's auxiliary
/// classifier heads) via [`WeightSnapshot::project`].
#[derive(Debug, Clone, Default)]
pub struct WeightSnapshot {
    version: u64,
    tag: Option<String>,
    blobs: Vec<Arc<Vec<f32>>>,
    keys: Vec<(String, usize)>,
}

/// Magic header of the weight-snapshot container written by
/// [`WeightSnapshot::save`] (distinct from the solver's `FECAFFE1`
/// training snapshot, which also carries optimizer history).
const WEIGHTS_MAGIC: &[u8; 8] = b"FEWSNAP1";

impl WeightSnapshot {
    /// Number of parameter blobs in the snapshot.
    pub fn len(&self) -> usize {
        self.blobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blobs.is_empty()
    }

    /// Total learnable parameter count.
    pub fn num_parameters(&self) -> usize {
        self.blobs.iter().map(|b| b.len()).sum()
    }

    /// Monotonic snapshot version (0 = unversioned; the engine assigns
    /// the next version on publish).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Optional human-readable tag (e.g. the training iteration).
    pub fn tag(&self) -> Option<&str> {
        self.tag.as_deref()
    }

    pub fn with_version(mut self, version: u64) -> WeightSnapshot {
        self.version = version;
        self
    }

    pub fn with_tag(mut self, tag: impl Into<String>) -> WeightSnapshot {
        self.tag = Some(tag.into());
        self
    }

    /// Per-blob identity: (owner layer name, slot index within that
    /// layer), aligned with `blobs`.
    pub fn keys(&self) -> &[(String, usize)] {
        &self.keys
    }

    /// Element count of every blob, in order.
    pub fn blob_lens(&self) -> Vec<usize> {
        self.blobs.iter().map(|b| b.len()).collect()
    }

    /// Read-only view of blob `i`'s values (None out of range).
    pub fn blob_data(&self, i: usize) -> Option<&[f32]> {
        self.blobs.get(i).map(|b| b.as_slice())
    }

    /// Re-order (and subset) this snapshot's blobs onto a target
    /// parameter schema, matching by `(owner, slot)` key. This is how a
    /// training-net snapshot lands on a deploy net whose pruned layers
    /// (aux heads) dropped some params: extra blobs in `self` are
    /// ignored, a *missing* target key or an element-count mismatch is
    /// an error. Cheap — blobs are `Arc`-cloned, never copied.
    pub fn project(
        &self,
        keys: &[(String, usize)],
        lens: &[usize],
    ) -> anyhow::Result<WeightSnapshot> {
        anyhow::ensure!(
            keys.len() == lens.len(),
            "project: {} keys but {} lens",
            keys.len(),
            lens.len()
        );
        anyhow::ensure!(
            self.keys.len() == self.blobs.len(),
            "snapshot is missing blob identity keys ({} keys, {} blobs)",
            self.keys.len(),
            self.blobs.len()
        );
        let mut index: HashMap<(&str, usize), usize> = HashMap::new();
        for (i, (owner, slot)) in self.keys.iter().enumerate() {
            index.insert((owner.as_str(), *slot), i);
        }
        let mut blobs = Vec::with_capacity(keys.len());
        for ((owner, slot), want) in keys.iter().zip(lens.iter()) {
            let i = *index.get(&(owner.as_str(), *slot)).ok_or_else(|| {
                anyhow::anyhow!("snapshot has no param for layer '{owner}' (slot {slot})")
            })?;
            let blob = &self.blobs[i];
            anyhow::ensure!(
                blob.len() == *want,
                "param of layer '{owner}' slot {slot}: snapshot has {} elements, model expects {}",
                blob.len(),
                want
            );
            blobs.push(blob.clone());
        }
        Ok(WeightSnapshot {
            version: self.version,
            tag: self.tag.clone(),
            blobs,
            keys: keys.to_vec(),
        })
    }

    /// Serialize to a standalone weight file (`FEWSNAP1` container:
    /// version, tag, and per blob its identity key + f32 data, all
    /// little-endian via [`crate::util::binio`]). The on-disk artifact
    /// behind the serving engine's `POST /admin/models/<name>:publish`
    /// endpoint.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        use crate::util::binio::{put_f32s, put_str, put_u32, put_u64};
        use std::io::Write;
        anyhow::ensure!(
            self.keys.len() == self.blobs.len(),
            "snapshot is missing blob identity keys"
        );
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
        w.write_all(WEIGHTS_MAGIC)?;
        put_u64(&mut w, self.version)?;
        put_str(&mut w, self.tag.as_deref().unwrap_or(""))?;
        put_u32(&mut w, self.blobs.len() as u32)?;
        for ((owner, slot), blob) in self.keys.iter().zip(self.blobs.iter()) {
            put_str(&mut w, owner)?;
            put_u32(&mut w, *slot as u32)?;
            put_u32(&mut w, blob.len() as u32)?;
            put_f32s(&mut w, blob)?;
        }
        Ok(())
    }

    /// Load a `FEWSNAP1` weight file written by [`WeightSnapshot::save`].
    /// Every length field is bounded by the file's actual size before
    /// anything is allocated, so a corrupt file fed to the publish
    /// endpoint errors out instead of requesting gigabytes inside a
    /// live serving process.
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<WeightSnapshot> {
        use crate::util::binio::{get_f32s, get_str, get_u32, get_u64};
        use std::io::Read;
        let file = std::fs::File::open(&path)?;
        let file_len = file.metadata()?.len() as usize;
        let mut r = std::io::BufReader::new(file);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(
            &magic == WEIGHTS_MAGIC,
            "not a FEWSNAP1 weight snapshot (bad magic)"
        );
        let version = get_u64(&mut r)?;
        let tag = get_str(&mut r, file_len)?;
        let count = get_u32(&mut r)? as usize;
        // Each blob record costs at least 12 bytes of headers, so a
        // count the file can't possibly hold is corruption.
        anyhow::ensure!(
            count <= file_len / 12,
            "implausible blob count {count} for a {file_len}-byte snapshot"
        );
        let mut blobs = Vec::with_capacity(count);
        let mut keys = Vec::with_capacity(count);
        for _ in 0..count {
            let owner = get_str(&mut r, file_len)?;
            let slot = get_u32(&mut r)? as usize;
            let n = get_u32(&mut r)? as usize;
            anyhow::ensure!(
                n <= file_len / 4,
                "implausible blob length {n} for a {file_len}-byte snapshot"
            );
            let data = get_f32s(&mut r, n)?;
            keys.push((owner, slot));
            blobs.push(Arc::new(data));
        }
        Ok(WeightSnapshot {
            version,
            tag: if tag.is_empty() { None } else { Some(tag) },
            blobs,
            keys,
        })
    }

    /// Assemble a snapshot from raw parts — used by `quant` to rebuild a
    /// dequantized (fake-quant) snapshot carrying the original identity.
    /// `keys` and `blobs` must align one-to-one.
    pub(crate) fn from_parts(
        version: u64,
        tag: Option<String>,
        keys: Vec<(String, usize)>,
        blobs: Vec<Arc<Vec<f32>>>,
    ) -> WeightSnapshot {
        assert_eq!(keys.len(), blobs.len(), "keys/blobs misaligned");
        WeightSnapshot { version, tag, blobs, keys }
    }
}

/// One learnable parameter with its schedule multipliers and owner.
pub struct NetParam {
    pub blob: SharedBlob,
    pub spec: ParamSpec,
    pub owner: String,
}

pub struct Net {
    pub name: String,
    pub phase: Phase,
    layers: Vec<Box<dyn Layer>>,
    bottoms: Vec<Vec<SharedBlob>>,
    tops: Vec<Vec<SharedBlob>>,
    prop_down: Vec<Vec<bool>>,
    layer_need_bw: Vec<bool>,
    blobs: BTreeMap<String, SharedBlob>,
    params: Vec<NetParam>,
    /// Deploy-style explicit input blob names, in declaration order
    /// (empty for data-layer-fed training nets). The first one carries
    /// the batch dimension [`Net::reshape_batch`] rewrites.
    inputs: Vec<String>,
}

impl Net {
    /// Build + setup the net for `phase` on `dev`.
    pub fn from_param(
        param: &NetParameter,
        phase: Phase,
        dev: &mut dyn Device,
    ) -> anyhow::Result<Net> {
        let phase_layers: Vec<LayerParameter> = param
            .layers_for_phase(phase)
            .into_iter()
            .cloned()
            .collect();
        let with_splits = insert_splits(&phase_layers);

        let mut net = Net {
            name: param.name.clone(),
            phase,
            layers: Vec::new(),
            bottoms: Vec::new(),
            tops: Vec::new(),
            prop_down: Vec::new(),
            layer_need_bw: Vec::new(),
            blobs: BTreeMap::new(),
            params: Vec::new(),
            inputs: Vec::new(),
        };

        // Deploy-style explicit inputs.
        for (name, shape) in &param.inputs {
            net.blobs
                .insert(name.clone(), shared(Blob::new(name, shape)));
            net.inputs.push(name.clone());
        }

        // Which blobs carry gradient back (label/data blobs don't).
        let mut blob_needs_grad: HashMap<String, bool> = HashMap::new();
        for (name, _) in &param.inputs {
            blob_needs_grad.insert(name.clone(), false);
        }

        for lp in &with_splits {
            let mut layer = create_layer(lp, phase)?;
            // Resolve bottoms (must already exist).
            let mut bots = Vec::new();
            for b in &lp.bottoms {
                let blob = net
                    .blobs
                    .get(b)
                    .ok_or_else(|| {
                        anyhow::anyhow!("layer {}: unknown bottom blob '{b}'", lp.name)
                    })?
                    .clone();
                bots.push(blob);
            }
            // Resolve/create tops (in-place reuses the bottom's blob).
            let mut tops = Vec::new();
            for t in &lp.tops {
                if let Some(pos) = lp.bottoms.iter().position(|b| b == t) {
                    tops.push(bots[pos].clone()); // in-place
                } else {
                    let blob = shared(Blob::new(t, &[1]));
                    net.blobs.insert(t.clone(), blob.clone());
                    tops.push(blob);
                }
            }
            layer.setup(dev, &bots, &tops)?;

            // prop_down: does each bottom need a gradient?
            let pd: Vec<bool> = lp
                .bottoms
                .iter()
                .map(|b| *blob_needs_grad.get(b).unwrap_or(&true))
                .collect();
            // This layer needs backward if it has params or any bottom
            // needs grad — and the layer type participates at all.
            let has_params = !layer.param_blobs().is_empty();
            let need_bw =
                layer.needs_backward() && (has_params || pd.iter().any(|&v| v));
            // Tops produced by a backward-participating layer carry grads.
            for t in &lp.tops {
                // Label outputs of data layers never need grad; covered by
                // needs_backward() == false for data layers.
                blob_needs_grad.insert(t.clone(), need_bw || layer.is_loss());
            }

            // Collect params with specs (padded with defaults like Caffe).
            let pblobs = layer.param_blobs();
            let specs = layer.param_specs();
            for (i, pb) in pblobs.iter().enumerate() {
                net.params.push(NetParam {
                    blob: pb.clone(),
                    spec: specs.get(i).copied().unwrap_or_default(),
                    owner: lp.name.clone(),
                });
            }

            net.layers.push(layer);
            net.bottoms.push(bots);
            net.tops.push(tops);
            net.prop_down.push(pd);
            net.layer_need_bw.push(need_bw);
        }
        Ok(net)
    }

    /// Rewrite the batch dimension of the (deploy-style) input blob to
    /// `n` and re-propagate shapes through the whole DAG — Caffe's
    /// reshape-on-the-fly, as one explicit phase. Learnable parameters
    /// are untouched (never reallocated); activation `SyncedMem`s grow
    /// only, so a replica cycling through batch sizes settles at its
    /// high-water allocation and pays no alloc/free churn per reshape;
    /// conv scratch is re-reserved through the bucketed scratch pool.
    /// Data layers keep their own fixed batch (they re-assert it), so
    /// this is only meaningful for nets with explicit `input` blobs.
    pub fn reshape_batch(&mut self, dev: &mut dyn Device, n: usize) -> anyhow::Result<()> {
        anyhow::ensure!(n >= 1, "reshape_batch: batch must be >= 1");
        let first = self.inputs.first().ok_or_else(|| {
            anyhow::anyhow!(
                "net '{}' has no explicit input blobs; only deploy-style nets can be re-batched",
                self.name
            )
        })?;
        let blob = self.blobs.get(first).expect("input blob registered").clone();
        {
            let mut b = blob.borrow_mut();
            let mut shape = b.shape().to_vec();
            anyhow::ensure!(
                !shape.is_empty(),
                "input blob '{first}' has no batch dimension"
            );
            shape[0] = n;
            b.reshape_grow_only(dev, &shape);
        }
        for i in 0..self.layers.len() {
            if let Err(e) = self.layers[i].reshape(dev, &self.bottoms[i], &self.tops[i]) {
                anyhow::bail!("reshape of layer '{}': {e:#}", self.layers[i].name());
            }
        }
        Ok(())
    }

    /// Full forward pass; returns the total (weighted) loss.
    pub fn forward(&mut self, dev: &mut dyn Device) -> anyhow::Result<f32> {
        let mut loss = 0.0;
        for i in 0..self.layers.len() {
            loss += self.layers[i].forward(dev, &self.bottoms[i], &self.tops[i])?;
        }
        Ok(loss)
    }

    /// Forward with per-layer timing (`caffe time` behaviour). Returns
    /// (loss, per-layer ns) using the device's simulated clock when
    /// available, else wallclock.
    pub fn forward_timed(&mut self, dev: &mut dyn Device) -> anyhow::Result<(f32, Vec<u64>)> {
        let mut times = Vec::with_capacity(self.layers.len());
        let loss = self.forward_traced(dev, &mut |t: LayerTiming<'_>| {
            times.push(t.sim_ns.unwrap_or(t.wall_ns));
        })?;
        Ok((loss, times))
    }

    /// Forward pass with a per-layer [`LayerTimer`] hook: every layer
    /// reports wall time (always) and simulated device time (when the
    /// device has a sim clock), with start offsets relative to this
    /// call. Each layer is bracketed by `dev.synchronize()`, so the
    /// per-layer sim durations telescope — their sum is *exactly* the
    /// sim-clock advance across the whole pass. This is the single
    /// timing path behind `forward_timed`, the serving worker's sampled
    /// batch traces, and `fecaffe profile`.
    pub fn forward_traced(
        &mut self,
        dev: &mut dyn Device,
        timer: &mut dyn LayerTimer,
    ) -> anyhow::Result<f32> {
        let wall0 = Instant::now();
        let sim0 = dev.sim_clock_ns();
        let mut loss = 0.0;
        for i in 0..self.layers.len() {
            let wall_start = wall0.elapsed().as_nanos() as u64;
            let sim_start = dev.sim_clock_ns();
            loss += self.layers[i].forward(dev, &self.bottoms[i], &self.tops[i])?;
            dev.synchronize();
            let wall_ns = (wall0.elapsed().as_nanos() as u64).saturating_sub(wall_start);
            let sim_end = dev.sim_clock_ns();
            timer.record(LayerTiming {
                index: i,
                name: self.layers[i].name(),
                kind: self.layers[i].kind(),
                wall_start_ns: wall_start,
                wall_ns,
                sim_start_ns: match (sim_start, sim0) {
                    (Some(s), Some(base)) => Some(s.saturating_sub(base)),
                    _ => None,
                },
                sim_ns: match (sim_start, sim_end) {
                    (Some(a), Some(b)) => Some(b.saturating_sub(a)),
                    _ => None,
                },
            });
        }
        Ok(loss)
    }

    /// Full backward pass.
    pub fn backward(&mut self, dev: &mut dyn Device) -> anyhow::Result<()> {
        for i in (0..self.layers.len()).rev() {
            if self.layer_need_bw[i] {
                self.layers[i].backward(dev, &self.tops[i], &self.prop_down[i], &self.bottoms[i])?;
            }
        }
        Ok(())
    }

    /// Backward with per-layer timing (reverse order, like `caffe time`).
    pub fn backward_timed(&mut self, dev: &mut dyn Device) -> anyhow::Result<Vec<u64>> {
        let mut times = vec![0u64; self.layers.len()];
        for i in (0..self.layers.len()).rev() {
            let t0 = clock(dev);
            if self.layer_need_bw[i] {
                self.layers[i].backward(dev, &self.tops[i], &self.prop_down[i], &self.bottoms[i])?;
            }
            dev.synchronize();
            times[i] = clock(dev) - t0;
        }
        Ok(times)
    }

    pub fn forward_backward(&mut self, dev: &mut dyn Device) -> anyhow::Result<f32> {
        let loss = self.forward(dev)?;
        self.backward(dev)?;
        Ok(loss)
    }

    pub fn blob(&self, name: &str) -> Option<SharedBlob> {
        self.blobs.get(name).cloned()
    }

    pub fn blob_names(&self) -> Vec<String> {
        self.blobs.keys().cloned().collect()
    }

    pub fn params(&self) -> &[NetParam] {
        &self.params
    }

    pub fn layer_names(&self) -> Vec<String> {
        self.layers.iter().map(|l| l.name().to_string()).collect()
    }

    pub fn layer_kinds(&self) -> Vec<&'static str> {
        self.layers.iter().map(|l| l.kind()).collect()
    }

    /// Total learnable parameter count.
    pub fn num_parameters(&self) -> usize {
        self.params.iter().map(|p| p.blob.borrow().count()).sum()
    }

    /// Sum of all blob bytes (data+diff), the device-DDR footprint driver.
    pub fn activation_bytes(&self) -> usize {
        self.blobs
            .values()
            .map(|b| 2 * b.borrow().bytes())
            .sum()
    }

    /// Publish this net's weights as a shared snapshot. O(1) per blob
    /// (the host vectors are moved into `Arc`s, not copied); this net
    /// keeps using the same storage and detaches copy-on-write if it
    /// later mutates a weight (solver step).
    pub fn share_weights(&mut self, dev: &mut dyn Device) -> WeightSnapshot {
        let mut blobs = Vec::with_capacity(self.params.len());
        let mut keys = Vec::with_capacity(self.params.len());
        let mut slot_of: HashMap<String, usize> = HashMap::new();
        for p in &self.params {
            let slot = slot_of.entry(p.owner.clone()).or_insert(0);
            keys.push((p.owner.clone(), *slot));
            *slot += 1;
            blobs.push(p.blob.borrow_mut().data.share_host(dev));
        }
        WeightSnapshot { version: 0, tag: None, blobs, keys }
    }

    /// Attach a shared weight snapshot to this replica. The nets must be
    /// built from the same `NetParameter` (parameter order and sizes
    /// must line up); activations and gradients stay per-replica.
    pub fn adopt_weights(
        &mut self,
        dev: &mut dyn Device,
        snap: &WeightSnapshot,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            snap.blobs.len() == self.params.len(),
            "weight snapshot has {} blobs, net '{}' has {} params",
            snap.blobs.len(),
            self.name,
            self.params.len()
        );
        // Validate every blob before mutating anything, so a mismatch
        // can't leave the net half-adopted (mixing two weight sets).
        for (p, shared) in self.params.iter().zip(snap.blobs.iter()) {
            let want = p.blob.borrow().count();
            anyhow::ensure!(
                shared.len() == want,
                "param of layer '{}': snapshot blob has {} elements, blob expects {}",
                p.owner,
                shared.len(),
                want
            );
        }
        for (p, shared) in self.params.iter().zip(snap.blobs.iter()) {
            p.blob
                .borrow_mut()
                .data
                .adopt_shared(dev, shared.clone())
                .map_err(|e| anyhow::anyhow!("param of layer '{}': {e}", p.owner))?;
        }
        Ok(())
    }
}

fn clock(dev: &mut dyn Device) -> u64 {
    dev.sim_clock_ns().unwrap_or_else(|| {
        use std::time::{SystemTime, UNIX_EPOCH};
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64
    })
}

/// Caffe's `insert_splits`: version blobs through in-place layers, count
/// consumers per version, and materialize a Split layer wherever a
/// version has more than one consumer.
pub fn insert_splits(layers: &[LayerParameter]) -> Vec<LayerParameter> {
    type Key = (String, usize);
    let mut version: HashMap<String, usize> = HashMap::new();
    let mut consumers: HashMap<Key, usize> = HashMap::new();

    // Pass 1: count consumers of each blob version.
    for lp in layers {
        for b in &lp.bottoms {
            let v = *version.get(b).unwrap_or(&0);
            *consumers.entry((b.clone(), v)).or_insert(0) += 1;
        }
        for t in &lp.tops {
            if lp.bottoms.contains(t) {
                *version.entry(t.clone()).or_insert(0) += 1; // in-place
            } else {
                version.insert(t.clone(), 0);
            }
        }
    }

    // Pass 2: rebuild with Split layers + remapped bottoms.
    let mut out = Vec::new();
    let mut version2: HashMap<String, usize> = HashMap::new();
    let mut pending: HashMap<Key, VecDeque<String>> = HashMap::new();

    for lp in layers {
        let mut lp = lp.clone();
        // Remap bottoms through pending split outputs. Tops keep their
        // original names: an in-place layer whose bottom was remapped to
        // a split alias simply stops being in-place (its top becomes a
        // fresh blob shadowing the old name, Caffe's behavior) — the
        // version bump in the accounting below still attributes later
        // consumers of the name to this layer's output.
        for b in lp.bottoms.iter_mut() {
            let v = *version2.get(b.as_str()).unwrap_or(&0);
            if let Some(q) = pending.get_mut(&(b.clone(), v)) {
                if let Some(alias) = q.pop_front() {
                    *b = alias;
                }
            }
        }
        let tops_now = lp.tops.clone();
        out.push(lp);
        for t in &tops_now {
            // Determine version for counting: split outputs aren't in the
            // consumers map (version2 entry created fresh).
            let was_in_place = version2.contains_key(t);
            let v = if was_in_place {
                let e = version2.get_mut(t).unwrap();
                *e += 1;
                *e
            } else {
                version2.insert(t.clone(), 0);
                0
            };
            let n = *consumers.get(&(t.clone(), v)).unwrap_or(&0);
            if n > 1 {
                // Materialize the split.
                let split_name = format!("{t}_split");
                let mut sp = LayerParameter::new(&split_name, "Split");
                sp.bottoms = vec![t.clone()];
                let mut q = VecDeque::new();
                for j in 0..n {
                    let alias = format!("{t}_split_{j}");
                    sp.tops.push(alias.clone());
                    q.push_back(alias);
                }
                pending.insert((t.clone(), v), q);
                out.push(sp);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::proto::parse_net;

    const TINY_NET: &str = r#"
name: "tiny"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 2 channels: 1 height: 8 width: 8 num_classes: 3 source: "digits" } }
layer { name: "conv1" type: "Convolution" bottom: "data" top: "conv1"
        convolution_param { num_output: 4 kernel_size: 3
          weight_filler { type: "xavier" } } }
layer { name: "relu1" type: "ReLU" bottom: "conv1" top: "conv1" }
layer { name: "pool1" type: "Pooling" bottom: "conv1" top: "pool1"
        pooling_param { pool: MAX kernel_size: 2 stride: 2 } }
layer { name: "fc" type: "InnerProduct" bottom: "pool1" top: "fc"
        inner_product_param { num_output: 3 weight_filler { type: "xavier" } } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc" bottom: "label" top: "loss" }
"#;

    #[test]
    fn builds_and_runs_forward_backward() {
        let mut dev = CpuDevice::new();
        let param = parse_net(TINY_NET).unwrap();
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        // conv(w,b) + fc(w,b) = 4 param blobs
        assert_eq!(net.params().len(), 4);
        let loss = net.forward_backward(&mut dev).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // conv weights received a gradient
        let g = net.params()[0].blob.borrow_mut().diff_vec(&mut dev);
        assert!(g.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn split_inserted_for_fanout() {
        let text = r#"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 1 channels: 1 height: 8 width: 8 num_classes: 3 source: "digits" } }
layer { name: "fc1" type: "InnerProduct" bottom: "data" top: "fc1"
        inner_product_param { num_output: 4 weight_filler { type: "xavier" } } }
layer { name: "a" type: "ReLU" bottom: "fc1" top: "a" }
layer { name: "b" type: "ReLU" bottom: "fc1" top: "b" }
"#;
        let param = parse_net(text).unwrap();
        let with_splits = insert_splits(&param.layers);
        let kinds: Vec<&str> = with_splits.iter().map(|l| l.kind.as_str()).collect();
        assert!(kinds.contains(&"Split"));
        let split = with_splits.iter().find(|l| l.kind == "Split").unwrap();
        assert_eq!(split.tops.len(), 2);
        // Consumers remapped to distinct split outputs.
        let a = with_splits.iter().find(|l| l.name == "a").unwrap();
        let b = with_splits.iter().find(|l| l.name == "b").unwrap();
        assert_ne!(a.bottoms[0], b.bottoms[0]);
        assert!(a.bottoms[0].starts_with("fc1_split_"));

        // And the built net accumulates both branch gradients.
        let mut dev = CpuDevice::new();
        let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        assert!(net.layer_kinds().contains(&"Split"));
    }

    #[test]
    fn in_place_chain_needs_no_split() {
        let text = r#"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 1 channels: 1 height: 8 width: 8 num_classes: 3 source: "digits" } }
layer { name: "fc1" type: "InnerProduct" bottom: "data" top: "fc1"
        inner_product_param { num_output: 4 weight_filler { type: "xavier" } } }
layer { name: "r" type: "ReLU" bottom: "fc1" top: "fc1" }
layer { name: "fc2" type: "InnerProduct" bottom: "fc1" top: "fc2"
        inner_product_param { num_output: 2 weight_filler { type: "xavier" } } }
"#;
        let param = parse_net(text).unwrap();
        let with_splits = insert_splits(&param.layers);
        assert!(with_splits.iter().all(|l| l.kind != "Split"));
    }

    #[test]
    fn split_after_in_place_keeps_later_consumers_fresh() {
        // A produces t; C consumes the pre-activation value; B rectifies
        // t in-place; D consumes the post-activation value. B's bottom is
        // remapped to a split alias, and its top must KEEP the name `t`
        // so D reads rectified data — insert_splits used to rename the
        // top to the alias, silently feeding D the stale pre-ReLU blob.
        let text = r#"
input: "data"
input_shape { dim: 1 dim: 1 dim: 1 dim: 2 }
layer { name: "a" type: "Pooling" bottom: "data" top: "t"
        pooling_param { pool: AVE kernel_size: 1 stride: 1 } }
layer { name: "c" type: "Pooling" bottom: "t" top: "c"
        pooling_param { pool: AVE global_pooling: true } }
layer { name: "b" type: "ReLU" bottom: "t" top: "t" }
layer { name: "d" type: "Pooling" bottom: "t" top: "d"
        pooling_param { pool: AVE global_pooling: true } }
"#;
        let param = parse_net(text).unwrap();
        let with_splits = insert_splits(&param.layers);
        let b = with_splits.iter().find(|l| l.name == "b").unwrap();
        assert!(
            b.bottoms[0].starts_with("t_split_"),
            "b must read a split alias, got '{}'",
            b.bottoms[0]
        );
        assert_eq!(b.tops[0], "t", "in-place top keeps its name after remap");
        let d_layer = with_splits.iter().find(|l| l.name == "d").unwrap();
        assert_eq!(d_layer.bottoms[0], "t");

        let mut dev = CpuDevice::new();
        let mut net = Net::from_param(&param, Phase::Test, &mut dev).unwrap();
        net.blob("data")
            .unwrap()
            .borrow_mut()
            .set_data(&mut dev, &[-1.0, 2.0]);
        net.forward(&mut dev).unwrap();
        let c = net.blob("c").unwrap().borrow_mut().data_vec(&mut dev);
        let d = net.blob("d").unwrap().borrow_mut().data_vec(&mut dev);
        assert_eq!(c, vec![0.5], "pre-activation consumer sees the raw mean");
        assert_eq!(d, vec![1.0], "post-activation consumer sees the rectified mean");
    }

    #[test]
    fn label_blob_gets_no_gradient() {
        let mut dev = CpuDevice::new();
        let param = parse_net(TINY_NET).unwrap();
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        net.forward_backward(&mut dev).unwrap();
        // loss layer prop_down for the label bottom must be false
        let loss_idx = net
            .layer_kinds()
            .iter()
            .position(|&k| k == "SoftmaxWithLoss")
            .unwrap();
        assert_eq!(net.prop_down[loss_idx], vec![true, false]);
    }

    #[test]
    fn weight_snapshot_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<WeightSnapshot>();
    }

    #[test]
    fn replica_adopts_shared_weights() {
        let param = parse_net(TINY_NET).unwrap();

        // Master: perturb its weights away from the seeded init so
        // adoption is observable.
        let mut dev_m = CpuDevice::new();
        let mut master = Net::from_param(&param, Phase::Train, &mut dev_m).unwrap();
        {
            let blob = master.params()[0].blob.clone();
            let mut b = blob.borrow_mut();
            let w = b.data.host_data_mut(&mut dev_m);
            for v in w.iter_mut() {
                *v += 0.25;
            }
        }
        let snap = master.share_weights(&mut dev_m);
        assert_eq!(snap.len(), master.params().len());
        assert_eq!(snap.num_parameters(), master.num_parameters());

        // Replica on its own device adopts the snapshot: identical loss.
        let mut dev_r = CpuDevice::new();
        let mut replica = Net::from_param(&param, Phase::Train, &mut dev_r).unwrap();
        replica.adopt_weights(&mut dev_r, &snap).unwrap();
        let wm = master.params()[0].blob.borrow_mut().data_vec(&mut dev_m);
        let wr = replica.params()[0].blob.borrow_mut().data_vec(&mut dev_r);
        assert_eq!(wm, wr, "replica must see the master's weights");

        // Both data layers draw the same seeded batch stream, so the
        // forward losses agree bit-for-bit.
        let lm = master.forward(&mut dev_m).unwrap();
        let lr = replica.forward(&mut dev_r).unwrap();
        assert_eq!(lm, lr);

        // A replica backward step detaches (copy-on-write) instead of
        // corrupting the master's weights.
        replica.backward(&mut dev_r).unwrap();
        {
            let blob = replica.params()[0].blob.clone();
            let mut b = blob.borrow_mut();
            let w = b.data.host_data_mut(&mut dev_r);
            w[0] = 1234.5;
        }
        let wm2 = master.params()[0].blob.borrow_mut().data_vec(&mut dev_m);
        assert_eq!(wm, wm2, "master weights must be unaffected");
    }

    #[test]
    fn adopt_rejects_mismatched_snapshot() {
        let param = parse_net(TINY_NET).unwrap();
        let mut dev = CpuDevice::new();
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        let empty = WeightSnapshot::default();
        assert!(net.adopt_weights(&mut dev, &empty).is_err());
    }

    #[test]
    fn snapshot_carries_version_tag_and_keys() {
        let param = parse_net(TINY_NET).unwrap();
        let mut dev = CpuDevice::new();
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        let snap = net.share_weights(&mut dev).with_version(7).with_tag("iter-7");
        assert_eq!(snap.version(), 7);
        assert_eq!(snap.tag(), Some("iter-7"));
        // conv1 (w, b) + fc (w, b): keys name the owner layers, slots
        // count within each layer.
        assert_eq!(snap.keys().len(), 4);
        assert_eq!(snap.keys()[0], ("conv1".to_string(), 0));
        assert_eq!(snap.keys()[1], ("conv1".to_string(), 1));
        assert_eq!(snap.keys()[2], ("fc".to_string(), 0));
        assert_eq!(snap.keys()[3], ("fc".to_string(), 1));
        assert_eq!(snap.blob_lens().len(), 4);
    }

    #[test]
    fn snapshot_projects_onto_a_param_subset_by_key() {
        let param = parse_net(TINY_NET).unwrap();
        let mut dev = CpuDevice::new();
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        let snap = net.share_weights(&mut dev).with_version(3);
        // A "deploy" schema that kept only the fc layer (as if conv were
        // pruned): projection selects the right blobs by owner key.
        let keys = vec![("fc".to_string(), 0), ("fc".to_string(), 1)];
        let lens: Vec<usize> = snap.blob_lens()[2..].to_vec();
        let proj = snap.project(&keys, &lens).unwrap();
        assert_eq!(proj.version(), 3);
        assert_eq!(proj.len(), 2);
        assert_eq!(proj.blob_lens(), lens);
        // Missing key and wrong length both fail loudly.
        let missing = vec![("nope".to_string(), 0)];
        assert!(snap.project(&missing, &[1]).is_err());
        let wrong_len = vec![("fc".to_string(), 0)];
        assert!(snap.project(&wrong_len, &[1]).is_err());
    }

    #[test]
    fn snapshot_file_round_trips_and_replica_adopts_it() {
        let tmp = std::env::temp_dir().join("fecaffe_weight_snapshot_test.fewts");
        let param = parse_net(TINY_NET).unwrap();
        let mut dev = CpuDevice::new();
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        let snap = net.share_weights(&mut dev).with_version(42).with_tag("golden");
        snap.save(&tmp).unwrap();
        let back = WeightSnapshot::load(&tmp).unwrap();
        assert_eq!(back.version(), 42);
        assert_eq!(back.tag(), Some("golden"));
        assert_eq!(back.keys(), snap.keys());
        assert_eq!(back.blob_lens(), snap.blob_lens());

        // A fresh replica adopting the loaded snapshot computes the
        // same forward as the source net.
        let mut dev_r = CpuDevice::new();
        let mut replica = Net::from_param(&param, Phase::Train, &mut dev_r).unwrap();
        replica.adopt_weights(&mut dev_r, &back).unwrap();
        let lm = net.forward(&mut dev).unwrap();
        let lr = replica.forward(&mut dev_r).unwrap();
        assert_eq!(lm, lr);
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn snapshot_load_rejects_bad_magic() {
        let tmp = std::env::temp_dir().join("fecaffe_weight_snapshot_bad.fewts");
        std::fs::write(&tmp, b"NOTSNAP!rest").unwrap();
        assert!(WeightSnapshot::load(&tmp).is_err());
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn reshape_batch_repropagates_shapes_without_touching_params() {
        let text = r#"
name: "deploy"
input: "data"
input_shape { dim: 4 dim: 1 dim: 8 dim: 8 }
layer { name: "conv1" type: "Convolution" bottom: "data" top: "conv1"
        convolution_param { num_output: 2 kernel_size: 3
          weight_filler { type: "xavier" } } }
layer { name: "relu1" type: "ReLU" bottom: "conv1" top: "conv1" }
layer { name: "pool1" type: "Pooling" bottom: "conv1" top: "pool1"
        pooling_param { pool: MAX kernel_size: 2 stride: 2 } }
layer { name: "fc" type: "InnerProduct" bottom: "pool1" top: "fc"
        inner_product_param { num_output: 3 weight_filler { type: "xavier" } } }
"#;
        let mut dev = CpuDevice::new();
        let param = parse_net(text).unwrap();
        let mut net = Net::from_param(&param, Phase::Test, &mut dev).unwrap();
        let w0 = net.params()[0].blob.borrow_mut().data_vec(&mut dev);

        net.reshape_batch(&mut dev, 2).unwrap();
        assert_eq!(net.blob("data").unwrap().borrow().shape(), &[2, 1, 8, 8]);
        assert_eq!(net.blob("conv1").unwrap().borrow().shape(), &[2, 2, 6, 6]);
        assert_eq!(net.blob("pool1").unwrap().borrow().shape(), &[2, 2, 3, 3]);
        assert_eq!(net.blob("fc").unwrap().borrow().shape(), &[2, 3]);
        // Weights are untouched by the reshape.
        assert_eq!(net.params()[0].blob.borrow_mut().data_vec(&mut dev), w0);

        // Grow back past the build batch: shapes and forward still work.
        net.reshape_batch(&mut dev, 6).unwrap();
        assert_eq!(net.blob("fc").unwrap().borrow().shape(), &[6, 3]);
        net.blob("data")
            .unwrap()
            .borrow_mut()
            .set_data(&mut dev, &vec![0.25; 6 * 64]);
        net.forward(&mut dev).unwrap();
        assert_eq!(
            net.blob("fc").unwrap().borrow_mut().data_vec(&mut dev).len(),
            18
        );
    }

    #[test]
    fn reshape_batch_requires_explicit_inputs() {
        let mut dev = CpuDevice::new();
        let param = parse_net(TINY_NET).unwrap();
        let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        assert!(net.reshape_batch(&mut dev, 4).is_err());
        assert!({
            let d = parse_net(
                r#"
name: "d"
input: "data"
input_shape { dim: 2 dim: 3 }
layer { name: "r" type: "ReLU" bottom: "data" top: "out" }
"#,
            )
            .unwrap();
            let mut n = Net::from_param(&d, Phase::Test, &mut dev).unwrap();
            n.reshape_batch(&mut dev, 0).is_err()
        });
    }

    #[test]
    fn deploy_inputs_create_blobs() {
        let text = r#"
name: "deploy"
input: "data"
input_shape { dim: 1 dim: 1 dim: 4 dim: 4 }
layer { name: "r" type: "ReLU" bottom: "data" top: "out" }
"#;
        let mut dev = CpuDevice::new();
        let param = parse_net(text).unwrap();
        let mut net = Net::from_param(&param, Phase::Test, &mut dev).unwrap();
        net.blob("data")
            .unwrap()
            .borrow_mut()
            .set_data(&mut dev, &[-1.0; 16]);
        net.forward(&mut dev).unwrap();
        assert_eq!(
            net.blob("out").unwrap().borrow_mut().data_vec(&mut dev),
            vec![0.0; 16]
        );
    }
}
