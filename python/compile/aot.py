"""AOT driver: manifest.json -> artifacts/<key>.hlo.txt.

The `.aocx`-compilation analogue: lower every manifest entry's jax
function (L2 graph calling L1 Pallas kernels) to **HLO text** and write it
next to the manifest. HLO *text* (not `.serialize()`) is the interchange
format because jax >= 0.5 emits protos with 64-bit instruction ids that
the rust side's xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).

Incremental: entries whose artifact already exists are skipped unless
--force. Python runs ONLY here — never on the rust request path.
"""

import argparse
import json
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from .model import build


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(key: str, spec: dict) -> str:
    fn, args = build(spec)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    # Guard: XLA's text printer ELIDES large dense constants ("..."),
    # silently corrupting the artifact. Kernels must build big tensors
    # from iotas instead of embedding numpy literals.
    if "..." in text:
        raise ValueError(
            f"{key}: HLO text contains an elided constant — rewrite the "
            "kernel to avoid large embedded literals"
        )
    return text


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--manifest", default="../artifacts/manifest.json")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default=None, help="substring filter on keys")
    args = ap.parse_args()

    with open(args.manifest) as f:
        manifest = json.load(f)
    entries = manifest["artifacts"]
    keys = sorted(entries)
    if args.only:
        keys = [k for k in keys if args.only in k]

    import os
    os.makedirs(args.out, exist_ok=True)
    done = skipped = failed = 0
    t0 = time.time()
    for i, key in enumerate(keys):
        path = os.path.join(args.out, f"{key}.hlo.txt")
        if not args.force and os.path.exists(path):
            skipped += 1
            continue
        try:
            text = lower_entry(key, entries[key])
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"[aot] FAILED {key}: {e}", file=sys.stderr)
            failed += 1
            continue
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
        done += 1
        if done % 50 == 0:
            rate = done / (time.time() - t0)
            eta = (len(keys) - i - 1) / max(rate, 1e-9)
            print(f"[aot] {done} lowered ({skipped} cached), eta {eta:.0f}s", flush=True)
    print(f"[aot] done: {done} lowered, {skipped} cached, {failed} failed, "
          f"{time.time()-t0:.1f}s")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
