//! ASCII table rendering for the paper-table benches.
//!
//! Produces aligned, pipe-delimited tables (markdown-compatible) so the
//! bench output can be pasted directly into EXPERIMENTS.md next to the
//! paper's numbers.

#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut width = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n", self.title));
        }
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }
}

/// Format milliseconds the way the paper's tables do (3 decimals).
pub fn ms(v: f64) -> String {
    format!("{v:.3}")
}

/// Format a ratio like "6.4x".
pub fn ratio(v: f64) -> String {
    format!("{v:.1}x")
}

/// Format a percentage like "77%".
pub fn pct(v: f64) -> String {
    format!("{:.0}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["Layer", "Forward", "Backward"]);
        t.row_strs(&["conv1", "20.269", "23.144"]);
        t.row_strs(&["fc8", "1.976", "5.603"]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        let lines: Vec<&str> = s.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
        // all body lines the same width
        assert_eq!(lines[1].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(1.23456), "1.235");
        assert_eq!(ratio(6.44), "6.4x");
        assert_eq!(pct(0.77), "77%");
    }
}
