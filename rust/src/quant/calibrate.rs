//! Post-training calibration: derive a versioned [`QuantSpec`] — static
//! per-kernel-shape operand ranges — by running a few fp32 batches
//! through the deploy net with a [`RangeObserver`] attached.
//!
//! The spec is keyed by [`quant_key`]: the kernel class and its
//! *batch-independent* dimensions. GEMM drops `m` (the batch dimension
//! of inner-product lowering), so one calibration batch size covers
//! every serving bucket; GEMV keeps both dimensions. At serve time the
//! backend looks its kernel up and quantizes with the calibrated
//! range — values outside it saturate, the standard static-quantization
//! contract.

use super::backend::{RangeMap, RangeObserver};
use crate::data::create_source;
use crate::device::cpu::CpuDevice;
use crate::device::{Device, Kernel};
use crate::net::{Net, WeightSnapshot};
use crate::proto::Phase;
use crate::util::prng::Pcg32;
use crate::zoo::DeployNet;
use std::collections::BTreeMap;

/// Container format version of `FEQSPEC1` payloads.
pub const QUANT_SPEC_VERSION: u32 = 1;

const QSPEC_MAGIC: &[u8; 8] = b"FEQSPEC1";

/// Static quantization ranges for one net: per-[`quant_key`] operand
/// (min, max) pairs, derived by [`calibrate`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QuantSpec {
    version: u32,
    net: String,
    entries: BTreeMap<String, [(f32, f32); 2]>,
}

impl QuantSpec {
    pub fn from_ranges(net: &str, ranges: RangeMap) -> QuantSpec {
        QuantSpec { version: QUANT_SPEC_VERSION, net: net.to_string(), entries: ranges }
    }

    pub fn version(&self) -> u32 {
        self.version
    }

    pub fn net(&self) -> &str {
        &self.net
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Calibrated `[A, B]` operand ranges for a kernel-shape key.
    pub fn ranges(&self, key: &str) -> Option<&[(f32, f32); 2]> {
        self.entries.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    /// Serialize as an `FEQSPEC1` container over `util::binio`.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        use crate::util::binio::{put_f32s, put_str, put_u32};
        use std::io::Write;
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
        w.write_all(QSPEC_MAGIC)?;
        put_u32(&mut w, self.version)?;
        put_str(&mut w, &self.net)?;
        put_u32(&mut w, self.entries.len() as u32)?;
        for (key, [(alo, ahi), (blo, bhi)]) in &self.entries {
            put_str(&mut w, key)?;
            put_f32s(&mut w, &[*alo, *ahi, *blo, *bhi])?;
        }
        Ok(())
    }

    /// Load an `FEQSPEC1` container (lengths bounded by file size).
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<QuantSpec> {
        use crate::util::binio::{get_f32s, get_str, get_u32};
        use std::io::Read;
        let file = std::fs::File::open(&path)?;
        let file_len = file.metadata()?.len() as usize;
        let mut r = std::io::BufReader::new(file);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == QSPEC_MAGIC, "not a FEQSPEC1 quant spec (bad magic)");
        let version = get_u32(&mut r)?;
        anyhow::ensure!(
            version == QUANT_SPEC_VERSION,
            "unsupported quant spec version {version} (expected {QUANT_SPEC_VERSION})"
        );
        let net = get_str(&mut r, file_len)?;
        let count = get_u32(&mut r)? as usize;
        anyhow::ensure!(
            count <= file_len / 20,
            "implausible entry count {count} for a {file_len}-byte container"
        );
        let mut entries = BTreeMap::new();
        for _ in 0..count {
            let key = get_str(&mut r, file_len)?;
            let v = get_f32s(&mut r, 4)?;
            anyhow::ensure!(
                v.iter().all(|x| x.is_finite()),
                "corrupt range for key '{key}'"
            );
            entries.insert(key, [(v[0], v[1]), (v[2], v[3])]);
        }
        Ok(QuantSpec { version, net, entries })
    }
}

/// Range-map key for a matmul kernel: class + batch-*independent* shape
/// dims. GEMM drops `m` (the batch dimension when inner products lower
/// to `GemmNT` at serving bucket sizes); GEMV keeps both. Non-matmul
/// kernels have no key (they are not quantized).
pub fn quant_key(kernel: &Kernel) -> Option<String> {
    match *kernel {
        Kernel::GemmNN { n, k, .. } => Some(format!("gemm_nn:n{n}:k{k}")),
        Kernel::GemmNT { n, k, .. } => Some(format!("gemm_nt:n{n}:k{k}")),
        Kernel::GemmTN { n, k, .. } => Some(format!("gemm_tn:n{n}:k{k}")),
        Kernel::Gemv { trans, m, n, .. } => {
            Some(format!("gemv:{}:m{m}:n{n}", if trans { "t" } else { "n" }))
        }
        _ => None,
    }
}

/// Run `batches` forwards of synthetic data through a fresh fp32 replica
/// of `dep` (adopting `weights` when given — calibrate on the weights
/// that will serve, i.e. the fake-quantized snapshot) and collect the
/// observed matmul operand ranges into a [`QuantSpec`].
pub fn calibrate(
    name: &str,
    dep: &DeployNet,
    weights: Option<&WeightSnapshot>,
    batches: usize,
    seed: u64,
) -> anyhow::Result<QuantSpec> {
    let observer = RangeObserver::new();
    let mut dev = CpuDevice::new().with_backend(Box::new(observer.clone()));
    let dev: &mut dyn Device = &mut dev;
    let mut net = Net::from_param(&dep.param, Phase::Test, dev)?;
    if let Some(snap) = weights {
        net.adopt_weights(dev, snap)?;
    }
    let [c, h, w] = dep.sample_shape;
    // Label distribution does not matter for a forward-only deploy net;
    // the source only has to produce representative input statistics.
    let source = create_source(if c == 1 { "digits" } else { "imagenet" }, c, h, w, 10)?;
    let input = net
        .blob(&dep.input)
        .ok_or_else(|| anyhow::anyhow!("input blob '{}' missing", dep.input))?;
    let mut rng = Pcg32::new(seed);
    for _ in 0..batches.max(1) {
        let batch = source.batch(&mut rng, dep.batch);
        input.borrow_mut().set_data(dev, &batch.data);
        net.forward(dev)?;
    }
    let ranges = observer.snapshot();
    anyhow::ensure!(
        !ranges.is_empty(),
        "calibration of '{name}' observed no matmul kernels"
    );
    Ok(QuantSpec::from_ranges(name, ranges))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_key_drops_gemm_batch_dim() {
        let k1 = quant_key(&Kernel::GemmNT { m: 1, n: 500, k: 800, alpha: 1.0, beta: 0.0 });
        let k64 = quant_key(&Kernel::GemmNT { m: 64, n: 500, k: 800, alpha: 1.0, beta: 0.0 });
        assert_eq!(k1, k64, "gemm key must be batch-independent");
        assert!(quant_key(&Kernel::ReluF { n: 4, slope: 0.0 }).is_none());
        let g = quant_key(&Kernel::Gemv { trans: true, m: 3, n: 5, alpha: 1.0, beta: 0.0 });
        assert_eq!(g.as_deref(), Some("gemv:t:m3:n5"));
    }

    #[test]
    fn spec_save_load_round_trip() {
        let mut ranges = RangeMap::new();
        ranges.insert("gemm_nn:n10:k20".to_string(), [(-1.5, 2.0), (0.0, 6.0)]);
        ranges.insert("gemv:n:m3:n5".to_string(), [(-0.25, 0.25), (-8.0, 8.0)]);
        let spec = QuantSpec::from_ranges("lenet", ranges);
        let dir = std::env::temp_dir().join(format!("feq_spec_{}", std::process::id()));
        let path = dir.join("lenet.feqspec");
        spec.save(&path).unwrap();
        let back = QuantSpec::load(&path).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.net(), "lenet");
        assert_eq!(back.version(), QUANT_SPEC_VERSION);
        assert_eq!(back.ranges("gemm_nn:n10:k20"), Some(&[(-1.5, 2.0), (0.0, 6.0)]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn calibrate_lenet_covers_every_matmul_layer() {
        let dep = crate::zoo::deploy_by_name("lenet", 4).unwrap();
        let spec = calibrate("lenet", &dep, None, 2, 7).unwrap();
        // LeNet deploy: conv1, conv2 (GemmNN), ip1, ip2 (GemmNT) → at
        // least 4 distinct matmul shapes.
        assert!(spec.len() >= 4, "only {} calibrated shapes", spec.len());
        for key in spec.keys() {
            let r = spec.ranges(key).unwrap();
            assert!(r[0].0 <= r[0].1 && r[1].0 <= r[1].1, "{key}: empty range");
        }
    }
}
