//! Native math substrate: the BLAS-ish library Caffe leans on (paper
//! Figure 1's "Math Functions → MKL/BLAS" box), implemented in Rust.
//!
//! Three roles:
//! 1. the CPU fallback device's compute (paper §3.3 / §5.2 workload
//!    partitioning),
//! 2. the correctness oracle every PJRT artifact is tested against,
//! 3. the numerical engine behind the FPGA simulator when an artifact is
//!    (deliberately) not generated for a shape.
//!
//! All tensors are dense row-major f32, matching both Caffe and the HLO
//! artifacts.

pub mod gemm;
pub mod blas1;
pub mod im2col;
pub mod pool;
pub mod lrn;
pub mod softmax;

pub use blas1::*;
pub use gemm::{gemm, gemv, Trans};
pub use im2col::{col2im, im2col, ConvGeom};
pub use lrn::*;
pub use pool::*;
pub use softmax::*;
