//! Integration: layer gradient checks (central finite differences on the
//! CPU device — caffe's own test style), device equivalence, and
//! GoogLeNet kernel accounting vs the paper.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::{Device, KClass};
use fecaffe::layers::{create_layer, shared, SharedBlob};
use fecaffe::blob::Blob;
use fecaffe::net::Net;
use fecaffe::proto::{parse_text, LayerParameter, Phase};
use fecaffe::util::prng::Pcg32;
use fecaffe::zoo;

fn layer_from(text: &str) -> Box<dyn fecaffe::layers::Layer> {
    let m = parse_text(text).unwrap();
    let lp = LayerParameter::from_message(m.msgs("layer").next().unwrap()).unwrap();
    create_layer(&lp, Phase::Train).unwrap()
}

/// Central-difference gradient check of a single-bottom single-top layer.
fn gradient_check(text: &str, bottom_shape: &[usize], tol: f32) {
    let mut dev = CpuDevice::new();
    let mut layer = layer_from(text);
    let bottom = shared(Blob::new("x", bottom_shape));
    let top = shared(Blob::new("y", &[1]));
    let mut rng = Pcg32::new(7);
    {
        let mut b = bottom.borrow_mut();
        let n = b.count();
        let mut data = vec![0f32; n];
        rng.fill_uniform(&mut data, -1.0, 1.0);
        b.set_data(&mut dev, &data);
    }
    let bots: Vec<SharedBlob> = vec![bottom.clone()];
    let tops: Vec<SharedBlob> = vec![top.clone()];
    layer.setup(&mut dev, &bots, &tops).unwrap();
    layer.forward(&mut dev, &bots, &tops).unwrap();
    // Random top_diff; objective = <top, td>.
    let tcount = top.borrow().count();
    let mut td = vec![0f32; tcount];
    rng.fill_uniform(&mut td, -1.0, 1.0);
    top.borrow_mut().set_diff(&mut dev, &td);
    layer.backward(&mut dev, &tops, &[true], &bots).unwrap();
    let analytic = bottom.borrow_mut().diff_vec(&mut dev);

    let eps = 1e-2f32;
    let base = bottom.borrow_mut().data_vec(&mut dev);
    for i in (0..base.len()).step_by((base.len() / 24).max(1)) {
        let mut obj = |v: f32| -> f32 {
            let mut d = base.clone();
            d[i] = v;
            bottom.borrow_mut().set_data(&mut dev, &d);
            layer.forward(&mut dev, &bots, &tops).unwrap();
            let t = top.borrow_mut().data_vec(&mut dev);
            t.iter().zip(td.iter()).map(|(a, b)| a * b).sum()
        };
        let fd = (obj(base[i] + eps) - obj(base[i] - eps)) / (2.0 * eps);
        assert!(
            (fd - analytic[i]).abs() <= tol * (1.0 + fd.abs().max(analytic[i].abs())),
            "grad mismatch at {i}: fd {fd} vs analytic {}",
            analytic[i]
        );
    }
    // restore
    bottom.borrow_mut().set_data(&mut dev, &base);
}

#[test]
fn gradient_check_convolution() {
    gradient_check(
        r#"layer { name: "c" type: "Convolution" bottom: "x" top: "y"
             convolution_param { num_output: 3 kernel_size: 3 pad: 1 stride: 2
               weight_filler { type: "xavier" } } }"#,
        &[2, 2, 5, 5],
        2e-2,
    );
}

#[test]
fn gradient_check_grouped_convolution() {
    gradient_check(
        r#"layer { name: "c" type: "Convolution" bottom: "x" top: "y"
             convolution_param { num_output: 4 kernel_size: 3 group: 2
               weight_filler { type: "gaussian" std: 0.3 } } }"#,
        &[1, 4, 6, 6],
        2e-2,
    );
}

#[test]
fn gradient_check_inner_product() {
    gradient_check(
        r#"layer { name: "f" type: "InnerProduct" bottom: "x" top: "y"
             inner_product_param { num_output: 5 weight_filler { type: "xavier" } } }"#,
        &[3, 7],
        2e-2,
    );
}

#[test]
fn gradient_check_pooling_ave() {
    gradient_check(
        r#"layer { name: "p" type: "Pooling" bottom: "x" top: "y"
             pooling_param { pool: AVE kernel_size: 3 stride: 2 } }"#,
        &[2, 2, 7, 7],
        1e-2,
    );
}

#[test]
fn gradient_check_lrn() {
    gradient_check(
        r#"layer { name: "n" type: "LRN" bottom: "x" top: "y"
             lrn_param { local_size: 3 alpha: 0.1 beta: 0.75 } }"#,
        &[1, 5, 3, 3],
        2e-2,
    );
}

#[test]
fn gradient_check_relu_separate() {
    gradient_check(
        r#"layer { name: "r" type: "ReLU" bottom: "x" top: "y" }"#,
        &[2, 10],
        1e-2,
    );
}

#[test]
fn fpga_and_cpu_nets_agree_on_every_zoo_small_net() {
    // LeNet + SqueezeNet at tiny batch: identical seeds → identical nets.
    for name in ["lenet", "squeezenet"] {
        let param = zoo::by_name(name, 1).unwrap();
        let mut cpu = CpuDevice::new();
        let mut net_c = Net::from_param(&param, Phase::Train, &mut cpu).unwrap();
        let loss_c = net_c.forward_backward(&mut cpu).unwrap();

        let mut fpga = FpgaSimDevice::new();
        let mut net_f = Net::from_param(&param, Phase::Train, &mut fpga).unwrap();
        let loss_f = net_f.forward_backward(&mut fpga).unwrap();
        assert!(
            (loss_c - loss_f).abs() < 1e-3,
            "{name}: cpu {loss_c} vs fpga {loss_f}"
        );
        // Gradients at the first conv also agree.
        let gc = net_c.params()[0].blob.borrow_mut().diff_vec(&mut cpu);
        let gf = net_f.params()[0].blob.borrow_mut().diff_vec(&mut fpga);
        let worst = gc
            .iter()
            .zip(gf.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(worst < 1e-3, "{name}: grad divergence {worst}");
    }
}

#[test]
fn googlenet_kernel_counts_match_paper_accounting() {
    // Paper Table 2 (batch 1 F→B): exact matches for the structural
    // counts our lowering shares with theirs.
    let mut dev = FpgaSimDevice::new();
    dev.timing_only = true;
    let param = zoo::by_name("googlenet", 1).unwrap();
    let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    net.forward(&mut dev).unwrap();
    dev.reset_timing();
    net.forward(&mut dev).unwrap();
    net.backward(&mut dev).unwrap();
    let stats = dev.profiler.stats();
    let count = |c: KClass| stats.get(&c).map(|s| s.instances).unwrap_or(0);
    assert_eq!(count(KClass::ReluF), 61, "paper: 61 ReLU_F");
    assert_eq!(count(KClass::ReluB), 61, "paper: 61 ReLU_B");
    assert_eq!(count(KClass::Concat), 72, "paper: 72 Concat");
    assert_eq!(count(KClass::Col2im), 19, "paper: 19 Col2im");
    assert_eq!(count(KClass::ReadBuffer), 3, "paper: 3 Read_Buffer (3 loss heads)");
    assert_eq!(count(KClass::MaxPoolF), 13, "paper: 13 Max_pool_F");
    assert_eq!(count(KClass::AvePoolF), 3, "paper: 3 Ave_pool_F");
    assert_eq!(count(KClass::DropoutF), 3, "paper: 3 Dropout_F");
    assert_eq!(count(KClass::Softmax), 3, "paper: 3 Softmax");
    // Gemm within a few % (186 in the paper; exact count depends on the
    // 1x1 fast path which the paper's fork lacked).
    let gemm = count(KClass::Gemm);
    assert!((180..=200).contains(&gemm), "gemm count {gemm}");
    let total = dev.profiler.total_instances();
    assert!((850..=1000).contains(&total), "total instances {total} (paper: 960)");
}

#[test]
fn vgg_fb_fits_2gb_but_training_does_not() {
    // Paper §4.4: VGG-16 F→B at batch 1 fits the 2 GB board (Table 1 has
    // its numbers) but *training* (solver history on top) does not.
    let param = zoo::by_name("vgg16", 1).unwrap();
    let mut dev = FpgaSimDevice::new();
    dev.timing_only = true;
    let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    net.forward_backward(&mut dev).unwrap();
    let peak = dev.ddr().peak();
    assert!(peak <= (2u64 << 30), "F->B peak {peak} B exceeds the board");

    // Training at any practical batch: activations + 553 MB SGD history
    // push past 2 GB (batch 1 peaks at 1.93 GB; batch 4 overflows).
    let param4 = zoo::by_name("vgg16", 4).unwrap();
    let mut dev4 = FpgaSimDevice::new();
    dev4.timing_only = true;
    let sp = zoo::default_solver("vgg16").unwrap();
    // OOM surfaces as Err from setup-time allocs or a panic from lazy
    // blob allocation (Caffe's CHECK-abort behaviour) — catch both.
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        Net::from_param(&param4, Phase::Train, &mut dev4)
            .and_then(|net| fecaffe::solver::Solver::new(sp, net, &mut dev4))
            .and_then(|mut s| s.step(&mut dev4).map(|_| ()))
    }));
    let failed = matches!(&r, Err(_)) || matches!(&r, Ok(Err(_)));
    assert!(failed, "vgg training should exceed 2 GB (paper: cannot be performed)");
}
