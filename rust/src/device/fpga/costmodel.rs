//! Analytic timing model of the Stratix 10 OpenCL board.
//!
//! Every constant here is an *input* justified by the paper's measured
//! microarchitectural ratios (cited inline); every table entry in the
//! benches is an *output* of network shapes × this model. See DESIGN.md §4.
//!
//! Per kernel invocation:
//!
//! ```text
//! t = t_launch + max( flops / (dsp_used × 2 × f_max),
//!                     bytes / (ddr_bw × ddr_eff(class)) )
//! ```
//!
//! PCIe transfers: `t = bytes / pcie_eff_bw + t_setup`.

use crate::device::{KClass, Kernel};
use crate::quant::Precision;

/// Board-level constants (paper Table 3/4 and §4.2).
#[derive(Debug, Clone)]
pub struct BoardParams {
    /// DDR4 peak at 300 MHz controller clock: 14 928 MB/s (paper §4.2).
    pub ddr_bw_bytes_per_s: f64,
    /// Achieved kernel clock after placement: 252–253 MHz (Table 3).
    pub fmax_hz: f64,
    /// Effective PCIe write bandwidth: measured 1.906 GB/s, i.e. 12 % of
    /// Gen3 x16 (paper §4.2).
    pub pcie_bw_bytes_per_s: f64,
    /// Per-transfer PCIe/driver setup latency.
    pub pcie_setup_s: f64,
    /// Host runtime overhead per kernel launch. Derived from the paper:
    /// 960 invocations account for the 30 % non-kernel share of the
    /// 857.8 ms F→B (§4.2) ⇒ ≈ 0.27 ms per invocation.
    pub launch_overhead_s: f64,
    /// Fixed kernel start latency on the device (command-queue to first
    /// work-item).
    pub kernel_start_s: f64,
    /// Device DDR capacity: 2 GB (Table 4) — the reason VGG training does
    /// not fit (paper §4.4).
    pub ddr_capacity_bytes: u64,
}

impl Default for BoardParams {
    fn default() -> Self {
        BoardParams {
            ddr_bw_bytes_per_s: 14_928.0e6,
            fmax_hz: 253.0e6,
            pcie_bw_bytes_per_s: 1.906e9,
            pcie_setup_s: 8.0e-6,
            launch_overhead_s: 0.27e-3,
            kernel_start_s: 10.0e-6,
            ddr_capacity_bytes: 2 * 1024 * 1024 * 1024,
        }
    }
}

/// DDR efficiency per kernel class: the fraction of peak DDR bandwidth the
/// kernel's access pattern sustains. Values are the paper's own dynamic
/// measurements (Table 2, "Efficiency" column); classes the paper doesn't
/// list inherit the nearest access-pattern sibling.
pub fn ddr_efficiency(class: KClass) -> f64 {
    match class {
        KClass::Gemm => 0.77,        // 2-D local-memory tiling (Table 2)
        KClass::Gemv => 0.81,        // 1-D local buffer (Table 2)
        KClass::Im2col => 0.42,      // strided gather (Table 2)
        KClass::Col2im => 0.54,      // strided scatter+acc (Table 2)
        KClass::MaxPoolF => 0.60,    // windowed streaming (Table 2)
        KClass::MaxPoolB => 0.62,
        KClass::AvePoolF => 0.39,
        KClass::AvePoolB => 0.36,
        KClass::ReluF => 0.10,       // short bursts, launch-bound (Table 2)
        KClass::ReluB => 0.17,
        KClass::LrnScale => 0.34,
        KClass::LrnOutput => 0.16,
        KClass::LrnDiff => 0.43,
        KClass::DropoutF => 0.10,
        KClass::DropoutB => 0.10,
        KClass::Bias => 0.12,
        KClass::Softmax => 0.05,     // paper rounds to 0 %
        KClass::SoftmaxLossF => 0.05,
        KClass::SoftmaxLossB => 0.05,
        KClass::Concat => 0.10,
        KClass::Split => 0.11,
        KClass::Add => 0.17,
        KClass::Asum => 0.05,
        KClass::Axpy => 0.20,
        KClass::Scal => 0.11,
        KClass::Eltwise => 0.15,
        KClass::Solver => 0.20,      // axpy-like streaming
        KClass::WriteBuffer | KClass::ReadBuffer => 1.0, // PCIe handled separately
    }
}

/// DSPs dedicated to each kernel class in the bitstream (Table 3: gemm
/// 1037, gemv 130; the remaining 629 of the 1796 total are shared across
/// the streaming kernels — we give each a small fixed lane count).
pub fn dsp_used(class: KClass) -> u32 {
    match class {
        KClass::Gemm => 1037,
        KClass::Gemv => 130,
        KClass::LrnScale | KClass::LrnOutput | KClass::LrnDiff => 64,
        KClass::Softmax | KClass::SoftmaxLossF | KClass::SoftmaxLossB => 16,
        KClass::Solver => 32,
        _ => 48,
    }
}

#[derive(Debug, Clone, Default)]
pub struct CostModel {
    pub board: BoardParams,
    /// Numeric precision of the modeled bitstream. `Fp32` (the default)
    /// reproduces the paper's measured board exactly; reduced precisions
    /// re-rate the matmul engines at their SIMD-lane packing advantage
    /// and scale *every* kernel's DDR traffic by the element width.
    pub precision: Precision,
}

impl CostModel {
    pub fn new() -> CostModel {
        CostModel::default()
    }

    /// Builder: model a bitstream compiled at `precision`.
    pub fn with_precision(mut self, precision: Precision) -> CostModel {
        self.precision = precision;
        self
    }

    /// Device-side execution time of one kernel invocation, in ns
    /// (excludes host launch overhead).
    ///
    /// Precision enters in two places: compute throughput of the
    /// DSP-bound matmul engines scales by the lane multiplier (int8
    /// packs 4 MACs where fp32 fits 1 — the standard Stratix 10 DSP
    /// `int9×9` packing ratio), and DDR bytes scale by `elem_bytes/4`
    /// for *all* classes, since a quantized bitstream stores weights and
    /// activations narrow end-to-end.
    pub fn kernel_time_ns(&self, kernel: &Kernel) -> u64 {
        let class = kernel.class();
        let lanes = self.precision.lane_multiplier(class);
        let width_ratio = self.precision.elem_bytes() as f64 / 4.0;
        let flops = kernel.flops() as f64;
        let bytes = kernel.bytes() as f64 * width_ratio;
        let compute_s =
            flops / (f64::from(dsp_used(class)) * 2.0 * self.board.fmax_hz * lanes);
        let memory_s = bytes / (self.board.ddr_bw_bytes_per_s * ddr_efficiency(class));
        ((self.board.kernel_start_s + compute_s.max(memory_s)) * 1e9) as u64
    }

    /// Host-side launch overhead per invocation, ns.
    pub fn launch_overhead_ns(&self) -> u64 {
        (self.board.launch_overhead_s * 1e9) as u64
    }

    /// PCIe transfer time for `bytes`, ns.
    pub fn pcie_time_ns(&self, bytes: u64) -> u64 {
        ((self.board.pcie_setup_s + bytes as f64 / self.board.pcie_bw_bytes_per_s) * 1e9)
            as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Kernel;

    #[test]
    fn gemm_is_compute_or_memory_bound_sensibly() {
        let cm = CostModel::new();
        // Big square gemm: compute-bound (arith intensity high).
        let big = Kernel::GemmNN { m: 1024, n: 1024, k: 1024, alpha: 1.0, beta: 0.0 };
        let t_big = cm.kernel_time_ns(&big) as f64 * 1e-9;
        let flops = big.flops() as f64;
        let peak = 1037.0 * 2.0 * cm.board.fmax_hz;
        assert!((t_big - (flops / peak + cm.board.kernel_start_s)).abs() / t_big < 0.05);

        // Skinny gemv-like gemm: memory-bound.
        let skinny = Kernel::GemmNN { m: 1, n: 1000, k: 4096, alpha: 1.0, beta: 0.0 };
        let t_skinny = cm.kernel_time_ns(&skinny) as f64 * 1e-9;
        let mem = skinny.bytes() as f64 / (cm.board.ddr_bw_bytes_per_s * 0.77);
        assert!((t_skinny - (mem + cm.board.kernel_start_s)).abs() / t_skinny < 0.05);
    }

    #[test]
    fn monotone_in_size() {
        let cm = CostModel::new();
        let mut last = 0;
        for n in [1_000usize, 10_000, 100_000, 1_000_000] {
            let t = cm.kernel_time_ns(&Kernel::ReluF { n, slope: 0.0 });
            assert!(t > last);
            last = t;
        }
    }

    #[test]
    fn gemm_average_instance_time_matches_paper_scale() {
        // Paper Table 2: 186 gemm instances → 58.4 ms total → ~0.31 ms avg
        // for GoogLeNet batch-1 gemms. A representative inception gemm
        // (128 out-ch, 3x3 over 28x28 with 128 in-ch) should be same order.
        let cm = CostModel::new();
        let g = Kernel::GemmNN { m: 128, n: 784, k: 1152, alpha: 1.0, beta: 0.0 };
        let t_ms = cm.kernel_time_ns(&g) as f64 / 1e6;
        assert!(
            (0.05..2.0).contains(&t_ms),
            "gemm instance {t_ms} ms out of paper's order of magnitude"
        );
    }

    #[test]
    fn pcie_write_speed_matches_measured() {
        let cm = CostModel::new();
        // 1 MB at 1.906 GB/s ≈ 524 µs + setup
        let t = cm.pcie_time_ns(1_000_000) as f64 / 1e3;
        assert!((t - (1e6 / 1.906e9 * 1e6 + 8.0)).abs() < 2.0, "{t} us");
    }

    #[test]
    fn launch_overhead_is_paper_scale() {
        let cm = CostModel::new();
        let us = cm.launch_overhead_ns() as f64 / 1e3;
        assert!((200.0..400.0).contains(&us));
    }

    #[test]
    fn int8_speeds_up_compute_bound_gemm_by_lane_ratio() {
        let fp32 = CostModel::new();
        let int8 = CostModel::new().with_precision(Precision::Int8);
        // Compute-bound gemm: the 4× lane packing should show ~4× once
        // the fixed kernel-start latency is subtracted.
        let g = Kernel::GemmNN { m: 1024, n: 1024, k: 1024, alpha: 1.0, beta: 0.0 };
        let start = (fp32.board.kernel_start_s * 1e9) as u64;
        let t32 = fp32.kernel_time_ns(&g) - start;
        let t8 = int8.kernel_time_ns(&g) - start;
        let ratio = t32 as f64 / t8 as f64;
        assert!((3.8..4.2).contains(&ratio), "int8 gemm speedup {ratio}");
        let fp16 = CostModel::new().with_precision(Precision::Fp16);
        let t16 = fp16.kernel_time_ns(&g) - start;
        let r16 = t32 as f64 / t16 as f64;
        assert!((1.9..2.1).contains(&r16), "fp16 gemm speedup {r16}");
    }

    #[test]
    fn int8_quarters_memory_bound_traffic_everywhere() {
        let fp32 = CostModel::new();
        let int8 = CostModel::new().with_precision(Precision::Int8);
        let start = (fp32.board.kernel_start_s * 1e9) as u64;
        // A streaming kernel gets no lane boost but moves 1/4 the bytes.
        let relu = Kernel::ReluF { n: 10_000_000, slope: 0.0 };
        let r = (fp32.kernel_time_ns(&relu) - start) as f64
            / (int8.kernel_time_ns(&relu) - start) as f64;
        assert!((3.8..4.2).contains(&r), "int8 relu byte ratio {r}");
        // Memory-bound skinny gemm also rides the byte reduction.
        let skinny = Kernel::GemmNN { m: 1, n: 1000, k: 4096, alpha: 1.0, beta: 0.0 };
        let r = (fp32.kernel_time_ns(&skinny) - start) as f64
            / (int8.kernel_time_ns(&skinny) - start) as f64;
        assert!((3.5..4.2).contains(&r), "int8 skinny gemm ratio {r}");
    }

    #[test]
    fn fp32_precision_is_the_identity_model() {
        let base = CostModel::new();
        let explicit = CostModel::new().with_precision(Precision::Fp32);
        for k in [
            Kernel::GemmNN { m: 64, n: 784, k: 1152, alpha: 1.0, beta: 0.0 },
            Kernel::Gemv { trans: false, m: 1000, n: 4096, alpha: 1.0, beta: 0.0 },
            Kernel::ReluF { n: 100_352, slope: 0.0 },
        ] {
            assert_eq!(base.kernel_time_ns(&k), explicit.kernel_time_ns(&k));
        }
    }
}
