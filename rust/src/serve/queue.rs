//! Bounded blocking MPMC queue (Mutex + Condvar, std-only).
//!
//! `std::sync::mpsc` receivers are single-consumer, but the serving
//! engine needs one dispatch queue drained by many workers and one
//! admission queue that rejects (rather than grows) under overload —
//! so this small queue implements both, plus the close-then-drain
//! protocol graceful shutdown relies on: after `close`, producers fail
//! fast while consumers keep popping until the queue is empty.
//!
//! Every lock acquisition here is poison-tolerant (`lock_unpoisoned`):
//! the queue's state is valid at every await point, so a worker panic
//! elsewhere in the pool must degrade that one batch — never cascade a
//! poisoned mutex into every producer and consumer of the pipeline.

use super::lock_unpoisoned;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

pub struct SharedQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct State<T> {
    items: VecDeque<T>,
    capacity: usize,
    closed: bool,
    /// Deepest the queue has ever been — the high-water gauge the
    /// autoscaling roadmap item reads (observable via `high_water`).
    high_water: usize,
}

/// Why a non-blocking push failed (the item is handed back).
pub enum PushError<T> {
    Full(T),
    Closed(T),
}

/// Outcome of a deadline-bounded pop.
pub enum Pop<T> {
    Item(T),
    /// Deadline passed with the queue still empty.
    TimedOut,
    /// Queue closed and fully drained.
    Closed,
}

impl<T> SharedQueue<T> {
    pub fn new(capacity: usize) -> SharedQueue<T> {
        SharedQueue {
            state: Mutex::new(State {
                items: VecDeque::new(),
                capacity: capacity.max(1),
                closed: false,
                high_water: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Non-blocking push — the admission-control path. On success
    /// returns the queue depth *including* the pushed item, so callers
    /// can export a depth gauge without re-taking the lock.
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut s = lock_unpoisoned(&self.state);
        if s.closed {
            return Err(PushError::Closed(item));
        }
        if s.items.len() >= s.capacity {
            return Err(PushError::Full(item));
        }
        s.items.push_back(item);
        let depth = s.items.len();
        s.high_water = s.high_water.max(depth);
        drop(s);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Blocking push; `Err(item)` if the queue closed while waiting.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut s = lock_unpoisoned(&self.state);
        loop {
            if s.closed {
                return Err(item);
            }
            if s.items.len() < s.capacity {
                break;
            }
            s = self.not_full.wait(s).unwrap_or_else(|p| p.into_inner());
        }
        s.items.push_back(item);
        let depth = s.items.len();
        s.high_water = s.high_water.max(depth);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = lock_unpoisoned(&self.state);
        loop {
            if let Some(item) = s.items.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Pop with a deadline — the micro-batch linger wait.
    pub fn pop_until(&self, deadline: Instant) -> Pop<T> {
        let mut s = lock_unpoisoned(&self.state);
        loop {
            if let Some(item) = s.items.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Pop::Item(item);
            }
            if s.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, _) = self
                .not_empty
                .wait_timeout(s, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            s = guard;
        }
    }

    /// Close the queue: wake every waiter. Producers fail from here on;
    /// consumers keep draining until empty.
    pub fn close(&self) {
        let mut s = lock_unpoisoned(&self.state);
        s.closed = true;
        drop(s);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.state).items.len()
    }

    /// Deepest the queue has ever been (monotone; survives drains).
    pub fn high_water(&self) -> usize {
        lock_unpoisoned(&self.state).high_water
    }

    /// Cheap admission pre-check. Racy by design — `try_push` still
    /// enforces the bound — and false when closed so the closed case
    /// surfaces as Closed, not Full.
    pub fn is_full(&self) -> bool {
        let s = lock_unpoisoned(&self.state);
        !s.closed && s.items.len() >= s.capacity
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        lock_unpoisoned(&self.state).closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_and_capacity() {
        let q = SharedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        match q.try_push(3) {
            Err(PushError::Full(v)) => assert_eq!(v, 3),
            _ => panic!("expected Full"),
        }
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn try_push_returns_depth_and_tracks_high_water() {
        let q = SharedQueue::new(4);
        assert!(matches!(q.try_push(1), Ok(1)));
        assert!(matches!(q.try_push(2), Ok(2)));
        assert_eq!(q.high_water(), 2);
        q.pop();
        q.pop();
        // Draining never lowers the high-water mark…
        assert_eq!(q.high_water(), 2);
        // …and pushing back below it leaves it alone.
        assert!(matches!(q.try_push(3), Ok(1)));
        assert_eq!(q.high_water(), 2);
    }

    #[test]
    fn close_drains_then_stops() {
        let q = SharedQueue::new(8);
        q.try_push(1).ok();
        q.try_push(2).ok();
        q.close();
        match q.try_push(3) {
            Err(PushError::Closed(v)) => assert_eq!(v, 3),
            _ => panic!("expected Closed"),
        }
        // Consumers still drain what was admitted.
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_until_times_out_then_delivers() {
        let q = SharedQueue::new(4);
        let deadline = Instant::now() + Duration::from_millis(10);
        match q.pop_until(deadline) {
            Pop::TimedOut => {}
            _ => panic!("expected TimedOut"),
        }
        q.try_push(7).ok();
        match q.pop_until(Instant::now() + Duration::from_millis(10)) {
            Pop::Item(v) => assert_eq!(v, 7),
            _ => panic!("expected Item"),
        }
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q = Arc::new(SharedQueue::<u32>::new(1));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(t.join().unwrap(), None);
    }

    /// A thread panicking while holding the queue mutex poisons it;
    /// every queue operation must keep working through the poison
    /// instead of cascading the panic pool-wide (satellite audit).
    #[test]
    fn queue_operations_survive_a_poisoned_mutex() {
        let q = Arc::new(SharedQueue::new(4));
        let q2 = q.clone();
        let _ = std::thread::spawn(move || {
            let _guard = q2.state.lock().unwrap();
            panic!("poison the queue mutex");
        })
        .join();
        assert!(q.state.lock().is_err(), "precondition: mutex is poisoned");
        assert!(matches!(q.try_push(1), Ok(1)));
        q.push(2).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.high_water(), 2);
        assert!(!q.is_full());
        assert_eq!(q.pop(), Some(1));
        match q.pop_until(Instant::now() + Duration::from_millis(5)) {
            Pop::Item(v) => assert_eq!(v, 2),
            _ => panic!("expected Item through the poisoned lock"),
        }
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn multi_producer_multi_consumer() {
        let q = Arc::new(SharedQueue::new(4));
        let mut producers = Vec::new();
        for p in 0..4u32 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..25u32 {
                    q.push(p * 100 + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap().len()).sum();
        assert_eq!(total, 100);
    }
}
