//! Concat layer (channel axis) — GoogLeNet's inception joiner. One
//! `Concat` kernel invocation per bottom per direction: 9 inceptions × 4
//! branches × (fwd+bwd) = the paper's 72 Concat instances.

use super::{Layer, SharedBlob};
use crate::device::{Device, Kernel, KernelCall};
use crate::proto::LayerParameter;

pub struct ConcatLayer {
    name: String,
    axis: usize,
    num: usize,
    /// channels*dim of each bottom and their channel-offsets in the top.
    sizes: Vec<usize>,
    offsets: Vec<usize>,
    total: usize,
}

impl ConcatLayer {
    pub fn new(param: &LayerParameter) -> ConcatLayer {
        ConcatLayer {
            name: param.name.clone(),
            axis: param.concat.as_ref().map(|c| c.axis).unwrap_or(1),
            num: 0,
            sizes: Vec::new(),
            offsets: Vec::new(),
            total: 0,
        }
    }
}

impl Layer for ConcatLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "Concat"
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(self.axis == 1, "concat: only channel axis supported");
        anyhow::ensure!(!bottoms.is_empty());
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let first = bottoms[0].borrow();
        let (num, h, w) = (first.num(), first.height(), first.width());
        drop(first);
        self.num = num;
        let mut channels = 0;
        self.sizes.clear();
        self.offsets.clear();
        for b in bottoms {
            let bb = b.borrow();
            anyhow::ensure!(
                bb.num() == num && bb.height() == h && bb.width() == w,
                "concat {}: inconsistent bottom shapes",
                self.name
            );
            self.offsets.push(channels * h * w);
            self.sizes.push(bb.channels() * h * w);
            channels += bb.channels();
        }
        self.total = channels * h * w;
        tops[0]
            .borrow_mut()
            .reshape_grow_only(dev, &[num, channels, h, w]);
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let t_id = tops[0].borrow_mut().data.dev_data_mut(dev);
        for (i, b) in bottoms.iter().enumerate() {
            let b_id = b.borrow_mut().data.dev_data(dev);
            dev.launch(&KernelCall::new(
                Kernel::ConcatF {
                    num: self.num,
                    this: self.sizes[i],
                    total: self.total,
                    offset: self.offsets[i],
                },
                &[b_id],
                &[t_id],
            ))?;
        }
        Ok(0.0)
    }

    fn backward(
        &mut self,
        dev: &mut dyn Device,
        tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let td_id = tops[0].borrow_mut().diff.dev_data(dev);
        for (i, b) in bottoms.iter().enumerate() {
            if !prop_down.get(i).copied().unwrap_or(true) {
                continue;
            }
            let bd_id = b.borrow_mut().diff.dev_data_mut(dev);
            dev.launch(&KernelCall::new(
                Kernel::ConcatB {
                    num: self.num,
                    this: self.sizes[i],
                    total: self.total,
                    offset: self.offsets[i],
                },
                &[td_id],
                &[bd_id],
            ))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::Blob;
    use crate::device::cpu::CpuDevice;

    #[test]
    fn concat_and_deconcat_two_branches() {
        let mut dev = CpuDevice::new();
        let mut layer = ConcatLayer::new(&LayerParameter::new("cat", "Concat"));
        let a = super::super::shared(Blob::new("a", &[2, 1, 1, 2]));
        let b = super::super::shared(Blob::new("b", &[2, 2, 1, 2]));
        let top = super::super::shared(Blob::new("t", &[1]));
        a.borrow_mut().set_data(&mut dev, &[1.0, 2.0, 11.0, 12.0]);
        b.borrow_mut()
            .set_data(&mut dev, &[3.0, 4.0, 5.0, 6.0, 13.0, 14.0, 15.0, 16.0]);
        layer
            .setup(&mut dev, &[a.clone(), b.clone()], &[top.clone()])
            .unwrap();
        assert_eq!(top.borrow().shape(), &[2, 3, 1, 2]);
        layer
            .forward(&mut dev, &[a.clone(), b.clone()], &[top.clone()])
            .unwrap();
        assert_eq!(
            top.borrow_mut().data_vec(&mut dev),
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 11.0, 12.0, 13.0, 14.0, 15.0, 16.0]
        );
        let td: Vec<f32> = (0..12).map(|v| v as f32).collect();
        top.borrow_mut().set_diff(&mut dev, &td);
        layer
            .backward(&mut dev, &[top], &[true, true], &[a.clone(), b.clone()])
            .unwrap();
        assert_eq!(a.borrow_mut().diff_vec(&mut dev), vec![0.0, 1.0, 6.0, 7.0]);
        assert_eq!(
            b.borrow_mut().diff_vec(&mut dev),
            vec![2.0, 3.0, 4.0, 5.0, 8.0, 9.0, 10.0, 11.0]
        );
    }
}
