//! Integration: dynamic-shape execution (ISSUE 5).
//!
//! The contract under test:
//!
//! * `Net::reshape_batch(k)` outputs are bit-identical to a *fresh*
//!   batch-k net with the same weights, for k ∈ {1, 3, max}, on both
//!   the CPU and the FPGA-sim device;
//! * a grow → shrink → grow reshape cycle reproduces the original
//!   full-batch outputs bit-for-bit (grow-only activations never
//!   corrupt a later larger batch);
//! * the serving engine's single shape-polymorphic replica serves a
//!   partial batch bit-identically to a fixed batch-k net, and the
//!   occupancy accounting reflects the bucketed rows it executed.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::Device;
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::serve::{DeviceKind, Engine, EngineConfig};
use fecaffe::util::prng::Pcg32;
use fecaffe::zoo;
use std::time::Duration;

fn mk_device(kind: DeviceKind) -> Box<dyn Device> {
    match kind {
        DeviceKind::Cpu => Box::new(CpuDevice::new()),
        DeviceKind::FpgaSim => Box::new(FpgaSimDevice::new()),
    }
}

fn random_samples(n: usize, len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    let mut v = vec![0f32; n * len];
    rng.fill_uniform(&mut v, 0.0, 1.0);
    v
}

/// Forward the first `k` samples through `net` (already shaped for
/// batch k) and return the output rows.
fn forward_k(
    dev: &mut dyn Device,
    net: &mut Net,
    dep: &zoo::DeployNet,
    samples: &[f32],
    k: usize,
) -> Vec<f32> {
    let input = net.blob(&dep.input).unwrap();
    input
        .borrow_mut()
        .set_data(dev, &samples[..k * dep.sample_len]);
    net.forward(dev).unwrap();
    let out = net.blob(&dep.output).unwrap();
    let v = out.borrow_mut().data_vec(dev);
    v
}

fn reshape_matches_fresh_net(kind: DeviceKind) {
    let max = 8usize;
    let dep = zoo::deploy_by_name("lenet", max).unwrap();
    let mut dev = mk_device(kind);
    let mut net = Net::from_param(&dep.param, Phase::Test, dev.as_mut()).unwrap();
    let snap = net.share_weights(dev.as_mut());
    let samples = random_samples(max, dep.sample_len, 99);

    for &k in &[1usize, 3, max] {
        net.reshape_batch(dev.as_mut(), k).unwrap();
        let got = forward_k(dev.as_mut(), &mut net, &dep, &samples, k);
        assert_eq!(got.len(), k * 10, "batch {k}: output row count");

        // Reference: a *fresh* net built at batch k with the same weights.
        let dep_k = zoo::deploy_by_name("lenet", k).unwrap();
        let mut dev_f = mk_device(kind);
        let mut fresh = Net::from_param(&dep_k.param, Phase::Test, dev_f.as_mut()).unwrap();
        fresh.adopt_weights(dev_f.as_mut(), &snap).unwrap();
        let want = forward_k(dev_f.as_mut(), &mut fresh, &dep_k, &samples, k);
        assert_eq!(got, want, "batch {k}: reshaped net diverged from fresh net");
    }
}

#[test]
fn reshape_batch_matches_fresh_net_on_cpu() {
    reshape_matches_fresh_net(DeviceKind::Cpu);
}

#[test]
fn reshape_batch_matches_fresh_net_on_fpga_sim() {
    reshape_matches_fresh_net(DeviceKind::FpgaSim);
}

/// Grow → shrink → grow: after cycling through smaller batches, the
/// full-batch forward must reproduce its original outputs exactly —
/// grow-only activations and the rebucketed scratch never leak state
/// into a later shape.
#[test]
fn grow_shrink_grow_cycle_is_exact() {
    let max = 8usize;
    let dep = zoo::deploy_by_name("lenet", max).unwrap();
    let mut dev = CpuDevice::new();
    let mut net = Net::from_param(&dep.param, Phase::Test, &mut dev).unwrap();
    let samples = random_samples(max, dep.sample_len, 5);

    let full_before = forward_k(&mut dev, &mut net, &dep, &samples, max);

    net.reshape_batch(&mut dev, 1).unwrap();
    let one = forward_k(&mut dev, &mut net, &dep, &samples, 1);
    // Per-sample math is batch-invariant: row 0 matches the full batch.
    assert_eq!(one, full_before[..10].to_vec());

    net.reshape_batch(&mut dev, 3).unwrap();
    let three = forward_k(&mut dev, &mut net, &dep, &samples, 3);
    assert_eq!(three, full_before[..30].to_vec());

    net.reshape_batch(&mut dev, max).unwrap();
    let full_after = forward_k(&mut dev, &mut net, &dep, &samples, max);
    assert_eq!(full_after, full_before, "grow-shrink-grow changed bits");
}

fn engine_partial_batch_matches_fixed_net(kind: DeviceKind) {
    let k = 3usize;
    let max_batch = 8usize;
    let param = zoo::by_name("lenet", 1).unwrap();
    let engine = Engine::new(
        &param,
        EngineConfig {
            workers: 1,
            max_batch,
            max_linger: Duration::from_millis(200),
            queue_capacity: 64,
            device: kind,
            intra_op_threads: 1,
            trace_sample: 0,
            ..EngineConfig::default()
        },
    )
    .unwrap();

    let samples = random_samples(k, engine.sample_len(), 21);
    let handles: Vec<_> = samples
        .chunks(engine.sample_len())
        .map(|s| engine.submit(s.to_vec()).unwrap())
        .collect();
    let got: Vec<Vec<f32>> = handles
        .into_iter()
        .map(|h| h.wait().unwrap().values)
        .collect();
    engine.shutdown();

    // Occupancy accounting: 3 filled rows; the replica executed the
    // bucketed rows for however the batcher coalesced them (one batch of
    // 3 buckets to 4), always strictly fewer than pad-to-max.
    let m = engine.metrics().snapshot();
    assert_eq!(m.filled_rows, k as u64);
    assert!(m.executed_rows >= k as u64);
    assert!(
        m.executed_rows < m.batches * max_batch as u64,
        "executed {} rows across {} batches — worker still pads to max_batch",
        m.executed_rows,
        m.batches
    );
    assert!(m.batch_occupancy > 0.0 && m.batch_occupancy <= 1.0);

    // Reference: a fixed batch-k net on the same device kind adopting
    // the engine's weights; responses must match bit for bit.
    let dep_k = zoo::deploy_by_name("lenet", k).unwrap();
    let mut dev = mk_device(kind);
    let mut fixed = Net::from_param(&dep_k.param, Phase::Test, dev.as_mut()).unwrap();
    fixed.adopt_weights(dev.as_mut(), &engine.weights()).unwrap();
    let want = forward_k(dev.as_mut(), &mut fixed, &dep_k, &samples, k);
    for (i, row) in got.iter().enumerate() {
        assert_eq!(
            row,
            &want[i * 10..(i + 1) * 10],
            "sample {i}: dynamic batch diverged from fixed batch-{k} net"
        );
    }
}

#[test]
fn engine_partial_batch_matches_fixed_net_on_cpu() {
    engine_partial_batch_matches_fixed_net(DeviceKind::Cpu);
}

#[test]
fn engine_partial_batch_matches_fixed_net_on_fpga_sim() {
    engine_partial_batch_matches_fixed_net(DeviceKind::FpgaSim);
}
