//! Level-1 BLAS + elementwise kernels (paper Table 2: `Add`, `Asum`,
//! `Axpy`, `Scale`, `ReLU_F/B`, `Dropout_F/B`, `Bias`, ...). These are the
//! "BLAS-related" kernel group of the paper's L1 layer.

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * xv;
    }
}

/// y = alpha * x + beta * y
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x.iter()) {
        *yv = alpha * xv + beta * *yv;
    }
}

/// x *= alpha
pub fn scal(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// sum of |x|
pub fn asum(x: &[f32]) -> f32 {
    x.iter().map(|v| v.abs()).sum()
}

/// dot product
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y.iter()).map(|(a, b)| a * b).sum()
}

/// z = x + y (paper's `Add` kernel — eltwise sum used by Split backward)
pub fn add(x: &[f32], y: &[f32], z: &mut [f32]) {
    assert!(x.len() == y.len() && y.len() == z.len());
    for i in 0..z.len() {
        z[i] = x[i] + y[i];
    }
}

/// z = x * y elementwise
pub fn mul(x: &[f32], y: &[f32], z: &mut [f32]) {
    assert!(x.len() == y.len() && y.len() == z.len());
    for i in 0..z.len() {
        z[i] = x[i] * y[i];
    }
}

/// y = x^p elementwise
pub fn powx(x: &[f32], p: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x.iter()) {
        *yv = xv.powf(p);
    }
}

pub fn set(x: &mut [f32], value: f32) {
    for v in x.iter_mut() {
        *v = value;
    }
}

/// ReLU forward: top = max(bottom, 0) + slope * min(bottom, 0)
pub fn relu_forward(bottom: &[f32], top: &mut [f32], negative_slope: f32) {
    assert_eq!(bottom.len(), top.len());
    for (t, &b) in top.iter_mut().zip(bottom.iter()) {
        *t = if b > 0.0 { b } else { negative_slope * b };
    }
}

/// ReLU backward: bottom_diff = top_diff * (bottom > 0 ? 1 : slope)
pub fn relu_backward(
    bottom_data: &[f32],
    top_diff: &[f32],
    bottom_diff: &mut [f32],
    negative_slope: f32,
) {
    assert!(bottom_data.len() == top_diff.len() && top_diff.len() == bottom_diff.len());
    for i in 0..bottom_diff.len() {
        bottom_diff[i] = top_diff[i]
            * if bottom_data[i] > 0.0 {
                1.0
            } else {
                negative_slope
            };
    }
}

/// Dropout forward (train): top = bottom * mask * scale, mask ∈ {0,1}.
/// The mask is produced host-side (Caffe does the same with its RNG) and
/// passed in so forward/backward agree.
pub fn dropout_forward(bottom: &[f32], mask: &[f32], scale: f32, top: &mut [f32]) {
    assert!(bottom.len() == mask.len() && mask.len() == top.len());
    for i in 0..top.len() {
        top[i] = bottom[i] * mask[i] * scale;
    }
}

pub fn dropout_backward(top_diff: &[f32], mask: &[f32], scale: f32, bottom_diff: &mut [f32]) {
    assert!(top_diff.len() == mask.len() && mask.len() == bottom_diff.len());
    for i in 0..bottom_diff.len() {
        bottom_diff[i] = top_diff[i] * mask[i] * scale;
    }
}

/// Bias forward (paper's `Bias` kernel): top[n,c,h,w] += bias[c].
/// `dim` = spatial size (H*W), applied over `outer` images of `channels`.
pub fn bias_forward(top: &mut [f32], bias: &[f32], outer: usize, channels: usize, dim: usize) {
    assert_eq!(top.len(), outer * channels * dim);
    assert_eq!(bias.len(), channels);
    for o in 0..outer {
        for c in 0..channels {
            let base = (o * channels + c) * dim;
            let bv = bias[c];
            for v in top[base..base + dim].iter_mut() {
                *v += bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_axpby_scal() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        axpby(1.0, &x, 0.5, &mut y);
        assert_eq!(y, [7.0, 14.0]);
        scal(2.0, &mut y);
        assert_eq!(y, [14.0, 28.0]);
    }

    #[test]
    fn reductions() {
        assert_eq!(asum(&[1.0, -2.0, 3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn eltwise() {
        let mut z = [0.0; 2];
        add(&[1.0, 2.0], &[3.0, 4.0], &mut z);
        assert_eq!(z, [4.0, 6.0]);
        mul(&[2.0, 3.0], &[4.0, 5.0], &mut z);
        assert_eq!(z, [8.0, 15.0]);
        powx(&[4.0, 9.0], 0.5, &mut z);
        assert_eq!(z, [2.0, 3.0]);
    }

    #[test]
    fn relu_fwd_bwd() {
        let bottom = [-1.0, 0.0, 2.0];
        let mut top = [0.0; 3];
        relu_forward(&bottom, &mut top, 0.0);
        assert_eq!(top, [0.0, 0.0, 2.0]);
        relu_forward(&bottom, &mut top, 0.1);
        assert_eq!(top, [-0.1, 0.0, 2.0]);

        let top_diff = [1.0, 1.0, 1.0];
        let mut bd = [9.0; 3];
        relu_backward(&bottom, &top_diff, &mut bd, 0.0);
        assert_eq!(bd, [0.0, 0.0, 1.0]);
    }

    #[test]
    fn dropout_scales_kept_units() {
        let bottom = [1.0, 2.0, 3.0, 4.0];
        let mask = [1.0, 0.0, 1.0, 0.0];
        let scale = 2.0; // 1/(1-0.5)
        let mut top = [0.0; 4];
        dropout_forward(&bottom, &mask, scale, &mut top);
        assert_eq!(top, [2.0, 0.0, 6.0, 0.0]);
        let mut bd = [0.0; 4];
        dropout_backward(&top, &mask, scale, &mut bd);
        assert_eq!(bd, [4.0, 0.0, 12.0, 0.0]);
    }

    #[test]
    fn bias_broadcast() {
        // 1 image, 2 channels, dim 2
        let mut top = [0.0, 0.0, 10.0, 10.0];
        bias_forward(&mut top, &[1.0, 2.0], 1, 2, 2);
        assert_eq!(top, [1.0, 1.0, 12.0, 12.0]);
        // 2 images
        let mut top2 = [0.0f32; 8];
        bias_forward(&mut top2, &[1.0, 2.0], 2, 2, 2);
        assert_eq!(top2, [1.0, 1.0, 2.0, 2.0, 1.0, 1.0, 2.0, 2.0]);
    }
}
