//! Kernel → artifact execution plans.
//!
//! Maps every AOT-covered [`Kernel`] to a stable artifact key, the input
//! marshalling recipe (buffers with shapes, runtime scalars), the output
//! mapping, and a JSON spec the python AOT side lowers from. Scalars
//! (learning rate, alpha, slopes, ...) are rank-0 *runtime inputs* of the
//! HLO, so one artifact serves all values — exactly like an OpenCL kernel
//! taking them as arguments.
//!
//! Elementwise kernels are generated at power-of-two size *buckets*
//! (padded at dispatch, truncated on writeback) to bound the artifact
//! count; shaped kernels (gemm, im2col, pool, lrn, softmax) are exact.

use crate::device::Kernel;
use crate::util::json::Json;

/// Bucket an elementwise length: next power of two (min 256). Very large
/// tensors (> 2^20) use their exact size — padding 37 M-element FC
/// weights to 64 M would double the traffic for nothing.
pub fn bucket(n: usize) -> usize {
    if n > (1 << 20) {
        return n;
    }
    n.max(256).next_power_of_two()
}

/// Bucket a serving batch size: next power of two, clamped to
/// `max_batch` (the capacity the replica was built at). This is the
/// shape policy of the dynamic-batch serving worker — a replica is
/// reshaped to `batch_bucket(k, max_batch)` before executing a batch of
/// `k` filled rows — bounding the distinct execution shapes (and AOT
/// artifacts) to `log2(max_batch)+1` while never executing more than 2×
/// the filled rows, instead of always padding to `max_batch`.
pub fn batch_bucket(k: usize, max_batch: usize) -> usize {
    k.max(1).next_power_of_two().min(max_batch.max(1))
}

/// Largest serving batch a zoo net is provisioned for: the per-net caps
/// the AOT manifest records artifacts at, and the bucket set `netlint`
/// checks DDR fit against. Caps keep the biggest nets' activations
/// inside board/host memory (VGG-16 is multi-GB even forward-only at
/// batch 32). Unknown nets get the engine's default capacity.
pub fn serve_bucket_cap(name: &str) -> usize {
    match name {
        "lenet" | "alexnet" => 32,
        "squeezenet" | "googlenet" => 16,
        "vgg16" => 8,
        _ => 8,
    }
}

/// The distinct execution shapes a replica built at `max_batch` can be
/// reshaped to: `batch_bucket(k, max_batch)` for every fill level k,
/// deduped (`batch_bucket` is nondecreasing in k). This is the exact
/// bucket walk the AOT manifest records and admission linting checks.
pub fn serve_buckets(max_batch: usize) -> Vec<usize> {
    let mut buckets: Vec<usize> = (1..=max_batch.max(1))
        .map(|k| batch_bucket(k, max_batch))
        .collect();
    buckets.dedup();
    buckets
}

/// The full zoo × serving-bucket walk, in the fixed net order the AOT
/// manifest and artifact cache enumerate. Single source of truth for
/// `gen-manifest`, `fecaffe aot build|verify` and the CI `repro` leg —
/// they must all agree on the matrix or caches verify against a
/// different set than was built.
pub fn serve_matrix() -> Vec<(&'static str, Vec<usize>)> {
    ["lenet", "alexnet", "squeezenet", "googlenet", "vgg16"]
        .into_iter()
        .map(|name| (name, serve_buckets(serve_bucket_cap(name))))
        .collect()
}

/// One input argument of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub enum Arg {
    /// call.inputs[idx], reshaped to dims (padded to product(dims) if the
    /// buffer slice is shorter — bucketed kernels).
    Buf { idx: usize, dims: Vec<usize> },
    /// Current contents of call.outputs[idx] (accumulating kernels:
    /// beta=1 gemm, col2im, bias, solver history/data).
    OutBuf { idx: usize, dims: Vec<usize> },
    /// Runtime scalar (rank-0 f32 input).
    Scalar(f32),
}

/// Where tuple element `i` of the result goes.
#[derive(Debug, Clone, PartialEq)]
pub struct OutMap {
    /// Index into call.outputs.
    pub idx: usize,
    /// Number of valid elements to copy back (truncates bucket padding).
    pub len: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ExecPlan {
    pub key: String,
    pub args: Vec<Arg>,
    pub outs: Vec<OutMap>,
    /// Lowering spec for python (op + shape params).
    pub spec: Json,
}

fn spec(op: &str, fields: &[(&str, Json)]) -> Json {
    let mut o = Json::obj();
    o.set("op", Json::str(op));
    for (k, v) in fields {
        o.set(k, v.clone());
    }
    o
}

fn buf(idx: usize, dims: &[usize]) -> Arg {
    Arg::Buf { idx, dims: dims.to_vec() }
}

fn outbuf(idx: usize, dims: &[usize]) -> Arg {
    Arg::OutBuf { idx, dims: dims.to_vec() }
}

/// Build the execution plan for a kernel, or None if the kernel is pure
/// data movement served natively (Concat, SetConst).
pub fn kernel_plan(kernel: &Kernel) -> Option<ExecPlan> {
    use Kernel::*;
    let plan = match kernel {
        GemmNN { m, n, k, beta, .. } | GemmNT { m, n, k, beta, .. }
        | GemmTN { m, n, k, beta, .. } => {
            let (op, a_dims, b_dims) = match kernel {
                GemmNN { .. } => ("gemm_nn", vec![*m, *k], vec![*k, *n]),
                GemmNT { .. } => ("gemm_nt", vec![*m, *k], vec![*n, *k]),
                _ => ("gemm_tn", vec![*k, *m], vec![*k, *n]),
            };
            let acc = *beta != 0.0;
            let key = format!("{op}_{m}x{k}x{n}{}", if acc { "_acc" } else { "" });
            let mut args = vec![buf(0, &a_dims), buf(1, &b_dims)];
            if acc {
                args.push(outbuf(0, &[*m, *n]));
            }
            ExecPlan {
                key,
                args,
                outs: vec![OutMap { idx: 0, len: m * n }],
                spec: spec(op, &[
                    ("m", Json::num(*m as f64)),
                    ("n", Json::num(*n as f64)),
                    ("k", Json::num(*k as f64)),
                    ("acc", Json::Bool(acc)),
                ]),
            }
        }
        Gemv { trans, m, n, beta, .. } => {
            let acc = *beta != 0.0;
            let t = if *trans { "t" } else { "n" };
            let (xl, yl) = if *trans { (*m, *n) } else { (*n, *m) };
            let key = format!("gemv_{t}_{m}x{n}{}", if acc { "_acc" } else { "" });
            let mut args = vec![buf(0, &[*m, *n]), buf(1, &[xl])];
            if acc {
                args.push(outbuf(0, &[yl]));
            }
            ExecPlan {
                key,
                args,
                outs: vec![OutMap { idx: 0, len: yl }],
                spec: spec("gemv", &[
                    ("m", Json::num(*m as f64)),
                    ("n", Json::num(*n as f64)),
                    ("trans", Json::Bool(*trans)),
                    ("acc", Json::Bool(acc)),
                ]),
            }
        }
        Axpy { n, alpha } => eltwise2_acc("axpy", *n, &[Arg::Scalar(*alpha)]),
        Split { n } => eltwise2_acc("axpy", *n, &[Arg::Scalar(1.0)]),
        Axpby { n, alpha, beta } => {
            eltwise2_acc("axpby", *n, &[Arg::Scalar(*alpha), Arg::Scalar(*beta)])
        }
        Scal { n, alpha } => {
            let b = bucket(*n);
            ExecPlan {
                key: format!("scal_{b}"),
                args: vec![Arg::Scalar(*alpha), outbuf(0, &[b])],
                outs: vec![OutMap { idx: 0, len: *n }],
                spec: spec("scal", &[("n", Json::num(b as f64))]),
            }
        }
        Asum { n } => {
            let b = bucket(*n);
            ExecPlan {
                key: format!("asum_{b}"),
                args: vec![buf(0, &[b])],
                outs: vec![OutMap { idx: 0, len: 1 }],
                spec: spec("asum", &[("n", Json::num(b as f64))]),
            }
        }
        Add { n } => eltwise3("add", *n, &[]),
        Mul { n } => eltwise3("mul", *n, &[]),
        PowX { n, p } => {
            let b = bucket(*n);
            ExecPlan {
                key: format!("powx_{b}"),
                args: vec![Arg::Scalar(*p), buf(0, &[b])],
                outs: vec![OutMap { idx: 0, len: *n }],
                spec: spec("powx", &[("n", Json::num(b as f64))]),
            }
        }
        SetConst { .. } => return None, // trivial fill: native
        Im2col { geom } | Col2im { geom } => {
            let g = geom;
            let is_i2c = matches!(kernel, Im2col { .. });
            let op = if is_i2c { "im2col" } else { "col2im" };
            let key = format!(
                "{op}_{}x{}x{}_k{}x{}_s{}x{}_p{}x{}",
                g.channels, g.height, g.width, g.kernel_h, g.kernel_w, g.stride_h,
                g.stride_w, g.pad_h, g.pad_w
            );
            let im_dims = vec![g.channels, g.height, g.width];
            let col_dims = vec![g.col_rows(), g.col_cols()];
            let (args, outs) = if is_i2c {
                (vec![buf(0, &im_dims)], vec![OutMap { idx: 0, len: g.col_len() }])
            } else {
                (
                    vec![buf(0, &col_dims), outbuf(0, &im_dims)],
                    vec![OutMap { idx: 0, len: g.im_len() }],
                )
            };
            ExecPlan {
                key,
                args,
                outs,
                spec: spec(op, &[
                    ("channels", Json::num(g.channels as f64)),
                    ("height", Json::num(g.height as f64)),
                    ("width", Json::num(g.width as f64)),
                    ("kernel_h", Json::num(g.kernel_h as f64)),
                    ("kernel_w", Json::num(g.kernel_w as f64)),
                    ("stride_h", Json::num(g.stride_h as f64)),
                    ("stride_w", Json::num(g.stride_w as f64)),
                    ("pad_h", Json::num(g.pad_h as f64)),
                    ("pad_w", Json::num(g.pad_w as f64)),
                ]),
            }
        }
        MaxPoolF { geom, num } | MaxPoolB { geom, num } | AvePoolF { geom, num }
        | AvePoolB { geom, num } => {
            let g = geom;
            let (op, fwd, is_max) = match kernel {
                MaxPoolF { .. } => ("maxpool_f", true, true),
                MaxPoolB { .. } => ("maxpool_b", false, true),
                AvePoolF { .. } => ("avepool_f", true, false),
                _ => ("avepool_b", false, false),
            };
            let key = format!(
                "{op}_{num}x{}x{}x{}_k{}x{}_s{}x{}_p{}x{}",
                g.channels, g.height, g.width, g.kernel_h, g.kernel_w, g.stride_h,
                g.stride_w, g.pad_h, g.pad_w
            );
            let in_dims = vec![*num, g.channels, g.height, g.width];
            let out_dims = vec![*num, g.channels, g.out_h(), g.out_w()];
            let (args, outs) = match (fwd, is_max) {
                (true, true) => (
                    vec![buf(0, &in_dims)],
                    vec![
                        OutMap { idx: 0, len: num * g.out_len() },
                        OutMap { idx: 1, len: num * g.out_len() },
                    ],
                ),
                (true, false) => (
                    vec![buf(0, &in_dims)],
                    vec![OutMap { idx: 0, len: num * g.out_len() }],
                ),
                (false, true) => (
                    vec![buf(0, &out_dims), buf(1, &out_dims)],
                    vec![OutMap { idx: 0, len: num * g.in_len() }],
                ),
                (false, false) => (
                    vec![buf(0, &out_dims)],
                    vec![OutMap { idx: 0, len: num * g.in_len() }],
                ),
            };
            ExecPlan {
                key,
                args,
                outs,
                spec: spec(op, &[
                    ("num", Json::num(*num as f64)),
                    ("channels", Json::num(g.channels as f64)),
                    ("height", Json::num(g.height as f64)),
                    ("width", Json::num(g.width as f64)),
                    ("kernel_h", Json::num(g.kernel_h as f64)),
                    ("kernel_w", Json::num(g.kernel_w as f64)),
                    ("stride_h", Json::num(g.stride_h as f64)),
                    ("stride_w", Json::num(g.stride_w as f64)),
                    ("pad_h", Json::num(g.pad_h as f64)),
                    ("pad_w", Json::num(g.pad_w as f64)),
                ]),
            }
        }
        LrnScale { num, channels, dim, local_size, alpha, k } => {
            let key = format!("lrn_scale_{num}x{channels}x{dim}_ls{local_size}");
            ExecPlan {
                key,
                args: vec![
                    Arg::Scalar(*alpha),
                    Arg::Scalar(*k),
                    buf(0, &[*num, *channels, *dim]),
                ],
                outs: vec![OutMap { idx: 0, len: num * channels * dim }],
                spec: spec("lrn_scale", &[
                    ("num", Json::num(*num as f64)),
                    ("channels", Json::num(*channels as f64)),
                    ("dim", Json::num(*dim as f64)),
                    ("local_size", Json::num(*local_size as f64)),
                ]),
            }
        }
        LrnOutput { n, beta } => {
            let b = bucket(*n);
            ExecPlan {
                key: format!("lrn_output_{b}"),
                args: vec![Arg::Scalar(*beta), buf(0, &[b]), buf(1, &[b])],
                outs: vec![OutMap { idx: 0, len: *n }],
                spec: spec("lrn_output", &[("n", Json::num(b as f64))]),
            }
        }
        LrnDiff { num, channels, dim, local_size, alpha, beta } => {
            let key = format!("lrn_diff_{num}x{channels}x{dim}_ls{local_size}");
            let dims = [*num, *channels, *dim];
            ExecPlan {
                key,
                args: vec![
                    Arg::Scalar(*alpha),
                    Arg::Scalar(*beta),
                    buf(0, &dims),
                    buf(1, &dims),
                    buf(2, &dims),
                    buf(3, &dims),
                ],
                outs: vec![OutMap { idx: 0, len: num * channels * dim }],
                spec: spec("lrn_diff", &[
                    ("num", Json::num(*num as f64)),
                    ("channels", Json::num(*channels as f64)),
                    ("dim", Json::num(*dim as f64)),
                    ("local_size", Json::num(*local_size as f64)),
                ]),
            }
        }
        DropoutF { n, scale } | DropoutB { n, scale } => {
            let b = bucket(*n);
            ExecPlan {
                key: format!("dropout_{b}"),
                args: vec![Arg::Scalar(*scale), buf(0, &[b]), buf(1, &[b])],
                outs: vec![OutMap { idx: 0, len: *n }],
                spec: spec("dropout", &[("n", Json::num(b as f64))]),
            }
        }
        ReluF { n, slope } => {
            let b = bucket(*n);
            ExecPlan {
                key: format!("relu_f_{b}"),
                args: vec![Arg::Scalar(*slope), buf(0, &[b])],
                outs: vec![OutMap { idx: 0, len: *n }],
                spec: spec("relu_f", &[("n", Json::num(b as f64))]),
            }
        }
        ReluB { n, slope } => {
            let b = bucket(*n);
            ExecPlan {
                key: format!("relu_b_{b}"),
                args: vec![Arg::Scalar(*slope), buf(0, &[b]), buf(1, &[b])],
                outs: vec![OutMap { idx: 0, len: *n }],
                spec: spec("relu_b", &[("n", Json::num(b as f64))]),
            }
        }
        BiasF { outer, channels, dim } => {
            let key = format!("bias_{outer}x{channels}x{dim}");
            ExecPlan {
                key,
                args: vec![buf(0, &[*channels]), outbuf(0, &[*outer, *channels, *dim])],
                outs: vec![OutMap { idx: 0, len: outer * channels * dim }],
                spec: spec("bias", &[
                    ("outer", Json::num(*outer as f64)),
                    ("channels", Json::num(*channels as f64)),
                    ("dim", Json::num(*dim as f64)),
                ]),
            }
        }
        SoftmaxF { n, c } => ExecPlan {
            key: format!("softmax_{n}x{c}"),
            args: vec![buf(0, &[*n, *c])],
            outs: vec![OutMap { idx: 0, len: n * c }],
            spec: spec("softmax", &[
                ("n", Json::num(*n as f64)),
                ("c", Json::num(*c as f64)),
            ]),
        },
        SoftmaxLossF { n, c } => ExecPlan {
            key: format!("softmaxloss_f_{n}x{c}"),
            args: vec![buf(0, &[*n, *c]), buf(1, &[*n])],
            outs: vec![OutMap { idx: 0, len: 1 }],
            spec: spec("softmaxloss_f", &[
                ("n", Json::num(*n as f64)),
                ("c", Json::num(*c as f64)),
            ]),
        },
        SoftmaxLossB { n, c, weight } => ExecPlan {
            key: format!("softmaxloss_b_{n}x{c}"),
            args: vec![Arg::Scalar(*weight), buf(0, &[*n, *c]), buf(1, &[*n])],
            outs: vec![OutMap { idx: 0, len: n * c }],
            spec: spec("softmaxloss_b", &[
                ("n", Json::num(*n as f64)),
                ("c", Json::num(*c as f64)),
            ]),
        },
        ConcatF { .. } | ConcatB { .. } => return None, // data movement: native
        SgdUpdate { n, lr, momentum } => solver_plan(
            "sgd",
            *n,
            &[Arg::Scalar(*lr), Arg::Scalar(*momentum)],
            1,
        ),
        NesterovUpdate { n, lr, momentum } => solver_plan(
            "nesterov",
            *n,
            &[Arg::Scalar(*lr), Arg::Scalar(*momentum)],
            1,
        ),
        AdaGradUpdate { n, lr, delta } => solver_plan(
            "adagrad",
            *n,
            &[Arg::Scalar(*lr), Arg::Scalar(*delta)],
            1,
        ),
        RmsPropUpdate { n, lr, decay, delta } => solver_plan(
            "rmsprop",
            *n,
            &[Arg::Scalar(*lr), Arg::Scalar(*decay), Arg::Scalar(*delta)],
            1,
        ),
        AdaDeltaUpdate { n, momentum, delta, lr } => solver_plan(
            "adadelta",
            *n,
            &[Arg::Scalar(*momentum), Arg::Scalar(*delta), Arg::Scalar(*lr)],
            2,
        ),
        AdamUpdate { n, lr, beta1, beta2, delta, t } => solver_plan(
            "adam",
            *n,
            &[
                Arg::Scalar(*lr),
                Arg::Scalar(*beta1),
                Arg::Scalar(*beta2),
                Arg::Scalar(*delta),
                Arg::Scalar(*t as f32),
            ],
            2,
        ),
    };
    Some(plan)
}

/// z = f(x, y-as-accumulator): key op_B, args [scalars..., x, out].
fn eltwise2_acc(op: &str, n: usize, scalars: &[Arg]) -> ExecPlan {
    let b = bucket(n);
    let mut args = scalars.to_vec();
    args.push(buf(0, &[b]));
    args.push(outbuf(0, &[b]));
    ExecPlan {
        key: format!("{op}_{b}"),
        args,
        outs: vec![OutMap { idx: 0, len: n }],
        spec: spec(op, &[("n", Json::num(b as f64))]),
    }
}

/// z = f(x, y): two inputs, one output.
fn eltwise3(op: &str, n: usize, scalars: &[Arg]) -> ExecPlan {
    let b = bucket(n);
    let mut args = scalars.to_vec();
    args.push(buf(0, &[b]));
    args.push(buf(1, &[b]));
    ExecPlan {
        key: format!("{op}_{b}"),
        args,
        outs: vec![OutMap { idx: 0, len: n }],
        spec: spec(op, &[("n", Json::num(b as f64))]),
    }
}

/// Solver update: inputs [scalars..., diff, hist..(outbufs), data(outbuf)],
/// outputs tuple (hist.., data).
fn solver_plan(op: &str, n: usize, scalars: &[Arg], hist_slots: usize) -> ExecPlan {
    let b = bucket(n);
    let mut args = scalars.to_vec();
    args.push(buf(0, &[b])); // diff
    for s in 0..hist_slots {
        args.push(outbuf(s, &[b]));
    }
    args.push(outbuf(hist_slots, &[b])); // data
    let mut outs = Vec::new();
    for s in 0..hist_slots {
        outs.push(OutMap { idx: s, len: n });
    }
    outs.push(OutMap { idx: hist_slots, len: n });
    ExecPlan {
        key: format!("{op}_{b}"),
        args,
        outs,
        spec: spec(op, &[("n", Json::num(b as f64))]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::ConvGeom;

    #[test]
    fn bucket_rules() {
        assert_eq!(bucket(1), 256);
        assert_eq!(bucket(257), 512);
        assert_eq!(bucket(1 << 20), 1 << 20);
        assert_eq!(bucket((1 << 20) + 5), (1 << 20) + 5); // exact above 1M
    }

    #[test]
    fn batch_bucket_rules() {
        assert_eq!(batch_bucket(1, 8), 1);
        assert_eq!(batch_bucket(2, 8), 2);
        assert_eq!(batch_bucket(3, 8), 4);
        assert_eq!(batch_bucket(5, 8), 8);
        assert_eq!(batch_bucket(8, 8), 8);
        // Clamped to the replica capacity; degenerate inputs stay sane.
        assert_eq!(batch_bucket(9, 8), 8);
        assert_eq!(batch_bucket(0, 8), 1);
        assert_eq!(batch_bucket(1, 1), 1);
        // Monotonic nondecreasing in k (dedup-able bucket walks).
        let max = 32;
        let mut prev = 0;
        for k in 1..=max {
            let b = batch_bucket(k, max);
            assert!(b >= k.min(max) && b >= prev && b <= max);
            prev = b;
        }
    }

    #[test]
    fn serve_bucket_walk() {
        assert_eq!(serve_buckets(8), vec![1, 2, 4, 8]);
        assert_eq!(serve_buckets(32), vec![1, 2, 4, 8, 16, 32]);
        assert_eq!(serve_buckets(1), vec![1]);
        assert_eq!(serve_buckets(0), vec![1]); // degenerate input stays sane
        // Every zoo net has a cap and its walk ends at the cap.
        for name in ["lenet", "alexnet", "squeezenet", "googlenet", "vgg16"] {
            let cap = serve_bucket_cap(name);
            assert_eq!(serve_buckets(cap).last(), Some(&cap));
        }
    }

    #[test]
    fn serve_matrix_is_the_fixed_zoo_walk() {
        let matrix = serve_matrix();
        let names: Vec<&str> = matrix.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["lenet", "alexnet", "squeezenet", "googlenet", "vgg16"]);
        for (name, buckets) in &matrix {
            assert_eq!(buckets, &serve_buckets(serve_bucket_cap(name)), "{name}");
            assert_eq!(buckets.first(), Some(&1));
        }
        // 6 + 6 + 5 + 5 + 4 containers in the full artifact matrix.
        let total: usize = matrix.iter().map(|(_, b)| b.len()).sum();
        assert_eq!(total, 26);
    }

    #[test]
    fn gemm_keys_and_acc() {
        let k0 = Kernel::GemmNN { m: 2, n: 3, k: 4, alpha: 1.0, beta: 0.0 };
        let p0 = kernel_plan(&k0).unwrap();
        assert_eq!(p0.key, "gemm_nn_2x4x3");
        assert_eq!(p0.args.len(), 2);
        let k1 = Kernel::GemmNT { m: 2, n: 3, k: 4, alpha: 1.0, beta: 1.0 };
        let p1 = kernel_plan(&k1).unwrap();
        assert_eq!(p1.key, "gemm_nt_2x4x3_acc");
        assert_eq!(p1.args.len(), 3);
        assert!(matches!(p1.args[2], Arg::OutBuf { .. }));
    }

    #[test]
    fn relu_bucketed_key_is_shared() {
        let a = kernel_plan(&Kernel::ReluF { n: 300, slope: 0.0 }).unwrap();
        let b = kernel_plan(&Kernel::ReluF { n: 500, slope: 0.1 }).unwrap();
        assert_eq!(a.key, b.key); // same bucket (512), slope is runtime scalar
        assert_eq!(a.key, "relu_f_512");
        assert_eq!(a.outs[0].len, 300);
    }

    #[test]
    fn concat_and_setconst_are_native() {
        assert!(kernel_plan(&Kernel::ConcatF { num: 1, this: 4, total: 8, offset: 0 }).is_none());
        assert!(kernel_plan(&Kernel::SetConst { n: 4, value: 0.0 }).is_none());
    }

    #[test]
    fn im2col_key_encodes_geometry() {
        let geom = ConvGeom {
            channels: 3,
            height: 227,
            width: 227,
            kernel_h: 11,
            kernel_w: 11,
            pad_h: 0,
            pad_w: 0,
            stride_h: 4,
            stride_w: 4,
        };
        let p = kernel_plan(&Kernel::Im2col { geom }).unwrap();
        assert_eq!(p.key, "im2col_3x227x227_k11x11_s4x4_p0x0");
    }

    #[test]
    fn adam_plan_has_three_outputs() {
        let p = kernel_plan(&Kernel::AdamUpdate {
            n: 1000,
            lr: 0.1,
            beta1: 0.9,
            beta2: 0.99,
            delta: 1e-8,
            t: 3,
        })
        .unwrap();
        assert_eq!(p.outs.len(), 3);
        assert_eq!(p.key, "adam_1024");
        // lr/betas/delta/t are runtime scalars, not in the key
        assert_eq!(
            p.args.iter().filter(|a| matches!(a, Arg::Scalar(_))).count(),
            5
        );
    }

    #[test]
    fn spec_json_is_self_describing() {
        let p = kernel_plan(&Kernel::SoftmaxF { n: 4, c: 10 }).unwrap();
        assert_eq!(p.spec.get("op").unwrap().as_str().unwrap(), "softmax");
        assert_eq!(p.spec.get("n").unwrap().as_usize().unwrap(), 4);
    }
}
