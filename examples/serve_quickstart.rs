//! Minimal serving-engine walkthrough: start an engine over LeNet,
//! submit a few single-sample requests, read the class probabilities,
//! shut down gracefully. `cargo run --release --example serve_quickstart`.

use fecaffe::serve::{DeviceKind, Engine, EngineConfig};
use fecaffe::util::prng::Pcg32;
use fecaffe::zoo;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let param = zoo::by_name("lenet", 1)?;
    let engine = Engine::new(
        &param,
        EngineConfig {
            workers: 2,
            max_batch: 4,
            max_linger: Duration::from_millis(1),
            queue_capacity: 64,
            device: DeviceKind::Cpu,
            // 0 = split the process thread budget across the 2 workers.
            intra_op_threads: 0,
            // Batch tracing off (1 would sample every batch into the
            // ring behind Engine::obs / GET /admin/trace).
            trace_sample: 0,
        },
    )?;
    println!(
        "engine up: {} inputs/sample, {} classes",
        engine.sample_len(),
        engine.output_len()
    );

    // Submit four random digits; handles resolve as batches complete.
    let mut rng = Pcg32::new(11);
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let mut sample = vec![0f32; engine.sample_len()];
            rng.fill_uniform(&mut sample, 0.0, 1.0);
            engine.submit(sample).expect("admission")
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait().expect("response");
        println!(
            "request {i}: class {} (p={:.3}) in {:?}",
            resp.argmax(),
            resp.values[resp.argmax()],
            resp.latency
        );
    }

    engine.shutdown();
    println!("{}", engine.metrics().snapshot().render());
    Ok(())
}
