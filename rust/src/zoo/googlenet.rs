//! GoogLeNet v1 (BVLC train_val): 9 inception modules, two LRNs, three
//! loss heads (loss1/loss2 at weight 0.3) — the paper's deepest network
//! and the subject of its Table 2 kernel breakdown and Figures 4/5
//! training traces.

use super::NetBuilder;
use crate::proto::{NetParameter, PoolMethod};

/// Inception module: four branches concatenated on channels.
#[allow(clippy::too_many_arguments)]
pub fn inception(
    b: &mut NetBuilder,
    name: &str,
    bottom: &str,
    c1x1: usize,
    c3x3r: usize,
    c3x3: usize,
    c5x5r: usize,
    c5x5: usize,
    pool_proj: usize,
) {
    let b1 = format!("{name}/1x1");
    let b3r = format!("{name}/3x3_reduce");
    let b3 = format!("{name}/3x3");
    let b5r = format!("{name}/5x5_reduce");
    let b5 = format!("{name}/5x5");
    let bp = format!("{name}/pool");
    let bpp = format!("{name}/pool_proj");
    b.conv_relu(&b1, bottom, c1x1, 1, 1, 0);
    b.conv_relu(&b3r, bottom, c3x3r, 1, 1, 0);
    b.conv_relu(&b3, &b3r, c3x3, 3, 1, 1);
    b.conv_relu(&b5r, bottom, c5x5r, 1, 1, 0);
    b.conv_relu(&b5, &b5r, c5x5, 5, 1, 2);
    b.pool(&bp, bottom, PoolMethod::Max, 3, 1, 1);
    b.conv_relu(&bpp, &bp, pool_proj, 1, 1, 0);
    b.concat(&format!("{name}/output"), &[&b1, &b3, &b5, &bpp]);
}

/// Auxiliary classifier head (loss1/loss2, weight 0.3).
fn aux_head(b: &mut NetBuilder, name: &str, bottom: &str) {
    let pool = format!("{name}/ave_pool");
    let conv = format!("{name}/conv");
    let fc = format!("{name}/fc");
    let cls = format!("{name}/classifier");
    b.pool(&pool, bottom, PoolMethod::Ave, 5, 3, 0);
    b.conv_relu(&conv, &pool, 128, 1, 1, 0);
    b.fc(&fc, &conv, 1024);
    b.relu_inplace(&format!("{name}/relu_fc"), &fc);
    b.dropout_inplace(&format!("{name}/drop_fc"), &fc, 0.7);
    b.fc(&cls, &fc, 1000);
    b.softmax_loss(&format!("{name}/loss"), &cls, 0.3);
}

pub fn googlenet(batch: usize) -> NetParameter {
    let mut b = NetBuilder::new("GoogLeNet_v1");
    b.data(batch, 3, 224, 1000, "imagenet");
    b.conv_relu("conv1/7x7_s2", "data", 64, 7, 2, 3);
    b.pool("pool1/3x3_s2", "conv1/7x7_s2", PoolMethod::Max, 3, 2, 0);
    b.lrn("pool1/norm1", "pool1/3x3_s2");
    b.conv_relu("conv2/3x3_reduce", "pool1/norm1", 64, 1, 1, 0);
    b.conv_relu("conv2/3x3", "conv2/3x3_reduce", 192, 3, 1, 1);
    b.lrn("conv2/norm2", "conv2/3x3");
    b.pool("pool2/3x3_s2", "conv2/norm2", PoolMethod::Max, 3, 2, 0);
    inception(&mut b, "inception_3a", "pool2/3x3_s2", 64, 96, 128, 16, 32, 32);
    inception(&mut b, "inception_3b", "inception_3a/output", 128, 128, 192, 32, 96, 64);
    b.pool("pool3/3x3_s2", "inception_3b/output", PoolMethod::Max, 3, 2, 0);
    inception(&mut b, "inception_4a", "pool3/3x3_s2", 192, 96, 208, 16, 48, 64);
    aux_head(&mut b, "loss1", "inception_4a/output");
    inception(&mut b, "inception_4b", "inception_4a/output", 160, 112, 224, 24, 64, 64);
    inception(&mut b, "inception_4c", "inception_4b/output", 128, 128, 256, 24, 64, 64);
    inception(&mut b, "inception_4d", "inception_4c/output", 112, 144, 288, 32, 64, 64);
    aux_head(&mut b, "loss2", "inception_4d/output");
    inception(&mut b, "inception_4e", "inception_4d/output", 256, 160, 320, 32, 128, 128);
    b.pool("pool4/3x3_s2", "inception_4e/output", PoolMethod::Max, 3, 2, 0);
    inception(&mut b, "inception_5a", "pool4/3x3_s2", 256, 160, 320, 32, 128, 128);
    inception(&mut b, "inception_5b", "inception_5a/output", 384, 192, 384, 48, 128, 128);
    b.global_ave_pool("pool5/7x7_s1", "inception_5b/output");
    b.dropout_inplace("pool5/drop_7x7_s1", "pool5/7x7_s1", 0.4);
    b.fc("loss3/classifier", "pool5/7x7_s1", 1000);
    b.accuracy("accuracy", "loss3/classifier");
    b.softmax_loss("loss3/loss3", "loss3/classifier", 1.0);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::net::Net;
    use crate::proto::Phase;

    #[test]
    fn structure_counts() {
        let net = googlenet(1);
        let convs = net.layers.iter().filter(|l| l.kind == "Convolution").count();
        // 3 stem + 9 inceptions × 6 + 2 aux heads × 1 = 59
        assert_eq!(convs, 59);
        let relus = net.layers.iter().filter(|l| l.kind == "ReLU").count();
        // 59 conv-relus + 2 aux fc relus = 61 (paper Table 2: 61 ReLU_F!)
        assert_eq!(relus, 61);
        let losses = net
            .layers
            .iter()
            .filter(|l| l.kind == "SoftmaxWithLoss")
            .count();
        assert_eq!(losses, 3);
        let pools = net.layers.iter().filter(|l| l.kind == "Pooling").count();
        // 4 stem/stage max pools + 9 inception pools + 2 aux ave + global = 16
        assert_eq!(pools, 16);
    }

    #[test]
    fn builds_with_correct_geometry() {
        let mut dev = CpuDevice::new();
        let param = googlenet(1);
        let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        let shape = |n: &str| net.blob(n).unwrap().borrow().shape().to_vec();
        assert_eq!(shape("conv1/7x7_s2"), vec![1, 64, 112, 112]);
        assert_eq!(shape("pool1/3x3_s2"), vec![1, 64, 56, 56]);
        assert_eq!(shape("pool2/3x3_s2"), vec![1, 192, 28, 28]);
        assert_eq!(shape("inception_3a/output"), vec![1, 256, 28, 28]);
        assert_eq!(shape("inception_3b/output"), vec![1, 480, 28, 28]);
        assert_eq!(shape("inception_4e/output"), vec![1, 832, 14, 14]);
        assert_eq!(shape("inception_5b/output"), vec![1, 1024, 7, 7]);
        assert_eq!(shape("pool5/7x7_s1"), vec![1, 1024, 1, 1]);
        // ~13.4M params (with aux heads)
        let p = net.num_parameters();
        assert!((12_000_000..15_000_000).contains(&p), "params {p}");
        // Splits exist for inception fan-outs
        assert!(net.layer_kinds().iter().filter(|&&k| k == "Split").count() >= 9);
    }
}
