//! E3 — regenerate paper Table 3: FPGA resource utilization (analytic
//! model; see device/fpga/resources.rs for the derivations).

fn main() {
    println!("{}", fecaffe::bench_tables::table3());
    println!("Paper reference (Table 3): Gemm 107K/2338/1037, Gemv 49K/756/130,");
    println!("Total 616K (66%) ALMs, 5419 (47%) M20K, 1796 (31%) DSPs @ 252-253 MHz.");
}
