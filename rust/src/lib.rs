//! # FeCaffe — FPGA-enabled Caffe, reproduced as a Rust + JAX/Pallas stack
//!
//! This crate is the Layer-3 coordinator of the reproduction of
//! *"FeCaffe: FPGA-enabled Caffe with OpenCL for Deep Learning Training
//! and Inference on Intel Stratix 10"* (He et al., 2019). It contains:
//!
//! * a Caffe-workalike framework: [`proto`] (prototxt parser), [`blob`]
//!   (+ the paper's extended `syncedmem` state machine), [`layers`],
//!   [`net`], [`solver`];
//! * the FPGA substrate the paper ran on, rebuilt as a simulator:
//!   [`device::fpga`] (device DDR, OpenCL-style command queue, PCIe
//!   model, per-kernel cost model, profiler);
//! * the AOT kernel runtime: [`runtime`] loads `artifacts/*.hlo.txt`
//!   (JAX/Pallas kernels lowered at build time) and executes them through
//!   PJRT — the `.aocx` bitstream analogue;
//! * a native math library [`math`] used as the CPU fallback device and
//!   as the correctness oracle;
//! * the paper's evaluation: [`bench_tables`] regenerates Tables 1–4 and
//!   Figures 4/5, with [`baseline`] implementing the F-CNN comparator;
//! * an inference serving engine: [`serve`] micro-batches single-sample
//!   requests onto a pool of warm net replicas with `Arc`-shared weights
//!   (the `serve` binary drives it under load);
//! * a content-addressed AOT plan cache: [`aot`] serializes recorded
//!   execution plans into deterministic `FEPLAN1` containers keyed by
//!   net schema × bucket × device config, letting the serving engine
//!   cold-boot without re-planning (`fecaffe aot build|verify|clean`);
//! * a unified observability layer: [`obs`] (sampled batch traces,
//!   per-layer timing hooks, training metrics) feeding the [`trace`]
//!   timeline renderers, the Prometheus `/metrics` exposition and the
//!   `fecaffe profile` per-layer/per-kernel breakdown.
//!
//! See `DESIGN.md` for the experiment index and substitution notes.

pub mod util;
pub mod proto;
pub mod blob;
pub mod math;
pub mod device;
pub mod runtime;
pub mod layers;
pub mod net;
pub mod netlint;
pub mod quant;
pub mod aot;
pub mod obs;
pub mod serve;
pub mod solver;
pub mod data;
pub mod zoo;
pub mod baseline;
pub mod trace;
pub mod bench_tables;
