//! AOT plan-cache integration: matrix build/verify reproducibility,
//! cold-boot serving through the engine, and the robustness matrix —
//! corrupted bytes, truncation, stale keys, envelope mismatches — each
//! of which must surface as a typed `AotError` and a clean fallback to
//! live planning, never a panic or a silently wrong plan.

use fecaffe::aot::{self, AotError};
use fecaffe::device::fpga::costmodel::BoardParams;
use fecaffe::quant::Precision;
use fecaffe::runtime::plan::serve_buckets;
use fecaffe::serve::{load_test, DeviceKind, Engine, EngineConfig};
use fecaffe::zoo;
use std::path::PathBuf;
use std::time::Duration;

/// Fresh per-test cache directory (process id + tag keeps parallel test
/// binaries and parallel tests apart).
fn temp_cache(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fecaffe_aot_test_{}_{tag}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn tiny_engine_cfg(cache: Option<PathBuf>) -> EngineConfig {
    EngineConfig {
        workers: 1,
        max_batch: 2,
        max_linger: Duration::from_micros(200),
        queue_capacity: 64,
        device: DeviceKind::Cpu,
        aot_cache: cache,
        ..EngineConfig::default()
    }
}

#[test]
fn build_verify_and_reproducibility() {
    let dir_a = temp_cache("repro_a");
    let dir_b = temp_cache("repro_b");
    let nets = ["lenet"];

    let a = aot::build_matrix(&dir_a, &nets).unwrap();
    let b = aot::build_matrix(&dir_b, &nets).unwrap();
    assert_eq!(a.files.len(), serve_buckets(32).len(), "one container per bucket");
    assert!(a.plan_count > 0);

    // Two independent builds: identical manifests, identical bytes.
    assert_eq!(a.files, b.files, "manifest (relpath, sha256) sets must match");
    let man_a = std::fs::read(dir_a.join(aot::MANIFEST_NAME)).unwrap();
    let man_b = std::fs::read(dir_b.join(aot::MANIFEST_NAME)).unwrap();
    assert_eq!(man_a, man_b, "MANIFEST.sha256 must be byte-identical");
    for (rel, _) in &a.files {
        let fa = std::fs::read(dir_a.join(rel)).unwrap();
        let fb = std::fs::read(dir_b.join(rel)).unwrap();
        assert_eq!(fa, fb, "{rel} must be byte-identical across builds");
    }

    // And the tree verifies against the live zoo.
    let report = aot::verify_matrix(&dir_a, &nets).unwrap();
    assert_eq!(report.files, a.files.len());
    assert_eq!(report.plan_count, a.plan_count);

    // clean() removes a real cache but refuses a non-cache directory.
    assert!(aot::clean(&dir_b).unwrap());
    assert!(!dir_b.exists());
    let decoy = temp_cache("decoy");
    std::fs::create_dir_all(decoy.join("precious")).unwrap();
    let err = aot::clean(&decoy).unwrap_err();
    assert!(err.to_string().contains("refusing"), "{err}");
    assert!(decoy.exists(), "refused clean must not delete anything");
    std::fs::remove_dir_all(&decoy).ok();
    std::fs::remove_dir_all(&dir_a).ok();
}

#[test]
fn verify_catches_corruption_truncation_and_strays() {
    let dir = temp_cache("verify");
    let nets = ["lenet"];
    aot::build_matrix(&dir, &nets).unwrap();
    let victim = dir.join("lenet_deploy/bucket_001.feplan");

    // Flipped byte: manifest digest mismatch, typed Corrupt in the chain.
    let pristine = std::fs::read(&victim).unwrap();
    let mut bad = pristine.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0xFF;
    std::fs::write(&victim, &bad).unwrap();
    let err = aot::verify_matrix(&dir, &nets).unwrap_err();
    let aot_err = err.downcast_ref::<AotError>().expect("typed AotError in chain");
    assert_eq!(aot_err.code(), "AOT0002", "{aot_err}");

    // Truncation: same class of typed failure.
    std::fs::write(&victim, &pristine[..pristine.len() / 3]).unwrap();
    let err = aot::verify_matrix(&dir, &nets).unwrap_err();
    assert_eq!(err.downcast_ref::<AotError>().unwrap().code(), "AOT0002");

    // Deleted file: Missing.
    std::fs::remove_file(&victim).unwrap();
    let err = aot::verify_matrix(&dir, &nets).unwrap_err();
    assert_eq!(err.downcast_ref::<AotError>().unwrap().code(), "AOT0001");
    std::fs::write(&victim, &pristine).unwrap();

    // A manifest entry outside the expected matrix is refused — a cache
    // can't smuggle artifacts verify never checks.
    let manifest = dir.join(aot::MANIFEST_NAME);
    let mut text = std::fs::read_to_string(&manifest).unwrap();
    text.push_str(&format!("{}  lenet_deploy/bucket_064.feplan\n", "ab".repeat(32)));
    std::fs::write(&manifest, text).unwrap();
    let err = aot::verify_matrix(&dir, &nets).unwrap_err();
    assert!(err.to_string().contains("not in the"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cold_boot_flags_stale_key_when_schema_changes_under_same_path() {
    let dir = temp_cache("stale");
    aot::build_matrix(&dir, &["lenet"]).unwrap();

    // Same cache path, evolved net: widen ip1. The canonical schema —
    // and therefore the content key — changes, so every artifact must
    // report StaleKey, not validate against the old plans.
    let mut dep = zoo::deploy_by_name("lenet", 2).unwrap();
    let ip = dep
        .param
        .layers
        .iter_mut()
        .find_map(|l| l.inner_product.as_mut())
        .expect("lenet has an InnerProduct layer");
    ip.num_output += 1;

    let boot = aot::cold_boot(&dir, &dep, &[1, 2], &BoardParams::default(), Precision::Fp32);
    assert!(!boot.complete());
    assert_eq!(boot.errors.len(), 2);
    for e in &boot.errors {
        assert_eq!(e.code(), "AOT0003", "{e}");
        assert!(e.to_string().contains("stale plan"), "{e}");
    }

    // The unmutated net still cold-boots cleanly from the same cache.
    let dep = zoo::deploy_by_name("lenet", 2).unwrap();
    let boot = aot::cold_boot(&dir, &dep, &[1, 2], &BoardParams::default(), Precision::Fp32);
    assert!(boot.complete(), "{:?}", boot.errors);
    assert_eq!(boot.hit_count(), 2);
    assert_eq!(boot.miss_count(), 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cold_boot_at_a_different_precision_never_reuses_fp32_plans() {
    // Precision is part of both the artifact path and the content key:
    // a cache built for fp32 serving must not satisfy an int8 boot (its
    // DDR envelope was checked at 4-byte widths). The int8 artifacts
    // live under distinct `.int8.feplan` paths, so the boot misses
    // (AOT0001) and demotes to live planning.
    let dir = temp_cache("precision");
    aot::build_matrix(&dir, &["lenet"]).unwrap();
    let dep = zoo::deploy_by_name("lenet", 2).unwrap();

    let boot = aot::cold_boot(&dir, &dep, &[1, 2], &BoardParams::default(), Precision::Int8);
    assert!(!boot.complete());
    assert_eq!(boot.errors.len(), 2, "{:?}", boot.errors);
    for e in &boot.errors {
        assert_eq!(e.code(), "AOT0001", "{e}");
        assert!(e.to_string().contains("int8"), "path should carry the precision: {e}");
    }

    // Even if the fp32 bytes were copied onto the int8 path (a cache
    // manipulated by hand), the content key differs: StaleKey, never a
    // silent reuse.
    for b in [1usize, 2] {
        std::fs::copy(
            dir.join(format!("lenet_deploy/bucket_{b:03}.feplan")),
            dir.join(format!("lenet_deploy/bucket_{b:03}.int8.feplan")),
        )
        .unwrap();
    }
    let boot = aot::cold_boot(&dir, &dep, &[1, 2], &BoardParams::default(), Precision::Int8);
    assert!(!boot.complete());
    assert!(boot.errors.iter().all(|e| e.code() == "AOT0003"), "{:?}", boot.errors);

    // Building the int8 matrix alongside makes the int8 boot complete —
    // and the fp32 boot still validates from the same directory.
    std::fs::remove_dir_all(&dir).ok();
    aot::build_matrix(&dir, &["lenet", "lenet@int8"]).unwrap();
    let boot = aot::cold_boot(&dir, &dep, &[1, 2], &BoardParams::default(), Precision::Int8);
    assert!(boot.complete(), "{:?}", boot.errors);
    let boot = aot::cold_boot(&dir, &dep, &[1, 2], &BoardParams::default(), Precision::Fp32);
    assert!(boot.complete(), "{:?}", boot.errors);
    aot::verify_matrix(&dir, &["lenet", "lenet@int8"]).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cold_boot_flags_envelope_and_board_mismatches() {
    let dir = temp_cache("envelope");
    aot::build_matrix(&dir, &["lenet"]).unwrap();
    let dep = zoo::deploy_by_name("lenet", 2).unwrap();

    // A different board capacity changes the device-config key field:
    // cached artifacts are stale for that board, never silently reused.
    let small_board = BoardParams { ddr_capacity_bytes: 1 << 20, ..BoardParams::default() };
    let boot = aot::cold_boot(&dir, &dep, &[1, 2], &small_board, Precision::Fp32);
    assert!(!boot.complete());
    assert!(boot.errors.iter().all(|e| e.code() == "AOT0003"), "{:?}", boot.errors);

    // Unknown bucket: Missing (no artifact file for bucket 64).
    let boot = aot::cold_boot(&dir, &dep, &[64], &BoardParams::default(), Precision::Fp32);
    assert_eq!(boot.errors.len(), 1);
    assert_eq!(boot.errors[0].code(), "AOT0001");

    // Weights-schema mismatch is a typed EnvelopeMismatch.
    let good = aot::cold_boot(&dir, &dep, &[2], &BoardParams::default(), Precision::Fp32);
    assert!(good.complete());
    let art = &good.hits[0].1;
    let err = aot::validate_weights(art, &[("phantom".to_string(), 0)], &[42], "p").unwrap_err();
    assert_eq!(err.code(), "AOT0004");
    assert!(err.to_string().contains("weights schema"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn engine_cold_boots_from_warm_cache_and_serves() {
    let dir = temp_cache("engine_warm");
    aot::build_matrix(&dir, &["lenet"]).unwrap();
    let param = zoo::by_name("lenet", 1).unwrap();
    let engine = Engine::new(&param, tiny_engine_cfg(Some(dir.clone()))).unwrap();

    // max_batch 2 ⇒ buckets [1, 2]; both artifacts validated.
    let snap = engine.metrics().snapshot();
    assert_eq!(snap.cache_hit, 2, "both serving buckets restored from cache");
    assert_eq!(snap.cache_miss, 0);

    // And the cold-booted engine serves real answers.
    let report = load_test(&engine, 2, 16, 0xF_EC_AF_FE);
    engine.shutdown();
    assert_eq!(report.failed, 0);
    assert_eq!(report.requests, 16);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn engine_falls_back_to_live_planning_on_bad_cache() {
    // A cache directory full of garbage: the engine must boot anyway
    // (live lint path), count the misses, and serve correctly.
    let dir = temp_cache("engine_bad");
    std::fs::create_dir_all(dir.join("lenet_deploy")).unwrap();
    std::fs::write(dir.join("lenet_deploy/bucket_001.feplan"), b"not a container").unwrap();

    let param = zoo::by_name("lenet", 1).unwrap();
    let engine = Engine::new(&param, tiny_engine_cfg(Some(dir.clone()))).unwrap();
    let snap = engine.metrics().snapshot();
    assert_eq!(snap.cache_hit, 0);
    assert_eq!(snap.cache_miss, 2, "corrupt bucket 1 + missing bucket 2");

    let report = load_test(&engine, 2, 16, 7);
    engine.shutdown();
    assert_eq!(report.failed, 0);
    assert_eq!(report.requests, 16);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn engine_without_cache_config_reports_zero_cache_counters() {
    let param = zoo::by_name("lenet", 1).unwrap();
    let engine = Engine::new(&param, tiny_engine_cfg(None)).unwrap();
    let snap = engine.metrics().snapshot();
    engine.shutdown();
    assert_eq!((snap.cache_hit, snap.cache_miss), (0, 0));
}
