//! Engine cold-start bench: boot time from a warm AOT plan cache vs
//! live admission planning, per zoo net, emitting `BENCH_coldstart.json`
//! — the serving cold-start budget ISSUE 9 asks for. Also times the
//! one-off `aot build` that materializes the cache.
//!
//! `cargo bench --bench coldstart`; `FECAFFE_BENCH_QUICK=1` for the CI
//! smoke variant (fewer nets, fewer reps).

use fecaffe::aot;
use fecaffe::serve::{DeviceKind, Engine, EngineConfig};
use fecaffe::util::json::Json;
use fecaffe::zoo;
use std::time::{Duration, Instant};

fn boot_once(
    param: &fecaffe::proto::NetParameter,
    max_batch: usize,
    cache: Option<&std::path::Path>,
) -> anyhow::Result<(Duration, u64, u64)> {
    let cfg = EngineConfig {
        workers: 1,
        max_batch,
        max_linger: Duration::from_micros(500),
        queue_capacity: 64,
        device: DeviceKind::Cpu,
        aot_cache: cache.map(std::path::Path::to_path_buf),
        ..EngineConfig::default()
    };
    let t0 = Instant::now();
    let engine = Engine::new(param, cfg)?;
    let dt = t0.elapsed();
    let snap = engine.metrics().snapshot();
    engine.shutdown();
    Ok((dt, snap.cache_hit, snap.cache_miss))
}

fn main() -> anyhow::Result<()> {
    // The engine-level env fallback must not leak into the "live" legs.
    std::env::remove_var(aot::AOT_CACHE_ENV);
    let quick = std::env::var("FECAFFE_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let nets: &[&str] = if quick {
        &["lenet", "squeezenet"]
    } else {
        &["lenet", "alexnet", "squeezenet", "googlenet", "vgg16"]
    };
    let reps = if quick { 2 } else { 3 };
    let dir = std::env::temp_dir().join(format!("fecaffe_aot_bench_{}", std::process::id()));

    // One-off cache materialization (the offline `fecaffe aot build`).
    let t0 = Instant::now();
    let built = aot::build_matrix(&dir, nets)?;
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "aot build: {} container(s), {} plan(s) in {build_ms:.1} ms",
        built.files.len(),
        built.plan_count
    );

    let mut results = Vec::new();
    for name in nets {
        let param = zoo::by_name(name, 1)?;
        // Boot at the net's full serving cap — the worst-case (most
        // buckets) admission planning load, and what `serve --http`
        // defaults resemble. Min over reps: boot time is one-shot cost,
        // so the minimum is the least-noisy estimator.
        let max_batch = fecaffe::runtime::plan::serve_bucket_cap(name);
        let mut live = Duration::MAX;
        let mut warm = Duration::MAX;
        for _ in 0..reps {
            let (dt, hit, miss) = boot_once(&param, max_batch, None)?;
            anyhow::ensure!(hit == 0 && miss == 0, "{name}: live boot touched a cache");
            live = live.min(dt);
            let (dt, hit, miss) = boot_once(&param, max_batch, Some(&dir))?;
            anyhow::ensure!(miss == 0, "{name}: warm-cache boot missed ({miss} miss(es))");
            anyhow::ensure!(hit > 0, "{name}: warm-cache boot recorded no hits");
            warm = warm.min(dt);
        }
        let (live_ms, warm_ms) = (live.as_secs_f64() * 1e3, warm.as_secs_f64() * 1e3);
        println!(
            "{name:>10} (max-batch {max_batch:>2}): live plan {live_ms:>8.2} ms, \
             cold boot {warm_ms:>8.2} ms ({:+.1}%)",
            (warm_ms - live_ms) * 100.0 / live_ms.max(1e-9)
        );
        let mut o = Json::obj();
        o.set("net", Json::str(*name));
        o.set("max_batch", Json::num(max_batch as f64));
        o.set("live_plan_ms", Json::num(live_ms));
        o.set("cold_boot_ms", Json::num(warm_ms));
        results.push(o);
    }

    let mut root = Json::obj();
    root.set("bench", Json::str("coldstart"));
    root.set("quick", Json::Bool(quick));
    root.set("cache_build_ms", Json::num(build_ms));
    root.set("cache_containers", Json::num(built.files.len() as f64));
    root.set("nets", Json::arr(results));
    std::fs::write("BENCH_coldstart.json", root.to_pretty())?;
    println!("wrote BENCH_coldstart.json");

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
