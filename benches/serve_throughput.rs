//! Serving-engine throughput bench: LeNet under a closed-loop load test
//! at micro-batch caps 1 / 8 / 32 in-process, plus the same engine
//! config behind the HTTP front-end (real sockets, persistent
//! connections), emitting `BENCH_serve.json` (requests/s and p99
//! latency per configuration). `cargo bench --bench serve_throughput`.

use fecaffe::serve::{
    http_load_test, load_test, DeviceKind, Engine, EngineConfig, HttpConfig, HttpServer,
    ModelRouter, RouterConfig,
};
use fecaffe::util::json::Json;
use fecaffe::util::stats::summarize;
use fecaffe::zoo;
use std::sync::Arc;
use std::time::Duration;

const WORKERS: usize = 4;
const CLIENTS: usize = 16;
const REQUESTS: usize = 384;

fn main() -> anyhow::Result<()> {
    let param = zoo::by_name("lenet", 1)?;
    let mut results = Vec::new();
    for &max_batch in &[1usize, 8, 32] {
        let cfg = EngineConfig {
            workers: WORKERS,
            max_batch,
            max_linger: Duration::from_micros(1000),
            queue_capacity: 1024,
            device: DeviceKind::Cpu,
            intra_op_threads: 0, // auto: split the machine across workers
        };
        let engine = Engine::new(&param, cfg)?;
        // Warm the replicas (first forward pays blob upload + scratch
        // growth), then snapshot so warm-up traffic doesn't contaminate
        // the measured batch statistics.
        let _ = load_test(&engine, CLIENTS, CLIENTS * 2, 1);
        let warm = engine.metrics().snapshot();
        let report = load_test(&engine, CLIENTS, REQUESTS, 7);
        engine.shutdown();
        let snap = engine.metrics().snapshot();
        let batches = snap.batches - warm.batches;
        let samples = snap.batched_samples - warm.batched_samples;
        let mean_batch = if batches == 0 { 0.0 } else { samples as f64 / batches as f64 };

        anyhow::ensure!(report.requests > 0, "no completed requests at max-batch {max_batch}");
        let mut lats = report.latencies_ns.clone();
        let s = summarize(&format!("lenet serve, max-batch {max_batch:>2}"), &mut lats);
        println!(
            "{}   ({:.1} req/s, mean batch {mean_batch:.2})",
            s.line(),
            report.rps,
        );

        let mut o = Json::obj();
        o.set("transport", Json::str("inproc"));
        o.set("max_batch", Json::num(max_batch as f64));
        o.set("requests", Json::num(report.requests as f64));
        o.set("failed", Json::num(report.failed as f64));
        o.set("rps", Json::num(report.rps));
        o.set("p50_ms", Json::num(s.median_ns / 1e6));
        o.set("p99_ms", Json::num(s.p99_ns / 1e6));
        o.set("mean_batch", Json::num(mean_batch));
        results.push(o);
    }

    // HTTP path: the same serving stack behind the TcpListener
    // front-end — measures end-to-end over real sockets (parse +
    // JSON + engine), the number an external load generator sees.
    {
        let cfg = RouterConfig {
            total_workers: WORKERS,
            max_batch: 8,
            max_linger: Duration::from_micros(1000),
            queue_capacity: 1024,
            device: DeviceKind::Cpu,
            intra_op_threads: 0,
        };
        let router = Arc::new(ModelRouter::from_zoo(&["lenet"], &cfg)?);
        let sample_len = router.engine("lenet").expect("registered").sample_len();
        let server = HttpServer::bind("127.0.0.1:0", router, HttpConfig::default())?;
        let addr = server.local_addr().to_string();
        let _ = http_load_test(&addr, "lenet", sample_len, CLIENTS, CLIENTS * 2, 1)?; // warm
        let report = http_load_test(&addr, "lenet", sample_len, CLIENTS, REQUESTS, 7)?;
        server.shutdown();
        anyhow::ensure!(report.requests > 0, "no completed requests over HTTP");
        let mut lats = report.latencies_ns.clone();
        let s = summarize("lenet serve, http max-batch  8", &mut lats);
        println!("{}   ({:.1} req/s over HTTP)", s.line(), report.rps);

        let mut o = Json::obj();
        o.set("transport", Json::str("http"));
        o.set("max_batch", Json::num(8.0));
        o.set("requests", Json::num(report.requests as f64));
        o.set("failed", Json::num(report.failed as f64));
        o.set("rps", Json::num(report.rps));
        o.set("p50_ms", Json::num(s.median_ns / 1e6));
        o.set("p99_ms", Json::num(s.p99_ns / 1e6));
        results.push(o);
    }

    let mut root = Json::obj();
    root.set("bench", Json::str("serve_throughput"));
    root.set("net", Json::str("lenet"));
    root.set("workers", Json::num(WORKERS as f64));
    root.set("clients", Json::num(CLIENTS as f64));
    root.set("results", Json::Arr(results));
    std::fs::write("BENCH_serve.json", root.to_pretty())?;
    println!("wrote BENCH_serve.json");
    Ok(())
}
