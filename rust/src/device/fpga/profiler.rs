//! FPGA-side profiling counters + timeline, standing in for the Intel
//! OpenCL profiler and VTune (paper §4.2/4.3, Table 2, Figures 4/5).

use crate::device::KClass;
use std::collections::BTreeMap;

/// Aggregated per-kernel-class statistics — one row of paper Table 2.
#[derive(Debug, Clone, Default)]
pub struct ClassStats {
    pub instances: u64,
    pub total_ns: u64,
}

/// One span on the device/host timeline (chrome-trace compatible).
#[derive(Debug, Clone)]
pub struct Span {
    /// Lane: "fpga-kernel", "pcie", "host".
    pub lane: &'static str,
    pub name: String,
    pub start_ns: u64,
    pub dur_ns: u64,
}

#[derive(Debug, Default)]
pub struct Profiler {
    stats: BTreeMap<KClass, ClassStats>,
    spans: Vec<Span>,
    /// Recording spans costs memory; tables only need counters.
    pub record_spans: bool,
    pub artifact_launches: u64,
    pub native_launches: u64,
}

impl Profiler {
    pub fn new() -> Profiler {
        Profiler::default()
    }

    pub fn record(&mut self, class: KClass, name: &str, lane: &'static str, start_ns: u64, dur_ns: u64) {
        let e = self.stats.entry(class).or_default();
        e.instances += 1;
        e.total_ns += dur_ns;
        if self.record_spans {
            self.spans.push(Span {
                lane,
                name: name.to_string(),
                start_ns,
                dur_ns,
            });
        }
    }

    pub fn stats(&self) -> &BTreeMap<KClass, ClassStats> {
        &self.stats
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Drain the recorded spans, leaving counters untouched — how the
    /// serving worker collects one sampled batch's device lanes without
    /// resetting the Table-2 aggregates.
    pub fn take_spans(&mut self) -> Vec<Span> {
        std::mem::take(&mut self.spans)
    }

    pub fn reset(&mut self) {
        self.stats.clear();
        self.spans.clear();
        self.artifact_launches = 0;
        self.native_launches = 0;
    }

    /// Total kernel + transfer time (Table 2's "Total" row numerator).
    pub fn total_ns(&self) -> u64 {
        self.stats.values().map(|s| s.total_ns).sum()
    }

    pub fn total_instances(&self) -> u64 {
        self.stats.values().map(|s| s.instances).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_class() {
        let mut p = Profiler::new();
        p.record(KClass::Gemm, "gemm", "fpga-kernel", 0, 100);
        p.record(KClass::Gemm, "gemm", "fpga-kernel", 100, 200);
        p.record(KClass::ReluF, "relu", "fpga-kernel", 300, 10);
        assert_eq!(p.stats()[&KClass::Gemm].instances, 2);
        assert_eq!(p.stats()[&KClass::Gemm].total_ns, 300);
        assert_eq!(p.total_ns(), 310);
        assert_eq!(p.total_instances(), 3);
    }

    #[test]
    fn spans_only_when_enabled() {
        let mut p = Profiler::new();
        p.record(KClass::Gemm, "g", "fpga-kernel", 0, 1);
        assert!(p.spans().is_empty());
        p.record_spans = true;
        p.record(KClass::Gemm, "g", "fpga-kernel", 1, 1);
        assert_eq!(p.spans().len(), 1);
    }

    #[test]
    fn take_spans_drains_timeline_but_keeps_counters() {
        let mut p = Profiler::new();
        p.record_spans = true;
        p.record(KClass::Gemm, "g", "fpga-kernel", 0, 5);
        let spans = p.take_spans();
        assert_eq!(spans.len(), 1);
        assert!(p.spans().is_empty());
        assert_eq!(p.stats()[&KClass::Gemm].instances, 1);
    }

    #[test]
    fn reset_clears() {
        let mut p = Profiler::new();
        p.record(KClass::Gemm, "g", "fpga-kernel", 0, 1);
        p.reset();
        assert_eq!(p.total_instances(), 0);
    }
}
