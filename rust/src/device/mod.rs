//! Device abstraction: the L2 "wrapper layer" of the paper's hierarchy.
//!
//! Layers never compute directly — they enqueue [`KernelCall`]s on a
//! [`Device`], exactly as FeCaffe's class layer invokes kernel-related
//! runtimes. Two devices exist:
//!
//! * [`cpu::CpuDevice`] — the host fallback (paper §3.3): native Rust math,
//!   zero-cost `write`/`read`;
//! * [`fpga::FpgaSimDevice`] — the simulated Stratix 10 board: buffers live
//!   in a capacity-limited device-DDR arena, `write`/`read` bill PCIe
//!   transfers, `launch` executes the kernel numerically (through a PJRT
//!   artifact when one exists) and bills simulated device time through the
//!   cost model.
//!
//! The [`Kernel`] enum is the complete kernel inventory of paper Table 2
//! plus the solver-update kernels of §4.3.

pub mod native;
pub mod cpu;
pub mod fpga;

use crate::math::{ConvGeom, PoolGeom};

/// Opaque device buffer handle (index into the device's slab/arena).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BufId(pub usize);

/// Typed device failure. Real OpenCL runtimes distinguish recoverable
/// launch hiccups (a transient PCIe/DMA error, a queue flush) from
/// permanent board state (out of device DDR, a lost context); the
/// serving worker retries [`DeviceError::Transient`] failures with a
/// short backoff before failing the batch, while
/// [`DeviceError::Permanent`] fails it immediately.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DeviceError {
    /// Retryable: the same call may succeed on a fresh attempt.
    Transient(String),
    /// Not retryable: the device (or the request) is at fault and a
    /// retry would fail identically.
    Permanent(String),
}

impl std::fmt::Display for DeviceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeviceError::Transient(m) => write!(f, "transient device error: {m}"),
            DeviceError::Permanent(m) => write!(f, "permanent device error: {m}"),
        }
    }
}

impl std::error::Error for DeviceError {}

/// True when `err` carries a [`DeviceError::Transient`] anywhere in its
/// chain — the worker's retry gate. Untyped errors (the historical
/// `anyhow!` paths) are conservatively treated as permanent.
pub fn is_transient(err: &anyhow::Error) -> bool {
    err.chain()
        .any(|c| matches!(c.downcast_ref::<DeviceError>(), Some(DeviceError::Transient(_))))
}

/// Kernel-class grouping used for Table 2 rows and cost-model efficiency
/// lookup. Names follow the paper's table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KClass {
    Gemm,
    Gemv,
    Im2col,
    Col2im,
    MaxPoolF,
    MaxPoolB,
    AvePoolF,
    AvePoolB,
    ReluF,
    ReluB,
    LrnScale,
    LrnOutput,
    LrnDiff,
    DropoutF,
    DropoutB,
    Bias,
    Softmax,
    SoftmaxLossF,
    SoftmaxLossB,
    Concat,
    Split,
    Add,
    Asum,
    Axpy,
    Scal,
    Eltwise,
    Solver,
    WriteBuffer,
    ReadBuffer,
}

impl KClass {
    /// Row label as printed in paper Table 2.
    pub fn label(&self) -> &'static str {
        match self {
            KClass::Gemm => "Gemm",
            KClass::Gemv => "Gemv",
            KClass::Im2col => "Im2col",
            KClass::Col2im => "Col2im",
            KClass::MaxPoolF => "Max_pool_F",
            KClass::MaxPoolB => "Max_pool_B",
            KClass::AvePoolF => "Ave_pool_F",
            KClass::AvePoolB => "Ave_pool_B",
            KClass::ReluF => "ReLU_F",
            KClass::ReluB => "ReLU_B",
            KClass::LrnScale => "LRN_Scale",
            KClass::LrnOutput => "LRN_Output",
            KClass::LrnDiff => "LRN_Diff",
            KClass::DropoutF => "Dropout_F",
            KClass::DropoutB => "Dropout_B",
            KClass::Bias => "Bias",
            KClass::Softmax => "Softmax",
            KClass::SoftmaxLossF => "SoftmaxLoss_F",
            KClass::SoftmaxLossB => "SoftmaxLoss_B",
            KClass::Concat => "Concat",
            KClass::Split => "Split",
            KClass::Add => "Add",
            KClass::Asum => "Asum",
            KClass::Axpy => "Axpy",
            KClass::Scal => "Scale",
            KClass::Eltwise => "Eltwise",
            KClass::Solver => "Solver_Update",
            KClass::WriteBuffer => "Write_Buffer",
            KClass::ReadBuffer => "Read_Buffer",
        }
    }
}

/// The kernel inventory. Input/output buffer conventions are documented on
/// each variant as `in:[...] out:[...]`; an in-place buffer appears in
/// both lists with the same id.
#[derive(Debug, Clone, PartialEq)]
pub enum Kernel {
    /// C = alpha*A*B + beta*C. in:[a,b] out:[c]
    GemmNN { m: usize, n: usize, k: usize, alpha: f32, beta: f32 },
    /// C = alpha*A*B^T + beta*C. in:[a,b] out:[c]
    GemmNT { m: usize, n: usize, k: usize, alpha: f32, beta: f32 },
    /// C = alpha*A^T*B + beta*C (A stored k-major as in caffe). in:[a,b] out:[c]
    GemmTN { m: usize, n: usize, k: usize, alpha: f32, beta: f32 },
    /// y = alpha*op(A)x + beta*y. in:[a,x] out:[y]
    Gemv { trans: bool, m: usize, n: usize, alpha: f32, beta: f32 },
    /// y += alpha*x. in:[x] out:[y]
    Axpy { n: usize, alpha: f32 },
    /// y = alpha*x + beta*y. in:[x] out:[y]
    Axpby { n: usize, alpha: f32, beta: f32 },
    /// x *= alpha. in:[x] out:[x]
    Scal { n: usize, alpha: f32 },
    /// out[0] = sum |x|. in:[x] out:[r(1)]
    Asum { n: usize },
    /// z = x + y. in:[x,y] out:[z]
    Add { n: usize },
    /// z = x * y. in:[x,y] out:[z]
    Mul { n: usize },
    /// y = x^p. in:[x] out:[y]
    PowX { n: usize, p: f32 },
    /// x = value. in:[] out:[x]
    SetConst { n: usize, value: f32 },
    /// Split-layer gradient accumulation: y += x. in:[x] out:[y]
    Split { n: usize },
    /// One image. in:[im] out:[col]
    Im2col { geom: ConvGeom },
    /// One image, accumulating. in:[col] out:[im]
    Col2im { geom: ConvGeom },
    /// Whole batch. in:[bottom] out:[top,mask]
    MaxPoolF { geom: PoolGeom, num: usize },
    /// in:[top_diff,mask] out:[bottom_diff] (kernel zeroes output first)
    MaxPoolB { geom: PoolGeom, num: usize },
    /// in:[bottom] out:[top]
    AvePoolF { geom: PoolGeom, num: usize },
    /// in:[top_diff] out:[bottom_diff] (zeroed first)
    AvePoolB { geom: PoolGeom, num: usize },
    /// in:[bottom] out:[top]
    ReluF { n: usize, slope: f32 },
    /// in:[bottom_data,top_diff] out:[bottom_diff]
    ReluB { n: usize, slope: f32 },
    /// Whole batch, (num, channels, dim). in:[bottom] out:[scale]
    LrnScale { num: usize, channels: usize, dim: usize, local_size: usize, alpha: f32, k: f32 },
    /// in:[bottom,scale] out:[top]
    LrnOutput { n: usize, beta: f32 },
    /// in:[bottom,top,scale,top_diff] out:[bottom_diff]
    LrnDiff {
        num: usize,
        channels: usize,
        dim: usize,
        local_size: usize,
        alpha: f32,
        beta: f32,
    },
    /// in:[bottom,mask] out:[top]
    DropoutF { n: usize, scale: f32 },
    /// in:[top_diff,mask] out:[bottom_diff]
    DropoutB { n: usize, scale: f32 },
    /// top[o,c,:] += bias[c]. in:[bias] out:[top]
    BiasF { outer: usize, channels: usize, dim: usize },
    /// Row-wise softmax (n,c). in:[bottom] out:[top]
    SoftmaxF { n: usize, c: usize },
    /// Mean NLL. in:[prob,label] out:[loss(1)]
    SoftmaxLossF { n: usize, c: usize },
    /// in:[prob,label] out:[bottom_diff]
    SoftmaxLossB { n: usize, c: usize, weight: f32 },
    /// Concat/de-concat one bottom into/out of the channel axis.
    /// Forward: in:[bottom_i] out:[top]; backward: in:[top_diff] out:[bottom_diff_i].
    /// `this` = channels*dim of this input, `total` = channels*dim of top,
    /// `offset` = channel-offset*dim within top, over `num` images.
    ConcatF { num: usize, this: usize, total: usize, offset: usize },
    ConcatB { num: usize, this: usize, total: usize, offset: usize },
    /// Solver weight updates (paper §4.3). All operate on n-length params.
    /// SGD: hist = momentum*hist + lr*diff; data -= hist.
    /// in:[diff] out:[hist,data]
    SgdUpdate { n: usize, lr: f32, momentum: f32 },
    /// Nesterov: hist_new = momentum*hist + lr*diff;
    /// data -= (1+momentum)*hist_new - momentum*hist_old.
    NesterovUpdate { n: usize, lr: f32, momentum: f32 },
    /// AdaGrad: hist += diff^2; data -= lr*diff/(sqrt(hist)+delta).
    AdaGradUpdate { n: usize, lr: f32, delta: f32 },
    /// RMSProp: hist = decay*hist + (1-decay)*diff^2;
    /// data -= lr*diff/(sqrt(hist)+delta).
    RmsPropUpdate { n: usize, lr: f32, decay: f32, delta: f32 },
    /// AdaDelta (two history slots). in:[diff] out:[hist1,hist2,data]
    AdaDeltaUpdate { n: usize, momentum: f32, delta: f32, lr: f32 },
    /// Adam (m, v slots + bias correction by step t).
    /// in:[diff] out:[m,v,data]
    AdamUpdate { n: usize, lr: f32, beta1: f32, beta2: f32, delta: f32, t: usize },
}

impl Kernel {
    pub fn class(&self) -> KClass {
        use Kernel::*;
        match self {
            GemmNN { .. } | GemmNT { .. } | GemmTN { .. } => KClass::Gemm,
            Gemv { .. } => KClass::Gemv,
            Axpy { .. } | Axpby { .. } => KClass::Axpy,
            Scal { .. } => KClass::Scal,
            Asum { .. } => KClass::Asum,
            Add { .. } => KClass::Add,
            Mul { .. } | PowX { .. } | SetConst { .. } => KClass::Eltwise,
            Split { .. } => KClass::Split,
            Im2col { .. } => KClass::Im2col,
            Col2im { .. } => KClass::Col2im,
            MaxPoolF { .. } => KClass::MaxPoolF,
            MaxPoolB { .. } => KClass::MaxPoolB,
            AvePoolF { .. } => KClass::AvePoolF,
            AvePoolB { .. } => KClass::AvePoolB,
            ReluF { .. } => KClass::ReluF,
            ReluB { .. } => KClass::ReluB,
            LrnScale { .. } => KClass::LrnScale,
            LrnOutput { .. } => KClass::LrnOutput,
            LrnDiff { .. } => KClass::LrnDiff,
            DropoutF { .. } => KClass::DropoutF,
            DropoutB { .. } => KClass::DropoutB,
            BiasF { .. } => KClass::Bias,
            SoftmaxF { .. } => KClass::Softmax,
            SoftmaxLossF { .. } => KClass::SoftmaxLossF,
            SoftmaxLossB { .. } => KClass::SoftmaxLossB,
            ConcatF { .. } | ConcatB { .. } => KClass::Concat,
            SgdUpdate { .. }
            | NesterovUpdate { .. }
            | AdaGradUpdate { .. }
            | RmsPropUpdate { .. }
            | AdaDeltaUpdate { .. }
            | AdamUpdate { .. } => KClass::Solver,
        }
    }

    /// Floating-point operations of one invocation (cost-model input).
    pub fn flops(&self) -> u64 {
        use Kernel::*;
        match self {
            GemmNN { m, n, k, .. } | GemmNT { m, n, k, .. } | GemmTN { m, n, k, .. } => {
                2 * (*m as u64) * (*n as u64) * (*k as u64)
            }
            Gemv { m, n, .. } => 2 * (*m as u64) * (*n as u64),
            Axpy { n, .. } | Axpby { n, .. } => 2 * *n as u64,
            Scal { n, .. } => *n as u64,
            Asum { n } | Add { n } | Split { n } => *n as u64,
            Mul { n } => *n as u64,
            PowX { n, .. } => 8 * *n as u64, // powf ≈ several ops
            SetConst { .. } => 0,
            Im2col { .. } | Col2im { .. } => 0,
            MaxPoolF { geom, num } | MaxPoolB { geom, num } => {
                (*num * geom.out_len() * geom.kernel_h * geom.kernel_w) as u64
            }
            AvePoolF { geom, num } | AvePoolB { geom, num } => {
                (*num * geom.out_len() * geom.kernel_h * geom.kernel_w) as u64
            }
            ReluF { n, .. } | ReluB { n, .. } => *n as u64,
            LrnScale { num, channels, dim, local_size, .. } => {
                (*num * channels * dim * (2 * local_size + 2)) as u64
            }
            LrnOutput { n, .. } => 8 * *n as u64,
            LrnDiff { num, channels, dim, local_size, .. } => {
                (*num * channels * dim * (3 * local_size + 10)) as u64
            }
            DropoutF { n, .. } | DropoutB { n, .. } => 2 * *n as u64,
            BiasF { outer, channels, dim } => (*outer * channels * dim) as u64,
            SoftmaxF { n, c } => (*n * c * 10) as u64,
            SoftmaxLossF { n, .. } => (*n * 10) as u64,
            SoftmaxLossB { n, c, .. } => (*n * c * 2) as u64,
            ConcatF { num, this, .. } | ConcatB { num, this, .. } => (*num * this) as u64,
            SgdUpdate { n, .. } | NesterovUpdate { n, .. } => 4 * *n as u64,
            AdaGradUpdate { n, .. } | RmsPropUpdate { n, .. } => 8 * *n as u64,
            AdaDeltaUpdate { n, .. } => 12 * *n as u64,
            AdamUpdate { n, .. } => 12 * *n as u64,
        }
    }

    /// DDR bytes moved by one invocation (cost-model input).
    pub fn bytes(&self) -> u64 {
        use Kernel::*;
        const W: u64 = 4;
        match self {
            GemmNN { m, n, k, beta, .. }
            | GemmNT { m, n, k, beta, .. }
            | GemmTN { m, n, k, beta, .. } => {
                // Tiled: A and B panels re-streamed once per opposite tile
                // is absorbed into the efficiency constant; count algebraic
                // traffic.
                let c_rw = if *beta == 0.0 { 1 } else { 2 };
                W * ((m * k) as u64 + (k * n) as u64 + c_rw * (m * n) as u64)
            }
            Gemv { m, n, .. } => W * ((m * n) as u64 + *n as u64 + 2 * *m as u64),
            Axpy { n, .. } | Axpby { n, .. } => W * 3 * *n as u64,
            Scal { n, .. } => W * 2 * *n as u64,
            Asum { n } => W * *n as u64,
            Add { n } | Split { n } => W * 3 * *n as u64,
            Mul { n } => W * 3 * *n as u64,
            PowX { n, .. } => W * 2 * *n as u64,
            SetConst { n, .. } => W * *n as u64,
            Im2col { geom } => W * 2 * geom.col_len() as u64,
            Col2im { geom } => W * (2 * geom.col_len() + geom.im_len()) as u64,
            // Pools: the paper's pooling kernels are plain NDRange ports
            // with NO local-memory window buffering (§3.2: only gemm/gemv
            // were optimized) — every output work-item re-reads its whole
            // kh*kw window from DDR.
            MaxPoolF { geom, num } => {
                let win = geom.kernel_h * geom.kernel_w;
                W * (*num as u64) * (geom.out_len() * win + 2 * geom.out_len()) as u64
            }
            MaxPoolB { geom, num } => {
                let win = geom.kernel_h * geom.kernel_w;
                W * (*num as u64)
                    * (geom.out_len() * win + geom.in_len() + 2 * geom.out_len()) as u64
            }
            AvePoolF { geom, num } => {
                let win = geom.kernel_h * geom.kernel_w;
                W * (*num as u64) * (geom.out_len() * win + geom.out_len()) as u64
            }
            AvePoolB { geom, num } => {
                let win = geom.kernel_h * geom.kernel_w;
                W * (*num as u64) * (geom.out_len() * win + geom.in_len()) as u64
            }
            ReluF { n, .. } => W * 2 * *n as u64,
            ReluB { n, .. } => W * 3 * *n as u64,
            LrnScale { num, channels, dim, .. } => {
                W * (*num * channels * dim) as u64 * 2
            }
            LrnOutput { n, .. } => W * 3 * *n as u64,
            LrnDiff { num, channels, dim, .. } => W * (*num * channels * dim) as u64 * 5,
            DropoutF { n, .. } | DropoutB { n, .. } => W * 3 * *n as u64,
            BiasF { outer, channels, dim } => {
                W * (2 * (*outer * channels * dim) as u64 + *channels as u64)
            }
            SoftmaxF { n, c } => W * 2 * (*n * c) as u64,
            SoftmaxLossF { n, c } => W * ((*n * c) as u64 + 2 * *n as u64),
            SoftmaxLossB { n, c, .. } => W * (2 * (*n * c) as u64 + *n as u64),
            ConcatF { num, this, .. } | ConcatB { num, this, .. } => {
                W * 2 * (*num * this) as u64
            }
            SgdUpdate { n, .. } | NesterovUpdate { n, .. } => W * 5 * *n as u64,
            AdaGradUpdate { n, .. } | RmsPropUpdate { n, .. } => W * 5 * *n as u64,
            AdaDeltaUpdate { n, .. } => W * 7 * *n as u64,
            AdamUpdate { n, .. } => W * 7 * *n as u64,
        }
    }
}

/// One enqueued kernel invocation. Buffers may be addressed at an element
/// offset (per-image slices, per-group weight panels — the same
/// sub-buffer addressing OpenCL kernels get via pointer arithmetic on
/// `__global` args).
#[derive(Debug, Clone)]
pub struct KernelCall {
    pub kernel: Kernel,
    pub inputs: Vec<BufId>,
    pub outputs: Vec<BufId>,
    /// Element offsets aligned with `inputs` / `outputs` (empty ⇒ zeros).
    pub in_offsets: Vec<usize>,
    pub out_offsets: Vec<usize>,
}

impl KernelCall {
    pub fn new(kernel: Kernel, inputs: &[BufId], outputs: &[BufId]) -> KernelCall {
        KernelCall {
            kernel,
            inputs: inputs.to_vec(),
            outputs: outputs.to_vec(),
            in_offsets: vec![0; inputs.len()],
            out_offsets: vec![0; outputs.len()],
        }
    }

    /// Builder: set element offsets (must match arity).
    pub fn at(mut self, in_offsets: &[usize], out_offsets: &[usize]) -> KernelCall {
        assert_eq!(in_offsets.len(), self.inputs.len());
        assert_eq!(out_offsets.len(), self.outputs.len());
        self.in_offsets = in_offsets.to_vec();
        self.out_offsets = out_offsets.to_vec();
        self
    }
}

/// The device interface (paper L2: common runtime = alloc/write/read,
/// kernel-related runtime = launch).
pub trait Device {
    fn kind(&self) -> &'static str;
    fn alloc(&mut self, len: usize) -> anyhow::Result<BufId>;
    fn free(&mut self, id: BufId);
    /// Host → device copy (bills PCIe on the FPGA sim).
    fn write(&mut self, id: BufId, data: &[f32]);
    /// Device → host copy (bills PCIe on the FPGA sim).
    fn read(&mut self, id: BufId, out: &mut [f32]);
    /// Enqueue + (synchronously or asynchronously) execute a kernel.
    fn launch(&mut self, call: &KernelCall) -> anyhow::Result<()>;
    /// Drain any outstanding async work (no-op on sync devices).
    fn synchronize(&mut self) {}
    /// Simulated device-time clock in ns (None ⇒ use wallclock).
    fn sim_clock_ns(&self) -> Option<u64> {
        None
    }
    /// Enable/disable span recording on the device profiler. No-op on
    /// devices without one (CPU) — the serving worker toggles this per
    /// *sampled* batch, so unprofiled devices pay nothing.
    fn set_span_recording(&mut self, _on: bool) {}
    /// Drain the profiler's recorded spans (lanes "host" / "pcie" /
    /// "fpga-kernel", timestamps on the simulated clock). Empty on
    /// devices without a profiler.
    fn take_spans(&mut self) -> Vec<fpga::profiler::Span> {
        Vec::new()
    }
    /// Per-kernel-class `(label, instances, total_ns)` rows accumulated
    /// since the last reset — the paper's Table 2 accounting. Empty on
    /// devices without a profiler.
    fn kernel_stats(&self) -> Vec<(&'static str, u64, u64)> {
        Vec::new()
    }
    /// Reset simulated clocks and profiler counters. No-op on
    /// wallclock devices.
    fn reset_timing(&mut self) {}
    /// Shared scratch buffer for slot `slot`, at least `len` elements.
    /// Conv layers share slots 0 (col) and 1 (col_diff) — one DDR scratch
    /// region for the whole net, like the OpenCL implementation's global
    /// im2col buffer (keeps VGG-16 within board memory).
    fn scratch(&mut self, slot: usize, len: usize) -> anyhow::Result<BufId>;
}

/// Reusable scratch-slot bookkeeping shared by the device impls:
/// `plan` tells the device what to do, `commit` records the result.
#[derive(Debug, Default)]
pub struct ScratchPool {
    slots: Vec<Option<(BufId, usize)>>,
}

/// Outcome of a scratch request.
pub enum ScratchAction {
    /// Existing buffer is big enough.
    Use(BufId),
    /// Free this buffer, allocate `len`, then `commit` the new id.
    Grow(Option<BufId>),
}

impl ScratchPool {
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    pub fn plan(&mut self, slot: usize, len: usize) -> ScratchAction {
        if self.slots.len() <= slot {
            self.slots.resize(slot + 1, None);
        }
        match self.slots[slot] {
            Some((id, cap)) if cap >= len => ScratchAction::Use(id),
            Some((id, _)) => ScratchAction::Grow(Some(id)),
            None => ScratchAction::Grow(None),
        }
    }

    pub fn commit(&mut self, slot: usize, id: BufId, len: usize) {
        self.slots[slot] = Some((id, len));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_and_bytes() {
        let k = Kernel::GemmNN { m: 2, n: 3, k: 4, alpha: 1.0, beta: 0.0 };
        assert_eq!(k.flops(), 48);
        assert_eq!(k.bytes(), 4 * (8 + 12 + 6));
        assert_eq!(k.class(), KClass::Gemm);
        let kb = Kernel::GemmNN { m: 2, n: 3, k: 4, alpha: 1.0, beta: 1.0 };
        assert!(kb.bytes() > k.bytes());
    }

    #[test]
    fn class_labels_match_paper() {
        assert_eq!(Kernel::Im2col { geom: dummy_geom() }.class().label(), "Im2col");
        assert_eq!(
            Kernel::MaxPoolF { geom: dummy_pool(), num: 1 }.class().label(),
            "Max_pool_F"
        );
        assert_eq!(Kernel::Split { n: 1 }.class().label(), "Split");
        assert_eq!(KClass::WriteBuffer.label(), "Write_Buffer");
    }

    fn dummy_geom() -> ConvGeom {
        ConvGeom {
            channels: 1,
            height: 4,
            width: 4,
            kernel_h: 2,
            kernel_w: 2,
            pad_h: 0,
            pad_w: 0,
            stride_h: 1,
            stride_w: 1,
        }
    }

    fn dummy_pool() -> PoolGeom {
        PoolGeom {
            channels: 1,
            height: 4,
            width: 4,
            kernel_h: 2,
            kernel_w: 2,
            pad_h: 0,
            pad_w: 0,
            stride_h: 2,
            stride_w: 2,
        }
    }

    #[test]
    fn transient_errors_are_detected_through_anyhow_chains() {
        let e = anyhow::Error::new(DeviceError::Transient("dma hiccup".into()));
        assert!(is_transient(&e));
        // Context layers don't hide the typed cause.
        let wrapped = e.context("launching Gemm");
        assert!(is_transient(&wrapped));
        let p = anyhow::Error::new(DeviceError::Permanent("out of device DDR".into()));
        assert!(!is_transient(&p));
        // Untyped errors stay permanent (no blind retries).
        assert!(!is_transient(&anyhow::anyhow!("some legacy failure")));
        assert!(DeviceError::Transient("x".into()).to_string().contains("transient"));
    }

    #[test]
    fn every_kernel_has_positive_bytes() {
        let kernels = vec![
            Kernel::Axpy { n: 10, alpha: 1.0 },
            Kernel::Scal { n: 10, alpha: 2.0 },
            Kernel::Asum { n: 10 },
            Kernel::ReluF { n: 10, slope: 0.0 },
            Kernel::SoftmaxF { n: 2, c: 5 },
            Kernel::AdamUpdate { n: 10, lr: 0.1, beta1: 0.9, beta2: 0.99, delta: 1e-8, t: 1 },
            Kernel::ConcatF { num: 1, this: 8, total: 16, offset: 0 },
        ];
        for k in kernels {
            assert!(k.bytes() > 0, "{k:?}");
        }
    }
}
