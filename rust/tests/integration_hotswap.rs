//! Integration: weight hot-swap semantics, end to end.
//!
//! The contract under test (ROADMAP "Weight hot-swap"):
//!
//! * `Engine::publish_weights` is atomic — under concurrent predict +
//!   publish load, every response is computed from exactly one snapshot
//!   version (old or new, never mixed), proven by making each version's
//!   weights produce a distinct, exactly-predictable output;
//! * no request is ever dropped or failed by a publish;
//! * after a publish returns and the queue drains, all subsequent
//!   responses report the new version;
//! * a live training solver publishes straight into a running engine
//!   (the paper's train-and-serve-in-one-framework claim);
//! * training-net snapshots project onto deploy nets that pruned
//!   param-carrying layers (GoogLeNet-style aux heads);
//! * bad snapshots are refused before they can reach a worker.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::net::{Net, WeightSnapshot};
use fecaffe::proto::{parse_net, NetParameter, Phase, SolverParameter};
use fecaffe::serve::{DeviceKind, Engine, EngineConfig, PublishError};
use fecaffe::solver::Solver;
use fecaffe::zoo;
use std::collections::HashMap;
use std::time::Duration;

/// Deploy-style net whose output is a pure linear map of the weights:
/// with every parameter set to the constant `c`, the output is exactly
/// predictable, so a response proves which snapshot computed it.
const SWAP_NET: &str = r#"
name: "swapnet"
input: "data"
input_shape { dim: 1 dim: 1 dim: 1 dim: 4 }
layer { name: "fc" type: "InnerProduct" bottom: "data" top: "fc"
        inner_product_param { num_output: 2 weight_filler { type: "xavier" } } }
"#;

/// Train_val net with an auxiliary classifier branch: the deploy
/// transform prunes layer "aux" (no path to the output), so its params
/// exist in training snapshots but not in the serving engine.
const AUX_NET: &str = r#"
name: "auxnet"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 2 channels: 1 height: 4 width: 4 num_classes: 3 source: "digits" seed: 2 } }
layer { name: "trunk" type: "InnerProduct" bottom: "data" top: "trunk"
        inner_product_param { num_output: 6 weight_filler { type: "xavier" } } }
layer { name: "aux" type: "InnerProduct" bottom: "trunk" top: "aux"
        inner_product_param { num_output: 3 weight_filler { type: "xavier" } } }
layer { name: "aux_loss" type: "SoftmaxWithLoss" bottom: "aux" bottom: "label" top: "aux_loss" }
layer { name: "main" type: "InnerProduct" bottom: "trunk" top: "main"
        inner_product_param { num_output: 3 weight_filler { type: "xavier" } } }
layer { name: "main_loss" type: "SoftmaxWithLoss" bottom: "main" bottom: "label" top: "main_loss" }
"#;

fn engine_for(param: &NetParameter, workers: usize, max_batch: usize) -> Engine {
    Engine::new(
        param,
        EngineConfig {
            workers,
            max_batch,
            max_linger: Duration::from_micros(500),
            queue_capacity: 256,
            device: DeviceKind::Cpu,
            intra_op_threads: 1,
            trace_sample: 0,
            ..EngineConfig::default()
        },
    )
    .unwrap()
}

/// Snapshot of `param`'s net with every parameter set to `c`.
fn constant_snapshot(param: &NetParameter, c: f32, version: u64) -> WeightSnapshot {
    let mut dev = CpuDevice::new();
    let mut net = Net::from_param(param, Phase::Test, &mut dev).unwrap();
    for p in net.params() {
        let blob = p.blob.clone();
        let mut b = blob.borrow_mut();
        for w in b.data.host_data_mut(&mut dev).iter_mut() {
            *w = c;
        }
    }
    net.share_weights(&mut dev).with_version(version)
}

/// Reference forward: a fresh replica adopting `snap`, fed `input`.
fn forward_with(param: &NetParameter, snap: &WeightSnapshot, input: &[f32]) -> Vec<f32> {
    let mut dev = CpuDevice::new();
    let mut net = Net::from_param(param, Phase::Test, &mut dev).unwrap();
    net.adopt_weights(&mut dev, snap).unwrap();
    let in_blob = net.blob("data").unwrap();
    in_blob.borrow_mut().set_data(&mut dev, input);
    net.forward(&mut dev).unwrap();
    let out = net.blob("fc").unwrap();
    let v = out.borrow_mut().data_vec(&mut dev);
    v
}

/// The core guarantee: under concurrent predict + publish traffic every
/// response is computed from exactly one snapshot version — its values
/// must match that version's reference output bit for bit — and no
/// request fails or is dropped.
#[test]
fn concurrent_publish_never_mixes_weight_versions() {
    const LAST: u64 = 6;
    let param = parse_net(SWAP_NET).unwrap();
    let engine = engine_for(&param, 2, 4);
    let input = vec![1.0f32; engine.sample_len()];

    let mut snaps: HashMap<u64, WeightSnapshot> = HashMap::new();
    let mut expected: HashMap<u64, Vec<f32>> = HashMap::new();
    for v in 1..=LAST {
        let snap = constant_snapshot(&param, v as f32, v);
        expected.insert(v, forward_with(&param, &snap, &input));
        snaps.insert(v, snap);
    }
    // Distinct weights must give distinct outputs, or the test is vacuous.
    assert_ne!(expected[&1], expected[&2]);

    // Publish v1 before any traffic: every response from here on is
    // computed from a *published* version, never the engine's own init.
    assert_eq!(engine.publish_weights(snaps[&1].clone()).unwrap(), 1);

    let total_per_client = 60;
    std::thread::scope(|scope| {
        let publisher = scope.spawn(|| {
            for v in 2..=LAST {
                std::thread::sleep(Duration::from_millis(8));
                let got = engine.publish_weights(snaps[&v].clone()).unwrap();
                assert_eq!(got, v);
            }
        });
        let clients: Vec<_> = (0..4)
            .map(|_| {
                let input = input.clone();
                let engine = &engine;
                let expected = &expected;
                scope.spawn(move || {
                    let mut seen = Vec::new();
                    for _ in 0..total_per_client {
                        let h = match engine.submit(input.clone()) {
                            Ok(h) => h,
                            Err(e) => panic!("submit failed under publish load: {e}"),
                        };
                        let resp = h.wait().expect("response under publish load");
                        let want = expected.get(&resp.weights_version).unwrap_or_else(|| {
                            panic!("response claims unpublished version {}", resp.weights_version)
                        });
                        assert_eq!(
                            &resp.values, want,
                            "version {} response does not match that version's weights \
                             (mixed snapshot?)",
                            resp.weights_version
                        );
                        seen.push(resp.weights_version);
                    }
                    seen
                })
            })
            .collect();
        publisher.join().unwrap();
        let all: Vec<u64> = clients
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        // Versions only move forward per client stream overall: the
        // engine-wide published version is monotonic, and each response
        // carries some published version.
        assert!(all.iter().all(|v| (1..=LAST).contains(v)), "{all:?}");
    });

    // The publisher finished before the clients stopped submitting, so
    // the queue has drained past the last publish: from here every
    // response must be on the final version.
    let resp = engine.submit(input).unwrap().wait().unwrap();
    assert_eq!(resp.weights_version, LAST);
    assert_eq!(resp.values, expected[&LAST]);

    engine.shutdown();
    let m = engine.metrics().snapshot();
    assert_eq!(m.failed, 0, "no request may fail across hot-swaps");
    assert_eq!(m.completed, 4 * total_per_client as u64 + 1);
    assert_eq!(m.weights_version, LAST);
    assert_eq!(m.publishes, LAST);
}

/// Solver → engine: a live training loop publishes into a running
/// engine via the `publish_every` hook; the served responses equal a
/// reference forward through the solver's exported weights.
#[test]
fn solver_publishes_into_live_engine() {
    let param = zoo::by_name("lenet", 2).unwrap();
    let engine = engine_for(&param, 1, 2);

    let mut dev = CpuDevice::new();
    let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    let mut sp = SolverParameter::default();
    sp.base_lr = 0.01;
    sp.display = 0;
    let mut solver = Solver::new(sp, net, &mut dev).unwrap();

    // 6 iterations, publishing every 2: versions 1, 2, 3 (the engine
    // assigns them; solver snapshots are tagged with the iteration).
    let mut published = Vec::new();
    solver
        .solve_with_publish(&mut dev, 6, 2, &mut |snap| {
            assert!(snap.tag().unwrap().starts_with("iter-"));
            published.push(engine.publish_weights(snap)?);
            Ok(())
        })
        .unwrap();
    assert_eq!(published, vec![1, 2, 3]);
    assert_eq!(engine.weights_version(), 3);

    // A request served now must be computed from the solver's latest
    // published weights: compare against a batch-1 deploy replica
    // adopting the engine's current snapshot.
    let sample: Vec<f32> = (0..engine.sample_len()).map(|i| (i % 7) as f32 / 7.0).collect();
    let resp = engine.submit(sample.clone()).unwrap().wait().unwrap();
    assert_eq!(resp.weights_version, 3);

    let deploy = zoo::deploy(&param, 1).unwrap();
    let mut dev_r = CpuDevice::new();
    let mut replica = Net::from_param(&deploy.param, Phase::Test, &mut dev_r).unwrap();
    replica.adopt_weights(&mut dev_r, &engine.weights()).unwrap();
    let in_blob = replica.blob(&deploy.input).unwrap();
    in_blob.borrow_mut().set_data(&mut dev_r, &sample);
    replica.forward(&mut dev_r).unwrap();
    let out = replica.blob(&deploy.output).unwrap();
    let want = out.borrow_mut().data_vec(&mut dev_r);
    assert_eq!(resp.values, want, "served row must equal the published weights' forward");

    engine.shutdown();
}

/// Reshape under hot-swap: the worker's single replica is reshaped to
/// each batch's bucket *and* adopts published snapshots at batch
/// boundaries — a publish landing between two differently-shaped
/// batches must neither stall the reshape nor leak the old weights into
/// the new shape.
#[test]
fn publish_between_reshapes_serves_exact_versions() {
    let param = parse_net(SWAP_NET).unwrap();
    let engine = engine_for(&param, 1, 4);
    let input = vec![1.0f32; engine.sample_len()];

    let s1 = constant_snapshot(&param, 1.0, 1);
    let e1 = forward_with(&param, &s1, &input);
    let s2 = constant_snapshot(&param, 2.0, 2);
    let e2 = forward_with(&param, &s2, &input);
    assert_ne!(e1, e2);

    engine.publish_weights(s1).unwrap();
    // Lone request: the replica reshapes down to the batch-1 bucket.
    let r = engine.submit(input.clone()).unwrap().wait().unwrap();
    assert_eq!(r.weights_version, 1);
    assert_eq!(r.values, e1);

    // Publish between reshapes, then a burst that reshapes back up.
    engine.publish_weights(s2).unwrap();
    let handles: Vec<_> = (0..3)
        .map(|_| engine.submit(input.clone()).unwrap())
        .collect();
    for h in handles {
        let r = h.wait().unwrap();
        assert_eq!(r.weights_version, 2, "post-publish batch must serve v2");
        assert_eq!(r.values, e2, "reshaped replica leaked old weights");
    }

    // And back down to a lone request on the new version.
    let r = engine.submit(input).unwrap().wait().unwrap();
    assert_eq!((r.weights_version, r.values), (2, e2));

    engine.shutdown();
    let m = engine.metrics().snapshot();
    assert_eq!(m.failed, 0);
    assert_eq!(m.completed, 5);
    // Rows were bucketed, never padded: the two lone requests cost 1 row
    // each and the burst at most its bucket of 4 (pad-to-max would have
    // executed 4 rows for every one of the ≥3 batches).
    assert_eq!(m.filled_rows, 5);
    assert!(
        m.executed_rows <= 6,
        "executed {} rows for 5 requests — still padding?",
        m.executed_rows
    );
}

/// A training-net snapshot with pruned-at-deploy extra params (aux
/// classifier head) publishes cleanly: the engine projects it onto the
/// deploy schema by (owner, slot) key.
#[test]
fn training_snapshot_projects_past_pruned_aux_head() {
    let param = parse_net(AUX_NET).unwrap();
    let engine = engine_for(&param, 1, 2);

    // The training net carries 6 param blobs (trunk, aux, main × w/b);
    // the deploy net pruned "aux", keeping 4.
    let mut dev = CpuDevice::new();
    let mut train = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    let snap = train.share_weights(&mut dev);
    assert_eq!(snap.len(), 6);
    assert_eq!(engine.weights().len(), 4);

    let v = engine.publish_weights(snap).unwrap();
    assert_eq!(v, 1);
    let published = engine.weights();
    assert_eq!(published.len(), 4, "projection keeps only deploy params");
    assert!(
        published.keys().iter().all(|(owner, _)| owner != "aux"),
        "aux params must be projected out: {:?}",
        published.keys()
    );

    // Traffic is served from the projected snapshot without issue.
    let resp = engine
        .submit(vec![0.5; engine.sample_len()])
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.weights_version, 1);
    assert_eq!(resp.values.len(), engine.output_len());
    engine.shutdown();
    assert_eq!(engine.metrics().snapshot().failed, 0);
}

/// Publish rejections: schema mismatches are refused before the swap
/// (and never reach a worker), stale versions are refused for
/// monotonicity, and a failed publish leaves the served version alone.
#[test]
fn bad_publishes_are_refused_and_change_nothing() {
    let param = parse_net(SWAP_NET).unwrap();
    let engine = engine_for(&param, 1, 2);

    // Empty snapshot: missing every param.
    match engine.publish_weights(WeightSnapshot::default()) {
        Err(PublishError::Mismatch(_)) => {}
        other => panic!("expected Mismatch, got {other:?}"),
    }
    // Wrong net entirely (param names differ).
    let other_param = parse_net(AUX_NET).unwrap();
    let mut dev = CpuDevice::new();
    let mut other = Net::from_param(&other_param, Phase::Train, &mut dev).unwrap();
    match engine.publish_weights(other.share_weights(&mut dev)) {
        Err(PublishError::Mismatch(_)) => {}
        other => panic!("expected Mismatch, got {other:?}"),
    }

    // Good publish at v5, then anything ≤ 5 is stale.
    let snap = constant_snapshot(&param, 1.0, 5);
    assert_eq!(engine.publish_weights(snap.clone()).unwrap(), 5);
    match engine.publish_weights(snap.clone().with_version(5)) {
        Err(PublishError::Stale { current: 5, offered: 5 }) => {}
        other => panic!("expected Stale, got {other:?}"),
    }
    match engine.publish_weights(snap.clone().with_version(3)) {
        Err(PublishError::Stale { current: 5, offered: 3 }) => {}
        other => panic!("expected Stale, got {other:?}"),
    }
    // Unversioned snapshots auto-advance past the failures.
    assert_eq!(engine.publish_weights(snap.with_version(0)).unwrap(), 6);
    assert_eq!(engine.weights_version(), 6);

    // The engine still serves, on the surviving version.
    let resp = engine
        .submit(vec![1.0; engine.sample_len()])
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.weights_version, 6);
    engine.shutdown();
}

/// A closed-loop load test with publishes landing mid-stream completes
/// every request: zero failures, zero drops (the acceptance bar for the
/// hot-swap path).
#[test]
fn load_test_with_publishes_has_zero_failures() {
    let param = parse_net(SWAP_NET).unwrap();
    let engine = engine_for(&param, 2, 8);
    let total = 300;
    let report = std::thread::scope(|scope| {
        let publisher = scope.spawn(|| {
            for v in 1..=10u64 {
                std::thread::sleep(Duration::from_millis(3));
                engine
                    .publish_weights(constant_snapshot(&param, v as f32, v))
                    .unwrap();
            }
        });
        let report = fecaffe::serve::load_test(&engine, 4, total, 99);
        publisher.join().unwrap();
        report
    });
    engine.shutdown();
    assert_eq!(report.failed, 0, "publishes must not fail requests");
    assert_eq!(report.requests, total as u64);
    let m = engine.metrics().snapshot();
    assert_eq!(m.failed, 0);
    assert_eq!(m.completed, total as u64);
    assert_eq!(m.publishes, 10);
    assert_eq!(m.weights_version, 10);
}
