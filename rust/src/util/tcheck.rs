//! Hand-rolled property-test harness (proptest is not in the offline
//! vendor set — DESIGN.md §10).
//!
//! `check(name, cases, |rng| ...)` runs a property closure against many
//! PRNG-seeded cases. On failure it panics with the failing case index and
//! the *derived seed*, so the exact case replays with
//! `replay(name, seed, |rng| ...)`. Each case gets an independent PCG
//! stream so shrinking the case count never changes earlier cases.

use crate::util::prng::Pcg32;

pub const DEFAULT_CASES: usize = 64;

/// Base seed: fixed for reproducible CI; override with FECAFFE_TCHECK_SEED.
fn base_seed() -> u64 {
    std::env::var("FECAFFE_TCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_f0ca_ffe0_2019)
}

/// Run `prop` for `cases` random cases. The closure returns `Result<(),
/// String>`; `Err` (or a panic inside) fails the property with replay info.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let base = base_seed();
    for i in 0..cases {
        let seed = base ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Pcg32::with_stream(seed, i as u64);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {i}/{cases}: {msg}\n  \
                 replay: tcheck::replay(\"{name}\", 0x{seed:016x}, {i}, ..)"
            );
        }
    }
}

/// Replay a single failing case.
pub fn replay<F>(_name: &str, seed: u64, case: usize, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let mut rng = Pcg32::with_stream(seed, case as u64);
    prop(&mut rng).expect("replayed property failed");
}

/// Assert two f32 slices match within atol+rtol; returns a useful error.
pub fn close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!(
                "mismatch at [{i}]: {x} vs {y} (|d|={}, tol={tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

/// Random shape helper: dims in [1, max_dim], total elements capped.
pub fn small_shape(rng: &mut Pcg32, rank: usize, max_dim: u32, max_elems: usize) -> Vec<usize> {
    loop {
        let shape: Vec<usize> = (0..rank).map(|_| rng.range_u(1, max_dim) as usize).collect();
        if shape.iter().product::<usize>() <= max_elems {
            return shape;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 10, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_name() {
        check("fails", 5, |rng| {
            if rng.next_f32() >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_detects_mismatch() {
        assert!(close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 0.0).is_ok());
        assert!(close(&[1.0], &[1.1], 1e-3, 0.0).is_err());
        assert!(close(&[1.0], &[1.0, 2.0], 1e-3, 0.0).is_err());
        // rtol scales with magnitude
        assert!(close(&[1000.0], &[1000.5], 0.0, 1e-3).is_ok());
    }

    #[test]
    fn small_shape_respects_caps() {
        let mut rng = Pcg32::new(1);
        for _ in 0..50 {
            let s = small_shape(&mut rng, 4, 8, 256);
            assert_eq!(s.len(), 4);
            assert!(s.iter().product::<usize>() <= 256);
            assert!(s.iter().all(|&d| (1..=8).contains(&d)));
        }
    }
}
