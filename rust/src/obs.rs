//! Unified observability: sampled batch traces, per-layer aggregates
//! and training metrics — the instrumentation substrate the paper's
//! evaluation method (VTune / OpenCL-profiler timelines, per-kernel
//! tables) demands for the *serving* pipeline, not just the FPGA sim.
//!
//! Design constraints, in order:
//! 1. **Wait-free when off.** With `trace_sample == 0` (the default)
//!    the hot path performs one field read and branches away — no
//!    atomics, no locks, no clock reads.
//! 2. **Cheap when on.** Sampling 1/N batches means one relaxed
//!    `fetch_add` per batch to decide, and only the sampled batch pays
//!    for `Instant::now` calls and span pushes (plain `Vec` pushes on
//!    the worker's stack — the ring lock is taken once per *sampled*
//!    batch, at commit).
//! 3. **One timeline per batch.** Host-side spans (queue wait, batch
//!    assembly, reshape, gather, per-layer forward, readback, scatter,
//!    respond) and the FPGA sim's profiler spans (pcie / fpga-kernel
//!    lanes) merge into a single chrome-trace view per batch — see
//!    [`crate::trace::chrome_trace_batches`] and `GET /admin/trace`.
//!
//! Span timestamps are nanoseconds relative to the batch's trace
//! origin (the oldest request's submit time). Device-profiler spans
//! run on the *simulated* clock; they are rebased so the batch's first
//! device operation lands at the host-side upload offset, which keeps
//! the lanes visually aligned even though they tick different clocks.

use crate::device::fpga::profiler::Span;
use crate::serve::metrics::Histogram;
use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Queue lane: admission-queue wait and dispatch wait.
pub const LANE_QUEUE: &str = "queue";
/// Host lane: batch-stage spans (reshape / gather / upload / forward /
/// readback / scatter / respond) plus the sim's host-partitioned
/// kernels.
pub const LANE_HOST: &str = "host";
/// Per-layer lane: one span per layer of the traced forward pass.
pub const LANE_LAYER: &str = "layer";

/// One sampled batch's complete lifecycle timeline.
#[derive(Debug, Clone)]
pub struct BatchTrace {
    /// Batch sequence number (counts batches seen by the sampler).
    pub seq: u64,
    /// Requests carried (filled rows).
    pub filled: usize,
    /// Rows the reshaped replica executed (the batch bucket).
    pub rows: usize,
    /// Weight snapshot version the batch was served from.
    pub weights_version: u64,
    /// Spans, timestamps in ns relative to the oldest request's submit.
    pub spans: Vec<Span>,
}

/// Accumulates one batch's spans on the worker stack; committed into
/// the ring as a [`BatchTrace`] only if the batch completes.
pub struct BatchTraceBuilder {
    seq: u64,
    t0: Instant,
    filled: usize,
    rows: usize,
    weights_version: u64,
    spans: Vec<Span>,
}

impl BatchTraceBuilder {
    pub fn new(seq: u64, t0: Instant, filled: usize, weights_version: u64) -> BatchTraceBuilder {
        BatchTraceBuilder {
            seq,
            t0,
            filled,
            rows: filled,
            weights_version,
            spans: Vec::with_capacity(32),
        }
    }

    /// Record the executed row count once the batch bucket is known.
    pub fn set_rows(&mut self, rows: usize) {
        self.rows = rows;
    }

    /// Nanosecond offset of `at` on this batch's timeline (0 for any
    /// instant at or before the trace origin).
    pub fn offset_of(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.t0).as_nanos() as u64
    }

    /// Push a span with explicit timeline-relative timestamps.
    pub fn push(&mut self, lane: &'static str, name: String, start_ns: u64, dur_ns: u64) {
        self.spans.push(Span { lane, name, start_ns, dur_ns });
    }

    /// Push a span covering `[from, to]` in wall time.
    pub fn span_between(&mut self, lane: &'static str, name: &str, from: Instant, to: Instant) {
        let start = self.offset_of(from);
        let end = self.offset_of(to);
        self.push(lane, name.to_string(), start, end.saturating_sub(start).max(1));
    }

    pub fn finish(self) -> BatchTrace {
        BatchTrace {
            seq: self.seq,
            filled: self.filled,
            rows: self.rows,
            weights_version: self.weights_version,
            spans: self.spans,
        }
    }
}

/// RAII span guard: records `[start, drop]` on `lane` when dropped.
/// Built over an `Option<&mut _>` so un-sampled batches can pass
/// `None` and pay nothing (not even a clock read).
pub struct TraceScope<'a> {
    builder: Option<&'a mut BatchTraceBuilder>,
    lane: &'static str,
    name: &'static str,
    start: Option<Instant>,
}

impl<'a> TraceScope<'a> {
    pub fn start(
        builder: Option<&'a mut BatchTraceBuilder>,
        lane: &'static str,
        name: &'static str,
    ) -> TraceScope<'a> {
        let start = builder.as_ref().map(|_| Instant::now());
        TraceScope { builder, lane, name, start }
    }
}

impl Drop for TraceScope<'_> {
    fn drop(&mut self) {
        if let (Some(b), Some(start)) = (self.builder.take(), self.start) {
            b.span_between(self.lane, self.name, start, Instant::now());
        }
    }
}

/// Sampled collector over a bounded ring of recent batch traces.
///
/// `every == 0` disables sampling entirely: [`begin`](Self::begin)
/// returns `None` after a single plain field read, so the serving hot
/// path stays wait-free. With `every == N`, every Nth batch is traced
/// (1 = every batch).
pub struct TraceCollector {
    every: u64,
    seq: AtomicU64,
    cap: usize,
    ring: Mutex<VecDeque<BatchTrace>>,
}

impl TraceCollector {
    pub fn new(every: u64, cap: usize) -> TraceCollector {
        TraceCollector {
            every,
            seq: AtomicU64::new(0),
            cap: cap.max(1),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// True when sampling is configured at all.
    pub fn enabled(&self) -> bool {
        self.every != 0
    }

    /// Per-batch sampling decision: `Some(seq)` if this batch should be
    /// traced. The off path (`every == 0`) touches no atomics.
    pub fn begin(&self) -> Option<u64> {
        if self.every == 0 {
            return None;
        }
        let n = self.seq.fetch_add(1, Ordering::Relaxed);
        (n % self.every == 0).then_some(n)
    }

    /// Commit a finished trace; evicts the oldest past capacity.
    pub fn commit(&self, trace: BatchTrace) {
        let mut ring = self.ring.lock().unwrap();
        ring.push_back(trace);
        while ring.len() > self.cap {
            ring.pop_front();
        }
    }

    /// Snapshot of the ring, oldest first.
    pub fn dump(&self) -> Vec<BatchTrace> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    pub fn clear(&self) {
        self.ring.lock().unwrap().clear();
    }
}

/// Per-layer forward-time aggregate across sampled batches.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerAgg {
    /// Sampled batches this layer appeared in.
    pub batches: u64,
    pub wall_ns: u64,
    /// Simulated device time (0 on CPU workers).
    pub sim_ns: u64,
}

/// Name-keyed per-layer aggregates, fed by sampled batches; read by
/// the Prometheus exposition (per-layer gauges) and `/admin/trace`
/// consumers that want totals rather than timelines.
#[derive(Default)]
pub struct LayerStats {
    inner: Mutex<BTreeMap<String, LayerAgg>>,
}

impl LayerStats {
    pub fn new() -> LayerStats {
        LayerStats::default()
    }

    /// Fold one sampled batch's `(layer, wall_ns, sim_ns)` rows in.
    pub fn record(&self, entries: &[(String, u64, u64)]) {
        let mut map = self.inner.lock().unwrap();
        for (name, wall, sim) in entries {
            let e = map.entry(name.clone()).or_default();
            e.batches += 1;
            e.wall_ns += wall;
            e.sim_ns += sim;
        }
    }

    /// Alphabetical (name, aggregate) snapshot.
    pub fn snapshot(&self) -> Vec<(String, LayerAgg)> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }
}

/// Everything one engine exposes to observers: the sampled trace ring
/// and the per-layer aggregates it feeds.
pub struct EngineObs {
    pub traces: TraceCollector,
    pub layers: LayerStats,
}

impl EngineObs {
    pub fn new(trace_every: u64, ring_cap: usize) -> EngineObs {
        EngineObs {
            traces: TraceCollector::new(trace_every, ring_cap),
            layers: LayerStats::new(),
        }
    }
}

/// Solver-side training metrics, published through `train --serve`:
/// per-iteration forward/backward/update time, the latest loss, and
/// weight-publish latency. All wait-free (counters + log2 histograms).
#[derive(Default)]
pub struct TrainMetrics {
    pub iterations: AtomicU64,
    /// f32 bits of the most recent iteration's loss.
    last_loss_bits: AtomicU32,
    pub forward: Histogram,
    pub backward: Histogram,
    pub update: Histogram,
    /// Publish-callback latency per weight publish.
    pub publish: Histogram,
}

impl TrainMetrics {
    pub fn new() -> TrainMetrics {
        TrainMetrics::default()
    }

    pub fn record_iteration(&self, forward_ns: u64, backward_ns: u64, update_ns: u64, loss: f32) {
        self.iterations.fetch_add(1, Ordering::Relaxed);
        self.last_loss_bits.store(loss.to_bits(), Ordering::Relaxed);
        self.forward.record(forward_ns);
        self.backward.record(backward_ns);
        self.update.record(update_ns);
    }

    pub fn record_publish(&self, ns: u64) {
        self.publish.record(ns);
    }

    pub fn last_loss(&self) -> f32 {
        f32::from_bits(self.last_loss_bits.load(Ordering::Relaxed))
    }

    /// JSON mirror for the `training` section of `GET /metrics`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "iterations",
            Json::num(self.iterations.load(Ordering::Relaxed) as f64),
        );
        o.set("last_loss", Json::num(self.last_loss() as f64));
        o.set("forward_mean_ms", Json::num(self.forward.mean_ns() / 1e6));
        o.set(
            "forward_p99_ms",
            Json::num(self.forward.quantile_ns(0.99) / 1e6),
        );
        o.set("backward_mean_ms", Json::num(self.backward.mean_ns() / 1e6));
        o.set(
            "backward_p99_ms",
            Json::num(self.backward.quantile_ns(0.99) / 1e6),
        );
        o.set("update_mean_ms", Json::num(self.update.mean_ns() / 1e6));
        o.set("publishes", Json::num(self.publish.count() as f64));
        o.set("publish_mean_ms", Json::num(self.publish.mean_ns() / 1e6));
        o
    }

    /// Append Prometheus text-format families (summaries without
    /// quantile lines: `_sum`/`_count` are exact, quantiles are not —
    /// see [`Histogram::quantile_ns`]).
    pub fn render_prometheus(&self, out: &mut String) {
        out.push_str("# TYPE fecaffe_train_iterations_total counter\n");
        out.push_str(&format!(
            "fecaffe_train_iterations_total {}\n",
            self.iterations.load(Ordering::Relaxed)
        ));
        out.push_str("# TYPE fecaffe_train_last_loss gauge\n");
        out.push_str(&format!("fecaffe_train_last_loss {}\n", self.last_loss()));
        for (name, h) in [
            ("fecaffe_train_forward_seconds", &self.forward),
            ("fecaffe_train_backward_seconds", &self.backward),
            ("fecaffe_train_update_seconds", &self.update),
            ("fecaffe_train_publish_seconds", &self.publish),
        ] {
            out.push_str(&format!("# TYPE {name} summary\n"));
            out.push_str(&format!("{name}_sum {}\n", h.sum_ns() as f64 / 1e9));
            out.push_str(&format!("{name}_count {}\n", h.count()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn collector_off_never_samples() {
        let c = TraceCollector::new(0, 8);
        assert!(!c.enabled());
        for _ in 0..100 {
            assert!(c.begin().is_none());
        }
        assert!(c.dump().is_empty());
    }

    #[test]
    fn collector_samples_every_nth_batch() {
        let c = TraceCollector::new(4, 8);
        let sampled: Vec<bool> = (0..12).map(|_| c.begin().is_some()).collect();
        let expect: Vec<bool> = (0..12).map(|i| i % 4 == 0).collect();
        assert_eq!(sampled, expect);
    }

    #[test]
    fn ring_is_bounded_and_fifo() {
        let c = TraceCollector::new(1, 3);
        for seq in 0..5 {
            let b = BatchTraceBuilder::new(seq, Instant::now(), 1, 0);
            c.commit(b.finish());
        }
        let traces = c.dump();
        assert_eq!(traces.len(), 3);
        assert_eq!(
            traces.iter().map(|t| t.seq).collect::<Vec<_>>(),
            vec![2, 3, 4]
        );
        c.clear();
        assert!(c.dump().is_empty());
    }

    #[test]
    fn builder_records_relative_spans_and_scopes() {
        let t0 = Instant::now();
        let mut b = BatchTraceBuilder::new(7, t0, 3, 2);
        b.set_rows(4);
        b.span_between(LANE_QUEUE, "queue-wait", t0, t0 + Duration::from_micros(50));
        b.push(LANE_LAYER, "conv1".to_string(), 60_000, 10_000);
        {
            let scope = TraceScope::start(Some(&mut b), LANE_HOST, "gather");
            std::thread::sleep(Duration::from_millis(1));
            drop(scope);
        }
        // A None scope is free and records nothing.
        drop(TraceScope::start(None, LANE_HOST, "noop"));
        let t = b.finish();
        assert_eq!((t.seq, t.filled, t.rows, t.weights_version), (7, 3, 4, 2));
        assert_eq!(t.spans.len(), 3);
        assert_eq!(t.spans[0].lane, LANE_QUEUE);
        assert_eq!(t.spans[0].start_ns, 0);
        assert!((45_000..=200_000).contains(&t.spans[0].dur_ns), "{}", t.spans[0].dur_ns);
        assert_eq!(t.spans[1].name, "conv1");
        assert_eq!(t.spans[2].name, "gather");
        assert!(t.spans[2].dur_ns >= 500_000, "{}", t.spans[2].dur_ns);
    }

    #[test]
    fn layer_stats_aggregate_across_batches() {
        let s = LayerStats::new();
        s.record(&[("conv1".to_string(), 100, 10), ("fc1".to_string(), 50, 5)]);
        s.record(&[("conv1".to_string(), 300, 30)]);
        let snap = s.snapshot();
        assert_eq!(snap.len(), 2);
        let conv = &snap[0];
        assert_eq!(conv.0, "conv1");
        assert_eq!(conv.1.batches, 2);
        assert_eq!(conv.1.wall_ns, 400);
        assert_eq!(conv.1.sim_ns, 40);
    }

    #[test]
    fn train_metrics_record_and_render() {
        let t = TrainMetrics::new();
        t.record_iteration(1_000_000, 2_000_000, 500_000, 0.25);
        t.record_iteration(1_000_000, 2_000_000, 500_000, 0.125);
        t.record_publish(3_000_000);
        assert_eq!(t.iterations.load(Ordering::Relaxed), 2);
        assert!((t.last_loss() - 0.125).abs() < 1e-9);
        let j = t.to_json();
        assert_eq!(j.get("iterations").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("publishes").unwrap().as_usize().unwrap(), 1);
        assert!(j.get("forward_mean_ms").unwrap().as_f64().unwrap() > 0.0);
        let mut out = String::new();
        t.render_prometheus(&mut out);
        assert!(out.contains("fecaffe_train_iterations_total 2"));
        assert!(out.contains("fecaffe_train_forward_seconds_count 2"));
        assert!(out.contains("fecaffe_train_last_loss 0.125"));
    }
}
