//! Reduced-precision serving integration: post-training quantization
//! round-trip bounds, bit-exact int8 execution across thread counts,
//! the ≤1% top-1 budget on the digits task, and fp32 + int8 variants
//! of one model served side by side through the router.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::quant::{self, backend::QuantBackend, Precision, QuantizedSnapshot};
use fecaffe::serve::{DeviceKind, Engine, EngineConfig, ModelRouter, RouterConfig};
use fecaffe::solver::Solver;
use fecaffe::zoo;
use std::time::Duration;

/// Freshly initialized LeNet weights (deterministic: seeded fillers).
fn lenet_weights() -> fecaffe::net::WeightSnapshot {
    let mut dev = CpuDevice::new();
    let param = zoo::by_name("lenet", 4).unwrap();
    let mut net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    net.share_weights(&mut dev)
}

#[test]
fn quantize_dequantize_round_trip_is_bounded_and_idempotent() {
    let snap = lenet_weights();
    let q = QuantizedSnapshot::from_snapshot(&snap);
    assert_eq!(q.len(), snap.len());
    assert_eq!(q.keys(), snap.keys());

    let deq = q.dequantize();
    for i in 0..snap.len() {
        let orig = snap.blob_data(i).unwrap();
        let fake = deq.blob_data(i).unwrap();
        let scale = q.blob(i).unwrap().scale;
        // Symmetric rounding: every element lands within half a step of
        // its original value, and the payload is exactly 1 B/element.
        let worst = orig
            .iter()
            .zip(fake.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            worst <= scale * 0.5 + 1e-7,
            "blob {i}: worst round-trip error {worst} exceeds scale/2 = {}",
            scale * 0.5
        );
    }
    assert_eq!(
        q.payload_bytes(),
        (0..snap.len()).map(|i| snap.blob_data(i).unwrap().len()).sum::<usize>()
    );

    // Fake-quant values sit exactly on the grid: re-quantizing them is
    // lossless, so prepare_weights is idempotent bit-for-bit.
    let twice = QuantizedSnapshot::from_snapshot(&deq).dequantize();
    for i in 0..deq.len() {
        let a = deq.blob_data(i).unwrap();
        let b = twice.blob_data(i).unwrap();
        assert!(
            a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "blob {i}: re-quantization moved values off the int8 grid"
        );
    }
}

/// One engine forward of `n` deterministic samples at `intra_op`
/// threads, int8 precision.
fn int8_outputs(intra_op: usize) -> Vec<Vec<f32>> {
    let param = zoo::by_name("lenet", 1).unwrap();
    let engine = Engine::new(
        &param,
        EngineConfig {
            workers: 1,
            max_batch: 4,
            max_linger: Duration::from_micros(200),
            queue_capacity: 64,
            device: DeviceKind::Cpu,
            intra_op_threads: intra_op,
            precision: Precision::Int8,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let len = engine.sample_len();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let sample: Vec<f32> = (0..len).map(|j| ((i * 31 + j) % 97) as f32 / 97.0).collect();
            engine.submit(sample).unwrap()
        })
        .collect();
    let outs: Vec<Vec<f32>> = handles.into_iter().map(|h| h.wait().unwrap().values).collect();
    engine.shutdown();
    outs
}

#[test]
fn int8_forward_is_bit_identical_across_thread_counts() {
    // The emulated int8 GEMM accumulates in i32 — exact integer sums —
    // so the forward must be reproducible bit for bit no matter how the
    // intra-op pool splits the work (the FECAFFE_THREADS=1 CI leg and
    // the default leg must agree).
    let one = int8_outputs(1);
    let four = int8_outputs(4);
    assert_eq!(one.len(), four.len());
    for (i, (a, b)) in one.iter().zip(four.iter()).enumerate() {
        assert_eq!(a.len(), b.len());
        assert!(
            a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "sample {i}: int8 forward diverged between 1 and 4 intra-op threads"
        );
    }
}

#[test]
fn int8_top1_stays_within_one_percent_on_digits() {
    // Train briefly, then evaluate the same weights at fp32 and through
    // the emulated int8 path (fake-quant weights + QuantBackend).
    let mut dev = CpuDevice::new();
    let param = zoo::by_name("lenet", 32).unwrap();
    let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    let mut sp = zoo::default_solver("lenet").unwrap();
    sp.display = 0;
    let mut solver = Solver::new(sp, net, &mut dev).unwrap();
    for _ in 0..60 {
        solver.step(&mut dev).unwrap();
    }
    let snap = solver.net.share_weights(&mut dev);

    let eval = |precision: Precision| -> f32 {
        let mut dev = CpuDevice::new();
        if precision != Precision::Fp32 {
            dev = dev.with_backend(Box::new(QuantBackend::new(precision, None)));
        }
        let tp = zoo::by_name("lenet", 100).unwrap();
        let mut tnet = Net::from_param(&tp, Phase::Test, &mut dev).unwrap();
        let weights = quant::prepare_weights(&snap, precision);
        tnet.adopt_weights(&mut dev, &weights).unwrap();
        tnet.forward(&mut dev).unwrap();
        tnet.blob("accuracy").unwrap().borrow_mut().data_vec(&mut dev)[0]
    };

    let fp32 = eval(Precision::Fp32);
    let int8 = eval(Precision::Int8);
    assert!(fp32 > 0.5, "training failed to leave chance territory: {fp32}");
    assert!(
        (fp32 - int8).abs() <= 0.01,
        "int8 top-1 delta {:.3} over the 1% budget (fp32 {fp32:.3}, int8 {int8:.3})",
        (fp32 - int8).abs()
    );
}

#[test]
fn router_serves_fp32_and_int8_variants_side_by_side() {
    let cfg = RouterConfig {
        total_workers: 2,
        max_batch: 4,
        max_linger: Duration::from_micros(200),
        queue_capacity: 64,
        device: DeviceKind::Cpu,
        ..RouterConfig::default()
    };
    let router = ModelRouter::from_zoo(&["lenet", "lenet@int8"], &cfg).unwrap();
    assert_eq!(router.models(), vec!["lenet", "lenet@int8"]);
    assert_eq!(router.engine("lenet").unwrap().precision(), Precision::Fp32);
    assert_eq!(router.engine("lenet@int8").unwrap().precision(), Precision::Int8);
    // The int8 engine carries its boot-time calibration; fp32 does not.
    assert!(router.engine("lenet@int8").unwrap().quant_spec().is_some());
    assert!(router.engine("lenet").unwrap().quant_spec().is_none());

    let len = router.engine("lenet").unwrap().sample_len();
    let sample: Vec<f32> = (0..len).map(|j| (j % 97) as f32 / 97.0).collect();
    let fp32 = router.submit("lenet", sample.clone()).unwrap().wait().unwrap();
    let int8 = router.submit("lenet@int8", sample).unwrap().wait().unwrap();
    assert_eq!(fp32.values.len(), int8.values.len());
    // Both are softmax rows over the same 10 classes.
    for r in [&fp32, &int8] {
        let sum: f32 = r.values.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "scores are not a softmax row: {sum}");
    }

    router.engine("lenet").unwrap().shutdown();
    router.engine("lenet@int8").unwrap().shutdown();
}
