//! E2 — regenerate paper Table 2: kernel instance counts, total times and
//! DDR/PCIe efficiencies for one GoogLeNet F→B at batch 1.

fn main() -> anyhow::Result<()> {
    let (text, stats) = fecaffe::bench_tables::table2()?;
    println!("{text}");
    use fecaffe::device::KClass;
    println!("Paper reference (Table 2): 960 total instances incl. 186 Gemm,");
    println!("98 Im2col, 19 Col2im, 61 ReLU_F, 72 Concat, 41 Split, 3 Read_Buffer.");
    let total: u64 = stats.values().map(|v| v.0).sum();
    println!("\nOurs: {total} instances; Gemm {}, Im2col {}, ReLU_F {}, Split {}",
        stats.get(&KClass::Gemm).map(|v| v.0).unwrap_or(0),
        stats.get(&KClass::Im2col).map(|v| v.0).unwrap_or(0),
        stats.get(&KClass::ReluF).map(|v| v.0).unwrap_or(0),
        stats.get(&KClass::Split).map(|v| v.0).unwrap_or(0));
    Ok(())
}
