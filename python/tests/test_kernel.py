"""L1 Pallas kernels vs the pure-numpy oracle — the core correctness
signal for the gemm/gemv artifacts. Shape sweeps are hypothesis-style:
a seeded PRNG draws many random shapes/values per property."""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.kernels import gemm as gk
from compile.kernels import ref

RNG = np.random.default_rng(0x5EED)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize("case", range(24))
def test_gemm_nn_random_shapes(case):
    m, n, k = (int(RNG.integers(1, 200)) for _ in range(3))
    a, b = rand(m, k), rand(k, n)
    out = np.asarray(gk.gemm(a, b))
    np.testing.assert_allclose(out, ref.gemm(a, b), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("ta,tb", [(False, True), (True, False), (True, True)])
def test_gemm_transposes(ta, tb):
    m, n, k = 33, 65, 17
    a = rand(k, m) if ta else rand(m, k)
    b = rand(n, k) if tb else rand(k, n)
    out = np.asarray(gk.gemm(a, b, ta=ta, tb=tb))
    np.testing.assert_allclose(out, ref.gemm(a, b, ta=ta, tb=tb), rtol=2e-4, atol=2e-4)


def test_gemm_acc():
    a, b, c = rand(7, 9), rand(9, 11), rand(7, 11)
    out = np.asarray(gk.gemm(a, b, c=c))
    np.testing.assert_allclose(out, ref.gemm(a, b, c=c), rtol=2e-4, atol=2e-4)


def test_gemm_tile_boundaries():
    # Exercise shapes straddling the 128/512 tile edges.
    for m, n, k in [(128, 512, 512), (129, 513, 511), (1, 1, 1), (127, 511, 513)]:
        a, b = rand(m, k), rand(k, n)
        out = np.asarray(gk.gemm(a, b))
        np.testing.assert_allclose(out, ref.gemm(a, b), rtol=3e-4, atol=3e-4)


def test_gemm_conv_shapes_from_zoo():
    # Real conv gemm shapes: lenet conv1/conv2, googlenet 3x3, alexnet fc
    for m, k, n in [(20, 25, 576), (50, 500, 64), (128, 1152, 784), (96, 363, 3025)]:
        a, b = rand(m, k), rand(k, n)
        out = np.asarray(gk.gemm(a, b))
        np.testing.assert_allclose(out, ref.gemm(a, b), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("case", range(12))
def test_gemv_random(case):
    m, n = (int(RNG.integers(1, 300)) for _ in range(2))
    trans = bool(RNG.integers(0, 2))
    a = rand(m, n)
    x = rand(m if trans else n)
    out = np.asarray(gk.gemv(a, x, trans=trans))
    np.testing.assert_allclose(out, ref.gemv(a, x, trans=trans), rtol=2e-4, atol=2e-4)


def test_gemv_acc():
    a, x, y = rand(13, 7), rand(7), rand(13)
    out = np.asarray(gk.gemv(a, x, y=y))
    np.testing.assert_allclose(out, ref.gemv(a, x, y=y), rtol=2e-4, atol=2e-4)


def test_vmem_budget():
    # The tile chooser must never exceed ~1.6M floats (6.4 MB) of operand
    # tiles — well under the 16 MB/core VMEM budget (DESIGN.md §8).
    for m, n, k in [(4096, 4096, 4096), (1, 1_000_000, 1), (128, 784, 1152)]:
        assert gk.vmem_floats(m, n, k) <= 400_000, (m, n, k)
