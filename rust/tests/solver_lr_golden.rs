//! Golden tests for every learning-rate schedule against hand-computed
//! `caffe::SGDSolver::GetLearningRate` values, plus the error contract:
//! an unknown `lr_policy` in a user-supplied prototxt is an `Err` at
//! parse time and at solver construction — never a panic.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::net::Net;
use fecaffe::proto::{parse_net, parse_solver, Phase, SolverParameter};
use fecaffe::solver::{learning_rate_at, Solver};

/// Relative tolerance for f32 schedule math.
fn assert_close(got: f32, want: f32, what: &str) {
    let tol = want.abs().max(1e-12) * 1e-5;
    assert!(
        (got - want).abs() <= tol,
        "{what}: got {got}, want {want}"
    );
}

fn solver_text(body: &str) -> SolverParameter {
    parse_solver(&format!("net: \"lenet\"\n{body}")).unwrap()
}

#[test]
fn fixed_is_constant() {
    let p = solver_text("base_lr: 0.01\nlr_policy: \"fixed\"");
    for iter in [0, 1, 999, 100_000] {
        assert_close(learning_rate_at(&p, iter).unwrap(), 0.01, "fixed");
    }
}

#[test]
fn step_matches_caffe() {
    // caffe: rate = base_lr * gamma^(iter / stepsize)
    let p = solver_text("base_lr: 0.1\nlr_policy: \"step\"\ngamma: 0.5\nstepsize: 10");
    for (iter, want) in [(0, 0.1), (9, 0.1), (10, 0.05), (19, 0.05), (20, 0.025), (35, 0.0125)] {
        assert_close(learning_rate_at(&p, iter).unwrap(), want, "step");
    }
}

#[test]
fn exp_matches_caffe() {
    // caffe: rate = base_lr * gamma^iter
    let p = solver_text("base_lr: 0.1\nlr_policy: \"exp\"\ngamma: 0.99");
    assert_close(learning_rate_at(&p, 0).unwrap(), 0.1, "exp@0");
    assert_close(learning_rate_at(&p, 1).unwrap(), 0.099, "exp@1");
    // 0.99^10 = 0.904382075...
    assert_close(learning_rate_at(&p, 10).unwrap(), 0.090438208, "exp@10");
}

#[test]
fn inv_matches_caffe() {
    // caffe: rate = base_lr * (1 + gamma*iter)^(-power) — LeNet's policy.
    let p = solver_text("base_lr: 0.01\nlr_policy: \"inv\"\ngamma: 0.0001\npower: 0.75");
    assert_close(learning_rate_at(&p, 0).unwrap(), 0.01, "inv@0");
    // (1 + 1)^-0.75 = 0.59460355...
    assert_close(learning_rate_at(&p, 10_000).unwrap(), 0.0059460355, "inv@10000");
    // (1 + 0.01)^-0.75 = 0.99256503...
    assert_close(learning_rate_at(&p, 100).unwrap(), 0.0099256503, "inv@100");
}

#[test]
fn poly_matches_caffe() {
    // caffe: rate = base_lr * (1 - iter/max_iter)^power — SqueezeNet's.
    let p = solver_text("base_lr: 0.04\nlr_policy: \"poly\"\npower: 1.0\nmax_iter: 100");
    assert_close(learning_rate_at(&p, 0).unwrap(), 0.04, "poly@0");
    assert_close(learning_rate_at(&p, 25).unwrap(), 0.03, "poly@25");
    assert_close(learning_rate_at(&p, 100).unwrap(), 0.0, "poly@100");
    let p = solver_text("base_lr: 0.04\nlr_policy: \"poly\"\npower: 2.0\nmax_iter: 100");
    assert_close(learning_rate_at(&p, 50).unwrap(), 0.01, "poly^2@50");
}

#[test]
fn sigmoid_matches_caffe() {
    // caffe: rate = base_lr * (1 / (1 + exp(-gamma * (iter - stepsize))))
    let p = solver_text("base_lr: 0.1\nlr_policy: \"sigmoid\"\ngamma: -0.01\nstepsize: 100");
    // At iter == stepsize the sigmoid is exactly 1/2.
    assert_close(learning_rate_at(&p, 100).unwrap(), 0.05, "sigmoid@step");
    // gamma*(0-100) = 1 → sigma(1) = 0.73105858...
    assert_close(learning_rate_at(&p, 0).unwrap(), 0.073105857, "sigmoid@0");
    // gamma*(200-100) = -1 → sigma(-1) = 0.26894142...
    assert_close(learning_rate_at(&p, 200).unwrap(), 0.026894143, "sigmoid@200");
}

#[test]
fn multistep_matches_caffe() {
    // caffe: current_step_ advances at each stepvalue boundary; rate =
    // base_lr * gamma^current_step_.
    let p = solver_text(
        "base_lr: 0.1\nlr_policy: \"multistep\"\ngamma: 0.5\n\
         stepvalue: 5\nstepvalue: 8\nstepvalue: 12",
    );
    assert_eq!(p.stepvalue, vec![5, 8, 12]);
    let want = [
        (0, 0.1),
        (4, 0.1),
        (5, 0.05),
        (7, 0.05),
        (8, 0.025),
        (11, 0.025),
        (12, 0.0125),
        (1000, 0.0125),
    ];
    for (iter, w) in want {
        assert_close(learning_rate_at(&p, iter).unwrap(), w, "multistep");
    }
    // No boundaries behaves like `fixed`.
    let p = solver_text("base_lr: 0.1\nlr_policy: \"multistep\"\ngamma: 0.5");
    assert_close(learning_rate_at(&p, 500).unwrap(), 0.1, "multistep-empty");
}

#[test]
fn multistep_prototxt_trains_end_to_end() {
    // A paper-style solver prototxt with multistep must parse, build and
    // step — the schedule visibly decays across the boundaries.
    const NET: &str = r#"
name: "t"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 4 channels: 1 height: 8 width: 8 num_classes: 3 source: "digits" seed: 5 } }
layer { name: "fc" type: "InnerProduct" bottom: "data" top: "fc"
        inner_product_param { num_output: 3 weight_filler { type: "xavier" } } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc" bottom: "label" top: "loss" }
"#;
    let sp = solver_text(
        "base_lr: 0.05\nlr_policy: \"multistep\"\ngamma: 0.1\n\
         stepvalue: 2\nstepvalue: 4\ndisplay: 0",
    );
    let mut dev = CpuDevice::new();
    let netp = parse_net(NET).unwrap();
    let net = Net::from_param(&netp, Phase::Train, &mut dev).unwrap();
    let mut solver = Solver::new(sp, net, &mut dev).unwrap();
    let mut rates = Vec::new();
    for _ in 0..5 {
        rates.push(solver.learning_rate().unwrap());
        solver.step(&mut dev).unwrap();
    }
    assert_close(rates[0], 0.05, "iter 0");
    assert_close(rates[1], 0.05, "iter 1");
    assert_close(rates[2], 0.005, "iter 2");
    assert_close(rates[3], 0.005, "iter 3");
    assert_close(rates[4], 0.0005, "iter 4");
}

#[test]
fn unknown_policy_fails_at_parse_and_at_construction() {
    // Parse-time rejection.
    let err = parse_solver("net: \"lenet\"\nlr_policy: \"warmup_cosine\"").unwrap_err();
    assert!(err.contains("unknown lr_policy"), "{err}");

    // Construction-time rejection for programmatically-built params.
    let mut sp = SolverParameter::default();
    sp.lr_policy = "warmup_cosine".into();
    assert!(learning_rate_at(&sp, 0).is_err());
    let mut dev = CpuDevice::new();
    let param = fecaffe::zoo::by_name("lenet", 4).unwrap();
    let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    let err = Solver::new(sp, net, &mut dev).unwrap_err().to_string();
    assert!(err.contains("unknown lr_policy"), "{err}");
}
