//! Paper-table regeneration harness (DESIGN.md §5 experiment index).
//!
//! Each `benches/*.rs` binary is a thin wrapper over a function here, so
//! integration tests can assert on the same numbers the benches print.
//! All timings come from the FPGA simulator's deterministic clock
//! (timing-only mode): one iteration is exact — where the paper averaged
//! 100 noisy wallclock runs, the simulator's model is noise-free.

use crate::device::fpga::{FpgaSimDevice, QueueMode};
use crate::device::{Device, KClass};
use crate::net::Net;
use crate::proto::Phase;
use crate::util::table::{ms, Table};
use crate::zoo;
use std::collections::BTreeMap;

/// A timing-only simulated board.
pub fn timing_device() -> FpgaSimDevice {
    let mut dev = FpgaSimDevice::new();
    dev.timing_only = true;
    dev
}

/// Larger-capacity variant for headroom experiments (§5.1 "enlarging DDR
/// storage" direction). The paper-setting benches all fit the true 2 GB
/// board thanks to the shared im2col scratch region.
pub fn timing_device_large() -> FpgaSimDevice {
    let mut dev = FpgaSimDevice::new().with_capacity(4 * 1024 * 1024 * 1024);
    dev.timing_only = true;
    dev
}

/// Paper Table 1 row grouping: fold relu/norm/pool/dropout/split layers
/// into their host group the way the paper's rows do ("the convolution
/// also involves a couple of operations associated").
pub fn group_of(net: &str, layer: &str) -> String {
    // Split layers inherit their source blob's group.
    let base = layer.strip_suffix("_split").unwrap_or(layer);
    match net {
        "alexnet" => {
            if base == "data" || base == "loss" || base == "accuracy" {
                return base.to_string();
            }
            let digit = base.chars().rev().find(|c| c.is_ascii_digit());
            match digit {
                Some(d @ '1'..='5') => format!("conv{d}"),
                Some(d) => format!("fc{d}"),
                None => base.to_string(),
            }
        }
        "vgg16" => {
            if let Some(rest) = base.strip_prefix("conv") {
                return format!("conv{}", &rest[..1]);
            }
            if let Some(rest) = base.strip_prefix("pool") {
                return format!("conv{}", &rest[..1]);
            }
            if let Some(rest) = base.strip_prefix("relu_conv") {
                return format!("conv{}", &rest[..1]);
            }
            let digit = base.chars().rev().find(|c| c.is_ascii_digit());
            match (
                base.starts_with("fc") || base.starts_with("relu") || base.starts_with("drop"),
                digit,
            ) {
                (true, Some(d)) => format!("fc{d}"),
                _ => base.to_string(),
            }
        }
        "squeezenet" => {
            let base = base.strip_prefix("relu_").unwrap_or(base);
            if let Some(head) = base.split('/').next() {
                if head.starts_with("fire") {
                    return head.to_string();
                }
            }
            match base {
                "pool1" | "relu_conv1" => "conv1".into(),
                "pool4" => "fire4".into(),
                "pool8" => "fire8".into(),
                "drop9" => "fire9".into(),
                "relu_conv10" | "pool10" => "conv10".into(),
                other => other.into(),
            }
        }
        "googlenet" => {
            let base2 = base.strip_prefix("relu_").unwrap_or(base);
            let head = base2.split('/').next().unwrap_or(base2);
            match head {
                "pool1" | "conv1" => "conv1".into(),
                "conv2" | "pool2" => "conv2".into(),
                "pool3" => "incep_3b".into(),
                "pool4" => "incep_4e".into(),
                "pool5" | "loss3" => "loss3".into(),
                h if h.starts_with("inception_") => {
                    format!("incep_{}", &h["inception_".len()..])
                }
                other => other.into(),
            }
        }
        _ => base.to_string(),
    }
}

/// Grouped per-layer fwd/bwd times for a net at a batch size, in
/// first-appearance order. Returns (group, fwd_ms, bwd_ms).
pub fn grouped_layer_times(
    name: &str,
    batch: usize,
    dev: &mut FpgaSimDevice,
) -> anyhow::Result<Vec<(String, f64, f64)>> {
    let param = zoo::by_name(name, batch)?;
    let mut net = Net::from_param(&param, Phase::Train, dev)?;
    // Warm one forward so lazily-created buffers (loss scalars) exist,
    // then reset the clock for a clean measured pass.
    net.forward(dev)?;
    dev.reset_timing();
    let names = net.layer_names();
    let (_, fwd) = net.forward_timed(dev)?;
    let bwd = net.backward_timed(dev)?;
    let mut order: Vec<String> = Vec::new();
    let mut agg: BTreeMap<String, (f64, f64)> = BTreeMap::new();
    for (i, lname) in names.iter().enumerate() {
        let group = group_of(name, lname);
        if !agg.contains_key(&group) {
            order.push(group.clone());
        }
        let e = agg.entry(group).or_insert((0.0, 0.0));
        e.0 += fwd[i] as f64 / 1e6;
        e.1 += bwd[i] as f64 / 1e6;
    }
    Ok(order
        .into_iter()
        .map(|g| {
            let (f, b) = agg[&g];
            (g, f, b)
        })
        .collect())
}

/// Table 1: per-layer fwd/bwd for the four ImageNet nets at batch 1.
pub fn table1() -> anyhow::Result<String> {
    let mut out = String::new();
    for name in ["alexnet", "vgg16", "squeezenet", "googlenet"] {
        let mut dev = timing_device();
        let rows = grouped_layer_times(name, 1, &mut dev)?;
        let mut t = Table::new(
            &format!("Table 1 — {name} (ms, batch=1, simulated S10)"),
            &["Layer", "Forward", "Backward"],
        );
        let (mut tf, mut tb) = (0.0, 0.0);
        for (g, f, b) in &rows {
            t.row(&[g.clone(), ms(*f), ms(*b)]);
            tf += f;
            tb += b;
        }
        t.row(&["TOTAL".into(), ms(tf), ms(tb)]);
        t.row(&["F->B".into(), ms(tf + tb), String::new()]);
        out.push_str(&t.render());
        out.push('\n');
    }
    Ok(out)
}

/// Table 2: kernel statistics for one GoogLeNet F→B at batch 1.
pub fn table2() -> anyhow::Result<(String, BTreeMap<KClass, (u64, f64)>)> {
    let mut dev = timing_device();
    let param = zoo::by_name("googlenet", 1)?;
    let mut net = Net::from_param(&param, Phase::Train, &mut dev)?;
    net.forward(&mut dev)?; // warmup allocations
    dev.reset_timing();
    net.forward(&mut dev)?;
    net.backward(&mut dev)?;
    dev.synchronize();
    let total_fb_ms = dev.sim_clock_ns().unwrap() as f64 / 1e6;

    let mut t = Table::new(
        "Table 2 — kernel statistics within F->B for GoogLeNet (batch=1)",
        &["Kernels", "Instance Count", "Total Time (ms)", "Efficiency"],
    );
    let mut stats_out = BTreeMap::new();
    let mut total_inst = 0u64;
    let mut total_ms = 0.0f64;
    for (class, s) in dev.profiler.stats() {
        let time_ms = s.total_ns as f64 / 1e6;
        let eff = match class {
            KClass::WriteBuffer | KClass::ReadBuffer => {
                format!("{:.0}% (PCIe)", 1.906 / 15.75 * 100.0)
            }
            c => format!(
                "{:.0}% (DDR)",
                crate::device::fpga::costmodel::ddr_efficiency(*c) * 100.0
            ),
        };
        t.row(&[
            class.label().to_string(),
            s.instances.to_string(),
            ms(time_ms),
            eff,
        ]);
        stats_out.insert(*class, (s.instances, time_ms));
        total_inst += s.instances;
        total_ms += time_ms;
    }
    t.row(&[
        "Total".into(),
        total_inst.to_string(),
        ms(total_ms),
        format!("{:.0}% (F->B)", total_ms / total_fb_ms * 100.0),
    ]);
    let mut text = t.render();
    text.push_str(&format!(
        "\n(total simulated F->B wall: {:.3} ms; kernel+transfer share {:.0}%)\n",
        total_fb_ms,
        total_ms / total_fb_ms * 100.0
    ));
    Ok((text, stats_out))
}

/// Table 3: hardware utilization model.
pub fn table3() -> String {
    use crate::device::fpga::resources::*;
    let (gemm, gemv, total) = full_bitstream();
    let mut t = Table::new(
        "Table 3 — modeled hardware utilization on S10 (GX2800)",
        &["", "ALMs", "Regs", "M20K", "DSPs", "Fmax"],
    );
    let row = |u: &Usage, name: &str, fmax: &str| {
        vec![
            name.to_string(),
            format!("{}K ({:.0}%)", u.alms / 1000, pct(u.alms, S10_ALMS)),
            format!("{}K", u.regs / 1000),
            format!("{} ({:.0}%)", u.m20k, pct(u.m20k, S10_M20K)),
            format!("{} ({:.0}%)", u.dsps, pct(u.dsps, S10_DSPS)),
            fmax.to_string(),
        ]
    };
    t.row(&row(&gemm, "Gemm", "252 MHz"));
    t.row(&row(&gemv, "Gemv", "253 MHz"));
    t.row(&row(&total, "Total", "253 MHz"));
    t.render()
}

/// Async-queue ablation (§5.2): GoogLeNet F→B sync vs async sim time.
pub fn ablation_async() -> anyhow::Result<String> {
    let mut results = Vec::new();
    for mode in [QueueMode::Sync, QueueMode::Async] {
        let mut dev = timing_device();
        dev.set_mode(mode);
        let param = zoo::by_name("googlenet", 1)?;
        let mut net = Net::from_param(&param, Phase::Train, &mut dev)?;
        net.forward(&mut dev)?;
        dev.reset_timing();
        net.forward(&mut dev)?;
        net.backward(&mut dev)?;
        dev.synchronize();
        results.push((mode, dev.sim_clock_ns().unwrap() as f64 / 1e6));
    }
    let speedup = results[0].1 / results[1].1;
    let mut t = Table::new(
        "Ablation — §5.2 asynchronous queue (GoogLeNet F->B, batch=1)",
        &["Queue mode", "Simulated time (ms)", "Speedup"],
    );
    t.row(&["sync (paper default)".into(), ms(results[0].1), "1.0x".into()]);
    t.row(&[
        "async (§5.2 optimization)".into(),
        ms(results[1].1),
        format!("{speedup:.2}x"),
    ]);
    Ok(t.render())
}

/// §5.2 partition ablation: GoogLeNet F→B with im2col/col2im on the FPGA
/// (paper default) vs partitioned to the host CPU.
pub fn ablation_partition() -> anyhow::Result<String> {
    let mut results = Vec::new();
    for partition in [false, true] {
        let mut dev = timing_device();
        if partition {
            dev.partition_to_host(KClass::Im2col);
            dev.partition_to_host(KClass::Col2im);
        }
        let param = zoo::by_name("googlenet", 1)?;
        let mut net = Net::from_param(&param, Phase::Train, &mut dev)?;
        net.forward(&mut dev)?;
        dev.reset_timing();
        net.forward(&mut dev)?;
        net.backward(&mut dev)?;
        dev.synchronize();
        results.push(dev.sim_clock_ns().unwrap() as f64 / 1e6);
    }
    let mut t = Table::new(
        "Ablation — §5.2 workload partition (GoogLeNet F->B, batch=1)",
        &["im2col/col2im placement", "Simulated time (ms)", "Speedup"],
    );
    t.row(&["FPGA (paper default)".into(), ms(results[0]), "1.0x".into()]);
    t.row(&[
        "host CPU (§5.2 partition)".into(),
        ms(results[1]),
        format!("{:.2}x", results[0] / results[1]),
    ]);
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouping_rules() {
        assert_eq!(group_of("alexnet", "norm1"), "conv1");
        assert_eq!(group_of("alexnet", "pool5"), "conv5");
        assert_eq!(group_of("alexnet", "drop6"), "fc6");
        assert_eq!(group_of("vgg16", "conv3_2"), "conv3");
        assert_eq!(group_of("vgg16", "relu_conv4_1"), "conv4");
        assert_eq!(group_of("vgg16", "pool5"), "conv5");
        assert_eq!(group_of("squeezenet", "fire4/expand3x3"), "fire4");
        assert_eq!(group_of("squeezenet", "fire2/squeeze1x1_split"), "fire2");
        assert_eq!(group_of("googlenet", "inception_3a/5x5_reduce"), "incep_3a");
        assert_eq!(group_of("googlenet", "inception_4e/output_split"), "incep_4e");
        assert_eq!(group_of("googlenet", "pool3/3x3_s2"), "incep_3b");
        assert_eq!(group_of("googlenet", "loss1/conv"), "loss1");
        assert_eq!(group_of("googlenet", "pool5/drop_7x7_s1"), "loss3");
        assert_eq!(group_of("googlenet", "relu_conv2/3x3"), "conv2");
    }

    #[test]
    fn lenet_grouped_times_positive() {
        let mut dev = timing_device();
        let rows = grouped_layer_times("lenet", 1, &mut dev).unwrap();
        assert!(rows.iter().any(|(g, _, _)| g == "conv1"));
        let total_f: f64 = rows.iter().map(|r| r.1).sum();
        assert!(total_f > 0.0);
    }

    #[test]
    fn table3_renders() {
        let t = table3();
        assert!(t.contains("Gemm") && t.contains("DSPs"));
    }

    #[test]
    fn async_ablation_overlaps() {
        let text = ablation_async().unwrap();
        assert!(text.contains("async"));
    }
}
