//! GEMM / GEMV — the paper's two "significant kernels" (Table 3).
//!
//! `gemm` computes `C = alpha * op(A) * op(B) + beta * C` for row-major
//! matrices, like `caffe_cpu_gemm`. The NN inner loop is written as a
//! register-blocked, cache-tiled kernel (see §Perf in EXPERIMENTS.md);
//! the transposed variants take the simple path since convolution's hot
//! call is NN (im2col'd convolution) by construction.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trans {
    No,
    Yes,
}

/// Row-major GEMM: C[m,n] = alpha*op(A)[m,k]*op(B)[k,n] + beta*C.
///
/// `a` is m×k when `ta == No`, k×m when `ta == Yes` (same storage order as
/// caffe_cpu_gemm's lda conventions).
pub fn gemm(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert!(c.len() >= m * n, "gemm: C too small");
    match (ta, tb) {
        (Trans::No, Trans::No) => {
            assert!(a.len() >= m * k && b.len() >= k * n, "gemm NN: input too small");
            gemm_nn(m, n, k, alpha, a, b, beta, c);
        }
        _ => {
            assert!(
                a.len() >= m * k && b.len() >= k * n,
                "gemm {:?}{:?}: input too small",
                ta,
                tb
            );
            gemm_generic(ta, tb, m, n, k, alpha, a, b, beta, c);
        }
    }
}

/// Cache-tiled NN kernel. Tiles: MC×KC panel of A, KC×NC panel of B; the
/// micro-kernel accumulates 4 rows at a time over a contiguous B row —
/// auto-vectorizes cleanly.
fn gemm_nn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    const MC: usize = 64;
    const KC: usize = 256;
    const NC: usize = 512;

    if beta != 1.0 {
        for v in c[..m * n].iter_mut() {
            *v *= beta;
        }
    }
    let mut i0 = 0;
    while i0 < m {
        let ib = MC.min(m - i0);
        let mut k0 = 0;
        while k0 < k {
            let kb = KC.min(k - k0);
            let mut j0 = 0;
            while j0 < n {
                let jb = NC.min(n - j0);
                // Micro: process 4 rows of A together.
                let mut i = 0;
                while i + 4 <= ib {
                    let (r0, r1, r2, r3) = (i0 + i, i0 + i + 1, i0 + i + 2, i0 + i + 3);
                    for kk in 0..kb {
                        let a0 = alpha * a[r0 * k + k0 + kk];
                        let a1 = alpha * a[r1 * k + k0 + kk];
                        let a2 = alpha * a[r2 * k + k0 + kk];
                        let a3 = alpha * a[r3 * k + k0 + kk];
                        if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                            continue;
                        }
                        let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + j0 + jb];
                        let c0 = r0 * n + j0;
                        let c1 = r1 * n + j0;
                        let c2 = r2 * n + j0;
                        let c3 = r3 * n + j0;
                        for (jj, &bv) in brow.iter().enumerate() {
                            c[c0 + jj] += a0 * bv;
                            c[c1 + jj] += a1 * bv;
                            c[c2 + jj] += a2 * bv;
                            c[c3 + jj] += a3 * bv;
                        }
                    }
                    i += 4;
                }
                // Remainder rows.
                while i < ib {
                    let r = i0 + i;
                    for kk in 0..kb {
                        let av = alpha * a[r * k + k0 + kk];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + j0 + jb];
                        let crow = &mut c[r * n + j0..r * n + j0 + jb];
                        for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += av * bv;
                        }
                    }
                    i += 1;
                }
                j0 += NC;
            }
            k0 += KC;
        }
        i0 += MC;
    }
}

fn gemm_generic(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    let at = |i: usize, kk: usize| match ta {
        Trans::No => a[i * k + kk],
        Trans::Yes => a[kk * m + i],
    };
    for i in 0..m {
        match tb {
            Trans::No => {
                // Accumulate row-wise over contiguous B rows.
                let crow = &mut c[i * n..(i + 1) * n];
                if beta != 1.0 {
                    for v in crow.iter_mut() {
                        *v *= beta;
                    }
                }
                for kk in 0..k {
                    let av = alpha * at(i, kk);
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..kk * n + n];
                    for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += av * bv;
                    }
                }
            }
            Trans::Yes => {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    // B^T: element (kk, j) is b[j * k + kk] — contiguous in kk.
                    let bcol = &b[j * k..j * k + k];
                    for (kk, &bv) in bcol.iter().enumerate() {
                        acc += at(i, kk) * bv;
                    }
                    let idx = i * n + j;
                    c[idx] = alpha * acc + beta * c[idx];
                }
            }
        }
    }
}

/// Row-major GEMV: y = alpha*op(A)*x + beta*y, A is m×n.
pub fn gemv(
    ta: Trans,
    m: usize,
    n: usize,
    alpha: f32,
    a: &[f32],
    x: &[f32],
    beta: f32,
    y: &mut [f32],
) {
    match ta {
        Trans::No => {
            assert!(a.len() >= m * n && x.len() >= n && y.len() >= m);
            for i in 0..m {
                let row = &a[i * n..i * n + n];
                let mut acc = 0.0f32;
                for (av, xv) in row.iter().zip(x.iter()) {
                    acc += av * xv;
                }
                y[i] = alpha * acc + beta * y[i];
            }
        }
        Trans::Yes => {
            assert!(a.len() >= m * n && x.len() >= m && y.len() >= n);
            if beta != 1.0 {
                for v in y[..n].iter_mut() {
                    *v *= beta;
                }
            }
            for i in 0..m {
                let av = alpha * x[i];
                if av == 0.0 {
                    continue;
                }
                let row = &a[i * n..i * n + n];
                for (yv, rv) in y[..n].iter_mut().zip(row.iter()) {
                    *yv += av * rv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::tcheck;

    fn naive_gemm(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: &[f32],
        b: &[f32],
        beta: f32,
        c: &mut [f32],
    ) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    let av = match ta {
                        Trans::No => a[i * k + kk],
                        Trans::Yes => a[kk * m + i],
                    };
                    let bv = match tb {
                        Trans::No => b[kk * n + j],
                        Trans::Yes => b[j * k + kk],
                    };
                    acc += av * bv;
                }
                c[i * n + j] = alpha * acc + beta * c[i * n + j];
            }
        }
    }

    #[test]
    fn small_closed_form() {
        // [[1,2],[3,4]] * [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0; 4];
        gemm(Trans::No, Trans::No, 2, 2, 2, 1.0, &a, &b, 0.0, &mut c);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn alpha_beta() {
        let a = [1.0, 0.0, 0.0, 1.0];
        let b = [2.0, 0.0, 0.0, 2.0];
        let mut c = [1.0, 1.0, 1.0, 1.0];
        gemm(Trans::No, Trans::No, 2, 2, 2, 0.5, &a, &b, 2.0, &mut c);
        assert_eq!(c, [3.0, 2.0, 2.0, 3.0]);
    }

    #[test]
    fn all_transpose_combos_match_naive() {
        tcheck::check("gemm_vs_naive", 48, |rng| {
            let m = rng.range_u(1, 33) as usize;
            let n = rng.range_u(1, 33) as usize;
            let k = rng.range_u(1, 33) as usize;
            let ta = if rng.bernoulli(0.5) { Trans::Yes } else { Trans::No };
            let tb = if rng.bernoulli(0.5) { Trans::Yes } else { Trans::No };
            let alpha = rng.uniform(-2.0, 2.0);
            let beta = if rng.bernoulli(0.5) { 0.0 } else { rng.uniform(-1.0, 1.0) };
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            let mut c = vec![0.0; m * n];
            rng.fill_uniform(&mut a, -1.0, 1.0);
            rng.fill_uniform(&mut b, -1.0, 1.0);
            rng.fill_uniform(&mut c, -1.0, 1.0);
            let mut c_ref = c.clone();
            gemm(ta, tb, m, n, k, alpha, &a, &b, beta, &mut c);
            naive_gemm(ta, tb, m, n, k, alpha, &a, &b, beta, &mut c_ref);
            tcheck::close(&c, &c_ref, 1e-4, 1e-4)
        });
    }

    #[test]
    fn large_shapes_cross_tile_boundaries() {
        let mut rng = Pcg32::new(5);
        // m not divisible by 4/MC; k crosses KC; n crosses NC.
        let (m, n, k) = (67, 521, 300);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_uniform(&mut a, -1.0, 1.0);
        rng.fill_uniform(&mut b, -1.0, 1.0);
        let mut c = vec![0.0; m * n];
        let mut c_ref = vec![0.0; m * n];
        gemm(Trans::No, Trans::No, m, n, k, 1.0, &a, &b, 0.0, &mut c);
        naive_gemm(Trans::No, Trans::No, m, n, k, 1.0, &a, &b, 0.0, &mut c_ref);
        tcheck::close(&c, &c_ref, 1e-3, 1e-4).unwrap();
    }

    #[test]
    fn gemv_matches_gemm() {
        tcheck::check("gemv_vs_gemm", 32, |rng| {
            let m = rng.range_u(1, 40) as usize;
            let n = rng.range_u(1, 40) as usize;
            let t = if rng.bernoulli(0.5) { Trans::Yes } else { Trans::No };
            let (xl, yl) = match t {
                Trans::No => (n, m),
                Trans::Yes => (m, n),
            };
            let mut a = vec![0.0; m * n];
            let mut x = vec![0.0; xl];
            let mut y = vec![0.0; yl];
            rng.fill_uniform(&mut a, -1.0, 1.0);
            rng.fill_uniform(&mut x, -1.0, 1.0);
            rng.fill_uniform(&mut y, -1.0, 1.0);
            let mut y_ref = y.clone();
            gemv(t, m, n, 1.5, &a, &x, 0.5, &mut y);
            // gemv == gemm with a 1-column vector, using matching op dims.
            match t {
                Trans::No => naive_gemm(Trans::No, Trans::No, m, 1, n, 1.5, &a, &x, 0.5, &mut y_ref),
                Trans::Yes => naive_gemm(Trans::Yes, Trans::No, n, 1, m, 1.5, &a, &x, 0.5, &mut y_ref),
            }
            tcheck::close(&y, &y_ref, 1e-4, 1e-4)
        });
    }
}
