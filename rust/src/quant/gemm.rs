//! Emulated low-precision GEMM/GEMV: int8 operands, i32 accumulation,
//! requantize to f32 at the output — the arithmetic contract of an
//! int8 OpenCL systolic kernel, run on the host for numerics.
//!
//! Determinism: integer accumulation is exact and associative, so the
//! result is bit-identical at any thread count by construction; work is
//! sharded over *output rows* only (each row is accumulated serially by
//! exactly one worker), mirroring the fp32 packed kernel's guarantee.

use crate::util::pool;

/// Quantization parameters for one operand: `real = scale · (q - zero_point)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QuantParams {
    /// Symmetric params (zero_point 0) from a maxabs: `scale = maxabs/127`,
    /// with an all-zero tensor mapping to scale 1.0 so dequantization is
    /// well-defined.
    pub fn symmetric(maxabs: f32) -> QuantParams {
        let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
        QuantParams { scale, zero_point: 0 }
    }

    /// Asymmetric params covering `[lo, hi]` on the int8 grid (used for
    /// activations, whose ranges are one-sided after ReLU).
    pub fn affine(lo: f32, hi: f32) -> QuantParams {
        let (lo, hi) = (lo.min(0.0), hi.max(0.0)); // grid must contain 0
        let span = hi - lo;
        if !span.is_finite() || span <= 0.0 {
            return QuantParams { scale: 1.0, zero_point: 0 };
        }
        let scale = span / 255.0;
        // zero_point is the int8 code representing real 0, rounded to the
        // nearest representable code.
        let zp = (-128.0 - lo / scale).round().clamp(-128.0, 127.0) as i32;
        QuantParams { scale, zero_point: zp }
    }

    /// Params for an observed `[lo, hi]` range: one-sided non-negative
    /// ranges (post-ReLU activations) use the full asymmetric grid,
    /// two-sided ranges stay symmetric — which also recovers the *exact*
    /// scale of a fake-quantized weight blob, making its re-quantization
    /// lossless. Degenerate/unobserved ranges fall back to identity.
    pub fn for_range(lo: f32, hi: f32) -> QuantParams {
        if !lo.is_finite() || !hi.is_finite() || lo > hi {
            return QuantParams { scale: 1.0, zero_point: 0 };
        }
        if lo >= 0.0 {
            QuantParams::affine(lo, hi)
        } else {
            QuantParams::symmetric((-lo).max(hi))
        }
    }
}

/// Serial maxabs scan (deterministic; f32 max is order-independent for
/// finite inputs anyway).
pub fn maxabs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// Serial (min, max) scan; empty input yields `(inf, -inf)`, which
/// [`QuantParams::for_range`] maps to identity params.
pub fn minmax(xs: &[f32]) -> (f32, f32) {
    xs.iter()
        .fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &x| (lo.min(x), hi.max(x)))
}

/// Quantize one value to the int8 grid of `p`.
#[inline]
pub fn quantize(x: f32, p: QuantParams) -> i8 {
    let q = (x / p.scale).round() as i64 + i64::from(p.zero_point);
    q.clamp(-128, 127) as i8
}

/// Dequantize one int8 code.
#[inline]
pub fn dequantize(q: i8, p: QuantParams) -> f32 {
    (i32::from(q) - p.zero_point) as f32 * p.scale
}

/// Quantize a slice.
pub fn quantize_slice(xs: &[f32], p: QuantParams) -> Vec<i8> {
    xs.iter().map(|&x| quantize(x, p)).collect()
}

/// i32 accumulator headroom: with zero-points subtracted each product is
/// bounded by 255·255, so k ≤ 33 025 708 rows stay exact in i32. The
/// largest reduction in the zoo is vgg16 fc6 (k = 25 088 · 1 ≈ 2.5e4;
/// conv gemms top out near 4.6e3), orders of magnitude inside the bound.
pub const MAX_EXACT_K: usize = (i32::MAX as usize) / (255 * 255);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trans {
    No,
    Yes,
}

/// Index of logical `A[i, l]` for an m×k matrix stored row-major as
/// m×k (`Trans::No`) or k×m (`Trans::Yes`) — the `math::gemm` layout
/// convention.
#[inline]
fn a_idx(ta: Trans, m: usize, k: usize, i: usize, l: usize) -> usize {
    let _ = m;
    match ta {
        Trans::No => i * k + l,
        Trans::Yes => l * m + i,
    }
}

/// Index of logical `B[l, j]` for a k×n matrix stored row-major as
/// k×n (`Trans::No`) or n×k (`Trans::Yes`).
#[inline]
fn b_idx(tb: Trans, k: usize, n: usize, l: usize, j: usize) -> usize {
    let _ = n;
    match tb {
        Trans::No => l * n + j,
        Trans::Yes => j * k + l,
    }
}

/// Int8 GEMM: `C = alpha · dequant(Aq ·i32 Bq) + beta · C` where the
/// inner product runs entirely in i32 over zero-point-corrected codes,
/// then requantizes with `sa·sb`. Shapes follow `math::gemm`: A is
/// logically m×k, B is k×n, C is m×n row-major; `trans` flags give the
/// stored layout of A and B.
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[i8],
    pa: QuantParams,
    b: &[i8],
    pb: QuantParams,
    beta: f32,
    c: &mut [f32],
) {
    assert!(a.len() >= m * k, "qgemm: A too short");
    assert!(b.len() >= k * n, "qgemm: B too short");
    assert!(c.len() >= m * n, "qgemm: C too short");
    assert!(k <= MAX_EXACT_K, "qgemm: k={k} exceeds exact i32 accumulation bound");
    let requant = pa.scale * pb.scale;
    let za = pa.zero_point;
    let zb = pb.zero_point;
    // Shard output rows: each row's dot products are serial, so the
    // split cannot change any accumulation order.
    let grain = (m * n).div_ceil(pool::current_threads().max(1)).max(n);
    let grain = grain.div_ceil(n) * n; // whole rows only
    pool::parallel_chunks_mut(&mut c[..m * n], grain, |off, rows| {
        debug_assert_eq!(off % n, 0);
        for (ri, crow) in rows.chunks_mut(n).enumerate() {
            let i = off / n + ri;
            for (j, cv) in crow.iter_mut().enumerate() {
                let mut acc: i32 = 0;
                for l in 0..k {
                    let av = i32::from(a[a_idx(ta, m, k, i, l)]);
                    let bv = i32::from(b[b_idx(tb, k, n, l, j)]);
                    acc += (av - za) * (bv - zb);
                }
                let real = acc as f32 * requant;
                *cv = if beta == 0.0 { alpha * real } else { alpha * real + beta * *cv };
            }
        }
    });
}

/// Int8 GEMV with the same contract; `trans == Yes` computes `A^T x`.
#[allow(clippy::too_many_arguments)]
pub fn qgemv(
    trans: Trans,
    m: usize,
    n: usize,
    alpha: f32,
    a: &[i8],
    pa: QuantParams,
    x: &[i8],
    px: QuantParams,
    beta: f32,
    y: &mut [f32],
) {
    let (rows, k) = match trans {
        Trans::No => (m, n),
        Trans::Yes => (n, m),
    };
    assert!(a.len() >= m * n, "qgemv: A too short");
    assert!(x.len() >= k, "qgemv: x too short");
    assert!(y.len() >= rows, "qgemv: y too short");
    assert!(k <= MAX_EXACT_K, "qgemv: k={k} exceeds exact i32 accumulation bound");
    let requant = pa.scale * px.scale;
    let za = pa.zero_point;
    let zx = px.zero_point;
    let grain = rows.div_ceil(pool::current_threads().max(1)).max(1);
    pool::parallel_chunks_mut(&mut y[..rows], grain, |off, chunk| {
        for (ri, yv) in chunk.iter_mut().enumerate() {
            let r = off + ri;
            let mut acc: i32 = 0;
            for l in 0..k {
                let av = match trans {
                    Trans::No => i32::from(a[r * n + l]),
                    Trans::Yes => i32::from(a[l * n + r]),
                };
                acc += (av - za) * (i32::from(x[l]) - zx);
            }
            let real = acc as f32 * requant;
            *yv = if beta == 0.0 { alpha * real } else { alpha * real + beta * *yv };
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_qgemm(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: &[i8],
        pa: QuantParams,
        b: &[i8],
        pb: QuantParams,
        beta: f32,
        c: &mut [f32],
    ) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for l in 0..k {
                    // Independent index arithmetic (no shared helpers):
                    // A[i,l] and B[l,j] in the math::gemm storage layout.
                    let av = i32::from(match ta {
                        Trans::No => a[i * k + l],
                        Trans::Yes => a[l * m + i],
                    });
                    let bv = i32::from(match tb {
                        Trans::No => b[l * n + j],
                        Trans::Yes => b[j * k + l],
                    });
                    acc += (av - pa.zero_point) * (bv - pb.zero_point);
                }
                let real = acc as f32 * pa.scale * pb.scale;
                c[i * n + j] = if beta == 0.0 {
                    alpha * real
                } else {
                    alpha * real + beta * c[i * n + j]
                };
            }
        }
    }

    fn fill(seed: u64, len: usize) -> Vec<i8> {
        let mut s = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s % 255) as i64 as i8
            })
            .collect()
    }

    #[test]
    fn matches_naive_all_transpose_combos() {
        let pa = QuantParams { scale: 0.02, zero_point: 3 };
        let pb = QuantParams::symmetric(1.27);
        for &(ta, tb) in &[
            (Trans::No, Trans::No),
            (Trans::No, Trans::Yes),
            (Trans::Yes, Trans::No),
            (Trans::Yes, Trans::Yes),
        ] {
            let (m, n, k) = (7, 11, 13);
            let a = fill(1, m * k);
            let b = fill(2, k * n);
            let mut c = vec![0.5f32; m * n];
            let mut c2 = c.clone();
            qgemm(ta, tb, m, n, k, 0.7, &a, pa, &b, pb, 0.3, &mut c);
            naive_qgemm(ta, tb, m, n, k, 0.7, &a, pa, &b, pb, 0.3, &mut c2);
            assert_eq!(c, c2, "mismatch for ({ta:?},{tb:?})");
        }
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let (m, n, k) = (33, 17, 65);
        let a = fill(3, m * k);
        let b = fill(4, k * n);
        let pa = QuantParams { scale: 0.013, zero_point: -7 };
        let pb = QuantParams::symmetric(0.9);
        let mut base = vec![0.0f32; m * n];
        pool::with_intra_op(1, || qgemm(Trans::No, Trans::Yes, m, n, k, 1.0, &a, pa, &b, pb, 0.0, &mut base));
        for t in [2usize, 3, 8] {
            let mut c = vec![0.0f32; m * n];
            pool::with_intra_op(t, || {
                qgemm(Trans::No, Trans::Yes, m, n, k, 1.0, &a, pa, &b, pb, 0.0, &mut c);
            });
            assert_eq!(c, base, "qgemm differs at {t} threads");
        }
        let mut ybase = vec![0.0f32; m];
        pool::with_intra_op(1, || qgemv(Trans::No, m, n, 1.0, &a, pa, &b[..n], pb, 0.0, &mut ybase));
        for t in [2usize, 5] {
            let mut y = vec![0.0f32; m];
            pool::with_intra_op(t, || {
                qgemv(Trans::No, m, n, 1.0, &a, pa, &b[..n], pb, 0.0, &mut y);
            });
            assert_eq!(y, ybase, "qgemv differs at {t} threads");
        }
    }

    #[test]
    fn quantize_roundtrip_error_bounded_by_half_scale() {
        let xs: Vec<f32> = (0..1000).map(|i| ((i as f32) * 0.77).sin() * 3.0).collect();
        let p = QuantParams::symmetric(maxabs(&xs));
        for &x in &xs {
            let err = (dequantize(quantize(x, p), p) - x).abs();
            assert!(err <= p.scale / 2.0 + 1e-7, "x={x} err={err} scale={}", p.scale);
        }
        // Requantizing a dequantized grid value is lossless.
        for q in -128i8..=127 {
            assert_eq!(quantize(dequantize(q, p), p), q);
        }
    }

    #[test]
    fn affine_params_cover_range_and_pin_zero() {
        let p = QuantParams::affine(0.0, 6.0); // post-ReLU style range
        assert_eq!(p.zero_point, -128);
        assert!((dequantize(-128, p)).abs() < 1e-7, "real 0 must be exact");
        assert!((dequantize(127, p) - 6.0).abs() < 1e-5);
        let p = QuantParams::affine(-1.0, 3.0);
        assert!((dequantize(quantize(0.0, p), p)).abs() < 1e-7);
        // Degenerate range falls back to identity-ish params.
        let p = QuantParams::affine(0.0, 0.0);
        assert_eq!(p, QuantParams { scale: 1.0, zero_point: 0 });
    }

    #[test]
    fn gemv_matches_gemm_row() {
        let (m, n) = (9, 21);
        let a = fill(9, m * n);
        let x = fill(10, n);
        let pa = QuantParams::symmetric(2.0);
        let px = QuantParams { scale: 0.05, zero_point: 11 };
        let mut y = vec![0.0f32; m];
        qgemv(Trans::No, m, n, 1.0, &a, pa, &x, px, 0.0, &mut y);
        let mut c = vec![0.0f32; m];
        qgemm(Trans::No, Trans::No, m, 1, n, 1.0, &a, pa, &x, px, 0.0, &mut c);
        assert_eq!(y, c);
    }
}
