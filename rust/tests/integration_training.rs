//! Training integration: convergence on the synthetic digit task across
//! devices and solvers, snapshot-resume determinism, and the Caffe-style
//! solver configuration path (prototxt text end to end).

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::net::Net;
use fecaffe::proto::{parse_solver, Phase};
use fecaffe::solver::{snapshot, Solver};
use fecaffe::zoo;

#[test]
fn lenet_converges_on_fpga_sim() {
    let mut dev = FpgaSimDevice::new();
    let param = zoo::by_name("lenet", 32).unwrap();
    let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    let mut sp = zoo::default_solver("lenet").unwrap();
    sp.display = 0;
    let mut solver = Solver::new(sp, net, &mut dev).unwrap();
    for _ in 0..60 {
        solver.step(&mut dev).unwrap();
    }
    let head: f32 = solver.loss_history[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = solver.loss_history.iter().rev().take(5).sum::<f32>() / 5.0;
    assert!(
        tail < head * 0.7,
        "no convergence on fpga-sim: {head:.3} -> {tail:.3}"
    );
    // Training really ran on the simulated device.
    assert!(dev.profiler.total_instances() > 1000);
}

#[test]
fn solver_prototxt_end_to_end() {
    let text = r#"
net: "lenet"
type: "Nesterov"
base_lr: 0.01
lr_policy: "step"
gamma: 0.5
stepsize: 40
momentum: 0.9
weight_decay: 0.0005
display: 0
"#;
    let sp = parse_solver(text).unwrap();
    let mut dev = CpuDevice::new();
    let param = zoo::by_name(&sp.net, 16).unwrap();
    let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    let mut solver = Solver::new(sp, net, &mut dev).unwrap();
    let l0 = solver.step(&mut dev).unwrap();
    for _ in 0..40 {
        solver.step(&mut dev).unwrap();
    }
    let l1 = *solver.loss_history.last().unwrap();
    assert!(l1.is_finite() && l1 < l0 * 1.5);
    // lr stepped down after stepsize iterations
    assert!((solver.learning_rate().unwrap() - 0.005).abs() < 1e-6);
}

#[test]
fn snapshot_resume_after_restart_is_deterministic() {
    let run = |resume_at: Option<usize>| -> Vec<f32> {
        let mut dev = CpuDevice::new();
        let param = zoo::by_name("lenet", 8).unwrap();
        let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
        let mut sp = zoo::default_solver("lenet").unwrap();
        sp.display = 0;
        let mut solver = Solver::new(sp, net, &mut dev).unwrap();
        let snap = std::env::temp_dir().join("fecaffe_it_resume.bin");
        if let Some(at) = resume_at {
            // advance the data stream like the original run did
            for _ in 0..at {
                solver.net.forward(&mut dev).unwrap();
            }
            snapshot::restore(&snap, &mut solver, &mut dev).unwrap();
        } else {
            for _ in 0..4 {
                solver.step(&mut dev).unwrap();
            }
            snapshot::save(&snap, &solver, &mut dev).unwrap();
        }
        let mut out = Vec::new();
        for _ in 0..4 {
            out.push(solver.step(&mut dev).unwrap());
        }
        out
    };
    let original = run(None);
    let resumed = run(Some(4));
    for (a, b) in original.iter().zip(resumed.iter()) {
        assert!((a - b).abs() < 1e-5, "{original:?} vs {resumed:?}");
    }
}

#[test]
fn adam_trains_googlenet_stem_without_nans() {
    // A GoogLeNet-like slice (stem + one inception) at tiny resolution
    // would need a custom net; instead run full GoogLeNet 2 iterations at
    // batch 1 with Adam and check numerics stay finite end to end.
    let mut dev = CpuDevice::new();
    let param = zoo::by_name("googlenet", 1).unwrap();
    let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    let mut sp = zoo::default_solver("googlenet").unwrap();
    sp.display = 0;
    let mut solver = Solver::new(sp, net, &mut dev).unwrap();
    for _ in 0..2 {
        let loss = solver.step(&mut dev).unwrap();
        assert!(loss.is_finite(), "loss diverged: {loss}");
        // three loss heads: total ≈ (1 + 0.3 + 0.3) * ln(1000) at init
        assert!(loss > 2.0 && loss < 20.0, "implausible loss {loss}");
    }
}

#[test]
fn accuracy_improves_with_training() {
    let mut dev = CpuDevice::new();
    let param = zoo::by_name("lenet", 32).unwrap();
    let net = Net::from_param(&param, Phase::Train, &mut dev).unwrap();
    let mut sp = zoo::default_solver("lenet").unwrap();
    sp.display = 0;
    let mut solver = Solver::new(sp, net, &mut dev).unwrap();

    let eval = |solver: &Solver, dev: &mut CpuDevice| -> f32 {
        let tp = zoo::by_name("lenet", 100).unwrap();
        let mut tnet = Net::from_param(&tp, Phase::Test, dev).unwrap();
        for (src, dst) in solver.net.params().iter().zip(tnet.params().iter()) {
            let w = src.blob.borrow_mut().data_vec(dev);
            dst.blob.borrow_mut().set_data(dev, &w);
        }
        tnet.forward(dev).unwrap();
        tnet.blob("accuracy").unwrap().borrow_mut().data_vec(dev)[0]
    };

    let acc0 = eval(&solver, &mut dev);
    for _ in 0..80 {
        solver.step(&mut dev).unwrap();
    }
    let acc1 = eval(&solver, &mut dev);
    assert!(
        acc1 > acc0 + 0.2,
        "accuracy did not improve: {acc0:.2} -> {acc1:.2}"
    );
}
