//! Snapshot save/restore — the paper's Table 4 "Ease of Use" row
//! explicitly lists snapshot support as part of the conventional-Caffe
//! workflow FeCaffe keeps.
//!
//! Format (own binary container; no protobuf offline):
//! `FECAFFE1` magic · u32 iter · u32 param count · per param:
//! u32 len · len×f32 data · len×f32 solver history (all slots).

use super::Solver;
use crate::device::Device;
use crate::util::binio::{get_f32s, get_u32, put_f32s, put_u32};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"FECAFFE1";

pub fn save(path: impl AsRef<Path>, solver: &Solver, dev: &mut dyn Device) -> anyhow::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut w = BufWriter::new(File::create(&path)?);
    w.write_all(MAGIC)?;
    put_u32(&mut w, solver.iter as u32)?;
    put_u32(&mut w, solver.net.params().len() as u32)?;
    for (i, p) in solver.net.params().iter().enumerate() {
        let mut blob = p.blob.borrow_mut();
        let n = blob.count();
        put_u32(&mut w, n as u32)?;
        put_f32s(&mut w, blob.data.host_data(dev))?;
        // history slots
        let slots = solver.history_slots(i);
        put_u32(&mut w, slots.len() as u32)?;
        for &h in slots {
            let mut buf = vec![0.0f32; n];
            dev.read(h, &mut buf);
            put_f32s(&mut w, &buf)?;
        }
    }
    Ok(())
}

pub fn restore(
    path: impl AsRef<Path>,
    solver: &mut Solver,
    dev: &mut dyn Device,
) -> anyhow::Result<()> {
    let mut r = BufReader::new(File::open(&path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad snapshot magic");
    solver.iter = get_u32(&mut r)? as usize;
    let count = get_u32(&mut r)? as usize;
    anyhow::ensure!(
        count == solver.net.params().len(),
        "snapshot has {count} params, net has {}",
        solver.net.params().len()
    );
    for i in 0..count {
        let n = get_u32(&mut r)? as usize;
        let p = &solver.net.params()[i];
        anyhow::ensure!(
            n == p.blob.borrow().count(),
            "param {i}: snapshot len {n} != blob len {}",
            p.blob.borrow().count()
        );
        let data = get_f32s(&mut r, n)?;
        p.blob.borrow_mut().set_data(dev, &data);
        let nslots = get_u32(&mut r)? as usize;
        let slots: Vec<crate::device::BufId> = solver.history_slots(i).to_vec();
        anyhow::ensure!(nslots == slots.len(), "history slot mismatch");
        for h in slots {
            let hist = get_f32s(&mut r, n)?;
            dev.write(h, &hist);
        }
    }
    Ok(())
}

impl Solver {
    /// History buffer ids for param `i` (for snapshotting).
    pub fn history_slots(&self, i: usize) -> &[crate::device::BufId] {
        &self.history[i]
    }
}

#[cfg(test)]
mod tests {
    use super::super::Solver;
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::net::Net;
    use crate::proto::{parse_net, Phase, SolverParameter};

    const NET: &str = r#"
name: "t"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 4 channels: 1 height: 8 width: 8 num_classes: 3 source: "digits" seed: 5 } }
layer { name: "fc" type: "InnerProduct" bottom: "data" top: "fc"
        inner_product_param { num_output: 3 weight_filler { type: "xavier" } } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc" bottom: "label" top: "loss" }
"#;

    fn mk(dev: &mut CpuDevice) -> Solver {
        let netp = parse_net(NET).unwrap();
        let net = Net::from_param(&netp, Phase::Train, dev).unwrap();
        let mut sp = SolverParameter::default();
        sp.display = 0;
        Solver::new(sp, net, dev).unwrap()
    }

    #[test]
    fn save_restore_resumes_identically() {
        let tmp = std::env::temp_dir().join("fecaffe_snapshot_test.bin");
        // Train A for 5 iters, snapshot, train 3 more → record losses.
        let mut dev_a = CpuDevice::new();
        let mut a = mk(&mut dev_a);
        for _ in 0..5 {
            a.step(&mut dev_a).unwrap();
        }
        save(&tmp, &a, &mut dev_a).unwrap();
        let losses_a: Vec<f32> = (0..3).map(|_| a.step(&mut dev_a).unwrap()).collect();

        // Fresh solver B restores the snapshot → must reproduce losses.
        // (Data layer streams are seeded by iteration-independent PRNGs, so
        // restore + same step count ⇒ same batches.)
        let mut dev_b = CpuDevice::new();
        let mut b = mk(&mut dev_b);
        // advance B's data stream by the same 5 batches A consumed
        for _ in 0..5 {
            b.net.forward(&mut dev_b).unwrap();
        }
        restore(&tmp, &mut b, &mut dev_b).unwrap();
        assert_eq!(b.iter, 5);
        let losses_b: Vec<f32> = (0..3).map(|_| b.step(&mut dev_b).unwrap()).collect();
        for (x, y) in losses_a.iter().zip(losses_b.iter()) {
            assert!((x - y).abs() < 1e-5, "{losses_a:?} vs {losses_b:?}");
        }
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn restore_rejects_mismatched_net() {
        let tmp = std::env::temp_dir().join("fecaffe_snapshot_test2.bin");
        let mut dev = CpuDevice::new();
        let a = mk(&mut dev);
        save(&tmp, &a, &mut dev).unwrap();
        // Build a different net (more outputs) and try to restore.
        let text = NET.replace("num_output: 3", "num_output: 5");
        let netp = parse_net(&text).unwrap();
        let mut dev2 = CpuDevice::new();
        let net = Net::from_param(&netp, Phase::Train, &mut dev2).unwrap();
        let mut sp = SolverParameter::default();
        sp.display = 0;
        let mut b = Solver::new(sp, net, &mut dev2).unwrap();
        assert!(restore(&tmp, &mut b, &mut dev2).is_err());
        let _ = std::fs::remove_file(tmp);
    }
}
