//! Little-endian binary IO helpers shared by the on-disk containers:
//! the solver's `FECAFFE1` training snapshot (`solver::snapshot`) and
//! the serving engine's `FEWSNAP1` weight snapshot
//! (`net::WeightSnapshot::{save, load}`). One copy of the format
//! plumbing, so endianness and error handling can't drift between the
//! two.

use std::io::{Read, Write};

pub fn put_u32(w: &mut impl Write, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn put_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn put_f32s(w: &mut impl Write, vs: &[f32]) -> std::io::Result<()> {
    for v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// u32 length prefix + raw UTF-8 bytes.
pub fn put_str(w: &mut impl Write, s: &str) -> std::io::Result<()> {
    put_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())
}

pub fn get_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn get_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn get_f32s(r: &mut impl Read, n: usize) -> std::io::Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Counterpart of [`put_str`]; fails on non-UTF-8 bytes. `max_len`
/// bounds the length prefix *before* the allocation, so a corrupt
/// container can't request gigabytes — pass the container's total size
/// (or a tighter format-specific cap).
pub fn get_str(r: &mut impl Read, max_len: usize) -> anyhow::Result<String> {
    let len = get_u32(r)? as usize;
    anyhow::ensure!(
        len <= max_len,
        "string length {len} exceeds container bound {max_len}"
    );
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    String::from_utf8(bytes).map_err(|_| anyhow::anyhow!("non-utf8 string in container"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEAD_BEEF).unwrap();
        put_u64(&mut buf, u64::MAX - 1).unwrap();
        put_str(&mut buf, "iter-42").unwrap();
        put_f32s(&mut buf, &[1.5, -0.25, f32::MIN_POSITIVE]).unwrap();
        let mut r = buf.as_slice();
        assert_eq!(get_u32(&mut r).unwrap(), 0xDEAD_BEEF);
        assert_eq!(get_u64(&mut r).unwrap(), u64::MAX - 1);
        assert_eq!(get_str(&mut r, 64).unwrap(), "iter-42");
        assert_eq!(
            get_f32s(&mut r, 3).unwrap(),
            vec![1.5, -0.25, f32::MIN_POSITIVE]
        );
        assert!(r.is_empty());
    }

    #[test]
    fn truncated_reads_error() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 7).unwrap();
        let mut r = &buf[..2];
        assert!(get_u32(&mut r).is_err());
        let mut r = buf.as_slice();
        assert!(get_f32s(&mut r, 2).is_err());
    }

    #[test]
    fn get_str_refuses_lengths_over_the_bound_before_allocating() {
        let mut buf = Vec::new();
        put_u32(&mut buf, u32::MAX).unwrap(); // bogus 4 GiB length prefix
        let mut r = buf.as_slice();
        let err = get_str(&mut r, 1024).unwrap_err().to_string();
        assert!(err.contains("exceeds container bound"), "{err}");
    }
}
