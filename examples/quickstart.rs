//! Quickstart: build a small CNN from prototxt text, run it on the
//! simulated FPGA through the full stack (PJRT artifacts when present),
//! inspect the memory-state machine and the profiler.
//!
//!     cargo run --release --example quickstart

use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::Device;
use fecaffe::net::Net;
use fecaffe::proto::{self, Phase};
use fecaffe::runtime::PjrtBackend;

const NET: &str = r#"
name: "quickstart"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 4 channels: 1 height: 28 width: 28
                     num_classes: 10 source: "digits" seed: 42 } }
layer { name: "conv1" type: "Convolution" bottom: "data" top: "conv1"
        convolution_param { num_output: 8 kernel_size: 5 stride: 1
          weight_filler { type: "xavier" } } }
layer { name: "relu1" type: "ReLU" bottom: "conv1" top: "conv1" }
layer { name: "pool1" type: "Pooling" bottom: "conv1" top: "pool1"
        pooling_param { pool: MAX kernel_size: 2 stride: 2 } }
layer { name: "fc1" type: "InnerProduct" bottom: "pool1" top: "fc1"
        inner_product_param { num_output: 10 weight_filler { type: "xavier" } } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc1" bottom: "label" top: "loss" }
"#;

fn main() -> anyhow::Result<()> {
    // 1. Parse standard Caffe prototxt.
    let param = proto::parse_net(NET).map_err(anyhow::Error::msg)?;
    println!("Parsed '{}' with {} layers", param.name, param.layers.len());

    // 2. A simulated Stratix 10 board; kernels execute through the AOT
    //    PJRT artifacts when `make artifacts` has run, else native math.
    let mut dev = FpgaSimDevice::new();
    if let Some(backend) = PjrtBackend::auto() {
        println!("Using PJRT artifacts (the .aocx analogue)");
        dev = dev.with_backend(Box::new(backend));
    } else {
        println!("No artifacts found — native math fallback");
    }

    // 3. Build the net (auto-split insertion, weight init, DDR allocation).
    let mut net = Net::from_param(&param, Phase::Train, &mut dev)?;
    println!(
        "Net ready: {} parameters, {} blobs, {} B device DDR in use",
        net.num_parameters(),
        net.blob_names().len(),
        dev.ddr().used()
    );

    // 4. Forward + backward.
    let loss = net.forward_backward(&mut dev)?;
    println!("loss = {loss:.4} (≈ ln(10) = 2.3026 for random init)");

    // 5. What did the board do? (paper Table 2 style)
    println!("\nKernel activity:");
    for (class, s) in dev.profiler.stats() {
        println!(
            "  {:<14} x{:<4} {:>10.3} ms",
            class.label(),
            s.instances,
            s.total_ns as f64 / 1e6
        );
    }
    println!(
        "\nSimulated device time: {:.3} ms  ({} artifact launches, {} native)",
        dev.sim_clock_ns().unwrap() as f64 / 1e6,
        dev.profiler.artifact_launches,
        dev.profiler.native_launches,
    );
    Ok(())
}
