#!/usr/bin/env bash
# ThreadSanitizer sweep over the concurrency surfaces:
#
#   * util::pool     — work-stealing intra-op pool (property suite)
#   * serve::queue   — bounded admission queue (MPMC handoff)
#   * serve::engine  — SharedWeights publish/adopt (RCU-style swap)
#   * serve::metrics — lock-free serving counters
#
# `-Zsanitizer=thread` is nightly-only and needs `-Zbuild-std` so std
# itself is instrumented (otherwise TSan reports false races inside
# uninstrumented std synchronization). CI runs this as the nightly
# `tsan` leg (schedule/workflow_dispatch); locally:
#
#   rustup toolchain install nightly --component rust-src
#   ./scripts/tsan.sh
set -euo pipefail
cd "$(dirname "$0")/.."

HOST="${HOST_TRIPLE:-x86_64-unknown-linux-gnu}"

if ! cargo +nightly --version >/dev/null 2>&1; then
    echo "tsan.sh: a rustup-managed nightly toolchain is required (-Zsanitizer=thread)."
    echo "  rustup toolchain install nightly --component rust-src"
    exit 2
fi
if ! rustup component list --toolchain nightly 2>/dev/null | grep -q 'rust-src.*(installed)'; then
    echo "tsan.sh: the nightly rust-src component is required (-Zbuild-std)."
    echo "  rustup component add rust-src --toolchain nightly"
    exit 2
fi

export RUSTFLAGS="-Zsanitizer=thread ${RUSTFLAGS:-}"
# TSan slows execution ~5-15x; pin a small deterministic pool size so
# the suites stay fast while still exercising cross-thread handoffs.
export FECAFFE_THREADS="${FECAFFE_THREADS:-4}"

exec cargo +nightly test --lib -Zbuild-std --target "$HOST" -- \
    util::pool serve::queue serve::engine serve::metrics
