//! `serve` — batched, multi-worker inference serving for any zoo model,
//! with a self-driven closed-loop load test and a latency/throughput
//! report.
//!
//! ```text
//! serve --net lenet --workers 4 --max-batch 32
//! serve --net googlenet --workers 2 --max-batch 8 --requests 64 --clients 8
//! serve --net lenet --device fpga --json BENCH_serve.json
//! ```

use fecaffe::serve::{load_test, DeviceKind, Engine, EngineConfig};
use fecaffe::util::cli::{usage, Args, Spec};
use fecaffe::util::json::Json;
use fecaffe::util::stats::{fmt_ns, summarize};
use fecaffe::util::table::Table;
use fecaffe::zoo;
use std::time::Duration;

const SPECS: &[Spec] = &[
    Spec::opt("net", Some("lenet"), "zoo network name or net prototxt path"),
    Spec::opt("workers", Some("4"), "worker replicas (threads)"),
    Spec::opt("max-batch", Some("32"), "micro-batch upper bound"),
    Spec::opt("linger-us", Some("2000"), "micro-batch linger deadline, microseconds"),
    Spec::opt("queue-cap", Some("1024"), "admission queue capacity (backpressure bound)"),
    Spec::opt("device", Some("cpu"), "worker device: cpu | fpga"),
    Spec::opt(
        "intra-op",
        Some("0"),
        "intra-op threads per worker (0 = split FECAFFE_THREADS evenly)",
    ),
    Spec::opt("requests", Some("512"), "load-test request count"),
    Spec::opt("clients", Some("8"), "load-test client threads"),
    Spec::opt("json", None, "also write the report as JSON to this path"),
];

fn run(args: &Args) -> anyhow::Result<()> {
    let name = args.get("net").unwrap_or("lenet");
    let param = if std::path::Path::new(name).is_file() {
        let text = std::fs::read_to_string(name)?;
        fecaffe::proto::parse_net(&text).map_err(anyhow::Error::msg)?
    } else {
        zoo::by_name(name, 1)?
    };
    let device = match args.get("device").unwrap_or("cpu") {
        "cpu" => DeviceKind::Cpu,
        "fpga" => DeviceKind::FpgaSim,
        other => anyhow::bail!("unknown device '{other}' (cpu | fpga)"),
    };
    let cfg = EngineConfig {
        workers: args.get_usize("workers").map_err(anyhow::Error::msg)?,
        max_batch: args.get_usize("max-batch").map_err(anyhow::Error::msg)?,
        max_linger: Duration::from_micros(
            args.get_usize("linger-us").map_err(anyhow::Error::msg)? as u64,
        ),
        queue_capacity: args.get_usize("queue-cap").map_err(anyhow::Error::msg)?,
        device,
        intra_op_threads: args.get_usize("intra-op").map_err(anyhow::Error::msg)?,
    };
    let requests = args.get_usize("requests").map_err(anyhow::Error::msg)?;
    let clients = args.get_usize("clients").map_err(anyhow::Error::msg)?;

    println!(
        "[serve] {} | {} worker(s) x {} intra-op thread(s) on {:?} | max-batch {} | linger {:?} | queue {}",
        param.name,
        cfg.workers,
        cfg.intra_op_budget(),
        cfg.device,
        cfg.max_batch,
        cfg.max_linger,
        cfg.queue_capacity
    );
    let engine = Engine::new(&param, cfg.clone())?;
    println!(
        "[serve] model ready: {} inputs/sample, {} outputs/sample, {} shared parameters",
        engine.sample_len(),
        engine.output_len(),
        engine.weights().num_parameters()
    );
    println!("[serve] load test: {requests} requests from {clients} client(s)...");

    let report = load_test(&engine, clients, requests, 0xF_EC_AF_FE);
    engine.shutdown();
    let snap = engine.metrics().snapshot();

    anyhow::ensure!(
        report.requests > 0,
        "load test completed no requests ({} failed) — see worker errors above",
        report.failed
    );
    let mut lats = report.latencies_ns.clone();
    let s = summarize("request latency", &mut lats);

    let mut table = Table::new(
        &format!("{} serving load test", param.name),
        &["Metric", "Value"],
    );
    table.row(&["requests completed".into(), format!("{}", report.requests)]);
    table.row(&["wall time".into(), format!("{:.3} s", report.wall.as_secs_f64())]);
    table.row(&["throughput".into(), format!("{:.1} req/s", report.rps)]);
    table.row(&["latency p50".into(), fmt_ns(s.median_ns)]);
    table.row(&["latency p95".into(), fmt_ns(s.p95_ns)]);
    table.row(&["latency p99".into(), fmt_ns(s.p99_ns)]);
    table.row(&["latency mean".into(), fmt_ns(s.mean_ns)]);
    table.row(&["batches executed".into(), format!("{}", snap.batches)]);
    table.row(&["mean batch size".into(), format!("{:.2}", snap.mean_batch)]);
    table.row(&["full batches".into(), format!("{}", snap.full_batches)]);
    table.row(&[
        "backpressure retries".into(),
        format!("{}", report.backpressure_retries),
    ]);
    table.row(&["failed requests".into(), format!("{}", report.failed)]);
    if snap.sim_batches > 0 {
        // FPGA-sim workers: batch cost in *simulated* device time (the
        // paper's cost model), alongside host wallclock.
        table.row(&["sim time / batch p50".into(), fmt_ns(snap.sim_p50_ns)]);
        table.row(&["sim time / batch p99".into(), fmt_ns(snap.sim_p99_ns)]);
        table.row(&["sim time total".into(), fmt_ns(snap.sim_total_ns as f64)]);
    }
    println!("{}", table.render());

    if let Some(path) = args.get("json") {
        let mut o = Json::obj();
        o.set("net", Json::str(param.name.clone()));
        o.set("workers", Json::num(cfg.workers as f64));
        o.set("max_batch", Json::num(cfg.max_batch as f64));
        o.set("requests", Json::num(report.requests as f64));
        o.set("rps", Json::num(report.rps));
        o.set("p50_ms", Json::num(s.median_ns / 1e6));
        o.set("p95_ms", Json::num(s.p95_ns / 1e6));
        o.set("p99_ms", Json::num(s.p99_ns / 1e6));
        o.set("mean_batch", Json::num(snap.mean_batch));
        if snap.sim_batches > 0 {
            o.set("sim_batch_p50_ms", Json::num(snap.sim_p50_ns / 1e6));
            o.set("sim_batch_p99_ms", Json::num(snap.sim_p99_ns / 1e6));
            o.set("sim_total_ms", Json::num(snap.sim_total_ns as f64 / 1e6));
        }
        std::fs::write(path, o.to_pretty())?;
        println!("[serve] wrote {path}");
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, SPECS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!(
                "error: {e}\n\n{}",
                usage("serve", "Batched multi-worker inference serving engine", SPECS)
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
